#!/usr/bin/env python
"""Markdown relative-link checker (stdlib only) — the CI docs gate.

Usage: python tools/check_links.py FILE.md [FILE.md ...]

Checks, for every ``[text](target)`` in the given markdown files:
  * http(s)/mailto targets are skipped (no network in CI),
  * a relative path target must exist on disk (resolved against the file),
  * a ``#fragment`` (same-file or on a .md target) must match a heading in
    the target file, using GitHub's anchor slug rules (lowercase, spaces ->
    dashes, punctuation dropped).

Exit code 0 when every link resolves, 1 otherwise (each broken link is
reported as ``file:line: message``).
"""
from __future__ import annotations

import re
import sys
import unicodedata
from pathlib import Path

LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "ftp://")


def github_slug(heading: str) -> str:
    """GitHub's markdown heading -> anchor id transform (close enough:
    strip markup, lowercase, drop punctuation, spaces to dashes)."""
    text = re.sub(r"[`*_]|\[([^\]]*)\]\([^)]*\)", r"\1", heading).strip()
    text = unicodedata.normalize("NFKD", text)
    out = []
    for ch in text.lower():
        if ch.isalnum() or ch in "-_ ":
            out.append("-" if ch == " " else ch)
    return "".join(out)


def anchors_of(path: Path) -> set[str]:
    slugs: set[str] = set()
    counts: dict[str, int] = {}
    for line in path.read_text(encoding="utf-8").splitlines():
        m = HEADING_RE.match(line)
        if not m:
            continue
        slug = github_slug(m.group(1))
        n = counts.get(slug, 0)
        counts[slug] = n + 1
        slugs.add(slug if n == 0 else f"{slug}-{n}")
    return slugs


def check_file(md: Path) -> list[str]:
    errors: list[str] = []
    in_code = False
    for lineno, line in enumerate(
            md.read_text(encoding="utf-8").splitlines(), 1):
        if line.lstrip().startswith("```"):
            in_code = not in_code
        if in_code:
            continue
        for m in LINK_RE.finditer(line):
            target = m.group(1)
            if target.startswith(SKIP_PREFIXES):
                continue
            path_part, _, fragment = target.partition("#")
            dest = md if not path_part else (md.parent / path_part).resolve()
            if not dest.exists():
                errors.append(f"{md}:{lineno}: broken link target "
                              f"{target!r} ({dest} does not exist)")
                continue
            if fragment and dest.suffix.lower() == ".md":
                if github_slug(fragment) not in anchors_of(dest):
                    errors.append(f"{md}:{lineno}: dangling anchor "
                                  f"#{fragment} in {dest.name}")
    return errors


def main(argv: list[str]) -> int:
    if not argv:
        print(__doc__)
        return 2
    errors: list[str] = []
    for name in argv:
        md = Path(name)
        if not md.exists():
            errors.append(f"{md}: file not found")
            continue
        errors.extend(check_file(md))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(argv)} file(s): "
          f"{'FAIL' if errors else 'ok'} ({len(errors)} broken)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

#!/usr/bin/env python
"""Accumulate bench-smoke artifacts across CI runs + the regression gate.

CI's bench-smoke job downloads the previous main-branch run's
``bench-history`` artifact, then runs

  python tools/bench_history.py --prev artifacts/prev/BENCH_HISTORY.json \
      --out artifacts/BENCH_HISTORY.json

which appends one point (read from the current run's
``artifacts/BENCH_*.json``) to the history and FAILS (exit 1) when a
gated metric regressed more than ``--max-regress`` (default 20%) against
the BEST of the last 10 prior points (anchoring on the recent best keeps
a slow sequence of sub-threshold regressions from ratcheting the
baseline down).

Gated metrics are chosen to be noise-robust on shared runners:
  * ``build_time.speedup``            — batched/legacy build ratio, both
    sides timed on the SAME machine, so runner speed cancels out;
  * ``recall_frontier.trees_saved_ratio`` — a deterministic tree count
    ratio, no wall-clock in it;
  * ``million_row.bytes_ratio`` — int8/fp32 candidate HBM bytes at 1M
    rows, a LOWER-is-better counted ratio (gated both against history and
    against the 0.30 absolute ceiling from DESIGN.md §11).
``build_time.bitwise_equal`` and ``million_row.bitwise_equal`` (the HBM
traversal + int8 kernel parity flags) must also hold (hard, not ratios).

Raw latencies (build seconds, churn p50/p99, fused speedup) ride along
in each point for trajectory plots but are never gated here.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")

# (history key, artifact file, fields copied into the point)
SOURCES = [
    ("build_time", "BENCH_build_time.json",
     ["speedup", "fused_speedup", "bitwise_equal", "legacy_s", "batched_s",
      "n", "n_trees"]),
    ("recall_frontier", "BENCH_recall_frontier.json",
     ["trees_saved_ratio", "single_probe_trees_at_target",
      "multi_probe_trees_at_target", "frontier_ok"]),
    ("fused_vs_staged", "BENCH_fused_vs_staged.json",
     ["min_speedup", "all_ids_match"]),
    ("mutation_churn", "BENCH_mutation_churn.json", []),
    ("million_row", "BENCH_million_row.json",
     ["bytes_ratio", "bitwise_equal", "traversal_bitwise_equal",
      "int8_kernel_ids_match", "no_jnp_fallback", "above_smem_cap",
      "p50_ms", "p99_ms", "build_s", "n", "n_trees"]),
    ("serving_slo", "BENCH_serving_slo.json",
     ["p99_ms_at_rated_qps", "rated_qps", "slo_p99_ms", "recall_at_rated",
      "recall_target", "slo_ok", "recall_ok", "overload_bounded",
      "shed_nonzero", "ladder_no_worse", "shed_steps"]),
    ("filtered_search", "BENCH_filtered_search.json",
     ["worst_recall", "recall_001_ok", "recall_all_ok", "no_leaks",
      "n_db", "k"]),
    ("probe_schedule", "BENCH_probe_schedule.json",
     ["p99_ratio", "mean_probes_scheduled", "fixed_n_probes",
      "recall_scheduled", "recall_ok", "probes_below_fixed", "p99_ok",
      "n", "k"]),
    ("autoscale", "BENCH_autoscale.json",
     ["shed_after_scaleup", "rated_qps_1replica", "replicas_after_leg1",
      "resizes", "min_resize_gap_s", "scaled_up", "shed_recovered",
      "p999_bounded", "control_sheds", "no_flapping"]),
]

# (section, metric, direction); a move beyond --max-regress against the
# recent best in the BAD direction fails ("higher" = bigger is better)
GATES = [("build_time", "speedup", "higher"),
         ("recall_frontier", "trees_saved_ratio", "higher"),
         ("million_row", "bytes_ratio", "lower"),
         # serving p99 at the planner's RATED qps: the rate scales with the
         # runner (derived from measured service time), so the p99 it must
         # hold is runner-relative too — safe to history-gate
         ("serving_slo", "p99_ms_at_rated_qps", "lower"),
         # scheduled-vs-fixed batch p99 at equal recall target: the whole
         # point of per-query scheduling is the tail, so the ratio may
         # only drift down
         ("probe_schedule", "p99_ratio", "lower")]

# million_row.bytes_ratio may never exceed this, history or not: the int8
# shortlist must keep candidate traffic under 0.30x fp32 (DESIGN.md §11)
BYTES_RATIO_CEILING = 0.30


def _load(path: str) -> dict | None:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def collect_point(artifacts_dir: str) -> dict:
    point: dict = {
        "commit": os.environ.get("GITHUB_SHA", ""),
        "run_id": os.environ.get("GITHUB_RUN_ID", ""),
    }
    for key, fname, fields in SOURCES:
        data = _load(os.path.join(artifacts_dir, fname))
        if data is None:
            continue
        if fields:
            point[key] = {f: data.get(f) for f in fields if f in data}
        else:   # mutation churn: keep the flat row the benchmark reports
            row = data.get("row", {})
            point[key] = {f: row.get(f) for f in
                          ("p50_steady_ms", "p99_steady_ms",
                           "p50_during_compaction_ms")}
    return point


def check_gates(history: list[dict], point: dict, max_regress: float,
                window: int = 10) -> list[str]:
    """Gate the new point against the BEST of the last ``window`` points.

    Comparing against only the previous point would let a sustained
    sub-threshold regression ratchet the baseline down run after run
    (4.0 -> 3.5 -> 3.1 -> ... each within 20%); anchoring on the recent
    best means the cumulative drop is what gets measured.
    """
    errors = []
    bt = point.get("build_time", {})
    if bt and bt.get("bitwise_equal") is False:
        errors.append("build_time.bitwise_equal is False: the batched "
                      "builder diverged from the legacy oracle")
    mr = point.get("million_row", {})
    if mr and mr.get("bitwise_equal") is False:
        errors.append(
            "million_row.bitwise_equal is False: a query kernel diverged "
            f"(traversal={mr.get('traversal_bitwise_equal')}, "
            f"int8={mr.get('int8_kernel_ids_match')}) — the HBM descent "
            "must bitwise-match the refs (and the SMEM kernel below the "
            "node cap), the int8 kernel its dequant-gather oracle")
    ratio = mr.get("bytes_ratio")
    if ratio is not None and ratio > BYTES_RATIO_CEILING:
        errors.append(
            f"million_row.bytes_ratio {ratio} exceeds the "
            f"{BYTES_RATIO_CEILING} ceiling: int8 candidate bytes must "
            "stay under 0.30x the fp32 path")
    sv = point.get("serving_slo", {})
    if sv:
        # hard serving gates (DESIGN.md §12): at the planner's rated QPS
        # the runtime must be in-SLO AND at the tuned recall target; at 2x
        # rated the degradation ladder must keep the tail bounded while
        # actually shedding (a zero shed fraction past saturation means
        # the ladder never engaged)
        for flag, why in (
                ("slo_ok", "p99 at the planner's rated QPS blew the SLO"),
                ("recall_ok", "recall at rated QPS fell below the tuned "
                              "target"),
                ("overload_bounded", "p999 at 2x rated was unbounded "
                                     "(queue growth / timeouts)"),
                ("shed_nonzero", "no shedding at 2x rated — the "
                                 "degradation ladder never engaged")):
            if sv.get(flag) is False:
                errors.append(f"serving_slo.{flag} is False: {why}")
    fs = point.get("filtered_search", {})
    if fs:
        # hard filtered-search gates (DESIGN.md §13): the acceptance
        # criterion (recall@10 >= 0.9 at selectivity 0.01 on all four
        # backends), the 0.85 all-cells floor, and the contract that a
        # predicate-failing row is never returned
        for flag, why in (
                ("recall_001_ok", "recall@10 at selectivity 0.01 fell "
                                  "below 0.9 on some backend"),
                ("recall_all_ok", "a filtered cell fell below the 0.85 "
                                  "recall floor"),
                ("no_leaks", "filtered search returned a row that fails "
                             "the predicate")):
            if fs.get(flag) is False:
                errors.append(f"filtered_search.{flag} is False: {why}")
    asc = point.get("autoscale", {})
    if asc:
        # hard autoscaling gates (DESIGN.md §15, the ISSUE-10 acceptance
        # criterion): a 2x-rated burst must provoke a scale-up, the scaled
        # fleet's shed fraction must return to <= 0.01 (while the static
        # control sheds at the same load), and resizes must respect the
        # control loop's cooldowns
        for flag, why in (
                ("scaled_up", "the 2x-rated burst never provoked a "
                              "scale-up"),
                ("shed_recovered", "shed fraction stayed above 0.01 after "
                                   "the scale-up — capacity never caught "
                                   "up with the burst"),
                ("p999_bounded", "p999 after scale-up was unbounded "
                                 "(queue growth / timeouts)"),
                ("control_sheds", "the static control did NOT shed — the "
                                  "burst never actually exceeded one "
                                  "replica"),
                ("no_flapping", "resizes came faster than the cooldown "
                                "allows — the loop is oscillating")):
            if asc.get(flag) is False:
                errors.append(f"autoscale.{flag} is False: {why}")
    ps = point.get("probe_schedule", {})
    if ps:
        # hard probe-schedule gates (DESIGN.md §14, the ISSUE-9 acceptance
        # criterion): scheduled recall@10 >= 0.9, mean probes processed
        # strictly below the fixed budget at the same recall target, and
        # batch p99 within 1.1x of the fixed budget
        for flag, why in (
                ("recall_ok", "scheduled recall@10 fell below the 0.9 "
                              "floor"),
                ("probes_below_fixed", "mean scheduled probes were not "
                                       "below the fixed budget at equal "
                                       "recall"),
                ("p99_ok", "scheduled batch p99 regressed more than 10% "
                           "vs the fixed budget")):
            if ps.get(flag) is False:
                errors.append(f"probe_schedule.{flag} is False: {why}")
    recent = history[-window:]
    for section, metric, direction in GATES:
        new = point.get(section, {}).get(metric)
        olds = [p.get(section, {}).get(metric) for p in recent]
        olds = [o for o in olds if o]
        if new is None or not olds:
            continue
        if direction == "higher":
            best = max(olds)
            floor = best * (1.0 - max_regress)
            bad = new < floor
            bound_desc = f"{new} < {floor:.3f}"
        else:
            best = min(olds)
            ceil = best * (1.0 + max_regress)
            bad = new > ceil
            bound_desc = f"{new} > {ceil:.3f}"
        if bad:
            errors.append(
                f"{section}.{metric} regressed: {bound_desc} "
                f"(best of last {len(olds)} point(s) {best}, allowed "
                f"regression {max_regress:.0%})")
    return errors


def main(argv: list[str]) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--prev", default="",
                    help="previous BENCH_HISTORY.json (absent on the "
                         "first run: history starts fresh)")
    ap.add_argument("--out", default=os.path.join(ART, "BENCH_HISTORY.json"))
    ap.add_argument("--artifacts", default=ART,
                    help="directory holding this run's BENCH_*.json")
    ap.add_argument("--max-regress", type=float, default=0.2)
    ap.add_argument("--max-points", type=int, default=200,
                    help="history ring size (oldest points dropped)")
    args = ap.parse_args(argv)

    history = (_load(args.prev) or {}).get("points", []) if args.prev else []
    point = collect_point(args.artifacts)
    # hard gates (parity flags, the bytes ceiling) apply from the very
    # first point; the history-relative gates skip themselves when empty
    errors = check_gates(history, point, args.max_regress)

    history.append(point)
    history = history[-args.max_points:]
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump({"points": history}, f, indent=1)

    print(f"bench history: {len(history)} point(s) -> "
          f"{os.path.relpath(args.out)}")
    for key in ("build_time", "recall_frontier", "million_row",
                "serving_slo", "filtered_search", "probe_schedule",
                "autoscale"):
        if key in point:
            print(f"  {key}: {point[key]}")
    for e in errors:
        print(f"REGRESSION: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

#!/usr/bin/env python
"""Keep the README capability matrix in sync with SearchParams.

The table between the ``<!-- capability-matrix:begin/end -->`` markers in
README.md is GENERATED from ``repro.index.params.CAPABILITY_MATRIX`` (the
same rows ``SearchParams.capabilities`` enforces), so the docs cannot
drift from what the code accepts:

  python tools/capability_table.py --write    # regenerate in place
  python tools/capability_table.py --check    # CI: exit 1 on drift
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

README = os.path.join(os.path.dirname(__file__), "..", "README.md")
BEGIN = "<!-- capability-matrix:begin -->"
END = "<!-- capability-matrix:end -->"


def render(readme_text: str) -> str:
    from repro.index.params import capability_table_md
    try:
        head, rest = readme_text.split(BEGIN, 1)
        _, tail = rest.split(END, 1)
    except ValueError:
        raise SystemExit(f"README.md is missing the {BEGIN} / {END} "
                         "marker pair")
    return f"{head}{BEGIN}\n{capability_table_md()}\n{END}{tail}"


def main(argv: list[str]) -> int:
    ap = argparse.ArgumentParser()
    mode = ap.add_mutually_exclusive_group(required=True)
    mode.add_argument("--check", action="store_true",
                      help="exit 1 when README.md is out of sync")
    mode.add_argument("--write", action="store_true",
                      help="regenerate the README table in place")
    ap.add_argument("--readme", default=README)
    args = ap.parse_args(argv)

    with open(args.readme) as f:
        current = f.read()
    fresh = render(current)
    if args.write:
        if fresh != current:
            with open(args.readme, "w") as f:
                f.write(fresh)
            print(f"capability matrix: rewrote {os.path.relpath(args.readme)}")
        else:
            print("capability matrix: already in sync")
        return 0
    if fresh != current:
        print("capability matrix: README.md is OUT OF SYNC with "
              "SearchParams.CAPABILITY_MATRIX — run "
              "`python tools/capability_table.py --write`", file=sys.stderr)
        return 1
    print("capability matrix: in sync")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

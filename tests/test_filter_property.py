"""Hypothesis property test for filtered search (DESIGN.md §13).

For ANY corpus, predicate tree and deletion set, on EVERY backend, a
filtered search must equal the brute force over the matching LIVE rows.
The deterministic sweep twin (runs without hypothesis) is
``test_filter.test_filtered_search_random_sweep``.
"""
import jax
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dep: pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.filter import And, Eq, In, Not, Or, Range  # noqa: E402
from repro.index import SearchParams, build_index  # noqa: E402
from test_filter import BACKENDS, _match_mask, _oracle, _spec  # noqa: E402


def _predicates(max_price):
    leaf = st.one_of(
        st.builds(Eq, st.just("cat"),
                  st.sampled_from(["a", "b", "c", "zzz"])),
        st.builds(In, st.just("price"),
                  st.lists(st.integers(0, max_price), min_size=1,
                           max_size=4).map(tuple)),
        st.builds(Range, st.just("price"), st.integers(0, max_price // 2),
                  st.integers(max_price // 2, max_price)),
    )
    return st.recursive(
        leaf,
        lambda kids: st.one_of(
            st.builds(lambda a, b: And(a, b), kids, kids),
            st.builds(lambda a, b: Or(a, b), kids, kids),
            st.builds(Not, kids)),
        max_leaves=4)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(60, 250), backend=st.sampled_from(BACKENDS),
       pred=_predicates(30), n_del=st.integers(0, 20),
       seed=st.integers(0, 2**30))
def test_filtered_search_property(n, backend, pred, n_del, seed):
    rng = np.random.default_rng(seed)
    db = np.abs(rng.normal(size=(n, 8)).astype(np.float32)) + 1e-3
    db /= np.linalg.norm(db, axis=1, keepdims=True)
    meta = {"cat": rng.choice(["a", "b", "c"], n),
            "price": rng.integers(0, 31, n).astype(np.int64)}
    idx = build_index(jax.random.key(seed % 997), db, _spec(backend),
                      metadata=meta)
    dead = rng.choice(n, size=min(n_del, n - 1), replace=False)
    for g in dead:
        idx.delete(int(g))
    q = db[rng.integers(0, n, 4)] + 0.001
    d, ids = map(np.asarray, idx.search(q, SearchParams(
        k=5, filter=pred, min_candidates=64)))
    mask = _match_mask(meta, pred)
    mask[dead] = False
    want = _oracle(q, db[mask], np.where(mask)[0], "l2", 5)
    for r, got_row in enumerate(ids):
        assert set(int(g) for g in got_row if g >= 0) == want[r]

"""Fused int8-row rerank kernel + quantized pipeline parity (DESIGN.md §11).

The Pallas kernel (kernels/fused_query_int8.py) DMAs d + 4 bytes per
candidate — the int8 row plus its f32 scale — and dequantizes in VMEM
registers.  Its oracle is ``ref.fused_gather_topk_int8_ref``, the retired
jnp dequant-gather.  End to end, ``pipeline.rerank_fused_quantized`` must
reproduce the staged quantized oracle (full (B, M, d) int8 gather) exactly
on tie-free data, in both ref and pallas modes and under any chunking.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ForestConfig
from repro.core.pipeline import fused_query, rerank_fused_quantized
from repro.core.quantized import (quantize_db, staged_query_quantized,
                                  staged_rerank_quantized)
from repro.kernels import ops, ref

RNG = np.random.default_rng(29)
TOL = dict(rtol=2e-5, atol=2e-5)


def _corpus(n, d, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))


def _assert_match(got, want):
    gd, gi = got
    wd, wi = want
    assert (np.asarray(gi) == np.asarray(wi)).all(), \
        f"id mismatch:\n{np.asarray(gi)}\nvs\n{np.asarray(wi)}"
    wd_np, gd_np = np.asarray(wd), np.asarray(gd)
    finite = np.isfinite(wd_np)
    assert (finite == np.isfinite(gd_np)).all()
    np.testing.assert_allclose(gd_np[finite], wd_np[finite], **TOL)


# ---------------------------------------------------------------------------
# kernel-level: pallas int8 kernel vs its jnp oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,m,n,d", [(4, 24, 200, 16), (9, 100, 500, 48),
                                     (1, 7, 60, 5)])
@pytest.mark.parametrize("k", [5, 33])
def test_int8_kernel_matches_oracle(b, m, n, d, k):
    if k > m:
        pytest.skip("k wider than the candidate axis")
    rng = np.random.default_rng(b * m + k)
    qdb = quantize_db(jnp.asarray(rng.normal(size=(n, d)).astype(np.float32)))
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    ids = rng.integers(0, n, size=(b, m)).astype(np.int32)
    ids[rng.uniform(size=ids.shape) < 0.15] = -1      # invalid slots
    ids = jnp.asarray(ids)
    pd, pi = ops.fused_rerank_int8(q, ids, qdb.q, qdb.scale, k, mode="pallas")
    rd, ri = ref.fused_gather_topk_int8_ref(q, ids, qdb.q, qdb.scale, k)
    rd_np = np.asarray(rd)
    finite = np.isfinite(rd_np)
    np.testing.assert_allclose(np.asarray(pd)[finite], rd_np[finite], **TOL)
    assert (np.isfinite(np.asarray(pd)) == finite).all()
    # continuous data: finite-distance ids are tie-free -> exact
    assert (np.asarray(pi)[finite] == np.asarray(ri)[finite]).all()


@pytest.mark.parametrize("mode", ["ref", "pallas"])
def test_int8_kernel_all_masked(mode):
    qdb = quantize_db(_corpus(50, 6, seed=1))
    q = _corpus(2, 6, seed=2)
    ids = jnp.full((2, 12), -1, jnp.int32)
    d, i = ops.fused_rerank_int8(q, ids, qdb.q, qdb.scale, 3, mode=mode)
    assert np.isinf(np.asarray(d)).all()
    assert (np.asarray(i) == -1).all()


def test_int8_kernel_dequant_is_exact():
    """Dequantized distances are exact vs an explicit fp recomputation —
    the kernel's register dequant is the same f32 op chain as the oracle."""
    qdb = quantize_db(_corpus(80, 12, seed=3))
    q = _corpus(4, 12, seed=4)
    ids = jnp.asarray(RNG.integers(0, 80, size=(4, 20)).astype(np.int32))
    pd, pi = ops.fused_rerank_int8(q, ids, qdb.q, qdb.scale, 6, mode="pallas")
    deq = (np.asarray(qdb.q).astype(np.float32)
           * np.asarray(qdb.scale)[:, None])
    want = np.sum((np.asarray(q)[:, None, :]
                   - deq[np.asarray(ids)]) ** 2, axis=-1)
    got_d = np.asarray(pd)
    for r in range(4):
        np.testing.assert_allclose(got_d[r], np.sort(want[r])[:6], **TOL)


# ---------------------------------------------------------------------------
# pipeline: rerank_fused_quantized vs the staged quantized oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["ref", "pallas"])
@pytest.mark.parametrize("expand", [2, 4])
def test_rerank_quantized_matches_staged(mode, expand):
    qdb = quantize_db(_corpus(600, 20, seed=5))
    q = _corpus(7, 20, seed=6)
    ids = jnp.asarray(RNG.integers(0, 600, size=(7, 60)).astype(np.int32))
    mask = jnp.asarray(RNG.uniform(size=(7, 60)) < 0.85)
    want = staged_rerank_quantized(q, ids, mask, qdb, 5, expand=expand)
    got = rerank_fused_quantized(q, ids, mask, qdb, 5, expand=expand,
                                 mode=mode)
    _assert_match(got, want)


@pytest.mark.parametrize("mode", ["ref", "pallas"])
def test_rerank_quantized_chunk_invariance(mode):
    """Coarse shortlist must be invariant to the streaming chunk width."""
    qdb = quantize_db(_corpus(500, 16, seed=7))
    q = _corpus(5, 16, seed=8)
    ids = jnp.asarray(RNG.integers(0, 500, size=(5, 48)).astype(np.int32))
    mask = jnp.ones((5, 48), bool)
    want = staged_rerank_quantized(q, ids, mask, qdb, 4)
    for chunk in (16, 24, 64):      # incl. non-divisors of M = 48
        got = rerank_fused_quantized(q, ids, mask, qdb, 4, chunk=chunk,
                                     mode=mode)
        _assert_match(got, want)


@pytest.mark.parametrize("mode", ["ref", "pallas"])
def test_rerank_quantized_valid_mask(mode):
    """Tombstoned rows must never reach the shortlist."""
    qdb = quantize_db(_corpus(300, 10, seed=9))
    q = _corpus(4, 10, seed=10)
    ids = jnp.asarray(RNG.integers(0, 300, size=(4, 40)).astype(np.int32))
    mask = jnp.ones((4, 40), bool)
    valid = jnp.asarray(RNG.uniform(size=300) < 0.7)
    want = staged_rerank_quantized(q, ids, mask & valid[ids], qdb, 4)
    got = rerank_fused_quantized(q, ids, mask, qdb, 4, mode=mode,
                                 valid=valid)
    _assert_match(got, want)
    dead = ~np.asarray(valid)
    got_i = np.asarray(got[1])
    assert not dead[got_i[got_i >= 0]].any()


@pytest.mark.parametrize("mode", ["ref", "pallas"])
def test_fused_query_quantized_end_to_end(mode, shared_builds):
    """Forest-driven: fused int8 pipeline vs the staged quantized oracle."""
    cfg = ForestConfig(n_trees=6, capacity=10)
    db = shared_builds.normal_db(1200, 24, 11)
    forest, _ = shared_builds.forest(4, cfg, db)
    qdb = quantize_db(db)
    q = _corpus(9, 24, seed=12)
    want = staged_query_quantized(forest, q, qdb, 5, cfg)
    got = fused_query(forest, q, qdb, 5, cfg, mode=mode)
    _assert_match(got, want)

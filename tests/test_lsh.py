import numpy as np
import pytest

from repro.core.lsh import CascadedLSH, LSHConfig, LSHIndex
from repro.data.synthetic import clustered_gaussians


@pytest.fixture(scope="module")
def db():
    x = clustered_gaussians(2000, 24, n_clusters=16, seed=4)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def test_lsh_self_retrieval(db):
    idx = LSHIndex(db, LSHConfig(n_tables=8, n_bits=8, width=0.7))
    hits = sum(int(j in idx.candidates(db[j])) for j in range(50))
    assert hits >= 48   # a point hashes to its own bucket


def test_cascade_recall_vs_tables(db):
    q = db[:64] + 0.01 * np.random.default_rng(0).normal(size=(64, 24)) \
        .astype(np.float32)
    d = ((db[None] - q[:, None]) ** 2).sum(-1)
    true1 = d.argmin(1)
    recalls = []
    for n_tables in (2, 16):
        lsh = CascadedLSH(db, radii=[0.3, 0.6, 1.0], n_tables=n_tables,
                          n_bits=10, seed=1)
        hits = sum(int(lsh.query(q[j], k=1)[1][0] == true1[j])
                   for j in range(64))
        recalls.append(hits / 64)
    assert recalls[1] >= recalls[0]
    assert recalls[1] > 0.5


def test_cascade_stops_when_enough(db):
    lsh = CascadedLSH(db, radii=[0.2, 0.5, 1.5], n_tables=4, n_bits=10)
    few = lsh.retrieve(db[0], min_candidates=1)
    many = lsh.retrieve(db[0], min_candidates=500)
    assert many.size >= few.size

"""Capability-matrix API tests (DESIGN.md §15).

``SearchParams.capabilities(context)`` is the ONE refusal surface —
``violations()`` / ``sharded_violations()`` are deprecated shims over it,
``require(context)`` raises the structured ``CapabilityError``, and the
README table is generated from ``CAPABILITY_MATRIX`` so the docs cannot
drift from the code.
"""
from __future__ import annotations

import os
import subprocess
import sys

import pytest

from repro.filter import Eq
from repro.index import SearchParams
from repro.index.params import (CAPABILITY_MATRIX, CONTEXTS,
                                CapabilityError, Violation,
                                capability_table_md)

REPO = os.path.join(os.path.dirname(__file__), "..")


# ---------------------------------------------------------------------------
# the matrix itself
# ---------------------------------------------------------------------------


def test_clean_params_pass_every_context():
    p = SearchParams(k=10, n_probes=4)
    for ctx in CONTEXTS:
        assert p.capabilities(ctx) == []
        assert p.require(ctx) is p


def test_unknown_context_rejected():
    with pytest.raises(ValueError, match="context"):
        SearchParams().capabilities("gpu")


def test_bad_metric_fails_everywhere():
    p = SearchParams(metric="bogus")
    for ctx in CONTEXTS:
        bad = p.capabilities(ctx)
        assert any(v.knob == "metric" for v in bad)
        with pytest.raises(CapabilityError):
            p.require(ctx)


def test_sharded_context_matches_matrix_rows():
    # every knob the matrix marks sharded-"no" must actually be refused,
    # and every sharded-"yes" knob accepted
    cases = {
        "adaptive_wave": SearchParams(adaptive_wave=8),
        "min_candidates": SearchParams(min_candidates=50),
        "n_trees": SearchParams(n_trees=4),
    }
    for knob, p in cases.items():
        bad = p.capabilities("sharded")
        assert [v.knob for v in bad] == [knob]
        assert p.capabilities("local") == []
    legal = SearchParams(k=5, filter=Eq("shop", "s0"), probe_schedule=4)
    assert legal.capabilities("sharded") == []


def test_matrix_rows_agree_with_capabilities():
    # the generated docs and the enforcement logic must tell one story:
    # a "no" cell in the matrix row <-> capabilities() flags that knob
    by_knob = {r["knob"]: r for r in CAPABILITY_MATRIX}
    assert by_knob["`adaptive_wave` (tree waves)"]["sharded"] == "no"
    assert by_knob["`n_trees` (forest prefix)"]["sharded"] == "no"
    assert by_knob["`filter` (metadata predicate)"]["sharded"].startswith(
        "yes")
    assert by_knob["`probe_schedule` (per-query probes)"][
        "sharded"].startswith("yes")
    md = capability_table_md()
    assert md.count("\n") == len(CAPABILITY_MATRIX) + 1
    for row in CAPABILITY_MATRIX:
        assert row["knob"] in md


# ---------------------------------------------------------------------------
# the structured error
# ---------------------------------------------------------------------------


def test_capability_error_structure():
    p = SearchParams(metric="bogus", filter="not a predicate")
    with pytest.raises(CapabilityError) as ei:
        p.require("local")
    err = ei.value
    assert isinstance(err, ValueError)          # legacy handlers keep working
    assert err.context == "local"
    knobs = {v.knob for v in err.violations}
    assert knobs == {"metric", "filter"}
    assert "[local]" in str(err)
    for v in err.violations:
        assert v.message in str(err)


def test_violation_str_includes_hint():
    v = Violation(knob="filter", context="sharded", message="no metadata",
                  hint="build with metadata=")
    assert str(v) == "no metadata — build with metadata="
    assert str(Violation(knob="k", context="local",
                         message="bare")) == "bare"


def test_deprecated_shims_render_messages():
    p = SearchParams(metric="bogus")
    assert p.violations() == [str(v) for v in p.capabilities("local")]
    assert any("metric" in s for s in p.sharded_violations())
    # legacy message substrings the old tests matched on still appear
    fp = SearchParams(filter=12345)
    assert any("Predicate" in s for s in fp.violations())


# ---------------------------------------------------------------------------
# consumers of the matrix
# ---------------------------------------------------------------------------


def test_make_query_fn_raises_structured_error():
    from repro import compat
    from repro.core import ForestConfig
    from repro.core.sharded_index import make_query_fn
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    for p, knob in ((SearchParams(k=5, probe_schedule=4), "probe_schedule"),
                    (SearchParams(k=5, filter=Eq("shop", "s0")), "filter")):
        with pytest.raises(CapabilityError) as ei:
            make_query_fn(ForestConfig(n_trees=4), 128, mesh, params=p)
        assert any(v.knob == knob for v in ei.value.violations)
        assert "ShardedIndex" in str(ei.value)  # points at the host driver


def test_readme_table_in_sync():
    r = subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "capability_table.py"),
         "--check"], capture_output=True, text=True)
    assert r.returncode == 0, (
        "README capability matrix drifted from "
        f"SearchParams.CAPABILITY_MATRIX:\n{r.stdout}{r.stderr}")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ForestConfig, exact_knn, recall_at_k
from repro.core.adaptive import adaptive_query


def test_adaptive_early_exit_keeps_recall(shared_builds):
    db = shared_builds.clustered_db(4000, 32, n_clusters=16, seed=6)
    q = db[:64] + 0.005   # easy queries: should exit early
    cfg = ForestConfig(n_trees=40, capacity=12)
    forest, _ = shared_builds.forest(0, cfg, db)
    d, ids, used = adaptive_query(forest, q, db, k=3, cfg=cfg, wave=8,
                                  tol=0.02)
    _, true_ids = exact_knn(q, db, k=3)
    rec = float(recall_at_k(ids, true_ids))
    assert rec > 0.9, rec
    assert used < 40, "easy queries should not need the full forest"


def test_adaptive_uses_more_trees_when_hard(shared_builds):
    db = shared_builds.normal_db(3000, 48, seed=1)   # unclustered = hard
    q = jnp.asarray(np.random.default_rng(2).normal(
        size=(32, 48)).astype(np.float32))
    cfg = ForestConfig(n_trees=32, capacity=12)
    forest, _ = shared_builds.forest(1, cfg, db)
    _, _, used_hard = adaptive_query(forest, q, db, k=3, cfg=cfg, wave=8,
                                     tol=0.001)
    db_easy = shared_builds.clustered_db(3000, 48, n_clusters=8, seed=3)
    forest_e, _ = shared_builds.forest(1, cfg, db_easy)
    _, _, used_easy = adaptive_query(forest_e, db_easy[:32], db_easy, k=3,
                                     cfg=cfg, wave=8, tol=0.001)
    assert used_hard >= used_easy

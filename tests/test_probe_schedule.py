"""Per-query adaptive probe scheduling (core/schedule.py, DESIGN.md §14).

The contract under test: the scheduler may only move a query along the
fixed-budget multi-probe frontier, never off it —

  * with the convergence threshold disabled (``tol = 0.0``) the scheduled
    path is BITWISE-identical to fixed ``n_probes = cap`` on every
    registered backend (the ISSUE-9 acceptance pin; replacement semantics
    make the final round literally the fixed-budget call),
  * recall is monotone in the widening cap (doubling schedules are
    prefix-nested, so a larger cap only ever re-descends with more probes),
  * a query the scheduler declares converged has nothing left to gain:
    its top-k equals its full-budget top-k (the per-query oracle),
  * tombstones and metadata filters compose unchanged (the schedule rides
    the same ``valid=`` path as every other search),
  * the sharded path rejects scheduled params exactly as
    ``sharded_violations()`` reports, and ``.sharded()`` strips them.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import ForestConfig
from repro.core.forest import build_forest
from repro.core.pipeline import fused_query
from repro.core.quantized import quantize_db
from repro.core.schedule import probe_widths, scheduled_query
from repro.core.search import recall_at_k
from repro.core.knn import exact_knn
from repro.index import IndexSpec, SearchParams, build_index

N, D, K = 2000, 24, 10
CFG = ForestConfig(n_trees=8, capacity=12)
CAP = 6

LSH_SPEC = dict(lsh_radii=(0.5, 1.0, 2.0), lsh_tables=6, lsh_bits=8)


@pytest.fixture(scope="module")
def corpus(shared_builds):
    db = shared_builds.clustered_db(N, D, n_clusters=16, seed=0)
    rng = np.random.default_rng(1)
    q = np.asarray(db[:32]) + 0.05 * rng.normal(size=(32, D)).astype(
        np.float32)
    return db, q.astype(np.float32)


# ---------------------------------------------------------------------------
# the schedule itself
# ---------------------------------------------------------------------------


def test_probe_widths_shape():
    assert probe_widths(1) == [1]
    assert probe_widths(2) == [1, 2]
    assert probe_widths(6) == [1, 2, 4, 6]
    assert probe_widths(8) == [1, 2, 4, 8]
    with pytest.raises(ValueError, match="cap"):
        probe_widths(0)


def test_params_validation():
    with pytest.raises(ValueError, match="probe_schedule"):
        SearchParams(probe_schedule=-1)
    # both knobs consume the same convergence signal: rejected, and by the
    # ONE violations() surface so every search path refuses it identically
    bad = SearchParams(probe_schedule=4, adaptive_wave=2)
    assert any("probe_schedule" in v for v in bad.violations())


def test_search_rejects_schedule_with_adaptive(shared_builds, corpus):
    db, q = corpus
    index = shared_builds.index("rpf", 0, db, forest_cfg=CFG)
    with pytest.raises(ValueError, match="probe_schedule"):
        index.search(q, SearchParams(k=K, probe_schedule=4, adaptive_wave=2))


# ---------------------------------------------------------------------------
# acceptance pin: tol = 0.0  =>  bitwise-identical to fixed n_probes = cap
# ---------------------------------------------------------------------------


def test_bitwise_parity_core_fp32_and_int8(shared_builds, corpus):
    """scheduled_query(tol=0) == fused_query(n_probes=cap) on both rerank
    sources, with full-cap probe accounting."""
    db, q = corpus
    forest, cfg = shared_builds.forest(0, CFG, db)
    for src in (db, quantize_db(db)):
        want_d, want_i = fused_query(forest, q, src, K, cfg, n_probes=CAP)
        got_d, got_i, final, processed = scheduled_query(
            forest, q, src, K, cfg, cap=CAP, tol=0.0)
        np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
        np.testing.assert_array_equal(np.asarray(got_d), np.asarray(want_d))
        assert (final == CAP).all()
        assert (processed == sum(probe_widths(CAP))).all()


@pytest.mark.parametrize("backend", ["rpf", "rpf+int8", "lsh-cascade",
                                     "bruteforce"])
def test_bitwise_parity_all_backends(shared_builds, corpus, backend):
    """Index.search with (probe_schedule=cap, tol=0) == the fixed-budget
    path on every backend; the non-forest backends ignore the knob."""
    db, q = corpus
    kw = LSH_SPEC if backend == "lsh-cascade" else {"forest_cfg": CFG}
    index = shared_builds.index(backend, 0, db, **kw)
    fixed = SearchParams(k=K, n_probes=CAP if backend.startswith("rpf")
                         else 1)
    sched = dataclasses.replace(fixed, n_probes=1, probe_schedule=CAP,
                                tol=0.0)
    dw, iw = map(np.asarray, index.search(q, fixed))
    dg, ig = map(np.asarray, index.search(q, sched))
    np.testing.assert_array_equal(ig, iw)
    np.testing.assert_array_equal(dg, dw)


# ---------------------------------------------------------------------------
# scheduling behavior: monotone cap, convergence oracle, accounting
# ---------------------------------------------------------------------------


def test_monotone_recall_in_cap(shared_builds, corpus):
    """Doubling schedules are prefix-nested (widths(2^j) is a prefix of
    widths(2^{j+1}) plus one wider final round), so a larger cap can only
    re-descend active queries with more probes: recall is non-decreasing."""
    db, q = corpus
    forest, cfg = shared_builds.forest(0, CFG, db)
    _, true_i = exact_knn(q, db, k=K)
    recalls = []
    for cap in (1, 2, 4, 8):
        _, ids, _, _ = scheduled_query(forest, q, db, K, cfg, cap=cap,
                                       tol=0.02)
        recalls.append(float(recall_at_k(ids, true_i)))
    assert all(b >= a for a, b in zip(recalls, recalls[1:])), recalls
    assert recalls[-1] > recalls[0]


def test_converged_query_oracle(shared_builds, corpus):
    """A query the scheduler stopped early had nothing left to gain: its
    top-k must equal its full-budget (n_probes=cap) top-k."""
    db, q = corpus
    forest, cfg = shared_builds.forest(0, CFG, db)
    cap = 8
    # tight tolerance: a declared plateau must be a REAL plateau (looser
    # tolerances trade this guarantee for cost — that envelope is
    # test_property.py's job, not the oracle's)
    d, ids, final, _ = scheduled_query(forest, q, db, K, cfg, cap=cap,
                                       tol=1e-3)
    full_d, full_i = fused_query(forest, q, db, K, cfg, n_probes=cap)
    converged = np.flatnonzero(final < cap)
    assert converged.size > 0, "corpus must converge some queries"
    np.testing.assert_array_equal(np.asarray(ids)[converged],
                                  np.asarray(full_i)[converged])
    np.testing.assert_array_equal(np.asarray(d)[converged],
                                  np.asarray(full_d)[converged])


def test_probe_accounting_on_instant_convergence(shared_builds, corpus):
    """tol=inf converges every query at the first checkpoint (width 2):
    final width 2, processed 1+2 — convergence needs one comparison round,
    so the cheapest scheduled query still costs 3 probes."""
    db, q = corpus
    forest, cfg = shared_builds.forest(0, CFG, db)
    _, _, final, processed = scheduled_query(forest, q, db, K, cfg, cap=8,
                                             tol=np.inf)
    assert (final == 2).all()
    assert (processed == 3).all()


def test_scheduled_cost_below_fixed_on_clustered_data(shared_builds, corpus):
    """The point of the feature: on clustered data most queries converge
    early, so the mean probes processed lands below the all-pay-the-cap
    fixed budget's cumulative cost."""
    db, q = corpus
    forest, cfg = shared_builds.forest(0, CFG, db)
    cap = 8
    _, _, final, processed = scheduled_query(forest, q, db, K, cfg, cap=cap,
                                             tol=0.05)
    assert float(processed.mean()) < sum(probe_widths(cap))
    assert final.max() <= cap


# ---------------------------------------------------------------------------
# composition: tombstones + filters ride the same valid= path
# ---------------------------------------------------------------------------


def test_tombstone_and_filter_composition(corpus):
    db, q = corpus
    db = np.asarray(db)
    meta = {"shop": np.array([f"s{i % 4}" for i in range(N)])}
    index = build_index(jax.random.key(0), db,
                        IndexSpec(backend="rpf", forest=CFG), metadata=meta)
    index.delete(list(range(0, 400)))
    from repro.filter import Eq
    fixed = SearchParams(k=K, n_probes=CAP, filter=Eq("shop", "s1"))
    sched = dataclasses.replace(fixed, n_probes=1, probe_schedule=CAP,
                                tol=0.0)
    dw, iw = map(np.asarray, index.search(q, fixed))
    dg, ig = map(np.asarray, index.search(q, sched))
    np.testing.assert_array_equal(ig, iw)
    np.testing.assert_array_equal(dg, dw)
    surfaced = ig[ig >= 0]
    assert (surfaced >= 400).all(), "tombstoned rows must not surface"
    assert (surfaced % 4 == 1).all(), "filtered-out rows must not surface"


# ---------------------------------------------------------------------------
# sharded path: reject-or-support parity with sharded_violations()
# ---------------------------------------------------------------------------


def test_sharded_reject_parity():
    from repro import compat
    from repro.core.sharded_index import make_query_fn
    p = SearchParams(k=5, probe_schedule=CAP)
    # the capability matrix: probe_schedule is sharded-LEGAL (ShardedIndex
    # host-drives the widening rounds), so the projection KEEPS it...
    assert not p.sharded_violations()
    assert p.sharded().probe_schedule == CAP
    # ...but the raw fixed-program compiler still refuses it, pointing at
    # the host driver that can serve it
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="probe_schedule"):
        make_query_fn(ForestConfig(n_trees=4), 128, mesh, params=p)
    fixed = dataclasses.replace(p, probe_schedule=0)
    make_query_fn(ForestConfig(n_trees=4), 128, mesh, params=fixed.sharded())

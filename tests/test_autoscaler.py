"""Autoscaler + fleet + fleet.yml config tests (DESIGN.md §15).

The control-loop tests run against a fake fleet with an injectable clock —
``Autoscaler.step()`` is pure control logic over ``fleet.stats()``, so the
scenarios (2x-rated burst, calm decay, panic override) are deterministic:
no sleeps, no racing threads.  One live test drives a real ``ReplicaFleet``
of sleep-cost runtimes through an actual burst.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np
import pytest

from repro.core import ForestConfig
from repro.index import IndexSpec, SearchParams
from repro.serve import loadgen
from repro.serve.autoscaler import Autoscaler, AutoscalerConfig, ReplicaFleet
from repro.serve.config import _parse_simple_yaml, build_fleet, load_config
from repro.serve.planner import TrafficModel, rated_qps
from repro.serve.runtime import ServingRuntime

# affine model: t(b) = 1ms + 1ms*b, 2ms batching wait
MODEL = TrafficModel(c0_s=0.001, c1_s=0.001, max_wait_s=0.002,
                     batch_grid=(1, 8, 32), measured_s=(), rows_per_query=1.0)
SLO_MS = 50.0
BATCH = 32
RATED1 = rated_qps(MODEL, SLO_MS, BATCH)     # one replica's rated qps


class _FakeFleet:
    """Counter-driven fleet stand-in: tests feed the counters directly."""

    def __init__(self):
        self.n = 1
        self.total = 0
        self.depth = 0
        self.degraded = 0
        self.resize_log: list[tuple[float, int]] = []
        self.clock = lambda: 0.0

    @property
    def n_replicas(self) -> int:
        return self.n

    def scale_to(self, n, batch=None):
        self.resize_log.append((self.clock(), n))
        self.n = n
        return n

    def stats(self) -> dict:
        return {"requests_total": self.total, "depth": self.depth,
                "requests_degraded": self.degraded}


def _loop(cfg=None, **cfg_kw):
    cfg = cfg or AutoscalerConfig(slo_p99_ms=SLO_MS, max_replicas=8,
                                  cooldown_s=1.0, scale_down_cooldown_s=4.0,
                                  demand_smoothing=1.0, **cfg_kw)
    ff = _FakeFleet()
    t = [0.0]
    ff.clock = lambda: t[0]
    a = Autoscaler(ff, MODEL, cfg, batch=BATCH, clock=lambda: t[0])
    return a, ff, t


def _tick(a, ff, t, dt, demand_qps):
    """Advance the fake clock one control period under ``demand_qps``:
    completions up to capacity, the excess piling into the queue."""
    t[0] += dt
    cap = ff.n * RATED1
    served = min(demand_qps, cap)
    ff.total += int(served * dt)
    if demand_qps > cap:
        ff.depth += int((demand_qps - cap) * dt)
    else:
        ff.depth = max(0, ff.depth - int((cap - demand_qps) * dt))
    return a.step()


# ---------------------------------------------------------------------------
# fake-clock control-loop scenarios
# ---------------------------------------------------------------------------


def test_burst_scales_up_then_cools_down():
    a, ff, t = _loop()
    a.step()                                    # baseline tick
    # 3s of 2x one replica's rated qps: must scale up, and to the
    # planner's target (2 replicas serve 2x rated with headroom)
    for _ in range(12):
        d = _tick(a, ff, t, 0.25, 2.0 * RATED1)
    assert any(d["action"] == "up" for d in a.history), \
        "2x-rated burst never scaled up"
    assert ff.n == 2
    up = next(d for d in a.history if d["action"] == "up")
    assert up["planned_batch"] == BATCH         # planned at the REAL batch
    # 8s of 0.2x rated: exactly one step-down after the calm window
    for _ in range(32):
        d = _tick(a, ff, t, 0.25, 0.2 * RATED1)
    downs = [d for d in a.history if d["action"] == "down"]
    assert len(downs) == 1 and ff.n == 1
    # no flapping: resize-to-resize gaps respect the cooldowns
    ts = [d["t"] for d in a.history if d["action"] != "hold"]
    gaps = [b - x for x, b in zip(ts, ts[1:])]
    assert all(g >= a.config.cooldown_s for g in gaps)
    assert a.stats()["scale_ups"] == 1 and a.stats()["scale_downs"] == 1


def test_plan_pins_the_fleet_batch():
    # the planner's default grid would pick a smaller batch whose rated
    # qps exceeds this demand (claiming one replica suffices) — but live
    # replicas serve at their BUILT batch, so the re-plan must be pinned
    a, ff, t = _loop()
    a.step()
    d = _tick(a, ff, t, 0.25, 2.0 * RATED1)
    assert d["action"] == "up" and d["planned_batch"] == BATCH
    # sanity: the default grid really does rate a smaller batch higher
    assert rated_qps(MODEL, SLO_MS, 8) > 2.0 * RATED1 > RATED1


def test_dead_band_holds_and_panic_overrides():
    a, ff, t = _loop()
    a.step()
    # demand just above capacity but inside the 15% dead band: hold
    d = _tick(a, ff, t, 0.25, 1.10 * RATED1)
    assert d["action"] == "hold"
    # same demand with a shed fraction above the panic threshold: scale,
    # the fleet is visibly degrading even though demand reads in-band
    ff.degraded += int(0.2 * RATED1 * 0.25)
    d = _tick(a, ff, t, 0.25, 1.10 * RATED1)
    assert d["action"] == "up" and d["reason"] == "panic"


def test_cooldown_blocks_immediate_rescale():
    a, ff, t = _loop()
    a.step()
    _tick(a, ff, t, 0.25, 2.0 * RATED1)
    assert ff.n == 2
    # push demand to 4x before the cooldown elapses: decision must wait
    d = _tick(a, ff, t, 0.25, 4.0 * RATED1)
    assert d["action"] == "hold" and d["reason"] == "cooldown"
    # once the cooldown has passed, the deferred scale-up lands
    for _ in range(3):
        d = _tick(a, ff, t, 0.25, 4.0 * RATED1)
    assert ff.n > 2


def test_scale_down_waits_for_calm():
    a, ff, t = _loop()
    a.step()
    for _ in range(8):
        _tick(a, ff, t, 0.25, 2.0 * RATED1)
    assert ff.n == 2
    # calm traffic, but briefly interrupted: the calm window restarts
    for _ in range(8):
        _tick(a, ff, t, 0.25, 0.2 * RATED1)     # 2s calm < 4s window
    # blip above 2-replica capacity but inside the dead band: no resize,
    # yet the calm window restarts
    _tick(a, ff, t, 0.25, 2.2 * RATED1)
    for _ in range(8):
        d = _tick(a, ff, t, 0.25, 0.2 * RATED1)
    assert ff.n == 2 and d["action"] == "hold"
    for _ in range(10):
        d = _tick(a, ff, t, 0.25, 0.2 * RATED1)
    assert ff.n == 1                            # calm finally long enough


def test_infeasible_demand_pins_ceiling():
    # demand beyond what max_replicas serves: plan() raises, the loop pins
    # the ceiling instead of dying (shed handles the excess)
    a, ff, t = _loop(cfg=AutoscalerConfig(
        slo_p99_ms=SLO_MS, max_replicas=2, cooldown_s=0.0,
        scale_down_cooldown_s=4.0, demand_smoothing=1.0))
    a.step()
    for _ in range(4):
        _tick(a, ff, t, 0.25, 50.0 * RATED1)
    assert ff.n == 2


def test_config_roundtrip_and_unknown_keys():
    cfg = AutoscalerConfig(slo_p99_ms=25.0, hysteresis=0.2)
    assert AutoscalerConfig.from_dict(cfg.to_dict()) == cfg
    # from_dict tolerates fleet.yml keys that aren't control knobs
    c2 = AutoscalerConfig.from_dict({"slo_p99_ms": 25.0, "enabled": True,
                                     "qps": 500.0, "hysteresis": 0.2})
    assert c2 == cfg


# ---------------------------------------------------------------------------
# live fleet: real runtimes, real burst
# ---------------------------------------------------------------------------


class _SleepIndex:
    """Sleep-cost index: deterministic service time, trivial results."""

    def __init__(self, per_batch_s=0.008):
        self.spec = IndexSpec(backend="rpf",
                              forest=ForestConfig(n_trees=8))
        self.tuned_params = SearchParams(k=5, n_probes=8)
        self.shard_params = None
        self.serving_plan = None
        self.per_batch_s = per_batch_s

    def search(self, q, params):
        time.sleep(self.per_batch_s)
        n = q.shape[0]
        return (np.zeros((n, params.k), np.float32),
                np.tile(np.arange(params.k), (n, 1)))

    def live_points(self):
        return np.arange(64), np.zeros((64, 4), np.float32)


def test_replica_fleet_dispatch_scale_and_monotone_stats():
    idx = _SleepIndex(per_batch_s=0.001)
    fleet = ReplicaFleet(lambda batch=None: ServingRuntime(
        idx, max_batch=int(batch or 8), max_wait_s=0.001), n_replicas=2)
    try:
        q = np.zeros(4, np.float32)
        d, i = fleet(q)
        assert i.shape == (5,)
        for _ in range(20):
            fleet(q)
        before = fleet.stats()
        assert before["n_replicas"] == 2
        assert before["requests_total"] >= 21
        fleet.scale_to(1)                       # retiree counters fold in
        fleet(q)
        after = fleet.stats()
        assert after["n_replicas"] == 1
        assert after["requests_total"] > before["requests_total"] - 1
        assert len(fleet.resizes) == 1
        fleet.scale_to(3)
        assert fleet.n_replicas == 3
    finally:
        fleet.stop()


def test_live_burst_scales_up():
    idx = _SleepIndex(per_batch_s=0.016)
    model = TrafficModel(c0_s=0.016, c1_s=0.0, max_wait_s=0.002,
                         batch_grid=(8,), measured_s=(),
                         rows_per_query=1.0)
    rated = rated_qps(model, SLO_MS, 8)
    fleet = ReplicaFleet(lambda batch=None: ServingRuntime(
        idx, max_batch=int(batch or 8), max_wait_s=0.002,
        slo_p99_ms=SLO_MS), n_replicas=1, batch=8)
    cfg = AutoscalerConfig(slo_p99_ms=SLO_MS, max_replicas=4,
                           interval_s=0.05, cooldown_s=0.3,
                           scale_down_cooldown_s=60.0,
                           demand_smoothing=0.7)
    scaler = Autoscaler(fleet, model, cfg, batch=8).start()
    try:
        q = np.zeros((8, 4), np.float32)
        offered = 2.0 * rated
        loadgen.run_open_loop(fleet, q, offered,
                              n_requests=int(offered * 2.0), seed=1,
                              timeout_s=30.0)
        assert fleet.n_replicas >= 2, \
            f"live 2x burst never scaled up: {scaler.history[-3:]}"
        ts = [d["t"] for d in scaler.history if d["action"] != "hold"]
        gaps = [b - x for x, b in zip(ts, ts[1:])]
        assert all(g >= 0.95 * cfg.cooldown_s for g in gaps)
    finally:
        scaler.stop()
        fleet.stop()


# ---------------------------------------------------------------------------
# fleet.yml config
# ---------------------------------------------------------------------------

FLEET_YML = """\
# fleet.yml
index: {manifest}
serving:
  slo_p99_ms: 25.0
  max_batch: 16
  max_wait_s: 0.002
  degrade: true
mesh: {mesh}
autoscale:
  enabled: {enabled}
  qps: 120.0
  min_replicas: 1
  max_replicas: 3
  cooldown_s: 0.5
"""


def test_simple_yaml_parser_matches_schema():
    text = FLEET_YML.format(manifest="/tmp/idx", mesh="", enabled="true")
    cfg = _parse_simple_yaml(text)
    assert cfg["index"] == "/tmp/idx"
    assert cfg["serving"]["slo_p99_ms"] == 25.0
    assert cfg["serving"]["max_batch"] == 16
    assert cfg["serving"]["degrade"] is True
    assert cfg["autoscale"]["enabled"] is True
    assert cfg["autoscale"]["qps"] == 120.0
    assert cfg["mesh"] is None
    # inline lists + quotes (the mesh section's shape/axes spelling)
    cfg = _parse_simple_yaml("mesh:\n  shape: [4, 2]\n"
                             "  axes: ['data', 'model']\n")
    assert cfg["mesh"]["shape"] == [4, 2]
    assert cfg["mesh"]["axes"] == ["data", "model"]


def test_simple_parser_agrees_with_pyyaml(tmp_path):
    yaml = pytest.importorskip("yaml")
    text = FLEET_YML.format(manifest="runs/w.idx", mesh="", enabled="false")
    assert _parse_simple_yaml(text) == yaml.safe_load(text)


def test_load_config(tmp_path):
    p = tmp_path / "fleet.yml"
    p.write_text(FLEET_YML.format(manifest="x.idx", mesh="", enabled="no"))
    cfg = load_config(str(p))
    assert cfg["index"] == "x.idx"
    assert cfg["autoscale"]["enabled"] is False


def test_build_fleet_requires_index():
    with pytest.raises(ValueError, match="index"):
        build_fleet({"serving": {"slo_p99_ms": 25.0}})


def test_build_fleet_serves_and_autoscales(tmp_path):
    # in-memory index override + explicit model: no manifest round-trip,
    # no calibration — stands up 1 replica + the control loop
    idx = _SleepIndex(per_batch_s=0.001)
    model = TrafficModel(c0_s=0.001, c1_s=0.0001, max_wait_s=0.002,
                         batch_grid=(16,), measured_s=(),
                         rows_per_query=1.0)
    cfg = {"serving": {"slo_p99_ms": 25.0, "max_batch": 16},
           "autoscale": {"enabled": True, "qps": 50.0,
                         "max_replicas": 3, "cooldown_s": 0.5}}
    handle = build_fleet(cfg, index=idx, model=model)
    try:
        assert handle.autoscaler is not None
        assert handle.plan is not None and handle.plan.n_replicas >= 1
        assert handle.fleet.n_replicas == handle.plan.n_replicas
        d, i = handle(np.zeros(4, np.float32))
        assert i.shape == (5,)
    finally:
        handle.stop()


def test_build_fleet_from_saved_manifest(tmp_path, shared_builds):
    import jax
    from repro.index import build_index
    db = shared_builds.clustered_db(600, 8, n_clusters=8, seed=0)
    index = build_index(jax.random.key(0), db,
                        IndexSpec(backend="rpf",
                                  forest=ForestConfig(n_trees=4,
                                                      capacity=32)))
    root = str(tmp_path / "idx")
    index.save(root)
    p = tmp_path / "fleet.yml"
    p.write_text(f"index: {root}\nserving:\n  slo_p99_ms: 50.0\n"
                 "  max_batch: 8\n")
    handle = build_fleet(str(p))
    try:
        assert handle.autoscaler is None        # autoscale not enabled
        assert handle.fleet.n_replicas == 1
        d, i = handle(np.asarray(db[0], np.float32))
        assert int(np.asarray(i)[0]) >= 0
    finally:
        handle.stop()


def test_sharded_projection_keeps_filter_and_schedule():
    # the regression the tentpole exists to prevent: projecting an
    # operating point onto a mesh must not silently drop the predicate
    from repro.filter import Eq
    p = SearchParams(k=5, filter=Eq("shop", "s0"), probe_schedule=4,
                     adaptive_wave=8)
    sp = p.sharded()
    assert sp.filter is p.filter
    assert sp.probe_schedule == 4
    assert sp.adaptive_wave == 0
    assert dataclasses.replace(sp, filter=None,
                               probe_schedule=0).sharded_violations() == []

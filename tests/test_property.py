"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dep: pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import ForestConfig, build_forest  # noqa: E402
from repro.core.forest import forest_stats, gather_candidates, traverse  # noqa: E402
from repro.core.search import mask_duplicates  # noqa: E402
from repro.core.sharded_index import merge_topk_pairs  # noqa: E402
from repro.kernels import ref  # noqa: E402

SETTINGS = dict(max_examples=15, deadline=None)


@settings(**SETTINGS)
@given(n=st.integers(80, 400), d=st.integers(2, 24),
       c=st.integers(4, 20), r=st.floats(0.1, 0.5),
       seed=st.integers(0, 2**30))
def test_forest_invariants(n, d, c, r, seed):
    """For ANY data/config: complete disjoint partition, occupancy <= C."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    cfg = ForestConfig(n_trees=2, capacity=c, split_ratio=r)
    f = build_forest(jax.random.key(seed % 1000), x, cfg)
    perm = np.asarray(f.perm)
    counts = np.asarray(f.leaf_count)
    child = np.asarray(f.child_base)
    for l in range(2):
        assert sorted(perm[l]) == list(range(n))
        leaves = child[l] < 0
        assert counts[l][leaves].sum() == n
        assert counts[l].max() <= c


@settings(**SETTINGS)
@given(n=st.integers(60, 300), d=st.integers(2, 20), c=st.integers(3, 16),
       r=st.floats(0.1, 0.5), tied=st.booleans(), seed=st.integers(0, 2**30))
def test_batched_builder_bitwise_invariant(n, d, c, r, tied, seed):
    """For ANY data/config/seed: the batched cross-tree builder places
    every point in the SAME leaf of the SAME tree as the legacy per-tree
    builder — full Forest equality, which subsumes the leaf partition
    (DESIGN.md §10; the deterministic matrix is test_forest_batched.py)."""
    from repro.core.forest import _build_forest_legacy
    rng = np.random.default_rng(seed)
    if tied:   # heavily tied coordinates: tie-escape + redraw paths
        x = rng.integers(0, 3, size=(n, d)).astype(np.float32)
    else:
        x = rng.normal(size=(n, d)).astype(np.float32)
    x = jnp.asarray(x)
    cfg = ForestConfig(n_trees=2, capacity=c, split_ratio=r)
    key = jax.random.key(seed % 9973)
    want = _build_forest_legacy(key, x, cfg.resolved(n))
    got = build_forest(key, x, cfg)
    for name in want._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(got, name)), np.asarray(getattr(want, name)),
            err_msg=f"batched builder diverges on Forest.{name}")


@settings(**SETTINGS)
@given(n=st.integers(100, 300), seed=st.integers(0, 2**30))
def test_traversal_deterministic_and_self_finding(n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, 8)).astype(np.float32))
    cfg = ForestConfig(n_trees=2, capacity=8)
    rcfg = cfg.resolved(n)
    f = build_forest(jax.random.key(1), x, cfg)
    l1 = np.asarray(traverse(f, x[:20], rcfg.max_depth))
    l2 = np.asarray(traverse(f, x[:20], rcfg.max_depth))
    assert (l1 == l2).all()
    ids, mask = gather_candidates(f, jnp.asarray(l1), rcfg.leaf_pad)
    ids, mask = np.asarray(ids), np.asarray(mask)
    for q in range(20):
        assert q in set(ids[q][mask[q]])   # own leaf contains the point


@settings(**SETTINGS)
@given(n=st.integers(100, 300), n_probes=st.integers(1, 6),
       seed=st.integers(0, 2**30))
def test_multiprobe_invariants(n, n_probes, seed):
    """For ANY data/probe width: probe 0 is bitwise the single descent,
    every probe is a leaf, and a tree's probes are pairwise distinct."""
    from repro.core.forest import traverse_multiprobe
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, 8)).astype(np.float32))
    cfg = ForestConfig(n_trees=2, capacity=8)
    rcfg = cfg.resolved(n)
    f = build_forest(jax.random.key(seed % 1000), x, cfg)
    q = x[:16]
    single = np.asarray(traverse(f, q, rcfg.max_depth))
    multi = np.asarray(traverse_multiprobe(f, q, rcfg.max_depth, n_probes))
    assert multi.shape == (2, 16, n_probes)
    assert (multi[:, :, 0] == single).all()
    child = np.asarray(f.child_base)
    for t in range(2):
        for b in range(16):
            real = multi[t, b][multi[t, b] >= 0]
            assert (child[t][real] < 0).all()
            assert len(set(real.tolist())) == real.size


@settings(max_examples=6, deadline=None)
@given(delta=st.sampled_from([-512, -64, 64, 512]),
       n=st.integers(80, 200), n_probes=st.sampled_from([1, 3]),
       seed=st.integers(0, 2**30))
def test_traversal_kernels_agree_across_smem_cap(delta, n, n_probes, seed):
    """For tree allocations straddling the old 64k SMEM node cap: the
    HBM-resident kernel, the SMEM kernel (legal in interpret mode at any
    size) and the jnp ref produce bitwise-identical leaves — the cap is a
    dispatch boundary, never a semantics boundary (DESIGN.md §11)."""
    from repro.kernels.forest_traverse import SMEM_NODE_CAP, forest_traverse
    from repro.kernels.forest_traverse_hbm import forest_traverse_hbm_tree
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, 8)).astype(np.float32))
    cfg = ForestConfig(n_trees=1, capacity=8,
                       max_nodes=SMEM_NODE_CAP + delta)
    rcfg = cfg.resolved(n)
    f = build_forest(jax.random.key(seed % 1000), x, cfg)
    q = x[:12]
    args = (f.proj_idx[0, :, 0], f.thresh[0], f.child_base[0], q,
            rcfg.max_depth)
    hbm = forest_traverse_hbm_tree(*args, interpret=True, n_probes=n_probes)
    smem = forest_traverse(*args, interpret=True, n_probes=n_probes)
    if n_probes == 1:
        want = ref.forest_traverse_ref(*args)
    else:
        want = ref.forest_traverse_multiprobe_ref(*args, n_probes)
    np.testing.assert_array_equal(np.asarray(hbm), np.asarray(smem))
    np.testing.assert_array_equal(np.asarray(hbm), np.asarray(want))


@settings(**SETTINGS)
@given(b=st.integers(1, 8), m=st.integers(2, 50), seed=st.integers(0, 2**30))
def test_mask_duplicates_idempotent_and_correct(b, m, seed):
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, max(m // 2, 1), size=(b, m))
                      .astype(np.int32))
    mask = jnp.asarray(rng.uniform(size=(b, m)) < 0.8)
    m1 = mask_duplicates(ids, mask)
    m2 = mask_duplicates(ids, m1)
    assert (np.asarray(m1) == np.asarray(m2)).all()
    # surviving ids are unique per row and cover the same id set
    idsn, m1n, maskn = np.asarray(ids), np.asarray(m1), np.asarray(mask)
    for r_ in range(b):
        kept = idsn[r_][m1n[r_]]
        assert len(set(kept)) == len(kept)
        assert set(kept) == set(idsn[r_][maskn[r_]])


@settings(**SETTINGS)
@given(b=st.integers(1, 5), parts=st.integers(1, 4), k=st.integers(1, 8),
       seed=st.integers(0, 2**30))
def test_topk_merge_associative(b, parts, k, seed):
    """Merging shard top-k lists in any grouping gives the global top-k."""
    rng = np.random.default_rng(seed)
    d = rng.uniform(size=(b, parts * k)).astype(np.float32)
    i = rng.permutation(parts * k * b).reshape(b, parts * k).astype(np.int32)
    all_d, all_i = merge_topk_pairs(jnp.asarray(d), jnp.asarray(i), k)
    # pairwise merge in a different order
    acc_d, acc_i = merge_topk_pairs(jnp.asarray(d[:, :k]),
                                    jnp.asarray(i[:, :k]), k)
    for p in range(1, parts):
        cat_d = jnp.concatenate([acc_d, jnp.asarray(d[:, p * k:(p + 1) * k])],
                                axis=1)
        cat_i = jnp.concatenate([acc_i, jnp.asarray(i[:, p * k:(p + 1) * k])],
                                axis=1)
        acc_d, acc_i = merge_topk_pairs(cat_d, cat_i, k)
    np.testing.assert_allclose(np.asarray(all_d), np.asarray(acc_d),
                               rtol=1e-6)


@settings(**SETTINGS)
@given(b=st.integers(1, 6), h=st.integers(1, 8), seed=st.integers(0, 2**30))
def test_embedding_bag_linearity(b, h, seed):
    """bag(w1 + w2) == bag(w1) + bag(w2) — the op is linear in weights."""
    rng = np.random.default_rng(seed)
    tab = jnp.asarray(rng.normal(size=(37, 8)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 37, size=(b, h)).astype(np.int32))
    w1 = jnp.asarray(rng.uniform(size=(b, h)).astype(np.float32))
    w2 = jnp.asarray(rng.uniform(size=(b, h)).astype(np.float32))
    lhs = ref.embedding_bag_ref(ids, w1 + w2, tab)
    rhs = ref.embedding_bag_ref(ids, w1, tab) + ref.embedding_bag_ref(
        ids, w2, tab)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-5,
                               atol=1e-5)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**30))
def test_rotation_invariance_mace(seed):
    """MACE total energy is invariant under global rotation (E(3))."""
    from repro.configs.base import MACEConfig
    from repro.models.mace import init_mace, mace_fwd
    import scipy.spatial.transform as sst
    rng = np.random.default_rng(seed)
    cfg = MACEConfig(n_layers=1, d_hidden=8, n_rbf=4, r_cut=3.0, n_species=4)
    params = init_mace(jax.random.key(seed % 997), cfg)
    n = 12
    pos = rng.uniform(-1.5, 1.5, size=(n, 3)).astype(np.float32)
    species = jnp.asarray(rng.integers(0, 4, size=n))
    dmat = np.linalg.norm(pos[:, None] - pos[None], axis=-1)
    s, r_ = np.where((dmat < 3.0) & (dmat > 0))
    if len(s) == 0:
        return
    e1 = mace_fwd(params, cfg, species, jnp.asarray(pos), jnp.asarray(s),
                  jnp.asarray(r_))["energy"]
    rot = sst.Rotation.random(random_state=seed % 123).as_matrix().astype(
        np.float32)
    e2 = mace_fwd(params, cfg, species, jnp.asarray(pos @ rot.T),
                  jnp.asarray(s), jnp.asarray(r_))["energy"]
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=2e-4,
                               atol=2e-5)


@settings(**SETTINGS)
@given(n=st.integers(150, 400), d=st.integers(4, 16),
       cap=st.sampled_from([2, 4, 6, 8]),
       tol=st.floats(0.0, 0.5), seed=st.integers(0, 2**30))
def test_probe_schedule_envelope(n, d, cap, tol, seed):
    """For ANY (data, seed, threshold): the per-query probe scheduler
    (DESIGN.md §14) may only trade inside its envelope — recall at least
    the fixed n_probes=1 floor (round 0 IS that search, and replacement
    rounds rerank supersets of its candidates), probes processed at most
    the never-converge cumulative budget, final width at most the cap."""
    from repro.core.knn import exact_knn
    from repro.core.pipeline import fused_query
    from repro.core.schedule import probe_widths, scheduled_query
    from repro.core.search import recall_at_k
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(8, d)).astype(np.float32))
    cfg = ForestConfig(n_trees=3, capacity=8)
    forest = build_forest(jax.random.key(seed % 9973), x, cfg)
    rcfg = cfg.resolved(n)
    k = 5
    _, true_i = exact_knn(q, x, k=k)
    _, base_i = fused_query(forest, q, x, k, rcfg, n_probes=1)
    _, sched_i, final, processed = scheduled_query(
        forest, q, x, k, rcfg, cap=cap, tol=tol)
    assert float(recall_at_k(sched_i, true_i)) >= \
        float(recall_at_k(base_i, true_i))
    assert processed.max() <= sum(probe_widths(cap))
    assert final.max() <= cap
    assert final.min() >= 1

"""Invariants of the TPU-native level-synchronous forest builder."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Forest, ForestConfig, build_forest, exact_knn,
                        gather_candidates, query_forest, recall_at_k,
                        traverse)
from repro.core.forest import forest_stats
from repro.data.synthetic import clustered_gaussians

N, D = 4000, 32


@pytest.fixture(scope="module")
def db(shared_builds):
    return shared_builds.clustered_db(N, D, n_clusters=16, seed=0)


@pytest.fixture(scope="module")
def forest(shared_builds, db):
    cfg = ForestConfig(n_trees=8, capacity=12, split_ratio=0.3)
    return shared_builds.forest(0, cfg, db)


def test_partition_complete(forest):
    """Every DB point appears exactly once in every tree's leaf CSR."""
    f, cfg = forest
    perm = np.asarray(f.perm)
    for l in range(perm.shape[0]):
        assert sorted(perm[l]) == list(range(N))


def test_leaf_counts_consistent(forest):
    f, cfg = forest
    counts = np.asarray(f.leaf_count)
    child = np.asarray(f.child_base)
    for l in range(counts.shape[0]):
        leaves = child[l] < 0
        assert counts[l][leaves].sum() == N          # completeness
        assert (counts[l][~leaves] == 0).all()       # internals hold nothing


def test_capacity_bound(forest):
    """Paper §3: every leaf holds <= C points (no fat-leaf overflow here)."""
    f, cfg = forest
    stats = forest_stats(f, cfg, N)
    assert stats["occ_max"] <= cfg.capacity
    assert stats["overflow_points"] == 0


def test_split_balance(forest):
    """Each split sends >= floor(r * n) points to each child (Eq. 1 psi in
    the [r, 1-r] percentile band)."""
    f, cfg = forest
    counts = np.asarray(f.leaf_count)
    child = np.asarray(f.child_base)

    def subtree_count(l, node):
        if child[l, node] < 0:
            return counts[l, node]
        return subtree_count(l, child[l, node]) + \
            subtree_count(l, child[l, node] + 1)

    import sys
    sys.setrecursionlimit(100000)
    for l in range(counts.shape[0]):
        stack = [0]
        while stack:
            n_ = stack.pop()
            if child[l, n_] < 0:
                continue
            left, right = child[l, n_], child[l, n_] + 1
            cl, cr = subtree_count(l, left), subtree_count(l, right)
            tot = cl + cr
            if tot > cfg.capacity:   # only nodes that actually split
                assert min(cl, cr) >= int(np.floor(cfg.split_ratio * tot)) - 1
            stack.extend([left, right])


def test_traverse_reaches_leaves(forest, db):
    f, cfg = forest
    leaves = np.asarray(traverse(f, db[:100], cfg.max_depth))
    child = np.asarray(f.child_base)
    for l in range(leaves.shape[0]):
        assert (child[l][leaves[l]] < 0).all()


def test_db_point_lands_in_own_leaf(forest, db):
    """Dropping a DB point down a tree must land in the leaf containing it."""
    f, cfg = forest
    leaves = np.asarray(traverse(f, db[:64], cfg.max_depth))   # (L, 64)
    ids, mask = gather_candidates(f, jnp.asarray(leaves), cfg.leaf_pad)
    ids, mask = np.asarray(ids), np.asarray(mask)
    for q in range(64):
        assert q in set(ids[q][mask[q]])


def test_query_recall(forest, db):
    f, cfg = forest
    q = db[:128]
    d, ids = query_forest(f, q, db, k=1, cfg=cfg)
    td, tids = exact_knn(q, db, k=1)
    rec = float(recall_at_k(ids, tids))
    assert rec > 0.9, rec   # 8 trees on clustered data: self-NN easily found
    # distances must match the true distance when the id matches
    same = np.asarray(ids[:, 0]) == np.asarray(tids[:, 0])
    # exact_knn uses the |q|^2-2qc+|c|^2 matmul expansion: ~1e-5 float noise
    np.testing.assert_allclose(np.asarray(d[:, 0])[same],
                               np.asarray(td[:, 0])[same], rtol=1e-3,
                               atol=5e-5)


def test_recall_improves_with_trees(shared_builds, db):
    # one 16-tree build; smaller forests are prefixes (trees independent)
    full_cfg = ForestConfig(n_trees=16, capacity=12, split_ratio=0.3)
    full, _ = shared_builds.forest(1, full_cfg, db)
    q = db[200:328] + 0.02 * jax.random.normal(jax.random.key(2), (128, D))
    _, tids = exact_knn(q, db, k=1)
    recalls = []
    for l in [1, 4, 16]:
        f = jax.tree.map(lambda a: a[:l], full)
        cfg = full_cfg._replace(n_trees=l)
        _, ids = query_forest(f, q, db, k=1, cfg=cfg)
        recalls.append(float(recall_at_k(ids, tids)))
    assert recalls[0] <= recalls[1] <= recalls[2] + 0.02
    assert recalls[2] > recalls[0]


def test_k2_projections(db):
    """K=2 random sparse hyperplanes (paper §3.1 general case)."""
    cfg = ForestConfig(n_trees=4, capacity=16, split_ratio=0.3, n_proj=2)
    f = build_forest(jax.random.key(3), db, cfg)
    rcfg = cfg.resolved(N)
    stats = forest_stats(f, rcfg, N)
    assert stats["occ_max"] <= 16
    q = db[:64]
    d, ids = query_forest(f, q, db, k=1, cfg=cfg)
    _, tids = exact_knn(q, db, k=1)
    assert float(recall_at_k(ids, tids)) > 0.7


def test_chi2_query(db):
    dbh = jnp.abs(db)
    cfg = ForestConfig(n_trees=8, capacity=12)
    f = build_forest(jax.random.key(4), dbh, cfg)
    q = dbh[:64]
    d, ids = query_forest(f, q, dbh, k=1, cfg=cfg, metric="chi2")
    _, tids = exact_knn(q, dbh, k=1, metric="chi2")
    assert float(recall_at_k(ids, tids)) > 0.9

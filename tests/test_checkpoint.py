import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.train.train_state import TrainState


def _state(seed=0):
    k = jax.random.key(seed)
    return TrainState(
        step=jnp.asarray(7),
        params={"w": jax.random.normal(k, (8, 4)),
                "nested": {"b": jnp.arange(5, dtype=jnp.float32)}},
        opt_state={"m": jnp.zeros((8, 4))},
        residuals=None)


def test_roundtrip_identity():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        s = _state()
        ck.save(7, s, block=True)
        restored, step = ck.restore(_state(seed=1))
        assert step == 7
        np.testing.assert_array_equal(np.asarray(restored.params["w"]),
                                      np.asarray(s.params["w"]))
        np.testing.assert_array_equal(
            np.asarray(restored.params["nested"]["b"]),
            np.asarray(s.params["nested"]["b"]))


def test_async_save_and_wait():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(1, _state(), block=False)
        ck.wait()
        assert ck.latest_step() == 1


def test_gc_keeps_latest():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        for s in (1, 2, 3, 4):
            ck.save(s, _state(), block=True)
        assert ck.all_steps() == [3, 4]


def test_atomicity_tmp_never_visible():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(5, _state(), block=True)
        assert not any(p.endswith(".tmp") for p in os.listdir(d))


def test_restore_missing_raises():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        with pytest.raises(FileNotFoundError):
            ck.restore(_state())


def test_restore_casts_dtype():
    """Elastic restore: target dtype wins (e.g. bf16 -> f32 promotion)."""
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        s = _state()
        s = s._replace(params=jax.tree.map(
            lambda a: a.astype(jnp.bfloat16), s.params))
        ck.save(2, s, block=True)
        restored, _ = ck.restore(_state())   # f32 target
        assert restored.params["w"].dtype == jnp.float32

"""Shared test infrastructure: the build cache + the CI shard splitter.

Two pieces (both motivated by CI wall-clock — see DESIGN.md §10):

* ``shared_builds`` — a session-scoped cache of deterministic, expensive
  builds (synthetic corpora, forests, whole indexes), keyed by
  ``(seed, cfg, data descriptor)``.  Builds are pure functions of their
  key, so tests that used to rebuild identical small forests now share
  one.  ONLY read-only uses may share: a test that mutates an index
  (delete/upsert/tune/compact) must build its own fresh instance.

* a pytest-split-style shard splitter — ``--splits N --group K``
  partitions test FILES into N duration-balanced groups so CI can run
  tier-1 as a matrix.  File granularity keeps module-scoped fixtures and
  the build cache effective inside one shard.  Weights come from
  ``--durations-path`` — the COMMITTED ``tests/.test_durations.json``,
  never a cache, so the partition is a pure function of the checkout and
  every matrix job computes the identical split (no test can be silently
  dropped by cache skew); files missing from it fall back to a
  size-based estimate.  Fresh timings are written by
  ``--store-durations`` (optionally to ``--store-durations-path`` — CI
  shards write per-group fragments, cached via actions/cache, and a
  drift check nags when the committed weights go stale).
"""
import json
import os
import sys

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; only launch/dryrun.py forces 512, and the
# multi-device tests spawn subprocesses that set 8.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest  # noqa: E402

_DEFAULT_DURATIONS = os.path.join(os.path.dirname(__file__),
                                  ".test_durations.json")


# ---------------------------------------------------------------------------
# session-scoped build cache
# ---------------------------------------------------------------------------


class SharedBuilds:
    """Session cache of deterministic builds, keyed by (seed, cfg, data).

    Everything handed out is shared across tests: treat it as frozen.
    ``index()`` builds are for read-only searching; mutating tests
    (delete/upsert/tune/save) build fresh via ``repro.index.build_index``.
    """

    def __init__(self):
        self._cache = {}

    def get(self, key, builder):
        """Generic memo: ``builder()`` runs once per hashable ``key``."""
        if key not in self._cache:
            self._cache[key] = builder()
        return self._cache[key]

    # ---- corpora ---------------------------------------------------------
    def clustered_db(self, n, d, n_clusters=16, seed=0):
        """jnp clustered_gaussians corpus (the standard ANN test corpus)."""
        import jax.numpy as jnp
        from repro.data.synthetic import clustered_gaussians
        return self.get(
            ("db.clustered", n, d, n_clusters, seed),
            lambda: jnp.asarray(clustered_gaussians(
                n, d, n_clusters=n_clusters, seed=seed)))

    def normal_db(self, n, d, seed, nonneg=False):
        """jnp standard-normal corpus (|x| when ``nonneg``, for chi2)."""
        import jax.numpy as jnp
        import numpy as np

        def build():
            x = np.random.default_rng(seed).normal(size=(n, d))
            x = np.abs(x) if nonneg else x
            return jnp.asarray(x.astype(np.float32))

        return self.get(("db.normal", n, d, seed, nonneg), build)

    # ---- forests ---------------------------------------------------------
    def forest(self, key_seed, cfg, db):
        """(Forest, resolved cfg) for ``build_forest(key(key_seed), db)``.

        ``db`` must come from one of the corpus methods above (its cache
        key rides along via identity lookup).
        """
        import jax
        from repro.core.forest import build_forest
        db_key = self._desc_of(db)
        return self.get(
            ("forest", key_seed, cfg, db_key),
            lambda: (build_forest(jax.random.key(key_seed), db, cfg),
                     cfg.resolved(db.shape[0])))

    # ---- whole indexes (READ-ONLY sharing) -------------------------------
    def index(self, backend, key_seed, db, forest_cfg=None, **spec_kw):
        """A built ``repro.index`` Index for read-only searching."""
        import jax
        import numpy as np
        from repro.index import IndexSpec, build_index
        if forest_cfg is not None:
            spec_kw["forest"] = forest_cfg
        spec = IndexSpec(backend=backend, **spec_kw)
        db_key = self._desc_of(db)
        return self.get(
            ("index", key_seed, spec, db_key),
            lambda: build_index(jax.random.key(key_seed), np.asarray(db),
                                spec))

    def _desc_of(self, db):
        """Reverse-map a cached corpus array to its descriptor key."""
        for key, val in self._cache.items():
            if val is db:
                return key
        raise KeyError(
            "db is not a SharedBuilds corpus; build it via clustered_db()/"
            "normal_db() so the cache key describes the data")


@pytest.fixture(scope="session")
def shared_builds():
    return SharedBuilds()


# ---------------------------------------------------------------------------
# duration-balanced file sharding (the tier-1 CI matrix)
# ---------------------------------------------------------------------------


def pytest_addoption(parser):
    group = parser.getgroup("shard", "duration-balanced test file sharding")
    group.addoption("--splits", type=int, default=0,
                    help="partition test files into this many groups")
    group.addoption("--group", type=int, default=1,
                    help="1-based group index of this run")
    group.addoption("--durations-path", default=_DEFAULT_DURATIONS,
                    help="per-file durations JSON read for balancing (the "
                         "committed file: the partition must be a pure "
                         "function of the checkout so every CI shard "
                         "computes the same split)")
    group.addoption("--store-durations", action="store_true",
                    help="write measured per-file durations on session "
                         "finish (to --store-durations-path)")
    group.addoption("--store-durations-path", default="",
                    help="write target for --store-durations; defaults to "
                         "--durations-path (CI shards write per-group "
                         "fragments instead to avoid racing the committed "
                         "weights)")


def _load_durations(path):
    try:
        with open(path) as f:
            data = json.load(f)
        return {k: float(v) for k, v in data.items()}
    except (OSError, ValueError):
        return {}


def _file_weight(rel_path, durations):
    if rel_path in durations:
        return durations[rel_path]
    # deterministic fallback: bigger files tend to run longer; the exact
    # scale is irrelevant (only the partition's balance depends on it)
    try:
        return os.path.getsize(os.path.join(
            os.path.dirname(__file__), "..", rel_path)) / 4000.0
    except OSError:
        return 1.0


def _partition(files, weights, n_groups):
    """Greedy longest-processing-time bin packing; deterministic."""
    bins = [(0.0, i, []) for i in range(n_groups)]
    for f in sorted(files, key=lambda f: (-weights[f], f)):
        load, idx, members = min(bins)
        members.append(f)
        bins[idx] = (load + weights[f], idx, members)
    return {f: idx + 1 for _, idx, members in bins for f in members}


def _rel_file(item):
    return item.location[0].replace(os.sep, "/")


@pytest.hookimpl(trylast=True)
def pytest_collection_modifyitems(config, items):
    splits = config.getoption("--splits")
    if splits <= 1:
        return
    group = config.getoption("--group")
    if not 1 <= group <= splits:
        raise pytest.UsageError(f"--group must be in 1..{splits}, "
                                f"got {group}")
    durations = _load_durations(config.getoption("--durations-path"))
    files = sorted({_rel_file(it) for it in items})
    weights = {f: _file_weight(f, durations) for f in files}
    assignment = _partition(files, weights, splits)
    keep, drop = [], []
    for it in items:
        (keep if assignment[_rel_file(it)] == group else drop).append(it)
    if drop:
        config.hook.pytest_deselected(items=drop)
        items[:] = keep
    load = sum(weights[f] for f, g in assignment.items() if g == group)
    sys.stderr.write(f"[shard] group {group}/{splits}: {len(keep)} tests in "
                     f"{sum(1 for g in assignment.values() if g == group)} "
                     f"files (est {load:.0f}s)\n")


def pytest_configure(config):
    config._shard_file_durations = {}


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_protocol(item, nextitem):
    import time
    t0 = time.perf_counter()
    yield
    sink = item.config._shard_file_durations
    f = _rel_file(item)
    sink[f] = sink.get(f, 0.0) + (time.perf_counter() - t0)


def pytest_sessionfinish(session, exitstatus):
    config = session.config
    if not config.getoption("--store-durations", default=False):
        return
    path = config.getoption("--store-durations-path") \
        or config.getoption("--durations-path")
    merged = _load_durations(path)
    merged.update({k: round(v, 2)
                   for k, v in config._shard_file_durations.items()})
    if not merged:
        return
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(dict(sorted(merged.items())), f, indent=1)
        f.write("\n")

"""Paper-pseudocode (Fig. 1/3) transcription: semantics oracle tests, and
agreement between the incremental and the level-synchronous builders."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ForestConfig, build_forest, exact_knn, query_forest, \
    recall_at_k
from repro.core.forest_incremental import IncrementalForest
from repro.data.synthetic import clustered_gaussians

N, D = 1500, 24


def _db():
    return clustered_gaussians(N, D, n_clusters=12, seed=5)


def test_incremental_invariants():
    x = _db()
    forest = IncrementalForest(x, n_trees=3, capacity=12, split_ratio=0.3,
                               seed=0)
    for tree in forest.trees:
        leaves = tree.leaves()
        pts = [p for lf in leaves for p in lf.points]
        assert sorted(pts) == list(range(N))             # complete + disjoint
        assert max(len(lf.points) for lf in leaves) <= 12
        mean_d, max_d = tree.depth_stats()
        assert mean_d > 3


def test_incremental_retrieve_contains_self():
    x = _db()
    forest = IncrementalForest(x, n_trees=4, capacity=12, seed=1)
    for i in range(0, 50, 7):
        cand = forest.retrieve(x[i])
        assert i in set(cand.tolist())


def test_incremental_query_recall():
    x = _db()
    forest = IncrementalForest(x, n_trees=10, capacity=12, seed=2)
    rng = np.random.default_rng(0)
    q = x[:40] + 0.02 * rng.normal(size=(40, D)).astype(np.float32)
    t_d, t_i = exact_knn(jnp.asarray(q), jnp.asarray(x), k=1)
    hits = 0
    for j in range(40):
        _, ids = forest.query(q[j], k=1)
        hits += int(ids[0] == int(t_i[j, 0]))
    assert hits / 40 > 0.85


def test_builders_agree_statistically():
    """The two builders produce the same partition DISTRIBUTION: equal-L
    forests should give recalls within a few points of each other, and
    similar candidate-set sizes (the paper's accuracy-vs-cost operating
    point does not depend on the build schedule)."""
    x = _db()
    L, C = 8, 12
    rng = np.random.default_rng(1)
    q = x[:60] + 0.02 * rng.normal(size=(60, D)).astype(np.float32)
    _, t_i = exact_knn(jnp.asarray(q), jnp.asarray(x), k=1)

    inc = IncrementalForest(x, n_trees=L, capacity=C, seed=3)
    inc_hits = np.mean([
        int(inc.query(q[j], k=1)[1][0] == int(t_i[j, 0])) for j in range(60)])
    inc_cost = np.mean([inc.retrieve(q[j]).size for j in range(60)])

    cfg = ForestConfig(n_trees=L, capacity=C, split_ratio=0.3)
    f = build_forest(jax.random.key(6), jnp.asarray(x), cfg)
    _, ids = query_forest(f, jnp.asarray(q), jnp.asarray(x), k=1, cfg=cfg)
    bat_hits = float(recall_at_k(ids, t_i))

    assert abs(inc_hits - bat_hits) < 0.15, (inc_hits, bat_hits)
    # candidate cost within 2x of each other (same C, same L)
    rcfg = cfg.resolved(N)
    from repro.core.forest import gather_candidates, traverse
    from repro.core.search import mask_duplicates
    leaves = traverse(f, jnp.asarray(q), rcfg.max_depth)
    cids, mask = gather_candidates(f, leaves, rcfg.leaf_pad)
    bat_cost = float(mask_duplicates(cids, mask).sum(1).mean())
    assert 0.5 < bat_cost / max(inc_cost, 1) < 2.0, (bat_cost, inc_cost)

"""Serving runtime subsystem tests (DESIGN.md §12).

Covers the PR-7 surface: manifest v4 round-trip + v3/v2/v1 read shims,
open-loop load-generator determinism, the batcher's shutdown contract
(drain vs fail-fast — no submitter ever hangs), degradation-ladder
construction + the "shedding never makes the tail worse" property, the
capacity planner's model math, and per-shard tuning.

The ladder/overload tests run against a fake index whose search cost is a
deterministic sleep proportional to the operating point's probe budget —
wall-clock enough to exercise queueing, deterministic enough for CI.
"""
from __future__ import annotations

import glob
import json
import os
import threading
import time

import jax
import numpy as np
import pytest

from repro.core import ForestConfig
from repro.index import (IndexSpec, SearchParams, build_index, load_index,
                         tune, tune_sharded)
from repro.serve import (BatcherStopped, DynamicBatcher, ServingRuntime,
                         arrival_schedule, build_ladder, loadgen, planner,
                         uniform_shard_params)
from repro.serve.runtime import _ladder_cost

SEED = 0


@pytest.fixture(scope="module")
def corpus(shared_builds):
    db = shared_builds.clustered_db(2000, 16, n_clusters=16, seed=SEED)
    q = db[np.random.default_rng(1).integers(0, len(db), 32)] + 0.003
    return db, np.asarray(q)


def _build(db, n_trees=8, capacity=32):
    spec = IndexSpec(backend="rpf",
                     forest=ForestConfig(n_trees=n_trees, capacity=capacity))
    return build_index(jax.random.key(SEED), db, spec)


# ---------------------------------------------------------------------------
# manifest v4 round-trip + read shims
# ---------------------------------------------------------------------------


def _manifest_path(root: str) -> str:
    return glob.glob(os.path.join(root, "step_*", "manifest.json"))[0]


def test_manifest_v4_roundtrip(tmp_path, corpus):
    db, q = corpus
    index = _build(db)
    tuned = tune(index, q, target_recall=0.8, k=10, probe_grid=(1, 2, 4),
                 tree_fracs=(1.0,))
    shard_params, _ = tune_sharded(index, q, n_shards=2, target_recall=0.8,
                                   k=10, probe_grid=(1, 2, 4))
    plan_payload = {"plan": {"qps": 500.0, "slo_p99_ms": 25.0,
                             "n_shards": 1, "n_replicas": 1, "batch": 32,
                             "rated_qps_per_replica": 700.0,
                             "predicted_p99_ms": 11.0, "utilization": 0.7,
                             "recall_target": 0.8},
                    "traffic_model": {"c0_s": 1e-3, "c1_s": 1e-5,
                                      "max_wait_s": 2e-3, "batch_grid": [1],
                                      "measured_s": [1e-3],
                                      "rows_per_query": 8.0}}
    index.serving_plan = plan_payload
    d0, i0 = map(np.asarray, index.search(q))

    path = str(tmp_path / "v4")
    index.save(path)
    with open(_manifest_path(path)) as fh:
        man = json.load(fh)
    assert man["extra"]["format"] == 5
    assert man["extra"]["meta_schema"] is None   # no metadata attached

    loaded = load_index(path)
    # the full v4 payload survives: tuned point, per-shard points, plan
    assert loaded.tuned_params == tuned
    assert loaded.shard_params == tuple(shard_params)
    assert loaded.serving_plan == plan_payload
    d1, i1 = map(np.asarray, loaded.search(q))
    assert np.array_equal(i0, i1) and np.array_equal(d0, d1)   # bitwise

    # and the runtime stands up from it without retuning
    rt = ServingRuntime.load(path, warmup=False)
    assert rt.params == uniform_shard_params(shard_params)
    assert rt.max_batch == 32          # from the persisted plan
    assert ServingRuntime.manifest_plan(loaded).qps == 500.0
    assert ServingRuntime.manifest_traffic_model(loaded).c0_s == 1e-3
    rt.stop()


@pytest.mark.parametrize("fmt", [3, 2])
def test_manifest_v3_v2_read_shims(tmp_path, corpus, fmt):
    db, q = corpus
    index = _build(db)
    tuned = tune(index, q, target_recall=0.8, k=10, probe_grid=(1, 2, 4),
                 tree_fracs=(1.0,))
    index.shard_params = (tuned, tuned)
    index.serving_plan = {"plan": None, "traffic_model": None}
    d0, i0 = map(np.asarray, index.search(q, tuned))

    path = str(tmp_path / f"v{fmt}")
    index.save(path)
    # rewrite the manifest as the older writer would have produced it
    mp = _manifest_path(path)
    with open(mp) as fh:
        man = json.load(fh)
    man["extra"]["format"] = fmt
    man["extra"].pop("meta_schema")
    man["extra"].pop("shard_params")
    man["extra"].pop("serving_plan")
    if fmt == 2:
        man["extra"].pop("tuned_params")
    with open(mp, "w") as fh:
        json.dump(man, fh)

    legacy = load_index(path)
    assert legacy.shard_params is None
    assert legacy.serving_plan is None
    assert legacy.tuned_params == (tuned if fmt == 3 else None)
    d1, i1 = map(np.asarray, legacy.search(q, tuned))
    assert np.array_equal(i0, i1) and np.array_equal(d0, d1)


def test_manifest_v1_read_shim_serves(tmp_path, corpus):
    """A pre-segment flat checkpoint still stands a runtime up."""
    from repro.checkpoint.checkpointer import Checkpointer
    db, q = corpus
    index = _build(db)
    path = str(tmp_path / "v1")
    Checkpointer(path, keep=1).save(
        0, {"db": index.db, "key_data": jax.random.key_data(index.key),
            "forest": index.forest},
        extra={"spec": index.spec.to_dict(), "backend": "rpf"})
    legacy = load_index(path)
    assert legacy.tuned_params is None and legacy.shard_params is None
    rt = ServingRuntime(legacy, params=SearchParams(k=5, n_probes=2),
                        max_batch=8, warmup=False)
    d, i = rt(q[0])
    assert i.shape == (5,) and np.isfinite(d).all()
    rt.stop()


# ---------------------------------------------------------------------------
# open-loop load generator
# ---------------------------------------------------------------------------


def test_arrival_schedule_deterministic():
    a = arrival_schedule(500.0, 1000, seed=7)
    b = arrival_schedule(500.0, 1000, seed=7)
    c = arrival_schedule(500.0, 1000, seed=8)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
    assert a[0] == 0.0 and np.all(np.diff(a) >= 0)
    # exponential gaps at rate qps: mean inter-arrival ~ 1/qps
    assert np.mean(np.diff(a)) == pytest.approx(1 / 500.0, rel=0.2)
    with pytest.raises(ValueError):
        arrival_schedule(0.0, 10)


def test_open_loop_charges_from_scheduled_time():
    """Latency is charged from the SCHEDULED arrival, not the submit call —
    the no-coordinated-omission property: a stalled server shows up in
    every queued request's tail, not just the one it stalled on."""
    stall = threading.Event()

    def fn(batch):
        stall.wait(0.2)
        return [0 for _ in batch]

    b = DynamicBatcher(fn, max_batch=4, max_wait_s=0.001).start()
    rep = loadgen.run_open_loop(b, np.zeros((4, 2), np.float32), qps=400.0,
                                n_requests=40, seed=0, timeout_s=10.0)
    b.stop()
    assert rep["n_ok"] == 40 and rep["n_failed"] == 0
    # the first batch stalls ~200ms; requests scheduled meanwhile queue up
    # behind it and must be charged that wait
    assert rep["p50_ms"] > 50.0
    assert rep["p999_ms"] >= rep["p99_ms"] >= rep["p50_ms"]


# ---------------------------------------------------------------------------
# batcher shutdown contract (the PR-6 stop() bug)
# ---------------------------------------------------------------------------


def _slow_echo(delay_s):
    def fn(batch):
        time.sleep(delay_s)
        return list(batch)
    return fn


def test_stop_drain_serves_every_queued_request():
    b = DynamicBatcher(_slow_echo(0.02), max_batch=4,
                       max_wait_s=0.001).start()
    reqs = [b.submit(j) for j in range(32)]      # ~8 batches of backlog
    b.stop(drain=True)
    assert all(r.event.is_set() for r in reqs)
    assert all(r.error is None and r.result == j
               for j, r in enumerate(reqs))
    assert b.stats["stopped"] == "drained"
    assert b.stats["failed_on_stop"] == 0
    assert b.stats["requests"] == 32


def test_stop_no_drain_fails_pending_fast():
    b = DynamicBatcher(_slow_echo(0.05), max_batch=4,
                       max_wait_s=0.001).start()
    reqs = [b.submit(j) for j in range(32)]
    t0 = time.perf_counter()
    b.stop(drain=False)
    took = time.perf_counter() - t0
    # worker finishes its in-flight batch then exits; queued work FAILS
    # instead of being served (32 reqs would otherwise take ~0.4s)
    assert took < 0.3
    assert all(r.event.is_set() for r in reqs)    # nobody hangs
    failed = [r for r in reqs if isinstance(r.error, BatcherStopped)]
    assert len(failed) >= 1
    assert b.stats["stopped"] == "failed"
    assert b.stats["failed_on_stop"] == len(failed)
    assert len(failed) + b.stats["requests"] == 32


def test_submit_after_stop_fail_fast():
    b = DynamicBatcher(_slow_echo(0.0), max_batch=4).start()
    b.stop()
    req = b.submit(1)
    assert req.event.is_set() and isinstance(req.error, BatcherStopped)
    with pytest.raises(BatcherStopped):
        b(2)


def test_concurrent_submitters_never_hang_across_stop():
    b = DynamicBatcher(_slow_echo(0.01), max_batch=8,
                       max_wait_s=0.001).start()
    outcomes: list = []

    def client(i):
        try:
            outcomes.append(("ok", b(i, timeout=10.0)))
        except BatcherStopped:
            outcomes.append(("stopped", i))

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(24)]
    for t in threads:
        t.start()
    time.sleep(0.02)
    b.stop(drain=False)
    for t in threads:
        t.join(timeout=10.0)
        assert not t.is_alive()      # the contract: no submitter hangs
    assert len(outcomes) == 24


# ---------------------------------------------------------------------------
# degradation ladder
# ---------------------------------------------------------------------------


def test_build_ladder_strictly_cheaper():
    base = SearchParams(k=10, n_probes=8)
    ladder = build_ladder(base, total_trees=16)
    assert ladder[0] == base
    costs = [_ladder_cost(p, 16) for p in ladder]
    assert all(a > b for a, b in zip(costs, costs[1:]))
    # probes step down before trees, trees floor at total//4
    assert [p.n_probes for p in ladder[:4]] == [8, 4, 2, 1]
    assert all((p.n_trees or 16) >= 4 for p in ladder)
    # adaptive-wave base points skip the tree rungs (waves already scale)
    wav = build_ladder(SearchParams(n_probes=4, adaptive_wave=2), 16)
    assert all(p.n_trees == 0 for p in wav)
    # degenerate base: ladder is just the base point
    assert build_ladder(SearchParams(n_probes=1, n_trees=4), 16) == \
        (SearchParams(n_probes=1, n_trees=4),)


def test_uniform_shard_params_covers_every_shard():
    a = SearchParams(k=10, n_probes=2, expand=2, n_trees=4)
    c = SearchParams(k=10, n_probes=8, expand=4, n_trees=4)
    u = uniform_shard_params([a, c])
    assert u.n_probes == 8 and u.expand == 4
    assert u.sharded_violations() == []     # mesh-legal by construction
    with pytest.raises(ValueError):
        uniform_shard_params([])


class _FakeIndex:
    """Index stand-in whose search cost is a deterministic sleep scaling
    with the probe budget — makes overload timing reproducible."""

    def __init__(self, per_probe_s=0.002, n_trees=8):
        self.spec = IndexSpec(backend="rpf",
                              forest=ForestConfig(n_trees=n_trees))
        self.tuned_params = SearchParams(k=5, n_probes=8)
        self.shard_params = None
        self.serving_plan = None

    def search(self, q, params):
        time.sleep(0.002 * params.n_probes)
        n = q.shape[0]
        return (np.zeros((n, params.k), np.float32),
                np.tile(np.arange(params.k), (n, 1)))

    def live_points(self):
        rows = np.zeros((64, 4), np.float32)
        return np.arange(64), rows


def _overload_run(degrade: bool, qps: float, n: int):
    rt = ServingRuntime(_FakeIndex(), max_batch=8, max_wait_s=0.002,
                        slo_p99_ms=50.0, degrade=degrade)
    rep = loadgen.run_open_loop(rt, np.zeros((8, 4), np.float32), qps,
                                n_requests=n, seed=3, timeout_s=60.0)
    stats = rt.stats()
    rt.stop()
    return rep, stats


def test_ladder_sheds_and_never_worsens_the_tail():
    """Past saturation, degrade=True must (a) actually shed, (b) keep the
    tail no worse than the no-ladder control at the same offered load.

    Rung 0 costs 16ms/batch-of-8 (=500 qps capacity); 700 qps offered is
    ~1.4x saturation, while rung 1 (4 probes) clears it with headroom.
    """
    rep_ctl, stats_ctl = _overload_run(degrade=False, qps=700.0, n=350)
    rep_lad, stats_lad = _overload_run(degrade=True, qps=700.0, n=350)
    assert stats_ctl["n_rungs"] == 1 and stats_ctl["shed_steps"] == 0
    assert rep_lad["n_ok"] == rep_ctl["n_ok"] == 350     # nobody dropped
    assert stats_lad["shed_steps"] > 0
    assert rep_lad["shed_fraction"] > 0.0
    assert rep_lad["p99_ms"] <= rep_ctl["p99_ms"]
    assert rep_lad["p999_ms"] <= rep_ctl["p999_ms"]


def test_ladder_idle_stays_on_rung_zero():
    rt = ServingRuntime(_FakeIndex(), max_batch=8, max_wait_s=0.002,
                        slo_p99_ms=200.0, degrade=True)
    for _ in range(4):
        d, i = rt(np.zeros(4, np.float32))
        assert i.shape == (5,)
    stats = rt.stats()
    rt.stop()
    assert stats["rung"] == 0
    assert stats["shed_steps"] == 0 and stats["requests_degraded"] == 0


# ---------------------------------------------------------------------------
# capacity planner
# ---------------------------------------------------------------------------


def test_fit_affine_recovers_model():
    c0, c1 = 2e-3, 5e-5
    grid = np.array([1, 8, 32, 64])
    lat = c0 + c1 * grid
    m0, m1 = planner.fit_affine(grid, lat)
    assert m0 == pytest.approx(c0, rel=1e-6)
    assert m1 == pytest.approx(c1, rel=1e-6)
    # single measurement: all cost attributed to the per-row term
    s0, s1 = planner.fit_affine([8], [4e-4])
    assert s0 == 0.0 and s1 == pytest.approx(5e-5)


def test_traffic_model_roundtrip_and_p99():
    m = planner.TrafficModel(c0_s=1e-3, c1_s=1e-5, max_wait_s=2e-3,
                             batch_grid=(1, 8), measured_s=(1e-3, 1.1e-3),
                             rows_per_query=64.0)
    assert planner.TrafficModel.from_dict(m.to_dict()) == m
    t = m.service_s(32)
    # below saturation the queueing tail is finite and grows with load;
    # at/over saturation it is infinite
    lam_sat = 32 / t
    assert m.p99_s(0.5 * lam_sat, 32) < m.p99_s(0.9 * lam_sat, 32)
    assert m.p99_s(1.1 * lam_sat, 32) == float("inf")
    # sharding s-ways cuts the per-row term s-ways
    assert m.service_s(32, n_shards=4) < m.service_s(32)


def test_rated_qps_and_plan_monotonicity():
    m = planner.TrafficModel(c0_s=1e-3, c1_s=1e-4, max_wait_s=2e-3,
                             batch_grid=(1,), measured_s=(1.1e-3,),
                             rows_per_query=0.0)
    loose = planner.rated_qps(m, slo_p99_ms=50.0, batch=32)
    tight = planner.rated_qps(m, slo_p99_ms=10.0, batch=32)
    assert 0 < tight < loose            # tighter SLO -> lower rated rate
    assert planner.rated_qps(m, slo_p99_ms=1.0, batch=32) == 0.0  # < t(B)

    p_small = planner.plan(m, qps=200.0, slo_p99_ms=50.0)
    p_big = planner.plan(m, qps=4000.0, slo_p99_ms=50.0)
    total_small = p_small.n_replicas * p_small.n_shards
    assert p_big.n_replicas * p_big.n_shards >= total_small
    assert p_big.predicted_p99_ms <= 50.0
    assert planner.CapacityPlan.from_dict(p_big.to_dict()) == p_big
    with pytest.raises(ValueError):     # SLO below c0: nothing can fit
        planner.plan(m, qps=100.0, slo_p99_ms=0.5, max_shards=1,
                     batch_grid=(1,))


# ---------------------------------------------------------------------------
# distributed tuning
# ---------------------------------------------------------------------------


def test_tune_sharded_persists_and_is_deterministic(corpus):
    db, q = corpus
    index = _build(db)
    sp1, report1 = tune_sharded(index, q, n_shards=2, target_recall=0.7,
                                k=10, probe_grid=(1, 2, 4))
    sp2, _ = tune_sharded(index, q, n_shards=2, target_recall=0.7,
                          k=10, probe_grid=(1, 2, 4))
    assert sp1 == sp2                           # deterministic
    assert len(sp1) == 2
    assert all(p.sharded_violations() == [] for p in sp1)
    assert index.shard_params == tuple(sp1)     # persisted on the index
    # per-shard rows report owned-neighbor recall; the summary row carries
    # the implied global recall = sum of owned hits / all true neighbors
    shard_rows = [r for r in report1 if r["shard"] >= 0]
    assert {r["shard"] for r in shard_rows} == {0, 1}
    assert all(0.0 <= r["recall_owned"] <= 1.0 for r in shard_rows)
    summary = [r for r in report1 if "implied_global_recall" in r]
    assert summary and 0.0 < summary[0]["implied_global_recall"] <= 1.0

"""Multi-probe traversal + recall-targeted tuner (DESIGN.md §9).

Pins the three contracts the PR rests on:
  * n_probes=1 is BITWISE-identical to the pre-multi-probe path, on the
    raw traversal, through the fused pipeline, and on all four backends;
  * widening probes only ever adds candidates (superset), so recall@k is
    non-decreasing in n_probes under the exact rerank;
  * the tuner is deterministic (same key + queries -> same SearchParams)
    and its result persists through the manifest (v3, with the v2 shim).
"""
import glob
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ForestConfig, exact_knn, fused_query,
                        gather_candidates, gather_candidates_multi,
                        recall_at_k, traverse, traverse_multiprobe)
from repro.core.adaptive import adaptive_query
from repro.core.search import rerank_topk
from repro.index import (IndexSpec, SearchParams, build_index, load_index,
                         tune, tune_report)
from repro.kernels import ops

N, D, L = 2000, 24, 8
BACKENDS = ["rpf", "rpf+int8", "lsh-cascade", "bruteforce"]
CFG = ForestConfig(n_trees=L, capacity=12)


@pytest.fixture(scope="module")
def db(shared_builds):
    return shared_builds.clustered_db(N, D, n_clusters=16, seed=0)


@pytest.fixture(scope="module")
def queries(db):
    noise = 0.03 * jax.random.normal(jax.random.key(5), (64, D))
    return db[100:164] + noise


@pytest.fixture(scope="module")
def forest(shared_builds, db):
    """The rpf index's forest, shared instead of rebuilt.

    ``build_index(key(0), db, rpf/CFG)`` builds exactly
    ``build_forest(key(0), db, CFG)`` inside its engine, so the traversal
    tests reuse that build rather than duplicating it (the builds are
    deterministic, and test_forest_batched.py pins the builder bitwise).
    """
    index = shared_builds.index("rpf", 0, db, forest_cfg=CFG)
    return index.forest, CFG.resolved(N)


# ---------------------------------------------------------------------------
# traversal-level contracts
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_probes", [1, 2, 4, 8])
def test_primary_probe_bitwise_matches_traverse(forest, queries, n_probes):
    f, cfg = forest
    single = np.asarray(traverse(f, queries, cfg.max_depth))
    multi = np.asarray(traverse_multiprobe(f, queries, cfg.max_depth,
                                           n_probes))
    assert multi.shape == (L, queries.shape[0], n_probes)
    np.testing.assert_array_equal(multi[:, :, 0], single)


def test_probes_are_distinct_leaves(forest, queries):
    f, cfg = forest
    probes = np.asarray(traverse_multiprobe(f, queries, cfg.max_depth, 6))
    child = np.asarray(f.child_base)
    for t in range(L):
        for b in range(queries.shape[0]):
            real = probes[t, b][probes[t, b] >= 0]
            assert real.size >= 1                      # primary always there
            assert (child[t][real] < 0).all()          # all are leaves
            assert len(set(real.tolist())) == real.size  # pairwise distinct


def test_gather_multi_p1_bitwise_matches_gather(forest, queries):
    f, cfg = forest
    leaves = traverse(f, queries, cfg.max_depth)
    i1, m1 = gather_candidates(f, leaves, cfg.leaf_pad)
    im, mm = gather_candidates_multi(f, leaves[:, :, None], cfg.leaf_pad)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(im))
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(mm))


def test_candidate_superset_in_probes(forest, queries):
    f, cfg = forest
    sets = []
    for p in (1, 2, 4):
        leaves = traverse_multiprobe(f, queries, cfg.max_depth, p)
        ids, mask = gather_candidates_multi(f, leaves, cfg.leaf_pad)
        ids, mask = np.asarray(ids), np.asarray(mask)
        sets.append([set(ids[b][mask[b]].tolist())
                     for b in range(queries.shape[0])])
    for prev, cur in zip(sets, sets[1:]):
        for b in range(queries.shape[0]):
            assert prev[b] <= cur[b]


def test_multiprobe_fused_matches_staged_oracle(forest, queries, db):
    """The widened candidate set through the fused rerank == the staged
    gather-everything oracle on the same candidates (ids bitwise)."""
    f, cfg = forest
    leaves = traverse_multiprobe(f, queries, cfg.max_depth, 3)
    ids, mask = gather_candidates_multi(f, leaves, cfg.leaf_pad)
    od, oi = rerank_topk(queries, ids, mask, db, k=10)
    fd, fi = fused_query(f, queries, db, 10, cfg, n_probes=3, mode="ref")
    np.testing.assert_array_equal(np.asarray(oi), np.asarray(fi))
    finite = np.isfinite(np.asarray(od))
    np.testing.assert_allclose(np.asarray(od)[finite],
                               np.asarray(fd)[finite], rtol=1e-5, atol=1e-5)


def test_monotone_recall_in_probes(forest, queries, db):
    """More probes -> superset candidates -> recall@k non-decreasing."""
    f, cfg = forest
    _, true_ids = exact_knn(queries, db, k=10)
    recalls = []
    for p in (1, 2, 4, 8):
        _, ids = fused_query(f, queries, db, 10, cfg, n_probes=p)
        recalls.append(float(recall_at_k(ids, true_ids)))
    for prev, cur in zip(recalls, recalls[1:]):
        assert cur >= prev - 1e-6, recalls
    assert recalls[-1] > recalls[0], recalls    # and it actually helps


def test_traverse_kernel_multiprobe_parity(forest, queries):
    """Pallas kernel (interpret) == jnp oracle == forest-level traversal."""
    f, cfg = forest
    fl = np.asarray(traverse_multiprobe(f, queries, cfg.max_depth, 4))
    for t in range(2):
        args = (f.proj_idx[t, :, 0], f.thresh[t], f.child_base[t], queries,
                cfg.max_depth)
        lp = ops.traverse_tree(*args, mode="pallas", n_probes=4)
        lr = ops.traverse_tree(*args, mode="ref", n_probes=4)
        np.testing.assert_array_equal(np.asarray(lp), np.asarray(lr))
        np.testing.assert_array_equal(np.asarray(lr), fl[t])
        # n_probes=1 keeps the historical (B,) contract
        l1 = ops.traverse_tree(*args, mode="pallas", n_probes=1)
        np.testing.assert_array_equal(np.asarray(l1), fl[t][:, 0])


def test_adaptive_composes_with_probes(forest, queries, db):
    f, cfg = forest
    _, true_ids = exact_knn(queries, db, k=10)
    d1, i1, used1 = adaptive_query(f, queries, db, 10, cfg, wave=2,
                                   tol=0.0, n_probes=1)
    d4, i4, used4 = adaptive_query(f, queries, db, 10, cfg, wave=2,
                                   tol=0.0, n_probes=4)
    assert used1 <= L and used4 <= L
    r1 = float(recall_at_k(i1, true_ids))
    r4 = float(recall_at_k(i4, true_ids))
    assert r4 >= r1 - 1e-6


# ---------------------------------------------------------------------------
# unified-API contracts: every backend, bitwise at n_probes=1
# ---------------------------------------------------------------------------


def _build(backend, db):
    """A FRESH index — for tests that mutate (delete / tune / save)."""
    return build_index(jax.random.key(0), np.asarray(db),
                       IndexSpec(backend=backend, forest=CFG))


def _shared(shared_builds, backend, db):
    """The session-cached index — read-only searching only."""
    return shared_builds.index(backend, 0, db, forest_cfg=CFG)


@pytest.mark.parametrize("backend", BACKENDS)
def test_nprobes1_bitwise_on_every_backend(backend, shared_builds, db,
                                           queries):
    index = _shared(shared_builds, backend, db)
    d0, i0 = index.search(queries, SearchParams(k=10))
    d1, i1 = index.search(queries, SearchParams(k=10, n_probes=1))
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize("backend", ["rpf", "rpf+int8"])
def test_tree_prefix_matches_prefix_forest(backend, shared_builds, db,
                                           queries):
    """search(n_trees=t) == querying a freshly-sliced prefix forest."""
    index = _shared(shared_builds, backend, db)
    t = L // 2
    d0, i0 = index.search(queries, SearchParams(k=5, n_trees=t))
    sub = jax.tree.map(lambda a: a[:t], index.forest)
    cfg = index.spec.forest._replace(n_trees=t)
    src = index.qdb if backend == "rpf+int8" else jnp.asarray(index.db)
    d1, i1 = fused_query(sub, queries, src, 5, cfg)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))


def test_multiprobe_respects_tombstones(db, queries):
    """Deleted rows never surface from the widened candidate set."""
    index = _build("rpf", db)
    _, ids = index.search(queries, SearchParams(k=10, n_probes=4))
    victims = sorted({int(np.asarray(ids)[0, 0]), int(np.asarray(ids)[1, 0])})
    index.delete(victims)
    _, ids2 = index.search(queries, SearchParams(k=10, n_probes=4))
    assert not np.isin(np.asarray(ids2), victims).any()


# ---------------------------------------------------------------------------
# tuner
# ---------------------------------------------------------------------------


def test_tuner_deterministic_and_meets_target(db, queries):
    index = _build("rpf", db)
    kw = dict(target_recall=0.9, k=10, probe_grid=(1, 2, 4),
              tree_fracs=(0.5, 1.0))
    p1, report = tune_report(index, queries, **kw)
    p2 = tune(index, queries, **kw)
    assert p1 == p2                                   # deterministic
    chosen = [r for r in report if r["params"] == p1]
    assert chosen and chosen[0]["recall"] >= 0.9
    # cheapest: nothing that met the target was cheaper
    assert all(r["cost"] >= chosen[0]["cost"]
               for r in report if r["meets_target"])


def test_tuner_persists_and_roundtrips(tmp_path, db, queries):
    index = _build("rpf", db)
    params = tune(index, queries, target_recall=0.85, k=10,
                  probe_grid=(1, 2, 4), tree_fracs=(0.5, 1.0))
    assert index.tuned_params == params
    # bare search uses the tuned operating point
    d0, i0 = index.search(queries)
    d1, i1 = index.search(queries, params)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    # explicit params still win
    _, i5 = index.search(queries, SearchParams(k=5))
    assert np.asarray(i5).shape[1] == 5

    path = str(tmp_path / "idx")
    index.save(path)
    loaded = load_index(path)
    assert loaded.tuned_params == params              # manifest v3
    d2, i2 = loaded.search(queries)
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(i0))

    # v2 read shim: strip the tuned payload, downgrade the format marker
    man_path = glob.glob(os.path.join(path, "step_*", "manifest.json"))[0]
    with open(man_path) as fh:
        man = json.load(fh)
    man["extra"]["format"] = 2
    man["extra"].pop("tuned_params")
    with open(man_path, "w") as fh:
        json.dump(man, fh)
    legacy = load_index(path)
    assert legacy.tuned_params is None
    _, i3 = legacy.search(queries, params)
    np.testing.assert_array_equal(np.asarray(i3), np.asarray(i0))


@pytest.mark.parametrize("backend", ["bruteforce", "lsh-cascade"])
def test_tuner_nonforest_backends(backend, db, queries):
    index = _build(backend, db)
    params = tune(index, queries, target_recall=0.5, k=5)
    _, ids = index.search(queries, params)
    assert np.asarray(ids).shape == (queries.shape[0], 5)


def test_tuner_empty_grid_raises(db, queries):
    index = _build("rpf", db)
    with pytest.raises(ValueError, match="grid is empty"):
        tune(index, queries, probe_grid=())
    with pytest.raises(ValueError, match="grid is empty"):
        # every wave covers the whole forest -> every combo pruned
        tune(index, queries, adaptive_waves=(L,))


def test_tuner_full_forest_spelled_as_zero(db, queries):
    """A tuned point that restricts nothing must say n_trees=0 ('all'), so
    it stays valid on surfaces without a search-time tree knob (sharded)."""
    from repro import compat
    from repro.core.sharded_index import make_query_fn
    index = _build("rpf", db)
    params = tune(index, queries, target_recall=1.01,   # unreachable ->
                  probe_grid=(8,), tree_fracs=(1.0,))   # full forest wins
    assert params.n_trees == 0
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    make_query_fn(ForestConfig(n_trees=4), 128, mesh, params=params)


def test_sharded_params_reject_n_trees():
    from repro import compat
    from repro.core.sharded_index import make_query_fn
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="n_trees"):
        make_query_fn(ForestConfig(n_trees=4), 128, mesh,
                      params=SearchParams(k=5, n_trees=2))

"""Per-arch smoke tests: REDUCED config of the same family, one forward/train
step on CPU, assert output shapes + no NaNs (assignment requirement f)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_arch
from repro.configs.base import LMConfig, MACEConfig, RecsysConfig

LM_ARCHS = ["llama4-maverick-400b-a17b", "granite-moe-1b-a400m",
            "smollm-135m", "stablelm-12b", "gemma3-4b"]
RECSYS_ARCHS = ["mind", "dlrm-mlperf", "autoint", "wide-deep"]


def _smoke_lm(cfg: LMConfig) -> LMConfig:
    """Shrink while preserving every structural feature (MoE arrangement,
    GQA ratio, window pattern, tied embeddings, shard mode)."""
    q_per_kv = max(1, cfg.n_heads // cfg.n_kv_heads)
    kv = 2
    return dataclasses.replace(
        cfg, n_layers=4 if cfg.moe_every == 2 else 3, d_model=48,
        n_heads=kv * q_per_kv, n_kv_heads=kv, head_dim=16, d_ff=64,
        vocab_size=301,
        n_experts=8 if cfg.moe else 0,
        top_k=min(cfg.top_k, 4) if cfg.moe else 0,
        sliding_window=4 if cfg.sliding_window else 0,
        global_every=2 if cfg.global_every else 0,
        param_dtype="float32", compute_dtype="float32", fsdp=False,
        remat=False)


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_smoke(arch_id):
    from repro.models import transformer as tr
    cfg = _smoke_lm(get_arch(arch_id).config)
    params = tr.init_lm(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (2, 12), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    (loss, m), grads = jax.value_and_grad(tr.loss_fn, has_aux=True)(
        params, batch, cfg)
    assert np.isfinite(float(loss)), arch_id
    for g in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(g)).all()
    logits, _ = tr.forward(params, tokens, cfg)
    assert logits.shape == (2, 12, cfg.padded_vocab)
    # decode path
    cache = tr.init_cache(cfg, 2, 16, jnp.float32)
    lg, cache = tr.decode_step(params, cache, tokens[:, :1],
                               jnp.zeros((), jnp.int32), cfg)
    assert lg.shape == (2, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(lg)).all()


def test_mace_smoke():
    from repro.data.graph_data import batched_molecules
    from repro.models import mace as mace_mod
    base = get_arch("mace").config
    cfg = dataclasses.replace(base, d_hidden=16)   # reduced width, same l_max
    mol = batched_molecules(4, 10, 24, seed=0)
    params = mace_mod.init_mace(jax.random.key(0), cfg, n_classes=3)
    out = mace_mod.mace_fwd(
        params, cfg, jnp.asarray(mol["species"] % cfg.n_species),
        jnp.asarray(mol["positions"]), jnp.asarray(mol["senders"]),
        jnp.asarray(mol["receivers"]), graph_ids=jnp.asarray(mol["graph_ids"]),
        n_graphs=4)
    assert out["energy"].shape == (4,)
    assert out["node_logits"].shape == (40, 3)
    assert np.isfinite(np.asarray(out["energy"])).all()
    # train step on energies
    def loss(p):
        o = mace_mod.mace_fwd(
            p, cfg, jnp.asarray(mol["species"] % cfg.n_species),
            jnp.asarray(mol["positions"]), jnp.asarray(mol["senders"]),
            jnp.asarray(mol["receivers"]),
            graph_ids=jnp.asarray(mol["graph_ids"]), n_graphs=4)
        return jnp.mean(o["energy"] ** 2)
    g = jax.grad(loss)(params)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()


def test_mace_edge_chunking_exact():
    """Chunked message passing == unchunked (segment_sum additivity)."""
    from repro.data.graph_data import batched_molecules
    from repro.models import mace as mace_mod
    cfg = dataclasses.replace(get_arch("mace").config, d_hidden=8)
    mol = batched_molecules(2, 8, 16, seed=1)
    params = mace_mod.init_mace(jax.random.key(0), cfg)
    args = (params, cfg, jnp.asarray(mol["species"] % cfg.n_species),
            jnp.asarray(mol["positions"]), jnp.asarray(mol["senders"]),
            jnp.asarray(mol["receivers"]))
    e1 = mace_mod.mace_fwd(*args, n_edge_chunks=1)["energy"]
    e2 = mace_mod.mace_fwd(*args, n_edge_chunks=4)["energy"]
    e3 = mace_mod.mace_fwd(*args, n_edge_chunks=4, unroll=True)["energy"]
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e3), rtol=1e-5)


def _smoke_recsys(cfg: RecsysConfig) -> RecsysConfig:
    return dataclasses.replace(
        cfg, table_sizes=tuple(min(s, 500) for s in cfg.table_sizes),
        item_vocab=min(cfg.item_vocab, 2000) if cfg.item_vocab else 0,
        row_pad_to=8)


@pytest.mark.parametrize("arch_id", RECSYS_ARCHS)
def test_recsys_smoke(arch_id):
    from repro.models import recsys as rs
    cfg = _smoke_recsys(get_arch(arch_id).config)
    rng = np.random.default_rng(0)
    b = 16
    if cfg.model == "mind":
        params = rs.init_mind(jax.random.key(0), cfg)
        hist = jnp.asarray(rng.integers(0, cfg.item_vocab, (b, cfg.hist_len))
                           .astype(np.int32))
        tgt = jnp.asarray(rng.integers(0, cfg.item_vocab, (b,))
                          .astype(np.int32))
        logits = rs.mind_train_logits(params, cfg, hist, tgt)
        assert logits.shape == (b,)
        interests = rs.mind_user_fwd(params, cfg, hist)
        assert interests.shape == (b, cfg.n_interests, cfg.embed_dim)
        grads = jax.grad(lambda p: jnp.mean(
            rs.mind_train_logits(p, cfg, hist, tgt) ** 2))(params)
    else:
        init = {"dlrm": rs.init_dlrm, "autoint": rs.init_autoint,
                "widedeep": rs.init_widedeep}[cfg.model]
        params = init(jax.random.key(0), cfg)
        sparse = jnp.asarray(rng.integers(0, 500, (b, cfg.n_sparse))
                             .astype(np.int32))
        if cfg.model == "dlrm":
            dense = jnp.asarray(rng.normal(size=(b, cfg.n_dense))
                                .astype(np.float32))
            fwd = lambda p: rs.dlrm_fwd(p, dense, sparse)
        elif cfg.model == "autoint":
            fwd = lambda p: rs.autoint_fwd(p, sparse)
        else:
            fwd = lambda p: rs.widedeep_fwd(p, sparse)
        logits = fwd(params)
        assert logits.shape == (b,)
        grads = jax.grad(lambda p: jnp.mean(fwd(p) ** 2))(params)
    assert np.isfinite(np.asarray(logits)).all()
    for g in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(g)).all()


def test_all_assigned_archs_registered():
    assert len(ASSIGNED) == 10
    for arch_id in ASSIGNED:
        spec = get_arch(arch_id)
        assert len(spec.cells) == 4, arch_id   # 4 shape cells each = 40 total


def test_param_counts_match_names():
    tol = 0.25
    for arch_id, target in [("llama4-maverick-400b-a17b", 400e9),
                            ("granite-moe-1b-a400m", 1.3e9),
                            ("smollm-135m", 135e6),
                            ("stablelm-12b", 12e9),
                            ("gemma3-4b", 4e9)]:
        n = get_arch(arch_id).config.param_count()
        assert abs(n - target) / target < tol, (arch_id, n)

"""Metric registry (DESIGN.md §13): every metric on every backend vs the
exact oracle, alias canonicalization, int8 coarse-stage metric parity,
and tuning under a non-default metric.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distances import METRIC_ALIASES, METRICS, canonical_metric
from repro.core.forest import ForestConfig
from repro.core.knn import exact_knn
from repro.core.quantized import quantize_db
from repro.index import IndexSpec, SearchParams, build_index
from repro.index.tune import tune
from repro.kernels import ref
from repro.kernels.fused_query_int8 import fused_gather_topk_int8

SEED = 0
BACKENDS = ["bruteforce", "rpf", "rpf+int8", "lsh-cascade"]
USER_METRICS = ["l2", "chi2", "cosine", "ip"]


@pytest.fixture(scope="module")
def corpus(shared_builds):
    db = shared_builds.clustered_db(2000, 16, n_clusters=16, seed=SEED)
    db = np.abs(db)                       # non-negative so chi2 composes
    db /= np.linalg.norm(db, axis=1, keepdims=True)
    rng = np.random.default_rng(1)
    q = np.abs(db[:16] + 0.003 * rng.normal(size=(16, 16)).astype(np.float32))
    return db, q


def _spec(backend):
    return IndexSpec(backend=backend,
                     forest=ForestConfig(n_trees=12, capacity=24),
                     lsh_radii=(0.5, 1.0, 2.0), lsh_tables=8, lsh_bits=8,
                     seed=0)


def _recall(ids, oracle_ids, k):
    return np.mean([len(set(a[a >= 0].tolist()) & set(b.tolist())) / k
                    for a, b in zip(np.asarray(ids), np.asarray(oracle_ids))])


# ---------------------------------------------------------------------------
# registry + aliases
# ---------------------------------------------------------------------------


def test_canonical_metric():
    assert canonical_metric("ip") == "dot"
    assert canonical_metric("inner_product") == "dot"
    assert canonical_metric("euclidean") == "l2"
    assert canonical_metric("chi2") == "chi2"
    with pytest.raises(ValueError, match="unknown metric"):
        canonical_metric("manhattan")
    assert set(METRIC_ALIASES.values()) <= set(METRICS)


def test_params_canonicalize_aliases():
    assert SearchParams(metric="ip") == SearchParams(metric="dot")
    assert SearchParams(metric="euclidean") == SearchParams()
    # unknown metrics survive construction; violations() reports them
    p = SearchParams(metric="manhattan")
    assert any("manhattan" in v for v in p.violations())


# ---------------------------------------------------------------------------
# every metric x every backend vs the exact oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("metric", USER_METRICS)
def test_metric_backend_recall_vs_oracle(corpus, backend, metric):
    db, q = corpus
    idx = build_index(jax.random.key(SEED), db, _spec(backend))
    p = SearchParams(k=10, metric=metric, n_probes=4, min_candidates=2000)
    d, ids = idx.search(q, p)
    gd, gi = exact_knn(jnp.asarray(q), jnp.asarray(db), 10, metric=metric)
    rec = _recall(ids, gi, 10)
    floor = 1.0 if backend in ("bruteforce", "lsh-cascade") else 0.9
    assert rec >= floor, f"{backend}/{metric}: recall {rec:.3f} < {floor}"
    # returned distances are the metric's own values, ascending
    dn = np.asarray(d)
    assert (np.diff(dn, axis=1) >= -1e-6).all()


def test_ip_and_dot_identical(corpus):
    db, q = corpus
    idx = build_index(jax.random.key(SEED), db, _spec("rpf"))
    d1, i1 = idx.search(q, SearchParams(k=10, metric="ip"))
    d2, i2 = idx.search(q, SearchParams(k=10, metric="dot"))
    assert np.array_equal(np.asarray(i1), np.asarray(i2))
    assert np.array_equal(np.asarray(d1), np.asarray(d2))


# ---------------------------------------------------------------------------
# int8 coarse stage scores under the metric (kernel == ref, all metrics)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("metric", ["l2", "dot", "chi2", "cosine"])
def test_int8_kernel_ref_parity_per_metric(corpus, metric):
    db, q = corpus
    qdb = quantize_db(jnp.asarray(db))
    rng = np.random.default_rng(3)
    ids = rng.integers(0, len(db), size=(8, 96)).astype(np.int32)
    ids[ids % 7 == 0] = -1                      # invalid slots mix in
    ids = jnp.asarray(ids)
    qj = jnp.asarray(q[:8])
    kd, ki = fused_gather_topk_int8(qj, ids, qdb.q, qdb.scale, 10,
                                    metric=metric, interpret=True)
    rd, ri = ref.fused_gather_topk_int8_ref(qj, ids, qdb.q, qdb.scale, 10,
                                            metric=metric)
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(kd), np.asarray(rd),
                               rtol=1e-5, atol=1e-5)


def test_int8_backend_unfiltered_l2_matches_prior_contract(corpus):
    """metric='l2' through the int8 backend keeps its pre-metric-registry
    semantics: the coarse stage's l2 branch is structurally the original
    scoring, so results equal the ref-mode (oracle) dispatch bitwise."""
    db, q = corpus
    idx = build_index(jax.random.key(SEED), db, _spec("rpf+int8"))
    d1, i1 = idx.search(q, SearchParams(k=10, mode="auto"))
    d2, i2 = idx.search(q, SearchParams(k=10, mode="ref"))
    assert np.array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# tuner under a non-default metric
# ---------------------------------------------------------------------------


def test_tune_with_metric(corpus):
    db, q = corpus
    idx = build_index(jax.random.key(SEED), db, _spec("rpf"))
    tuned = tune(idx, q, target_recall=0.85, k=10, metric="cosine",
                 probe_grid=(1, 2, 4), tree_fracs=(1.0,))
    assert tuned.metric == "cosine"
    d, ids = idx.search(q, tuned)
    _, gi = exact_knn(jnp.asarray(q), jnp.asarray(db), 10, metric="cosine")
    assert _recall(ids, gi, 10) >= 0.85

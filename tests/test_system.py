"""End-to-end behaviour tests for the paper's system (index -> serve)."""
import threading

import numpy as np
import pytest

from repro.core.forest import ForestConfig
from repro.core.service import AnnService
from repro.data.synthetic import clustered_gaussians
from repro.serve.ann_serve import make_ann_server
from repro.serve.batching import DynamicBatcher


@pytest.fixture(scope="module")
def corpus():
    return clustered_gaussians(3000, 32, n_clusters=24, seed=9)


def test_service_query_and_insert(corpus):
    svc = AnnService(corpus, ForestConfig(n_trees=16, capacity=12))
    d, i = svc.query(corpus[:8], k=3)
    assert i.shape == (8, 3)
    assert (i[:, 0] == np.arange(8)).mean() > 0.8   # self is the 1-NN
    # paper §5: incremental insert is immediately queryable
    novel = corpus[0] + 0.5
    nid = svc.insert(novel)
    d, i = svc.query(novel[None], k=1)
    assert int(i[0, 0]) == nid
    assert d[0, 0] < 1e-9


def test_service_rebuild_folds_overflow(corpus):
    svc = AnnService(corpus[:500], ForestConfig(n_trees=8, capacity=12),
                     rebuild_frac=0.02)   # rebuild after 10 inserts
    for j in range(12):
        svc.insert(corpus[1000 + j])
    st = svc.stats()
    assert st["n_static"] > 500            # rebuild happened
    assert st["n_overflow"] < 12
    d, i = svc.query(corpus[1005][None], k=1)
    assert d[0, 0] < 1e-9                  # folded point still findable


def test_dynamic_batcher_batches_and_answers(corpus):
    calls = []

    def fn(payloads):
        calls.append(len(payloads))
        return [p.sum() for p in payloads]

    b = DynamicBatcher(fn, max_batch=16, max_wait_s=0.02).start()
    results = {}

    def client(j):
        results[j] = b(corpus[j])

    threads = [threading.Thread(target=client, args=(j,)) for j in range(32)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    b.stop()
    assert len(results) == 32
    for j in range(32):
        np.testing.assert_allclose(results[j], corpus[j].sum(), rtol=1e-6)
    assert max(calls) > 1                  # actual batching happened
    assert b.stats["requests"] == 32


def test_ann_server_end_to_end(corpus):
    svc, batcher = make_ann_server(corpus, ForestConfig(n_trees=16),
                                   k=3, max_wait_s=0.01)
    d, i = batcher(corpus[5])
    assert int(i[0]) == 5
    batcher.stop()


def test_watchdog_flags_stragglers():
    from repro.train.train_loop import Watchdog
    wd = Watchdog(factor=3.0, warmup=3)
    flagged = []
    for step, dt in enumerate([0.1] * 10 + [1.0] + [0.1] * 3):
        if wd.observe(step, dt):
            flagged.append(step)
    assert flagged == [10]
    # EMA not poisoned by the straggler: a normal step after it is not flagged
    assert wd.ema < 0.2

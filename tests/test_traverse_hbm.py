"""HBM-resident traversal kernel parity (DESIGN.md §11).

Three-way bitwise agreement at every tree size: the HBM kernel (node records
double-buffer-DMA'd per descent level) must equal the SMEM kernel (whole
tree scalar-prefetched; only legal below ``SMEM_NODE_CAP``) and the jnp
refs, for single- and multi-probe descents.  Leaf ids are integers and the
float compare chain is operation-identical across the three, so every
comparison here is exact (== / array_equal), not toleranced.

The cap-straddling hypothesis sweep lives in test_property.py; this file is
the deterministic matrix.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ForestConfig, build_forest
from repro.core.forest import traverse, traverse_forest, traverse_multiprobe
from repro.kernels import ops, ref
from repro.kernels.forest_traverse import SMEM_NODE_CAP, forest_traverse
from repro.kernels.forest_traverse_hbm import (forest_traverse_hbm,
                                               forest_traverse_hbm_tree)


def _forest(n=700, d=20, n_trees=2, seed=0, **cfg_kw):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    cfg = ForestConfig(n_trees=n_trees, **cfg_kw)
    f = build_forest(jax.random.key(seed), x, cfg)
    return f, cfg.resolved(n), x


# ---------------------------------------------------------------------------
# kernel-level three-way parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_probes", [1, 2, 5])
@pytest.mark.parametrize("b", [1, 33, 64])
def test_hbm_matches_smem_and_ref(n_probes, b):
    f, rcfg, x = _forest()
    q = x[:b]
    hbm = forest_traverse_hbm(f.proj_idx[:, :, 0], f.thresh, f.child_base,
                              q, rcfg.max_depth, interpret=True,
                              n_probes=n_probes)
    for t in range(f.n_trees):
        args = (f.proj_idx[t, :, 0], f.thresh[t], f.child_base[t], q,
                rcfg.max_depth)
        smem = forest_traverse(*args, interpret=True, n_probes=n_probes)
        if n_probes == 1:
            r = ref.forest_traverse_ref(*args)
        else:
            r = ref.forest_traverse_multiprobe_ref(*args, n_probes)
        np.testing.assert_array_equal(np.asarray(hbm[t]), np.asarray(smem))
        np.testing.assert_array_equal(np.asarray(hbm[t]), np.asarray(r))


def test_hbm_probe0_is_single_probe():
    """Probe 0 of the multi-probe output is bitwise the single descent."""
    f, rcfg, x = _forest(seed=3)
    q = x[:21]
    single = forest_traverse_hbm(f.proj_idx[:, :, 0], f.thresh, f.child_base,
                                 q, rcfg.max_depth, interpret=True)
    multi = forest_traverse_hbm(f.proj_idx[:, :, 0], f.thresh, f.child_base,
                                q, rcfg.max_depth, interpret=True, n_probes=4)
    np.testing.assert_array_equal(np.asarray(multi[:, :, 0]),
                                  np.asarray(single))


def test_hbm_above_cap_parity():
    """A tree allocated past SMEM_NODE_CAP (dead padding nodes — the cap is
    about array bytes, not reachable nodes) still matches the refs."""
    f, rcfg, x = _forest(n=400, d=12, seed=5, max_nodes=SMEM_NODE_CAP + 512)
    assert f.max_nodes > SMEM_NODE_CAP
    q = x[:17]
    hbm = forest_traverse_hbm(f.proj_idx[:, :, 0], f.thresh, f.child_base,
                              q, rcfg.max_depth, interpret=True, n_probes=3)
    for t in range(f.n_trees):
        r = ref.forest_traverse_multiprobe_ref(
            f.proj_idx[t, :, 0], f.thresh[t], f.child_base[t], q,
            rcfg.max_depth, 3)
        np.testing.assert_array_equal(np.asarray(hbm[t]), np.asarray(r))


def test_single_tree_wrapper_contract():
    f, rcfg, x = _forest(seed=7)
    q = x[:9]
    one = forest_traverse_hbm_tree(f.proj_idx[0, :, 0], f.thresh[0],
                                   f.child_base[0], q, rcfg.max_depth,
                                   interpret=True)
    assert one.shape == (9,)
    multi = forest_traverse_hbm_tree(f.proj_idx[0, :, 0], f.thresh[0],
                                     f.child_base[0], q, rcfg.max_depth,
                                     interpret=True, n_probes=3)
    assert multi.shape == (9, 3)
    np.testing.assert_array_equal(np.asarray(multi[:, 0]), np.asarray(one))


# ---------------------------------------------------------------------------
# dispatch: ops.traverse_tree kernel selection + forest-level routing
# ---------------------------------------------------------------------------


def test_ops_dispatch_picks_hbm_above_cap():
    """mode="pallas" must serve any tree size: SMEM kernel below the cap,
    HBM kernel above — and both agree with ref."""
    small, rs, xs = _forest(n=300, d=10, seed=11)
    big, rb, xb = _forest(n=300, d=10, seed=11,
                          max_nodes=SMEM_NODE_CAP + 256)
    assert small.max_nodes <= SMEM_NODE_CAP < big.max_nodes
    for f, rcfg, x in ((small, rs, xs), (big, rb, xb)):
        q = x[:13]
        got = ops.traverse_tree(f.proj_idx[0, :, 0], f.thresh[0],
                                f.child_base[0], q, rcfg.max_depth,
                                mode="pallas")
        want = ops.traverse_tree(f.proj_idx[0, :, 0], f.thresh[0],
                                 f.child_base[0], q, rcfg.max_depth,
                                 mode="ref")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ops_dispatch_forced_kernels_agree():
    f, rcfg, x = _forest(seed=13)
    q = x[:11]
    args = (f.proj_idx[0, :, 0], f.thresh[0], f.child_base[0], q,
            rcfg.max_depth)
    for n_probes in (1, 3):
        smem = ops.traverse_tree(*args, mode="pallas", n_probes=n_probes,
                                 kernel="smem")
        hbm = ops.traverse_tree(*args, mode="pallas", n_probes=n_probes,
                                kernel="hbm")
        np.testing.assert_array_equal(np.asarray(smem), np.asarray(hbm))


@pytest.mark.parametrize("n_probes", [1, 3])
def test_traverse_forest_pallas_matches_jnp(n_probes):
    """The pipeline's traversal entry: Pallas routing is bitwise the XLA
    descent (K=1 coefficients are identically 1.0)."""
    f, rcfg, x = _forest(seed=17, n_trees=3)
    q = x[:19]
    got = traverse_forest(f, q, rcfg.max_depth, n_probes, mode="pallas")
    if n_probes == 1:
        want = traverse(f, q, rcfg.max_depth)
    else:
        want = traverse_multiprobe(f, q, rcfg.max_depth, n_probes)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_traverse_forest_k2_falls_back():
    """K > 1 forests (coefficients matter) must use the XLA traversal."""
    f, rcfg, x = _forest(seed=19, n_proj=2)
    q = x[:7]
    got = traverse_forest(f, q, rcfg.max_depth, 1, mode="pallas")
    want = traverse(f, q, rcfg.max_depth)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

"""Fused single-pass query pipeline vs the staged oracle.

The fused path (core/pipeline.py + kernels/fused_query.py) must reproduce the
staged composition (traverse -> gather -> mask_duplicates -> rerank_topk)
exactly: bitwise on ids, to fp tolerance on distances.  Test data uses
continuous random vectors, so distance ties occur only between identical
candidate ids — bitwise id parity is well-defined under any tie-break.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ForestConfig
from repro.core.pipeline import fused_query, rerank_fused, staged_query
from repro.core.search import rerank_topk
from repro.kernels import ops, ref

RNG = np.random.default_rng(7)
TOL = dict(rtol=2e-5, atol=2e-5)


def _corpus(n, d, metric, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    if metric == "chi2":
        x = np.abs(x)      # chi2 wants non-negative histogram features
    return jnp.asarray(x)


def _shared_forest(shared_builds, n, d, metric, seed, key_seed, cfg):
    """One cached (db, forest) per distinct (corpus, cfg, key) — the
    parametrized parity tests below would otherwise rebuild it per case."""
    db = shared_builds.normal_db(n, d, seed, nonneg=(metric == "chi2"))
    forest, _ = shared_builds.forest(key_seed, cfg, db)
    return db, forest


def _assert_match(fused, staged):
    fd, fi = fused
    sd, si = staged
    assert (np.asarray(fi) == np.asarray(si)).all(), \
        f"id mismatch:\n{np.asarray(fi)}\nvs\n{np.asarray(si)}"
    sd_np, fd_np = np.asarray(sd), np.asarray(fd)
    finite = np.isfinite(sd_np)
    assert (finite == np.isfinite(fd_np)).all()
    np.testing.assert_allclose(fd_np[finite], sd_np[finite], **TOL)


# ---------------------------------------------------------------------------
# end-to-end pipeline parity (forest-driven, ragged real leaf sizes)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("metric", ["l2", "dot", "chi2", "cosine"])
@pytest.mark.parametrize("dedup", [True, False])
@pytest.mark.parametrize("mode", ["ref", "pallas"])
def test_fused_matches_staged(metric, dedup, mode, shared_builds):
    cfg = ForestConfig(n_trees=6, capacity=10)
    db, forest = _shared_forest(shared_builds, 1500, 24, metric, 1, 0, cfg)
    q = _corpus(13, 24, metric, seed=2)
    staged = staged_query(forest, q, db, 5, cfg, metric=metric, dedup=dedup)
    fused = fused_query(forest, q, db, 5, cfg, metric=metric, dedup=dedup,
                        mode=mode)
    _assert_match(fused, staged)


@pytest.mark.parametrize("mode", ["ref", "pallas"])
def test_fused_chunked_matches_unchunked(mode, shared_builds):
    """Result must be invariant to the candidate-chunk width."""
    cfg = ForestConfig(n_trees=8, capacity=8)
    db, forest = _shared_forest(shared_builds, 1200, 16, "l2", 3, 1, cfg)
    q = _corpus(9, 16, "l2", seed=4)
    staged = staged_query(forest, q, db, 4, cfg)
    for chunk in (16, 24, 64):     # including non-divisors of M = 8*8
        fused = fused_query(forest, q, db, 4, cfg, mode=mode, chunk=chunk)
        _assert_match(fused, staged)


@pytest.mark.parametrize("mode", ["ref", "pallas"])
def test_fused_b1_edge(mode, shared_builds):
    """B=1: the degenerate serving case (single online query)."""
    cfg = ForestConfig(n_trees=4, capacity=12)
    db, forest = _shared_forest(shared_builds, 800, 12, "l2", 5, 2, cfg)
    q = _corpus(1, 12, "l2", seed=6)
    staged = staged_query(forest, q, db, 3, cfg)
    fused = fused_query(forest, q, db, 3, cfg, mode=mode, chunk=8)
    _assert_match(fused, staged)


def test_rerank_fused_batch_slabbing():
    """B beyond the SMEM row budget must slab the batch, same results."""
    db = _corpus(500, 8, "l2", seed=20)
    q = _corpus(70, 8, "l2", seed=21)
    ids = jnp.asarray(RNG.integers(0, 500, size=(70, 30)).astype(np.int32))
    mask = jnp.ones((70, 30), bool)
    want = rerank_topk(q, ids, mask, db, k=4)
    for mode in ("ref", "pallas"):
        got = rerank_fused(q, ids, mask, db, 4, mode=mode, rows_budget=16)
        _assert_match(got, want)


@pytest.mark.parametrize("mode", ["ref", "pallas"])
def test_rerank_fused_k_exceeds_chunk(mode):
    """k wider than the streaming chunk: chunk must clamp up, not crash."""
    db = _corpus(400, 10, "l2", seed=22)
    q = _corpus(5, 10, "l2", seed=23)
    ids = jnp.asarray(RNG.integers(0, 400, size=(5, 64)).astype(np.int32))
    mask = jnp.ones((5, 64), bool)
    want = rerank_topk(q, ids, mask, db, k=20)
    got = rerank_fused(q, ids, mask, db, 20, mode=mode, chunk=16)
    _assert_match(got, want)


def test_fused_ragged_leaf_sizes(shared_builds):
    """Tiny capacity -> heavily ragged leaves -> many invalid padded slots."""
    cfg = ForestConfig(n_trees=5, capacity=4, split_ratio=0.45)
    db, forest = _shared_forest(shared_builds, 400, 8, "l2", 7, 3, cfg)
    q = _corpus(6, 8, "l2", seed=8)
    staged = staged_query(forest, q, db, 4, cfg)
    for mode in ("ref", "pallas"):
        _assert_match(fused_query(forest, q, db, 4, cfg, mode=mode), staged)


# ---------------------------------------------------------------------------
# rerank_fused parity on synthetic candidate matrices (controlled edge cases)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("metric", ["l2", "dot", "chi2"])
@pytest.mark.parametrize("mode", ["ref", "pallas"])
def test_rerank_fused_matches_rerank_topk(metric, mode):
    db = _corpus(300, 20, metric, seed=9)
    q = _corpus(7, 20, metric, seed=10)
    ids = jnp.asarray(RNG.integers(0, 300, size=(7, 50)).astype(np.int32))
    mask = jnp.asarray(RNG.uniform(size=(7, 50)) < 0.8)
    staged = rerank_topk(q, ids, mask, db, k=6, metric=metric, dedup=True)
    fused = rerank_fused(q, ids, mask, db, 6, metric=metric, mode=mode,
                         dedup=True, chunk=16)
    _assert_match(fused, staged)


@pytest.mark.parametrize("mode", ["ref", "pallas"])
def test_rerank_fused_all_duplicate_row(mode):
    """A row whose candidates are all the same id: dedup keeps exactly one."""
    db = _corpus(100, 10, "l2", seed=11)
    q = _corpus(3, 10, "l2", seed=12)
    ids = jnp.full((3, 24), 42, jnp.int32)
    mask = jnp.ones((3, 24), bool)
    d, i = rerank_fused(q, ids, mask, db, 4, mode=mode, dedup=True, chunk=8)
    d, i = np.asarray(d), np.asarray(i)
    assert (i[:, 0] == 42).all()
    assert (i[:, 1:] == -1).all()           # only one unique candidate
    assert np.isinf(d[:, 1:]).all()
    np.testing.assert_allclose(
        d[:, 0], np.sum((np.asarray(q) - np.asarray(db)[42]) ** 2, -1), **TOL)


@pytest.mark.parametrize("mode", ["ref", "pallas"])
def test_rerank_fused_all_masked(mode):
    db = _corpus(50, 6, "l2", seed=13)
    q = _corpus(2, 6, "l2", seed=14)
    ids = jnp.zeros((2, 12), jnp.int32)
    mask = jnp.zeros((2, 12), bool)
    d, i = rerank_fused(q, ids, mask, db, 3, mode=mode)
    assert np.isinf(np.asarray(d)).all()
    assert (np.asarray(i) == -1).all()


# ---------------------------------------------------------------------------
# kernel-level: pallas fused_gather_topk vs its jnp oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,m,n,d", [(4, 24, 200, 16), (9, 100, 500, 48),
                                     (1, 7, 60, 5)])
@pytest.mark.parametrize("metric", ["l2", "dot", "chi2"])
def test_fused_kernel_matches_oracle(b, m, n, d, metric):
    rng = np.random.default_rng(b * m)
    db = np.abs(rng.normal(size=(n, d))).astype(np.float32)
    q = np.abs(rng.normal(size=(b, d))).astype(np.float32)
    ids = rng.integers(0, n, size=(b, m)).astype(np.int32)
    ids[rng.uniform(size=ids.shape) < 0.15] = -1      # invalid slots
    pd, pi = ops.fused_rerank(jnp.asarray(q), jnp.asarray(ids),
                              jnp.asarray(db), 5, metric=metric,
                              mode="pallas")
    rd, ri = ref.fused_gather_topk_ref(jnp.asarray(q), jnp.asarray(ids),
                                       jnp.asarray(db), 5, metric=metric)
    rd_np = np.asarray(rd)
    finite = np.isfinite(rd_np)
    np.testing.assert_allclose(np.asarray(pd)[finite], rd_np[finite], **TOL)
    assert (np.isfinite(np.asarray(pd)) == finite).all()
    # continuous data: finite-distance ids are tie-free -> exact
    assert (np.asarray(pi)[finite] == np.asarray(ri)[finite]).all()

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distances as dm


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    q = rng.normal(size=(7, 33)).astype(np.float32)
    db = rng.normal(size=(19, 33)).astype(np.float32)
    return jnp.abs(jnp.asarray(q)), jnp.abs(jnp.asarray(db))


def test_l2_matches_numpy(data):
    q, db = data
    got = np.asarray(dm.pairwise_l2_sq(q, db))
    want = ((np.asarray(q)[:, None] - np.asarray(db)[None]) ** 2).sum(-1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_chi2_matches_numpy(data):
    q, db = data
    got = np.asarray(dm.pairwise_chi2(q, db))
    x, y = np.asarray(q)[:, None], np.asarray(db)[None]
    want = ((x - y) ** 2 / (x + y + 1e-12)).sum(-1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_pairwise_consistent_with_pointwise(data):
    q, db = data
    for metric in ["l2", "chi2", "dot", "cosine"]:
        pw = np.asarray(dm.PAIRWISE[metric](q, db))
        pt = np.asarray(dm.METRICS[metric](q[:, None, :], db[None, :, :]))
        np.testing.assert_allclose(pw, pt, rtol=1e-4, atol=1e-4)


def test_chi2_properties(data):
    q, _ = data
    # identity: chi2(x, x) == 0; symmetry
    self_d = np.asarray(dm.chi2(q, q))
    np.testing.assert_allclose(self_d, 0.0, atol=1e-6)
    a, b = q[0], q[1]
    assert abs(float(dm.chi2(a, b)) - float(dm.chi2(b, a))) < 1e-5


def test_normalize_rows(data):
    q, _ = data
    n = np.linalg.norm(np.asarray(dm.normalize_rows(q)), axis=1)
    np.testing.assert_allclose(n, 1.0, rtol=1e-5)

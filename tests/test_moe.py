"""MoE dispatch correctness vs a direct dense-mixture reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe as moe_mod


def _ref_moe(params, x, n_experts, top_k):
    """No-capacity reference: every token sees its exact top-k experts."""
    logits = x.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, sel = jax.lax.top_k(probs, top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    # per-token dense expert evaluation
    h = jax.nn.silu(jnp.einsum("td,edf->tef", x, params["w_gate"])) * \
        jnp.einsum("td,edf->tef", x, params["w_up"])
    y_all = jnp.einsum("tef,efd->ted", h, params["w_down"])   # (T, E, D)
    picked = jnp.take_along_axis(y_all, sel[:, :, None], axis=1)
    out = jnp.sum(picked * gate[:, :, None].astype(y_all.dtype), axis=1)
    if "shared" in params:
        s = params["shared"]
        out = out + (jax.nn.silu(x @ s["w_gate"]) * (x @ s["w_up"])) \
            @ s["w_down"]
    return out


@pytest.mark.parametrize("top_k,shared", [(1, False), (2, False), (2, True)])
def test_moe_matches_dense_reference(top_k, shared):
    t, d, f, e = 64, 16, 32, 8
    params = moe_mod.init_moe(jax.random.key(0), d, f, e, jnp.float32,
                              shared)
    x = jax.random.normal(jax.random.key(1), (t, d))
    # ample capacity: nothing dropped -> must match the dense reference
    out, aux = moe_mod.moe_fwd(params, x, n_experts=e, top_k=top_k,
                               capacity_factor=8.0)
    want = _ref_moe(params, x, e, top_k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-4,
                               atol=2e-4)
    assert np.isfinite(float(aux))


def test_moe_capacity_drops_tokens():
    """With capacity 0+ epsilon most tokens drop -> output mostly zeros
    (plus shared expert when present)."""
    t, d, f, e = 64, 16, 32, 4
    params = moe_mod.init_moe(jax.random.key(0), d, f, e, jnp.float32, False)
    x = jax.random.normal(jax.random.key(1), (t, d))
    out_low, _ = moe_mod.moe_fwd(params, x, n_experts=e, top_k=1,
                                 capacity_factor=0.25)
    out_hi, _ = moe_mod.moe_fwd(params, x, n_experts=e, top_k=1,
                                capacity_factor=8.0)
    # low capacity zeroes some token outputs that high capacity fills
    zeros_low = np.mean(np.abs(np.asarray(out_low)).sum(-1) < 1e-9)
    zeros_hi = np.mean(np.abs(np.asarray(out_hi)).sum(-1) < 1e-9)
    assert zeros_low > zeros_hi


def test_position_in_expert():
    e_ids = jnp.asarray([2, 0, 2, 1, 0, 2], jnp.int32)
    pos = moe_mod._position_in_expert(e_ids, 3)
    # expert 0: slots 1,4 -> 0,1 ; expert 1: slot 3 -> 0; expert 2: 0,2,5
    assert list(np.asarray(pos)) == [0, 0, 1, 0, 1, 2]


def test_moe_grads_finite():
    t, d, f, e = 32, 8, 16, 4
    params = moe_mod.init_moe(jax.random.key(0), d, f, e, jnp.float32, True)
    x = jax.random.normal(jax.random.key(1), (t, d))

    def loss(p):
        out, aux = moe_mod.moe_fwd(p, x, n_experts=e, top_k=2,
                                   capacity_factor=1.25)
        return jnp.mean(out**2) + 0.01 * aux

    g = jax.grad(loss)(params)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()

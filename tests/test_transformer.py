"""Transformer correctness: variants, decode-vs-forward consistency, masks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LMConfig
from repro.models import transformer as tr

BASE = dict(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
            d_ff=128, vocab_size=257, remat=False,
            param_dtype="float32", compute_dtype="float32")


def _batch(cfg, b=2, s=16, seed=1):
    tokens = jax.random.randint(jax.random.key(seed), (b, s), 0,
                                cfg.vocab_size)
    return {"tokens": tokens, "labels": tokens}


@pytest.mark.parametrize("extra", [
    {},                                                        # dense
    {"sliding_window": 8, "global_every": 2},                  # gemma-style
    {"moe": True, "n_experts": 8, "top_k": 2, "d_ff": 64},     # granite-style
    {"moe": True, "n_experts": 8, "top_k": 1, "moe_every": 2,
     "shared_expert": True, "d_ff": 64},                       # llama4-style
    {"tie_embeddings": True},
])
def test_forward_and_grads_finite(extra):
    cfg = LMConfig(name="t", **{**BASE, **extra})
    params = tr.init_lm(jax.random.key(0), cfg)
    batch = _batch(cfg)
    (loss, m), grads = jax.value_and_grad(tr.loss_fn, has_aux=True)(
        params, batch, cfg)
    assert np.isfinite(float(loss))
    for g in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(g)).all()


def test_decode_matches_forward():
    """Teacher-forcing consistency: step-by-step decode logits == full
    forward logits at every position (the KV-cache path is exact)."""
    cfg = LMConfig(name="t", **BASE)
    params = tr.init_lm(jax.random.key(0), cfg)
    b, s = 2, 12
    tokens = jax.random.randint(jax.random.key(5), (b, s), 0, cfg.vocab_size)
    full_logits, _ = tr.forward(params, tokens, cfg)

    cache = tr.init_cache(cfg, b, s, jnp.float32)
    outs = []
    for t in range(s):
        logits, cache = tr.decode_step(params, cache, tokens[:, t:t + 1],
                                       jnp.asarray(t), cfg)
        outs.append(logits[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), rtol=2e-3, atol=2e-3)


def test_prefill_matches_forward_last():
    cfg = LMConfig(name="t", **{**BASE, "sliding_window": 6,
                                "global_every": 2})
    params = tr.init_lm(jax.random.key(0), cfg)
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.key(6), (b, s), 0, cfg.vocab_size)
    full_logits, _ = tr.forward(params, tokens, cfg)
    cache = tr.init_cache(cfg, b, s, jnp.float32)
    logits, cache = tr.decode_step(params, cache, tokens,
                                   jnp.zeros((), jnp.int32), cfg,
                                   last_only=True)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full_logits[:, -1]), rtol=2e-3,
                               atol=2e-3)


def test_prefill_then_decode_continues():
    """Prefill s tokens, then decode token s — must equal full forward."""
    cfg = LMConfig(name="t", **BASE)
    params = tr.init_lm(jax.random.key(0), cfg)
    b, s = 2, 10
    tokens = jax.random.randint(jax.random.key(7), (b, s + 1), 0,
                                cfg.vocab_size)
    full_logits, _ = tr.forward(params, tokens, cfg)
    cache = tr.init_cache(cfg, b, s + 1, jnp.float32)
    _, cache = tr.decode_step(params, cache, tokens[:, :s],
                              jnp.zeros((), jnp.int32), cfg, last_only=True)
    logits, _ = tr.decode_step(params, cache, tokens[:, s:s + 1],
                               jnp.asarray(s), cfg)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full_logits[:, -1]), rtol=2e-3,
                               atol=2e-3)


def test_sliding_window_masks_past():
    """With window w, moving a token far outside the window must not change
    the current logits; moving one inside must."""
    cfg = LMConfig(name="t", **{**BASE, "n_layers": 2, "sliding_window": 4})
    params = tr.init_lm(jax.random.key(0), cfg)
    s = 16
    tok = jax.random.randint(jax.random.key(8), (1, s), 0, cfg.vocab_size)
    base, _ = tr.forward(params, tok, cfg)
    # perturb a token well outside every window of the last position
    tok_far = tok.at[0, 2].set((tok[0, 2] + 1) % cfg.vocab_size)
    far, _ = tr.forward(params, tok_far, cfg)
    np.testing.assert_allclose(np.asarray(base[0, -1]),
                               np.asarray(far[0, -1]), rtol=1e-4, atol=1e-4)
    # perturb inside the window -> logits must change
    tok_near = tok.at[0, s - 2].set((tok[0, s - 2] + 1) % cfg.vocab_size)
    near, _ = tr.forward(params, tok_near, cfg)
    assert np.abs(np.asarray(base[0, -1]) - np.asarray(near[0, -1])).max() \
        > 1e-4


def test_chunked_ce_matches_dense_ce():
    cfg = LMConfig(name="t", **BASE)
    params = tr.init_lm(jax.random.key(0), cfg)
    batch = _batch(cfg, b=2, s=16)
    l1, _ = tr.loss_fn(params, batch, cfg, logit_chunk=0)
    l2, _ = tr.loss_fn(params, batch, cfg, logit_chunk=4)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    g1 = jax.grad(lambda p: tr.loss_fn(p, batch, cfg)[0])(params)
    g2 = jax.grad(lambda p: tr.loss_fn(p, batch, cfg, logit_chunk=4)[0])(
        params)
    for a, b_ in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=2e-4,
                                   atol=2e-5)


def test_causality():
    """Future tokens never influence current logits."""
    cfg = LMConfig(name="t", **{**BASE, "n_layers": 2})
    params = tr.init_lm(jax.random.key(0), cfg)
    tok = jax.random.randint(jax.random.key(9), (1, 12), 0, cfg.vocab_size)
    base, _ = tr.forward(params, tok, cfg)
    tok2 = tok.at[0, -1].set((tok[0, -1] + 3) % cfg.vocab_size)
    pert, _ = tr.forward(params, tok2, cfg)
    np.testing.assert_allclose(np.asarray(base[0, :-1]),
                               np.asarray(pert[0, :-1]), rtol=1e-4,
                               atol=1e-4)

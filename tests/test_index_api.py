"""Unified index API (repro.index): the single public search surface.

Covers the PR-2 acceptance criteria:
  * every registered backend x SearchParams combination matches its staged
    oracle (quantized + adaptive-wave compositions included),
  * save/load roundtrips return bitwise-identical search results,
  * the rpf+int8 and adaptive paths dispatch through the fused pipeline —
    no (B, M, d)-sized gather appears in their jaxprs,
  * the old entry points (query_forest / query_forest_quantized /
    adaptive_query) remain oracle-identical shims,
  * serving-layer contracts: fixed batch shapes (pad to max_batch) and the
    bounded latency ring buffer,
  * vectorized LSH batch candidates == the scalar per-point path.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ForestConfig, exact_knn
from repro.core.adaptive import _merge_dedup, adaptive_query
from repro.core.forest import gather_candidates, traverse
from repro.core.lsh import CascadedLSH
from repro.core.pipeline import (fused_query, rerank_fused_quantized,
                                 staged_query)
from repro.core.quantized import (query_forest_quantized,
                                  staged_query_quantized,
                                  staged_rerank_quantized)
from repro.core.search import rerank_topk
from repro.data.synthetic import clustered_gaussians
from repro.index import (IndexSpec, SearchParams, available_backends,
                         build_index, load_index)

N_DB, N_Q, DIM = 2500, 24, 24
FOREST = ForestConfig(n_trees=12, capacity=10)
LSH_RADII = (0.5, 1.0, 2.0)


@pytest.fixture(scope="module")
def corpus():
    db = clustered_gaussians(N_DB, DIM, n_clusters=16, seed=11)
    db = np.abs(db)            # non-negative so chi2 composes too
    db /= np.linalg.norm(db, axis=1, keepdims=True)
    rng = np.random.default_rng(5)
    q = db[:N_Q] + 0.01 * rng.normal(size=(N_Q, DIM)).astype(np.float32)
    return db, np.abs(q)


def _spec(backend):
    return IndexSpec(backend=backend, forest=FOREST, lsh_radii=LSH_RADII,
                     lsh_tables=8, lsh_bits=8, seed=0)


def _index(corpus, backend):
    return build_index(jax.random.key(0), corpus[0], _spec(backend))


def _assert_match(got, want):
    gd, gi = np.asarray(got[0]), np.asarray(got[1])
    wd, wi = np.asarray(want[0]), np.asarray(want[1])
    assert (gi == wi).all(), f"id mismatch:\n{gi}\nvs\n{wi}"
    finite = np.isfinite(wd)
    assert (finite == np.isfinite(gd)).all()
    np.testing.assert_allclose(gd[finite], wd[finite], rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# staged oracles (pre-fusion compositions, materialize (B, M, d))
# ---------------------------------------------------------------------------


def _staged_adaptive(forest, q, db, k, cfg, wave, tol, qdb=None, expand=4,
                     metric="l2"):
    """The pre-fusion adaptive wave loop (rerank_topk / staged quantized)."""
    n = (qdb.fp if qdb is not None else db).shape[0]
    cfg = cfg.resolved(n)
    best_d = jnp.full((q.shape[0], k), jnp.inf)
    best_i = jnp.full((q.shape[0], k), -1, jnp.int32)
    prev_kth, used = None, 0
    for w0 in range(0, forest.n_trees, wave):
        sub = jax.tree.map(lambda a: a[w0:w0 + wave], forest)
        leaves = traverse(sub, q, cfg.max_depth)
        ids, mask = gather_candidates(sub, leaves, cfg.leaf_pad)
        if qdb is not None:
            d, i = staged_rerank_quantized(q, ids, mask, qdb, k, expand)
        else:
            d, i = rerank_topk(q, ids, mask, db, k=k, metric=metric)
        best_d, best_i = _merge_dedup(best_d, best_i, d, i, k)
        used = min(w0 + wave, forest.n_trees)
        kth = float(jnp.mean(jnp.where(jnp.isfinite(best_d[:, -1]),
                                       best_d[:, -1], 0.0)))
        if prev_kth is not None and prev_kth > 0 \
                and (prev_kth - kth) / prev_kth < tol:
            break
        prev_kth = kth
    return best_d, best_i, used


def _lsh_oracle(index, q, params):
    """Scalar cascade probe + numpy exact rerank, padded to k."""
    k = params.k
    dists = np.full((q.shape[0], k), np.inf, np.float32)
    ids = np.full((q.shape[0], k), -1, np.int64)
    for j in range(q.shape[0]):
        d, i, _ = index.cascade.query(q[j], k=k,
                                      min_candidates=params.min_candidates)
        m = min(k, len(i))
        dists[j, :m], ids[j, :m] = d[:m], i[:m]
    return dists, ids


def _oracle(index, q, params, corpus):
    db_j = jnp.asarray(corpus[0])
    q_j = jnp.asarray(q)
    backend = index.backend
    if backend == "rpf":
        if params.adaptive_wave:
            d, i, _ = _staged_adaptive(index.forest, q_j, db_j, params.k,
                                       FOREST, params.adaptive_wave,
                                       params.tol, metric=params.metric)
            return d, i
        return staged_query(index.forest, q_j, db_j, params.k, FOREST,
                            metric=params.metric, dedup=params.dedup)
    if backend == "rpf+int8":
        if params.adaptive_wave:
            d, i, _ = _staged_adaptive(index.forest, q_j, db_j, params.k,
                                       FOREST, params.adaptive_wave,
                                       params.tol, qdb=index.qdb,
                                       expand=params.expand)
            return d, i
        return staged_query_quantized(index.forest, q_j, index.qdb, params.k,
                                      FOREST, expand=params.expand)
    if backend == "lsh-cascade":
        return _lsh_oracle(index, q, params)
    return exact_knn(q_j, db_j, k=params.k, metric=params.metric)


# ---------------------------------------------------------------------------
# the matrix: every backend x params combination vs its staged oracle
# ---------------------------------------------------------------------------

MATRIX = [
    ("rpf", SearchParams(k=5)),
    ("rpf", SearchParams(k=5, metric="cosine")),
    ("rpf", SearchParams(k=5, metric="chi2")),
    ("rpf", SearchParams(k=5, dedup=False)),
    ("rpf", SearchParams(k=5, chunk=16)),
    ("rpf", SearchParams(k=5, adaptive_wave=4, tol=0.02)),
    ("rpf", SearchParams(k=5, adaptive_wave=5, tol=1e-6)),
    ("rpf+int8", SearchParams(k=5)),
    ("rpf+int8", SearchParams(k=5, expand=2)),
    ("rpf+int8", SearchParams(k=5, chunk=16)),
    ("rpf+int8", SearchParams(k=5, adaptive_wave=4, tol=0.02)),
    ("lsh-cascade", SearchParams(k=5)),
    ("lsh-cascade", SearchParams(k=5, min_candidates=40)),
    ("bruteforce", SearchParams(k=5)),
    ("bruteforce", SearchParams(k=5, metric="dot")),
]


@pytest.mark.parametrize("backend,params", MATRIX,
                         ids=[f"{b}-{i}" for i, (b, _) in enumerate(MATRIX)])
def test_backend_params_matrix_matches_oracle(corpus, backend, params):
    index = _index(corpus, backend)
    got = index.search(corpus[1], params)
    want = _oracle(index, corpus[1], params, corpus)
    _assert_match(got, want)


def test_all_backends_registered():
    assert available_backends() == ["bruteforce", "lsh-cascade", "rpf",
                                    "rpf+int8"]


def test_pallas_mode_spot_check(corpus):
    """The kernel dispatch path (interpret off-TPU) agrees with ref."""
    for backend in ("rpf", "rpf+int8"):
        index = _index(corpus, backend)
        got = index.search(corpus[1], SearchParams(k=4, mode="pallas"))
        want = index.search(corpus[1], SearchParams(k=4, mode="ref"))
        _assert_match(got, want)


def test_search_params_validation():
    with pytest.raises(ValueError):
        SearchParams(mode="fast")
    with pytest.raises(ValueError):
        SearchParams(k=0)
    with pytest.raises(KeyError):
        build_index(None, np.zeros((4, 2), np.float32),
                    IndexSpec(backend="no-such-backend"))


# ---------------------------------------------------------------------------
# deprecation shims: old entry points stay oracle-identical
# ---------------------------------------------------------------------------


def test_old_entry_points_are_oracle_identical(corpus):
    db_j, q_j = jnp.asarray(corpus[0]), jnp.asarray(corpus[1])
    index = _index(corpus, "rpf+int8")
    forest, qdb = index.forest, index.qdb

    from repro.core import query_forest
    _assert_match(query_forest(forest, q_j, db_j, 5, FOREST),
                  staged_query(forest, q_j, db_j, 5, FOREST))
    _assert_match(query_forest_quantized(forest, q_j, qdb, 5, FOREST),
                  staged_query_quantized(forest, q_j, qdb, 5, FOREST))
    d, i, used = adaptive_query(forest, q_j, db_j, 5, FOREST, wave=4,
                                tol=0.02)
    wd, wi, wused = _staged_adaptive(forest, q_j, db_j, 5, FOREST, 4, 0.02)
    assert used == wused
    _assert_match((d, i), (wd, wi))


# ---------------------------------------------------------------------------
# save / load roundtrip: bitwise-identical results
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["rpf", "rpf+int8", "lsh-cascade",
                                     "bruteforce"])
def test_save_load_roundtrip_bitwise(corpus, backend, tmp_path):
    index = _index(corpus, backend)
    params = SearchParams(k=4)
    d0, i0 = map(np.asarray, index.search(corpus[1], params))
    path = os.path.join(tmp_path, "idx")
    index.save(path)
    index2 = load_index(path)
    assert index2.backend == backend
    assert index2.spec == index.spec
    d1, i1 = map(np.asarray, index2.search(corpus[1], params))
    assert np.array_equal(i0, i1)
    assert np.array_equal(d0, d1)          # bitwise, not just allclose
    # the restored index keeps serving: adds are queryable immediately
    novel = corpus[0][0] + 0.25
    nid = index2.add(novel)
    _, i = index2.search(novel[None], SearchParams(k=1))
    assert int(np.asarray(i)[0, 0]) == nid


def test_save_folds_pending_adds(corpus, tmp_path):
    index = _index(corpus, "rpf")
    nid = index.add(corpus[0][0] + 0.5)
    path = os.path.join(tmp_path, "idx")
    index.save(path)
    assert index.stats()["n_overflow"] == 0          # compacted on save
    index2 = load_index(path)
    assert index2.db.shape[0] == N_DB + 1
    _, i = index2.search((corpus[0][0] + 0.5)[None], SearchParams(k=1))
    assert int(np.asarray(i)[0, 0]) == nid


# ---------------------------------------------------------------------------
# acceptance: no (B, M, d) gather in the quantized / adaptive jaxprs
# ---------------------------------------------------------------------------


def _max_gather_elems(jaxpr) -> int:
    """Largest gather output (in elements) anywhere in a jaxpr tree."""
    worst = 0

    def walk(jx):
        nonlocal worst
        for eqn in jx.eqns:
            if eqn.primitive.name == "gather":
                for ov in eqn.outvars:
                    worst = max(worst, int(np.prod(ov.aval.shape)))
            for v in eqn.params.values():
                for sub in (v if isinstance(v, (list, tuple)) else [v]):
                    if hasattr(sub, "eqns"):
                        walk(sub)
                    elif hasattr(sub, "jaxpr"):
                        walk(sub.jaxpr)

    walk(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr)
    return worst


def test_no_bmd_gather_in_fused_paths(corpus):
    """rpf+int8 and adaptive-wave searches go through the fused pipeline:
    nothing in their jaxprs gathers a (B, M, d)-sized tensor."""
    index = _index(corpus, "rpf+int8")
    q = jnp.asarray(corpus[1][:8])
    cfg = FOREST.resolved(N_DB)
    m = cfg.n_trees * cfg.leaf_pad
    bmd = q.shape[0] * m * DIM

    db_j = jnp.asarray(corpus[0])

    def quantized_search(qq, qdb):
        return fused_query(index.forest, qq, qdb, 5, FOREST, mode="pallas",
                           chunk=32)

    def plain_search(qq, db):
        # the same program each adaptive wave traces (on a forest prefix)
        return fused_query(index.forest, qq, db, 5, FOREST, mode="pallas",
                           chunk=32)

    jx_q = jax.make_jaxpr(quantized_search)(q, index.qdb)
    jx_p = jax.make_jaxpr(plain_search)(q, db_j)
    assert _max_gather_elems(jx_q) < bmd, "quantized path gathers (B,M,d)"
    assert _max_gather_elems(jx_p) < bmd, "fused path gathers (B,M,d)"

    def quantized_rerank(qq, ids, mask, qdb):
        return rerank_fused_quantized(qq, ids, mask, qdb, 5, mode="pallas",
                                      chunk=32)

    ids = jnp.zeros((8, m), jnp.int32)
    mask = jnp.ones((8, m), bool)
    jx_r = jax.make_jaxpr(quantized_rerank)(q, ids, mask, index.qdb)
    assert _max_gather_elems(jx_r) < bmd

    # sanity: the checker DOES see the staged oracle's full-width gather
    def staged(qq, db):
        return staged_query(index.forest, qq, db, 5, FOREST)

    assert _max_gather_elems(jax.make_jaxpr(staged)(q, db_j)) >= bmd


# ---------------------------------------------------------------------------
# vectorized LSH batch path == scalar per-point path
# ---------------------------------------------------------------------------


def test_lsh_batch_candidates_match_scalar(corpus):
    db, q = corpus
    cascade = CascadedLSH(db, list(LSH_RADII), n_tables=6, n_bits=8, seed=3)
    level = cascade.levels[0]
    batch_sets = level.candidate_sets(q)
    for j in range(q.shape[0]):
        assert batch_sets[j] == level.candidates(q[j])
    ids, mask = level.candidates_batch(q, pad_multiple=32)
    assert ids.shape == mask.shape and ids.shape[1] % 32 == 0
    for j in range(q.shape[0]):
        assert set(ids[j][mask[j]].tolist()) == batch_sets[j]

    # cascade semantics: per-query early stop matches the scalar retrieve
    for mc in (1, 30):
        sets = cascade.retrieve_sets(q, min_candidates=mc)
        for j in range(q.shape[0]):
            assert sets[j] == set(cascade.retrieve(q[j], mc).tolist())


# ---------------------------------------------------------------------------
# serving contracts: fixed batch shapes + bounded latency buffer
# ---------------------------------------------------------------------------


def test_serve_batch_pads_to_max_batch(corpus):
    from repro.serve.ann_serve import make_ann_server
    db = corpus[0][:600]
    index, batcher = make_ann_server(
        db, IndexSpec(backend="rpf", forest=ForestConfig(n_trees=6)),
        k=3, max_batch=8, max_wait_s=0.01)
    seen_shapes = []
    orig_search = index.search

    def spying_search(qq, params=None, **kw):
        seen_shapes.append(np.asarray(qq).shape)
        return orig_search(qq, params, **kw)

    index.search = spying_search
    try:
        for n in (1, 3, 7):                 # distinct logical batch sizes
            rs = [batcher.submit(db[j]) for j in range(n)]
            for j, r in enumerate(rs):
                assert r.event.wait(30)
                assert int(r.result[1][0]) == j     # self is the 1-NN
    finally:
        batcher.stop()
    assert seen_shapes and all(s == (8, db.shape[1]) for s in seen_shapes), \
        f"expected fixed (max_batch, d) shapes, saw {seen_shapes}"


def test_latency_ring_buffer_bounded():
    from repro.serve.batching import DynamicBatcher
    b = DynamicBatcher(lambda ps: [0 for _ in ps], max_batch=4,
                       max_wait_s=0.001, latency_window=16).start()
    for _ in range(100):
        b(np.zeros(3))
    b.stop()
    assert b._latencies.shape[0] == 16       # fixed-size ring
    assert b._latency_count == 100
    assert b.stats["requests"] == 100
    assert np.isfinite(b.stats["p99_latency_ms"])

"""Bitwise parity of the batched cross-tree builder vs the legacy oracle.

The batched builder (DESIGN.md §10) must reproduce the legacy per-tree
builder EXACTLY under ``seed_mode="compat"`` — every Forest array, every
dtype, every config — because three families of existing pins rest on
deterministic builds: multi-probe probe-0 bitwise, save/load roundtrip,
and compaction-vs-fresh.  ``seed_mode="fused"`` draws a different (valid)
stream and is checked against the structural invariants instead.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ForestConfig
from repro.core.forest import (_build_forest_legacy, build_forest,
                               forest_stats)

TIED = "tied"  # heavily tied coordinates: exercises tie-escape + redraws


def _corpus(n, d, dtype=np.float32, kind="normal", seed=0):
    rng = np.random.default_rng(seed)
    if kind == TIED:
        # sparse-histogram-like: most entries exactly 0, few quantized
        x = rng.integers(0, 4, size=(n, d)).astype(np.float32)
        x[rng.uniform(size=x.shape) < 0.7] = 0.0
    else:
        x = rng.normal(size=(n, d))
    return jnp.asarray(x.astype(dtype))


def _assert_forests_bitwise(got, want):
    for name in want._fields:
        a, b = np.asarray(getattr(want, name)), np.asarray(getattr(got, name))
        np.testing.assert_array_equal(
            b, a, err_msg=f"batched builder diverges on Forest.{name}")
        assert a.dtype == b.dtype, (name, a.dtype, b.dtype)


# ---------------------------------------------------------------------------
# bitwise matrix: dtypes x depths x ragged leaf sizes x tie-heavy data
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
@pytest.mark.parametrize("n,d,cfg_kw", [
    (700, 16, dict(n_trees=6, capacity=12)),
    (701, 16, dict(n_trees=5, capacity=5, split_ratio=0.45)),   # ragged
    (256, 8, dict(n_trees=3, capacity=9, split_ratio=0.12)),
    (300, 12, dict(n_trees=4, capacity=8, max_depth=4)),        # depth-capped
    (300, 12, dict(n_trees=4, capacity=8, n_proj=2)),           # K=2 tests
])
def test_batched_bitwise_matches_legacy(dtype, n, d, cfg_kw):
    x = _corpus(n, d, dtype=dtype, seed=n + d)
    cfg = ForestConfig(**cfg_kw)
    key = jax.random.key(n)
    want = _build_forest_legacy(key, x, cfg.resolved(n))
    got = build_forest(key, x, cfg)
    _assert_forests_bitwise(got, want)


def test_batched_bitwise_on_tied_data():
    """Tie-escape splits + degenerate-node redraws follow the same path."""
    x = _corpus(900, 24, kind=TIED, seed=3)
    cfg = ForestConfig(n_trees=6, capacity=10)
    key = jax.random.key(11)
    want = _build_forest_legacy(key, x, cfg.resolved(900))
    got = build_forest(key, x, cfg)
    _assert_forests_bitwise(got, want)


def test_batched_bitwise_under_node_budget_pressure():
    """A tight max_nodes budget trips the allocation-overflow guard; the
    batched builder must freeze the same trees at the same level."""
    x = _corpus(600, 8, seed=9)
    cfg = ForestConfig(n_trees=4, capacity=4, max_nodes=96)
    key = jax.random.key(2)
    want = _build_forest_legacy(key, x, cfg.resolved(600))
    got = build_forest(key, x, cfg)
    _assert_forests_bitwise(got, want)


def test_staged_shrink_bitwise_matches_single_stage():
    """Force the multi-stage active-set shrink on a small corpus (tiny
    ``restage_min``): stage relaunches at narrower sort widths must not
    perturb a single bit — compaction is order-preserving, so each
    overfull segment sorts to the same value sequence."""
    from repro.core.forest import _build_forest_batched
    x = _corpus(1200, 16, seed=8)
    cfg = ForestConfig(n_trees=5, capacity=6).resolved(1200)
    key = jax.random.key(3)
    want = _build_forest_legacy(key, x, cfg)
    keys = jax.random.split(key, cfg.n_trees)
    got = _build_forest_batched(keys, x, cfg, restage_min=64)
    _assert_forests_bitwise(got, want)
    # tied data through the staged path too (degenerate redraw nodes keep
    # their points active across stage boundaries)
    xt = _corpus(1000, 12, kind=TIED, seed=10)
    cfg = ForestConfig(n_trees=4, capacity=8).resolved(1000)
    want = _build_forest_legacy(key, xt, cfg)
    got = _build_forest_batched(jax.random.split(key, 4), xt, cfg,
                                restage_min=64)
    _assert_forests_bitwise(got, want)


def test_tree_chunk_bitwise_matches_unchunked():
    """Compat-mode chunking slices the same per-tree key split."""
    x = _corpus(500, 12, seed=4)
    cfg = ForestConfig(n_trees=10, capacity=12)
    key = jax.random.key(5)
    full = build_forest(key, x, cfg)
    for chunk in (1, 3, 4, 10):
        _assert_forests_bitwise(build_forest(key, x, cfg, tree_chunk=chunk),
                                full)
    # and the chunked legacy path agrees too (three-way pin)
    _assert_forests_bitwise(
        _build_forest_legacy(key, x, cfg.resolved(500), tree_chunk=3), full)


def test_build_forest_traceable():
    """build_forest must stay wrappable in jit/vmap (the pre-batched
    builder was itself @jax.jit): a traced key with a concrete closed-over
    db takes the in-graph single-stage path, bitwise-equal to the host
    driver; same inside shard_map-style tracing of both args."""
    x = _corpus(900, 10, seed=12)
    cfg = ForestConfig(n_trees=4, capacity=8)
    want = build_forest(jax.random.key(9), x, cfg)

    got_k = jax.jit(lambda k: build_forest(k, x, cfg))(jax.random.key(9))
    _assert_forests_bitwise(got_k, want)
    got_kx = jax.jit(lambda k, d: build_forest(k, d, cfg))(
        jax.random.key(9), x)
    _assert_forests_bitwise(got_kx, want)


def test_tiny_corpus_no_split_edge():
    """N <= capacity: the early-exit loop must not run at all; both
    builders return the single-root-leaf forest."""
    x = _corpus(8, 4, seed=6)
    cfg = ForestConfig(n_trees=3, capacity=12)
    key = jax.random.key(1)
    want = _build_forest_legacy(key, x, cfg.resolved(8))
    got = build_forest(key, x, cfg)
    _assert_forests_bitwise(got, want)
    assert int(np.asarray(got.n_nodes).max()) == 1


# ---------------------------------------------------------------------------
# fused seed mode: different stream, same structural contract
# ---------------------------------------------------------------------------


def test_fused_seed_mode_valid_partition():
    n = 1200
    x = _corpus(n, 16, seed=7)
    cfg = ForestConfig(n_trees=6, capacity=12)
    f = build_forest(jax.random.key(0), x, cfg, seed_mode="fused")
    perm = np.asarray(f.perm)
    for tree in range(cfg.n_trees):
        assert sorted(perm[tree]) == list(range(n))
    stats = forest_stats(f, cfg, n)
    assert stats["occ_max"] <= cfg.capacity
    assert stats["overflow_points"] == 0


def test_impl_and_seed_mode_validation():
    x = _corpus(100, 4)
    cfg = ForestConfig(n_trees=2, capacity=8)
    with pytest.raises(ValueError, match="impl"):
        build_forest(jax.random.key(0), x, cfg, impl="nope")
    with pytest.raises(ValueError, match="seed_mode"):
        build_forest(jax.random.key(0), x, cfg, seed_mode="nope")


# The hypothesis any-(data, config, seed) version of the bitwise invariant
# lives in test_property.py::test_batched_builder_bitwise_invariant (that
# module carries the optional-dependency skip).

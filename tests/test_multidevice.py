"""Multi-device tests: run in a subprocess with 8 forced host devices so the
main pytest process keeps seeing 1 device (assignment requirement)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _run(body: str, timeout: int = 420) -> str:
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import compat
        mesh = compat.make_mesh((4, 2), ("data", "model"))
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_sharded_index_end_to_end():
    out = _run("""
        from repro.core.sharded_index import build_sharded_index, make_query_fn
        from repro.core import ForestConfig, exact_knn
        from repro.data.synthetic import clustered_gaussians
        N, d = 4096, 48
        db = jnp.asarray(clustered_gaussians(N, d, seed=0))
        q = db[:64] + 0.01
        cfg = ForestConfig(n_trees=16, capacity=12)
        idx = build_sharded_index(jax.random.key(0), db, cfg, mesh)
        qfn = make_query_fn(idx.cfg, idx.n_local, mesh, k=5)
        with mesh:
            dists, ids = qfn(idx, q, db)
        td, tids = exact_knn(q, db, k=5)
        rec1 = float((np.asarray(ids)[:, :1] == np.asarray(tids)[:, :1])
                     .any(1).mean())
        assert rec1 > 0.9, rec1
        # merged distances must be sorted ascending
        dd = np.asarray(dists)
        assert (np.diff(dd, axis=1) >= -1e-6).all()
        print("OK rec1", rec1)
    """)
    assert "OK rec1" in out


def test_sharded_index_validity_mask():
    """with_validity=True: the tombstone bitmap rides the fused rerank's
    id/mask path per cell — deleted rows never surface from any shard and
    the remaining results match the unmasked path exactly."""
    out = _run("""
        from repro.core.sharded_index import build_sharded_index, make_query_fn
        from repro.core import ForestConfig
        from repro.data.synthetic import clustered_gaussians
        N, d = 4096, 48
        db = jnp.asarray(clustered_gaussians(N, d, seed=0))
        q = db[:32] + 0.01
        cfg = ForestConfig(n_trees=16, capacity=12)
        idx = build_sharded_index(jax.random.key(0), db, cfg, mesh)
        qfn = make_query_fn(idx.cfg, idx.n_local, mesh, k=5)
        qfn_v = make_query_fn(idx.cfg, idx.n_local, mesh, k=5,
                              with_validity=True)
        dead = np.arange(0, 64, 2)
        live = np.ones(N, bool); live[dead] = False
        with mesh:
            d0, i0 = qfn(idx, q, db)
            d1, i1 = qfn_v(idx, q, db, jnp.asarray(live))
            d2, i2 = qfn_v(idx, q, db, jnp.ones(N, dtype=bool))
        i1 = np.asarray(i1)
        assert not np.isin(i1, dead).any(), "tombstoned row surfaced"
        # all-live mask == unmasked path, bitwise
        np.testing.assert_array_equal(np.asarray(i2), np.asarray(i0))
        np.testing.assert_array_equal(np.asarray(d2), np.asarray(d0))
        # masked results are live rows with sorted distances
        dd = np.asarray(d1)
        assert (np.diff(dd, axis=1) >= -1e-6).all()
        print("OK validity")
    """)
    assert "OK validity" in out


def test_sharded_index_multiprobe():
    """params.n_probes widens every cell's descent (DESIGN.md §9): the
    n_probes=1 spelling is bitwise the default path, and wider probes only
    improve recall of the all-gathered global top-k."""
    out = _run("""
        from repro.core.sharded_index import build_sharded_index, make_query_fn
        from repro.core import ForestConfig, exact_knn
        from repro.data.synthetic import clustered_gaussians
        from repro.index import SearchParams
        N, d = 4096, 48
        db = jnp.asarray(clustered_gaussians(N, d, seed=0))
        q = db[:48] + 0.02
        cfg = ForestConfig(n_trees=16, capacity=12)
        idx = build_sharded_index(jax.random.key(0), db, cfg, mesh)
        qfn = make_query_fn(idx.cfg, idx.n_local, mesh, k=5)
        qfn1 = make_query_fn(idx.cfg, idx.n_local, mesh,
                             params=SearchParams(k=5, n_probes=1))
        qfn4 = make_query_fn(idx.cfg, idx.n_local, mesh,
                             params=SearchParams(k=5, n_probes=4))
        with mesh:
            d0, i0 = qfn(idx, q, db)
            d1, i1 = qfn1(idx, q, db)
            d4, i4 = qfn4(idx, q, db)
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i0))
        np.testing.assert_array_equal(np.asarray(d1), np.asarray(d0))
        _, tids = exact_knn(q, db, k=5)
        def rec(i):
            return float((np.asarray(i)[:, :, None]
                          == np.asarray(tids)[:, None, :]).any(1).mean())
        r1, r4 = rec(i1), rec(i4)
        assert r4 >= r1 - 1e-6, (r1, r4)
        dd = np.asarray(d4)
        assert (np.diff(dd, axis=1) >= -1e-6).all()
        print("OK multiprobe", r1, r4)
    """)
    assert "OK multiprobe" in out


def test_sharded_index_class_search_and_admission():
    """ShardedIndex mirrors the Index protocol on a mesh: search honors
    tombstones, strict mode raises the structured CapabilityError, and
    non-strict projects (counting the downgrade) — never silently."""
    out = _run("""
        from repro.core import ForestConfig, exact_knn
        from repro.core.sharded_index import ShardedIndex
        from repro.data.synthetic import clustered_gaussians
        from repro.index import IndexSpec, SearchParams, build_index
        from repro.index.params import CapabilityError
        N, d = 4096, 32
        db = clustered_gaussians(N, d, seed=0)
        q = db[:48] + 0.01
        spec = IndexSpec(backend="rpf",
                         forest=ForestConfig(n_trees=16, capacity=12))
        index = build_index(jax.random.key(0), db, spec)
        dead = list(range(0, 200, 2))
        index.delete(dead)
        sx = ShardedIndex(index, mesh)
        dists, ids = sx.search(q, SearchParams(k=5))
        ids = np.asarray(ids)
        assert not np.isin(ids, dead).any(), "tombstoned id surfaced"
        live_gids, live_rows = index.live_points()
        td, tpos = exact_knn(q, live_rows, k=5)
        tids = np.asarray(live_gids)[np.asarray(tpos)]
        rec1 = float((ids[:, :1] == tids[:, :1]).any(1).mean())
        assert rec1 > 0.9, rec1
        assert (np.diff(np.asarray(dists), axis=1) >= -1e-6).all()
        # strict (default): mesh-illegal knobs raise, naming the knob
        wavy = SearchParams(k=5, adaptive_wave=8)
        try:
            sx.search(q, wavy)
            raise AssertionError("strict ShardedIndex accepted "
                                 "adaptive_wave")
        except CapabilityError as e:
            assert any(v.knob == "adaptive_wave" for v in e.violations)
        # non-strict: projects the knob away and counts the downgrade
        lax_sx = ShardedIndex(index, mesh, strict=False)
        d2, i2 = lax_sx.search(q, wavy)
        assert lax_sx.stats()["counters"]["stripped_knobs"] >= 1
        st = sx.stats()
        assert st["sharded"] and st["n_live"] == N - len(dead)
        print("OK class", rec1)
    """)
    assert "OK class" in out


def test_sharded_filtered_parity_with_host_oracle():
    """The ISSUE-10 acceptance criterion: sharded filtered search answers
    recall-identical to the single-host filtered oracle — in the brute
    regime literally bitwise, in the ride-the-mesh regime leak-free with
    oracle-level recall."""
    out = _run("""
        from repro.core import exact_knn, ForestConfig
        from repro.core.sharded_index import ShardedIndex
        from repro.data.synthetic import clustered_gaussians
        from repro.filter import Eq, Range
        from repro.index import IndexSpec, SearchParams, build_index
        N, d = 12288, 32
        db = clustered_gaussians(N, d, seed=0)
        q = db[:32] + 0.01
        meta = {"shop": np.asarray([f"s{i % 8}" for i in range(N)]),
                "price": np.arange(N, dtype=np.int64)}
        spec = IndexSpec(backend="rpf",
                         forest=ForestConfig(n_trees=16, capacity=12))
        index = build_index(jax.random.key(0), db, spec, metadata=meta)
        sx = ShardedIndex(index, mesh)
        # brute regime (1536 matching rows <= 4096): both paths scan the
        # same canonical live rows -> bitwise-identical to the host oracle
        pb = SearchParams(k=10, filter=Eq("shop", "s1"))
        hd, hi = map(np.asarray, index.search(q, pb))
        sd, si = map(np.asarray, sx.search(q, pb))
        np.testing.assert_array_equal(si, hi)
        np.testing.assert_array_equal(sd, hd)
        assert (si[si >= 0] % 8 == 1).all()
        # ride-the-mesh regime (6144 matches, selectivity 0.5): the host
        # filter bitmap lands on the row-sharded validity argument
        pm = SearchParams(k=10, filter=Range("price", 0, N // 2 - 1))
        md_, mi = map(np.asarray, sx.search(q, pm))
        ok = mi[mi >= 0]
        assert (ok < N // 2).all(), "filtered-out row leaked on the mesh"
        sub = db[:N // 2]
        _, tpos = exact_knn(q, sub, k=10)
        def rec(i, t):
            return float((i[:, :, None] == t[:, None, :]).any(1).mean())
        r_mesh = rec(mi, np.asarray(tpos))
        hd2, hi2 = map(np.asarray, index.search(q, pm))
        r_host = rec(hi2, np.asarray(tpos))
        assert r_mesh >= r_host - 0.05, (r_mesh, r_host)
        st = sx.stats()["counters"]
        assert st["filtered_queries"] == 2 * len(q)
        assert st["brute_filtered_queries"] == len(q)
        print("OK filtered", r_mesh, r_host)
    """)
    assert "OK filtered" in out


def test_sharded_probe_schedule_parity():
    """probe_schedule rides the mesh: tol=0.0 is bitwise the fixed-cap
    step (the scheduled_query invariant, now over per-width mesh steps),
    and a loose tol processes fewer probes on average."""
    out = _run("""
        import dataclasses
        from repro.core import ForestConfig
        from repro.core.sharded_index import ShardedIndex
        from repro.data.synthetic import clustered_gaussians
        from repro.index import IndexSpec, SearchParams, build_index
        N, d, CAP = 4096, 32, 4
        db = clustered_gaussians(N, d, seed=0)
        q = db[:64] + 0.01
        spec = IndexSpec(backend="rpf",
                         forest=ForestConfig(n_trees=16, capacity=12))
        index = build_index(jax.random.key(0), db, spec)
        sx = ShardedIndex(index, mesh)
        fixed = SearchParams(k=5, n_probes=CAP)
        sched = dataclasses.replace(fixed, n_probes=1, probe_schedule=CAP,
                                    tol=0.0)
        df, jf = map(np.asarray, sx.search(q, fixed))
        ds, js = map(np.asarray, sx.search(q, sched))
        np.testing.assert_array_equal(js, jf)
        np.testing.assert_array_equal(ds, df)
        st = sx.stats()["counters"]
        assert st["scheduled_queries"] == len(q)
        assert st["probe_rounds"] >= 1
        # loose tol: easy queries converge below the cap, so the loose run
        # processes strictly fewer probes than the tol=0.0 exhaustive run
        # (counters are cumulative: diff isolates the loose run's cost)
        exhaustive = st["probes_processed"]
        loose = dataclasses.replace(sched, tol=0.05)
        sx.search(q, loose)
        st2 = sx.stats()["counters"]
        assert st2["probes_processed"] - exhaustive < exhaustive
        print("OK schedule")
    """)
    assert "OK schedule" in out


def test_mesh_serving_runtime_filters_and_schedules():
    """The serving bugfix: a mesh ServingRuntime SERVES filtered and
    scheduled params; the one refusal left (filter without metadata) is a
    structured CapabilityError naming the capabilities() entry."""
    out = _run("""
        from repro.core import ForestConfig
        from repro.data.synthetic import clustered_gaussians
        from repro.filter import Eq
        from repro.index import IndexSpec, SearchParams, build_index
        from repro.index.params import CapabilityError
        from repro.serve.runtime import ServingRuntime
        N, d = 2048, 32
        db = clustered_gaussians(N, d, seed=0)
        meta = {"shop": np.asarray([f"s{i % 4}" for i in range(N)])}
        spec = IndexSpec(backend="rpf",
                         forest=ForestConfig(n_trees=16, capacity=12))
        index = build_index(jax.random.key(0), db, spec, metadata=meta)
        p = SearchParams(k=5, filter=Eq("shop", "s1"), probe_schedule=4,
                         tol=0.0)
        rt = ServingRuntime(index, params=p, mesh=mesh, max_batch=8,
                            max_wait_s=0.001)
        try:
            for j in range(8):
                dists, ids = rt(np.asarray(db[j], np.float32))
                ids = np.asarray(ids)
                assert (ids[ids >= 0] % 4 == 1).all(), ids
        finally:
            rt.stop()
        # no metadata -> structured refusal naming the filter entry
        bare = build_index(jax.random.key(0), db, spec)
        try:
            ServingRuntime(bare, params=SearchParams(
                k=5, filter=Eq("shop", "s1")), mesh=mesh, warmup=False)
            raise AssertionError("mesh runtime accepted a filter with no "
                                 "metadata")
        except CapabilityError as e:
            assert any(v.knob == "filter" for v in e.violations)
            assert "metadata" in str(e)
        print("OK mesh serving")
    """)
    assert "OK mesh serving" in out


def test_dp_train_step_with_compression():
    out = _run("""
        from repro.configs.base import LMConfig
        from repro.models import transformer as tr
        from repro.train.optimizer import adamw, constant_schedule
        from repro.train.train_state import (init_train_state,
                                             make_dp_train_step)
        cfg = LMConfig(name="t", n_layers=2, d_model=32, n_heads=2,
                       n_kv_heads=1, head_dim=16, d_ff=64, vocab_size=128,
                       remat=False, param_dtype="float32",
                       compute_dtype="float32")
        params = tr.init_lm(jax.random.key(0), cfg)
        opt = adamw(constant_schedule(1e-2))
        tok = jax.random.randint(jax.random.key(1), (8, 16), 0, 128)
        batch = {"tokens": tok, "labels": tok}
        def lf(p, b): return tr.loss_fn(p, b, cfg)
        losses = {}
        for compress in (False, True):
            state = init_train_state(params, opt, compress=compress)
            step = make_dp_train_step(lf, opt, mesh, compress=compress)
            ls = []
            for i in range(10):
                state, m = step(state, batch)
                ls.append(float(m["loss"]))
            losses[compress] = ls
            assert ls[-1] < ls[0], (compress, ls)
        # int8+EF trajectory tracks the exact one closely
        diff = abs(losses[True][-1] - losses[False][-1])
        assert diff < 0.15 * losses[False][0], (diff, losses)
        print("OK dp", losses[False][-1], losses[True][-1])
    """)
    assert "OK dp" in out


def test_sharded_moe_matches_unsharded():
    out = _run("""
        from repro.models import moe as moe_mod
        from repro.models.layers import Axes
        t, d, f, e = 64, 16, 32, 8
        params = moe_mod.init_moe(jax.random.key(0), d, f, e, jnp.float32,
                                  True)
        x = jax.random.normal(jax.random.key(1), (t, d))
        want, aux_w = moe_mod.moe_fwd(params, x, n_experts=e, top_k=2,
                                      capacity_factor=8.0)
        axes = Axes(dp=("data",), tp="model", mesh=mesh)
        with mesh:
            got, aux_g = jax.jit(lambda p, xx: moe_mod.moe_fwd_sharded(
                p, xx, n_experts=e, top_k=2, capacity_factor=8.0,
                axes=axes))(params, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-4, atol=3e-4)
        print("OK moe", float(aux_g))
    """)
    assert "OK moe" in out


def test_elastic_checkpoint_reshard():
    """Save under a (4,2) mesh sharding, restore under (2,4) — elasticity."""
    out = _run("""
        import tempfile
        from jax.sharding import NamedSharding
        from repro.checkpoint.checkpointer import Checkpointer
        x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        s1 = NamedSharding(mesh, P("data", "model"))
        xs = jax.device_put(x, s1)
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d)
            ck.save(3, {"x": xs}, block=True)
            mesh2 = compat.make_mesh((2, 4), ("data", "model"))
            s2 = NamedSharding(mesh2, P("model", "data"))
            restored, step = ck.restore({"x": xs}, shardings={"x": s2})
            assert step == 3
            np.testing.assert_array_equal(np.asarray(restored["x"]), x)
            assert restored["x"].sharding == s2
        print("OK elastic")
    """)
    assert "OK elastic" in out


def test_sharded_mace_matches_local():
    out = _run("""
        import dataclasses
        from repro.configs import get_arch
        from repro.data.graph_data import (random_graph, sort_edges_for_mesh)
        from repro.models import mace as mace_mod
        from repro.models.layers import Axes
        cfg = dataclasses.replace(get_arch("mace").config, d_hidden=8)
        g = random_graph(64, 256, seed=0)
        s, r, em = sort_edges_for_mesh(g["senders"], g["receivers"], 64, 4)
        params = mace_mod.init_mace(jax.random.key(0), cfg)
        species = jnp.asarray(g["species"] % cfg.n_species)
        args = dict(species=species,
                    positions=jnp.asarray(g["positions"]),
                    senders=jnp.asarray(s), receivers=jnp.asarray(r),
                    edge_mask=jnp.asarray(em))
        want = mace_mod.mace_fwd(params, cfg, **args)["energy"]
        axes = Axes(dp=("data",), tp="model", mesh=mesh)
        with mesh:
            got = jax.jit(lambda p: mace_mod.mace_fwd(
                p, cfg, **args, axes=axes)["energy"])(params)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-5)
        print("OK mace sharded")
    """)
    assert "OK mace sharded" in out


def test_a2a_moe_matches_reference():
    out = _run("""
        from repro.models import moe as moe_mod
        from repro.models.layers import Axes
        t, d, f, e = 128, 16, 32, 8
        params = moe_mod.init_moe(jax.random.key(0), d, f, e, jnp.float32,
                                  True)
        x = jax.random.normal(jax.random.key(1), (t, d))
        want, _ = moe_mod.moe_fwd(params, x, n_experts=e, top_k=1,
                                  capacity_factor=8.0)
        axes = Axes(dp=("data",), tp="model", mesh=mesh)
        with mesh:
            got, aux = jax.jit(lambda p, xx: moe_mod.moe_fwd_a2a(
                p, xx, n_experts=e, capacity_factor=8.0, axes=axes))(
                params, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-4, atol=3e-4)
        print("OK a2a")
    """)
    assert "OK a2a" in out


def test_quantized_gather_close_to_exact():
    out = _run("""
        from repro.models import moe as moe_mod
        from repro.models.layers import Axes
        t, d, f, e = 64, 16, 32, 8
        params = moe_mod.init_moe(jax.random.key(0), d, f, e, jnp.float32,
                                  False)
        x = jax.random.normal(jax.random.key(1), (t, d))
        axes = Axes(dp=("data",), tp="model", mesh=mesh)
        with mesh:
            ref, _ = jax.jit(lambda p, xx: moe_mod.moe_fwd_sharded(
                p, xx, n_experts=e, top_k=2, capacity_factor=8.0, axes=axes,
                fsdp=True))(params, x)
            qnt, _ = jax.jit(lambda p, xx: moe_mod.moe_fwd_sharded(
                p, xx, n_experts=e, top_k=2, capacity_factor=8.0, axes=axes,
                fsdp=True, gather_quant=True))(params, x)
        err = np.abs(np.asarray(ref) - np.asarray(qnt)).max() / \
            (np.abs(np.asarray(ref)).max() + 1e-9)
        assert err < 0.05, err
        print("OK gq", err)
    """)
    assert "OK gq" in out

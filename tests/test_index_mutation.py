"""Segmented mutable-index lifecycle (repro.index, DESIGN.md §8).

Covers the PR-3 acceptance criteria:
  * search over a mutated index (adds + deletes + upserts, pre- AND
    post-compaction) is bitwise-identical to a fresh build of the
    equivalent live point set, for every registered backend,
  * searches issued during a background compaction return without
    blocking on the rebuild (readers never take the writer lock),
  * delete-then-search tombstone correctness vs a brute-force oracle,
    including the adaptive-wave and int8-shortlist compositions,
  * threaded add/delete/search/save stress + mid-mutation save→load
    bitwise roundtrip,
  * the format-1 (single-segment) checkpoint read shim,
  * snapshot isolation and the mutation counters in ``stats()``.

The bitwise tests run each backend in its full-recall regime (fat leaves /
full-width shortlist / all-level cascade probing) so approximate candidate
generation cannot mask a divergence: any distance or id mismatch is then a
real bug in the segment fan-out, tombstone masking, or merge.
"""
import os
import threading
import time

import jax
import numpy as np
import pytest

from repro.core import ForestConfig
from repro.index import (IndexSpec, SearchParams, build_index, load_index)

N_DB, DIM = 220, 12

# full-recall regimes: every live point is a candidate on every path
FULL_RECALL = {
    "rpf": (IndexSpec(backend="rpf",
                      forest=ForestConfig(n_trees=4, capacity=512)),
            SearchParams(k=5)),
    "rpf+int8": (IndexSpec(backend="rpf+int8",
                           forest=ForestConfig(n_trees=4, capacity=512)),
                 SearchParams(k=5, expand=128)),
    "lsh-cascade": (IndexSpec(backend="lsh-cascade",
                              lsh_radii=(0.5, 1.0, 2.0), lsh_tables=6,
                              lsh_bits=6),
                    SearchParams(k=5, min_candidates=10**9)),
    "bruteforce": (IndexSpec(backend="bruteforce"), SearchParams(k=5)),
}


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(7)
    db = np.abs(rng.normal(size=(N_DB, DIM))).astype(np.float32)
    db /= np.linalg.norm(db, axis=1, keepdims=True)
    q = np.abs(db[:6] + 0.01 * rng.normal(size=(6, DIM)).astype(np.float32))
    return db, q


def _mutate(index, dim=DIM, seed=3):
    """A fixed add/delete/upsert churn: multi-segment + tombstones in both
    sealed segments and the delta."""
    rng = np.random.default_rng(seed)
    added = [index.add(np.abs(rng.normal(size=dim)).astype(np.float32))
             for _ in range(25)]
    index.delete(list(range(0, 40, 3)) + added[::4])
    index.upsert(7, np.abs(rng.normal(size=dim)).astype(np.float32))
    return index


def _assert_bitwise_vs_fresh(index, q, spec, params):
    """Mutated-index results == fresh build of the live point set, bitwise."""
    gids, rows = index.live_points()
    fresh = build_index(jax.random.key(0), rows, spec)
    dm, im = map(np.asarray, index.search(q, params))
    df, i_f = map(np.asarray, fresh.search(q, params))
    # fresh ids are positions into the canonical live ordering -> map back
    i_f_g = np.where(i_f >= 0, gids[np.maximum(i_f, 0)], -1)
    assert np.array_equal(im, i_f_g), f"{im}\nvs\n{i_f_g}"
    assert np.array_equal(dm, df), "distances must be bitwise identical"


# ---------------------------------------------------------------------------
# acceptance: mutated index == fresh build, pre- and post-compaction
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", sorted(FULL_RECALL))
def test_mutated_index_bitwise_vs_fresh(corpus, backend):
    db, q = corpus
    spec, params = FULL_RECALL[backend]
    index = _mutate(build_index(jax.random.key(0), db, spec))
    if backend == "lsh-cascade":
        # the delta overlay is brute-forced (recall 1 by construction); the
        # hash-probed equivalence needs the adds sealed into a hashed segment
        index.flush()
    assert index.stats()["n_segments"] >= 1
    _assert_bitwise_vs_fresh(index, q, spec, params)      # pre-compaction
    gids_before, _ = index.live_points()
    index.compact()
    st = index.stats()
    assert st["n_segments"] == 1 and st["n_tombstones"] == 0
    gids_after, _ = index.live_points()
    assert np.array_equal(gids_before, gids_after)        # order preserved
    _assert_bitwise_vs_fresh(index, q, spec, params)      # post-compaction


def test_post_compaction_bitwise_any_config(corpus):
    """compact() rebuilds with the index's original key over the canonical
    live ordering, so post-compaction bitwise equality holds for ANY forest
    config — not just the full-recall regime."""
    db, q = corpus
    spec = IndexSpec(backend="rpf",
                     forest=ForestConfig(n_trees=10, capacity=8))
    index = _mutate(build_index(jax.random.key(0), db, spec))
    index.compact()
    _assert_bitwise_vs_fresh(index, q, spec, SearchParams(k=4))


# ---------------------------------------------------------------------------
# tombstone correctness vs the brute-force oracle (incl. compositions)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend,params", [
    ("rpf", SearchParams(k=5)),
    ("rpf", SearchParams(k=5, adaptive_wave=2, tol=1e-9)),
    ("rpf+int8", SearchParams(k=5, expand=128)),
    ("rpf+int8", SearchParams(k=5, expand=128, adaptive_wave=2, tol=1e-9)),
    ("lsh-cascade", SearchParams(k=5, min_candidates=10**9)),
    ("bruteforce", SearchParams(k=5)),
])
def test_delete_then_search_matches_bruteforce_oracle(corpus, backend,
                                                      params):
    db, q = corpus
    spec = FULL_RECALL[backend][0]
    index = build_index(jax.random.key(0), db, spec)
    deleted = list(range(0, 60, 2))
    index.delete(deleted)
    if backend == "lsh-cascade":
        index.flush()
    _, ids = index.search(q, params)
    ids = np.asarray(ids)
    assert not np.isin(ids, deleted).any(), "tombstoned id surfaced"
    # numpy brute-force oracle over the live rows only
    gids, rows = index.live_points()
    d = np.sum((q[:, None, :] - rows[None, :, :]) ** 2, axis=-1)
    oracle = gids[np.argsort(d, axis=1)[:, :params.k]]
    if backend == "lsh-cascade":
        # hashing bounds recall even with all levels probed: require only
        # that every result is live and most of the oracle is recovered
        assert np.isin(ids, gids).all()
        assert (ids == oracle).mean() > 0.5
    else:
        assert np.array_equal(ids, oracle)


def test_upsert_replaces_vector_and_keeps_id(corpus):
    db, q = corpus
    spec, params = FULL_RECALL["rpf"]
    index = build_index(jax.random.key(0), db, spec)
    new_vec = np.abs(np.full(DIM, 0.9, np.float32))
    index.upsert(3, new_vec)
    d, i = index.search(new_vec[None], SearchParams(k=1))
    assert int(np.asarray(i)[0, 0]) == 3
    assert float(np.asarray(d)[0, 0]) < 1e-9
    # the OLD vector for id 3 must be gone: searching near it no longer
    # returns id 3 (its nearest live neighbor is some other point)
    d, i = index.search(db[3][None], SearchParams(k=3))
    assert 3 not in np.asarray(i).ravel().tolist()
    # exactly one live row per id at all times
    gids, _ = index.live_points()
    assert np.unique(gids).size == gids.size


def test_delete_validation_is_atomic(corpus):
    db, _ = corpus
    index = build_index(jax.random.key(0), db, FULL_RECALL["rpf"][0])
    with pytest.raises(KeyError):
        index.delete([1, 2, 10**6])          # unknown id -> no mutation
    assert index.stats()["n_tombstones"] == 0
    with pytest.raises(KeyError):
        index.delete([3, 3])                 # duplicate in one batch
    assert index.stats()["n_tombstones"] == 0
    index.delete([1, 2])
    with pytest.raises(KeyError):
        index.delete(1)                      # double delete
    assert index.stats()["n_tombstones"] == 2
    # the published view stayed consistent through the rejected batches
    _, ids = index.search(db[:4], SearchParams(k=3))
    assert not np.isin(np.asarray(ids), [1, 2]).any()
    assert np.isin(3, np.asarray(index.live_points()[0]))


# ---------------------------------------------------------------------------
# snapshots: copy-on-write point-in-time reads
# ---------------------------------------------------------------------------


def test_snapshot_isolation(corpus):
    db, _ = corpus
    spec, params = FULL_RECALL["rpf"]
    index = build_index(jax.random.key(0), db, spec)
    snap = index.snapshot()
    d0, i0 = map(np.asarray, snap.search(db[5][None], params))
    index.delete(5)
    index.add(db[5] * 0.5)
    # the snapshot still answers from its frozen state — bitwise
    d1, i1 = map(np.asarray, snap.search(db[5][None], params))
    assert np.array_equal(i0, i1) and np.array_equal(d0, d1)
    assert int(i1[0, 0]) == 5
    # the live index sees the mutation
    _, i2 = index.search(db[5][None], params)
    assert 5 not in np.asarray(i2).ravel().tolist()


def test_stats_counters(corpus):
    db, _ = corpus
    spec = IndexSpec(backend="rpf",
                     forest=ForestConfig(n_trees=4, capacity=64),
                     delta_cap=8)
    index = build_index(jax.random.key(0), db, spec)
    for j in range(20):
        index.add(db[j] + 0.01)
    st = index.stats()
    assert st["n_seals"] == 2 and st["n_segments"] == 3    # 2 sealed deltas
    assert st["n_overflow"] == 20 - 16
    index.delete([0, 1, 2])
    st = index.stats()
    assert st["n_tombstones"] == 3 and st["n_deleted_total"] == 3
    assert st["n_live"] == N_DB + 20 - 3
    index.compact()
    st = index.stats()
    assert st["n_segments"] == 1 and st["n_compactions"] == 1
    assert st["n_tombstones"] == 0 and st["n_live"] == N_DB + 20 - 3


# ---------------------------------------------------------------------------
# non-blocking background compaction
# ---------------------------------------------------------------------------


def test_search_during_compaction_does_not_block(corpus, monkeypatch):
    db, q = corpus
    spec, params = FULL_RECALL["rpf"]
    index = _mutate(build_index(jax.random.key(0), db, spec))
    index.flush()
    d0, i0 = map(np.asarray, index.search(q, params))      # warm the jit

    import repro.index.backends as backends_mod
    real_build = backends_mod.build_forest
    build_started = threading.Event()

    def slow_build(*a, **kw):
        build_started.set()
        time.sleep(3.0)
        return real_build(*a, **kw)

    monkeypatch.setattr(backends_mod, "build_forest", slow_build)
    t = index.compact(block=False)
    assert build_started.wait(30), "compaction rebuild never started"
    assert index.stats()["compaction_in_progress"]
    # a search issued mid-rebuild must return promptly (it reads the
    # published view — never the writer lock, never the rebuild)
    t0 = time.perf_counter()
    d1, i1 = map(np.asarray, index.search(q, params))
    dt = time.perf_counter() - t0
    assert dt < 2.0, f"search blocked on the background rebuild ({dt:.2f}s)"
    assert np.array_equal(i0, i1) and np.array_equal(d0, d1)
    # mutations keep landing during the rebuild too
    gid = index.add(np.abs(np.full(DIM, 0.7, np.float32)))
    t.join(30)
    assert not index.stats()["compaction_in_progress"]
    # the racing add survived the swap and deletes were folded in
    _, i2 = index.search(np.full(DIM, 0.7, np.float32)[None],
                         SearchParams(k=1))
    assert int(np.asarray(i2)[0, 0]) == gid
    _assert_bitwise_vs_fresh(index, q, spec, params)


def test_delete_racing_compaction_is_folded_in(corpus):
    db, q = corpus
    spec, params = FULL_RECALL["rpf"]
    index = build_index(jax.random.key(0), db, spec)
    # run a real background compaction and delete while it is in flight
    t = index.compact(block=False)
    index.delete([11, 13])
    t.join(30)
    _, ids = index.search(q, params)
    assert not np.isin(np.asarray(ids), [11, 13]).any()
    st = index.stats()
    assert st["n_compactions"] == 1
    assert st["n_live"] == N_DB - 2


# ---------------------------------------------------------------------------
# save/load: mid-mutation bitwise roundtrip + format-1 read shim
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["rpf", "bruteforce"])
def test_mid_mutation_save_load_roundtrip_bitwise(corpus, backend, tmp_path):
    db, q = corpus
    spec, params = FULL_RECALL[backend]
    index = _mutate(build_index(jax.random.key(0), db, spec))
    path = os.path.join(tmp_path, "idx")
    index.save(path)                        # seals the delta, keeps segments
    d0, i0 = map(np.asarray, index.search(q, params))
    index2 = load_index(path)
    d1, i1 = map(np.asarray, index2.search(q, params))
    assert np.array_equal(i0, i1)
    assert np.array_equal(d0, d1)           # bitwise, not just allclose
    s0, s1 = index.stats(), index2.stats()
    assert s0["n_segments"] == s1["n_segments"] > 1
    assert s0["n_tombstones"] == s1["n_tombstones"] > 0
    assert s0["n_live"] == s1["n_live"]
    # the restored index keeps mutating: ids continue past the saved ones
    gid = index2.add(db[0] * 0.5)
    assert gid == index.add(db[0] * 0.5)


def test_v1_checkpoint_read_shim(corpus, tmp_path):
    """Checkpoints written by the pre-segment (format-1) code still load."""
    from repro.checkpoint.checkpointer import Checkpointer
    db, q = corpus
    spec, params = FULL_RECALL["rpf"]
    index = build_index(jax.random.key(0), db, spec)
    # emulate the PR-2 writer: flat {db, key_data, forest} + spec extra
    path = os.path.join(tmp_path, "v1_idx")
    Checkpointer(path, keep=1).save(
        0, {"db": index.db, "key_data": jax.random.key_data(index.key),
            "forest": index.forest},
        extra={"spec": spec.to_dict(), "backend": "rpf"})
    index2 = load_index(path)
    d0, i0 = map(np.asarray, index.search(q, params))
    d1, i1 = map(np.asarray, index2.search(q, params))
    assert np.array_equal(i0, i1) and np.array_equal(d0, d1)
    # and the shimmed index is fully mutable
    index2.delete(0)
    _, ids = index2.search(q, params)
    assert 0 not in np.asarray(ids).ravel().tolist()


# ---------------------------------------------------------------------------
# threaded add/delete/search/save stress
# ---------------------------------------------------------------------------


def test_threaded_mutation_stress(corpus, tmp_path):
    db, q = corpus
    spec = IndexSpec(backend="rpf",
                     forest=ForestConfig(n_trees=4, capacity=32),
                     delta_cap=16)
    index = build_index(jax.random.key(0), db, spec)
    errors: list = []
    stop = threading.Event()

    def writer(tid):
        try:
            rng = np.random.default_rng(tid)
            mine = []
            for j in range(30):
                mine.append(index.add(
                    np.abs(rng.normal(size=DIM)).astype(np.float32)))
                if j % 3 == 2:
                    index.delete(mine.pop(rng.integers(len(mine))))
                if j % 7 == 6:
                    index.upsert(mine[-1],
                                 np.abs(rng.normal(size=DIM)
                                        ).astype(np.float32))
        except Exception as e:                       # pragma: no cover
            errors.append(e)

    def reader():
        try:
            while not stop.is_set():
                d, i = index.search(q, SearchParams(k=3))
                assert np.asarray(i).shape == (len(q), 3)
        except Exception as e:                       # pragma: no cover
            errors.append(e)

    def saver():
        try:
            for j in range(2):
                index.save(os.path.join(tmp_path, f"stress_{j}"))
        except Exception as e:                       # pragma: no cover
            errors.append(e)

    writers = [threading.Thread(target=writer, args=(tid,))
               for tid in range(3)]
    readers = [threading.Thread(target=reader) for _ in range(2)]
    saver_t = threading.Thread(target=saver)
    for t in writers + readers + [saver_t]:
        t.start()
    for t in writers + [saver_t]:
        t.join(120)
    index.compact()
    stop.set()
    for t in readers:
        t.join(120)
    assert not errors, errors

    # post-churn invariants: directory, live set, and search agree
    st = index.stats()
    gids, rows = index.live_points()
    assert st["n_live"] == gids.shape[0]
    assert np.unique(gids).size == gids.size
    _, ids = index.search(q, SearchParams(k=5))
    live = set(gids.tolist())
    for g in np.asarray(ids).ravel().tolist():
        assert g == -1 or g in live
    # a save→load roundtrip after the churn is still bitwise
    path = os.path.join(tmp_path, "final")
    index.save(path)
    d0, i0 = map(np.asarray, index.search(q, SearchParams(k=5)))
    index2 = load_index(path)
    d1, i1 = map(np.asarray, index2.search(q, SearchParams(k=5)))
    assert np.array_equal(i0, i1) and np.array_equal(d0, d1)


# ---------------------------------------------------------------------------
# empty-index edge: everything deleted
# ---------------------------------------------------------------------------


def test_delete_everything_then_readd(corpus):
    db, _ = corpus
    small = db[:16]
    index = build_index(jax.random.key(0), small, FULL_RECALL["rpf"][0])
    index.delete(list(range(16)))
    d, i = index.search(small[:2], SearchParams(k=3))
    assert (np.asarray(i) == -1).all()
    assert np.isinf(np.asarray(d)).all()
    index.compact()
    assert index.stats()["n_segments"] == 0
    gid = index.add(small[0])
    _, i = index.search(small[:1], SearchParams(k=1))
    assert int(np.asarray(i)[0, 0]) == gid


# ---------------------------------------------------------------------------
# stale-tune gap (ISSUE 9): compact() after heavy churn must retune
# ---------------------------------------------------------------------------


def test_compact_retunes_stale_operating_point(corpus):
    """A tuned operating point is a statement about a specific corpus:
    after churn that removes >25% of the live rows, compact() must refresh
    it from the recorded tuning context (and count it in stats), and the
    refreshed point must still clear the original recall target on the
    post-churn live set.  Mild churn below the staleness threshold — and
    a compaction with no churn at all — must NOT retune."""
    from repro.index import tune

    db, q = corpus
    spec = IndexSpec(backend="rpf",
                     forest=ForestConfig(n_trees=4, capacity=16))
    index = build_index(jax.random.key(0), db, spec)
    tune(index, q, target_recall=0.9, k=5, probe_grid=(1, 2, 4),
         tree_fracs=(1.0,))
    assert index.stats()["n_retunes"] == 0

    index.compact()                      # no churn: not stale
    assert index.stats()["n_retunes"] == 0

    index.delete(list(range(0, 80)))     # 80/220 > 25% drift
    index.compact()
    assert index.stats()["n_retunes"] == 1
    assert index.tuned_params is not None

    # the refreshed default operating point answers the ORIGINAL target
    # on the post-churn live set (the regression: it used to keep the
    # pre-churn point)
    gids, rows = index.live_points()
    from repro.core.knn import exact_knn
    _, pos = exact_knn(q, rows, k=5)
    true_ids = np.asarray(gids)[np.asarray(pos)]
    _, ids = index.search(q)             # bare search -> tuned_params
    hits = (np.asarray(ids)[:, :, None] == true_ids[:, None, :]).any(1)
    assert hits.mean() >= 0.9

    index.delete(list(range(80, 90)))    # 10/140 < 25% drift
    index.compact()
    assert index.stats()["n_retunes"] == 1

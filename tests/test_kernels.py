"""Pallas kernels vs jnp oracles: shape/dtype sweeps in interpret mode.

Tie-breaking note: both kernel and ref break distance ties by smaller id, so
ids are compared exactly; distances with assert_allclose.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _rand(shape, dtype):
    x = RNG.normal(size=shape).astype(np.float32)
    return jnp.asarray(x).astype(dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


@pytest.mark.parametrize("b,n,d", [(8, 128, 32), (50, 700, 96), (3, 1030, 15)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("metric", ["l2", "dot"])
def test_matmul_topk_sweep(b, n, d, dtype, metric):
    q, db = _rand((b, d), dtype), _rand((n, d), dtype)
    k = 7
    pd, pi = ops.topk(q, db, k, metric=metric, mode="pallas")
    rd, ri = ops.topk(q, db, k, metric=metric, mode="ref")
    np.testing.assert_allclose(np.asarray(pd), np.asarray(rd), **TOL[dtype])
    if dtype == jnp.float32:
        assert (np.asarray(pi) == np.asarray(ri)).mean() > 0.98


@pytest.mark.parametrize("b,n,d", [(8, 128, 32), (16, 500, 64)])
def test_chi2_topk_sweep(b, n, d):
    q, db = jnp.abs(_rand((b, d), jnp.float32)), jnp.abs(_rand((n, d),
                                                              jnp.float32))
    pd, pi = ops.topk(q, db, 5, metric="chi2", mode="pallas")
    rd, ri = ops.topk(q, db, 5, metric="chi2", mode="ref")
    np.testing.assert_allclose(np.asarray(pd), np.asarray(rd), rtol=2e-5,
                               atol=2e-5)
    assert (np.asarray(pi) == np.asarray(ri)).mean() > 0.98


@pytest.mark.parametrize("b,m,d", [(4, 24, 16), (10, 96, 48)])
@pytest.mark.parametrize("metric", ["l2", "chi2"])
def test_distance_topk_sweep(b, m, d, metric):
    q = jnp.abs(_rand((b, d), jnp.float32))
    db = jnp.abs(_rand((200, d), jnp.float32))
    ids = jnp.asarray(RNG.integers(0, 200, size=(b, m)).astype(np.int32))
    mask = jnp.asarray(RNG.uniform(size=(b, m)) < 0.85)
    cand = db[ids]
    pd, pi = ops.rerank_candidates(q, cand, ids, mask, 5, metric=metric,
                                   mode="pallas")
    rd, ri = ops.rerank_candidates(q, cand, ids, mask, 5, metric=metric,
                                   mode="ref")
    np.testing.assert_allclose(np.asarray(pd), np.asarray(rd), rtol=2e-5,
                               atol=2e-5)


def test_distance_topk_all_masked_row():
    q = _rand((2, 8), jnp.float32)
    cand = _rand((2, 6, 8), jnp.float32)
    ids = jnp.zeros((2, 6), jnp.int32)
    mask = jnp.zeros((2, 6), bool)
    pd, pi = ops.rerank_candidates(q, cand, ids, mask, 3, mode="pallas")
    assert np.isinf(np.asarray(pd)).all()
    assert (np.asarray(pi) == -1).all()


@pytest.mark.parametrize("b,h,v,d", [(4, 3, 50, 16), (9, 7, 211, 33)])
def test_embedding_bag_sweep(b, h, v, d):
    tab = _rand((v, d), jnp.float32)
    ids = jnp.asarray(RNG.integers(0, v, size=(b, h)).astype(np.int32))
    w = jnp.asarray((RNG.uniform(size=(b, h)) < 0.8).astype(np.float32))
    pb = ops.embedding_bag(ids, w, tab, mode="pallas")
    rb = ops.embedding_bag(ids, w, tab, mode="ref")
    np.testing.assert_allclose(np.asarray(pb), np.asarray(rb), rtol=1e-5,
                               atol=1e-5)


def test_forest_traverse_kernel_matches_ref():
    from repro.core import ForestConfig, build_forest
    from repro.data.synthetic import clustered_gaussians
    x = jnp.asarray(clustered_gaussians(1000, 16, seed=7))
    cfg = ForestConfig(n_trees=3)
    rcfg = cfg.resolved(1000)
    f = build_forest(jax.random.key(0), x, cfg)
    q = x[:40]
    for t in range(3):
        lp = ops.traverse_tree(f.proj_idx[t, :, 0], f.thresh[t],
                               f.child_base[t], q, rcfg.max_depth,
                               mode="pallas")
        lr = ops.traverse_tree(f.proj_idx[t, :, 0], f.thresh[t],
                               f.child_base[t], q, rcfg.max_depth, mode="ref")
        assert (np.asarray(lp) == np.asarray(lr)).all()


def test_topk_k_larger_than_block():
    """k spanning several blocks exercises the running-merge path."""
    q, db = _rand((4, 16), jnp.float32), _rand((300, 16), jnp.float32)
    pd, pi = ops.topk(q, db, 20, mode="pallas")
    rd, ri = ops.topk(q, db, 20, mode="ref")
    np.testing.assert_allclose(np.asarray(pd), np.asarray(rd), rtol=2e-5,
                               atol=2e-5)
    assert (np.asarray(pi) == np.asarray(ri)).mean() > 0.98

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.optimizer import (adafactor, adamw, apply_updates,
                                   clip_by_global_norm, cosine_schedule,
                                   global_norm, sgdm)


def _quadratic_descent(opt, steps=60):
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3), "b": jnp.ones((2, 3))}

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2) + jnp.sum(p["b"] ** 2)

    state = opt.init(params)
    losses = []
    for _ in range(steps):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
        losses.append(float(loss(params)))
    return losses


def test_adamw_descends():
    losses = _quadratic_descent(adamw(cosine_schedule(0.1, 5, 60),
                                      weight_decay=0.0))
    assert losses[-1] < 0.05 * losses[0]


def test_adafactor_descends():
    losses = _quadratic_descent(adafactor(cosine_schedule(0.5, 5, 60)))
    assert losses[-1] < 0.2 * losses[0]


def test_sgdm_descends():
    losses = _quadratic_descent(sgdm(lambda s: 0.05))
    assert losses[-1] < 0.1 * losses[0]


def test_adamw_state_dtype():
    opt = adamw(lambda s: 1e-3, state_dtype=jnp.bfloat16)
    params = {"w": jnp.zeros((4, 4))}
    state = opt.init(params)
    assert state.m["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((4, 4))}
    upd, state = opt.update(g, state, params)
    assert state.v["w"].dtype == jnp.bfloat16
    assert np.isfinite(np.asarray(upd["w"])).all()


def test_global_norm_clip():
    tree = {"a": jnp.ones(4) * 3.0, "b": jnp.ones(9) * 4.0}
    n = float(global_norm(tree))
    np.testing.assert_allclose(n, np.sqrt(4 * 9 + 9 * 16), rtol=1e-6)
    clipped, norm = clip_by_global_norm(tree, 1.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    # below the bound -> untouched
    same, _ = clip_by_global_norm(tree, 1e6)
    np.testing.assert_allclose(np.asarray(same["a"]), 3.0)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=110, final_frac=0.1)
    assert float(lr(0)) == 0.0
    np.testing.assert_allclose(float(lr(10)), 1.0, rtol=1e-5)
    assert 0.09 < float(lr(110)) < 0.11
    assert float(lr(60)) < float(lr(20))


def test_adafactor_memory_is_factored():
    opt = adafactor(lambda s: 1e-3)
    params = {"w": jnp.zeros((128, 64))}
    state = opt.init(params)
    assert state.vr["w"].shape == (128,)
    assert state.vc["w"].shape == (64,)

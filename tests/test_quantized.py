import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ForestConfig, exact_knn, query_forest, recall_at_k
from repro.core.quantized import quantize_db, query_forest_quantized
from repro.data.synthetic import clustered_gaussians


def test_quantized_recall_matches_fp32(shared_builds):
    db = shared_builds.clustered_db(4000, 32, n_clusters=16, seed=2)
    q = db[:96] + 0.01
    cfg = ForestConfig(n_trees=16, capacity=12)
    forest, _ = shared_builds.forest(0, cfg, db)
    qdb = quantize_db(db)

    d_fp, i_fp = query_forest(forest, q, db, k=5, cfg=cfg)
    d_q, i_q = query_forest_quantized(forest, q, qdb, k=5, cfg=cfg, expand=4)
    _, true_ids = exact_knn(q, db, k=5)
    r_fp = float(recall_at_k(i_fp, true_ids))
    r_q = float(recall_at_k(i_q, true_ids))
    assert r_q > r_fp - 0.03, (r_q, r_fp)
    # final distances are exact fp32 values
    same = np.asarray(i_q[:, 0]) == np.asarray(i_fp[:, 0])
    np.testing.assert_allclose(np.asarray(d_q[:, 0])[same],
                               np.asarray(d_fp[:, 0])[same], rtol=1e-4,
                               atol=1e-5)


def test_quantize_roundtrip_error_bounded():
    db = jnp.asarray(clustered_gaussians(500, 16, seed=3))
    qdb = quantize_db(db)
    deq = qdb.q.astype(jnp.float32) * qdb.scale[:, None]
    rel = np.abs(np.asarray(deq - db)) / (np.abs(np.asarray(db)) + 1e-6)
    # int8 per-row quantization: max error ~ scale/2 per element
    max_abs = np.abs(np.asarray(db)).max(axis=1)
    err = np.abs(np.asarray(deq - db))
    assert (err <= (max_abs[:, None] / 127.0) * 0.51 + 1e-6).all()

"""Filtered search subsystem (DESIGN.md §13): predicate AST, metadata
store, validity-path enforcement, manifest v5 persistence.

The contract under test everywhere: a filtered search returns exactly (or,
on the widened approximate path, nearly) the brute-force top-k over the
LIVE rows matching the predicate — never a non-matching or dead row.
"""
import dataclasses
import glob
import json
import os

import jax
import numpy as np
import pytest

from repro.core.distances import PAIRWISE
from repro.core.forest import ForestConfig
from repro.filter import And, Eq, In, Not, Or, Range, from_dict
from repro.filter.metadata import MetaBlock, MetadataStore
from repro.filter.predicate import use_brute_force, widen_params
from repro.index import IndexSpec, SearchParams, build_index, load_index

SEED = 0
LSH_RADII = (0.5, 1.0, 2.0)
BACKENDS = ["bruteforce", "rpf", "rpf+int8", "lsh-cascade"]


def _spec(backend):
    return IndexSpec(backend=backend,
                     forest=ForestConfig(n_trees=10, capacity=16),
                     lsh_radii=LSH_RADII, lsh_tables=8, lsh_bits=8, seed=0)


def _corpus(n=600, d=16, seed=SEED):
    from repro.data.synthetic import clustered_gaussians
    db = np.abs(clustered_gaussians(n, d, n_clusters=12, seed=seed))
    db /= np.linalg.norm(db, axis=1, keepdims=True)
    rng = np.random.default_rng(seed + 1)
    q = np.abs(db[:8] + 0.003 * rng.normal(size=(8, d)).astype(np.float32))
    meta = {
        "shop": np.array([f"s{i % 5}" for i in range(n)]),
        "price": (np.arange(n) * 7 % 100).astype(np.int64),
        "ts": np.int64(1_700_000_000_000_000_000) + np.arange(n),
    }
    return db, q, meta


def _match_mask(meta, pred):
    """Numpy oracle for predicate matching on raw (unencoded) metadata."""
    if isinstance(pred, Eq):
        return meta[pred.column] == pred.value
    if isinstance(pred, In):
        return np.isin(meta[pred.column], list(pred.values))
    if isinstance(pred, Range):
        col = meta[pred.column]
        out = np.ones(len(col), bool)
        if pred.lo is not None:
            out &= col >= pred.lo
        if pred.hi is not None:
            out &= col <= pred.hi
        return out
    if isinstance(pred, And):
        out = np.ones(len(next(iter(meta.values()))), bool)
        for c in pred.children:
            out &= _match_mask(meta, c)
        return out
    if isinstance(pred, Or):
        out = np.zeros(len(next(iter(meta.values()))), bool)
        for c in pred.children:
            out |= _match_mask(meta, c)
        return out
    if isinstance(pred, Not):
        return ~_match_mask(meta, pred.child)
    raise TypeError(pred)


def _oracle(q, rows, gids, metric, k):
    """Exact top-k (gids) over the given rows under the metric."""
    if len(rows) == 0:
        return [set() for _ in range(len(q))]
    d = np.asarray(PAIRWISE[metric](jax.numpy.asarray(q),
                                    jax.numpy.asarray(rows)))
    out = []
    for row in d:
        order = np.lexsort((gids, row))
        out.append(set(gids[order[:k]].tolist()))
    return out


# ---------------------------------------------------------------------------
# predicate AST
# ---------------------------------------------------------------------------


def test_predicate_roundtrip_and_validation():
    p = And(Or(Eq("shop", "s1"), In("price", [3, 5, 7])),
            Not(Range("ts", 10, None)))
    assert from_dict(p.to_dict()) == p
    assert p.columns() == {"shop", "price", "ts"}
    assert In("price", [5, 3, 3]).values == (5, 3, 3)
    with pytest.raises(TypeError):
        And()          # no children
    with pytest.raises(ValueError):
        Range("ts", None, None)   # unbounded both sides
    with pytest.raises(ValueError):
        from_dict({"op": "between", "column": "ts"})


def test_range_on_categorical_rejected():
    store, block = MetadataStore.from_arrays(
        {"shop": np.array(["a", "b"])}, 2)
    with pytest.raises(ValueError, match="categorical"):
        Range("shop", "a", "b").evaluate(block, store)


def test_unseen_categorical_matches_nothing():
    store, block = MetadataStore.from_arrays(
        {"shop": np.array(["a", "b", "a"])}, 3)
    assert not block.match(Eq("shop", "zzz"), store).any()
    assert block.match(Eq("shop", "a"), store).tolist() == [True, False, True]


def test_metablock_concat_take():
    a = MetaBlock({"x": np.arange(4, dtype=np.int64)})
    b = MetaBlock({"x": np.arange(10, 14, dtype=np.int64)})
    cat = MetaBlock.concat([a, b])
    assert cat.column("x").tolist() == [0, 1, 2, 3, 10, 11, 12, 13]
    assert cat.take(np.array([1, 5])).column("x").tolist() == [1, 11]


# ---------------------------------------------------------------------------
# selectivity-aware plan
# ---------------------------------------------------------------------------


def test_use_brute_force_thresholds():
    assert use_brute_force(0.01, 100_000)       # selective enough
    assert use_brute_force(0.5, 1000)           # tiny match set
    assert not use_brute_force(0.5, 100_000)    # broad filter, big set


def test_widen_params_scales_with_selectivity():
    p = SearchParams(k=10, n_probes=2, min_candidates=8, n_trees=4)
    w = widen_params(p, 0.25)
    assert w.n_probes == 4                       # 2 / sqrt(0.25)
    assert w.min_candidates >= 2 * 10 / 0.25
    assert w.n_trees == 0                        # full forest under filter
    assert w.filter is p.filter
    assert widen_params(p, 1e-9).n_probes <= 16  # capped


# ---------------------------------------------------------------------------
# filtered search == brute force over matching live rows (all backends)
# ---------------------------------------------------------------------------


PREDICATES = [
    Eq("shop", "s2"),
    And(In("shop", ["s0", "s3"]), Range("price", 20, 60)),
    Or(Eq("price", 7), Eq("price", 14)),
    Not(Eq("shop", "s1")),
    Range("ts", 1_700_000_000_000_000_100, 1_700_000_000_000_000_400),
]


@pytest.mark.parametrize("backend", BACKENDS)
def test_filtered_search_matches_oracle(backend):
    db, q, meta = _corpus()
    idx = build_index(jax.random.key(SEED), db, _spec(backend),
                      metadata=meta)
    for pred in PREDICATES:
        for metric in ("l2", "cosine"):
            p = SearchParams(k=5, metric=metric, filter=pred,
                             min_candidates=64)
            d, ids = map(np.asarray, idx.search(q, p))
            mask = _match_mask(meta, pred)
            want = _oracle(q, db[mask], np.where(mask)[0], metric, 5)
            for r, got_row in enumerate(ids):
                got = set(int(g) for g in got_row if g >= 0)
                # small corpora ride the exact brute path: full equality
                assert got == want[r], \
                    f"{backend}/{metric}/{pred}: {got} vs {want[r]}"
            assert (d[ids < 0] == np.inf).all()


def test_filtered_search_widened_path_recall():
    """Above the brute-force thresholds the widened approximate path must
    still deliver high recall vs the filtered oracle."""
    from repro.data.synthetic import clustered_gaussians
    n = 12_000
    db = clustered_gaussians(n, 16, n_clusters=32, seed=3)
    meta = {"bucket": (np.arange(n) % 2).astype(np.int64)}
    rng = np.random.default_rng(4)
    q = db[rng.integers(0, n, 16)] + 0.003
    idx = build_index(jax.random.key(SEED), db,
                      _spec("rpf"), metadata=meta)
    pred = Eq("bucket", 1)                      # selectivity 0.5, 6k rows
    assert not use_brute_force(0.5, n // 2)     # really the widened path
    base = SearchParams(k=10, n_probes=4)       # a solid operating point
    d, ids = map(np.asarray, idx.search(
        q, dataclasses.replace(base, filter=pred)))
    mask = _match_mask(meta, pred)
    assert (np.asarray(ids) % 2 == 1).all()     # only matching rows surface
    want = _oracle(q, db[mask], np.where(mask)[0], "l2", 10)
    hit = np.mean([len(set(r[r >= 0].tolist()) & want[i]) / 10
                   for i, r in enumerate(ids)])
    assert hit >= 0.9, f"widened-path recall {hit:.2f} < 0.9"
    # and widening COMPENSATES: recall under filter >= unfiltered recall
    # of the same base point vs its own (unfiltered) oracle
    du, iu = map(np.asarray, idx.search(q, base))
    want_u = _oracle(q, db, np.arange(n), "l2", 10)
    hit_u = np.mean([len(set(r[r >= 0].tolist()) & want_u[i]) / 10
                     for i, r in enumerate(iu)])
    assert hit >= hit_u - 0.05, f"filter lost recall: {hit} vs {hit_u}"


def test_empty_match_returns_empty():
    db, q, meta = _corpus()
    idx = build_index(jax.random.key(SEED), db, _spec("bruteforce"),
                      metadata=meta)
    d, ids = map(np.asarray, idx.search(q, SearchParams(
        k=5, filter=Eq("shop", "nope"))))
    assert (ids == -1).all() and np.isinf(d).all()


# ---------------------------------------------------------------------------
# randomized sweep: ANY data / predicate tree / deletion set, every backend
# (the hypothesis-driven generalization lives in test_filter_property.py;
# this deterministic sweep keeps the invariant exercised when hypothesis
# is absent)
# ---------------------------------------------------------------------------


def random_predicate(rng, depth=2):
    roll = rng.integers(0, 6 if depth > 0 else 3)
    if roll == 0:
        return Eq("cat", rng.choice(["a", "b", "c", "zzz"]))
    if roll == 1:
        return In("price", tuple(rng.integers(0, 31, rng.integers(1, 4))))
    if roll == 2:
        lo = int(rng.integers(0, 16))
        return Range("price", lo, int(rng.integers(lo, 31)))
    kids = [random_predicate(rng, depth - 1) for _ in range(2)]
    if roll == 3:
        return And(*kids)
    if roll == 4:
        return Or(*kids)
    return Not(kids[0])


@pytest.mark.parametrize("backend", BACKENDS)
def test_filtered_search_random_sweep(backend):
    """For varied corpora, predicate trees and deletion sets: filtered
    search == brute force over the matching LIVE rows."""
    for trial in range(4):
        rng = np.random.default_rng(1000 * trial + BACKENDS.index(backend))
        n = int(rng.integers(60, 250))
        db = np.abs(rng.normal(size=(n, 8)).astype(np.float32)) + 1e-3
        db /= np.linalg.norm(db, axis=1, keepdims=True)
        meta = {"cat": rng.choice(["a", "b", "c"], n),
                "price": rng.integers(0, 31, n).astype(np.int64)}
        idx = build_index(jax.random.key(trial), db, _spec(backend),
                          metadata=meta)
        dead = rng.choice(n, size=int(rng.integers(0, 20)), replace=False)
        for g in dead:
            idx.delete(int(g))
        pred = random_predicate(rng)
        q = db[rng.integers(0, n, 4)] + 0.001
        d, ids = map(np.asarray, idx.search(q, SearchParams(
            k=5, filter=pred, min_candidates=64)))
        mask = _match_mask(meta, pred)
        mask[dead] = False
        want = _oracle(q, db[mask], np.where(mask)[0], "l2", 5)
        for r, got_row in enumerate(ids):
            got = set(int(g) for g in got_row if g >= 0)
            assert got == want[r], f"trial {trial} pred={pred}"


# ---------------------------------------------------------------------------
# mutation lifecycle: add/upsert/delete/flush/compact with metadata
# ---------------------------------------------------------------------------


def test_metadata_survives_mutation_lifecycle():
    db, q, meta = _corpus(n=300)
    idx = build_index(jax.random.key(SEED), db, _spec("rpf"), metadata=meta)
    pred = Eq("shop", "s9")                      # only new rows match
    rng = np.random.default_rng(7)
    new_gids = []
    for i in range(40):
        v = np.abs(rng.normal(size=16).astype(np.float32))
        v /= np.linalg.norm(v)
        g = idx.add(v, metadata={"shop": "s9", "price": 1000 + i,
                                 "ts": 2_000_000_000_000_000_000 + i})
        new_gids.append(g)
    idx.delete(new_gids[0])
    idx.upsert(new_gids[1], np.abs(db[0]),
               metadata={"shop": "s9", "price": 5000,
                         "ts": 2_100_000_000_000_000_000})
    for stage in ("delta", "flushed", "compacted"):
        d, ids = map(np.asarray, idx.search(q, SearchParams(k=50,
                                                            filter=pred)))
        got = set(ids[ids >= 0].tolist())
        assert got == set(new_gids[1:]), f"stage={stage}: {got}"
        if stage == "delta":
            idx.flush()
        elif stage == "flushed":
            idx.compact()
    # price update via upsert is visible
    d, ids = map(np.asarray, idx.search(q, SearchParams(
        k=10, filter=Range("price", 4000, 6000))))
    assert set(ids[ids >= 0].tolist()) == {new_gids[1]}


def test_add_without_metadata_on_meta_index_raises():
    db, q, meta = _corpus(n=100)
    idx = build_index(jax.random.key(SEED), db, _spec("rpf"), metadata=meta)
    with pytest.raises(ValueError, match="metadata"):
        idx.add(db[0])
    # and a filter on a metadata-less index is a clear error, not a KeyError
    bare = build_index(jax.random.key(SEED), db, _spec("rpf"))
    with pytest.raises(ValueError, match="no metadata"):
        bare.search(q, SearchParams(k=5, filter=Eq("shop", "s0")))


# ---------------------------------------------------------------------------
# capability surface: the ONE violations() definition
# ---------------------------------------------------------------------------


def test_violations_surface():
    p = SearchParams(k=5, metric="bogus")
    assert any("metric" in v for v in p.violations())
    db, q, meta = _corpus(n=100)
    idx = build_index(jax.random.key(SEED), db, _spec("rpf"), metadata=meta)
    with pytest.raises(ValueError, match="metric"):
        idx.search(q, p)
    bad_filter = SearchParams(k=5, filter="price > 3")
    assert any("Predicate" in v for v in bad_filter.violations())
    with pytest.raises(ValueError, match="Predicate"):
        idx.search(q, bad_filter)
    # sharded: the capability matrix makes filters sharded-LEGAL (host
    # bitmap on the validity path), so the projection must NOT strip the
    # predicate — silently dropping it would answer unfiltered results
    fp = SearchParams(k=5, filter=Eq("shop", "s0"))
    assert fp.sharded_violations() == []
    assert fp.violations() == []
    assert fp.sharded().filter is fp.filter
    # knobs the mesh genuinely cannot serve still project away
    wavy = SearchParams(k=5, adaptive_wave=8)
    assert any("adaptive_wave" in v for v in wavy.sharded_violations())
    assert wavy.sharded().adaptive_wave == 0


def test_serving_runtime_consults_violations():
    from repro.serve.runtime import ServingRuntime
    db, q, meta = _corpus(n=200)
    idx = build_index(jax.random.key(SEED), db, _spec("rpf"), metadata=meta)
    with pytest.raises(ValueError, match="metric"):
        ServingRuntime(idx, params=SearchParams(k=5, metric="bogus"),
                       warmup=False)
    # a filter is fine on the host-local runtime
    rt = ServingRuntime(idx, params=SearchParams(
        k=5, filter=Eq("shop", "s0")), warmup=False)
    try:
        d, ids = rt(q[0])
        shop = meta["shop"]
        assert all(shop[g] == "s0" for g in np.asarray(ids) if g >= 0)
    finally:
        rt.stop()


# ---------------------------------------------------------------------------
# manifest v5 + read shims
# ---------------------------------------------------------------------------


def _manifest_path(root):
    return glob.glob(os.path.join(root, "step_*", "manifest.json"))[0]


def test_manifest_v5_roundtrip_with_metadata(tmp_path):
    db, q, meta = _corpus(n=250)
    idx = build_index(jax.random.key(SEED), db, _spec("rpf"), metadata=meta)
    idx.delete(3)
    idx.add(np.abs(db[1]), metadata={"shop": "s1", "price": 12,
                                     "ts": 2_000_000_000_000_000_000})
    pred = And(Eq("shop", "s1"), Range("price", 0, 50))
    p = SearchParams(k=5, filter=pred)
    d0, i0 = map(np.asarray, idx.search(q, p))
    path = str(tmp_path / "v5")
    idx.save(path)
    with open(_manifest_path(path)) as fh:
        man = json.load(fh)
    assert man["extra"]["format"] == 5
    assert set(man["extra"]["meta_schema"]["columns"]) == set(meta)

    loaded = load_index(path)
    d1, i1 = map(np.asarray, loaded.search(q, p))
    assert np.array_equal(i0, i1) and np.array_equal(d0, d1)   # bitwise
    # int64 timestamp columns survive losslessly (no 32-bit truncation)
    seg = loaded._view.segments[0]
    assert seg.meta.column("ts").dtype == np.int64
    assert int(seg.meta.column("ts").max()) >= 1_700_000_000_000_000_000
    # tuned filter params survive via to_dict/from_dict
    assert SearchParams.from_dict(p.to_dict()) == p


def test_manifest_v4_shim_drops_metadata(tmp_path):
    """A manifest rewritten as a v4 writer would have produced it (no
    meta_schema, no meta leaves in the skeleton) still loads and serves;
    filtered search then fails with the no-metadata error."""
    db, q, meta = _corpus(n=200)
    idx = build_index(jax.random.key(SEED), db, _spec("rpf"), metadata=meta)
    d0, i0 = map(np.asarray, idx.search(q))
    path = str(tmp_path / "v4shim")
    idx.save(path)
    mp = _manifest_path(path)
    with open(mp) as fh:
        man = json.load(fh)
    man["extra"]["format"] = 4
    man["extra"].pop("meta_schema")
    with open(mp, "w") as fh:
        json.dump(man, fh)
    legacy = load_index(path)
    d1, i1 = map(np.asarray, legacy.search(q))
    assert np.array_equal(i0, i1) and np.array_equal(d0, d1)
    with pytest.raises(ValueError, match="no metadata"):
        legacy.search(q, SearchParams(k=5, filter=Eq("shop", "s0")))

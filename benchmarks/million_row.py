"""Million-row all-Pallas serving gate: 1M x 784 int8 index, zero fallback.

The PR-6 tentpole claim is that the full query path — tree descent, int8
coarse shortlist, fp32 rerank — stays inside Pallas kernels at a scale
where the old dispatch could not: with ~1M rows the per-tree node
allocation passes the 64k SMEM node cap, which used to force
``ops.traverse_tree`` back to jnp, and the int8 coarse stage used to BE a
jnp dequant-gather.  This benchmark builds a 1M x 784 clustered corpus,
serves it through ``pipeline.fused_query`` with a ``QuantizedDB``, and
checks four things:

  * it builds and serves at all (``build_s``, query ``p50_ms``/``p99_ms``
    — timed in mode="auto": the jnp oracle on CPU runners, the kernels on
    TPU; latency history is same-machine so runner speed cancels),
  * zero jnp fallback in the traced mode="pallas" program: the jaxpr holds
    one pallas_call per stage (descent + int8 coarse + fp32 rerank, >= 3)
    and no (B, M, d)-sized gather — the same inspection
    tests/test_index_api.py runs at unit scale,
  * the MEASURED candidate-bytes ratio: valid (deduped) candidate slots
    counted from the actual mask, int8 bytes = valid*(d+4) + B*k'*4d
    (coarse rows + scales, then the fp32 shortlist) vs fp32 bytes =
    valid*4d; gated at <= 0.30 (tools/bench_history.py, lower-is-better),
  * kernel parity on a query subsample, interpret mode: the HBM descent
    kernel bitwise-matches the multiprobe ref (and the SMEM kernel when
    the tree fits under the cap; probe 0 matches the single-probe ref),
    and the int8 kernel's ids match its oracle — ``bitwise_equal`` is a
    hard CI gate.

Usage:
  PYTHONPATH=src python -m benchmarks.million_row [--smoke]

--smoke keeps N = 1M (the point of the gate) and trims query iterations.
Writes artifacts/BENCH_million_row.json (uploaded + gated by CI
bench-smoke) and merges into artifacts/bench_results.json.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import record
from repro.core import ForestConfig, build_forest
from repro.core.forest import gather_candidates_multi, traverse_forest
from repro.core.pipeline import fused_query
from repro.core.quantized import quantize_db
from repro.core.search import mask_duplicates
from repro.data.synthetic import clustered_gaussians
from repro.kernels import ref
from repro.kernels.forest_traverse import SMEM_NODE_CAP, forest_traverse
from repro.kernels.forest_traverse_hbm import forest_traverse_hbm
from repro.kernels.fused_query_int8 import fused_gather_topk_int8

ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                        "BENCH_million_row.json")


def _walk_jaxpr(jaxpr, fn):
    for eqn in jaxpr.eqns:
        fn(eqn)
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (list, tuple)) else [v]):
                if hasattr(sub, "eqns"):
                    _walk_jaxpr(sub, fn)
                elif hasattr(sub, "jaxpr"):
                    _walk_jaxpr(sub.jaxpr, fn)


def _inspect(jaxpr) -> tuple[int, int]:
    """-> (pallas_call count, largest gather output in elements)."""
    n_pallas, worst = 0, 0

    def see(eqn):
        nonlocal n_pallas, worst
        if eqn.primitive.name == "pallas_call":
            n_pallas += 1
        if eqn.primitive.name == "gather":
            for ov in eqn.outvars:
                worst = max(worst, int(np.prod(ov.aval.shape)))

    _walk_jaxpr(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr, see)
    return n_pallas, worst


def _traversal_parity(forest, rcfg, q, n_probes: int) -> bool:
    """HBM kernel == multiprobe ref per tree (bitwise), probe 0 == the
    single-probe ref, and == the SMEM kernel where that kernel is legal."""
    feat = forest.proj_idx[:, :, 0]
    hbm = np.asarray(forest_traverse_hbm(
        feat, forest.thresh, forest.child_base, q, rcfg.max_depth,
        interpret=True, n_probes=n_probes))
    ok = True
    for t in range(forest.n_trees):
        args = (feat[t], forest.thresh[t], forest.child_base[t], q,
                rcfg.max_depth)
        want = np.asarray(ref.forest_traverse_multiprobe_ref(*args, n_probes))
        ok &= bool((hbm[t] == want).all())
        single = np.asarray(ref.forest_traverse_ref(*args))
        ok &= bool((hbm[t, :, 0] == single).all())
        if forest.max_nodes <= SMEM_NODE_CAP:
            smem = np.asarray(forest_traverse(*args, interpret=True,
                                              n_probes=n_probes))
            ok &= bool((hbm[t] == smem).all())
    return ok


def _int8_parity(qdb, q, seed: int = 0) -> bool:
    """Pallas int8 kernel ids == the jnp dequant-gather oracle on a
    candidate subsample drawn from the full 1M-row table."""
    rng = np.random.default_rng(seed)
    n = qdb.q.shape[0]
    ids = rng.integers(0, n, size=(q.shape[0], 128)).astype(np.int32)
    ids[rng.uniform(size=ids.shape) < 0.1] = -1
    ids = jnp.asarray(ids)
    pd, pi = fused_gather_topk_int8(q, ids, qdb.q, qdb.scale, 10,
                                    interpret=True)
    rd, ri = ref.fused_gather_topk_int8_ref(q, ids, qdb.q, qdb.scale, 10)
    ids_ok = bool((np.asarray(pi) == np.asarray(ri)).all())
    d_ok = bool(np.allclose(np.asarray(pd), np.asarray(rd), rtol=2e-5,
                            atol=2e-5, equal_nan=True))
    return ids_ok and d_ok


def run(n: int, d: int, n_trees: int, capacity: int, n_probes: int, b: int,
        k: int, expand: int, iters: int, parity_b: int) -> dict:
    x = jnp.asarray(clustered_gaussians(n, d, n_clusters=1024, seed=0))
    queries = jnp.asarray(clustered_gaussians(b, d, n_clusters=1024, seed=1))
    cfg = ForestConfig(n_trees=n_trees, capacity=capacity, split_ratio=0.3)
    rcfg = cfg.resolved(n)
    print(f"  corpus: clustered n={n} d={d} L={n_trees} C={capacity} "
          f"P={n_probes} nodes={rcfg.max_nodes} "
          f"(smem_cap={SMEM_NODE_CAP}) depth={rcfg.max_depth}")

    t0 = time.perf_counter()
    forest = jax.block_until_ready(build_forest(jax.random.key(0), x, cfg))
    build_s = time.perf_counter() - t0
    qdb = quantize_db(x)

    # --- serving latency (mode="auto": what this runner actually executes)
    def serve(q):
        return fused_query(forest, q, qdb, k, cfg, n_probes=n_probes)

    jax.block_until_ready(serve(queries))          # compile
    lat = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(serve(queries))
        lat.append(time.perf_counter() - t0)
    p50_ms = float(np.percentile(lat, 50) * 1e3)
    p99_ms = float(np.percentile(lat, 99) * 1e3)

    # --- measured candidate bytes: count the VALID deduped slots the rerank
    # actually scores, from the same traversal the pipeline runs
    leaves = traverse_forest(forest, queries, rcfg.max_depth, n_probes)
    cand_ids, mask = gather_candidates_multi(forest, leaves, rcfg.leaf_pad)
    valid = int(np.asarray(mask_duplicates(cand_ids, mask)).sum())
    m = int(cand_ids.shape[1])
    kp = min(expand * k, m)
    int8_bytes = valid * (d + 4) + b * kp * 4 * d
    fp32_bytes = valid * 4 * d
    bytes_ratio = int8_bytes / fp32_bytes

    # --- zero-fallback inspection of the traced mode="pallas" program
    def pallas_serve(f_, q_, qdb_):
        return fused_query(f_, q_, qdb_, k, cfg, mode="pallas",
                           n_probes=n_probes)

    n_pallas, worst_gather = _inspect(
        jax.make_jaxpr(pallas_serve)(forest, queries, qdb))
    no_fallback = n_pallas >= 3 and worst_gather < b * m * d

    # --- kernel parity (interpret mode) on a query subsample
    qs = queries[:parity_b]
    trav_ok = _traversal_parity(forest, rcfg, qs, n_probes)
    int8_ok = _int8_parity(qdb, qs)

    out = dict(
        n=n, d=d, n_trees=n_trees, capacity=capacity, n_probes=n_probes,
        b=b, k=k, expand=expand,
        max_nodes=rcfg.max_nodes, smem_cap=SMEM_NODE_CAP,
        above_smem_cap=bool(rcfg.max_nodes > SMEM_NODE_CAP),
        build_s=round(build_s, 2),
        p50_ms=round(p50_ms, 2), p99_ms=round(p99_ms, 2),
        valid_candidates=valid,
        int8_candidate_bytes=int(int8_bytes),
        fp32_candidate_bytes=int(fp32_bytes),
        bytes_ratio=round(bytes_ratio, 4),
        n_pallas_calls=int(n_pallas),
        worst_gather_elems=int(worst_gather),
        no_jnp_fallback=bool(no_fallback),
        traversal_bitwise_equal=bool(trav_ok),
        int8_kernel_ids_match=bool(int8_ok),
        bitwise_equal=bool(trav_ok and int8_ok),
    )
    print(f"  build {build_s:.1f}s | query p50 {p50_ms:.1f}ms "
          f"p99 {p99_ms:.1f}ms (B={b}) | bytes {bytes_ratio:.3f}x "
          f"({valid} valid cands) | pallas_calls={n_pallas} "
          f"fallback_free={no_fallback} | traversal={trav_ok} "
          f"int8={int8_ok}")
    assert no_fallback, "mode='pallas' program still contains jnp fallback"
    return out


def main(smoke: bool = False) -> dict:
    print(f"[million_row] smoke={smoke}")
    # N stays at 1M in smoke — the whole point is the above-cap tree;
    # capacity 128 puts the node allocation past the 64k SMEM cap.
    if smoke:
        out = run(n=1_000_000, d=784, n_trees=2, capacity=128, n_probes=8,
                  b=64, k=10, expand=4, iters=8, parity_b=16)
    else:
        out = run(n=1_000_000, d=784, n_trees=4, capacity=128, n_probes=8,
                  b=256, k=10, expand=4, iters=30, parity_b=32)
    out.update(smoke=smoke, backend=jax.default_backend())

    os.makedirs(os.path.dirname(os.path.abspath(ARTIFACT)), exist_ok=True)
    with open(ARTIFACT, "w") as f:
        json.dump(out, f, indent=1)
    record({}, "million_row", out)
    print(f"  -> {os.path.relpath(ARTIFACT)} bytes_ratio="
          f"{out['bytes_ratio']} bitwise={out['bitwise_equal']}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI-size run")
    a = ap.parse_args()
    main(smoke=a.smoke)

"""Forest build-time: the batched cross-tree builder vs its baselines.

Construction used to dominate the tier-1 suite and every ``compact()``:
the legacy path builds the L trees as L independent level-synchronous
problems (one lexsort + two searchsorted per tree per level, always to
the full worst-case depth budget).  The batched builder (DESIGN.md §10)
advances all L trees together — one segmented sort over composite
(tree, node) keys per level, the percentile-threshold draw fused into
the same sorted pass, and an early exit once no leaf anywhere is
overfull — while staying bitwise-identical in compat seed mode.

Measured on the 784-d benchmark corpus (mnist-statistics, the same
generator the recall frontier uses):

  * ``legacy_s``     — ``build_forest(impl="legacy")``, the per-tree path
  * ``batched_s``    — the batched builder, compat seed mode (default)
  * ``fused_s``      — batched + one-key-split-per-level seed mode
  * ``incremental_s``— the paper's one-point-at-a-time numpy builder
                       (forest_incremental.py), timed on a subsample and
                       scaled per-point: the paper-faithful reference
  * ``speedup``      — legacy_s / batched_s (CI history-gates this ratio:
                       same-machine, so runner speed cancels)
  * ``bitwise_equal``— batched output == legacy output, every array

Usage:
  PYTHONPATH=src python -m benchmarks.build_time [--smoke]

Writes artifacts/BENCH_build_time.json (uploaded + history-gated by CI
bench-smoke, see tools/bench_history.py) and merges into
artifacts/bench_results.json.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import record, timer
from repro.core import ForestConfig, build_forest
from repro.core.forest_incremental import IncrementalForest
from repro.data.synthetic import mnist_like

ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                        "BENCH_build_time.json")


def run(n: int, n_trees: int, capacity: int, iters: int,
        incremental_n: int) -> dict:
    db, _, _, _ = mnist_like(n=n, n_test=1, seed=0)
    x = jax.numpy.asarray(db)
    d = int(x.shape[1])
    cfg = ForestConfig(n_trees=n_trees, capacity=capacity, split_ratio=0.3)
    rcfg = cfg.resolved(n)
    key = jax.random.key(0)
    print(f"  corpus: mnist-statistics n={n} d={d} L={n_trees} "
          f"C={capacity} depth_budget={rcfg.max_depth}")

    legacy_s, f_legacy = timer(
        lambda: build_forest(key, x, cfg, impl="legacy"),
        iters=iters, reduce="min")
    batched_s, f_batched = timer(
        lambda: build_forest(key, x, cfg),
        iters=iters, reduce="min")
    fused_s, _ = timer(
        lambda: build_forest(key, x, cfg, seed_mode="fused"),
        iters=iters, reduce="min")

    bitwise = all(
        np.array_equal(np.asarray(getattr(f_legacy, name)),
                       np.asarray(getattr(f_batched, name)))
        for name in f_legacy._fields)

    # the paper's incremental insert loop (semantic oracle), subsampled —
    # it is O(n log n) python/numpy and only here to anchor the comparison
    sub = db[:incremental_n]
    t0 = time.perf_counter()
    IncrementalForest(sub, n_trees=2, capacity=capacity,
                      split_ratio=0.3, seed=0)
    inc_sub_s = time.perf_counter() - t0
    incremental_s = inc_sub_s * (n / incremental_n) * (n_trees / 2)

    out = dict(
        n=n, d=d, n_trees=n_trees, capacity=capacity,
        depth_budget=rcfg.max_depth,
        legacy_s=round(legacy_s, 4),
        batched_s=round(batched_s, 4),
        fused_s=round(fused_s, 4),
        incremental_s=round(incremental_s, 2),
        incremental_note=(f"paper insert loop, measured on n="
                          f"{incremental_n} x 2 trees, scaled linearly"),
        speedup=round(legacy_s / batched_s, 2),
        fused_speedup=round(legacy_s / fused_s, 2),
        bitwise_equal=bool(bitwise),
    )
    print(f"  legacy {legacy_s:.2f}s | batched {batched_s:.2f}s "
          f"({out['speedup']}x) | fused-seed {fused_s:.2f}s "
          f"({out['fused_speedup']}x) | paper-incremental "
          f"~{incremental_s:.0f}s (scaled) | bitwise={bitwise}")
    return out


def main(smoke: bool = False) -> dict:
    print(f"[build_time] smoke={smoke}")
    if smoke:
        out = run(n=8000, n_trees=32, capacity=12, iters=3,
                  incremental_n=1500)
    else:
        out = run(n=60000, n_trees=80, capacity=12, iters=3,
                  incremental_n=4000)
    out.update(smoke=smoke, backend=jax.default_backend())

    os.makedirs(os.path.dirname(os.path.abspath(ARTIFACT)), exist_ok=True)
    with open(ARTIFACT, "w") as f:
        json.dump(out, f, indent=1)
    record({}, "build_time", out)
    print(f"  -> {os.path.relpath(ARTIFACT)} speedup={out['speedup']}x "
          f"bitwise={out['bitwise_equal']}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI-size run")
    a = ap.parse_args()
    main(smoke=a.smoke)

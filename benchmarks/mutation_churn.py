"""Mutation churn under the segmented index lifecycle (DESIGN.md §8).

Interleaves add/delete/upsert/search against one index and reports search
latency percentiles — including while a background ``compact()`` rebuild is
in flight.  The pre-segment design re-stacked the overflow on every query
and stalled every reader behind the synchronous fold-rebuild; this
benchmark is the regression tripwire for both fixes:

  * search p50/p99 during steady churn (delta cache, tombstone masking),
  * search p50/p99 DURING the background compaction (readers must keep
    answering from the published view while the rebuild runs off-lock),
  * correctness: after the churn + compaction, results match a numpy
    brute-force oracle over the surviving live point set.

Usage:
  PYTHONPATH=src python -m benchmarks.mutation_churn [--smoke] [--mode auto]

Writes artifacts/BENCH_mutation_churn.json (uploaded by the CI bench-smoke
job) and merges into artifacts/bench_results.json.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.core import ForestConfig
from repro.index import IndexSpec, SearchParams, build_index

ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                        "BENCH_mutation_churn.json")


def _pct(xs: list, p: float) -> float:
    return float(np.percentile(np.asarray(xs), p)) if xs else float("nan")


def run_churn(n_db: int, dim: int, n_ops: int, batch: int, mode: str,
              seed: int = 0) -> dict:
    from repro.data.synthetic import clustered_gaussians
    rng = np.random.default_rng(seed)
    db = clustered_gaussians(n_db, dim, n_clusters=max(8, n_db // 128),
                             seed=seed)
    spec = IndexSpec(backend="rpf",
                     forest=ForestConfig(n_trees=16, capacity=16),
                     delta_cap=max(64, n_db // 20))
    index = build_index(jax.random.key(seed), db, spec)
    params = SearchParams(k=10, mode=mode)
    queries = db[rng.integers(0, n_db, size=batch)] + 0.005

    # warm the jitted search paths (steady-state latency is the metric)
    jax.block_until_ready(index.search(queries, params))

    live = list(range(n_db))
    dead: list = []
    lat_steady, lat_compact, lat_post = [], [], []
    compact_thread = None
    compact_at = n_ops // 2
    t_compact_start = t_compact = float("nan")

    for op in range(n_ops):
        gid = index.add(rng.normal(size=dim).astype(np.float32))
        live.append(gid)
        victim = live.pop(int(rng.integers(len(live))))
        index.delete(victim)
        dead.append(victim)
        if op % 7 == 6:
            index.upsert(live[-1], rng.normal(size=dim).astype(np.float32))
        if op == compact_at:
            # hammer searches for the whole background rebuild: every one
            # must answer from the published view without blocking on it
            t_compact_start = time.perf_counter()
            compact_thread = index.compact(block=False)
            while compact_thread.is_alive() and len(lat_compact) < 500:
                t0 = time.perf_counter()
                jax.block_until_ready(index.search(queries, params))
                lat_compact.append(time.perf_counter() - t0)
            compact_thread.join()
            t_compact = time.perf_counter() - t_compact_start
            continue

        t0 = time.perf_counter()
        jax.block_until_ready(index.search(queries, params))
        dt = time.perf_counter() - t0
        if compact_thread is not None:
            lat_post.append(dt)
        else:
            lat_steady.append(dt)

    # correctness after the dust settles: compact and compare against a
    # numpy brute-force oracle over the live point set
    index.compact()
    gids, rows = index.live_points()
    d = np.sum((queries[:, None, :] - rows[None, :, :]) ** 2, axis=-1)
    oracle = gids[np.argsort(d, axis=1)[:, :params.k]]
    _, got = index.search(queries, params)
    got = np.asarray(got)
    recall = float((got[:, :, None] == oracle[:, None, :]).any(-1).mean())
    deleted_surfaced = bool(np.isin(got, np.asarray(dead)).any())

    st = index.stats()
    return {
        "n_db": n_db, "dim": dim, "n_ops": n_ops, "batch": batch,
        "mode": mode,
        "p50_steady_ms": round(_pct(lat_steady, 50) * 1e3, 3),
        "p99_steady_ms": round(_pct(lat_steady, 99) * 1e3, 3),
        "p50_during_compaction_ms": round(_pct(lat_compact, 50) * 1e3, 3),
        "p99_during_compaction_ms": round(_pct(lat_compact, 99) * 1e3, 3),
        "p50_post_compaction_ms": round(_pct(lat_post, 50) * 1e3, 3),
        "p99_post_compaction_ms": round(_pct(lat_post, 99) * 1e3, 3),
        "searches_during_compaction": len(lat_compact),
        "compaction_wall_s": round(t_compact, 3),
        "final_recall_vs_oracle": recall,
        "deleted_id_surfaced": deleted_surfaced,
        "n_segments_final": st["n_segments"],
        "n_compactions": st["n_compactions"],
    }


def main(smoke: bool = False, mode: str = "auto") -> dict:
    print(f"[mutation_churn] mode={mode} smoke={smoke}")
    if smoke:
        row = run_churn(n_db=1500, dim=24, n_ops=60, batch=8, mode=mode)
    else:
        row = run_churn(n_db=20000, dim=64, n_ops=400, batch=32, mode=mode)
    print(f"  steady p50={row['p50_steady_ms']:.2f}ms "
          f"p99={row['p99_steady_ms']:.2f}ms | during compaction "
          f"p50={row['p50_during_compaction_ms']:.2f}ms "
          f"p99={row['p99_during_compaction_ms']:.2f}ms "
          f"({row['searches_during_compaction']} searches overlapped a "
          f"{row['compaction_wall_s']:.2f}s rebuild)")
    print(f"  final recall vs oracle = {row['final_recall_vs_oracle']:.3f}, "
          f"deleted id surfaced = {row['deleted_id_surfaced']}")
    out = {"row": row, "smoke": smoke, "mode": mode,
           "backend": jax.default_backend(),
           "recall_floor_ok": row["final_recall_vs_oracle"] >= 0.8,
           "no_tombstone_leak": not row["deleted_id_surfaced"]}
    os.makedirs(os.path.dirname(os.path.abspath(ARTIFACT)), exist_ok=True)
    with open(ARTIFACT, "w") as f:
        json.dump(out, f, indent=1)
    print(f"  -> {os.path.relpath(ARTIFACT)}")
    return out


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="tiny corpus for CI (seconds, not minutes)")
    p.add_argument("--mode", default="auto",
                   choices=["auto", "pallas", "ref"])
    args = p.parse_args()
    result = main(smoke=args.smoke, mode=args.mode)
    from benchmarks.common import record
    record({}, "mutation_churn", result)   # run.py records for harness runs

"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

from repro.roofline import format_table, merged_table


def main(fast: bool = True) -> dict:
    rows = merged_table(mesh="single")
    if not rows:
        print("  (no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun --all` first)")
        return {}
    print(format_table(rows))
    return {f"{r['arch']}/{r['cell']}": r for r in rows}


if __name__ == "__main__":
    main()

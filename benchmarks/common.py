"""Shared benchmark harness utilities."""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                            "bench_results.json")


def timer(fn, *args, warmup: int = 1, iters: int = 3, reduce: str = "mean"):
    """Time ``fn(*args)``; ``reduce`` = mean (default) or min (noise-robust:
    the minimum over iters is the standard scheduler-jitter-free estimate)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    if reduce == "min":
        best = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            out = jax.block_until_ready(fn(*args))
            best = min(best, time.perf_counter() - t0)
        return best, out
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters, out


def record(results: dict, name: str, payload):
    os.makedirs(os.path.dirname(os.path.abspath(RESULTS_PATH)), exist_ok=True)
    existing = {}
    if os.path.exists(RESULTS_PATH):
        with open(RESULTS_PATH) as f:
            existing = json.load(f)
    existing[name] = payload
    with open(RESULTS_PATH, "w") as f:
        json.dump(existing, f, indent=1)
    results[name] = payload


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"

"""Benchmark harness: one module per paper table/figure + roofline.

  PYTHONPATH=src python -m benchmarks.run [--paper-scale]

Prints ``name,us_per_call,derived`` CSV rows (harness contract) and writes
artifacts/bench_results.json consumed by EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (autoscale, build_time, fig4_mnist, fig5_iss,
                        filtered_search, fused_vs_staged, million_row,
                        probe_schedule, recall_frontier, retrieval_compare,
                        roofline_table, serving_slo, speedup_table,
                        tree_stats)
from benchmarks.common import csv_row, record


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--paper-scale", action="store_true",
                   help="full N=60000/250736 runs (slow on CPU)")
    p.add_argument("--only", default="",
                   help="comma list: fig4,fig5,speedup,tree,retrieval,"
                        "fused,frontier,build,roof,million,serving,"
                        "filtered,schedule,autoscale")
    args = p.parse_args()
    fast = not args.paper_scale
    only = set(args.only.split(",")) if args.only else None

    results: dict = {}
    rows: list[str] = []

    def want(name):
        return only is None or name in only

    if want("fig4"):
        r = fig4_mnist.main(fast=fast)
        record(results, "fig4_mnist", r)
        best = max(r["rpf"], key=lambda x: x["recall"])
        rows.append(csv_row(
            "fig4_rpf_best", best["query_us"],
            f"recall={best['recall']:.4f}@L={best['L']}"
            f";frac={best['frac_searched']:.4f}"))
        if r["lsh"]:
            bl = max(r["lsh"], key=lambda x: x["recall"])
            rows.append(csv_row(
                "fig4_lsh_best", bl["query_us"],
                f"recall={bl['recall']:.4f};frac={bl['frac_searched']:.4f}"))
    if want("fig5"):
        r = fig5_iss.main(fast=fast)
        record(results, "fig5_iss", r)
        best = max(r["rpf"], key=lambda x: x["recall"])
        rows.append(csv_row(
            "fig5_rpf_best", best["query_us"],
            f"recall={best['recall']:.4f}@L={best['L']}"
            f";frac={best['frac_searched']:.4f}"))
    if want("speedup"):
        r = speedup_table.main(fast=fast)
        record(results, "speedup_table", r)
        rows.append(csv_row(
            "speedup_vs_exhaustive", r["indexed_us"],
            f"wallclock={r['wallclock_speedup']}x"
            f";bytes={r['bytes_speedup']}x;recall={r['recall']:.3f}"))
    if want("tree"):
        r = tree_stats.main(fast=fast)
        record(results, "tree_stats", r)
        rows.append(csv_row(
            "tree_stats", 0.0,
            f"occ_max={r['occ_max']};depth_mean={r['depth_mean']:.1f}"))
    if want("retrieval"):
        r = retrieval_compare.main(fast=fast)
        record(results, "retrieval_compare", r)
        rows.append(csv_row(
            "retrieval_rpf", r["rpf_us"],
            f"recall_vs_brute={r['recall_vs_brute']:.3f}"
            f";reduction={r['reduction']}x"))
    if want("fused"):
        r = fused_vs_staged.main(smoke=fast)
        record(results, "fused_vs_staged", r)
        worst = min(r["rows"], key=lambda x: x["speedup"])
        rows.append(csv_row(
            "fused_vs_staged", worst["fused_us"],
            f"speedup={worst['speedup']}x"
            f";traffic={worst['traffic_ratio']:.1f}x"
            f";ids_match={r['all_ids_match']}"))
    if want("frontier"):
        r = recall_frontier.main(smoke=fast)
        record(results, "recall_frontier", r)
        rows.append(csv_row(
            "recall_frontier", 0.0,
            f"single_trees={r['single_probe_trees_at_target']}"
            f";multi_trees={r['multi_probe_trees_at_target']}"
            f";saved={r['trees_saved_ratio']}x"))
    if want("build"):
        r = build_time.main(smoke=fast)
        record(results, "build_time", r)
        rows.append(csv_row(
            "forest_build", r["batched_s"] * 1e6,
            f"speedup={r['speedup']}x;fused={r['fused_speedup']}x"
            f";bitwise={r['bitwise_equal']}"))
    if want("million"):
        r = million_row.main(smoke=fast)
        record(results, "million_row", r)
        rows.append(csv_row(
            "million_row", r["p50_ms"] * 1e3,
            f"p99_ms={r['p99_ms']};bytes_ratio={r['bytes_ratio']}"
            f";bitwise={r['bitwise_equal']}"
            f";fallback_free={r['no_jnp_fallback']}"))
    if want("serving"):
        r = serving_slo.main(smoke=fast)
        record(results, "serving_slo", r)
        rows.append(csv_row(
            "serving_slo", r["p99_ms_at_rated_qps"] * 1e3,
            f"rated_qps={r['rated_qps']}"
            f";recall={r['recall_at_rated']:.3f}"
            f";shed2x={r['overload']['shed_fraction']:.2f}"
            f";slo_ok={r['slo_ok']};shed_nonzero={r['shed_nonzero']}"))
    if want("filtered"):
        r = filtered_search.main(smoke=fast)
        record(results, "filtered_search", r)
        worst = min(r["rows"], key=lambda c: c["recall"])
        rows.append(csv_row(
            "filtered_search", worst["us_per_query"],
            f"worst={worst['backend']}/{worst['metric']}"
            f"@s={worst['selectivity']}"
            f";recall={worst['recall']:.3f}"
            f";gate001={r['recall_001_ok']};all={r['recall_all_ok']}"
            f";no_leaks={r['no_leaks']}"))
    if want("schedule"):
        r = probe_schedule.main(smoke=fast)
        record(results, "probe_schedule", r)
        rows.append(csv_row(
            "probe_schedule", r["p99_scheduled_ms"] * 1e3,
            f"mean_probes={r['mean_probes_scheduled']}"
            f"/fixed={r['fixed_n_probes']}"
            f";recall={r['recall_scheduled']:.3f}"
            f";p99_ratio={r['p99_ratio']}"
            f";gates={r['recall_ok']}/{r['probes_below_fixed']}"
            f"/{r['p99_ok']}"))
    if want("autoscale"):
        r = autoscale.main(smoke=fast)
        record(results, "autoscale", r)
        rows.append(csv_row(
            "autoscale", r["scaled_leg"]["p99_ms"] * 1e3,
            f"replicas={r['replicas_after_leg1']}"
            f";shed_scaled={r['shed_after_scaleup']:.3f}"
            f";shed_static={r['static_control']['shed_fraction']:.2f}"
            f";gates={r['scaled_up']}/{r['shed_recovered']}"
            f"/{r['no_flapping']}"))
    if want("roof"):
        r = roofline_table.main(fast=fast)
        record(results, "roofline", r)
        if r:
            worst = min(r.values(), key=lambda t: t["roofline_fraction"]
                        if t["roofline_fraction"] > 0 else 9e9)
            rows.append(csv_row(
                "roofline_worst_cell", 0.0,
                f"{worst['arch']}/{worst['cell']}"
                f";frac={worst['roofline_fraction']:.3f}"))

    print("\n=== CSV (name,us_per_call,derived) ===")
    for row in rows:
        print(row)


if __name__ == "__main__":
    main()

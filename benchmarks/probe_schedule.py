"""Per-query probe scheduling benchmark (core/schedule.py, DESIGN.md §14).

The fixed multi-probe budget charges every query the price of the hardest
one; the per-query scheduler (``SearchParams.probe_schedule``) lets easy
queries stop at the width where their top-k stops moving.  This benchmark
measures that trade on mixed ANN serving traffic over the MNIST-statistics
corpus:

  * corpus — ``mnist_like`` rows plus planted micro-clusters of
    near-duplicate rows (duplicated images, the classic easy case: a
    lookup's whole top-k sits in one leaf),
  * traffic — a majority of near-duplicate lookups (easy) blended with
    held-out queries (hard), the skew the scheduler exists for,
  * baseline — the smallest fixed ``n_probes`` reaching the recall
    target on this traffic (the operating point a fixed-budget operator
    would tune to),
  * scheduled — ``probe_schedule`` capped at that same budget.

Headline numbers (the CI acceptance gate, checked in
tools/bench_history.py):
  * ``recall_ok``           — scheduled recall@10 >= 0.9,
  * ``probes_below_fixed``  — mean probes PROCESSED per scheduled query
    (cumulative over re-descent rounds — the honest compute charge, the
    same number ``tune()`` cost-models) strictly below the fixed budget,
  * ``p99_ok``              — scheduled batch p99 latency <= 1.1x fixed,
  * ``p99_ratio``           — scheduled/fixed batch p99 (the lower-is-
    better history series).

Usage:
  PYTHONPATH=src python -m benchmarks.probe_schedule [--smoke]

Writes artifacts/BENCH_probe_schedule.json and merges into
artifacts/bench_results.json.  docs/TUNING.md's "Scheduling probes per
query" entry walks this output.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import record
from repro.core import ForestConfig, exact_knn, recall_at_k
from repro.core.schedule import probe_widths
from repro.data.synthetic import mnist_like
from repro.index import IndexSpec, SearchParams, build_index

ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                        "BENCH_probe_schedule.json")

RECALL_FLOOR = 0.9        # the CI acceptance gate (ISSUE 9)
P99_REGRESSION_CAP = 1.1  # scheduled p99 may not exceed 1.1x fixed


def _mixed_corpus(n_base: int, n_clusters: int, dup: int, n_easy: int,
                  n_hard: int, seed: int):
    """MNIST-statistics rows + planted near-duplicate micro-clusters, and
    a query blend of micro-cluster lookups (easy) + held-out (hard)."""
    base, _, test_q, _ = mnist_like(n=n_base, n_test=max(n_hard, 8),
                                    seed=seed)
    rng = np.random.default_rng(seed + 2)
    centers = base[rng.choice(n_base, n_clusters, replace=False)]
    dups = (np.repeat(centers, dup, 0)
            + 1e-3 * rng.normal(size=(n_clusters * dup, base.shape[1]))
            ).astype(np.float32)
    db = np.concatenate([base, dups])
    easy = (centers[:n_easy]
            + 1e-3 * rng.normal(size=(n_easy, base.shape[1]))
            ).astype(np.float32)
    queries = np.concatenate([easy, test_q[:n_hard]]).astype(np.float32)
    return db, queries


def _batch_p99_ms(index, q, params, iters: int, reps: int = 3) -> float:
    """p99 over jit-warm full-batch search latencies; best of `reps`
    measurement blocks (the repo's reduce="min" idiom — scheduler noise
    only ever inflates a tail percentile)."""
    for _ in range(2):     # warm every (bucket, width) jit variant
        jax.block_until_ready(index.search(q, params))
    p99s = []
    for _ in range(reps):
        times = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(index.search(q, params))
            times.append(time.perf_counter() - t0)
        p99s.append(np.percentile(times, 99))
    return float(min(p99s) * 1e3)


def run(n_base: int, n_clusters: int, dup: int, n_easy: int, n_hard: int,
        k: int, target: float, tol: float, iters: int) -> dict:
    db, queries = _mixed_corpus(n_base, n_clusters, dup, n_easy, n_hard,
                                seed=0)
    print(f"  corpus: mnist-statistics n={db.shape[0]} d={db.shape[1]} "
          f"({n_clusters} micro-clusters x{dup}) "
          f"traffic B={queries.shape[0]} ({n_easy} easy + {n_hard} hard)")
    _, true_ids = exact_knn(jnp.asarray(queries), jnp.asarray(db), k=k)

    cfg = ForestConfig(n_trees=16, capacity=24, split_ratio=0.3)
    index = build_index(jax.random.key(0), db,
                        IndexSpec(backend="rpf", forest=cfg))

    # fixed-budget baseline: the smallest n_probes reaching the target on
    # this traffic — what a fixed-budget operator would tune to
    frontier = []
    fixed_probes = None
    for p in (1, 2, 4, 6, 8, 12, 16):
        _, ids = index.search(queries, SearchParams(k=k, n_probes=p))
        rec = float(recall_at_k(ids, true_ids))
        frontier.append(dict(n_probes=p, recall=round(rec, 4)))
        print(f"  fixed n_probes={p:2d}: recall@{k}={rec:.3f}")
        if rec >= target and fixed_probes is None:
            fixed_probes = p
    if fixed_probes is None:
        raise RuntimeError(f"no fixed budget reaches recall {target}")
    fixed_params = SearchParams(k=k, n_probes=fixed_probes)
    _, ids = index.search(queries, fixed_params)
    recall_fixed = float(recall_at_k(ids, true_ids))

    # scheduled: same cap, per-query convergence gate
    sched_params = SearchParams(k=k, probe_schedule=fixed_probes, tol=tol)
    _, ids = index.search(queries, sched_params)
    recall_sched = float(recall_at_k(ids, true_ids))
    mean_probes = float(index.last_mean_probes)

    p99_fixed = _batch_p99_ms(index, queries, fixed_params, iters)
    p99_sched = _batch_p99_ms(index, queries, sched_params, iters)
    p99_ratio = p99_sched / p99_fixed

    print(f"  fixed  n_probes={fixed_probes}: recall={recall_fixed:.3f} "
          f"p99={p99_fixed:.1f}ms")
    print(f"  sched  cap={fixed_probes} tol={tol}: recall={recall_sched:.3f} "
          f"mean_probes={mean_probes:.2f} p99={p99_sched:.1f}ms "
          f"ratio={p99_ratio:.2f}")

    return dict(
        n=int(db.shape[0]), d=int(db.shape[1]), k=k,
        n_easy=n_easy, n_hard=n_hard, target_recall=target, tol=tol,
        frontier=frontier,
        fixed_n_probes=fixed_probes, recall_fixed=round(recall_fixed, 4),
        recall_scheduled=round(recall_sched, 4),
        mean_probes_scheduled=round(mean_probes, 3),
        max_probes_budget=sum(probe_widths(fixed_probes)),
        p99_fixed_ms=round(p99_fixed, 2),
        p99_scheduled_ms=round(p99_sched, 2),
        p99_ratio=round(p99_ratio, 3),
        recall_ok=bool(recall_sched >= RECALL_FLOOR),
        probes_below_fixed=bool(mean_probes < fixed_probes),
        p99_ok=bool(p99_ratio <= P99_REGRESSION_CAP),
    )


def main(smoke: bool = False, k: int = 10, target: float = 0.98,
         tol: float = 0.01) -> dict:
    print(f"[probe_schedule] smoke={smoke}")
    if smoke:
        # B=128: large enough that per-round probe work dominates the
        # scheduler's per-round dispatch overhead (tiny batches hide the
        # win behind fixed per-round cost, especially on CPU)
        out = run(n_base=4000, n_clusters=96, dup=12, n_easy=96, n_hard=32,
                  k=k, target=target, tol=tol, iters=20)
    else:
        out = run(n_base=20000, n_clusters=128, dup=12, n_easy=128,
                  n_hard=64, k=k, target=target, tol=tol, iters=50)
    out.update(smoke=smoke, backend=jax.default_backend())

    os.makedirs(os.path.dirname(os.path.abspath(ARTIFACT)), exist_ok=True)
    with open(ARTIFACT, "w") as f:
        json.dump(out, f, indent=1)
    record({}, "probe_schedule", out)
    print(f"  -> {os.path.relpath(ARTIFACT)} "
          f"recall_ok={out['recall_ok']} "
          f"probes_below_fixed={out['probes_below_fixed']} "
          f"p99_ok={out['p99_ok']}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--target-recall", type=float, default=0.98)
    ap.add_argument("--tol", type=float, default=0.01)
    args = ap.parse_args()
    main(smoke=args.smoke, k=args.k, target=args.target_recall,
         tol=args.tol)

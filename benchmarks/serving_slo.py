"""Serving SLO benchmark: does the planner's rated QPS hold up under fire?

Closes the loop the serving runtime promises (DESIGN.md §12):

  1. build + tune an index to a recall target,
  2. calibrate the traffic model and ask the planner for the rated QPS at
     a p99 SLO derived from the measured service time (so the gate is
     runner-speed-relative, not an absolute ms that shared CI can't hold),
  3. drive OPEN-LOOP Poisson traffic at the rated QPS — p99 must meet the
     SLO and recall-vs-oracle must meet the tuned target,
  4. drive 2x the rated QPS — past saturation by construction — and the
     degradation ladder must keep p999 bounded (every request completes;
     no unbounded queue growth) with a NONZERO shed fraction, measured
     against a ladder-disabled control run at the same load.

Usage:
  PYTHONPATH=src python -m benchmarks.serving_slo [--smoke]

Writes artifacts/BENCH_serving_slo.json (uploaded + gated by CI:
``p99_ms_at_rated_qps`` is history-gated in tools/bench_history.py, the
recall/SLO/shed flags are hard gates).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.core import ForestConfig
from repro.index import IndexSpec, build_index, tune
from repro.serve import loadgen, planner
from repro.serve.runtime import ServingRuntime

ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                        "BENCH_serving_slo.json")

# rated = utilization * (1 - t/budget) * capacity; with budget >= 5*t the
# factor is >= 0.56 > 0.5, so 2x rated ALWAYS exceeds the true saturation
# rate — the overload leg is past the knee by construction, not by luck
SLO_SERVICE_MULT = 5.0
UTILIZATION = 0.7
MAX_RATED_QPS = 2500.0   # host dispatcher ceiling: beyond this the Python
#                          submit loop's sleep granularity, not the server,
#                          dominates the open-loop schedule


def run_slo(n_db: int, dim: int, n_trees: int, capacity: int,
            target_recall: float, k: int, max_batch: int,
            n_requests: int, seed: int = 0) -> dict:
    from repro.data.synthetic import clustered_gaussians
    from repro.core.knn import exact_knn

    db = clustered_gaussians(n_db, dim, n_clusters=max(16, n_db // 256),
                             seed=seed)
    spec = IndexSpec(backend="rpf",
                     forest=ForestConfig(n_trees=n_trees,
                                         capacity=capacity))
    t0 = time.perf_counter()
    index = build_index(jax.random.key(seed), db, spec)
    build_s = time.perf_counter() - t0
    queries = db[np.random.default_rng(seed).integers(0, n_db, size=128)] \
        + 0.003
    tuned = tune(index, queries[:64], target_recall=target_recall, k=k,
                 probe_grid=(1, 2, 4, 8))
    gids, rows = index.live_points()
    _, pos = exact_knn(queries, rows, k=k)
    true_ids = np.asarray(gids)[np.asarray(pos)]

    def make_runtime(degrade: bool, slo_ms: float | None):
        # max_wait sized so batches actually FILL at the rated rate
        # (~max_batch / rated arrivals); with partial batches the affine
        # model overestimates capacity and the rated leg runs hot
        return ServingRuntime(index, slo_p99_ms=slo_ms,
                              max_batch=max_batch, max_wait_s=0.008,
                              degrade=degrade)

    # ---- calibrate + plan (SLO derived from the measured service time,
    # so the whole gate scales with the runner instead of fighting it)
    runtime = make_runtime(degrade=True, slo_ms=None)
    model = runtime.calibrate(queries, batch_grid=(1, max_batch // 4,
                                                   max_batch))
    slo_p99_ms = (model.max_wait_s
                  + SLO_SERVICE_MULT * model.service_s(max_batch)) * 1e3
    rated = planner.rated_qps(model, slo_p99_ms, max_batch,
                              utilization=UTILIZATION)
    rated = min(rated, MAX_RATED_QPS)
    if rated <= 0:
        raise RuntimeError(f"planner found no in-SLO rate (model "
                           f"c0={model.c0_s}, c1={model.c1_s})")
    plan = planner.plan(model, qps=rated, slo_p99_ms=slo_p99_ms,
                        batch_grid=(max_batch,), utilization=UTILIZATION,
                        recall_target=target_recall)
    runtime.stop()

    # ---- leg 1: rated QPS, SLO + recall gate (fresh runtime so leg-1
    # counters/rung state can't leak into leg 2)
    runtime = make_runtime(degrade=True, slo_ms=slo_p99_ms)
    at_rated = loadgen.run_open_loop(runtime, queries, rated,
                                     n_requests=n_requests, seed=1,
                                     true_ids=true_ids)
    runtime.stop()

    # ---- leg 2: 2x rated (past saturation), ladder on vs off
    over_n = int(n_requests * 1.5)
    runtime = make_runtime(degrade=True, slo_ms=slo_p99_ms)
    overload = loadgen.run_open_loop(runtime, queries, 2 * rated,
                                     n_requests=over_n, seed=2,
                                     true_ids=true_ids)
    shed_stats = runtime.stats()
    runtime.stop()
    control = make_runtime(degrade=False, slo_ms=slo_p99_ms)
    overload_ctl = loadgen.run_open_loop(control, queries, 2 * rated,
                                         n_requests=over_n, seed=2,
                                         true_ids=true_ids)
    control.stop()

    return {
        "n_db": n_db, "dim": dim, "n_trees": n_trees, "k": k,
        "max_batch": max_batch, "build_s": round(build_s, 2),
        "tuned_params": tuned.to_dict(),
        "recall_target": target_recall,
        "traffic_model": model.to_dict(),
        "plan": plan.to_dict(),
        "slo_p99_ms": round(slo_p99_ms, 3),
        "rated_qps": round(rated, 1),
        "at_rated": at_rated,
        "overload": overload,
        "overload_no_ladder": overload_ctl,
        "ladder_rungs": len(ServingRuntime(index, warmup=False,
                                           max_batch=max_batch).ladder),
        "shed_steps": shed_stats["shed_steps"],
        "recover_steps": shed_stats["recover_steps"],
        # the gated headline metrics
        "p99_ms_at_rated_qps": at_rated["p99_ms"],
        "recall_at_rated": at_rated.get("recall_vs_oracle", 0.0),
    }


def main(smoke: bool = False) -> dict:
    print(f"[serving_slo] smoke={smoke}")
    if smoke:
        row = run_slo(n_db=20000, dim=64, n_trees=32, capacity=32,
                      target_recall=0.9, k=10, max_batch=8,
                      n_requests=1200)
    else:
        row = run_slo(n_db=60000, dim=128, n_trees=40, capacity=32,
                      target_recall=0.95, k=10, max_batch=32,
                      n_requests=4000)
    slo = row["slo_p99_ms"]
    rated, over = row["at_rated"], row["overload"]
    ctl = row["overload_no_ladder"]
    # gates — all runner-speed-relative:
    #   * in-SLO + on-target recall at the planner's rated QPS,
    #   * at 2x rated: every request answered (bounded queue), p999 within
    #     10x SLO, nonzero shed, and the ladder not worse than no ladder
    slo_ok = rated["p99_ms"] <= slo and rated["n_timeout"] == 0
    recall_ok = row["recall_at_rated"] >= row["recall_target"] - 0.01
    overload_bounded = (over["n_timeout"] == 0 and over["n_failed"] == 0
                        and over["p999_ms"] <= 10.0 * slo)
    shed_nonzero = over["shed_fraction"] > 0.0
    ladder_no_worse = over["p999_ms"] <= max(ctl["p999_ms"] * 1.25,
                                             over["p99_ms"] + slo)
    tm = row["traffic_model"]
    t_b_ms = (tm["c0_s"] + tm["c1_s"] * row["max_batch"]) * 1e3
    print(f"  plan: rated {row['rated_qps']} qps @ p99<={slo:.1f}ms "
          f"(t(B)={t_b_ms:.2f}ms, {row['ladder_rungs']} ladder rungs)")
    print(f"  at rated:   p50={rated['p50_ms']:.1f} p99={rated['p99_ms']:.1f} "
          f"p999={rated['p999_ms']:.1f}ms recall={row['recall_at_rated']:.3f} "
          f"shed={rated['shed_fraction']:.1%} -> slo_ok={slo_ok} "
          f"recall_ok={recall_ok}")
    print(f"  at 2x:      p50={over['p50_ms']:.1f} p99={over['p99_ms']:.1f} "
          f"p999={over['p999_ms']:.1f}ms shed={over['shed_fraction']:.1%} "
          f"({row['shed_steps']} shed / {row['recover_steps']} recover "
          f"steps) -> bounded={overload_bounded} shed_nonzero={shed_nonzero}")
    print(f"  2x no-ladder control: p99={ctl['p99_ms']:.1f} "
          f"p999={ctl['p999_ms']:.1f}ms -> ladder_no_worse={ladder_no_worse}")
    out = {**row, "smoke": smoke, "backend": jax.default_backend(),
           "slo_ok": slo_ok, "recall_ok": recall_ok,
           "overload_bounded": overload_bounded,
           "shed_nonzero": shed_nonzero,
           "ladder_no_worse": ladder_no_worse}
    os.makedirs(os.path.dirname(os.path.abspath(ARTIFACT)), exist_ok=True)
    with open(ARTIFACT, "w") as f:
        json.dump(out, f, indent=1)
    print(f"  -> {os.path.relpath(ARTIFACT)}")
    return out


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="CI-size corpus + short runs (tens of seconds)")
    args = p.parse_args()
    result = main(smoke=args.smoke)
    from benchmarks.common import record
    record({}, "serving_slo", result)

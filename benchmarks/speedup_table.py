"""Paper §4 speedup claim: exhaustive vs indexed query wall-clock.

The paper reports 0.73 s/query exhaustive -> 0.009 s indexed (81x) at 96%
recall on 250736 x 595 chi2 (2.4 GHz CPU, 2005-era).  We reproduce the RATIO
on this container's CPU, and — since the TPU target cannot be timed here —
also derive the bytes-touched ratio (the roofline-model speedup: exhaustive
reads N*d floats/query, RPF reads ~L*C*d + traversal), which is
hardware-independent.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ForestConfig, build_forest, exact_knn, recall_at_k
from repro.core.forest import gather_candidates, traverse
from repro.core.search import mask_duplicates, rerank_topk
from repro.data.synthetic import iss_like


def run(n_db: int = 50000, n_test: int = 128, L: int = 80,
        capacity: int = 12, metric: str = "chi2", seed: int = 2) -> dict:
    db_np, _, q_np, _ = iss_like(n=n_db, n_test=n_test, seed=seed)
    db, q = jnp.asarray(db_np), jnp.asarray(q_np)

    # exhaustive
    t0 = time.perf_counter()
    td, tids = exact_knn(q, db, k=1, metric=metric)
    jax.block_until_ready(td)
    # time it again warm
    t0 = time.perf_counter()
    td, tids = exact_knn(q, db, k=1, metric=metric)
    jax.block_until_ready(td)
    exhaustive_s = (time.perf_counter() - t0) / n_test

    cfg = ForestConfig(n_trees=L, capacity=capacity, split_ratio=0.3)
    rcfg = cfg.resolved(n_db)
    forest = build_forest(jax.random.key(seed), db, cfg, tree_chunk=64)

    def indexed(qq):
        leaves = traverse(forest, qq, rcfg.max_depth)
        ids, mask = gather_candidates(forest, leaves, rcfg.leaf_pad)
        mask_d = mask_duplicates(ids, mask)
        return rerank_topk(qq, ids, mask_d, db, k=1, metric=metric,
                           dedup=False)

    d, pred = indexed(q)          # warm/compile
    jax.block_until_ready(d)
    t0 = time.perf_counter()
    d, pred = indexed(q)
    jax.block_until_ready(d)
    indexed_s = (time.perf_counter() - t0) / n_test

    recall = float(recall_at_k(pred, tids))
    ids, mask = gather_candidates(
        forest, traverse(forest, q, rcfg.max_depth), rcfg.leaf_pad)
    n_cand = float(mask_duplicates(ids, mask).sum(1).mean())

    d_dim = db.shape[1]
    bytes_exhaustive = n_db * d_dim * 4
    bytes_indexed = (n_cand * d_dim * 4                 # candidate rows
                     + L * rcfg.max_depth * 8)          # traversal loads
    out = dict(
        n_db=n_db, L=L, recall=recall,
        exhaustive_us=round(exhaustive_s * 1e6, 1),
        indexed_us=round(indexed_s * 1e6, 1),
        wallclock_speedup=round(exhaustive_s / indexed_s, 1),
        bytes_speedup=round(bytes_exhaustive / bytes_indexed, 1),
        mean_candidates=round(n_cand, 1),
        paper_claim="81x at 96% recall (250736x595, 2.4GHz-era CPU)",
    )
    print(f"  exhaustive {out['exhaustive_us']:.0f}us vs indexed "
          f"{out['indexed_us']:.0f}us -> {out['wallclock_speedup']}x "
          f"wall-clock, {out['bytes_speedup']}x bytes-touched, "
          f"recall {recall:.3f}")
    return out


def main(fast: bool = True):
    print("[speedup] exhaustive vs RPF-indexed query")
    if fast:
        return run(n_db=50000, n_test=128, L=80)
    return run(n_db=250000, n_test=512, L=160)


if __name__ == "__main__":
    main()

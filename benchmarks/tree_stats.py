"""Paper §3.4 structural claims: occupancy bounds, depth, density adaptivity.

Validates: (a) every leaf holds <= C points (and >= ~r*C modulo fat-leaf
remainders), (b) depth ~= log_{2/(1+r)}(2N/C) (paper reports ~13 at N=60000,
C=12), (c) the partition adapts to density — cells in dense regions are
geometrically smaller.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ForestConfig, build_forest
from repro.core.forest import forest_stats
from repro.data.synthetic import mnist_like


def run(n_db: int = 60000, capacity: int = 12, L: int = 8) -> dict:
    db, _, _, _ = mnist_like(n=n_db, n_test=1)
    cfg = ForestConfig(n_trees=L, capacity=capacity, split_ratio=0.3)
    forest = build_forest(jax.random.key(0), jnp.asarray(db), cfg)
    stats = forest_stats(forest, cfg, n_db)
    paper_depth = float(np.log(2 * n_db / ((1 + 0.3) * capacity))
                        / np.log(2))
    out = {k: v for k, v in stats.items() if k != "per_tree"}
    out["paper_expected_depth"] = round(paper_depth, 1)
    print(f"  occupancy max={stats['occ_max']:.0f} (C={capacity}), "
          f"mean={stats['occ_mean']:.1f}; depth mean={stats['depth_mean']:.1f}"
          f" (paper formula ~{paper_depth:.1f}), max={stats['depth_max']:.0f};"
          f" overflow={stats['overflow_points']:.0f} pts")
    return out


def main(fast: bool = True):
    print("[tree_stats] partition structure (paper §3.4)")
    return run(n_db=20000 if fast else 60000)


if __name__ == "__main__":
    main()

"""Paper Fig. 5: 595-D shape descriptors, chi-square metric, RPF vs LSH.

Paper operating points (ISS, N=250736): L=40 -> 69% @ 0.13%;
L=160 -> 91% @ 0.48%; L=320 -> 96% @ 0.91%.  LSH hashes in L2 (p-stable,
as the E2LSH software does) and reranks in chi2 — the metric mismatch is the
paper's point about LSH's rigidity vs RPF's data adaptivity.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ForestConfig, build_forest, exact_knn, recall_at_k
from repro.core.forest import gather_candidates, traverse
from repro.core.search import mask_duplicates, rerank_topk
from repro.data.synthetic import iss_like
from repro.index import IndexSpec, SearchParams, build_index


def run(n_db: int = 20000, n_test: int = 256,
        l_sweep=(10, 20, 40, 80, 160), capacity: int = 12,
        seed: int = 1) -> dict:
    db_np, _, q_np, _ = iss_like(n=n_db, n_test=n_test, seed=seed)
    db, q = jnp.asarray(db_np), jnp.asarray(q_np)
    _, true_ids = exact_knn(q, db, k=1, metric="chi2",
                            db_chunk=5000 if n_db % 5000 == 0 else 0)

    rows = []
    for L in l_sweep:
        cfg = ForestConfig(n_trees=L, capacity=capacity, split_ratio=0.3)
        rcfg = cfg.resolved(n_db)
        forest = build_forest(jax.random.key(seed), db, cfg,
                              tree_chunk=64 if L > 64 else 0)
        t0 = time.perf_counter()
        leaves = traverse(forest, q, rcfg.max_depth)
        ids, mask = gather_candidates(forest, leaves, rcfg.leaf_pad)
        mask_d = mask_duplicates(ids, mask)
        d, pred = rerank_topk(q, ids, mask_d, db, k=1, metric="chi2",
                              dedup=False)
        jax.block_until_ready(d)
        query_s = time.perf_counter() - t0
        recall = float(recall_at_k(pred, true_ids))
        cost = float(mask_d.sum(1).mean()) / n_db
        rows.append(dict(L=L, recall=recall, frac_searched=cost,
                         query_us=round(query_s / n_test * 1e6, 1)))
        print(f"  RPF L={L:4d}: recall@1={recall:.4f} "
              f"frac={cost*100:.3f}%")

    # LSH baseline via the unified index API: L2 p-stable hashing on
    # histogram features, chi2 rerank through the shared fused stage (the
    # metric mismatch is the paper's point about LSH's rigidity)
    lsh_rows = []
    tid = np.asarray(true_ids)
    for n_tables, bits in ((8, 12), (16, 10), (32, 8)):
        index = build_index(None, db_np, IndexSpec(
            backend="lsh-cascade", lsh_radii=(0.02, 0.05, 0.1, 0.3),
            lsh_tables=n_tables, lsh_bits=bits, seed=0))
        _, ids = index.search(q_np, SearchParams(k=1, metric="chi2"))
        recall = float((np.asarray(ids)[:, 0] == tid[:, 0]).mean())
        frac = index.last_mean_candidates / n_db
        lsh_rows.append(dict(n_tables=n_tables, bits=bits,
                             recall=recall, frac_searched=frac))
        print(f"  LSH T={n_tables:3d} K={bits}: recall@1={recall:.4f} "
              f"frac={frac*100:.3f}%")
    return {"rpf": rows, "lsh": lsh_rows, "n_db": n_db, "n_test": n_test,
            "metric": "chi2"}


def main(fast: bool = True):
    print("[fig5] ISS-595-like (chi2), RPF vs LSH")
    if fast:
        return run(n_db=20000, n_test=256, l_sweep=(10, 20, 40, 80, 160))
    return run(n_db=250000, n_test=2000, l_sweep=(10, 20, 40, 80, 160, 320))


if __name__ == "__main__":
    main()

"""Fused single-pass query pipeline vs the staged oracle: latency + traffic.

The staged path (traverse -> gather_candidates -> mask_duplicates ->
rerank_topk) round-trips the padded (B, M) candidate matrix and the gathered
(B, M, d) candidate tensor through HBM between dispatches.  The fused path
(core/pipeline.fused_query) runs the same math in ONE jit and streams
candidate chunks through the fused gather+distance+top-k kernel, so the
(B, M, d) tensor never materializes.

Reported per workload:
  * wall latency of both paths (jit-warm, block_until_ready),
  * speedup = staged / fused  (acceptance floor: >= 1.0),
  * the analytic HBM candidate-traffic model (DESIGN.md §4): staged moves
    every padded candidate row 3x (gather read + write + kernel read); fused
    moves each *valid* row once,
  * id parity between the two paths (must be exact).

Usage:
  PYTHONPATH=src python -m benchmarks.fused_vs_staged [--smoke] [--mode auto]

Writes artifacts/BENCH_fused_vs_staged.json (the perf-trajectory artifact CI
uploads) and merges into artifacts/bench_results.json.
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timer
from repro.core import ForestConfig, build_forest
from repro.core.forest import gather_candidates, traverse
from repro.core.pipeline import fused_query, staged_query
from repro.data.synthetic import iss_like, mnist_like

ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                        "BENCH_fused_vs_staged.json")


def traffic_model(b: int, m_padded: int, m_valid: float, d: int,
                  bytes_per_elt: int = 4) -> dict:
    """Candidate-tensor HBM bytes per query batch (DESIGN.md §4).

    staged: XLA gather reads M_padded rows and writes the (B, M, d) tensor,
    then the rerank kernel reads it back -> 3 crossings of every padded row.
    fused: the kernel DMAs each valid row HBM->VMEM once; invalid (padded or
    duplicate-masked) slots issue no DMA.
    """
    row = d * bytes_per_elt
    staged = 3 * b * m_padded * row
    fused = int(b * m_valid * row)
    return {"staged_bytes": staged, "fused_bytes": fused,
            "traffic_ratio": staged / max(fused, 1)}


def run_workload(name: str, db: np.ndarray, q: np.ndarray, metric: str,
                 n_trees: int, capacity: int, k: int, mode: str,
                 iters: int = 5) -> dict:
    db_j, q_j = jnp.asarray(db), jnp.asarray(q)
    cfg = ForestConfig(n_trees=n_trees, capacity=capacity)
    rcfg = cfg.resolved(db.shape[0])
    forest = build_forest(jax.random.key(0), db_j, cfg)
    jax.block_until_ready(forest.thresh)

    staged_s, (sd, si) = timer(
        lambda: staged_query(forest, q_j, db_j, k, cfg, metric=metric),
        iters=iters, reduce="min")
    fused_s, (fd, fi) = timer(
        lambda: fused_query(forest, q_j, db_j, k, cfg, metric=metric,
                            mode=mode),
        iters=iters, reduce="min")

    ids_match = bool((np.asarray(si) == np.asarray(fi)).all())
    finite = np.isfinite(np.asarray(sd))
    dist_err = float(np.max(np.abs(np.asarray(sd)[finite]
                                   - np.asarray(fd)[finite]), initial=0.0))

    # valid-candidate stats for the traffic model (post-dedup)
    from repro.core.search import mask_duplicates
    leaves = traverse(forest, q_j, rcfg.max_depth)
    ids, mask = gather_candidates(forest, leaves, rcfg.leaf_pad)
    m_valid = float(mask_duplicates(ids, mask).sum(1).mean())
    b, m_padded = ids.shape

    row = dict(
        workload=name, metric=metric, mode=mode,
        n_db=int(db.shape[0]), n_test=int(q.shape[0]), d=int(db.shape[1]),
        n_trees=n_trees, m_padded=int(m_padded), m_valid=round(m_valid, 1),
        staged_us=round(staged_s / q.shape[0] * 1e6, 2),
        fused_us=round(fused_s / q.shape[0] * 1e6, 2),
        speedup=round(staged_s / fused_s, 3),
        ids_match=ids_match, dist_err=dist_err,
        **traffic_model(b, m_padded, m_valid, db.shape[1]),
    )
    print(f"  {name:12s} staged={row['staged_us']:9.1f}us/q "
          f"fused={row['fused_us']:9.1f}us/q speedup={row['speedup']:.2f}x "
          f"traffic={row['traffic_ratio']:.1f}x ids_match={ids_match}")
    return row


def main(smoke: bool = False, mode: str = "auto") -> dict:
    print(f"[fused_vs_staged] mode={mode} smoke={smoke}")
    if smoke:
        # small batch: serving-shaped, where the staged path's 4-dispatch
        # overhead (the thing fusion removes) is a visible fraction of cost
        workloads = [
            ("fig4_mnist", *mnist_like(n=2000, n_test=32, seed=0)[::2], "l2",
             10, 12),
            ("fig5_iss", *iss_like(n=2000, n_test=32, seed=1)[::2], "chi2",
             10, 12),
        ]
        k, iters = 5, 20
    else:
        workloads = [
            ("fig4_mnist", *mnist_like(n=20000, n_test=512, seed=0)[::2],
             "l2", 40, 12),
            ("fig5_iss", *iss_like(n=20000, n_test=256, seed=1)[::2], "chi2",
             40, 12),
        ]
        k, iters = 10, 5
    rows = [run_workload(name, db, q, metric, n_trees=nt, capacity=c, k=k,
                         mode=mode, iters=iters)
            for name, db, q, metric, nt, c in workloads]
    out = {"rows": rows, "mode": mode, "smoke": smoke,
           "backend": jax.default_backend(),
           "min_speedup": min(r["speedup"] for r in rows),
           "all_ids_match": all(r["ids_match"] for r in rows)}

    os.makedirs(os.path.dirname(os.path.abspath(ARTIFACT)), exist_ok=True)
    with open(ARTIFACT, "w") as f:
        json.dump(out, f, indent=1)
    print(f"  -> {os.path.relpath(ARTIFACT)} "
          f"min_speedup={out['min_speedup']:.2f}x")
    return out


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="tiny corpus for CI (seconds, not minutes)")
    p.add_argument("--mode", default="auto",
                   choices=["auto", "pallas", "ref"])
    args = p.parse_args()
    result = main(smoke=args.smoke, mode=args.mode)
    from benchmarks.common import record
    record({}, "fused_vs_staged", result)   # run.py records for harness runs

"""Paper Fig. 4: NN accuracy vs search cost on MNIST-784, RPF vs LSH.

Paper operating points (real MNIST, N=60000, C=12, r=0.3, K=1):
  L=1   ->  7.7% recall @ ~9/60000 points (0.015%)
  L=80  -> 96.1% @ 0.9% of DB
  L=640 -> 99.99% @ 4.7% of DB
This reproduction uses the deterministic MNIST-statistics generator
(DESIGN.md §7.5); the absolute recall at a given L shifts slightly, the
recall-vs-cost FRONT and the RPF>>LSH dominance are the validated claims.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ForestConfig, build_forest, exact_knn, recall_at_k
from repro.core.forest import gather_candidates, traverse
from repro.core.search import mask_duplicates, rerank_topk
from repro.data.synthetic import mnist_like
from repro.index import IndexSpec, SearchParams, build_index


def run(n_db: int = 20000, n_test: int = 512,
        l_sweep=(1, 2, 5, 10, 20, 40, 80, 160),
        capacity: int = 12, split_ratio: float = 0.3, seed: int = 0) -> dict:
    db_np, _, q_np, _ = mnist_like(n=n_db, n_test=n_test, seed=seed)
    db, q = jnp.asarray(db_np), jnp.asarray(q_np)
    _, true_ids = exact_knn(q, db, k=1, db_chunk=0)

    rows = []
    for L in l_sweep:
        cfg = ForestConfig(n_trees=L, capacity=capacity,
                           split_ratio=split_ratio)
        rcfg = cfg.resolved(n_db)
        t0 = time.perf_counter()
        forest = build_forest(jax.random.key(seed), db, cfg,
                              tree_chunk=64 if L > 64 else 0)
        jax.block_until_ready(forest.thresh)
        build_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        leaves = traverse(forest, q, rcfg.max_depth)
        ids, mask = gather_candidates(forest, leaves, rcfg.leaf_pad)
        mask_d = mask_duplicates(ids, mask)
        d, pred = rerank_topk(q, ids, mask_d, db, k=1, metric="l2",
                              dedup=False)
        jax.block_until_ready(d)
        query_s = time.perf_counter() - t0

        recall = float(recall_at_k(pred, true_ids))
        cost = float(mask_d.sum(1).mean()) / n_db
        rows.append(dict(L=L, recall=recall, frac_searched=cost,
                         build_s=round(build_s, 2),
                         query_us=round(query_s / n_test * 1e6, 1)))
        print(f"  RPF L={L:4d}: recall@1={recall:.4f} "
              f"frac={cost*100:.3f}% build={build_s:.1f}s")
    return {"rpf": rows, "lsh": run_lsh(db_np, q_np, np.asarray(true_ids)),
            "n_db": n_db, "n_test": n_test}


def run_lsh(db: np.ndarray, q: np.ndarray, true_ids: np.ndarray,
            sweeps=((8, 16), (16, 12), (32, 10), (64, 8), (96, 6))) -> list:
    """Cascaded multi-radius LSH (paper's baseline), (n_tables, bits) sweep.

    Runs through the unified index API's lsh-cascade backend: one hash per
    batch per level + the shared fused rerank stage — the same surface the
    forest backends answer, so the comparison is apples-to-apples.
    """
    radii = (0.4, 0.53, 0.63, 0.88)          # the paper's cascade
    rows = []
    n_db, n_test = db.shape[0], q.shape[0]
    params = SearchParams(k=1, min_candidates=1)
    for n_tables, bits in sweeps:
        index = build_index(None, db,
                            IndexSpec(backend="lsh-cascade", lsh_radii=radii,
                                      lsh_tables=n_tables, lsh_bits=bits,
                                      lsh_width_scale=1.0, seed=0))
        t0 = time.perf_counter()
        _, ids = index.search(q, params)
        np.asarray(ids)
        dt = time.perf_counter() - t0
        recall = float((np.asarray(ids)[:, 0] == true_ids[:, 0]).mean())
        frac = index.last_mean_candidates / n_db
        rows.append(dict(n_tables=n_tables, bits=bits, recall=recall,
                         frac_searched=frac,
                         query_us=round(dt / n_test * 1e6, 1)))
        print(f"  LSH T={n_tables:3d} K={bits}: recall@1={recall:.4f} "
              f"frac={frac*100:.3f}%")
    return rows


def main(fast: bool = True):
    print("[fig4] MNIST-784-like, RPF vs cascaded LSH")
    if fast:
        return run(n_db=20000, n_test=512, l_sweep=(1, 2, 5, 10, 20, 40, 80))
    return run(n_db=60000, n_test=2000,
               l_sweep=(1, 2, 5, 10, 20, 40, 80, 160, 320, 640))


if __name__ == "__main__":
    main()

"""Autoscaling benchmark: a 2x-rated burst must scale up, not shed forever.

The serving_slo benchmark proved the degradation ladder keeps a 2x burst
BOUNDED — at the cost of sustained recall shedding, because a static fleet
has no capacity actuator.  This benchmark closes that loop (DESIGN.md §15):

  1. build + tune an index, calibrate the traffic model, derive a
     runner-speed-relative SLO and the single-replica rated QPS (same
     recipe as serving_slo, so the two benchmarks agree on "rated"),
  2. stand up a ONE-replica ``ReplicaFleet`` with the ``Autoscaler``
     control loop running against the calibrated model,
  3. leg 1 (scale-up window): open-loop traffic at 2x the single-replica
     rated QPS — the autoscaler must scale up within the leg,
  4. leg 2 (post-scale window): the same offered load against the
     now-scaled fleet — windowed shed fraction must return to <= 0.01 and
     p999 must stay bounded,
  5. control: the same 2x load against a STATIC single replica — it must
     shed, demonstrating the burst actually exceeds one replica.

Gates (hard flags in tools/bench_history.py):
  scaled_up        autoscaler reached >= 2 replicas during leg 1
  shed_recovered   leg-2 shed fraction <= 0.01
  p999_bounded     no timeouts/failures in either leg, leg-2 p999 <= 10xSLO
  control_sheds    static single replica sheds > 0.01 at the same load
  no_flapping      resize-to-resize gaps respect the autoscaler cooldowns

Usage:
  PYTHONPATH=src python -m benchmarks.autoscale [--smoke]

Writes artifacts/BENCH_autoscale.json (uploaded + gated by CI).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.core import ForestConfig
from repro.index import IndexSpec, build_index, tune
from repro.serve import loadgen, planner
from repro.serve.autoscaler import Autoscaler, AutoscalerConfig, ReplicaFleet
from repro.serve.runtime import ServingRuntime

ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                        "BENCH_autoscale.json")

# same rated-QPS recipe as serving_slo: budget >= 5*t(B) makes the planner
# factor >= 0.56 > 0.5, so 2x rated ALWAYS exceeds one replica's saturation
SLO_SERVICE_MULT = 5.0
UTILIZATION = 0.7
SERVICE_SLEEP_S = 0.010  # added per-batch service cost: pins one replica's
#                          saturation far below the host dispatch ceiling,
#                          so 2x rated is a REPLICA shortage (fixable by
#                          scaling) rather than a GIL shortage (not)


class _SleepIndex:
    """Index proxy adding a fixed per-batch service cost.

    The calibrated traffic model sees the sleep (it measures through the
    runtime), so the planner's rated QPS, the autoscaler's re-plan, and the
    actual service rate all agree — the benchmark then tests the CONTROL
    LOOP, not how many queries a shared CI host can push through Python
    dispatch per second.
    """

    def __init__(self, index, sleep_s: float):
        self._index = index
        self._sleep_s = float(sleep_s)

    def search(self, q, params):
        time.sleep(self._sleep_s)
        return self._index.search(q, params)

    def __getattr__(self, name):
        return getattr(self._index, name)


def run_burst(n_db: int, dim: int, n_trees: int, capacity: int,
              target_recall: float, k: int, max_batch: int,
              leg_s: float, seed: int = 0) -> dict:
    from repro.data.synthetic import clustered_gaussians

    db = clustered_gaussians(n_db, dim, n_clusters=max(16, n_db // 256),
                             seed=seed)
    spec = IndexSpec(backend="rpf",
                     forest=ForestConfig(n_trees=n_trees,
                                         capacity=capacity))
    index = build_index(jax.random.key(seed), db, spec)
    queries = db[np.random.default_rng(seed).integers(0, n_db, size=128)] \
        + 0.003
    tune(index, queries[:64], target_recall=target_recall, k=k,
         probe_grid=(1, 2, 4, 8))
    index = _SleepIndex(index, SERVICE_SLEEP_S)

    # ---- calibrate + derive the runner-relative SLO / rated rate
    probe = ServingRuntime(index, max_batch=max_batch, max_wait_s=0.008)
    model = probe.calibrate(queries, batch_grid=(1, max_batch // 4,
                                                 max_batch))
    probe.stop()
    slo_p99_ms = (model.max_wait_s
                  + SLO_SERVICE_MULT * model.service_s(max_batch)) * 1e3
    rated = planner.rated_qps(model, slo_p99_ms, max_batch,
                              utilization=UTILIZATION)
    if rated <= 0:
        raise RuntimeError(f"planner found no in-SLO rate (model "
                           f"c0={model.c0_s}, c1={model.c1_s})")
    offered = 2.0 * rated
    n_leg = max(200, int(offered * leg_s))

    def make_replica(batch: int | None = None):
        return ServingRuntime(index, slo_p99_ms=slo_p99_ms,
                              max_batch=int(batch or max_batch),
                              max_wait_s=0.008, degrade=True)

    # ---- elastic fleet: 1 replica + the control loop
    cfg = AutoscalerConfig(slo_p99_ms=slo_p99_ms, min_replicas=1,
                           max_replicas=4, interval_s=0.1,
                           cooldown_s=0.5, scale_down_cooldown_s=30.0,
                           utilization=UTILIZATION, demand_smoothing=0.7)
    fleet = ReplicaFleet(make_replica, n_replicas=1, batch=max_batch)
    scaler = Autoscaler(fleet, model, cfg, batch=max_batch).start()
    leg1 = loadgen.run_open_loop(fleet, queries, offered,
                                 n_requests=n_leg, seed=1)
    replicas_after_leg1 = fleet.n_replicas
    leg2 = loadgen.run_open_loop(fleet, queries, offered,
                                 n_requests=n_leg, seed=2)
    scaler.stop()
    decisions = [d for d in scaler.history if d["action"] != "hold"]
    fleet_stats = fleet.stats()
    fleet.stop()

    # ---- static control: same load, one replica, no control loop
    control = make_replica()
    ctl = loadgen.run_open_loop(control, queries, offered,
                                n_requests=n_leg, seed=1)
    control.stop()

    # flapping check: consecutive resize decisions must respect the
    # tighter of the two cooldowns (scale-downs are blocked for 30s here,
    # so in practice this checks scale-up spacing)
    ts = [d["t"] for d in decisions]
    gaps = [b - a for a, b in zip(ts, ts[1:])]
    min_gap = min(gaps) if gaps else float("inf")

    return {
        "n_db": n_db, "dim": dim, "n_trees": n_trees, "k": k,
        "max_batch": max_batch,
        "traffic_model": model.to_dict(),
        "slo_p99_ms": round(slo_p99_ms, 3),
        "rated_qps_1replica": round(rated, 1),
        "offered_qps": round(offered, 1),
        "n_requests_per_leg": n_leg,
        "replicas_after_leg1": replicas_after_leg1,
        "replicas_final": fleet_stats["n_replicas"],
        "resizes": fleet_stats["resizes"],
        "decisions": decisions,
        "min_resize_gap_s": (round(min_gap, 3)
                             if min_gap != float("inf") else None),
        "scaleup_leg": leg1,
        "scaled_leg": leg2,
        "static_control": ctl,
    }


def main(smoke: bool = False) -> dict:
    print(f"[autoscale] smoke={smoke}")
    if smoke:
        row = run_burst(n_db=20000, dim=64, n_trees=32, capacity=32,
                        target_recall=0.9, k=10, max_batch=8, leg_s=4.0)
    else:
        row = run_burst(n_db=60000, dim=128, n_trees=40, capacity=32,
                        target_recall=0.95, k=10, max_batch=32, leg_s=6.0)
    slo = row["slo_p99_ms"]
    leg1, leg2, ctl = row["scaleup_leg"], row["scaled_leg"], \
        row["static_control"]
    scaled_up = row["replicas_after_leg1"] >= 2
    shed_recovered = leg2["shed_fraction"] <= 0.01
    p999_bounded = (leg1["n_timeout"] == 0 and leg1["n_failed"] == 0
                    and leg2["n_timeout"] == 0 and leg2["n_failed"] == 0
                    and leg2["p999_ms"] <= 10.0 * slo)
    control_sheds = ctl["shed_fraction"] > 0.01
    no_flapping = (row["min_resize_gap_s"] is None
                   or row["min_resize_gap_s"] >= 0.5 * 0.95)
    print(f"  rated {row['rated_qps_1replica']} qps/replica @ "
          f"p99<={slo:.1f}ms; offered {row['offered_qps']} qps (2x)")
    print(f"  leg1 (scale-up): p99={leg1['p99_ms']:.1f}ms "
          f"shed={leg1['shed_fraction']:.1%} -> "
          f"{row['replicas_after_leg1']} replicas ({row['resizes']} "
          f"resizes) -> scaled_up={scaled_up}")
    print(f"  leg2 (scaled):   p99={leg2['p99_ms']:.1f}ms "
          f"p999={leg2['p999_ms']:.1f}ms shed={leg2['shed_fraction']:.1%} "
          f"-> shed_recovered={shed_recovered} p999_bounded={p999_bounded}")
    print(f"  static control:  p99={ctl['p99_ms']:.1f}ms "
          f"shed={ctl['shed_fraction']:.1%} -> control_sheds={control_sheds}")
    print(f"  min resize gap {row['min_resize_gap_s']}s -> "
          f"no_flapping={no_flapping}")
    out = {**row, "smoke": smoke, "backend": jax.default_backend(),
           "scaled_up": scaled_up, "shed_recovered": shed_recovered,
           "p999_bounded": p999_bounded, "control_sheds": control_sheds,
           "no_flapping": no_flapping,
           # the history-gated headline metric
           "shed_after_scaleup": leg2["shed_fraction"]}
    os.makedirs(os.path.dirname(os.path.abspath(ARTIFACT)), exist_ok=True)
    with open(ARTIFACT, "w") as f:
        json.dump(out, f, indent=1)
    print(f"  -> {os.path.relpath(ARTIFACT)}")
    return out


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="CI-size corpus + short legs (tens of seconds)")
    args = p.parse_args()
    t0 = time.perf_counter()
    result = main(smoke=args.smoke)
    print(f"[autoscale] total {time.perf_counter() - t0:.1f}s")
    from benchmarks.common import record
    record({}, "autoscale", result)

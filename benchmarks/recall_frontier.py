"""Probes-vs-trees recall frontier: the accuracy/cost surface of DESIGN.md §9.

The paper's only recall knob is L (trees), which multiplies BOTH build
memory and query cost.  Multi-probe traversal reaches the same recall from
far fewer trees by descending to the ``n_probes`` most marginal leaves per
tree.  This benchmark sweeps the (n_trees, n_probes) grid on one built
forest (both are search-time knobs — ``SearchParams(n_trees=…, n_probes=…)``
— so one build serves the whole sweep), measuring recall@k against the
brute-force oracle and p50 query latency.

Headline numbers (the CI acceptance gate):
  * ``single_probe_trees_at_target`` — fewest trees reaching the target
    recall with the paper's single descent,
  * ``multi_probe_trees_at_target``  — fewest trees reaching it with any
    n_probes > 1,
  * ``trees_saved_ratio``            — their ratio (>= 2 expected: the
    multi-probe frontier dominates), asserted by the CI bench-smoke job.

Usage:
  PYTHONPATH=src python -m benchmarks.recall_frontier [--smoke]
      [--target-recall 0.95] [--k 10]

Writes artifacts/BENCH_recall_frontier.json (the perf-trajectory artifact
CI uploads) and merges into artifacts/bench_results.json.  docs/TUNING.md
walks a worked example over this output.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import record
from repro.core import ForestConfig, exact_knn, recall_at_k
from repro.data.synthetic import mnist_like
from repro.index import IndexSpec, SearchParams, build_index

ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                        "BENCH_recall_frontier.json")


def _p50_us(index, q, params, iters: int) -> float:
    """Median per-query latency (jit-warm) of index.search under params."""
    jax.block_until_ready(index.search(q, params))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(index.search(q, params))
        times.append(time.perf_counter() - t0)
    return float(np.median(times) / q.shape[0] * 1e6)


def run(n: int, n_test: int, trees_grid: list[int], probes_grid: list[int],
        k: int, target: float, iters: int, capacity: int = 24) -> dict:
    db, _, queries, _ = mnist_like(n=n, n_test=n_test, seed=0)
    print(f"  corpus: mnist-statistics n={n} d={db.shape[1]} "
          f"B={n_test} k={k} target={target}")
    _, true_ids = exact_knn(jax.numpy.asarray(queries),
                            jax.numpy.asarray(db), k=k)

    l_max = max(trees_grid)
    cfg = ForestConfig(n_trees=l_max, capacity=capacity, split_ratio=0.3)
    index = build_index(jax.random.key(0), db,
                        IndexSpec(backend="rpf", forest=cfg))
    leaf_pad = cfg.resolved(n).leaf_pad

    rows = []
    for t in trees_grid:
        for p in probes_grid:
            if t * p > l_max:
                # beyond the single-probe baseline's candidate budget —
                # off the interesting side of the frontier; skip to keep
                # the CI smoke sweep bounded
                continue
            params = SearchParams(k=k, n_trees=t, n_probes=p)
            _, ids = index.search(queries, params)
            rec = float(recall_at_k(ids, true_ids))
            p50 = _p50_us(index, queries, params, iters)
            rows.append(dict(n_trees=t, n_probes=p, recall=round(rec, 4),
                             p50_us=round(p50, 1),
                             candidate_rows=t * p * leaf_pad))
            print(f"  L={t:3d} probes={p:2d}: recall@{k}={rec:.3f} "
                  f"p50={p50:8.1f}us/q rows={t * p * leaf_pad}")

    def fewest_trees(pred):
        hit = [r["n_trees"] for r in rows if pred(r) and r["recall"] >= target]
        return min(hit) if hit else None

    single = fewest_trees(lambda r: r["n_probes"] == 1)
    multi = fewest_trees(lambda r: r["n_probes"] > 1)
    return dict(rows=rows, n=n, d=int(db.shape[1]), k=k,
                target_recall=target, leaf_pad=leaf_pad,
                trees_grid=trees_grid, probes_grid=probes_grid,
                single_probe_trees_at_target=single,
                multi_probe_trees_at_target=multi,
                trees_saved_ratio=(round(single / multi, 2)
                                   if single and multi else None),
                frontier_ok=bool(multi is not None
                                 and (single is None or multi * 2 <= single)))


def main(smoke: bool = False, target: float = 0.95, k: int = 10) -> dict:
    print(f"[recall_frontier] smoke={smoke}")
    if smoke:
        out = run(n=4000, n_test=64, trees_grid=[8, 16, 32, 64, 128],
                  probes_grid=[1, 2, 4, 8], k=k, target=target, iters=3)
    else:
        out = run(n=20000, n_test=256, trees_grid=[8, 16, 32, 64, 128, 256],
                  probes_grid=[1, 2, 4, 8, 16], k=k, target=target, iters=9)
    out.update(smoke=smoke, backend=jax.default_backend())

    os.makedirs(os.path.dirname(os.path.abspath(ARTIFACT)), exist_ok=True)
    with open(ARTIFACT, "w") as f:
        json.dump(out, f, indent=1)
    record({}, "recall_frontier", out)
    print(f"  -> {os.path.relpath(ARTIFACT)} "
          f"single_probe_trees={out['single_probe_trees_at_target']} "
          f"multi_probe_trees={out['multi_probe_trees_at_target']} "
          f"frontier_ok={out['frontier_ok']}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI-size sweep")
    ap.add_argument("--target-recall", type=float, default=0.95)
    ap.add_argument("--k", type=int, default=10)
    a = ap.parse_args()
    main(smoke=a.smoke, target=a.target_recall, k=a.k)

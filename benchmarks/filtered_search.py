"""Filtered + multi-metric search benchmark (DESIGN.md §13).

Measures recall-vs-filtered-oracle and per-query latency across the two
grids the subsystem promises:

  1. every backend x selectivity {0.5, 0.1, 0.01} under l2 — covers both
     the selectivity-aware plans (widened index probe at broad filters,
     exact matching-row scan below the brute-force thresholds), and
  2. every metric (l2 / cosine / ip / chi2) x selectivity on the
     rpf+int8 backend — the int8 coarse stage scoring under the metric
     rides end to end.

The oracle per cell is the exact brute force over the rows MATCHING the
predicate (recall against the unfiltered oracle would reward returning
non-matching rows).

Usage:
  PYTHONPATH=src python -m benchmarks.filtered_search [--smoke]

Writes artifacts/BENCH_filtered_search.json (uploaded + gated by CI:
``recall_001_ok`` — recall@10 >= 0.9 on ALL FOUR backends at selectivity
0.01 — and ``recall_all_ok`` are hard gates in tools/bench_history.py).
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timer
from repro.core import ForestConfig
from repro.core.distances import PAIRWISE, canonical_metric
from repro.filter import Range
from repro.filter.predicate import use_brute_force
from repro.index import IndexSpec, SearchParams, build_index

ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                        "BENCH_filtered_search.json")

SELECTIVITIES = (0.5, 0.1, 0.01)
METRICS = ("l2", "cosine", "ip", "chi2")
BACKENDS = ("bruteforce", "rpf", "rpf+int8", "lsh-cascade")
RECALL_FLOOR_001 = 0.9     # the CI acceptance gate at selectivity 0.01
RECALL_FLOOR_ALL = 0.85    # every cell, both grids


def _corpus(n: int, d: int, n_q: int, seed: int):
    """Non-negative, unit-norm clustered rows (all four metrics compose)
    + a uniform int 'bucket' column giving exact selectivity slices."""
    from repro.data.synthetic import clustered_gaussians
    db = np.abs(clustered_gaussians(n, d, n_clusters=max(16, n // 1024),
                                    seed=seed))
    db /= np.linalg.norm(db, axis=1, keepdims=True)
    rng = np.random.default_rng(seed + 1)
    q = np.abs(db[rng.integers(0, n, n_q)]
               + 0.003 * rng.normal(size=(n_q, d)).astype(np.float32))
    meta = {"bucket": rng.integers(0, 1000, n).astype(np.int64)}
    return db, q, meta


def _predicate(selectivity: float):
    return Range("bucket", 0, int(round(1000 * selectivity)) - 1)


def _oracle_ids(q, db, mask, metric, k):
    rows = db[mask]
    gids = np.where(mask)[0]
    d = np.asarray(PAIRWISE[canonical_metric(metric)](
        jnp.asarray(q), jnp.asarray(rows)))
    out = []
    for row in d:
        order = np.lexsort((gids, row))
        out.append(set(gids[order[:k]].tolist()))
    return out


def _base_params(backend: str, k: int) -> SearchParams:
    if backend in ("rpf", "rpf+int8"):
        return SearchParams(k=k, n_probes=4)
    if backend == "lsh-cascade":
        return SearchParams(k=k, min_candidates=16 * k)
    return SearchParams(k=k)


def _cell(index, db, q, meta, backend: str, metric: str,
          selectivity: float, k: int) -> dict:
    import dataclasses
    pred = _predicate(selectivity)
    mask = (meta["bucket"] >= 0) & (meta["bucket"] <= pred.hi)
    n_match = int(mask.sum())
    params = dataclasses.replace(_base_params(backend, k), metric=metric,
                                 filter=pred)
    us, (_, ids) = timer(lambda: index.search(q, params), iters=3)
    want = _oracle_ids(q, db, mask, metric, k)
    ids = np.asarray(ids)
    hit = np.mean([len(set(r[r >= 0].tolist()) & want[i]) / k
                   for i, r in enumerate(ids)])
    leaked = int(sum((~mask[r[r >= 0]]).sum() for r in ids))
    return {
        "backend": backend, "metric": metric, "selectivity": selectivity,
        "n_match": n_match,
        "plan": ("brute" if use_brute_force(n_match / len(db), n_match)
                 else "widened"),
        "recall": round(float(hit), 4),
        "non_matching_returned": leaked,          # must be 0 by contract
        "us_per_query": round(us * 1e6 / len(q), 1),
    }


def run_filtered(n_db: int, dim: int, n_q: int, k: int, n_trees: int,
                 capacity: int, seed: int = 0) -> dict:
    db, q, meta = _corpus(n_db, dim, n_q, seed)
    spec_kw = dict(forest=ForestConfig(n_trees=n_trees, capacity=capacity),
                   lsh_radii=(0.5, 1.0, 2.0), lsh_tables=8, lsh_bits=10,
                   seed=seed)
    rows = []
    for backend in BACKENDS:
        index = build_index(jax.random.key(seed), db,
                            IndexSpec(backend=backend, **spec_kw),
                            metadata=meta)
        for s in SELECTIVITIES:
            rows.append(_cell(index, db, q, meta, backend, "l2", s, k))
            print("  " + ", ".join(f"{kk}={vv}"
                                   for kk, vv in rows[-1].items()))
        if backend == "rpf+int8":                 # grid 2 on the int8 path
            for metric in METRICS:
                if metric == "l2":
                    continue                      # grid 1 covered it
                for s in SELECTIVITIES:
                    rows.append(_cell(index, db, q, meta, backend, metric,
                                      s, k))
                    print("  " + ", ".join(f"{kk}={vv}"
                                           for kk, vv in rows[-1].items()))
    return {"n_db": n_db, "dim": dim, "n_q": n_q, "k": k,
            "n_trees": n_trees, "rows": rows}


def main(smoke: bool = False) -> dict:
    print(f"[filtered_search] smoke={smoke}")
    if smoke:
        result = run_filtered(n_db=20_000, dim=32, n_q=32, k=10,
                              n_trees=16, capacity=32)
    else:
        result = run_filtered(n_db=60_000, dim=64, n_q=64, k=10,
                              n_trees=32, capacity=32)
    rows = result["rows"]
    cells_001 = [r for r in rows if r["selectivity"] == 0.01]
    recall_001_ok = (
        {r["backend"] for r in cells_001 if r["metric"] == "l2"}
        == set(BACKENDS)
        and all(r["recall"] >= RECALL_FLOOR_001 for r in cells_001))
    recall_all_ok = all(r["recall"] >= RECALL_FLOOR_ALL for r in rows)
    no_leaks = all(r["non_matching_returned"] == 0 for r in rows)
    worst = min(rows, key=lambda r: r["recall"])
    print(f"  worst cell: {worst['backend']}/{worst['metric']}"
          f"@s={worst['selectivity']} recall={worst['recall']}")
    print(f"  recall_001_ok={recall_001_ok} recall_all_ok={recall_all_ok} "
          f"no_leaks={no_leaks}")
    out = {**result, "smoke": smoke, "backend_jax": jax.default_backend(),
           "worst_recall": worst["recall"],
           "recall_001_ok": recall_001_ok,
           "recall_all_ok": recall_all_ok,
           "no_leaks": no_leaks}
    os.makedirs(os.path.dirname(os.path.abspath(ARTIFACT)), exist_ok=True)
    with open(ARTIFACT, "w") as f:
        json.dump(out, f, indent=1)
    print(f"  -> {os.path.relpath(ARTIFACT)}")
    return out


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="CI-size corpus (tens of seconds)")
    args = p.parse_args()
    result = main(smoke=args.smoke)
    from benchmarks.common import record
    record({}, "filtered_search", result)

"""Paper-technique integration benchmark: recsys retrieval_cand via RPF.

Compares, for multi-interest (MIND-style) retrieval over a 1M-item catalog
(scaled down for CPU wall-clock):
  * brute force: fused score+top-k over all candidates (kernels/matmul_topk),
  * RPF index:   forest-pruned candidates + exact rerank (the paper).
Reports recall@k of RPF vs brute force and the candidate-reduction factor.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ForestConfig
from repro.core.knn import exact_knn
from repro.data.synthetic import clustered_gaussians
from repro.index import IndexSpec, SearchParams, build_index


def run(n_items: int = 100_000, d: int = 64, n_users: int = 64,
        n_interests: int = 4, L: int = 40, k: int = 20) -> dict:
    items = clustered_gaussians(n_items, d, n_clusters=256, seed=3)
    items /= np.linalg.norm(items, axis=1, keepdims=True)
    rng = np.random.default_rng(0)
    # interests = perturbed item vectors (as a trained tower would produce)
    seeds = rng.integers(0, n_items, size=(n_users, n_interests))
    interests = items[seeds] + 0.05 * rng.normal(
        size=(n_users, n_interests, d)).astype(np.float32)

    items_j = jnp.asarray(items)
    flat = jnp.asarray(interests.reshape(-1, d))

    # brute force (max over interests of dot): top-k per interest then merge
    t0 = time.perf_counter()
    bf_d, bf_i = exact_knn(flat, items_j, k=k, metric="dot")
    jax.block_until_ready(bf_d)
    brute_s = time.perf_counter() - t0

    # RPF over items with L2 on unit vectors (equivalent ordering to dot),
    # through the unified index API (the serving surface)
    cfg = ForestConfig(n_trees=L, capacity=12, split_ratio=0.3)
    t0 = time.perf_counter()
    index = build_index(jax.random.key(0), items,
                        IndexSpec(backend="rpf", forest=cfg, tree_chunk=64))
    jax.block_until_ready(index.forest.thresh)
    build_s = time.perf_counter() - t0
    params = SearchParams(k=k, metric="l2")
    t0 = time.perf_counter()
    rpf_d, rpf_i = index.search(flat, params)
    jax.block_until_ready(rpf_d)
    rpf_s = time.perf_counter() - t0

    # recall of RPF vs brute-force truth (per interest-query)
    hits = (np.asarray(rpf_i)[:, :, None]
            == np.asarray(bf_i)[:, None, :]).any(1).mean()
    rcfg = cfg.resolved(n_items)
    out = dict(n_items=n_items, L=L, k=k,
               recall_vs_brute=float(hits),
               brute_us=round(brute_s / flat.shape[0] * 1e6, 1),
               rpf_us=round(rpf_s / flat.shape[0] * 1e6, 1),
               speedup=round(brute_s / rpf_s, 2),
               candidates_per_query=L * rcfg.leaf_pad,
               reduction=round(n_items / (L * rcfg.leaf_pad), 1),
               build_s=round(build_s, 1))
    print(f"  RPF recall@{k} vs brute = {hits:.3f}; "
          f"{out['reduction']}x candidate reduction; "
          f"{out['speedup']}x wall-clock on CPU")
    return out


def main(fast: bool = True):
    print("[retrieval] recsys retrieval_cand: RPF index vs brute force")
    if fast:
        return run(n_items=100_000)
    return run(n_items=1_000_000, L=80)


if __name__ == "__main__":
    main()

"""Paper-technique integration benchmark: recsys retrieval_cand via RPF.

Compares, for multi-interest (MIND-style) retrieval over a 1M-item catalog
(scaled down for CPU wall-clock):
  * brute force: fused score+top-k over all candidates (kernels/matmul_topk),
  * RPF index:   forest-pruned candidates + exact rerank (the paper).
Reports recall@k of RPF vs brute force and the candidate-reduction factor.

Usage:
  PYTHONPATH=src python -m benchmarks.retrieval_compare
      [--target-recall R] [--full] [--trees L]

``--target-recall`` routes the search knobs through the recall-targeted
tuner (``repro.index.tune``, DESIGN.md §9) on a held-out interest sample —
the recommended spelling.  ``--trees`` (the old hand-picked L) survives as
a DEPRECATED alias that pins the single-probe configuration.
"""
from __future__ import annotations

import argparse
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ForestConfig
from repro.core.knn import exact_knn
from repro.data.synthetic import clustered_gaussians
from repro.index import IndexSpec, SearchParams, build_index, tune


def run(n_items: int = 100_000, d: int = 64, n_users: int = 64,
        n_interests: int = 4, L: int = 40, k: int = 20,
        target_recall: float | None = None) -> dict:
    items = clustered_gaussians(n_items, d, n_clusters=256, seed=3)
    items /= np.linalg.norm(items, axis=1, keepdims=True)
    rng = np.random.default_rng(0)
    # interests = perturbed item vectors (as a trained tower would produce)
    seeds = rng.integers(0, n_items, size=(n_users, n_interests))
    interests = items[seeds] + 0.05 * rng.normal(
        size=(n_users, n_interests, d)).astype(np.float32)

    items_j = jnp.asarray(items)
    flat = jnp.asarray(interests.reshape(-1, d))

    # brute force (max over interests of dot): top-k per interest then merge
    t0 = time.perf_counter()
    bf_d, bf_i = exact_knn(flat, items_j, k=k, metric="dot")
    jax.block_until_ready(bf_d)
    brute_s = time.perf_counter() - t0

    # RPF over items with L2 on unit vectors (equivalent ordering to dot),
    # through the unified index API (the serving surface)
    cfg = ForestConfig(n_trees=L, capacity=12, split_ratio=0.3)
    t0 = time.perf_counter()
    index = build_index(jax.random.key(0), items,
                        IndexSpec(backend="rpf", forest=cfg, tree_chunk=64))
    jax.block_until_ready(index.forest.thresh)
    build_s = time.perf_counter() - t0
    if target_recall is not None:
        # tune on a DISJOINT interest sample drawn the same way (the
        # reported recall stays honestly held out from the tuning set;
        # the tuner's oracle is its own exact k-NN over the index rows)
        tune_seeds = rng.integers(0, n_items, size=64)
        tune_q = (items[tune_seeds] + 0.05 * rng.normal(
            size=(64, d)).astype(np.float32))
        params = tune(index, tune_q, target_recall=target_recall, k=k)
        print(f"  tuned for recall@{k} >= {target_recall}: "
              f"n_trees={params.n_trees or L}, n_probes={params.n_probes}")
    else:
        params = SearchParams(k=k, metric="l2")
    t0 = time.perf_counter()
    rpf_d, rpf_i = index.search(flat, params)
    jax.block_until_ready(rpf_d)
    rpf_s = time.perf_counter() - t0

    # recall of RPF vs brute-force truth (per interest-query)
    hits = (np.asarray(rpf_i)[:, :, None]
            == np.asarray(bf_i)[:, None, :]).any(1).mean()
    rcfg = cfg.resolved(n_items)
    trees_used = params.n_trees or L
    cand = trees_used * params.n_probes * rcfg.leaf_pad
    out = dict(n_items=n_items, L=L, k=k,
               trees_used=trees_used, n_probes=params.n_probes,
               target_recall=target_recall,
               recall_vs_brute=float(hits),
               brute_us=round(brute_s / flat.shape[0] * 1e6, 1),
               rpf_us=round(rpf_s / flat.shape[0] * 1e6, 1),
               speedup=round(brute_s / rpf_s, 2),
               candidates_per_query=cand,
               reduction=round(n_items / cand, 1),
               build_s=round(build_s, 1))
    print(f"  RPF recall@{k} vs brute = {hits:.3f}; "
          f"{out['reduction']}x candidate reduction; "
          f"{out['speedup']}x wall-clock on CPU")
    return out


def main(fast: bool = True, target_recall: float | None = None,
         trees: int | None = None):
    print("[retrieval] recsys retrieval_cand: RPF index vs brute force")
    if trees is not None:
        warnings.warn("--trees/-L is deprecated: state a --target-recall "
                      "and let repro.index.tune pick the knobs "
                      "(docs/TUNING.md)", DeprecationWarning, stacklevel=2)
    if fast:
        return run(n_items=100_000, L=trees or 40,
                   target_recall=target_recall)
    return run(n_items=1_000_000, L=trees or 80, target_recall=target_recall)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--target-recall", type=float, default=None,
                    help="route search knobs through repro.index.tune")
    ap.add_argument("--trees", "-L", type=int, default=None,
                    help="DEPRECATED: hand-picked tree count (old spelling)")
    ap.add_argument("--full", action="store_true",
                    help="1M-item catalog (minutes on CPU)")
    a = ap.parse_args()
    main(fast=not a.full, target_recall=a.target_recall, trees=a.trees)

"""Inject generated tables + bench numbers into EXPERIMENTS.md markers."""
from __future__ import annotations

import json
import os

from repro.roofline import load_artifacts, merged_table
from benchmarks.make_experiments_tables import (dryrun_table, roofline_md,
                                                variants_md)

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def paper_validation_md() -> str:
    path = os.path.join(ROOT, "artifacts", "bench_results.json")
    if not os.path.exists(path):
        return "_(run `python -m benchmarks.run` to populate)_"
    with open(path) as f:
        r = json.load(f)
    out = []
    if "fig4_mnist" in r:
        out.append("**Fig. 4 (MNIST-784-like, L2).** RPF recall@1 vs fraction"
                   " searched:")
        out.append("")
        out.append("| L | recall@1 | % of DB searched |  | LSH (T,K) | recall@1 | % searched |")
        out.append("|---|---|---|---|---|---|---|")
        rpf = r["fig4_mnist"]["rpf"]
        lsh = r["fig4_mnist"]["lsh"]
        for i in range(max(len(rpf), len(lsh))):
            a = rpf[i] if i < len(rpf) else None
            b = lsh[i] if i < len(lsh) else None
            out.append(
                "| " + (f"{a['L']} | {a['recall']:.3f} | "
                        f"{a['frac_searched']*100:.3f}" if a else " | | ")
                + " |  | "
                + (f"({b['n_tables']},{b['bits']}) | {b['recall']:.3f} | "
                   f"{b['frac_searched']*100:.3f}" if b else " | | ") + " |")
        out.append("")
    if "fig5_iss" in r:
        out.append("**Fig. 5 (ISS-595-like, chi-square).**")
        out.append("")
        out.append("| L | recall@1 | % searched |")
        out.append("|---|---|---|")
        for a in r["fig5_iss"]["rpf"]:
            out.append(f"| {a['L']} | {a['recall']:.3f} | "
                       f"{a['frac_searched']*100:.3f} |")
        for b in r["fig5_iss"]["lsh"]:
            out.append(f"| LSH({b['n_tables']},{b['bits']}) | "
                       f"{b['recall']:.3f} | {b['frac_searched']*100:.3f} |")
        out.append("")
    if "speedup_table" in r:
        s = r["speedup_table"]
        out.append(f"**Speedup vs exhaustive** (N={s['n_db']}, chi2, L={s['L']}): "
                   f"{s['wallclock_speedup']}× wall-clock on this CPU, "
                   f"{s['bytes_speedup']}× bytes-touched (hardware-"
                   f"independent), recall {s['recall']:.3f} "
                   f"(paper: {s['paper_claim']}).")
    if "tree_stats" in r:
        t = r["tree_stats"]
        out.append(f"\n**Tree structure** (§3.4): occupancy max "
                   f"{t['occ_max']:.0f} (C=12; tie-bound fat leaves), "
                   f"mean depth {t['depth_mean']:.1f} "
                   f"(paper formula ~{t['paper_expected_depth']}).")
    if "retrieval_compare" in r:
        t = r["retrieval_compare"]
        out.append(f"\n**RecSys retrieval integration**: RPF recall@{t['k']} "
                   f"vs brute = {t['recall_vs_brute']:.3f} at "
                   f"{t['reduction']}× candidate reduction "
                   f"({t['n_items']}-item catalog).")
    return "\n".join(out)


def main():
    arts = load_artifacts()
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(path) as f:
        doc = f.read()
    doc = doc.replace("<!-- PAPER_VALIDATION -->", paper_validation_md())
    doc = doc.replace("<!-- DRYRUN_TABLE -->", dryrun_table(arts))
    doc = doc.replace("<!-- ROOFLINE_TABLE -->", roofline_md())
    doc = doc.replace("<!-- VARIANTS_TABLE -->", variants_md(arts))
    with open(path, "w") as f:
        f.write(doc)
    print("EXPERIMENTS.md assembled;",
          len(arts), "artifacts,", len(merged_table()), "roofline rows")


if __name__ == "__main__":
    main()

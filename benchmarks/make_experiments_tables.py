"""Generate the EXPERIMENTS.md §Dry-run and §Roofline markdown tables from
the dry-run artifacts.  Run after `dryrun --all` (+ unroll variants):

  PYTHONPATH=src python -m benchmarks.make_experiments_tables > artifacts/tables.md
"""
from __future__ import annotations

import json

from repro.configs import ASSIGNED, get_arch
from repro.roofline import load_artifacts, merged_table, roofline_terms


def dryrun_table(arts: dict) -> str:
    rows = ["| arch | cell | mesh | compile s | HLO GFLOP/dev | temp GiB/dev "
            "| coll GiB/dev | collective mix |",
            "|---|---|---|---|---|---|---|---|"]
    for arch_id in ASSIGNED:
        for cell in get_arch(arch_id).cells:
            for mesh in ("single", "multipod"):
                r = arts.get((arch_id, cell.name, mesh, "base"))
                if r is None:
                    if cell.skip and mesh == "single":
                        rows.append(f"| {arch_id} | {cell.name} | — | — | — "
                                    f"| — | — | SKIPPED: {cell.skip_reason} |")
                    continue
                mix = ", ".join(
                    f"{k.split('-')[1] if '-' in k else k}:"
                    f"{v/2**30:.2f}G"
                    for k, v in r["collectives"]["bytes"].items() if v)
                rows.append(
                    f"| {arch_id} | {cell.name} | {mesh} "
                    f"| {r['compile_s']} "
                    f"| {r['cost']['flops']/1e9:.1f} "
                    f"| {r['memory']['temp_bytes']/2**30:.2f} "
                    f"| {r['collectives']['total_bytes']/2**30:.3f} "
                    f"| {mix or '—'} |")
    return "\n".join(rows)


def roofline_md(mesh: str = "single") -> str:
    rows = merged_table(mesh=mesh)
    out = ["| arch | cell | compute s | memory s | collective s | dominant "
           "| MODEL_FLOPS | MF/HLO ratio | RL fraction | temp GiB | fits "
           "| src |",
           "|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for t in rows:
        out.append(
            f"| {t['arch']} | {t['cell']} "
            f"| {t['compute_s']:.3e} | {t['memory_s']:.3e} "
            f"| {t['collective_s']:.3e} | **{t['dominant']}** "
            f"| {t['model_flops']:.2e} | {t['model_flops_ratio']:.3f} "
            f"| {t['roofline_fraction']:.3f} | {t['temp_gib']:.1f} "
            f"| {'Y' if t['fits_hbm'] else 'N'} | {t['traffic_source']} |")
    return "\n".join(out)


def variants_md(arts: dict) -> str:
    """All non-base variants vs their base (the §Perf raw numbers)."""
    out = ["| arch/cell | variant | GFLOP/dev | mem GB acc/dev | coll GiB/dev"
           " | temp GiB |", "|---|---|---|---|---|---|"]
    for (arch, cell, mesh, variant), r in sorted(arts.items()):
        if mesh != "single":
            continue
        out.append(
            f"| {arch}/{cell} | {variant} "
            f"| {r['cost']['flops']/1e9:.1f} "
            f"| {r['cost']['bytes_accessed']/1e9:.1f} "
            f"| {r['collectives']['total_bytes']/2**30:.3f} "
            f"| {r['memory']['temp_bytes']/2**30:.2f} |")
    return "\n".join(out)


def main():
    arts = load_artifacts()
    print("## §Dry-run (scan/base variants; both production meshes)\n")
    print(dryrun_table(arts))
    print("\n\n## §Roofline (single pod; traffic from unroll variants)\n")
    print(roofline_md())
    print("\n\n## §Variants (raw per-variant numbers)\n")
    print(variants_md(arts))


if __name__ == "__main__":
    main()

"""Quickstart: build a random-partition-forest index and query it.

  PYTHONPATH=src python examples/quickstart.py

The 60-second version of the paper: index 20k 784-D vectors, query with
exact-NN ground truth, watch recall rise with L at a tiny search cost.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ForestConfig, build_forest, exact_knn, query_forest,
                        recall_at_k)
from repro.data.synthetic import mnist_like


def main():
    print("generating MNIST-statistics data (offline stand-in)...")
    db, _, queries, _ = mnist_like(n=20_000, n_test=256)
    db, queries = jnp.asarray(db), jnp.asarray(queries)

    print("exact ground truth...")
    _, true_ids = exact_knn(queries, db, k=1)

    for L in (5, 20, 80):
        cfg = ForestConfig(n_trees=L, capacity=12, split_ratio=0.3)
        forest = build_forest(jax.random.key(0), db, cfg)
        dists, ids = query_forest(forest, queries, db, k=1, cfg=cfg)
        rec = float(recall_at_k(ids, true_ids))
        frac = L * cfg.resolved(db.shape[0]).leaf_pad / db.shape[0]
        print(f"L={L:3d} trees: recall@1 = {rec:.3f}, "
              f"<= {frac*100:.2f}% of the DB touched per query")

    # k-NN search with the chi-square metric (the paper's ISS experiment)
    db_h = jnp.abs(db)
    cfg = ForestConfig(n_trees=40, capacity=12)
    forest = build_forest(jax.random.key(1), db_h, cfg)
    d, ids = query_forest(forest, db_h[:8], db_h, k=3, cfg=cfg,
                          metric="chi2")
    print("chi2 3-NN of first db point:", np.asarray(ids[0]),
          "dists", np.round(np.asarray(d[0]), 5))


if __name__ == "__main__":
    main()

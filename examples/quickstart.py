"""Quickstart: the unified index API over every backend.

  PYTHONPATH=src python examples/quickstart.py [--tiny] [--target-recall R]

The 60-second version of the paper through the one public surface
(repro.index): build an IndexSpec per backend, search with SearchParams,
watch recall rise with L at a tiny search cost — then compose the
beyond-paper knobs (multi-probe descent, int8 shortlist, early-exit waves)
with the same call, and let the recall-targeted tuner pick the cheapest
operating point (docs/TUNING.md).  ``--tiny`` shrinks the corpus for the
CI examples-smoke job; ``--target-recall`` sets the tuner's goal (the old
way — hand-picking L per backend — still works and is shown first, but
the tuner is the recommended spelling).
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ForestConfig, exact_knn, recall_at_k
from repro.data.synthetic import mnist_like
from repro.index import IndexSpec, SearchParams, build_index, tune


def main(tiny: bool = False, target_recall: float = 0.9):
    n, n_test = (2_000, 128) if tiny else (20_000, 256)
    print(f"generating MNIST-statistics data (offline stand-in, n={n})...")
    db, _, queries, _ = mnist_like(n=n, n_test=n_test)
    db_j, q_j = jnp.asarray(db), jnp.asarray(queries)

    print("exact ground truth (the bruteforce backend is the same oracle)...")
    _, true_ids = exact_knn(q_j, db_j, k=1)

    # ---- one spec per operating point; one search call for all of them ----
    for L in (5, 20) if tiny else (5, 20, 80):
        cfg = ForestConfig(n_trees=L, capacity=12, split_ratio=0.3)
        index = build_index(jax.random.key(0), db,
                            IndexSpec(backend="rpf", forest=cfg))
        _, ids = index.search(queries, SearchParams(k=1))
        rec = float(recall_at_k(ids, true_ids))
        frac = L * cfg.resolved(n).leaf_pad / n
        print(f"L={L:3d} trees: recall@1 = {rec:.3f}, "
              f"<= {frac*100:.2f}% of the DB touched per query")

    # ---- or skip the hand-tuning: state a recall target ------------------
    # tune() measures recall against a brute-force oracle on a query
    # sample, walks the probes-vs-trees frontier (DESIGN.md §9) and keeps
    # the cheapest SearchParams meeting the target as the index default.
    index = build_index(jax.random.key(0), db,
                        IndexSpec(backend="rpf",
                                  forest=ForestConfig(n_trees=20 if tiny
                                                      else 40, capacity=12)))
    # tune on the first half of the query sample, report on the (held-out)
    # second half — never measure on the queries you tuned with
    half = queries.shape[0] // 2
    tuned = tune(index, queries[:half], target_recall=target_recall, k=1)
    _, ids_t = index.search(queries[half:])  # tuned params now the default
    print(f"tuned for recall@1 >= {target_recall}: held-out measured "
          f"{float(recall_at_k(ids_t, true_ids[half:])):.3f} with "
          f"n_trees={tuned.n_trees or index.spec.forest.n_trees}, "
          f"n_probes={tuned.n_probes} "
          f"(persisted: save/load keeps this operating point)")

    # ---- every query-time knob composes with every backend ---------------
    cfg = ForestConfig(n_trees=20 if tiny else 40, capacity=12)
    index8 = build_index(jax.random.key(0), db,
                         IndexSpec(backend="rpf+int8", forest=cfg))
    _, ids8 = index8.search(queries, SearchParams(k=1, expand=4))
    _, ids8w = index8.search(queries,
                             SearchParams(k=1, expand=4, adaptive_wave=5,
                                          tol=0.01))
    print(f"rpf+int8: recall@1 = {float(recall_at_k(ids8, true_ids)):.3f} "
          f"(4x less candidate HBM traffic)")
    print(f"rpf+int8 + early-exit waves: recall@1 = "
          f"{float(recall_at_k(ids8w, true_ids)):.3f} using "
          f"{index8.last_trees_used}/{cfg.n_trees} trees")

    # ---- mutating an index: add / delete / upsert / compact --------------
    # (paper §5 + DESIGN.md §8: adds land in a delta buffer, deletes are
    # tombstones masked inside the fused rerank, compact() rebuilds the
    # live set in the background without blocking searches)
    index = build_index(jax.random.key(0), db,
                        IndexSpec(backend="rpf",
                                  forest=ForestConfig(n_trees=20,
                                                      capacity=12)))
    novel = (0.5 * (db[0] + db[1])).astype(np.float32)
    gid = index.add(novel)                      # queryable immediately
    _, ids = index.search(novel[None], SearchParams(k=1))
    assert int(np.asarray(ids)[0, 0]) == gid
    index.delete([0, 1])                        # gone from results at once
    index.upsert(2, novel * 0.9)                # replace id 2's vector
    _, ids = index.search(novel[None], SearchParams(k=3))
    assert not np.isin(np.asarray(ids), [0, 1]).any()
    st = index.stats()
    print(f"mutated: {st['n_live']} live rows, {st['n_tombstones']} "
          f"tombstones, {st['n_segments']} segment(s)")
    index.compact()                             # explicit rebuild (off-lock)
    print("compacted:", {k: index.stats()[k]
                         for k in ("n_live", "n_tombstones", "n_segments")})

    # ---- k-NN with the chi-square metric (the paper's ISS experiment) ----
    db_h = np.abs(db)
    index_h = build_index(jax.random.key(1), db_h,
                          IndexSpec(backend="rpf",
                                    forest=ForestConfig(n_trees=20,
                                                        capacity=12)))
    d, ids = index_h.search(db_h[:8], SearchParams(k=3, metric="chi2"))
    print("chi2 3-NN of first db point:", np.asarray(ids[0]),
          "dists", np.round(np.asarray(d[0]), 5))


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--tiny", action="store_true",
                   help="CI-size corpus (seconds, not minutes)")
    p.add_argument("--target-recall", type=float, default=0.9,
                   help="recall@1 goal handed to repro.index.tune")
    a = p.parse_args()
    main(tiny=a.tiny, target_recall=a.target_recall)

"""Train a two-tower retrieval model, index the item tower with the paper's
RPF, and serve retrieval — the full train->index->serve pipeline.

  PYTHONPATH=src python examples/two_tower_retrieval.py

Steps:
  1. train a two-tower model with in-batch softmax on synthetic interactions,
  2. encode the item catalog, build the RPF index over item embeddings,
  3. serve user queries through the index with ``metric="ip"`` (maximum
     inner product — the two-tower scoring function) and ASSERT recall
     vs the exact-MIPS brute force, so this example is a checked workload,
     not a demo that can silently rot.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ForestConfig, exact_knn
from repro.index import IndexSpec, SearchParams, build_index
from repro.models import recsys as rs
from repro.train.optimizer import adamw, cosine_schedule
from repro.train.train_state import init_train_state, make_train_step
from repro.train.train_loop import LoopConfig, train

N_USERS, N_ITEMS, D = 2000, 20_000, 64


def main():
    rng = np.random.default_rng(0)
    # planted taste structure: users like items in their cluster
    n_tastes = 32
    user_taste = rng.integers(0, n_tastes, N_USERS)
    item_taste = rng.integers(0, n_tastes, N_ITEMS)
    taste_items = [np.where(item_taste == t)[0] for t in range(n_tastes)]

    def batch(bs=256):
        u = rng.integers(0, N_USERS, bs)
        i = np.array([rng.choice(taste_items[user_taste[uu]]) for uu in u])
        return jnp.asarray(u), jnp.asarray(i)

    params = rs.init_two_tower(jax.random.key(0), N_USERS, N_ITEMS, d=D)
    opt = adamw(cosine_schedule(3e-3, 20, 300), weight_decay=1e-4)
    state = init_train_state(params, opt)

    def lf(p, b):
        return rs.two_tower_loss(p, b[0], b[1]), {}

    step = make_train_step(lf, opt)
    state, hist = train(state, step, iter(lambda: batch(), None),
                        LoopConfig(total_steps=200, log_every=50))
    print(f"two-tower loss {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f}")

    # ---- encode catalog + build the paper's index (unified API) ----------
    item_emb = rs.two_tower_item(state.params, jnp.arange(N_ITEMS))
    item_emb = item_emb / jnp.linalg.norm(item_emb, axis=1, keepdims=True)
    cfg = ForestConfig(n_trees=60, capacity=16, split_ratio=0.3)
    index = build_index(jax.random.key(1), np.asarray(item_emb),
                        IndexSpec(backend="rpf", forest=cfg))

    # ---- retrieve for a user batch (MIPS: the model scores by u . i) -----
    users = jnp.arange(64)
    u_emb = rs.two_tower_user(state.params, users)
    u_emb = u_emb / jnp.linalg.norm(u_emb, axis=1, keepdims=True)
    _, rpf_ids = index.search(u_emb, SearchParams(k=20, metric="ip",
                                                  n_probes=8))
    _, bf_ids = exact_knn(u_emb, item_emb, k=20, metric="ip")
    recall = float((np.asarray(rpf_ids)[:, :, None]
                    == np.asarray(bf_ids)[:, None, :]).any(1).mean())
    rcfg = cfg.resolved(N_ITEMS)
    touched = 8 * cfg.n_trees * rcfg.leaf_pad
    print(f"RPF retrieval recall@20 vs exact MIPS: {recall:.3f} "
          f"(touching <= {touched}/{N_ITEMS} items/query)")
    assert recall >= 0.8, f"ip retrieval recall regressed: {recall:.3f} < 0.8"
    # taste-consistency: retrieved items should share the user's taste
    top = np.asarray(rpf_ids)[:, 0]
    taste_hit = (item_taste[top] == user_taste[:64]).mean()
    print(f"top-1 item matches user taste for {taste_hit*100:.0f}% of users")


if __name__ == "__main__":
    main()

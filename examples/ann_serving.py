"""End-to-end serving driver: the paper's index behind a batched service.

  PYTHONPATH=src python examples/ann_serving.py

Builds the RPF index, stands up the dynamic batcher, fires concurrent
requests, validates recall, and exercises the paper's §5 incremental-update
path (insert -> immediate queryability -> background rebuild).
"""
import threading
import time

import numpy as np

from repro.core.forest import ForestConfig
from repro.data.synthetic import mnist_like
from repro.serve.ann_serve import make_ann_server


def main():
    db, _, queries, _ = mnist_like(n=10_000, n_test=128)
    cfg = ForestConfig(n_trees=40, capacity=12, split_ratio=0.3)
    service, batcher = make_ann_server(db, cfg, k=5, max_batch=64,
                                       max_wait_s=0.01)
    print("index:", service.stats())

    # concurrent clients
    results = {}
    def client(j):
        results[j] = batcher(queries[j])

    t0 = time.perf_counter()
    threads = [threading.Thread(target=client, args=(j,)) for j in range(128)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dt = time.perf_counter() - t0
    print(f"128 concurrent requests in {dt*1e3:.0f} ms; "
          f"batcher: {batcher.stats}")

    # incremental update (paper §5): a novel point becomes queryable at once
    novel = queries[0] * 0.9 + 0.1 * queries[1]
    novel /= np.linalg.norm(novel)
    new_id = service.insert(novel)
    d, i = service.query(novel[None], k=1)
    assert int(i[0, 0]) == new_id, (int(i[0, 0]), new_id)
    print(f"inserted point {new_id}: self-query hits it at dist "
          f"{float(d[0,0]):.2e}")
    batcher.stop()


if __name__ == "__main__":
    main()

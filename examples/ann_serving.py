"""End-to-end serving driver: a unified-API index behind a batched service.

  PYTHONPATH=src python examples/ann_serving.py [--tiny]

Builds the index from an IndexSpec, stands up the dynamic batcher (batches
are padded to max_batch, so the jitted query step compiles once), fires
concurrent requests, validates recall, and exercises the paper's §5
incremental-update path (add -> immediate queryability -> background
rebuild).  ``--tiny`` shrinks the corpus for the CI examples-smoke job.
"""
import argparse
import threading
import time

import numpy as np

from repro.core.forest import ForestConfig
from repro.data.synthetic import mnist_like
from repro.index import IndexSpec, SearchParams
from repro.serve.ann_serve import make_ann_server


def main(tiny: bool = False):
    n, n_clients = (2_000, 32) if tiny else (10_000, 128)
    db, _, queries, _ = mnist_like(n=n, n_test=max(n_clients, 32))
    spec = IndexSpec(backend="rpf",
                     forest=ForestConfig(n_trees=20 if tiny else 40,
                                         capacity=12, split_ratio=0.3))
    index, batcher = make_ann_server(db, spec, k=5, max_batch=64,
                                     max_wait_s=0.01)
    print("index:", index.stats())

    # concurrent clients
    results = {}

    def client(j):
        results[j] = batcher(queries[j])

    t0 = time.perf_counter()
    threads = [threading.Thread(target=client, args=(j,))
               for j in range(n_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dt = time.perf_counter() - t0
    print(f"{n_clients} concurrent requests in {dt*1e3:.0f} ms; "
          f"batcher: {batcher.stats}")

    # incremental update (paper §5): a novel point becomes queryable at once
    novel = queries[0] * 0.9 + 0.1 * queries[1]
    novel /= np.linalg.norm(novel)
    new_id = index.add(novel)
    d, i = index.search(novel[None], SearchParams(k=1))
    d, i = np.asarray(d), np.asarray(i)
    assert int(i[0, 0]) == new_id, (int(i[0, 0]), new_id)
    print(f"inserted point {new_id}: self-query hits it at dist "
          f"{float(d[0, 0]):.2e}")

    # mutate WHILE serving (DESIGN.md §8): delete + background compaction —
    # batcher threads keep answering from the published immutable view
    index.delete(new_id)
    t = index.compact(block=False)
    d, i = batcher(novel)                       # served mid-rebuild
    assert int(i[0]) != new_id, "tombstoned id surfaced while compacting"
    t.join()
    st = index.stats()
    print(f"deleted {new_id} + compacted in the background while serving: "
          f"{st['n_live']} live rows, {st['n_segments']} segment(s), "
          f"{st['n_compactions']} compaction(s)")
    batcher.stop()


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--tiny", action="store_true",
                   help="CI-size corpus (seconds, not minutes)")
    main(tiny=p.parse_args().tiny)

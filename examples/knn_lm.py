"""kNN-LM: augment a small LM's next-token prediction with the paper's index.

  PYTHONPATH=src python examples/knn_lm.py

Train a SmolLM-family reduced config on a Markov corpus, memorize (hidden
state -> next token) pairs into an RPF index via the unified index API
(repro.index), then interpolate LM logits with the kNN distribution
(Khandelwal et al. 2020 applied through Zhong's index).  Neighbor lookup
runs under ``metric="cosine"`` (hidden-state direction, not magnitude,
carries the signal) and the retrieval is recall-ASSERTED against the exact
cosine brute force, so the example is a checked workload.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.core import ForestConfig
from repro.data.lm_data import MarkovTokens
from repro.index import IndexSpec, SearchParams, build_index
from repro.models import transformer as tr
from repro.train.optimizer import adamw, cosine_schedule
from repro.train.train_state import init_train_state, make_train_step
from repro.train.train_loop import LoopConfig, train

CFG = LMConfig(name="smol-smoke", n_layers=4, d_model=96, n_heads=4,
               n_kv_heads=2, head_dim=24, d_ff=256, vocab_size=512,
               tie_embeddings=True, remat=False,
               param_dtype="float32", compute_dtype="float32")


def main():
    data = MarkovTokens(CFG.vocab_size, branch=8, seed=0)
    params = tr.init_lm(jax.random.key(0), CFG)
    opt = adamw(cosine_schedule(3e-3, 20, 400))
    state = init_train_state(params, opt)
    step = make_train_step(lambda p, b: tr.loss_fn(p, b, CFG), opt)

    def batches():
        for b in data.batches(16, 64):
            yield {"tokens": jnp.asarray(b["tokens"]),
                   "labels": jnp.asarray(b["labels"])}

    state, hist = train(state, step, batches(),
                        LoopConfig(total_steps=300, log_every=100))
    print(f"LM loss {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f}")

    # ---- memorize: hidden states -> next tokens --------------------------
    mem = data.sample(64, 64)
    mem_tok, mem_next = mem[:, :-1], mem[:, 1:]
    hidden, _ = tr.forward_hidden(state.params, jnp.asarray(mem_tok), CFG)
    keys = np.array(hidden).reshape(-1, CFG.d_model)   # copy: jax buffers are read-only
    vals = mem_next.reshape(-1)
    keys /= np.linalg.norm(keys, axis=1, keepdims=True) + 1e-9

    index = build_index(jax.random.key(2), keys,
                        IndexSpec(backend="rpf",
                                  forest=ForestConfig(n_trees=40,
                                                      capacity=12)))

    # ---- evaluate interpolated next-token accuracy ------------------------
    test = data.sample(32, 64)
    t_tok, t_next = test[:, :-1], test[:, 1:]
    h, _ = tr.forward_hidden(state.params, jnp.asarray(t_tok), CFG)
    logits, _ = tr.forward(state.params, jnp.asarray(t_tok), CFG)
    q = np.array(h).reshape(-1, CFG.d_model)
    q /= np.linalg.norm(q, axis=1, keepdims=True) + 1e-9

    k = 8
    d, ids = index.search(q, SearchParams(k=k, metric="cosine"))
    # retrieval quality gate: the kNN distribution is only as good as the
    # neighbor set, so assert recall vs the exact cosine oracle
    from repro.core import exact_knn
    _, bf_ids = exact_knn(jnp.asarray(q), jnp.asarray(keys), k=k,
                          metric="cosine")
    recall = float((np.asarray(ids)[:, :, None]
                    == np.asarray(bf_ids)[:, None, :]).any(1).mean())
    print(f"kNN recall@{k} vs exact cosine: {recall:.3f}")
    assert recall >= 0.8, f"cosine kNN recall regressed: {recall:.3f} < 0.8"
    knn_next = vals[np.clip(np.asarray(ids), 0, len(vals) - 1)]   # (Q, k)
    w = np.exp(-np.asarray(d) * 10.0) * (np.asarray(ids) >= 0)
    knn_probs = np.zeros((q.shape[0], CFG.padded_vocab), np.float32)
    for j in range(k):
        np.add.at(knn_probs, (np.arange(q.shape[0]), knn_next[:, j]),
                  w[:, j])
    knn_probs /= knn_probs.sum(1, keepdims=True) + 1e-9

    lm_probs = np.asarray(jax.nn.softmax(logits, axis=-1)).reshape(
        -1, CFG.padded_vocab)
    truth = t_next.reshape(-1)
    for lam in (0.0, 0.3, 0.6):
        mix = (1 - lam) * lm_probs + lam * knn_probs
        acc = (mix.argmax(1) == truth).mean()
        print(f"lambda={lam:.1f}: next-token acc {acc:.3f}"
              + ("  (pure LM)" if lam == 0 else ""))


if __name__ == "__main__":
    main()

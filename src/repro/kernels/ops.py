"""Public jit'd wrappers around the Pallas kernels with backend dispatch.

Policy (``mode``):
  * "auto"   — Pallas-compiled on TPU, jnp reference elsewhere (CPU containers
               run the oracle; the kernels are validated via interpret mode in
               the test suite).
  * "pallas" — force the Pallas kernel (interpret=True off-TPU).
  * "ref"    — force the jnp oracle.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax

from repro.kernels import chi2_topk as _chi2
from repro.kernels import distance_topk as _dist
from repro.kernels import embedding_bag as _bag
from repro.kernels import forest_traverse as _trav
from repro.kernels import forest_traverse_hbm as _trav_hbm
from repro.kernels import fused_query as _fused
from repro.kernels import fused_query_int8 as _fused_i8
from repro.kernels import matmul_topk as _mm
from repro.kernels import ref as _ref
from repro.kernels.forest_traverse import SMEM_NODE_CAP

Mode = Literal["auto", "pallas", "ref"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(mode: Mode) -> tuple[bool, bool]:
    """-> (use_pallas, interpret)."""
    if mode == "ref":
        return False, False
    if mode == "pallas":
        return True, not _on_tpu()
    return (True, False) if _on_tpu() else (False, False)


def topk(q, db, k: int, metric: str = "l2", mode: Mode = "auto"):
    """Brute-force fused scoring + top-k. metric in {l2, dot, chi2}."""
    use_pallas, interp = _resolve(mode)
    if metric == "chi2":
        if use_pallas:
            return _chi2.chi2_topk(q, db, k, interpret=interp)
        return _ref.chi2_topk_ref(q, db, k)
    if use_pallas:
        return _mm.matmul_topk(q, db, k, metric=metric, interpret=interp)
    return _ref.matmul_topk_ref(q, db, k, metric=metric)


def rerank_candidates(q, cand, ids, mask, k: int, metric: str = "l2",
                      mode: Mode = "auto"):
    """Fused gathered-candidate distance + top-k."""
    use_pallas, interp = _resolve(mode)
    if use_pallas:
        return _dist.distance_topk(q, cand, ids, mask, k, metric=metric,
                                   interpret=interp)
    return _ref.distance_topk_ref(q, cand, ids, mask, k, metric=metric)


def fused_rerank(q, ids, db, k: int, metric: str = "l2", mode: Mode = "auto",
                 bq: int = 8, bm: int = 32):
    """Fused DB-row gather + distance + top-k over one candidate chunk.

    ids (B, M) int32 with -1 marking invalid slots.  Unlike
    ``rerank_candidates`` this takes the raw DB — the (B, M, d) gathered
    tensor never materializes in HBM (see kernels/fused_query.py).
    """
    use_pallas, interp = _resolve(mode)
    if use_pallas:
        return _fused.fused_gather_topk(q, ids, db, k, metric=metric, bq=bq,
                                        bm=bm, interpret=interp)
    return _ref.fused_gather_topk_ref(q, ids, db, k, metric=metric)


def fused_rerank_int8(q, ids, q8, scale, k: int, metric: str = "l2",
                      mode: Mode = "auto", bq: int = 8, bm: int = 32):
    """Fused int8-row gather + dequantize + coarse top-k over one chunk.

    ids (B, M) int32 with -1 marking invalid slots; q8 (N, d) int8 rows with
    per-row f32 scales; ``metric`` scores the dequantized rows so the coarse
    shortlist ranks like the fp32 rerank of record.  The Pallas kernel DMAs
    d + 4 bytes per candidate (kernels/fused_query_int8.py); the ref branch
    is the retired jnp dequant-gather, kept as the oracle.
    """
    use_pallas, interp = _resolve(mode)
    if use_pallas:
        return _fused_i8.fused_gather_topk_int8(q, ids, q8, scale, k,
                                                metric=metric, bq=bq,
                                                bm=bm, interpret=interp)
    return _ref.fused_gather_topk_int8_ref(q, ids, q8, scale, k,
                                           metric=metric)


def embedding_bag(ids, weights, table, mode: Mode = "auto"):
    """Weighted multi-hot embedding-bag (B, H) x (V, D) -> (B, D)."""
    use_pallas, interp = _resolve(mode)
    if use_pallas:
        return _bag.embedding_bag(ids, weights, table, interpret=interp)
    return _ref.embedding_bag_ref(ids, weights, table)


def traverse_tree(feat, thresh, child_base, queries, max_depth: int,
                  mode: Mode = "auto", n_probes: int = 1,
                  kernel: str = "auto"):
    """Single-tree batched descent -> leaf ids.

    (B,) for ``n_probes == 1`` (the historical contract); (B, n_probes)
    multi-probe leaf ids (primary first, then ascending margin, -1 for
    absent probes) otherwise.

    ``kernel`` selects the Pallas variant: "smem" keeps the tree arrays in
    scalar memory (fast, capped at ``SMEM_NODE_CAP`` allocated nodes),
    "hbm" streams node records from HBM with double-buffered DMA (no cap,
    DESIGN.md §11); "auto" picks by tree size — so the Pallas path never
    falls back to jnp on large trees.  Both variants are bitwise-identical
    to each other and to the refs.
    """
    use_pallas, interp = _resolve(mode)
    if use_pallas:
        if kernel == "auto":
            kernel = "smem" if feat.shape[0] <= SMEM_NODE_CAP else "hbm"
        if kernel == "hbm":
            return _trav_hbm.forest_traverse_hbm_tree(
                feat, thresh, child_base, queries, max_depth,
                interpret=interp, n_probes=n_probes)
        return _trav.forest_traverse(feat, thresh, child_base, queries,
                                     max_depth, interpret=interp,
                                     n_probes=n_probes)
    if n_probes == 1:
        return _ref.forest_traverse_ref(feat, thresh, child_base, queries,
                                        max_depth)
    return _ref.forest_traverse_multiprobe_ref(feat, thresh, child_base,
                                               queries, max_depth, n_probes)

"""Fused brute-force scoring + streaming top-k (the exhaustive/rerank hot-spot).

Computes, for a query tile against the whole database, either

  * l2 :  ||q - c||^2  via the MXU expansion |q|^2 - 2 q.c + |c|^2, or
  * dot: -q.c          (retrieval scoring, e.g. recsys retrieval_cand),

and keeps a running top-k in VMEM while streaming database blocks HBM->VMEM —
the full (B, N) score matrix never exists in HBM.  This is the beyond-paper
optimized exhaustive path and the exact-rerank stage of the forest query.

Blocking: grid = (B/bq, N/bn); the db block (bn, d) is the streamed operand;
the output top-k block (bq, k) is revisited across j (consecutive -> stays in
VMEM).  MXU work per step: (bq x d) @ (d x bn).

VMEM budget (f32, defaults bq=128, bn=512, d<=1024):
  q tile 0.5 MB + db block 2 MB + scores 0.25 MB + topk carry ~tiny  << 16 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import tpu_compiler_params
from repro.kernels.common import POS_INF, merge_topk, select_topk_block


def _kernel(q_ref, db_ref, db_sq_ref, out_d_ref, out_i_ref, *, k: int,
            bn: int, n_total: int, metric: str):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_d_ref[...] = jnp.full_like(out_d_ref, POS_INF)
        out_i_ref[...] = jnp.full_like(out_i_ref, -1)

    q = q_ref[...].astype(jnp.float32)          # (bq, d)
    db = db_ref[...].astype(jnp.float32)        # (bn, d)
    cross = jax.lax.dot_general(
        q, db, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)     # (bq, bn) on the MXU
    if metric == "l2":
        q_sq = jnp.sum(q * q, axis=1, keepdims=True)
        scores = q_sq - 2.0 * cross + db_sq_ref[...]      # (bq, bn)
    elif metric == "dot":
        scores = -cross
    else:
        raise ValueError(metric)

    ids = j * bn + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(ids < n_total, scores, POS_INF)    # padding rows
    bd, bi = select_topk_block(scores, ids, k)
    md, mi = merge_topk(out_d_ref[...], out_i_ref[...], bd, bi, k)
    out_d_ref[...] = md
    out_i_ref[...] = mi


@functools.partial(jax.jit, static_argnames=("k", "metric", "bq", "bn",
                                             "interpret"))
def matmul_topk(q: jax.Array, db: jax.Array, k: int, metric: str = "l2",
                bq: int = 128, bn: int = 512, interpret: bool = False
                ) -> tuple[jax.Array, jax.Array]:
    """(B, d) x (N, d) -> top-k (dists (B,k) f32, ids (B,k) int32)."""
    b, d = q.shape
    n, _ = db.shape
    bq = min(bq, max(8, b))
    bn = min(bn, n)
    # pad to tile multiples (padded db rows are masked by id >= n in-kernel)
    b_pad = -b % bq
    n_pad = -n % bn
    qp = jnp.pad(q, ((0, b_pad), (0, 0)))
    dbp = jnp.pad(db, ((0, n_pad), (0, 0)))
    db_sq = jnp.sum(dbp.astype(jnp.float32) ** 2, axis=1)[None, :]  # (1, N')

    grid = ((b + b_pad) // bq, (n + n_pad) // bn)
    out_d, out_i = pl.pallas_call(
        functools.partial(_kernel, k=k, bn=bn, n_total=n, metric=metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b + b_pad, k), jnp.float32),
            jax.ShapeDtypeStruct((b + b_pad, k), jnp.int32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qp, dbp, db_sq)
    return out_d[:b], out_i[:b]

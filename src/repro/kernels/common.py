"""Shared in-kernel helpers for the Pallas kernels.

Streaming top-k: TPU Mosaic does not support lax.top_k/sort inside kernels, so
we use a k-pass min-selection built only from elementwise ops, reductions and
iota — all Mosaic-lowerable. Cost O(k * m) per (rows, m) block, negligible next
to the O(m * d) distance math for the k (<= ~32) this library targets.
"""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = float("-inf")
POS_INF = float("inf")


def select_topk_block(dists: jnp.ndarray, ids: jnp.ndarray, k: int
                      ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row k smallest of a (rows, m) block. Returns ((rows,k), (rows,k)).

    Pure elementwise/reduction ops (Mosaic-safe): k passes of
    min -> first-occurrence one-hot -> masked extract -> invalidate.
    """
    rows, m = dists.shape
    col = jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32)[None, :], (rows, m))
    work = dists
    out_d, out_i = [], []
    for _ in range(k):
        mn = jnp.min(work, axis=1, keepdims=True)             # (rows, 1)
        hit = work == mn                                       # ties -> many
        # first occurrence: smallest column index among hits
        first_col = jnp.min(jnp.where(hit, col, m), axis=1, keepdims=True)
        onehot = col == first_col                              # (rows, m)
        out_d.append(mn[:, 0])
        out_i.append(jnp.sum(jnp.where(onehot, ids, 0), axis=1))
        work = jnp.where(onehot, POS_INF, work)
    return jnp.stack(out_d, axis=1), jnp.stack(out_i, axis=1).astype(jnp.int32)


def merge_topk(cur_d: jnp.ndarray, cur_i: jnp.ndarray,
               new_d: jnp.ndarray, new_i: jnp.ndarray, k: int
               ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Merge two (rows, k) sorted-or-not candidate lists into the k best."""
    d = jnp.concatenate([cur_d, new_d], axis=1)
    i = jnp.concatenate([cur_i, new_i], axis=1)
    return select_topk_block(d, i, k)

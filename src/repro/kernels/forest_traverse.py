"""Pallas batched forest traversal (K=1 trees): query tile -> leaf ids.

The paper's descent is one coordinate access + one float compare per level.
Batched over a query tile, each level is two dynamic gathers:
  (1) node -> (feat, thresh, child_base)   [tree arrays, scalar memory]
  (2) per-row coordinate q[b, feat_b]      [query tile, VMEM]

Tree arrays are passed as scalar-prefetch operands (SMEM-resident). This caps
the supported tree size at the SMEM budget (~64k nodes of 12 B/node ~= 768 KB);
larger trees use the XLA traversal in core.forest (the production default —
traversal is <2% of query cost at paper-scale L*C, see EXPERIMENTS.md §Perf).

Grid = (B/bq,); the depth loop is a fori_loop inside the kernel so the query
tile is read once from HBM for the whole descent.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(feat_ref, thresh_ref, child_ref, q_ref, out_ref, *,
            max_depth: int):
    q = q_ref[...]                       # (bq, d)
    feat = feat_ref[...]                 # (max_nodes,)
    thresh = thresh_ref[...]
    child = child_ref[...]

    def step(_, node):
        f = jnp.take(feat, node)                        # (bq,)
        t = jnp.take(thresh, node)
        cb = jnp.take(child, node)
        xv = jnp.take_along_axis(q, f[:, None], axis=1)[:, 0]
        go_right = (xv >= t).astype(jnp.int32)
        return jnp.where(cb < 0, node, cb + go_right)

    node0 = jnp.zeros((q.shape[0],), jnp.int32)
    leaf = jax.lax.fori_loop(0, max_depth, step, node0)
    out_ref[...] = leaf[:, None]


@functools.partial(jax.jit, static_argnames=("max_depth", "bq", "interpret"))
def forest_traverse(feat: jax.Array, thresh: jax.Array, child_base: jax.Array,
                    queries: jax.Array, max_depth: int, bq: int = 256,
                    interpret: bool = False) -> jax.Array:
    """Single K=1 tree: feat/thresh/child_base (max_nodes,), queries (B, d).

    Returns leaf node ids (B,) int32.  vmap over trees for the forest.
    """
    b, d = queries.shape
    bq = min(bq, b)
    b_pad = -b % bq
    qp = jnp.pad(queries, ((0, b_pad), (0, 0)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,           # feat, thresh, child_base in SMEM
        grid=((b + b_pad) // bq,),
        in_specs=[pl.BlockSpec((bq, d), lambda i, *_: (i, 0))],
        out_specs=pl.BlockSpec((bq, 1), lambda i, *_: (i, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, max_depth=max_depth),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b + b_pad, 1), jnp.int32),
        interpret=interpret,
    )(feat, thresh, child_base, qp)
    return out[:b, 0]

"""Pallas batched forest traversal (K=1 trees): query tile -> leaf ids.

The paper's descent is one coordinate access + one float compare per level.
Batched over a query tile, each level is two dynamic gathers:
  (1) node -> (feat, thresh, child_base)   [tree arrays, scalar memory]
  (2) per-row coordinate q[b, feat_b]      [query tile, VMEM]

``n_probes > 1`` adds the bounded multi-probe expansion of DESIGN.md §9 in
the same tile: the primary descent records per-level projection margins in
registers, then each alternate re-descends with the smallest-margin routing
decision flipped — (n_probes - 1) extra fori_loops, no extra HBM traffic
(the query tile is already resident).

Tree arrays are passed as scalar-prefetch operands (SMEM-resident). This caps
the supported tree size at the SMEM budget (~64k nodes of 12 B/node ~= 768 KB,
``SMEM_NODE_CAP``); above the cap ``ops.traverse_tree`` dispatches to the
HBM-resident kernel (kernels/forest_traverse_hbm.py, DESIGN.md §11), which
fetches node records per descent level with double-buffered DMA — so the
Pallas path now covers every tree size.  Below the cap this kernel stays the
fast path (the whole tree is on-chip: zero per-level DMA).

Grid = (B/bq,); the depth loop is a fori_loop inside the kernel so the query
tile is read once from HBM for the whole descent.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Largest tree (allocated max_nodes) this kernel accepts: three 4-byte
# arrays per node must fit the ~1 MB scalar memory with headroom for the
# grid machinery.  kernels/ops.py dispatches to the HBM kernel above this.
SMEM_NODE_CAP = 64 * 1024


def _kernel(feat_ref, thresh_ref, child_ref, q_ref, out_ref, *,
            max_depth: int, n_probes: int):
    q = q_ref[...]                       # (bq, d)
    feat = feat_ref[...]                 # (max_nodes,)
    thresh = thresh_ref[...]
    child = child_ref[...]
    bq = q.shape[0]
    node0 = jnp.zeros((bq,), jnp.int32)

    def descend(node):
        """One gather+compare step: (node, margin, child-if-internal)."""
        f = jnp.take(feat, node)                        # (bq,)
        t = jnp.take(thresh, node)
        cb = jnp.take(child, node)
        xv = jnp.take_along_axis(q, f[:, None], axis=1)[:, 0]
        go_right = xv >= t
        internal = cb >= 0
        margin = jnp.where(internal, jnp.abs(xv - t), jnp.inf)
        return internal, go_right, cb, node, margin

    # ---- primary descent, recording per-level margins in registers -------
    depth_col = jax.lax.broadcasted_iota(jnp.int32, (bq, max_depth), 1)

    def primary_step(t, carry):
        node, margins = carry
        internal, go_right, cb, node, margin = descend(node)
        margins = jnp.where(depth_col == t, margin[:, None], margins)
        nxt = jnp.where(internal, cb + go_right.astype(jnp.int32), node)
        return nxt, margins

    margins0 = jnp.full((bq, max_depth), jnp.inf, jnp.float32)
    leaf, margins = jax.lax.fori_loop(0, max_depth, primary_step,
                                      (node0, margins0))
    out_ref[:, 0] = leaf

    # ---- bounded best-first expansion: flip the smallest-margin node -----
    # n_probes is small and static: an unrolled argmin + re-descent per
    # alternate (ties -> shallower depth, matching traverse_multiprobe's
    # lax.top_k ordering)
    for p in range(1, n_probes):
        best = jnp.min(margins, axis=1)                              # (bq,)
        is_best = margins == best[:, None]
        first = jnp.min(jnp.where(is_best, depth_col, max_depth), axis=1)
        margins = jnp.where(depth_col == first[:, None], jnp.inf, margins)

        def alt_step(t, node, flip=first):
            internal, go_right, cb, node, _ = descend(node)
            go_right = jnp.where(t == flip, ~go_right, go_right)
            return jnp.where(internal, cb + go_right.astype(jnp.int32), node)

        alt = jax.lax.fori_loop(0, max_depth, alt_step, node0)
        out_ref[:, p] = jnp.where(jnp.isfinite(best), alt, -1)


@functools.partial(jax.jit, static_argnames=("max_depth", "bq", "interpret",
                                             "n_probes"))
def forest_traverse(feat: jax.Array, thresh: jax.Array, child_base: jax.Array,
                    queries: jax.Array, max_depth: int, bq: int = 256,
                    interpret: bool = False, n_probes: int = 1) -> jax.Array:
    """Single K=1 tree: feat/thresh/child_base (max_nodes,), queries (B, d).

    Returns leaf node ids (B,) int32 for ``n_probes == 1`` (the historical
    contract), else the multi-probe leaf set (B, n_probes) int32 with -1
    marking absent probes — the same ordering (primary leaf first, then
    ascending projection margin) as ``core.forest.traverse_multiprobe``.
    vmap over trees for the forest.
    """
    b, d = queries.shape
    bq = min(bq, b)
    b_pad = -b % bq
    qp = jnp.pad(queries, ((0, b_pad), (0, 0)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,           # feat, thresh, child_base in SMEM
        grid=((b + b_pad) // bq,),
        in_specs=[pl.BlockSpec((bq, d), lambda i, *_: (i, 0))],
        out_specs=pl.BlockSpec((bq, n_probes), lambda i, *_: (i, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, max_depth=max_depth, n_probes=n_probes),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b + b_pad, n_probes), jnp.int32),
        interpret=interpret,
    )(feat, thresh, child_base, qp)
    return out[:b, 0] if n_probes == 1 else out[:b]

"""Fused candidate rerank: per-query gathered candidates -> distance -> top-k.

This is the forest-query hot path: each query carries its own (M = L*C)-wide
padded candidate matrix (gathered outside the kernel — XLA's gather is the
fastest HBM row-collector; see DESIGN.md §2).  The kernel streams candidate
blocks, computes masked L2/chi2 distances and maintains the running top-k in
VMEM, so neither the (B, M) distance matrix nor the merged candidate list ever
round-trips HBM.

Layout: cand (B, M, d) f32, ids/mask (B, M).  Grid = (B/bq, M/bm); blocks
(bq, bm, d) are the streamed operand.

VMEM (defaults bq=8, bm=64, d<=1024 f32): 8*64*1024*4 = 2 MB cand block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import tpu_compiler_params
from repro.kernels.common import POS_INF, merge_topk, select_topk_block

EPS = 1e-12


def _kernel(q_ref, cand_ref, ids_ref, mask_ref, out_d_ref, out_i_ref, *,
            k: int, metric: str):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_d_ref[...] = jnp.full_like(out_d_ref, POS_INF)
        out_i_ref[...] = jnp.full_like(out_i_ref, -1)

    q = q_ref[...].astype(jnp.float32)[:, None, :]   # (bq, 1, d)
    c = cand_ref[...].astype(jnp.float32)            # (bq, bm, d)
    if metric == "l2":
        diff = q - c
        scores = jnp.sum(diff * diff, axis=-1)       # (bq, bm)
    elif metric == "chi2":
        scores = jnp.sum((q - c) ** 2 / (q + c + EPS), axis=-1)
    else:
        raise ValueError(metric)
    scores = jnp.where(mask_ref[...], scores, POS_INF)
    bd, bi = select_topk_block(scores, ids_ref[...], k)
    md, mi = merge_topk(out_d_ref[...], out_i_ref[...], bd, bi, k)
    out_d_ref[...] = md
    out_i_ref[...] = mi


@functools.partial(jax.jit, static_argnames=("k", "metric", "bq", "bm",
                                             "interpret"))
def distance_topk(q: jax.Array, cand: jax.Array, ids: jax.Array,
                  mask: jax.Array, k: int, metric: str = "l2", bq: int = 8,
                  bm: int = 64, interpret: bool = False
                  ) -> tuple[jax.Array, jax.Array]:
    """q (B,d), cand (B,M,d), ids (B,M) int32, mask (B,M) bool -> top-k."""
    b, d = q.shape
    m = cand.shape[1]
    bq = min(bq, max(1, b))
    bm = min(bm, m)
    b_pad = -b % bq
    m_pad = -m % bm
    qp = jnp.pad(q, ((0, b_pad), (0, 0)))
    candp = jnp.pad(cand, ((0, b_pad), (0, m_pad), (0, 0)))
    idsp = jnp.pad(ids, ((0, b_pad), (0, m_pad)), constant_values=-1)
    maskp = jnp.pad(mask, ((0, b_pad), (0, m_pad)), constant_values=False)

    grid = ((b + b_pad) // bq, (m + m_pad) // bm)
    out_d, out_i = pl.pallas_call(
        functools.partial(_kernel, k=k, metric=metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, bm, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bq, bm), lambda i, j: (i, j)),
            pl.BlockSpec((bq, bm), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b + b_pad, k), jnp.float32),
            jax.ShapeDtypeStruct((b + b_pad, k), jnp.int32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qp, candp, idsp, maskp)
    return out_d[:b], out_i[:b]

"""Fused candidate gather + exact distance + running top-k (the query hot path).

The staged pipeline materializes ``db[cand_ids]`` — a ``(B, M, d)`` tensor —
in HBM between the XLA gather and the rerank kernel, so every candidate row
crosses HBM three times (gather read, gather write, kernel read).  This kernel
closes that seam: candidate ids arrive as a scalar-prefetch operand (SMEM),
the DB stays in HBM, and the kernel DMAs exactly the rows it needs into a
``(bq, bm, d)`` VMEM tile, scores them against the query tile, and folds them
into an on-chip ``(bq, k)`` running top-k.  The gathered tensor never exists
in HBM; per-candidate traffic drops to a single HBM read.

Contract (mirrored by ``kernels.ref.fused_gather_topk_ref``):
  q (B, d) f32/bf16, ids (B, M) int32 with -1 marking invalid slots,
  db (N, d) -> (dists (B, k) f32, ids (B, k) int32); invalid: +inf / -1.

The -1 id slot is the kernel's whole masking vocabulary, and it is load
bearing for the segmented mutable index: tombstoned (deleted/upserted) DB
rows are folded into this same id/mask path by ``core.pipeline`` — a dead
row's candidate slot becomes -1 before the kernel, so it issues no DMA,
scores +inf, and can never occupy a top-k slot.  The kernel itself needs
no tombstone concept.

Layout: grid = (B/bq, M/bm), candidate axis innermost ("arbitrary") so the
(bq, k) state lives in the revisited output block across the whole stream.

SMEM budget: the ids operand is SMEM-resident, so B*M*4 bytes must fit the
scalar memory (~1 MB).  ``core.pipeline`` chunk-streams the M axis to stay
under that bound; this kernel asserts nothing and trusts its caller.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.kernels.common import POS_INF, merge_topk, select_topk_block

EPS = 1e-12


def _kernel(ids_smem, q_ref, ids_ref, db_ref, out_d_ref, out_i_ref,
            rows, sem, *, bq: int, bm: int, k: int, metric: str):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_d_ref[...] = jnp.full_like(out_d_ref, POS_INF)
        out_i_ref[...] = jnp.full_like(out_i_ref, -1)

    # ---- tile-by-tile HBM row gather -------------------------------------
    # Launch all row DMAs for this (bq, bm) tile, then drain: the copies
    # overlap each other and the queue keeps the HBM pipe full. Invalid
    # slots (id < 0) issue no DMA; their scores are masked to +inf below.
    def _copy(t):
        b, jj = t // bm, t % bm
        rid = ids_smem[i * bq + b, j * bm + jj]
        return rid, pltpu.make_async_copy(
            db_ref.at[jnp.maximum(rid, 0)], rows.at[b, jj], sem)

    def _start(t, _):
        rid, cp = _copy(t)

        @pl.when(rid >= 0)
        def _():
            cp.start()
        return 0

    def _wait(t, _):
        rid, cp = _copy(t)

        @pl.when(rid >= 0)
        def _():
            cp.wait()
        return 0

    jax.lax.fori_loop(0, bq * bm, _start, 0)
    jax.lax.fori_loop(0, bq * bm, _wait, 0)

    # ---- score the tile ---------------------------------------------------
    q = q_ref[...].astype(jnp.float32)[:, None, :]     # (bq, 1, d)
    c = rows[...].astype(jnp.float32)                  # (bq, bm, d)
    if metric == "l2":
        diff = q - c
        scores = jnp.sum(diff * diff, axis=-1)
    elif metric == "dot":
        scores = -jnp.sum(q * c, axis=-1)
    elif metric == "chi2":
        scores = jnp.sum((q - c) ** 2 / (q + c + EPS), axis=-1)
    elif metric == "cosine":
        qn = q / (jnp.sqrt(jnp.sum(q * q, -1, keepdims=True)) + EPS)
        cn = c / (jnp.sqrt(jnp.sum(c * c, -1, keepdims=True)) + EPS)
        scores = 1.0 - jnp.sum(qn * cn, axis=-1)
    else:
        raise ValueError(metric)
    ids_vec = ids_ref[...]                             # (bq, bm)
    scores = jnp.where(ids_vec >= 0, scores, POS_INF)

    # ---- fold into the running (bq, k) top-k ------------------------------
    bd, bi = select_topk_block(scores, ids_vec, k)
    md, mi = merge_topk(out_d_ref[...], out_i_ref[...], bd, bi, k)
    out_d_ref[...] = md
    out_i_ref[...] = mi


@functools.partial(jax.jit, static_argnames=("k", "metric", "bq", "bm",
                                             "interpret"))
def fused_gather_topk(q: jax.Array, ids: jax.Array, db: jax.Array, k: int,
                      metric: str = "l2", bq: int = 8, bm: int = 32,
                      interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """q (B, d), ids (B, M) int32 (-1 = invalid), db (N, d) -> top-k (B, k).

    Never materializes the gathered ``(B, M, d)`` candidate tensor: DB rows
    are DMA'd HBM -> VMEM tile-by-tile inside the kernel.
    """
    b, d = q.shape
    m = ids.shape[1]
    bq = min(bq, max(1, b))
    bm = min(bm, m)
    b_pad = -b % bq
    m_pad = -m % bm
    qp = jnp.pad(q, ((0, b_pad), (0, 0)))
    idsp = jnp.pad(ids, ((0, b_pad), (0, m_pad)), constant_values=-1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                     # ids -> SMEM
        grid=((b + b_pad) // bq, (m + m_pad) // bm),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j, *_: (i, 0)),
            pl.BlockSpec((bq, bm), lambda i, j, *_: (i, j)),
            pl.BlockSpec(memory_space=pltpu.ANY),  # db stays in HBM
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i, j, *_: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j, *_: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, bm, d), db.dtype),
            pltpu.SemaphoreType.DMA,
        ],
    )
    out_d, out_i = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bm=bm, k=k, metric=metric),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b + b_pad, k), jnp.float32),
            jax.ShapeDtypeStruct((b + b_pad, k), jnp.int32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(idsp, qp, idsp, db)
    out_d, out_i = out_d[:b], out_i[:b]
    return out_d, jnp.where(jnp.isinf(out_d), -1, out_i)

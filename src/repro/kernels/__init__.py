"""Pallas TPU kernels for the paper's compute hot-spots + jnp oracles.

Kernels (each <name>.py has the pl.pallas_call; ref.py has the oracle):
  * matmul_topk    -- fused MXU scoring (l2/dot) + streaming top-k
  * chi2_topk      -- fused chi-square scoring + streaming top-k
  * distance_topk  -- fused per-query candidate rerank + top-k (pre-gathered)
  * fused_query    -- DMA row gather + distance + running top-k in one pass
                      (the forest-query hot path; no (B, M, d) intermediate)
  * fused_query_int8 -- the same fused pass over int8 rows + per-row scales:
                      d + 4 bytes DMA'd per candidate, dequantized in VMEM
                      registers (the quantized shortlist stage, DESIGN.md §11)
  * embedding_bag  -- scalar-prefetch gather + weighted segment-sum
  * forest_traverse-- batched partition-tree descent (SMEM-resident tree,
                      capped at SMEM_NODE_CAP nodes); n_probes > 1 adds the
                      in-tile multi-probe expansion (DESIGN.md §9)
  * forest_traverse_hbm -- the uncapped variant: tree arrays stay in HBM,
                      node records fetched per level with double-buffered
                      DMA (DESIGN.md §11); bitwise-matches the SMEM kernel
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]

"""Pallas TPU kernels for the paper's compute hot-spots + jnp oracles.

Kernels (each <name>.py has the pl.pallas_call; ref.py has the oracle):
  * matmul_topk    -- fused MXU scoring (l2/dot) + streaming top-k
  * chi2_topk      -- fused chi-square scoring + streaming top-k
  * distance_topk  -- fused per-query candidate rerank + top-k (pre-gathered)
  * fused_query    -- DMA row gather + distance + running top-k in one pass
                      (the forest-query hot path; no (B, M, d) intermediate)
  * embedding_bag  -- scalar-prefetch gather + weighted segment-sum
  * forest_traverse-- batched partition-tree descent; n_probes > 1 adds the
                      in-tile multi-probe expansion (DESIGN.md §9)
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]

"""Fused chi-square scoring + streaming top-k (the paper's ISS-595 metric).

chi2(q, c) = sum_k (q_k - c_k)^2 / (q_k + c_k)  — elementwise (VPU-bound), so
unlike the L2 kernel there is no MXU contraction; the win is fusing the
d-reduction with the top-k so the (B, N) score matrix never round-trips HBM,
and streaming the feature dimension in chunks to bound the (bq, bn, dc)
broadcast intermediate in VMEM.

VMEM (f32, defaults bq=64, bn=256, dc=128): 64*256*128*4 = 8 MB intermediate.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import tpu_compiler_params
from repro.kernels.common import POS_INF, merge_topk, select_topk_block

EPS = 1e-12


def _kernel(q_ref, db_ref, out_d_ref, out_i_ref, *, k: int, bn: int,
            n_total: int, dc: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_d_ref[...] = jnp.full_like(out_d_ref, POS_INF)
        out_i_ref[...] = jnp.full_like(out_i_ref, -1)

    q = q_ref[...].astype(jnp.float32)          # (bq, d)
    db = db_ref[...].astype(jnp.float32)        # (bn, d)
    d = q.shape[1]
    n_chunks = max(1, d // dc)
    scores = jnp.zeros((q.shape[0], db.shape[0]), jnp.float32)
    for c in range(n_chunks):                   # static unroll over d-chunks
        lo, hi = c * dc, min((c + 1) * dc, d)
        qc = q[:, None, lo:hi]
        cc = db[None, :, lo:hi]
        scores = scores + jnp.sum((qc - cc) ** 2 / (qc + cc + EPS), axis=-1)

    ids = j * bn + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(ids < n_total, scores, POS_INF)
    bd, bi = select_topk_block(scores, ids, k)
    md, mi = merge_topk(out_d_ref[...], out_i_ref[...], bd, bi, k)
    out_d_ref[...] = md
    out_i_ref[...] = mi


@functools.partial(jax.jit, static_argnames=("k", "bq", "bn", "dc",
                                             "interpret"))
def chi2_topk(q: jax.Array, db: jax.Array, k: int, bq: int = 64, bn: int = 256,
              dc: int = 128, interpret: bool = False
              ) -> tuple[jax.Array, jax.Array]:
    """(B, d) x (N, d) -> chi2 top-k (dists (B,k) f32, ids (B,k) int32)."""
    b, d = q.shape
    n, _ = db.shape
    bq = min(bq, max(8, b))
    bn = min(bn, n)
    b_pad = -b % bq
    n_pad = -n % bn
    qp = jnp.pad(q, ((0, b_pad), (0, 0)))
    dbp = jnp.pad(db, ((0, n_pad), (0, 0)))

    grid = ((b + b_pad) // bq, (n + n_pad) // bn)
    out_d, out_i = pl.pallas_call(
        functools.partial(_kernel, k=k, bn=bn, n_total=n, dc=dc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b + b_pad, k), jnp.float32),
            jax.ShapeDtypeStruct((b + b_pad, k), jnp.int32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qp, dbp)
    return out_d[:b], out_i[:b]

"""Pure-jnp oracles for every Pallas kernel (the correctness references).

Each function mirrors its kernel's contract exactly (same shapes, dtypes,
padding and tie-breaking semantics: ties broken by smaller candidate id).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

EPS = 1e-12
POS_INF = float("inf")


def _topk_smallest(scores: jax.Array, ids: jax.Array, k: int
                   ) -> tuple[jax.Array, jax.Array]:
    """Top-k smallest with ties broken by smaller id (matches kernels)."""
    order = jnp.lexsort((ids, scores), axis=-1)
    top = order[..., :k]
    return (jnp.take_along_axis(scores, top, axis=-1),
            jnp.take_along_axis(ids, top, axis=-1))


@functools.partial(jax.jit, static_argnames=("k", "metric"))
def matmul_topk_ref(q: jax.Array, db: jax.Array, k: int, metric: str = "l2"
                    ) -> tuple[jax.Array, jax.Array]:
    qf = q.astype(jnp.float32)
    dbf = db.astype(jnp.float32)
    cross = qf @ dbf.T
    if metric == "l2":
        scores = (jnp.sum(qf * qf, 1)[:, None] - 2 * cross
                  + jnp.sum(dbf * dbf, 1)[None, :])
    elif metric == "dot":
        scores = -cross
    else:
        raise ValueError(metric)
    ids = jnp.broadcast_to(jnp.arange(db.shape[0], dtype=jnp.int32)[None, :],
                           scores.shape)
    d, i = _topk_smallest(scores, ids, k)
    return d, jnp.where(jnp.isinf(d), -1, i)


@functools.partial(jax.jit, static_argnames=("k",))
def chi2_topk_ref(q: jax.Array, db: jax.Array, k: int
                  ) -> tuple[jax.Array, jax.Array]:
    qf = q.astype(jnp.float32)[:, None, :]
    dbf = db.astype(jnp.float32)[None, :, :]
    scores = jnp.sum((qf - dbf) ** 2 / (qf + dbf + EPS), axis=-1)
    ids = jnp.broadcast_to(jnp.arange(db.shape[0], dtype=jnp.int32)[None, :],
                           scores.shape)
    d, i = _topk_smallest(scores, ids, k)
    return d, jnp.where(jnp.isinf(d), -1, i)


@functools.partial(jax.jit, static_argnames=("k", "metric"))
def distance_topk_ref(q: jax.Array, cand: jax.Array, ids: jax.Array,
                      mask: jax.Array, k: int, metric: str = "l2"
                      ) -> tuple[jax.Array, jax.Array]:
    qf = q.astype(jnp.float32)[:, None, :]
    cf = cand.astype(jnp.float32)
    if metric == "l2":
        scores = jnp.sum((qf - cf) ** 2, axis=-1)
    elif metric == "chi2":
        scores = jnp.sum((qf - cf) ** 2 / (qf + cf + EPS), axis=-1)
    else:
        raise ValueError(metric)
    scores = jnp.where(mask, scores, POS_INF)
    d, i = _topk_smallest(scores, ids, k)
    return d, jnp.where(jnp.isinf(d), -1, i)


@functools.partial(jax.jit, static_argnames=("k", "metric"))
def fused_gather_topk_ref(q: jax.Array, ids: jax.Array, db: jax.Array, k: int,
                          metric: str = "l2") -> tuple[jax.Array, jax.Array]:
    """Oracle for kernels.fused_query.fused_gather_topk (one candidate chunk).

    ids (B, M) int32 with -1 marking invalid slots.  The gather here is an
    XLA gather over the chunk only — the caller (core.pipeline) streams
    chunks so the full (B, M_total, d) candidate tensor never materializes.
    """
    n = db.shape[0]
    valid = ids >= 0
    cand = db[jnp.clip(ids, 0, n - 1)].astype(jnp.float32)   # (B, M, d)
    qf = q.astype(jnp.float32)[:, None, :]
    if metric == "l2":
        scores = jnp.sum((qf - cand) ** 2, axis=-1)
    elif metric == "dot":
        scores = -jnp.sum(qf * cand, axis=-1)
    elif metric == "chi2":
        scores = jnp.sum((qf - cand) ** 2 / (qf + cand + EPS), axis=-1)
    elif metric == "cosine":
        qn = qf / (jnp.sqrt(jnp.sum(qf * qf, -1, keepdims=True)) + EPS)
        cn = cand / (jnp.sqrt(jnp.sum(cand * cand, -1, keepdims=True)) + EPS)
        scores = 1.0 - jnp.sum(qn * cn, axis=-1)
    else:
        raise ValueError(metric)
    scores = jnp.where(valid, scores, POS_INF)
    # lax.top_k (ties -> earlier slot), matching the staged oracle's
    # selection exactly; cheaper than the lexsort the brute-force refs use
    neg_d, pos = jax.lax.top_k(-scores, k)
    d = -neg_d
    i = jnp.take_along_axis(ids, pos, axis=-1)
    return d, jnp.where(jnp.isinf(d), -1, i)


@functools.partial(jax.jit, static_argnames=("k", "metric"))
def fused_gather_topk_int8_ref(q: jax.Array, ids: jax.Array, q8: jax.Array,
                               scale: jax.Array, k: int, metric: str = "l2"
                               ) -> tuple[jax.Array, jax.Array]:
    """Oracle for kernels.fused_query_int8.fused_gather_topk_int8.

    This is the retired jnp dequant-gather the int8 coarse stage used to run
    in production (``core.pipeline`` pre-§11): an XLA gather materializes the
    dequantized (B, M, d) f32 block for the chunk, scored under ``metric``.
    The caller streams chunks, so M here is one chunk's width.
    """
    valid = ids >= 0
    safe = jnp.where(valid, ids, 0)
    deq = q8[safe].astype(jnp.float32) * scale[safe][:, :, None]
    qf = q.astype(jnp.float32)[:, None, :]
    if metric == "l2":
        d = jnp.sum((qf - deq) ** 2, axis=-1)
    elif metric == "dot":
        d = -jnp.sum(qf * deq, axis=-1)
    elif metric == "chi2":
        d = jnp.sum((qf - deq) ** 2 / (qf + deq + EPS), axis=-1)
    elif metric == "cosine":
        qn = qf / (jnp.sqrt(jnp.sum(qf * qf, -1, keepdims=True)) + EPS)
        cn = deq / (jnp.sqrt(jnp.sum(deq * deq, -1, keepdims=True)) + EPS)
        d = 1.0 - jnp.sum(qn * cn, axis=-1)
    else:
        raise ValueError(metric)
    d = jnp.where(valid, d, POS_INF)
    neg_d, pos = jax.lax.top_k(-d, k)
    out_d = -neg_d
    out_i = jnp.take_along_axis(ids, pos, axis=-1)
    return out_d, jnp.where(jnp.isinf(out_d), -1, out_i)


@jax.jit
def embedding_bag_ref(ids: jax.Array, weights: jax.Array, table: jax.Array
                      ) -> jax.Array:
    rows = table[ids]                                   # (B, H, D) gather
    return jnp.sum(rows.astype(jnp.float32) * weights[..., None], axis=1)


@functools.partial(jax.jit, static_argnames=("max_depth",))
def forest_traverse_ref(feat: jax.Array, thresh: jax.Array,
                        child_base: jax.Array, queries: jax.Array,
                        max_depth: int) -> jax.Array:
    def step(_, node):
        f = feat[node]
        xv = jnp.take_along_axis(queries, f[:, None], axis=1)[:, 0]
        go_right = (xv >= thresh[node]).astype(jnp.int32)
        cb = child_base[node]
        return jnp.where(cb < 0, node, cb + go_right)

    node0 = jnp.zeros((queries.shape[0],), jnp.int32)
    return jax.lax.fori_loop(0, max_depth, step, node0)


@functools.partial(jax.jit, static_argnames=("max_depth", "n_probes"))
def forest_traverse_multiprobe_ref(feat: jax.Array, thresh: jax.Array,
                                   child_base: jax.Array, queries: jax.Array,
                                   max_depth: int, n_probes: int) -> jax.Array:
    """Oracle for the multi-probe traversal kernel (single K=1 tree).

    Same contract as ``forest_traverse(..., n_probes=n)``: (B, n_probes)
    leaf ids, primary leaf first then alternates by ascending projection
    margin, -1 for absent probes.  Implemented over the single-tree arrays
    so kernel parity needs no Forest object; ``core.forest
    .traverse_multiprobe`` is the forest-level (vmapped, K-general) twin.
    """
    b = queries.shape[0]
    node0 = jnp.zeros((b,), jnp.int32)
    n_alt = max(0, min(n_probes - 1, max_depth))

    def primary_step(node, _):
        f = feat[node]
        xv = jnp.take_along_axis(queries, f[:, None], axis=1)[:, 0]
        cb = child_base[node]
        internal = cb >= 0
        margin = jnp.where(internal, jnp.abs(xv - thresh[node]), jnp.inf)
        child = cb + (xv >= thresh[node]).astype(jnp.int32)
        return jnp.where(internal, child, node), margin

    leaf, margins = jax.lax.scan(primary_step, node0, None, length=max_depth)
    probes = [leaf[:, None]]
    if n_alt:
        neg, flip_depth = jax.lax.top_k(-margins.T, n_alt)      # (B, n_alt)

        def alt_descend(depth_sel):
            def step(t, node):
                f = feat[node]
                xv = jnp.take_along_axis(queries, f[:, None], axis=1)[:, 0]
                cb = child_base[node]
                go_right = xv >= thresh[node]
                go_right = jnp.where(t == depth_sel, ~go_right, go_right)
                return jnp.where(cb >= 0,
                                 cb + go_right.astype(jnp.int32), node)

            return jax.lax.fori_loop(0, max_depth, step, node0)

        alts = jax.vmap(alt_descend, in_axes=1, out_axes=1)(flip_depth)
        probes.append(jnp.where(jnp.isfinite(neg), alts, -1))
    out = jnp.concatenate(probes, axis=1)
    if out.shape[1] < n_probes:
        out = jnp.pad(out, ((0, 0), (0, n_probes - out.shape[1])),
                      constant_values=-1)
    return out

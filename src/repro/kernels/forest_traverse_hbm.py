"""Pallas HBM-resident forest traversal: no SMEM node cap (DESIGN.md §11).

The SMEM kernel (kernels/forest_traverse.py) passes the tree arrays as
scalar-prefetch operands, which caps the tree at the scalar-memory budget
(~64k nodes).  Paper-scale trees (1M rows at C=12 allocate ~1.1M nodes per
tree) need the arrays to stay in HBM; this kernel fetches exactly the node
records a descent touches.

Dataflow per (tree, query-tile) grid step:
  * ``feat``/``thresh``/``child_base`` are ``memory_space=ANY`` operands —
    they never leave HBM; the query tile is the only fat VMEM block.
  * The descent is level-synchronous over the tile: at level ``t`` the bq
    per-row node records already sit in VMEM slot ``t % 2`` (three (2, bq)
    scratch buffers, one per tree array).  The kernel compares level ``t``,
    computes the per-row child, bounces the child ids VMEM -> SMEM (DMA;
    the copy engine needs scalar indices and scalars live in SMEM), and
    immediately starts the per-row record DMAs for level ``t + 1`` into
    slot ``(t + 1) % 2``.  The multi-probe margin bookkeeping then runs
    while those copies are in flight — fetch of level ``i + 1`` overlaps
    compare of level ``i`` (double buffering), so the per-level DMA
    latency hides behind compute instead of serializing the descent.
  * Node traffic is 12 B per (row, level) — at paper scale that is <1% of
    the candidate-row bytes the rerank stage moves (docs/TUNING.md).

Multi-probe: identical register-resident margin tracking to the SMEM
kernel — the primary descent records per-level margins, each alternate
re-descends with the smallest-margin decision flipped (ties -> shallower
depth).  Alternates re-fetch their node path from HBM (another
``max_depth`` rounds of 12 B records), unlike the SMEM kernel whose whole
tree is already resident — the price of removing the cap.

Bitwise contract: the float compare chain (coordinate gather, ``xv >=
thresh``, ``|xv - thresh|`` margins) is operation-for-operation the SMEM
kernel's, so leaf ids match it (and ``ref.forest_traverse_multiprobe_ref``)
bitwise at any tree size; tests/test_traverse_hbm.py pins this.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params


def _kernel(feat_hbm, thresh_hbm, child_hbm, q_ref, out_ref,
            rec_f, rec_t, rec_c, nxt_v, nxt_s, sem_rec, sem_nxt, *,
            max_depth: int, n_probes: int, bq: int):
    l = pl.program_id(0)
    q = q_ref[...]                                   # (bq, d)

    def _record_copies(slot, b):
        """The three 4-byte record DMAs for row ``b`` into ``slot``."""
        rid = nxt_s[b]
        return (
            pltpu.make_async_copy(feat_hbm.at[l, pl.ds(rid, 1)],
                                  rec_f.at[slot, pl.ds(b, 1)], sem_rec),
            pltpu.make_async_copy(thresh_hbm.at[l, pl.ds(rid, 1)],
                                  rec_t.at[slot, pl.ds(b, 1)], sem_rec),
            pltpu.make_async_copy(child_hbm.at[l, pl.ds(rid, 1)],
                                  rec_c.at[slot, pl.ds(b, 1)], sem_rec),
        )

    def start_fetch(slot):
        def body(b, _):
            for cp in _record_copies(slot, b):
                cp.start()
            return 0
        jax.lax.fori_loop(0, bq, body, 0)

    def wait_fetch(slot):
        def body(b, _):
            for cp in _record_copies(slot, b):
                cp.wait()
            return 0
        jax.lax.fori_loop(0, bq, body, 0)

    def hand_to_dma(node_vec):
        """Bounce per-row node ids into SMEM so DMA can index with them."""
        nxt_v[0, :] = node_vec
        cp = pltpu.make_async_copy(nxt_v.at[0], nxt_s, sem_nxt)
        cp.start()
        cp.wait()

    depth_col = jax.lax.broadcasted_iota(jnp.int32, (bq, max_depth), 1)
    node0 = jnp.zeros((bq,), jnp.int32)

    def descend(flip):
        """Full double-buffered descent; ``flip`` (bq,) is the depth whose
        routing decision is inverted (-1: none — the primary descent)."""
        hand_to_dma(node0)                 # level 0: every row at the root
        start_fetch(0)

        def step(t, carry):
            node, margins = carry
            slot = jax.lax.rem(t, 2)
            wait_fetch(slot)
            f = rec_f[slot]                              # (bq,) int32
            th = rec_t[slot]                             # (bq,) f32
            cb = rec_c[slot]                             # (bq,) int32
            xv = jnp.take_along_axis(q, f[:, None], axis=1)[:, 0]
            go_right = xv >= th
            go_right = jnp.where(t == flip, ~go_right, go_right)
            internal = cb >= 0
            nxt = jnp.where(internal, cb + go_right.astype(jnp.int32), node)
            # issue level t+1 fetches first; the margin bookkeeping below
            # executes while they fly (the double-buffer overlap)
            hand_to_dma(nxt)
            start_fetch(1 - slot)
            margin = jnp.where(internal, jnp.abs(xv - th), jnp.inf)
            margins = jnp.where(depth_col == t, margin[:, None], margins)
            return nxt, margins

        margins0 = jnp.full((bq, max_depth), jnp.inf, jnp.float32)
        leaf, margins = jax.lax.fori_loop(0, max_depth, step,
                                          (node0, margins0))
        wait_fetch(jax.lax.rem(max_depth, 2))   # drain the trailing prefetch
        return leaf, margins

    leaf, margins = descend(jnp.full((bq,), -1, jnp.int32))
    out_ref[0, :, 0] = leaf

    # bounded best-first expansion, identical to the SMEM kernel: flip the
    # smallest-margin decision per alternate (ties -> shallower depth)
    for p in range(1, n_probes):
        best = jnp.min(margins, axis=1)                              # (bq,)
        is_best = margins == best[:, None]
        first = jnp.min(jnp.where(is_best, depth_col, max_depth), axis=1)
        margins = jnp.where(depth_col == first[:, None], jnp.inf, margins)
        alt, _ = descend(first)
        out_ref[0, :, p] = jnp.where(jnp.isfinite(best), alt, -1)


@functools.partial(jax.jit, static_argnames=("max_depth", "bq", "interpret",
                                             "n_probes"))
def forest_traverse_hbm(feat: jax.Array, thresh: jax.Array,
                        child_base: jax.Array, queries: jax.Array,
                        max_depth: int, bq: int = 256,
                        interpret: bool = False, n_probes: int = 1
                        ) -> jax.Array:
    """Whole-forest descent with HBM-resident trees (no node-count cap).

    feat/thresh/child_base (L, max_nodes), queries (B, d).  Returns leaf
    ids (L, B) int32 for ``n_probes == 1``, else (L, B, n_probes) with -1
    marking absent probes — the same ordering as the SMEM kernel and
    ``core.forest.traverse_multiprobe``.  The tree axis rides the grid, so
    one pallas_call serves the forest.
    """
    n_trees = feat.shape[0]
    b, d = queries.shape
    bq = min(bq, b)
    b_pad = -b % bq
    qp = jnp.pad(queries, ((0, b_pad), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_kernel, max_depth=max_depth, n_probes=n_probes,
                          bq=bq),
        grid=(n_trees, (b + b_pad) // bq),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),      # feat stays in HBM
            pl.BlockSpec(memory_space=pltpu.ANY),      # thresh
            pl.BlockSpec(memory_space=pltpu.ANY),      # child_base
            pl.BlockSpec((bq, d), lambda t, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, n_probes), lambda t, i: (t, i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_trees, b + b_pad, n_probes),
                                       jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((2, bq), jnp.int32),    # rec_f: double-buffered feat
            pltpu.VMEM((2, bq), jnp.float32),  # rec_t: thresh
            pltpu.VMEM((2, bq), jnp.int32),    # rec_c: child_base
            pltpu.VMEM((1, bq), jnp.int32),    # nxt_v: node-id bounce (VMEM)
            pltpu.SMEM((bq,), jnp.int32),      # nxt_s: node ids for DMA
            pltpu.SemaphoreType.DMA,           # record fetches
            pltpu.SemaphoreType.DMA,           # VMEM->SMEM bounce
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(feat, thresh, child_base, qp)
    out = out[:, :b]
    return out[..., 0] if n_probes == 1 else out


def forest_traverse_hbm_tree(feat: jax.Array, thresh: jax.Array,
                             child_base: jax.Array, queries: jax.Array,
                             max_depth: int, bq: int = 256,
                             interpret: bool = False, n_probes: int = 1
                             ) -> jax.Array:
    """Single K=1 tree, matching ``forest_traverse``'s contract exactly:
    (B,) leaf ids for ``n_probes == 1``, else (B, n_probes)."""
    out = forest_traverse_hbm(feat[None], thresh[None], child_base[None],
                              queries, max_depth, bq=bq, interpret=interpret,
                              n_probes=n_probes)
    return out[0]

"""Pallas embedding-bag: ragged gather + weighted segment-sum (recsys hot path).

JAX has no native EmbeddingBag; the library's XLA path is take+segment_sum
(kernels/ref.py).  This kernel is the TPU-native variant in the FBGEMM-TBE
style: the multi-hot id matrix is a *scalar-prefetch* operand, so the BlockSpec
index_map itself selects which embedding-table row to DMA HBM->VMEM at each
grid step — the table is never gathered into an intermediate (B, H, D) tensor.

Layout: ids (B, H) int32 (padded with 0s), weights (B, H) f32 (0 at padding),
table (V, D).  Grid = (B, H): step (b, h) DMAs table row ids[b, h] (1, D) and
accumulates weights[b,h] * row into the (1, D) output block of bag b, which is
revisited across h (stays in VMEM; zero-initialised at h == 0).

Production note: one-row DMAs underutilize HBM bandwidth; the deployed config
sorts ids and fuses `rows_per_step` consecutive rows (see ops.embedding_bag
``rows_per_step``) — the structure here keeps the reference readable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, w_ref, row_ref, out_ref):
    h = pl.program_id(1)

    @pl.when(h == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += w_ref[0, 0] * row_ref[...].astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag(ids: jax.Array, weights: jax.Array, table: jax.Array,
                  interpret: bool = False) -> jax.Array:
    """ids (B, H) int32, weights (B, H) f32, table (V, D) -> bags (B, D) f32."""
    b, h = ids.shape
    v, d = table.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, ids_p: (i, j)),       # weights
            pl.BlockSpec((1, d), lambda i, j, ids_p: (ids_p[i, j], 0)),  # row
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, j, ids_p: (i, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=interpret,
    )(ids, weights, table)

"""Fused int8-row gather + dequantize + coarse L2 + running top-k.

The int8 shortlist stage of ``core.pipeline.rerank_fused_quantized`` used to
dequantize candidate blocks with a plain jnp gather — the (B, chunk, d) f32
block materialized in HBM, so the modeled 4x byte saving of int8 storage was
never realized on the wire.  This kernel is ``kernels/fused_query.py`` with
an int8 rerank source: candidate ids arrive as a scalar-prefetch operand
(SMEM), the quantized rows (N, d) int8 and per-row scales (N,) f32 stay in
HBM, and the kernel DMAs exactly the rows + scales a tile needs — d + 4
bytes per candidate instead of 4d — dequantizing in VMEM registers
(``rows * scale``) right before the distance math.  The dequantized tensor
never exists anywhere; the shortlist's HBM traffic drops ~4x for real
(gated at 1M rows by benchmarks/million_row.py).

Contract (mirrored by ``kernels.ref.fused_gather_topk_int8_ref``):
  q (B, d) f32, ids (B, M) int32 with -1 marking invalid slots,
  q8 (N, d) int8, scale (N,) f32  ->  (dists (B, k) f32, ids (B, k) int32);
  invalid slots: +inf / -1.  The metric (l2 | dot | chi2 | cosine) scores
  the DEQUANTIZED rows, so the coarse shortlist ranks under the same
  metric the fp32 rerank of record applies (DESIGN.md §13); the symmetric
  per-row quantization stays L2-calibrated (DESIGN.md §11) — for chi2 the
  dequantized values are promoted to f32 before the divide.

The -1-id masking vocabulary is identical to fused_query.py, so segment
tombstones compose unchanged: a dead row's slot is -1 before the kernel,
issues no DMA, scores +inf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.kernels.common import POS_INF, merge_topk, select_topk_block

EPS = 1e-12


def _kernel(ids_smem, q_ref, ids_ref, q8_ref, scale_ref, out_d_ref, out_i_ref,
            rows, srow, sem, *, bq: int, bm: int, k: int, metric: str):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_d_ref[...] = jnp.full_like(out_d_ref, POS_INF)
        out_i_ref[...] = jnp.full_like(out_i_ref, -1)

    # ---- tile-by-tile HBM gather: int8 row + 4-byte scale per candidate ---
    def _copies(t):
        b, jj = t // bm, t % bm
        rid = ids_smem[i * bq + b, j * bm + jj]
        safe = jnp.maximum(rid, 0)
        return rid, (
            pltpu.make_async_copy(q8_ref.at[safe], rows.at[b, jj], sem),
            pltpu.make_async_copy(scale_ref.at[pl.ds(safe, 1)],
                                  srow.at[b, pl.ds(jj, 1)], sem),
        )

    def _start(t, _):
        rid, cps = _copies(t)

        @pl.when(rid >= 0)
        def _():
            for cp in cps:
                cp.start()
        return 0

    def _wait(t, _):
        rid, cps = _copies(t)

        @pl.when(rid >= 0)
        def _():
            for cp in cps:
                cp.wait()
        return 0

    jax.lax.fori_loop(0, bq * bm, _start, 0)
    jax.lax.fori_loop(0, bq * bm, _wait, 0)

    # ---- dequantize in registers and score under the metric ---------------
    q = q_ref[...].astype(jnp.float32)[:, None, :]          # (bq, 1, d)
    deq = rows[...].astype(jnp.float32) * srow[...][:, :, None]
    if metric == "l2":
        diff = q - deq
        scores = jnp.sum(diff * diff, axis=-1)              # (bq, bm)
    elif metric == "dot":
        scores = -jnp.sum(q * deq, axis=-1)
    elif metric == "chi2":
        scores = jnp.sum((q - deq) ** 2 / (q + deq + EPS), axis=-1)
    elif metric == "cosine":
        qn = q / (jnp.sqrt(jnp.sum(q * q, -1, keepdims=True)) + EPS)
        cn = deq / (jnp.sqrt(jnp.sum(deq * deq, -1, keepdims=True)) + EPS)
        scores = 1.0 - jnp.sum(qn * cn, axis=-1)
    else:
        raise ValueError(metric)
    ids_vec = ids_ref[...]
    scores = jnp.where(ids_vec >= 0, scores, POS_INF)

    # ---- fold into the running (bq, k) top-k ------------------------------
    bd, bi = select_topk_block(scores, ids_vec, k)
    md, mi = merge_topk(out_d_ref[...], out_i_ref[...], bd, bi, k)
    out_d_ref[...] = md
    out_i_ref[...] = mi


@functools.partial(jax.jit, static_argnames=("k", "metric", "bq", "bm",
                                             "interpret"))
def fused_gather_topk_int8(q: jax.Array, ids: jax.Array, q8: jax.Array,
                           scale: jax.Array, k: int, metric: str = "l2",
                           bq: int = 8, bm: int = 32, interpret: bool = False
                           ) -> tuple[jax.Array, jax.Array]:
    """q (B, d), ids (B, M) int32 (-1 = invalid), q8 (N, d) int8,
    scale (N,) f32 -> coarse top-k (B, k) under ``metric`` on the
    dequantized rows.

    Never materializes the gathered or dequantized (B, M, d) tensor: int8
    rows + scales are DMA'd HBM -> VMEM tile-by-tile inside the kernel.
    """
    b, d = q.shape
    m = ids.shape[1]
    bq = min(bq, max(1, b))
    bm = min(bm, m)
    b_pad = -b % bq
    m_pad = -m % bm
    qp = jnp.pad(q, ((0, b_pad), (0, 0)))
    idsp = jnp.pad(ids, ((0, b_pad), (0, m_pad)), constant_values=-1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                     # ids -> SMEM
        grid=((b + b_pad) // bq, (m + m_pad) // bm),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j, *_: (i, 0)),
            pl.BlockSpec((bq, bm), lambda i, j, *_: (i, j)),
            pl.BlockSpec(memory_space=pltpu.ANY),  # q8 stays in HBM
            pl.BlockSpec(memory_space=pltpu.ANY),  # scale stays in HBM
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i, j, *_: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j, *_: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, bm, d), q8.dtype),     # int8 candidate tile
            pltpu.VMEM((bq, bm), jnp.float32),     # per-row scales
            pltpu.SemaphoreType.DMA,
        ],
    )
    out_d, out_i = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bm=bm, k=k, metric=metric),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b + b_pad, k), jnp.float32),
            jax.ShapeDtypeStruct((b + b_pad, k), jnp.int32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(idsp, qp, idsp, q8, scale)
    out_d, out_i = out_d[:b], out_i[:b]
    return out_d, jnp.where(jnp.isinf(out_d), -1, out_i)

"""Live autoscaling: the control loop that closes the planner's loop.

The PR-7 planner answers "given QPS X and SLO Y, what fleet?" — but it
emitted a static plan nothing acted on: a 2x-rated burst against a
statically-planned fleet sheds (degrades recall) forever, because the
degradation ladder is a LATENCY actuator, not a CAPACITY one.  This module
adds the capacity actuator (DESIGN.md §15):

  * :class:`ReplicaFleet` — N identical ``ServingRuntime`` replicas behind
    one least-depth ``submit``; ``scale_to`` adds replicas (compiled via
    their own warmup) or drains retired ones in the background without
    dropping queued requests.
  * :class:`Autoscaler` — a control loop over the fleet's own counters:
    each ``step()`` measures demand over the window as
    ``completions + queue growth`` (completions alone under-report an
    overloaded fleet — the queue is where the excess went), re-runs
    ``planner.plan`` against the measured traffic model, and resizes with
    hysteresis (a dead band around the current rated capacity) plus
    asymmetric cooldowns (scale-up after ``cooldown_s``; scale-down only
    after ``scale_down_cooldown_s`` of calm) so a burst scales up instead
    of shedding forever, and the burst's end doesn't flap the fleet.

Determinism for tests: the clock is injectable (``clock=``), ``step()`` is
pure control logic over ``fleet.stats()``, and every decision is recorded
in ``Autoscaler.history`` with its inputs.  The background ``start()``
thread is a convenience wrapper that just calls ``step()`` on a period.

Config-driven stand-up (the yml schema -> ``plan()`` / ``ServingRuntime``
wiring) lives in :mod:`repro.serve.config`.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable

from repro.serve import planner as planner_mod

__all__ = ["AutoscalerConfig", "Autoscaler", "ReplicaFleet"]


@dataclasses.dataclass(frozen=True)
class AutoscalerConfig:
    """Control-loop knobs (all times in seconds).

    slo_p99_ms            the SLO the planner re-plans against
    min_replicas          floor (never drain below)
    max_replicas          ceiling (planner targets clamp here)
    interval_s            ``start()``'s control period
    cooldown_s            min time between resizes (scale-up direction)
    scale_down_cooldown_s min CALM time before a scale-down — longer than
                          the up cooldown on purpose: adding capacity late
                          sheds requests, removing it late only costs money
    hysteresis            dead band: scale up only when measured demand
                          exceeds current rated capacity by this fraction,
                          down only when it fits the smaller fleet with
                          this much room — demand inside the band never
                          resizes, which bounds oscillation
    utilization           the planner's derate (headroom for burstiness)
    shed_panic            windowed shed fraction that overrides the dead
                          band (not the cooldown): the fleet is visibly
                          degrading, scale on the next legal tick
    demand_smoothing      EWMA weight of the newest window's demand
                          estimate (1.0 = no smoothing)
    """

    slo_p99_ms: float
    min_replicas: int = 1
    max_replicas: int = 8
    interval_s: float = 0.25
    cooldown_s: float = 1.0
    scale_down_cooldown_s: float = 4.0
    hysteresis: float = 0.15
    utilization: float = 0.7
    shed_panic: float = 0.05
    demand_smoothing: float = 0.5

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "AutoscalerConfig":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


class ReplicaFleet:
    """N identical serving replicas behind one least-depth dispatcher.

    ``make_replica`` is a zero-arg (or ``batch=``-accepting) factory
    returning a started ``ServingRuntime``; the fleet owns the replicas'
    lifecycle.  Retiring replicas drain in the background (their queued
    requests complete) and their counters fold into the fleet totals, so
    ``stats()`` stays monotone across resizes — the property the loadgen's
    delta-based shed accounting and the autoscaler's demand estimator both
    rely on.
    """

    def __init__(self, make_replica: Callable, n_replicas: int = 1,
                 batch: int | None = None):
        self._make = make_replica
        self._batch = batch
        self._lock = threading.Lock()
        self._retired = {"requests_total": 0, "requests_degraded": 0,
                         "shed_steps": 0, "recover_steps": 0}
        self._drainers: list[threading.Thread] = []
        self.resizes: list[dict] = []
        self._replicas = [self._spawn() for _ in range(max(1, n_replicas))]

    def _spawn(self):
        if self._batch is not None:
            try:
                return self._make(batch=self._batch)
            except TypeError:
                pass   # factory ignores batch re-planning
        return self._make()

    @property
    def n_replicas(self) -> int:
        return len(self._replicas)

    @property
    def replicas(self) -> list:
        return list(self._replicas)

    # ---------------------------------------------------------- dispatch
    def submit(self, query):
        with self._lock:
            target = min(self._replicas, key=lambda r: r.depth())
        return target.submit(query)

    def __call__(self, query, timeout: float = 30.0):
        req = self.submit(query)
        if not req.event.wait(timeout):
            raise TimeoutError(f"no result within {timeout}s")
        if req.error is not None:
            raise req.error
        return req.result

    # ------------------------------------------------------------ sizing
    def scale_to(self, n: int, batch: int | None = None) -> int:
        """Resize to ``n`` replicas (>=1); returns the new count.

        Growth spawns (and warms up) new replicas before they join the
        dispatch set; shrink retires the deepest-queued last, draining each
        retiree in a background thread so in-flight requests finish.
        """
        n = max(1, int(n))
        if batch is not None:
            self._batch = int(batch)
        with self._lock:
            before = len(self._replicas)
            while len(self._replicas) < n:
                self._replicas.append(self._spawn())
            retirees = []
            if len(self._replicas) > n:
                # retire the shallowest queues first: least work to drain
                keep = sorted(self._replicas, key=lambda r: -r.depth())
                self._replicas, retirees = keep[:n], keep[n:]
            if before != n:
                self.resizes.append({"t": time.monotonic(),
                                     "from": before, "to": n})
        for r in retirees:
            st = r.stats()
            for key in self._retired:
                self._retired[key] += st.get(key, 0)
            th = threading.Thread(target=r.stop, kwargs={"drain": True},
                                  daemon=True)
            th.start()
            self._drainers.append(th)
        return n

    # ------------------------------------------------------------- stats
    def stats(self) -> dict:
        with self._lock:
            reps = list(self._replicas)
        per = [r.stats() for r in reps]
        agg = dict(self._retired)
        for st in per:
            for key in self._retired:
                agg[key] += st.get(key, 0)
        agg["n_replicas"] = len(reps)
        agg["depth"] = sum(r.depth() for r in reps)
        agg["rung"] = max((st.get("rung", 0) for st in per), default=0)
        total = max(1, agg["requests_total"])
        agg["shed_fraction"] = agg["requests_degraded"] / total
        agg["resizes"] = len(self.resizes)
        return agg

    def stop(self, drain: bool = True) -> None:
        with self._lock:
            reps, self._replicas = self._replicas, []
        for r in reps:
            r.stop(drain=drain)
        for th in self._drainers:
            th.join(timeout=30.0)


class Autoscaler:
    """Measured-demand -> planner -> resize, with hysteresis + cooldown.

    ``step()`` is one control tick; ``start()`` runs ticks on
    ``config.interval_s`` in a daemon thread.  The traffic model is the
    calibrated/manifest one the static plan used — re-planning against it
    with MEASURED demand is exactly "re-run the PR-7 planner against the
    measured traffic model".
    """

    def __init__(self, fleet: ReplicaFleet, model: "planner_mod.TrafficModel",
                 config: AutoscalerConfig, batch: int | None = None,
                 clock: Callable[[], float] = time.monotonic):
        self.fleet = fleet
        self.model = model
        self.config = config
        self.batch = int(batch) if batch else None
        self._clock = clock
        self._prev: tuple | None = None     # (t, total, depth, degraded)
        self._demand: float = 0.0           # EWMA demand estimate (qps)
        self._last_resize_t: float | None = None
        self._calm_since: float | None = None
        self.history: list[dict] = []
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()

    # ------------------------------------------------------------ control
    def _serving_batch(self) -> int | None:
        """The batch the fleet actually serves at, or None if unknowable.

        Planning against the full batch grid lets the planner claim
        capacity the live replicas don't have (a replica built at batch 32
        cannot serve at batch 8's rated qps) — so the re-plan is pinned to
        the fleet's real batch whenever it can be observed.
        """
        if self.batch:
            return self.batch
        b = getattr(self.fleet, "_batch", None)
        if b:
            return int(b)
        for r in getattr(self.fleet, "replicas", []) or []:
            mb = getattr(r, "max_batch", None)
            if mb:
                return int(mb)
        return None

    def _plan_for(self, qps: float) -> tuple[int, float, int]:
        """(target replicas, rated qps/replica, batch) for measured qps —
        the planner re-run, clamped to the config's fleet bounds."""
        cfg = self.config
        kw = {}
        b = self._serving_batch()
        if b:
            kw["batch_grid"] = (b,)
        try:
            plan = planner_mod.plan(
                self.model, qps=max(qps, 1e-3), slo_p99_ms=cfg.slo_p99_ms,
                max_shards=1, max_replicas=cfg.max_replicas,
                utilization=cfg.utilization, **kw)
            return (min(max(plan.n_replicas, cfg.min_replicas),
                        cfg.max_replicas),
                    plan.rated_qps_per_replica, plan.batch)
        except ValueError:
            # demand exceeds what max_replicas serves in-SLO (or the SLO is
            # infeasible outright): pin the ceiling, shed handles the rest
            return cfg.max_replicas, 0.0, 0

    def step(self) -> dict:
        """One control tick; returns (and records) the decision."""
        cfg = self.config
        now = self._clock()
        st = self.fleet.stats()
        total, depth = st["requests_total"], st["depth"]
        degraded = st["requests_degraded"]
        n_now = self.fleet.n_replicas
        decision = {"t": now, "n_replicas": n_now, "action": "hold",
                    "reason": "", "demand_qps": 0.0, "shed_window": 0.0}
        if self._prev is None:
            # first tick only baselines the counters
            self._prev = (now, total, depth, degraded)
            self._calm_since = now
            decision["reason"] = "baseline"
            self.history.append(decision)
            return decision
        t0, total0, depth0, degraded0 = self._prev
        dt = max(now - t0, 1e-6)
        self._prev = (now, total, depth, degraded)
        served = (total - total0) / dt
        # demand = completions + queue growth: an overloaded fleet completes
        # at capacity, the excess shows up as queue depth
        inst = max(0.0, served + (depth - depth0) / dt)
        a = cfg.demand_smoothing
        self._demand = a * inst + (1.0 - a) * self._demand
        shed_win = ((degraded - degraded0) / max(1, total - total0))
        decision["demand_qps"] = round(self._demand, 3)
        decision["shed_window"] = round(shed_win, 4)

        target, per_replica, batch = self._plan_for(self._demand)
        decision["planned_replicas"] = target
        decision["planned_batch"] = batch
        capacity = n_now * per_replica
        panicking = shed_win > cfg.shed_panic
        if panicking or self._demand > capacity:
            self._calm_since = None
        elif self._calm_since is None:
            self._calm_since = now
        since_resize = (now - self._last_resize_t
                        if self._last_resize_t is not None else float("inf"))

        if target > n_now:
            over = (per_replica <= 0.0
                    or self._demand > capacity * (1.0 + cfg.hysteresis))
            if (panicking or over) and since_resize >= cfg.cooldown_s:
                self.fleet.scale_to(target, batch=batch or None)
                self._last_resize_t = now
                decision.update(action="up", n_replicas=target,
                                reason="panic" if panicking else "demand")
            else:
                decision["reason"] = ("cooldown" if since_resize
                                      < cfg.cooldown_s else "dead-band")
        elif target < n_now and n_now > cfg.min_replicas:
            smaller = n_now - 1        # step down one at a time
            fits = (per_replica > 0.0
                    and self._demand < smaller * per_replica
                    * (1.0 - cfg.hysteresis))
            calm = (self._calm_since is not None
                    and now - self._calm_since >= cfg.scale_down_cooldown_s)
            if fits and calm and since_resize >= cfg.scale_down_cooldown_s:
                self.fleet.scale_to(smaller, batch=batch or None)
                self._last_resize_t = now
                decision.update(action="down", n_replicas=smaller,
                                reason="calm")
            else:
                decision["reason"] = "awaiting-calm" if not calm else \
                    ("cooldown" if since_resize < cfg.scale_down_cooldown_s
                     else "dead-band")
        else:
            decision["reason"] = "at-target"
        self.history.append(decision)
        return decision

    # --------------------------------------------------------- background
    def start(self) -> "Autoscaler":
        if self._thread is not None:
            return self
        self._stop.clear()

        def loop():
            while not self._stop.wait(self.config.interval_s):
                try:
                    self.step()
                except Exception:       # control must not die mid-burst
                    pass

        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None

    def stats(self) -> dict:
        ups = sum(1 for d in self.history if d["action"] == "up")
        downs = sum(1 for d in self.history if d["action"] == "down")
        return {"ticks": len(self.history), "scale_ups": ups,
                "scale_downs": downs, "n_replicas": self.fleet.n_replicas,
                "demand_qps": round(self._demand, 3)}

"""Dynamic request batching for the serving paths.

Requests accumulate in a queue; a batch fires when either ``max_batch`` is
reached or ``max_wait_s`` elapses with a non-empty queue — the standard
continuous-batching front-end.  Fixed batch shapes (pad to max_batch) keep
the jitted step cache warm.

Shutdown contract: ``stop(drain=True)`` (the default) finishes everything
already queued before the worker exits; ``stop(drain=False)`` fails every
pending request fast — either way NO submitter is left hanging on an event
that will never be set (requests that are rejected or abandoned carry an
``error`` that ``__call__`` re-raises).  ``stats["stopped"]`` records which
path ran, with ``drained_on_stop`` / ``failed_on_stop`` counts.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    payload: Any
    event: threading.Event = dataclasses.field(
        default_factory=threading.Event)
    result: Any = None
    error: Optional[BaseException] = None
    enqueue_t: float = dataclasses.field(default_factory=time.perf_counter)
    done_t: float = 0.0     # completion timestamp (perf_counter), set by
    #                         the worker — open-loop load generators read it
    #                         instead of timing event.wait() themselves

    def finish(self, result=None, error: BaseException | None = None):
        self.result = result
        self.error = error
        self.done_t = time.perf_counter()
        self.event.set()


class BatcherStopped(RuntimeError):
    """Raised to submitters whose request was rejected/failed at shutdown."""


class DynamicBatcher:
    def __init__(self, serve_batch_fn: Callable[[list], list],
                 max_batch: int = 64, max_wait_s: float = 0.005,
                 latency_window: int = 1024):
        """serve_batch_fn: list[payload] -> list[result] (padded inside).

        Latencies are kept in a fixed-size ring buffer of ``latency_window``
        samples (bounded memory under sustained traffic); p99_latency_ms is
        computed over that window.
        """
        self.fn = serve_batch_fn
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.q: "queue.Queue[Request]" = queue.Queue()
        self._stop = threading.Event()
        self._drain = True
        self._worker = threading.Thread(target=self._loop, daemon=True)
        self.stats = {"batches": 0, "requests": 0, "mean_batch": 0.0,
                      "p99_latency_ms": 0.0, "depth_peak": 0,
                      "stopped": None, "drained_on_stop": 0,
                      "failed_on_stop": 0}
        self._latencies = np.zeros(max(1, latency_window), np.float64)
        self._latency_count = 0      # total samples ever observed

    def start(self):
        self._worker.start()
        return self

    def stop(self, drain: bool = True):
        """Shut the worker down without abandoning queued requests.

        ``drain=True`` serves everything already queued, then exits;
        ``drain=False`` fails every pending request immediately with
        :class:`BatcherStopped`.  Either way, every ``Request.event`` ever
        handed out IS set — concurrent submitters never hang (they either
        get a result or the error re-raised from ``__call__``).
        """
        self._drain = drain
        self._stop.set()
        if self._worker.is_alive():
            self._worker.join(timeout=30)
        self._fail_pending()    # anything the worker didn't get to
        self.stats["stopped"] = "drained" if drain else "failed"

    def depth(self) -> int:
        """Current queue depth (approximate — the scheduling signal the
        serving runtime's degradation ladder keys on)."""
        return self.q.qsize()

    def submit(self, payload) -> Request:
        req = Request(payload)
        if self._stop.is_set():
            # fail-fast: the worker may already be gone; never enqueue a
            # request nobody will answer
            req.finish(error=BatcherStopped("batcher is stopped"))
            return req
        self.q.put(req)
        return req

    def __call__(self, payload, timeout: float = 30.0):
        req = self.submit(payload)
        if not req.event.wait(timeout):
            raise TimeoutError("serve request timed out")
        if req.error is not None:
            raise req.error
        return req.result

    def _fail_pending(self) -> int:
        n = 0
        while True:
            try:
                req = self.q.get_nowait()
            except queue.Empty:
                break
            req.finish(error=BatcherStopped("batcher stopped before "
                                            "this request was served"))
            n += 1
        self.stats["failed_on_stop"] += n
        return n

    def _loop(self):
        while True:
            if self._stop.is_set():
                if not self._drain or self.q.empty():
                    break
            depth = self.q.qsize()
            if depth > self.stats["depth_peak"]:
                self.stats["depth_peak"] = depth
            batch: list[Request] = []
            try:
                batch.append(self.q.get(timeout=0.05))
            except queue.Empty:
                continue
            deadline = time.perf_counter() + self.max_wait_s
            while len(batch) < self.max_batch:
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    break
                try:
                    batch.append(self.q.get(timeout=remaining))
                except queue.Empty:
                    break
            try:
                results = self.fn([r.payload for r in batch])
            except BaseException as exc:   # noqa: BLE001 — surfaced per-req
                for r in batch:
                    r.finish(error=exc)
                continue
            window = self._latencies.shape[0]
            for r, res in zip(batch, results):
                r.finish(result=res)
                self._latencies[self._latency_count % window] = \
                    (r.done_t - r.enqueue_t) * 1e3
                self._latency_count += 1
            self.stats["batches"] += 1
            self.stats["requests"] += len(batch)
            self.stats["mean_batch"] = (self.stats["requests"]
                                        / self.stats["batches"])
            if self._latency_count:
                filled = self._latencies[:min(self._latency_count, window)]
                self.stats["p99_latency_ms"] = float(
                    np.percentile(filled, 99))
            if self._stop.is_set() and self._drain:
                self.stats["drained_on_stop"] += len(batch)

"""Dynamic request batching for the serving paths.

Requests accumulate in a queue; a batch fires when either ``max_batch`` is
reached or ``max_wait_s`` elapses with a non-empty queue — the standard
continuous-batching front-end.  Fixed batch shapes (pad to max_batch) keep
the jitted step cache warm.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    payload: Any
    event: threading.Event = dataclasses.field(
        default_factory=threading.Event)
    result: Any = None
    enqueue_t: float = dataclasses.field(default_factory=time.perf_counter)


class DynamicBatcher:
    def __init__(self, serve_batch_fn: Callable[[list], list],
                 max_batch: int = 64, max_wait_s: float = 0.005,
                 latency_window: int = 1024):
        """serve_batch_fn: list[payload] -> list[result] (padded inside).

        Latencies are kept in a fixed-size ring buffer of ``latency_window``
        samples (bounded memory under sustained traffic); p99_latency_ms is
        computed over that window.
        """
        self.fn = serve_batch_fn
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.q: "queue.Queue[Request]" = queue.Queue()
        self._stop = threading.Event()
        self._worker = threading.Thread(target=self._loop, daemon=True)
        self.stats = {"batches": 0, "requests": 0, "mean_batch": 0.0,
                      "p99_latency_ms": 0.0}
        self._latencies = np.zeros(max(1, latency_window), np.float64)
        self._latency_count = 0      # total samples ever observed

    def start(self):
        self._worker.start()
        return self

    def stop(self):
        self._stop.set()
        self._worker.join(timeout=5)

    def submit(self, payload) -> Request:
        req = Request(payload)
        self.q.put(req)
        return req

    def __call__(self, payload, timeout: float = 30.0):
        req = self.submit(payload)
        if not req.event.wait(timeout):
            raise TimeoutError("serve request timed out")
        return req.result

    def _loop(self):
        while not self._stop.is_set():
            batch: list[Request] = []
            try:
                batch.append(self.q.get(timeout=0.05))
            except queue.Empty:
                continue
            deadline = time.perf_counter() + self.max_wait_s
            while len(batch) < self.max_batch:
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    break
                try:
                    batch.append(self.q.get(timeout=remaining))
                except queue.Empty:
                    break
            results = self.fn([r.payload for r in batch])
            now = time.perf_counter()
            window = self._latencies.shape[0]
            for r, res in zip(batch, results):
                r.result = res
                self._latencies[self._latency_count % window] = \
                    (now - r.enqueue_t) * 1e3
                self._latency_count += 1
                r.event.set()
            self.stats["batches"] += 1
            self.stats["requests"] += len(batch)
            self.stats["mean_batch"] = (self.stats["requests"]
                                        / self.stats["batches"])
            if self._latency_count:
                filled = self._latencies[:min(self._latency_count, window)]
                self.stats["p99_latency_ms"] = float(
                    np.percentile(filled, 99))

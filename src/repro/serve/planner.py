"""Traffic-model capacity planner: QPS x p99 SLO -> shards/replicas/params.

The paper promises *efficient searching at scale*; this module makes the
fleet-sizing half of that measurable instead of guessed.  From a few short
calibration runs it fits an affine batch-latency model per operating point
(degradation rung), then answers the operator's question directly:

    model = calibrate(search_fn, queries, batch_grid=(1, 8, 32))
    plan  = plan(model, qps=2000, slo_p99_ms=25, n_rows=index.n_rows)
    # -> CapacityPlan(n_shards=2, n_replicas=3, rated_qps_per_replica=812,
    #                 predicted_p99_ms=21.4, ...)

Traffic model (DESIGN.md §12).  One batched search of size ``b`` costs

    t(b) = c0 + c1 * b                       (seconds; least-squares fit)

``c0`` is the fixed dispatch/kernel-launch floor, ``c1`` the marginal
per-query cost (linear in rows touched per query, which is the tuner's
cost proxy — DESIGN.md §9).  Under open-loop Poisson arrivals at rate
``lam`` served in batches of up to ``B``, a replica's utilization is
``rho = lam * t(B) / B`` and the modeled p99 sojourn is

    p99(lam) ~= w + t(B) / (1 - rho)         (w = batcher max_wait)

— the standard single-server heavy-traffic inflation: service time
stretched by the queueing factor 1/(1-rho), plus the batching delay.  The
model is deliberately coarse (it is fit from ~seconds of calibration) but
it is *monotone* in lam, so inverting it for the rated QPS at a given SLO
is exact, and the serving_slo benchmark closes the loop by measuring the
real p99 at the plan's rated QPS.

Sharding enters through ``c1``: DB rows shard evenly across ``s`` shards
(core/sharded_index.py), each cell reranks ~1/s of the candidate rows, so
the per-query marginal cost scales like ``c1 / s`` while the floor ``c0``
(traversal depth, merge, dispatch) does not.  ``plan`` picks the smallest
shard count whose modeled service time fits inside the SLO with queueing
headroom, then the replica count that carries the offered QPS.

Everything here is plain host math — no jax — so the planner can run in a
control plane far from the accelerators.  ``TrafficModel``/``CapacityPlan``
round-trip through dicts and ride the index manifest (format 4).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Sequence

import numpy as np

__all__ = ["TrafficModel", "CapacityPlan", "calibrate", "plan",
           "rated_qps"]


@dataclasses.dataclass(frozen=True)
class TrafficModel:
    """Affine batch-latency model of one operating point on one host.

    c0_s / c1_s      fit of t(b) = c0 + c1*b (seconds)
    max_wait_s       batching delay budget the model was asked about
    batch_grid       batch sizes measured
    measured_s       median latency at each grid point (evidence, kept for
                     refits and for the manifest)
    rows_per_query   the operating point's cost proxy (tuner units); lets a
                     refit rescale c1 when the operating point changes
                     without re-measuring
    """

    c0_s: float
    c1_s: float
    max_wait_s: float = 0.002
    batch_grid: tuple[int, ...] = ()
    measured_s: tuple[float, ...] = ()
    rows_per_query: float = 0.0

    def service_s(self, batch: int, n_shards: int = 1) -> float:
        """Modeled latency of one batch of ``batch`` on ``n_shards`` shards
        (marginal cost scales 1/s, the fixed floor does not)."""
        return self.c0_s + self.c1_s * batch / max(1, n_shards)

    def p99_s(self, qps: float, batch: int, n_shards: int = 1) -> float:
        """Modeled p99 sojourn at offered ``qps`` (inf past saturation)."""
        t = self.service_s(batch, n_shards)
        rho = qps * t / batch
        if rho >= 1.0:
            return float("inf")
        return self.max_wait_s + t / (1.0 - rho)

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "TrafficModel":
        known = {f.name for f in dataclasses.fields(cls)}
        d = {k: v for k, v in d.items() if k in known}
        d["batch_grid"] = tuple(d.get("batch_grid", ()))
        d["measured_s"] = tuple(d.get("measured_s", ()))
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class CapacityPlan:
    """``plan()``'s answer: the fleet shape for (qps, slo) + its evidence.

    Persisted into the index manifest (format 4) so a loaded index carries
    not just its tuned operating point but the fleet it was sized for.
    """

    qps: float                   # offered load the plan was sized for
    slo_p99_ms: float            # the latency promise
    n_shards: int                # DB shards per replica (latency axis)
    n_replicas: int              # identical serving replicas (throughput)
    batch: int                   # serving batch size
    rated_qps_per_replica: float  # max QPS one replica sustains in-SLO
    predicted_p99_ms: float      # modeled p99 at the offered per-replica QPS
    utilization: float           # headroom derate used when sizing
    recall_target: float = 0.0   # the tune() target this plan serves (0 =
    #                              unknown); the serving_slo gate checks it

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "CapacityPlan":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


def fit_affine(batch_sizes: Sequence[int],
               latencies_s: Sequence[float]) -> tuple[float, float]:
    """Least-squares (c0, c1) of t(b) = c0 + c1*b, clamped nonnegative.

    With a single grid point the whole latency is charged to c1 (the
    conservative split: predicted big-batch latency is then an upper
    bound).
    """
    b = np.asarray(batch_sizes, np.float64)
    t = np.asarray(latencies_s, np.float64)
    if b.size == 0:
        raise ValueError("cannot fit a latency model from zero points")
    if b.size == 1:
        return 0.0, float(t[0] / max(b[0], 1.0))
    a = np.stack([np.ones_like(b), b], axis=1)
    (c0, c1), *_ = np.linalg.lstsq(a, t, rcond=None)
    return float(max(c0, 0.0)), float(max(c1, 1e-9))


def calibrate(search_fn: Callable[[np.ndarray], Any], queries: np.ndarray,
              batch_grid: Sequence[int] = (1, 8, 32), repeats: int = 5,
              max_wait_s: float = 0.002,
              rows_per_query: float = 0.0) -> TrafficModel:
    """Short calibration run -> TrafficModel.

    ``search_fn(q_batch)`` must block until results are ready (the serving
    runtime passes its warmed per-rung step).  Each grid point is measured
    ``repeats`` times and the MEDIAN kept (one-off jit compiles and GC
    pauses land in the discarded tail).  Wall cost: ~grid x repeats
    searches — seconds, by design, so planning can rerun on every deploy.
    """
    queries = np.asarray(queries)
    grid = sorted({int(b) for b in batch_grid if b >= 1})
    med = []
    for b in grid:
        reps = min(b, queries.shape[0])
        q = queries[np.arange(b) % queries.shape[0]] if reps else queries[:b]
        search_fn(q)                       # warm the shape (compile cache)
        ts = []
        for _ in range(max(1, repeats)):
            t0 = time.perf_counter()
            search_fn(q)
            ts.append(time.perf_counter() - t0)
        med.append(float(np.median(ts)))
    c0, c1 = fit_affine(grid, med)
    return TrafficModel(c0_s=c0, c1_s=c1, max_wait_s=max_wait_s,
                        batch_grid=tuple(grid), measured_s=tuple(med),
                        rows_per_query=rows_per_query)


def rated_qps(model: TrafficModel, slo_p99_ms: float, batch: int,
              n_shards: int = 1, utilization: float = 0.7) -> float:
    """Max in-SLO QPS for one replica: invert p99(lam) <= slo, derated.

    The inversion of ``w + t/(1-rho) <= slo`` gives the critical rate
    ``lam* = (1 - t/(slo - w)) * B / t``; the ``utilization`` derate keeps
    headroom for burstiness the Poisson mean doesn't capture (0.7 is the
    classic serving-fleet target).  Returns 0.0 when the SLO is infeasible
    at this batch/shard point (service alone exceeds it).
    """
    slo_s = slo_p99_ms / 1e3
    t = model.service_s(batch, n_shards)
    budget = slo_s - model.max_wait_s
    if budget <= t:
        return 0.0
    lam_crit = (1.0 - t / budget) * batch / t
    return max(0.0, lam_crit * utilization)


def plan(model: TrafficModel, qps: float, slo_p99_ms: float,
         batch_grid: Sequence[int] = (1, 2, 4, 8, 16, 32, 64, 128),
         max_shards: int = 64, max_replicas: int = 4096,
         utilization: float = 0.7, recall_target: float = 0.0
         ) -> CapacityPlan:
    """Answer "given QPS X and p99 SLO Y, what fleet?".

    Walks shard counts upward (1, 2, 4, ...) until some batch size serves
    in-SLO with queueing headroom, picks the batch with the highest rated
    QPS at that shard count (fewest replicas), then sizes the replica
    count for the offered load.  Raises ValueError when no point within
    ``max_shards`` can meet the SLO — an honest "this SLO is not
    servable", rather than a plan that will melt.
    """
    if qps <= 0:
        raise ValueError(f"qps must be positive, got {qps}")
    shards = 1
    while shards <= max_shards:
        best: tuple[float, int] | None = None      # (rated, batch)
        for b in sorted({int(x) for x in batch_grid if x >= 1}):
            r = rated_qps(model, slo_p99_ms, b, shards, utilization)
            if r > 0 and (best is None or r > best[0]):
                best = (r, b)
        if best is not None:
            per_replica, batch = best
            n_replicas = int(np.ceil(qps / per_replica))
            if n_replicas <= max_replicas:
                lam = qps / n_replicas
                return CapacityPlan(
                    qps=float(qps), slo_p99_ms=float(slo_p99_ms),
                    n_shards=shards, n_replicas=n_replicas, batch=batch,
                    rated_qps_per_replica=round(per_replica, 3),
                    predicted_p99_ms=round(
                        model.p99_s(lam, batch, shards) * 1e3, 3),
                    utilization=utilization,
                    recall_target=float(recall_target))
        shards *= 2
    raise ValueError(
        f"no plan within {max_shards} shards meets p99<={slo_p99_ms}ms at "
        f"{qps} qps (model floor c0={model.c0_s * 1e3:.2f}ms, "
        f"max_wait={model.max_wait_s * 1e3:.2f}ms) — relax the SLO or "
        "cheapen the operating point")

"""End-to-end ANN serving: RPF index behind a dynamic batcher.

This is the paper's system as a service: build the forest over a corpus,
then serve batched k-NN queries through the fused single-pass pipeline
(core/pipeline.py).  Also provides the recsys retrieval bridge —
MIND interest vectors -> RPF candidate pruning -> exact rerank (compared
against brute-force fused matmul_topk in benchmarks).
"""
from __future__ import annotations

import numpy as np

from repro.core.forest import ForestConfig
from repro.core.service import AnnService
from repro.serve.batching import DynamicBatcher


def make_ann_server(db: np.ndarray, cfg: ForestConfig, k: int = 10,
                    metric: str = "l2", max_batch: int = 128,
                    max_wait_s: float = 0.002, mode: str = "auto"):
    """Returns (service, batcher). Submit 1-D query vectors; get (d, ids).

    ``mode`` is the kernel-dispatch policy (auto|pallas|ref) forwarded to the
    fused query pipeline the service runs on.
    """
    service = AnnService(db, cfg, metric=metric, mode=mode)

    def serve_batch(payloads: list) -> list:
        q = np.stack(payloads)
        d, i = service.query(q, k=k)
        return [(d[j], i[j]) for j in range(len(payloads))]

    batcher = DynamicBatcher(serve_batch, max_batch=max_batch,
                             max_wait_s=max_wait_s).start()
    return service, batcher


def retrieval_via_index(service: AnnService, interests: np.ndarray,
                        k: int = 100) -> tuple[np.ndarray, np.ndarray]:
    """Multi-interest retrieval (MIND): query the index once per interest,
    merge by max-score (= min inner-product distance)."""
    b, n_int, d = interests.shape
    flat = interests.reshape(b * n_int, d)
    dists, ids = service.query(flat, k=k)
    dists = dists.reshape(b, n_int * k)
    ids = ids.reshape(b, n_int * k)
    order = np.argsort(dists, axis=1)[:, :k]
    return (np.take_along_axis(dists, order, axis=1),
            np.take_along_axis(ids, order, axis=1))

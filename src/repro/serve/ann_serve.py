"""End-to-end ANN serving: a unified-API index behind a dynamic batcher.

This is the paper's system as a service: build any registered backend over a
corpus (IndexSpec), then serve batched k-NN queries through the fused
single-pass pipeline (core/pipeline.py).  Batches are PADDED to ``max_batch``
before hitting the index so the jitted query step compiles exactly once —
variable-size batches would otherwise trigger a fresh XLA compile per
distinct size (serve/batching.py promises fixed batch shapes).

Also provides the recsys retrieval bridge — MIND interest vectors -> RPF
candidate pruning -> exact rerank (compared against brute-force fused
matmul_topk in benchmarks).
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core.forest import ForestConfig
from repro.core.service import AnnService
from repro.index import Index, IndexSpec, SearchParams, build_index
from repro.serve.batching import DynamicBatcher


def make_ann_server(db: np.ndarray, spec: IndexSpec | ForestConfig,
                    k: int = 10, metric: str = "l2", max_batch: int = 128,
                    max_wait_s: float = 0.002, mode: str = "auto",
                    params: SearchParams | None = None,
                    index: Index | None = None
                    ) -> tuple[Index, DynamicBatcher]:
    """Returns (index, batcher). Submit 1-D query vectors; get (d, ids).

    ``spec`` selects the backend (a bare ForestConfig is accepted as
    shorthand for the rpf backend); ``params`` carries the per-query knobs
    (k/metric/mode arguments are the legacy shorthand for the common ones).
    Pass a prebuilt ``index`` to serve an existing (possibly mutated)
    index instead of building a fresh one from ``db``.

    The served index is fully mutable while serving: ``index.add`` /
    ``delete`` / ``upsert`` publish new immutable views that in-flight
    batches pick up on their next search, and ``index.compact(block=False)``
    rebuilds in the background without stalling the batcher threads
    (searches read published views, never the writer lock — DESIGN.md §8).
    """
    if isinstance(spec, ForestConfig):
        spec = IndexSpec(backend="rpf", forest=spec)
    if params is None:
        params = SearchParams(k=k, metric=metric, mode=mode)
    if index is None:
        index = build_index(jax.random.key(spec.seed), db, spec)
    d_dim = index.db.shape[1]

    def serve_batch(payloads: list) -> list:
        # fixed batch shape: pad to max_batch, slice results — one compile.
        # Pad rows REPEAT the last real query (not zeros): batch-coupled
        # paths (the adaptive-wave stop criterion is a batch mean; the
        # lsh cascade probes per row) must not be skewed by synthetic points.
        n = len(payloads)
        q = np.stack(payloads)
        q = np.concatenate(
            [q, np.repeat(q[-1:], max_batch - n, axis=0)]) if n < max_batch \
            else q
        dists, ids = index.search(q, params)
        dists, ids = np.asarray(dists), np.asarray(ids)
        return [(dists[j], ids[j]) for j in range(n)]

    batcher = DynamicBatcher(serve_batch, max_batch=max_batch,
                             max_wait_s=max_wait_s).start()
    return index, batcher


def retrieval_via_index(service: "AnnService | Index", interests: np.ndarray,
                        k: int = 100) -> tuple[np.ndarray, np.ndarray]:
    """Multi-interest retrieval (MIND): query the index once per interest,
    merge by max-score (= min inner-product distance)."""
    b, n_int, d = interests.shape
    flat = interests.reshape(b * n_int, d)
    if isinstance(service, Index):
        dists, ids = map(np.asarray, service.search(flat, SearchParams(k=k)))
    else:
        dists, ids = service.query(flat, k=k)
    dists = dists.reshape(b, n_int * k)
    ids = ids.reshape(b, n_int * k)
    order = np.argsort(dists, axis=1)[:, :k]
    return (np.take_along_axis(dists, order, axis=1),
            np.take_along_axis(ids, order, axis=1))

"""Serving runtime subsystem (DESIGN.md §12).

    runtime.ServingRuntime   tuned (sharded) serving + overload degradation
    planner                  traffic-model capacity planner (QPS x SLO)
    loadgen                  open-loop Poisson load generation
    batching.DynamicBatcher  continuous-batching front-end
    ann_serve                legacy index+batcher bridge (kept; the runtime
                             is the serving surface going forward)
"""
from repro.serve.batching import BatcherStopped, DynamicBatcher
from repro.serve.loadgen import arrival_schedule, run_open_loop, sweep
from repro.serve.planner import CapacityPlan, TrafficModel, calibrate, plan
from repro.serve.runtime import (ServingRuntime, build_ladder,
                                 uniform_shard_params)

__all__ = [
    "BatcherStopped", "CapacityPlan", "DynamicBatcher", "ServingRuntime",
    "TrafficModel", "arrival_schedule", "build_ladder", "calibrate",
    "plan", "run_open_loop", "sweep", "uniform_shard_params",
]

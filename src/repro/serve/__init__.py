"""Serving runtime subsystem (DESIGN.md §12, §15).

    runtime.ServingRuntime   tuned (sharded) serving + overload degradation
    planner                  traffic-model capacity planner (QPS x SLO)
    autoscaler               replica fleet + the control loop that re-runs
                             the planner against measured demand
    config                   fleet.yml -> plan() -> fleet stand-up
    loadgen                  open-loop Poisson load generation
    batching.DynamicBatcher  continuous-batching front-end
    ann_serve                legacy index+batcher bridge (kept; the runtime
                             is the serving surface going forward)
"""
from repro.serve.autoscaler import Autoscaler, AutoscalerConfig, ReplicaFleet
from repro.serve.batching import BatcherStopped, DynamicBatcher
from repro.serve.config import FleetHandle, build_fleet, load_config
from repro.serve.loadgen import arrival_schedule, run_open_loop, sweep
from repro.serve.planner import CapacityPlan, TrafficModel, calibrate, plan
from repro.serve.runtime import (ServingRuntime, build_ladder,
                                 uniform_shard_params)

__all__ = [
    "Autoscaler", "AutoscalerConfig", "BatcherStopped", "CapacityPlan",
    "DynamicBatcher", "FleetHandle", "ReplicaFleet", "ServingRuntime",
    "TrafficModel", "arrival_schedule", "build_fleet", "build_ladder",
    "calibrate", "load_config", "plan", "run_open_loop", "sweep",
    "uniform_shard_params",
]

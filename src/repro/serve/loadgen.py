"""Open-loop load generation: Poisson arrivals, coordinated-omission-free.

A closed-loop driver (fire request, wait, fire next — what the old
launch/serve.py did with a thread per request) measures the SERVER's pace,
not the traffic's: when the server slows down, a closed loop politely slows
its offered load and the tail you report is fiction.  This generator is
open-loop: arrivals follow a seeded Poisson process at the target QPS
regardless of completions, and each request's latency is charged from its
*scheduled* arrival time — so dispatcher lag and queueing both land in the
tail where they belong (no coordinated omission).

    report = run_open_loop(runtime, queries, qps=500, n_requests=2000)
    # report: achieved_qps, p50/p99/p999_ms, shed_fraction, recall...

Determinism: the arrival schedule and the query assigned to each request
are pure functions of (qps, n_requests, seed) — ``arrival_schedule`` is
exposed separately so tests can pin that.  Latencies are wall-clock and of
course are not.

``sweep`` walks a QPS ladder past saturation; the achieved-vs-offered gap,
the shed fraction and the p999 curve together locate the knee — the
measured rated capacity the planner's model is validated against
(benchmarks/serving_slo.py).
"""
from __future__ import annotations

import time
from typing import Sequence

import numpy as np

__all__ = ["arrival_schedule", "run_open_loop", "sweep"]


def arrival_schedule(qps: float, n_requests: int,
                     seed: int = 0) -> np.ndarray:
    """Cumulative arrival offsets (s) of a Poisson process at ``qps``.

    Deterministic in (qps, n_requests, seed); exponential inter-arrivals,
    first arrival at t=0 so a 1-request schedule is instant.
    """
    if qps <= 0:
        raise ValueError(f"qps must be positive, got {qps}")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(scale=1.0 / qps, size=max(0, n_requests - 1))
    return np.concatenate([[0.0], np.cumsum(gaps)])


def _percentiles(lat_ms: np.ndarray) -> dict:
    if lat_ms.size == 0:
        return {"p50_ms": float("nan"), "p99_ms": float("nan"),
                "p999_ms": float("nan"), "max_ms": float("nan")}
    return {"p50_ms": round(float(np.percentile(lat_ms, 50)), 3),
            "p99_ms": round(float(np.percentile(lat_ms, 99)), 3),
            "p999_ms": round(float(np.percentile(lat_ms, 99.9)), 3),
            "max_ms": round(float(lat_ms.max()), 3)}


def run_open_loop(runtime, queries: np.ndarray, qps: float,
                  n_requests: int = 1000, seed: int = 0,
                  timeout_s: float = 120.0,
                  true_ids: np.ndarray | None = None) -> dict:
    """Drive ``runtime`` (ServingRuntime or DynamicBatcher) open-loop.

    Request ``j`` uses ``queries[j % len(queries)]`` and is submitted at
    ``t0 + schedule[j]`` (if the dispatcher falls behind it submits
    immediately but latency is STILL charged from the scheduled time).
    ``true_ids`` (Q, k') enables recall-vs-oracle over the completed
    requests.  Returns the standard report dict; shed/degradation counters
    are read as a delta around the run when the runtime exposes them.
    """
    queries = np.asarray(queries, np.float32)
    sched = arrival_schedule(qps, n_requests, seed)
    # ServingRuntime.stats is a method; a bare DynamicBatcher exposes a
    # plain stats dict with no shed counters — only read the former
    stats_fn = getattr(runtime, "stats", None)
    stats_fn = stats_fn if callable(stats_fn) else None
    shed0 = stats_fn().get("requests_degraded", 0) if stats_fn else 0

    reqs = [None] * n_requests
    t0 = time.perf_counter()
    for j in range(n_requests):
        delay = t0 + sched[j] - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        reqs[j] = runtime.submit(queries[j % len(queries)])
    dispatch_s = time.perf_counter() - t0

    deadline = time.perf_counter() + timeout_s
    n_failed = n_timeout = 0
    lat_ms = np.full(n_requests, np.nan)
    results = [None] * n_requests
    for j, req in enumerate(reqs):
        if not req.event.wait(max(0.0, deadline - time.perf_counter())):
            n_timeout += 1
            continue
        if req.error is not None:
            n_failed += 1
            continue
        # open-loop accounting: latency from the SCHEDULED arrival (done_t
        # is stamped by the batcher worker, so waiting for events in
        # submission order doesn't skew later completions)
        lat_ms[j] = (req.done_t - (t0 + sched[j])) * 1e3
        results[j] = req.result
    done = np.isfinite(lat_ms)
    n_ok = int(done.sum())
    # wall clock of the run = last completion offset (arrival + sojourn)
    wall_s = (float(np.nanmax(sched + lat_ms / 1e3)) if n_ok
              else dispatch_s)
    wall_s = max(wall_s, dispatch_s, 1e-9)

    report = {
        "offered_qps": round(float(qps), 3),
        "achieved_qps": round(n_ok / wall_s, 3) if wall_s > 0 else 0.0,
        "n_requests": n_requests, "n_ok": n_ok, "n_failed": n_failed,
        "n_timeout": n_timeout, "seed": seed,
        "dispatch_lag_ms": round(
            max(0.0, float(dispatch_s - sched[-1]) * 1e3), 3),
        **_percentiles(lat_ms[done]),
    }
    if stats_fn:
        after = stats_fn()
        window = max(1, n_ok)
        report["shed_fraction"] = round(
            (after.get("requests_degraded", 0) - shed0) / window, 4)
        report["rung_final"] = after.get("rung", 0)
        report["shed_steps_total"] = after.get("shed_steps", 0)
    if true_ids is not None and n_ok:
        true_ids = np.asarray(true_ids)
        hits = []
        for j in range(n_requests):
            if results[j] is None:
                continue
            got = np.asarray(results[j][1]).ravel()
            truth = true_ids[j % len(queries)]
            hits.append(np.isin(truth, got).mean())
        report["recall_vs_oracle"] = round(float(np.mean(hits)), 4)
    return report


def sweep(runtime, queries: np.ndarray, qps_list: Sequence[float],
          n_requests: int = 500, seed: int = 0,
          true_ids: np.ndarray | None = None,
          settle_s: float = 0.25) -> list[dict]:
    """One ``run_open_loop`` per QPS point, letting the queue drain between
    points (``settle_s``) so saturation at rate i doesn't bleed into the
    rate i+1 measurement.  Returns the report rows in sweep order."""
    rows = []
    for i, qps in enumerate(qps_list):
        rows.append(run_open_loop(runtime, queries, qps,
                                  n_requests=n_requests, seed=seed + i,
                                  true_ids=true_ids))
        time.sleep(settle_s)
    return rows

"""ServingRuntime: the tuned, sharded, overload-safe serving front-end.

This closes the tune -> mesh loop (DESIGN.md §12).  Before it, the tuned
operating point died at the manifest boundary: ``tune()`` persisted
``tuned_params`` but ``launch/serve.py`` never read them, and nothing drove
``n_probes`` on the sharded query path.  The runtime owns that plumbing:

  * loads an index (or takes a built one) and resolves its operating point
    — per-shard tuned params (manifest v4) > host tuned params (v3) >
    explicit ``params`` > defaults;
  * serves either host-local (``index.search``, mutable while serving) or
    mesh-sharded (rows partitioned via ``core.sharded_index.ShardedIndex``;
    the resolved operating point is projected with
    ``SearchParams.sharded()`` — which keeps filters and probe schedules,
    both served on the mesh since DESIGN.md §15 — and its knobs actually
    reach the compiled mesh steps);
  * fronts everything with the DynamicBatcher, plus **overload
    degradation**: a precompiled ladder of operating points descending in
    cost (step ``n_probes`` down, then ``n_trees``/``adaptive_wave``); when
    queue depth breaches what the SLO model says is drainable in time, the
    runtime steps one rung down instead of letting p999 explode, and steps
    back up once the queue clears.  Every shed decision is counted
    (``stats()``: shed_steps / recover_steps / requests_degraded /
    batches_by_rung) so capacity decisions are made from evidence.

The ladder is compiled at startup (one warmup batch per rung) so a rung
switch under fire never pays an XLA compile, and the warmup timings seed
the queue-depth threshold and the planner's traffic model.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import numpy as np

from repro.index import SearchParams, load_index
from repro.serve import planner as planner_mod
from repro.serve.batching import DynamicBatcher

__all__ = ["ServingRuntime", "build_ladder", "uniform_shard_params"]


def _ladder_cost(p: SearchParams, total_trees: int) -> float:
    """Relative cost of a rung: candidate rows/query (tuner cost units)."""
    trees = p.n_trees or total_trees
    if p.probe_schedule:
        # per-query scheduling (DESIGN.md §14): the cap bounds the final
        # width, but most queries converge well below it — charge an
        # empirical ~0.6 of the cap (the tuner's measured mean replaces
        # this estimate once tune() has run with a schedule_grid)
        cost = float(trees * p.probe_schedule) * 0.6
    else:
        cost = float(trees * p.n_probes)
    if p.adaptive_wave:
        # early exit can only reduce trees actually visited
        cost *= 0.75
    return cost


def build_ladder(params: SearchParams, total_trees: int,
                 max_rungs: int = 6) -> tuple[SearchParams, ...]:
    """Degradation ladder: rung 0 = the tuned point, then strictly cheaper.

    Policy: halve the probe axis to 1 first (multi-probe buys recall
    cheaply, so it is also the cheapest recall to give back — DESIGN.md
    §9); on a scheduled base point that axis is the ``probe_schedule`` cap
    (the rungs keep the per-query convergence gate, a cap of 1 degenerates
    to the single descent), otherwise the fixed ``n_probes``.  Then halve
    the trees queried (``n_trees``; skipped when the base point has
    adaptive waves, which already scale trees).  Rungs are deduplicated and
    strictly cost-decreasing; the last rung is the cheapest the backend can
    answer at all (1 probe, >=1/4 of the trees).
    """
    rungs = [params]
    p = params
    while p.probe_schedule > 1:
        p = dataclasses.replace(p,
                                probe_schedule=max(1, p.probe_schedule // 2))
        rungs.append(p)
    while p.n_probes > 1:
        p = dataclasses.replace(p, n_probes=max(1, p.n_probes // 2))
        rungs.append(p)
    if not params.adaptive_wave:
        trees = p.n_trees or total_trees
        floor = max(1, total_trees // 4)
        while trees // 2 >= floor and trees > 1:
            trees = trees // 2
            p = dataclasses.replace(p, n_trees=trees)
            rungs.append(p)
    out, seen = [], set()
    last = float("inf")
    for p in rungs:
        c = _ladder_cost(p, total_trees)
        if p in seen or c >= last and out:
            continue
        seen.add(p)
        out.append(p)
        last = c
    return tuple(out[:max_rungs])


def uniform_shard_params(shard_params: Sequence[SearchParams]
                         ) -> SearchParams:
    """One SPMD-servable operating point covering every shard's tuned one.

    ``shard_map`` traces a single program, so per-shard knobs must collapse
    to a uniform point for the mesh hot loop: the elementwise MAX of the
    cost knobs (n_probes, expand) — every shard gets at least what its own
    tuning asked for, so the per-shard recall guarantees still hold.  The
    per-shard list itself still rides the manifest for replica-per-shard
    deployments that can honor heterogeneity.
    """
    if not shard_params:
        raise ValueError("empty shard_params")
    base = shard_params[0]
    return dataclasses.replace(
        base,
        n_probes=max(p.n_probes for p in shard_params),
        expand=max(p.expand for p in shard_params),
        chunk=max(p.chunk for p in shard_params)).sharded()


class ServingRuntime:
    """One process's serving stack: index -> (sharded) query step ->
    degradation ladder -> dynamic batcher.

    ``submit(q)`` / ``__call__(q)`` serve single 1-D query vectors and
    return ``(dists (k,), global_ids (k,))``; ``stop(drain=...)`` shuts the
    batcher down without abandoning queued requests.
    """

    def __init__(self, index, *, params: SearchParams | None = None,
                 use_tuned: bool = True, slo_p99_ms: float | None = None,
                 max_batch: int = 64, max_wait_s: float = 0.002,
                 ladder: Sequence[SearchParams] | None = None,
                 degrade: bool = True, mesh=None,
                 db_axes: Sequence[str] = ("data",),
                 tree_axis: str = "model", warmup: bool = True,
                 shed_depth: int | None = None):
        self.index = index
        self.mesh = mesh
        self.max_batch = int(max_batch)
        self.slo_p99_ms = slo_p99_ms
        total_trees = int(getattr(index.spec.forest, "n_trees", 1))
        self.params = self._resolve_params(index, params, use_tuned)
        # same capability surface as Index.search / ShardedIndex: the ONE
        # capabilities() matrix (DESIGN.md §13/§15), checked at stand-up so
        # a bad operating point fails here, not per-request in the batcher.
        # Mesh runtimes serve filters and probe schedules since §15; the
        # only filter refusal left is index-dependent (no metadata), and it
        # surfaces as a structured CapabilityError naming the entry.
        bad = self.params.capabilities("serving")
        if (mesh is not None and self.params.filter is not None
                and getattr(index, "meta_store", None) is None):
            from repro.index.params import Violation
            bad.append(Violation(
                "filter", "sharded",
                "params.filter is set but this index carries no metadata",
                "build with build_index(..., metadata={col: values}) to "
                "serve filtered queries on a mesh"))
        if bad:
            from repro.index.params import CapabilityError
            raise CapabilityError(bad, "serving")
        if ladder is None:
            ladder = build_ladder(self.params, total_trees)
        if not degrade:
            ladder = ladder[:1]
        if mesh is not None:
            # project perf knobs onto the mesh-legal set (counted as a
            # latency downgrade, not a correctness change); .sharded()
            # KEEPS filter and probe_schedule — ShardedIndex serves both
            ladder = tuple(dict.fromkeys(p.sharded() for p in ladder))
        self.ladder: tuple[SearchParams, ...] = tuple(ladder)
        self._rung = 0
        self._counters = {
            "shed_steps": 0, "recover_steps": 0, "requests_degraded": 0,
            "requests_total": 0, "batches_by_rung": [0] * len(self.ladder),
        }
        self._service_s: list[float] = [0.0] * len(self.ladder)
        if mesh is not None:
            self._init_sharded(db_axes, tree_axis)
        else:
            self._search = self._search_local
        self._batcher = DynamicBatcher(self._serve_batch,
                                       max_batch=max_batch,
                                       max_wait_s=max_wait_s)
        if warmup:
            self.warmup()
        self._shed_depth = (shed_depth if shed_depth is not None
                            else self._derive_shed_depth())
        self._batcher.start()

    # ------------------------------------------------------------ resolve
    @staticmethod
    def _resolve_params(index, params: SearchParams | None,
                        use_tuned: bool) -> SearchParams:
        """Operating-point precedence: explicit > per-shard tuned (v4) >
        host tuned (v3) > SearchParams() — the exact gap launch/serve.py
        used to have (ROADMAP: 'serve.py never reads tuned_params')."""
        if params is not None:
            return params
        if use_tuned:
            shard_params = getattr(index, "shard_params", None)
            if shard_params:
                return uniform_shard_params(shard_params)
            if index.tuned_params is not None:
                return index.tuned_params
        return SearchParams()

    @classmethod
    def load(cls, path: str, **kw) -> "ServingRuntime":
        """Stand a runtime up from a saved manifest: the tuned operating
        point, per-shard params and capacity plan (format 4) all apply
        without retuning."""
        index = load_index(path)
        plan = cls.manifest_plan(index)
        if plan is not None and "max_batch" not in kw:
            kw["max_batch"] = int(plan.batch)
        if plan is not None and "slo_p99_ms" not in kw:
            kw["slo_p99_ms"] = float(plan.slo_p99_ms)
        return cls(index, **kw)

    @staticmethod
    def manifest_plan(index) -> "planner_mod.CapacityPlan | None":
        sp = getattr(index, "serving_plan", None)
        if sp and sp.get("plan"):
            return planner_mod.CapacityPlan.from_dict(sp["plan"])
        return None

    @staticmethod
    def manifest_traffic_model(index) -> "planner_mod.TrafficModel | None":
        sp = getattr(index, "serving_plan", None)
        if sp and sp.get("traffic_model"):
            return planner_mod.TrafficModel.from_dict(sp["traffic_model"])
        return None

    # ------------------------------------------------------------ sharded
    def _init_sharded(self, db_axes: Sequence[str], tree_axis: str) -> None:
        # the ShardedIndex facade owns the padded rows, validity bitmap,
        # gid remap and per-rung compiled steps (DESIGN.md §15); ladder
        # rungs are already .sharded()-projected, so strict mode never
        # trips on a perf knob — it guards the unstrippable ones (filter)
        from repro.core.sharded_index import ShardedIndex
        self._sharded = ShardedIndex(self.index, self.mesh,
                                     db_axes=db_axes, tree_axis=tree_axis,
                                     strict=True)
        self._search = self._search_sharded

    def _search_local(self, q: np.ndarray, rung: int):
        d, i = self.index.search(q, self.ladder[rung])
        return np.asarray(d), np.asarray(i)

    def _search_sharded(self, q: np.ndarray, rung: int):
        d, i = self._sharded.search(q, self.ladder[rung])
        return np.asarray(d), np.asarray(i)

    # ------------------------------------------------------------- serving
    def _serve_batch(self, payloads: list) -> list:
        rung = self._schedule_rung()
        n = len(payloads)
        q = np.stack(payloads)
        if n < self.max_batch:
            # fixed batch shape: pad by repeating the last real query (not
            # zeros — batch-coupled paths must not see synthetic points),
            # slice results; one XLA compile per rung, paid at warmup
            q = np.concatenate(
                [q, np.repeat(q[-1:], self.max_batch - n, axis=0)])
        dists, ids = self._search(q, rung)
        self._counters["batches_by_rung"][rung] += 1
        self._counters["requests_total"] += n
        if rung > 0:
            self._counters["requests_degraded"] += n
        return [(dists[j], ids[j]) for j in range(n)]

    def _schedule_rung(self) -> int:
        """One ladder step per batch, keyed on queue depth vs the SLO model
        (hysteresis at half the shed depth so the rung doesn't flap)."""
        depth = self._batcher.depth()
        if depth > self._shed_depth and self._rung < len(self.ladder) - 1:
            self._rung += 1
            self._counters["shed_steps"] += 1
        elif depth < max(1, self._shed_depth // 2) and self._rung > 0:
            self._rung -= 1
            self._counters["recover_steps"] += 1
        return self._rung

    def _derive_shed_depth(self) -> int:
        """Queue depth beyond which the SLO is unrecoverable at rung 0.

        A queued request waits ~ depth/max_batch full-batch services; with
        the p99 budget left after one service + the batching wait, the
        drainable depth is ``budget / t_batch * max_batch``.  Without an
        SLO (or before warmup timed the rungs) fall back to 4 batches —
        a queue deeper than that means arrivals outrun service anyway.
        """
        t0 = self._service_s[0]
        if self.slo_p99_ms is None or t0 <= 0:
            return 4 * self.max_batch
        budget = self.slo_p99_ms / 1e3 - self._batcher.max_wait_s - t0
        depth = int(budget / t0 * self.max_batch) if budget > 0 else 0
        return max(self.max_batch, depth)

    def warmup(self) -> list[float]:
        """Compile every ladder rung and time one steady batch of each.

        The timings order-check the ladder, seed the shed threshold, and
        are reused by ``calibrate()`` callers; returns seconds per rung.
        """
        gids, rows = self.index.live_points()
        if rows.shape[0] == 0:
            return self._service_s
        q = rows[np.arange(self.max_batch) % rows.shape[0]].copy()
        for r in range(len(self.ladder)):
            self._search(q, r)            # compile
            t0 = time.perf_counter()
            self._search(q, r)
            self._service_s[r] = time.perf_counter() - t0
        return list(self._service_s)

    def calibrate(self, queries: np.ndarray | None = None,
                  batch_grid: Sequence[int] = (1, 8, 32),
                  repeats: int = 5) -> "planner_mod.TrafficModel":
        """Fit the planner's traffic model on THIS runtime's rung-0 step."""
        if queries is None:
            _, rows = self.index.live_points()
            queries = rows[:max(batch_grid)]
        total_trees = int(getattr(self.index.spec.forest, "n_trees", 1))
        return planner_mod.calibrate(
            lambda q: self._search(np.asarray(q), 0), np.asarray(queries),
            batch_grid=batch_grid, repeats=repeats,
            max_wait_s=self._batcher.max_wait_s,
            rows_per_query=_ladder_cost(self.ladder[0], total_trees))

    # ------------------------------------------------------------- surface
    def submit(self, query: np.ndarray):
        return self._batcher.submit(np.asarray(query, np.float32))

    def __call__(self, query: np.ndarray, timeout: float = 30.0):
        return self._batcher(np.asarray(query, np.float32), timeout=timeout)

    def depth(self) -> int:
        return self._batcher.depth()

    @property
    def rung(self) -> int:
        return self._rung

    @property
    def shed_depth(self) -> int:
        return self._shed_depth

    def stats(self) -> dict:
        c = dict(self._counters)
        c["batches_by_rung"] = list(c["batches_by_rung"])
        total = max(1, c["requests_total"])
        return {
            "rung": self._rung,
            "n_rungs": len(self.ladder),
            "shed_depth": self._shed_depth,
            "shed_fraction": c["requests_degraded"] / total,
            "service_s_by_rung": list(self._service_s),
            "sharded": self.mesh is not None,
            **c,
            "batcher": dict(self._batcher.stats),
        }

    def stop(self, drain: bool = True) -> None:
        self._batcher.stop(drain=drain)

"""Config-driven fleet stand-up: a small yml schema -> plan() + runtimes.

A serving fleet used to be hand-wired kwargs across ``ServingRuntime``,
``planner.plan`` and the mesh helpers; this module makes it a file
(DESIGN.md §15):

    # fleet.yml
    index: runs/wiki.idx            # saved manifest (ServingRuntime.load
                                    # semantics: plan/tuned params apply)
    serving:
      slo_p99_ms: 25.0
      max_batch: 32
      max_wait_s: 0.002
      degrade: true
    mesh:                           # optional: serve row-sharded
      shape: [4, 2]
      axes: [data, model]
    autoscale:                      # optional: close the planner loop
      enabled: true
      qps: 500.0                    # initial sizing target for plan()
      min_replicas: 1
      max_replicas: 8
      cooldown_s: 1.0
      scale_down_cooldown_s: 4.0
      hysteresis: 0.15

    handle = build_fleet("fleet.yml")     # plan -> replicas -> autoscaler
    handle.fleet(query)                   # serve
    handle.stop()

Parsing prefers PyYAML when importable and falls back to a built-in
parser covering exactly this schema's subset (nested maps by 2-space
indentation, scalars, inline ``[a, b]`` lists, ``#`` comments) — the
serving stack adds no hard dependency.
"""
from __future__ import annotations

import dataclasses
from typing import Any

__all__ = ["load_config", "build_fleet", "FleetHandle"]


# --------------------------------------------------------------- parsing
def _scalar(tok: str) -> Any:
    tok = tok.strip()
    if tok.startswith("[") and tok.endswith("]"):
        inner = tok[1:-1].strip()
        return [_scalar(t) for t in inner.split(",")] if inner else []
    if (tok.startswith('"') and tok.endswith('"')) or \
            (tok.startswith("'") and tok.endswith("'")):
        return tok[1:-1]
    low = tok.lower()
    if low in ("true", "yes", "on"):
        return True
    if low in ("false", "no", "off"):
        return False
    if low in ("null", "none", "~", ""):
        return None
    try:
        return int(tok)
    except ValueError:
        try:
            return float(tok)
        except ValueError:
            return tok


def _parse_simple_yaml(text: str) -> dict:
    """Indentation-nested ``key: value`` maps — the fleet.yml subset."""
    root: dict = {}
    stack: list[tuple[int, dict]] = [(-1, root)]
    for raw in text.splitlines():
        line = raw.split("#", 1)[0].rstrip()
        if not line.strip():
            continue
        indent = len(line) - len(line.lstrip())
        key, _, rest = line.strip().partition(":")
        while stack and indent <= stack[-1][0]:
            stack.pop()
        parent = stack[-1][1]
        if rest.strip():
            parent[key.strip()] = _scalar(rest)
        else:
            child: dict = {}
            parent[key.strip()] = child
            stack.append((indent, child))

    def _none_empty(d: dict):
        # a key that never got children parses as None (PyYAML parity)
        return {k: (_none_empty(v) or None) if isinstance(v, dict) else v
                for k, v in d.items()}

    return _none_empty(root)


def load_config(path: str) -> dict:
    """Parse a fleet.yml (PyYAML when available, built-in subset parser
    otherwise)."""
    with open(path) as f:
        text = f.read()
    try:
        import yaml
        return yaml.safe_load(text) or {}
    except ImportError:
        return _parse_simple_yaml(text)


# ---------------------------------------------------------------- wiring
@dataclasses.dataclass
class FleetHandle:
    """Everything ``build_fleet`` stood up, with one ``stop()``."""

    fleet: Any                       # ReplicaFleet
    autoscaler: Any | None           # Autoscaler (started) or None
    plan: Any | None                 # initial CapacityPlan or None
    model: Any | None                # TrafficModel the plan/loop use
    config: dict                     # the parsed config, as wired
    index: Any                       # the loaded/received Index

    def __call__(self, query, timeout: float = 30.0):
        return self.fleet(query, timeout=timeout)

    def stop(self) -> None:
        if self.autoscaler is not None:
            self.autoscaler.stop()
        self.fleet.stop()


def build_fleet(config: str | dict, index=None, model=None) -> FleetHandle:
    """Stand a fleet up from a fleet.yml path (or parsed dict).

    The stand-up order is the PR-7 pipeline made config-driven: load the
    manifest (tuned params + serving plan apply via ``ServingRuntime``'s
    own resolution), obtain a traffic model (manifest first, calibration
    on a probe runtime otherwise), ``plan()`` the initial replica count
    for the configured qps, then optionally start the autoscaler that
    keeps re-running that plan against measured demand.

    ``index`` / ``model`` override the manifest for callers that already
    hold one (tests, benchmarks).
    """
    from repro.serve import planner as planner_mod
    from repro.serve.autoscaler import (Autoscaler, AutoscalerConfig,
                                        ReplicaFleet)
    from repro.serve.runtime import ServingRuntime

    cfg = load_config(config) if isinstance(config, str) else dict(config)
    serving = dict(cfg.get("serving") or {})
    mesh_cfg = cfg.get("mesh") or {}
    auto_cfg = dict(cfg.get("autoscale") or {})

    if index is None:
        path = cfg.get("index")
        if not path:
            raise ValueError("fleet config needs an 'index: <manifest>' "
                             "entry (or pass index=)")
        from repro.index import load_index
        index = load_index(path)

    mesh = None
    if mesh_cfg:
        from repro import compat
        shape = tuple(int(s) for s in mesh_cfg.get("shape", ()))
        axes = tuple(str(a) for a in mesh_cfg.get("axes",
                                                  ("data", "model")))
        if len(shape) != len(axes):
            raise ValueError(f"mesh shape {shape} / axes {axes} mismatch")
        mesh = compat.make_mesh(shape, axes)

    manifest_plan = ServingRuntime.manifest_plan(index)
    slo = serving.get("slo_p99_ms",
                      manifest_plan.slo_p99_ms if manifest_plan else 25.0)
    rt_kw = dict(
        slo_p99_ms=float(slo),
        max_batch=int(serving.get(
            "max_batch", manifest_plan.batch if manifest_plan else 64)),
        max_wait_s=float(serving.get("max_wait_s", 0.002)),
        degrade=bool(serving.get("degrade", True)),
        use_tuned=bool(serving.get("use_tuned", True)),
        mesh=mesh)

    def make_replica(batch: int | None = None):
        kw = dict(rt_kw)
        if batch:
            kw["max_batch"] = int(batch)
        return ServingRuntime(index, **kw)

    if model is None:
        model = ServingRuntime.manifest_traffic_model(index)
    plan = None
    n0 = int(auto_cfg.get("min_replicas", 1))
    target_qps = auto_cfg.get("qps", serving.get("qps"))
    fleet = None
    if model is None and (target_qps or auto_cfg.get("enabled")):
        # no manifest model: calibrate on a probe replica, which then
        # joins the fleet as replica 0 (calibration is read-only traffic)
        probe = make_replica()
        model = probe.calibrate()
        seed = [probe]

        def seeded(batch: int | None = None):
            return seed.pop() if seed else make_replica(batch)

        fleet = ReplicaFleet(seeded, n_replicas=1)
    if model is not None and target_qps:
        try:
            plan = planner_mod.plan(
                model, qps=float(target_qps), slo_p99_ms=float(slo),
                max_shards=1,
                max_replicas=int(auto_cfg.get("max_replicas", 8)),
                utilization=float(auto_cfg.get("utilization", 0.7)))
            n0 = max(n0, plan.n_replicas)
        except ValueError:
            n0 = int(auto_cfg.get("max_replicas", 8))
    if fleet is None:
        fleet = ReplicaFleet(make_replica, n_replicas=n0)
    elif fleet.n_replicas < n0:
        fleet.scale_to(n0)

    scaler = None
    if auto_cfg.get("enabled") and model is not None:
        ac = AutoscalerConfig.from_dict({"slo_p99_ms": float(slo),
                                         **auto_cfg})
        scaler = Autoscaler(fleet, model, ac).start()
    return FleetHandle(fleet=fleet, autoscaler=scaler, plan=plan,
                       model=model, config=cfg, index=index)

"""Distribution utilities (re-exports; implementations live with their users).

  * meshes:               launch/mesh.py  (make_production_mesh, dp_axes)
  * logical->mesh axes:   models/layers.Axes + per-model *_specs functions
  * collectives:          core/sharded_index (global top-k merge),
                          models/moe.moe_fwd_sharded (expert-parallel psum),
                          models/mace._a_features_sharded (gather/scatter MP)
  * gradient compression: train/grad_compress (int8 error-feedback psum)
  * elastic resharding:   checkpoint/checkpointer.Checkpointer.restore
"""
from repro.core.sharded_index import merge_topk_pairs
from repro.launch.mesh import dp_axes, make_production_mesh, make_test_mesh
from repro.models.layers import Axes

__all__ = ["Axes", "dp_axes", "make_production_mesh", "make_test_mesh",
           "merge_topk_pairs"]

"""Three-term roofline model from compiled dry-run artifacts.

TPU v5e hardware constants (per chip):
  peak bf16 compute: 197 TFLOP/s
  HBM bandwidth:     819 GB/s
  ICI per link:      ~50 GB/s

Terms (all in seconds, PER DEVICE — XLA compiles one per-device SPMD program,
so cost_analysis()'s flops/bytes are already per-device):
  compute_s    = HLO_flops / peak
  memory_s     = HLO_bytes_accessed / HBM_bw     (post-fusion operand traffic;
                 an upper proxy for HBM bytes — documented in EXPERIMENTS.md)
  collective_s = collective_bytes / ICI_link_bw  (sum of per-device result
                 bytes of all all-gather/all-reduce/reduce-scatter/all-to-all/
                 collective-permute ops)

MODEL_FLOPS is the analytic useful work (6*N*D train / 2*N*D inference for
LMs, analogous per-family formulas in launch/steps meta). The
model_flops_ratio = MODEL_FLOPS / (HLO_flops * n_chips) catches
remat/redundancy waste; roofline_fraction = ideal_time / bound, where
ideal_time = MODEL_FLOPS / (chips * peak) and bound = max(three terms).

Scan caveat: XLA cost_analysis counts a while-loop body ONCE regardless of
trip count, so traffic terms must come from the ``unroll`` dry-run variants
(layers/chunks as python loops); the scan variants give the honest
memory_analysis. benchmarks/roofline_table.py merges the two.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

ARTIFACT_DIR = os.path.abspath(os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "../../artifacts/dryrun"))


def load_artifacts(directory: str = ARTIFACT_DIR) -> dict:
    out = {}
    for path in glob.glob(os.path.join(directory, "*.json")):
        with open(path) as f:
            r = json.load(f)
        key = (r["arch"], r["cell"], r["mesh"], r.get("variant", "base"))
        out[key] = r
    return out


def analytic_model_bytes(arch: str, cell_name: str, kind: str) -> int:
    """Analytic minimum HBM bytes for the step (TOTAL across chips):
    the data that MUST move — params/optimizer traffic for training, active
    params + KV cache for decode, catalog rows for retrieval, edge/node
    features for GNNs.  Used for the memory side of the ideal-time floor."""
    from repro.configs import get_arch
    from repro.configs.base import LMConfig, MACEConfig, RecsysConfig
    spec = get_arch(arch)
    cfg = spec.config
    cell = {c.name: c for c in spec.cells}[cell_name]
    if isinstance(cfg, LMConfig):
        pb = 2 if cfg.param_dtype == "bfloat16" else 4
        params_b = cfg.param_count() * pb
        act_b = 2 if cfg.compute_dtype == "bfloat16" else 4
        if kind == "train":
            # fwd read + bwd read + grad write + optimizer read/write (~2
            # moments) + stored layer activations (write + read)
            acts = (cell.global_batch * cell.seq_len * cfg.d_model
                    * cfg.n_layers * act_b * 2)
            return 6 * params_b + acts
        cache_b = (cfg.n_layers * cell.global_batch * cell.seq_len
                   * cfg.n_kv_heads * cfg.head_dim * 2 * 2)
        if kind == "prefill":
            return 2 * params_b + cache_b            # params + cache write
        # decode: active params once + the visible cache read
        active_b = params_b
        if cfg.moe:
            # only routed-active experts are read
            active_frac = (cfg.param_count() and
                           (cfg.param_count() - 0) )
            from repro.launch.steps import _lm_meta  # reuse active calc
            active_b = _lm_meta(cfg, cell, 1, "decode")["params_active"] * pb
        win = cfg.layer_windows
        vis = sum(min(w, cell.seq_len) if w else cell.seq_len for w in win)
        cache_read = (cell.global_batch * vis * cfg.n_kv_heads
                      * cfg.head_dim * 2 * 2)
        return active_b + cache_read
    if isinstance(cfg, MACEConfig):
        # per-edge messages (write+read) dominate
        from repro.launch.steps import build_cell  # not needed; use cell dims
        n_edges = cell.n_edges or (cell.batch_nodes or 0) * 165
        if cell.name == "molecule":
            n_edges = cell.n_edges * cell.n_graphs
        if cell.name == "minibatch_lg":
            n_edges = cell.batch_nodes * 165
        c = cfg.d_hidden
        return int(n_edges) * c * 9 * 4 * 2 * cfg.n_layers * 3
    if isinstance(cfg, RecsysConfig):
        d = cfg.embed_dim
        if kind == "retrieval":
            return cell.n_candidates * d * 4         # scan the catalog once
        rows = cfg.n_sparse if cfg.model != "mind" else cfg.hist_len
        per_ex = rows * d * 4 * (3 if kind == "train" else 1)
        mlp = sum(np.prod([a]) for a in [0]) if False else 0
        return cell.batch * per_ex
    return 0


def roofline_terms(record: dict) -> dict:
    """Three terms + bottleneck + model-flops ratio for one artifact.

    memory_s_upper uses cost_analysis 'bytes accessed' (per-instruction
    operand bytes post-fusion — a gross upper proxy on the CPU backend);
    memory_s_lower uses the buffer-assignment sizes (arguments + outputs +
    peak temps — every byte lives in HBM at least once).  The bound uses the
    lower estimate; both are reported.
    """
    flops = record["cost"]["flops"]
    byts = record["cost"]["bytes_accessed"]
    coll = record["collectives"]["total_bytes"]
    n_dev = record["n_devices"] if record["mesh"] == "multipod" else 256
    mem = record["memory"]
    # CPU-backend bf16 legalization: XLA-on-CPU upcasts bf16 tensors to f32
    # before collectives and in buffers, inflating every byte count 2x vs the
    # TPU program.  For archs whose params are bf16 (payloads ~all bf16) we
    # apply the 0.5 correction; mixed-dtype archs are left uncorrected
    # (conservative).  Verified by HLO inspection (EXPERIMENTS.md §Roofline).
    corr = 1.0
    try:
        from repro.configs import get_arch
        cfg = get_arch(record["arch"]).config
        if getattr(cfg, "param_dtype", "") == "bfloat16":
            corr = 0.5
    except Exception:
        pass
    compute_s = flops / PEAK_FLOPS
    memory_s_upper = corr * byts / HBM_BW
    memory_s = corr * (mem["argument_bytes"] + mem["output_bytes"]
                       + mem["temp_bytes"]) / HBM_BW
    collective_s = corr * coll / ICI_BW
    bound = max(compute_s, memory_s, collective_s, 1e-12)
    dominant = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)), key=lambda kv: kv[1])[0]
    model_flops = record["meta"].get("model_flops", 0)
    try:
        model_bytes = analytic_model_bytes(record["arch"], record["cell"],
                                           record["meta"].get("kind", ""))
    except Exception:
        model_bytes = 0
    ideal_s = max(model_flops / (n_dev * PEAK_FLOPS),
                  model_bytes / (n_dev * HBM_BW))
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "memory_s_upper": memory_s_upper,
        "collective_s": collective_s,
        "dominant": dominant,
        "bound_s": bound,
        "model_flops": model_flops,
        "model_bytes": model_bytes,
        "ideal_s": ideal_s,
        "hlo_flops_total": flops * n_dev,
        "model_flops_ratio": (model_flops / (flops * n_dev)
                              if flops else 0.0),
        "roofline_fraction": ideal_s / bound if bound else 0.0,
        "temp_gib": corr * record["memory"]["temp_bytes"] / 2**30,
        "fits_hbm": corr * (record["memory"]["temp_bytes"]
                            + record["memory"]["argument_bytes"])
        < 16 * 2**30,
        "bf16_corrected": corr != 1.0,
    }


def merged_table(directory: str = ARTIFACT_DIR,
                 mesh: str = "single") -> list[dict]:
    """One row per (arch, cell): traffic from the unroll variant when
    available, memory from the scan (base) variant."""
    arts = load_artifacts(directory)
    rows = []
    cells = sorted({(a, c) for (a, c, m, v) in arts if m == mesh})
    for arch, cell in cells:
        base = arts.get((arch, cell, mesh, "base"))
        unroll = arts.get((arch, cell, mesh, "unroll=1"))
        src = unroll or base
        if src is None:
            continue
        t = roofline_terms(src)
        if base is not None:
            t["temp_gib"] = base["memory"]["temp_bytes"] / 2**30
            t["fits_hbm"] = (base["memory"]["temp_bytes"]
                             + base["memory"]["argument_bytes"]) < 16 * 2**30
        t["arch"], t["cell"], t["mesh"] = arch, cell, mesh
        t["traffic_source"] = "unroll" if unroll else "scan(base)"
        rows.append(t)
    return rows


def format_table(rows: list[dict]) -> str:
    hdr = (f"{'arch':<26} {'cell':<14} {'compute':>9} {'memory':>9} "
           f"{'collect':>9} {'dom':>9} {'MF-ratio':>8} {'RL-frac':>8} "
           f"{'temp':>8} {'src':>12}")
    lines = [hdr, "-" * len(hdr)]
    for t in rows:
        lines.append(
            f"{t['arch']:<26} {t['cell']:<14} {t['compute_s']*1e3:8.2f}m "
            f"{t['memory_s']*1e3:8.2f}m {t['collective_s']*1e3:8.2f}m "
            f"{t['dominant']:>9} {t['model_flops_ratio']:8.3f} "
            f"{t['roofline_fraction']:8.3f} {t['temp_gib']:6.1f}G "
            f"{t['traffic_source']:>12}")
    return "\n".join(lines)

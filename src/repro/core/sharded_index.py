"""Distributed random-partition-forest index (multi-pod shard_map runtime).

Sharding model (DESIGN.md §3.1):
  * DB rows sharded over the ``db_axes`` mesh axes (e.g. ("pod", "data")) —
    each DB shard builds forests over *its own rows only*, so index build needs
    ZERO communication (the paper's 'easily parallelizable and distributable'
    property, made concrete).
  * Within a DB shard, the L trees are sharded over ``tree_axis`` ("model"):
    each cell owns L / |model| trees.
  * Query: the query batch is replicated; every (db, tree) cell traverses its
    trees, reranks against its local DB rows via the fused gather+distance+
    top-k path (no (B, M, d) intermediate — see core/pipeline.py), and emits
    a local top-k of (distance, global-id) pairs; a global top-k merge
    all-gathers the tiny (B, k) payloads over model then db axes —
    O(cells * k * 8B) bytes/query, independent of DB size.

Two query surfaces (DESIGN.md §15):
  * ``make_query_fn`` — the raw jit-able SPMD step, ONE fixed program per
    operating point.  Serves the per-cell knobs only; host-driven knobs
    (``probe_schedule``, ``filter``) are rejected with a pointer to
  * ``ShardedIndex`` — the ``Index``-protocol facade that drives those
    steps from the host: it compiles predicate bitmaps onto the row-sharded
    validity argument (the tombstone trick generalized, zero kernel
    changes) and schedules per-query probe rounds over per-width steps.

Fault tolerance: a cell's index state is a pure function of (db shard, rng
key), so recovery from a lost node = rebuild of one shard, no global state.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.forest import (Forest, ForestConfig, build_forest,
                               gather_candidates, gather_candidates_multi,
                               traverse, traverse_multiprobe)
from repro.core.search import merge_topk_pairs  # noqa: F401  (re-export)


class ShardedForest(NamedTuple):
    """Forest pytree with two leading sharded axes: (db_shards, tree_shards)."""

    forest: Forest      # arrays: (D, T, L_local, ...), P(db_axes, tree_axis)
    n_local: int        # rows per DB shard (static)
    cfg: ForestConfig   # resolved for n_local

    @property
    def trees_per_cell(self) -> int:
        return self.forest.thresh.shape[2]


def _db_spec(db_axes: Sequence[str]) -> P:
    return P(tuple(db_axes))


def build_sharded_index(key: jax.Array, db: jax.Array, cfg: ForestConfig,
                        mesh: Mesh, db_axes: Sequence[str] = ("data",),
                        tree_axis: str = "model") -> ShardedForest:
    """db: (N, d) sharded over rows by ``db_axes``. Returns a ShardedForest."""
    d_shards = 1
    for a in db_axes:
        d_shards *= mesh.shape[a]
    t_shards = mesh.shape[tree_axis]
    n_local = db.shape[0] // d_shards
    l_local = max(1, cfg.n_trees // t_shards)
    local_cfg = cfg._replace(n_trees=l_local).resolved(n_local)

    def _build(db_local):
        db_local = db_local.reshape(n_local, db.shape[1])
        di = jax.lax.axis_index(tuple(db_axes))
        ti = jax.lax.axis_index(tree_axis)
        k = jax.random.fold_in(jax.random.fold_in(key, di), ti)
        forest = build_forest(k, db_local, local_cfg)
        # add the (db, tree) leading shard axes for the out_specs
        return jax.tree.map(lambda x: x[None, None], forest)

    spec = P(tuple(db_axes), tree_axis)
    forest = compat.shard_map(
        _build, mesh=mesh,
        in_specs=(_db_spec(db_axes),),
        out_specs=jax.tree.map(lambda _: spec, Forest(
            proj_idx=0, proj_coef=0, thresh=0, child_base=0, perm=0,
            leaf_offset=0, leaf_count=0, n_nodes=0)),
        check_vma=False,
    )(db)
    return ShardedForest(forest=forest, n_local=n_local, cfg=local_cfg)


def make_query_fn(index_cfg: ForestConfig, n_local: int, mesh: Mesh,
                  db_axes: Sequence[str] = ("data",), tree_axis: str = "model",
                  k: int = 10, metric: str = "l2", dedup: bool = True,
                  kernel_mode: str = "auto", params=None,
                  with_validity: bool = False):
    """Build the jit-able sharded query step: (index, queries, db) -> top-k.

    The returned function is the unit the launcher lowers/compiles for the
    dry-run; :class:`ShardedIndex` (and through it the serving hot loop)
    drives one such step per operating point.  Kept as the compatibility
    wrapper for callers that want the raw step — new code should prefer
    ``ShardedIndex.search``.

    ``params`` (a ``repro.index.SearchParams``) is the unified-API spelling
    of the query knobs; when given it overrides the k/metric/dedup/
    kernel_mode arguments and supplies the candidate-chunk width and the
    multi-probe width (``n_probes`` — each cell descends its local trees to
    that many most-marginal leaves; the wider per-cell candidate set rides
    the same fused id/mask path and the same tiny (B, k) all-gather merge).
    Only the per-cell knobs compile into the ONE fixed SPMD program this
    returns (k, metric, dedup, mode, chunk, n_probes) — a params carrying
    ``adaptive_wave``, ``min_candidates`` or a search-time ``n_trees``
    restriction is rejected per ``SearchParams.capabilities("sharded")``,
    and the host-driven knobs (``probe_schedule``, ``filter``) are rejected
    HERE with a pointer to ``ShardedIndex.search``, which serves them by
    scheduling rounds / compiling bitmaps around steps like this one.

    ``with_validity=True`` grows the step signature to
    ``(index, queries, db, live)`` where ``live`` is an (N,) bool row
    bitmap sharded like the DB rows: the segmented-lifecycle tombstone
    mask (DESIGN.md §8) — and, since DESIGN.md §15, the carrier for
    host-compiled predicate bitmaps too.  Each cell folds its local slice
    into the fused rerank's id/mask path, so a deleted (or filtered-out)
    row never reaches any cell's top-k — serving a mutating snapshot needs
    no index rebuild, only a refreshed bitmap.
    """
    chunk, n_probes = 0, 1
    if params is not None:
        from repro.index.params import CapabilityError, Violation
        bad = list(params.capabilities("sharded"))
        if params.probe_schedule and not any(v.knob == "probe_schedule"
                                             for v in bad):
            bad.append(Violation(
                "probe_schedule", "sharded",
                f"probe_schedule={params.probe_schedule} (make_query_fn "
                f"compiles ONE fixed SPMD program; the schedule's round "
                f"count is data-dependent)",
                "use ShardedIndex.search, which host-schedules rounds "
                "over per-width steps"))
        if params.filter is not None and not any(v.knob == "filter"
                                                 for v in bad):
            bad.append(Violation(
                "filter", "sharded",
                "filter=<predicate> (the raw step consumes a validity "
                "bitmap, not a predicate AST)",
                "use ShardedIndex.search, which compiles the predicate "
                "into the row-sharded validity argument"))
        if bad:
            raise CapabilityError(
                bad, "sharded",
                prefix="make_query_fn cannot compile these params")
        k, metric = params.k, params.metric
        dedup, kernel_mode = params.dedup, params.mode
        chunk, n_probes = params.chunk, params.n_probes
    cfg = index_cfg.resolved(n_local)
    all_axes = tuple(db_axes) + (tree_axis,)

    def _query(forest_cell: Forest, queries: jax.Array, db_local: jax.Array,
               live_local: jax.Array | None = None):
        from repro.core.pipeline import rerank_fused
        forest_cell = jax.tree.map(lambda x: x[0, 0], forest_cell)
        db_local = db_local.reshape(n_local, -1)
        if live_local is not None:
            live_local = live_local.reshape(n_local)
        # 1) descend the local trees (paper: one gather + compare per level;
        #    n_probes > 1 widens to the multi-probe leaf set, DESIGN.md §9)
        if n_probes > 1:
            leaves = traverse_multiprobe(forest_cell, queries, cfg.max_depth,
                                         n_probes)
            cand_ids, mask = gather_candidates_multi(forest_cell, leaves,
                                                     cfg.leaf_pad)
        else:
            leaves = traverse(forest_cell, queries, cfg.max_depth)
            cand_ids, mask = gather_candidates(forest_cell, leaves,
                                               cfg.leaf_pad)
        # 2) fused exact rerank against local DB rows — dedup + tile-streamed
        #    gather + running top-k, no (B, M, d) intermediate per cell;
        #    tombstoned (and filtered-out) rows fold into the same id/mask
        #    path
        loc_d, loc_i = rerank_fused(queries, cand_ids, mask, db_local, k,
                                    metric=metric, mode=kernel_mode,
                                    dedup=dedup, chunk=chunk,
                                    valid=live_local)
        # 3) globalize ids, then tiny all-gather merge over tree + db axes
        di = jax.lax.axis_index(tuple(db_axes))
        glob_i = jnp.where(loc_i >= 0, loc_i + di * n_local, -1)
        gd = jax.lax.all_gather(loc_d, all_axes, axis=1, tiled=True)
        gi = jax.lax.all_gather(glob_i, all_axes, axis=1, tiled=True)
        gd = jnp.where(gi >= 0, gd, jnp.inf)
        if dedup:
            # tree shards over the same row shard surface the same
            # neighbors; without a cross-cell dedup the merged top-k holds
            # each id t_shards times, capping distinct recall at k/t_shards
            order = jnp.argsort(gi, axis=1)
            gi = jnp.take_along_axis(gi, order, axis=1)
            gd = jnp.take_along_axis(gd, order, axis=1)
            dup = jnp.concatenate(
                [jnp.zeros_like(gi[:, :1], bool), gi[:, 1:] == gi[:, :-1]],
                axis=1)
            gd = jnp.where(dup, jnp.inf, gd)
        neg, pos = jax.lax.top_k(-gd, k)
        out_i = jnp.take_along_axis(gi, pos, axis=1)
        return -neg, jnp.where(jnp.isinf(neg), -1, out_i)

    spec = P(tuple(db_axes), tree_axis)
    forest_specs = jax.tree.map(lambda _: spec, Forest(
        proj_idx=0, proj_coef=0, thresh=0, child_base=0, perm=0,
        leaf_offset=0, leaf_count=0, n_nodes=0))

    if with_validity:
        fwd = compat.shard_map(
            _query, mesh=mesh,
            in_specs=(forest_specs, P(), _db_spec(db_axes),
                      _db_spec(db_axes)),
            out_specs=(P(), P()),
            check_vma=False,
        )

        @jax.jit
        def query_step(index: ShardedForest, queries: jax.Array,
                       db: jax.Array, live: jax.Array):
            return fwd(index.forest, queries, db, live)

        return query_step

    fwd = compat.shard_map(
        lambda f, q, db_local: _query(f, q, db_local), mesh=mesh,
        in_specs=(forest_specs, P(), _db_spec(db_axes)),
        out_specs=(P(), P()),
        check_vma=False,
    )

    @jax.jit
    def query_step(index: ShardedForest, queries: jax.Array, db: jax.Array):
        return fwd(index.forest, queries, db)

    return query_step


class ShardedIndex:
    """``Index``-protocol facade over the sharded query path.

    Snapshots an ``repro.index.Index``'s live point set, builds the
    per-cell forests over the mesh, and serves ``search(queries, params)``
    / ``stats()`` / ``violations(params)`` like the host index — replacing
    ``make_query_fn``'s kwarg sprawl with one object that owns the padded
    rows, the validity bitmap, the gid remap and a cache of compiled steps
    (one per operating point actually served).

    Beyond the raw step it serves the two host-driven knobs the SPMD
    program cannot (DESIGN.md §15):

    * ``params.filter`` — the predicate is compiled ONCE host-side into a
      match bitmap in ``live_points()`` row order (exactly the row order
      the sharded DB was laid out in), ANDed with the pad/tombstone
      bitmap, and fed through the existing ``with_validity`` argument: the
      per-segment trick of DESIGN.md §13, with the mesh none the wiser.
      Selectivity is exact (bitmap counts), so the same brute-force-vs-
      widen policy applies: under ``use_brute_force`` the matching rows
      (≤ ~4k by definition) are exact-scanned host-side — distributing a
      sub-batch-sized scan is pure overhead — otherwise ``n_probes`` is
      widened per ``widen_params`` and the query rides the mesh.
    * ``params.probe_schedule`` — the host drives convergence-gated
      rounds at doubling probe widths over per-width compiled steps,
      mirroring ``core.schedule.scheduled_query``: active queries gather
      into pow2-padded buckets, each round REPLACES results (per-cell
      probe leaf sets are monotone prefixes, so the merged global top-k
      at width w sees a superset of every earlier round — replacement is
      sound shard-by-shard for the same reason it is locally), and
      ``tol=0.0`` never converges, making the final round bitwise equal
      to the fixed-cap step.

    ``strict`` controls reject-or-strip for the knobs the mesh cannot
    honor (``capabilities("sharded")``): ``strict=True`` (default) raises
    :class:`repro.index.params.CapabilityError`; ``strict=False`` strips
    exactly the perf knobs ``SearchParams.sharded()`` neutralizes
    (``adaptive_wave``/``min_candidates``/``n_trees``) and counts the
    downgrade in ``stats()``.  A ``filter`` is NEVER stripped in either
    mode — silently dropping one would change which rows come back; a
    filter that cannot be served (no metadata on the index) raises a
    structured error naming the failed capability instead.
    """

    def __init__(self, index, mesh: Mesh,
                 db_axes: Sequence[str] = ("data",),
                 tree_axis: str = "model", strict: bool = True):
        self.index = index
        self.mesh = mesh
        self.db_axes = tuple(db_axes)
        self.tree_axis = tree_axis
        self.strict = bool(strict)
        self._view = index.snapshot()
        gids, rows = self._view.live_points()
        self.n_live = int(gids.shape[0])
        if self.n_live == 0:
            raise ValueError("cannot shard an empty index")
        d_shards = 1
        for a in self.db_axes:
            d_shards *= mesh.shape[a]
        pad = (-self.n_live) % d_shards
        if pad:
            # pad to an even row split; the validity bitmap masks pad rows
            # out of every cell's top-k (same path as tombstones)
            rows = np.concatenate([rows, np.repeat(rows[-1:], pad, axis=0)])
        self._rows_host = np.asarray(rows, np.float32)
        pad_live = np.ones(rows.shape[0], bool)
        pad_live[self.n_live:] = False
        self._pad_live = pad_live
        self._gids = np.asarray(gids, np.int64)
        self._db = jnp.asarray(self._rows_host)
        self._live = jnp.asarray(pad_live)
        self._forest = build_sharded_index(
            index.key, self._db, index.spec.forest, mesh,
            db_axes=self.db_axes, tree_axis=tree_axis)
        self._steps: dict = {}           # step params -> compiled mesh step
        self._filters: dict = {}         # predicate -> (n_match, np, jnp)
        self._counters = {
            "queries": 0, "filtered_queries": 0, "brute_filtered_queries": 0,
            "scheduled_queries": 0, "probe_rounds": 0, "probes_processed": 0,
            "stripped_knobs": 0,
        }

    # --------------------------------------------------------- capability
    def _resolve(self, params, kw):
        from repro.index.params import SearchParams
        if params is not None:
            return params
        if kw:
            return SearchParams(**kw)
        tuned = getattr(self.index, "tuned_params", None)
        return tuned if tuned is not None else SearchParams()

    def violations(self, params=None) -> list:
        """``capabilities("sharded")`` of ``params`` (default: the index's
        tuned point) plus the index-dependent entries — currently one: a
        filter on a metadata-less index."""
        from repro.index.params import Violation
        params = self._resolve(params, {})
        bad = params.capabilities("sharded")
        if params.filter is not None and self._view.store is None:
            bad.append(Violation(
                "filter", "sharded",
                "params.filter is set but this index carries no metadata",
                "build with build_index(..., metadata={col: values}) to "
                "enable filtered search"))
        return bad

    def _admit(self, params):
        """Reject-or-strip per ``strict``; returns the params to serve."""
        from repro.index.params import CapabilityError
        bad = self.violations(params)
        if not bad:
            return params
        if self.strict:
            raise CapabilityError(bad, "sharded")
        stripped = params.sharded()
        still = self.violations(stripped)
        if still:
            # whatever survives .sharded() cannot be stripped away — a
            # malformed/unservable filter, an unknown metric: refuse loudly
            raise CapabilityError(still, "sharded")
        self._counters["stripped_knobs"] += len(bad)
        return stripped

    # ------------------------------------------------------------- search
    def search(self, queries, params=None, **params_kw):
        """queries (B, d) or (d,) -> (dists (B, k), GLOBAL ids (B, k)).

        Same contract as ``Index.search`` (invalid slots: dist +inf,
        id -1), answered over the snapshot this object was built from.
        """
        params = self._admit(self._resolve(params, params_kw))
        q = jnp.asarray(np.atleast_2d(np.asarray(queries, np.float32)))
        self._counters["queries"] += int(q.shape[0])
        live, eff = self._live, params
        if params.filter is not None:
            done, a, b = self._filtered_setup(q, params)
            if done:                     # zero-match / host brute regimes
                return a, b
            live, eff = a, b
        if eff.probe_schedule:
            d, gi = self._search_scheduled(q, eff, live)
        else:
            step = self._step(eff)
            with self.mesh:
                d, gi = step(self._forest, q, self._db, live)
        return jnp.asarray(d), self._remap(gi)

    def _filtered_setup(self, q, params):
        """Resolve a filtered query into ``(done, a, b)``: either the
        finished host answer ``(True, dists, ids)`` (zero-match and
        brute-force regimes) or ``(False, live bitmap, widened params)``
        to ride the mesh with."""
        from repro.filter.predicate import use_brute_force, widen_params
        from repro.index.segments import brute_force_topk
        n_match, match_np, match_dev = self._filter_bitmap(params.filter)
        self._counters["filtered_queries"] += int(q.shape[0])
        if n_match == 0:
            b = q.shape[0]
            return (True,
                    jnp.full((b, params.k), jnp.inf, jnp.float32),
                    jnp.full((b, params.k), -1, jnp.int32))
        selectivity = n_match / max(self.n_live, 1)
        if use_brute_force(selectivity, n_match):
            # the matching set is sub-batch-sized: exact-scan it host-side
            # (the same decision IndexView._search_filtered makes, so the
            # sharded path is answer-for-answer the host oracle here)
            self._counters["brute_filtered_queries"] += int(q.shape[0])
            idx = np.flatnonzero(match_np)
            d, li = brute_force_topk(q, jnp.asarray(self._rows_host[idx]),
                                     params)
            li = np.asarray(li)
            gi = np.where(li >= 0, self._gids[idx[np.clip(li, 0, None)]], -1)
            return True, jnp.asarray(d), jnp.asarray(gi)
        eff = widen_params(params, selectivity)
        # widen_params raises the lsh stop threshold too, but the cascade
        # is not served sharded — re-neutralize the non-per-cell knobs
        eff = dataclasses.replace(eff, min_candidates=1, n_trees=0)
        return False, match_dev, eff

    def _filter_bitmap(self, predicate):
        cached = self._filters.get(predicate)
        if cached is None:
            match = self._view.filter_match_live(predicate)
            bits = np.zeros(self._pad_live.shape[0], bool)
            bits[:self.n_live] = match
            cached = (int(np.count_nonzero(bits)), bits, jnp.asarray(bits))
            self._filters[predicate] = cached
        return cached

    def _step(self, params):
        # the step consumes the filter through the validity argument and
        # the schedule through per-width calls — neither is part of the
        # compiled program, so neither belongs in the cache key
        key = dataclasses.replace(params, filter=None, probe_schedule=0)
        step = self._steps.get(key)
        if step is None:
            step = make_query_fn(self._forest.cfg, self._forest.n_local,
                                 self.mesh, db_axes=self.db_axes,
                                 tree_axis=self.tree_axis, params=key,
                                 with_validity=True)
            self._steps[key] = step
        return step

    def _search_scheduled(self, q, params, live):
        """Host-driven probe rounds over per-width mesh steps — the
        ``scheduled_query`` loop with the fused local query swapped for
        the sharded step (DESIGN.md §14 one level up)."""
        from repro.core.schedule import _bucket, _improvement, probe_widths
        widths = probe_widths(params.probe_schedule)
        b, k = int(q.shape[0]), params.k
        self._counters["scheduled_queries"] += b

        def run(q_batch, w):
            step = self._step(dataclasses.replace(params, n_probes=w))
            with self.mesh:
                return step(self._forest, q_batch, self._db, live)

        best_d, best_i = run(q, widths[0])
        probes_processed = np.full(b, widths[0], np.int64)
        prev_kth = np.array(best_d[:, -1])      # writable host copy
        active = np.arange(b)
        self._counters["probe_rounds"] += 1

        for w in widths[1:]:
            if active.size == 0:
                break
            if active.size == b:
                q_act, n_act = q, b              # full batch: original order
            else:
                n_act = active.size
                padded = np.concatenate(
                    [active, np.full(_bucket(n_act, b) - n_act, active[0])])
                q_act = q[jnp.asarray(padded)]
            d, i = run(q_act, w)
            d_act, i_act = d[:n_act], i[:n_act]
            if active.size == b:
                best_d, best_i = d_act, i_act
            else:
                sel = jnp.asarray(active)
                best_d = best_d.at[sel].set(d_act)
                best_i = best_i.at[sel].set(i_act)
            probes_processed[active] += w
            self._counters["probe_rounds"] += 1
            kth = np.asarray(d_act[:, -1])
            converged = _improvement(prev_kth[active], kth) < params.tol
            prev_kth[active] = kth
            active = active[~converged]

        self._counters["probes_processed"] += int(probes_processed.sum())
        return best_d, best_i

    def _remap(self, i):
        i = np.asarray(i)
        # shard-local positions were globalized over the padded row order;
        # remap to the index's global ids (pad rows are validity-masked, so
        # positions >= n_live never appear in a top-k)
        ok = (i >= 0) & (i < self._gids.shape[0])
        return jnp.asarray(np.where(
            ok, self._gids[np.clip(i, 0, None) % self._gids.shape[0]], -1))

    # -------------------------------------------------------------- stats
    def stats(self) -> dict:
        d_shards = 1
        for a in self.db_axes:
            d_shards *= self.mesh.shape[a]
        return {
            "sharded": True,
            "strict": self.strict,
            "n_live": self.n_live,
            "n_padded": int(self._pad_live.shape[0]) - self.n_live,
            "d_shards": d_shards,
            "t_shards": self.mesh.shape[self.tree_axis],
            "n_local": self._forest.n_local,
            "trees_per_cell": self._forest.trees_per_cell,
            "compiled_steps": len(self._steps),
            "cached_filters": len(self._filters),
            "counters": dict(self._counters),
        }

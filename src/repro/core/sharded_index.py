"""Distributed random-partition-forest index (multi-pod shard_map runtime).

Sharding model (DESIGN.md §3.1):
  * DB rows sharded over the ``db_axes`` mesh axes (e.g. ("pod", "data")) —
    each DB shard builds forests over *its own rows only*, so index build needs
    ZERO communication (the paper's 'easily parallelizable and distributable'
    property, made concrete).
  * Within a DB shard, the L trees are sharded over ``tree_axis`` ("model"):
    each cell owns L / |model| trees.
  * Query: the query batch is replicated; every (db, tree) cell traverses its
    trees, reranks against its local DB rows via the fused gather+distance+
    top-k path (no (B, M, d) intermediate — see core/pipeline.py), and emits
    a local top-k of (distance, global-id) pairs; a global top-k merge
    all-gathers the tiny (B, k) payloads over model then db axes —
    O(cells * k * 8B) bytes/query, independent of DB size.

Fault tolerance: a cell's index state is a pure function of (db shard, rng
key), so recovery from a lost node = rebuild of one shard, no global state.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.forest import (Forest, ForestConfig, build_forest,
                               gather_candidates, gather_candidates_multi,
                               traverse, traverse_multiprobe)
from repro.core.search import merge_topk_pairs  # noqa: F401  (re-export)


class ShardedIndex(NamedTuple):
    """Forest pytree with two leading sharded axes: (db_shards, tree_shards)."""

    forest: Forest      # arrays: (D, T, L_local, ...), P(db_axes, tree_axis)
    n_local: int        # rows per DB shard (static)
    cfg: ForestConfig   # resolved for n_local

    @property
    def trees_per_cell(self) -> int:
        return self.forest.thresh.shape[2]


def _db_spec(db_axes: Sequence[str]) -> P:
    return P(tuple(db_axes))


def build_sharded_index(key: jax.Array, db: jax.Array, cfg: ForestConfig,
                        mesh: Mesh, db_axes: Sequence[str] = ("data",),
                        tree_axis: str = "model") -> ShardedIndex:
    """db: (N, d) sharded over rows by ``db_axes``. Returns a ShardedIndex."""
    d_shards = 1
    for a in db_axes:
        d_shards *= mesh.shape[a]
    t_shards = mesh.shape[tree_axis]
    n_local = db.shape[0] // d_shards
    l_local = max(1, cfg.n_trees // t_shards)
    local_cfg = cfg._replace(n_trees=l_local).resolved(n_local)

    def _build(db_local):
        db_local = db_local.reshape(n_local, db.shape[1])
        di = jax.lax.axis_index(tuple(db_axes))
        ti = jax.lax.axis_index(tree_axis)
        k = jax.random.fold_in(jax.random.fold_in(key, di), ti)
        forest = build_forest(k, db_local, local_cfg)
        # add the (db, tree) leading shard axes for the out_specs
        return jax.tree.map(lambda x: x[None, None], forest)

    spec = P(tuple(db_axes), tree_axis)
    forest = compat.shard_map(
        _build, mesh=mesh,
        in_specs=(_db_spec(db_axes),),
        out_specs=jax.tree.map(lambda _: spec, Forest(
            proj_idx=0, proj_coef=0, thresh=0, child_base=0, perm=0,
            leaf_offset=0, leaf_count=0, n_nodes=0)),
        check_vma=False,
    )(db)
    return ShardedIndex(forest=forest, n_local=n_local, cfg=local_cfg)


def make_query_fn(index_cfg: ForestConfig, n_local: int, mesh: Mesh,
                  db_axes: Sequence[str] = ("data",), tree_axis: str = "model",
                  k: int = 10, metric: str = "l2", dedup: bool = True,
                  kernel_mode: str = "auto", params=None,
                  with_validity: bool = False):
    """Build the jit-able sharded query step: (index, queries, db) -> top-k.

    The returned function is the unit the launcher lowers/compiles for the
    dry-run, and the serving hot loop.

    ``params`` (a ``repro.index.SearchParams``) is the unified-API spelling
    of the query knobs; when given it overrides the k/metric/dedup/
    kernel_mode arguments and supplies the candidate-chunk width and the
    multi-probe width (``n_probes`` — each cell descends its local trees to
    that many most-marginal leaves; the wider per-cell candidate set rides
    the same fused id/mask path and the same tiny (B, k) all-gather merge).
    Only the per-cell knobs apply here (k, metric, dedup, mode, chunk,
    n_probes) — the sharded path has no int8/adaptive/lsh composition,
    trees are a build-time shard property, and metadata filters need the
    host-side bitmap compiler — so a params carrying ``adaptive_wave``,
    ``min_candidates``, a search-time ``n_trees`` restriction or a
    ``filter`` predicate is rejected rather than silently ignored
    (``SearchParams.sharded_violations`` is the one list of what rejects).

    ``with_validity=True`` grows the step signature to
    ``(index, queries, db, live)`` where ``live`` is an (N,) bool row
    bitmap sharded like the DB rows: the segmented-lifecycle tombstone
    mask (DESIGN.md §8).  Each cell folds its local slice into the fused
    rerank's id/mask path, so a deleted row never reaches any cell's
    top-k — serving a mutating snapshot needs no index rebuild, only a
    refreshed bitmap.
    """
    chunk, n_probes = 0, 1
    if params is not None:
        violations = params.sharded_violations()
        if violations:
            raise ValueError(
                "sharded queries support only the per-cell knobs of "
                "SearchParams (k/metric/dedup/mode/chunk/n_probes, no "
                "filter); got " + ", ".join(violations)
                + " — project the operating point with params.sharded()")
        k, metric = params.k, params.metric
        dedup, kernel_mode = params.dedup, params.mode
        chunk, n_probes = params.chunk, params.n_probes
    cfg = index_cfg.resolved(n_local)
    all_axes = tuple(db_axes) + (tree_axis,)

    def _query(forest_cell: Forest, queries: jax.Array, db_local: jax.Array,
               live_local: jax.Array | None = None):
        from repro.core.pipeline import rerank_fused
        forest_cell = jax.tree.map(lambda x: x[0, 0], forest_cell)
        db_local = db_local.reshape(n_local, -1)
        if live_local is not None:
            live_local = live_local.reshape(n_local)
        # 1) descend the local trees (paper: one gather + compare per level;
        #    n_probes > 1 widens to the multi-probe leaf set, DESIGN.md §9)
        if n_probes > 1:
            leaves = traverse_multiprobe(forest_cell, queries, cfg.max_depth,
                                         n_probes)
            cand_ids, mask = gather_candidates_multi(forest_cell, leaves,
                                                     cfg.leaf_pad)
        else:
            leaves = traverse(forest_cell, queries, cfg.max_depth)
            cand_ids, mask = gather_candidates(forest_cell, leaves,
                                               cfg.leaf_pad)
        # 2) fused exact rerank against local DB rows — dedup + tile-streamed
        #    gather + running top-k, no (B, M, d) intermediate per cell;
        #    tombstoned rows fold into the same id/mask path
        loc_d, loc_i = rerank_fused(queries, cand_ids, mask, db_local, k,
                                    metric=metric, mode=kernel_mode,
                                    dedup=dedup, chunk=chunk,
                                    valid=live_local)
        # 3) globalize ids, then tiny all-gather merge over tree + db axes
        di = jax.lax.axis_index(tuple(db_axes))
        glob_i = jnp.where(loc_i >= 0, loc_i + di * n_local, -1)
        gd = jax.lax.all_gather(loc_d, all_axes, axis=1, tiled=True)
        gi = jax.lax.all_gather(glob_i, all_axes, axis=1, tiled=True)
        neg, pos = jax.lax.top_k(-jnp.where(gi >= 0, gd, jnp.inf), k)
        return -neg, jnp.take_along_axis(gi, pos, axis=1)

    spec = P(tuple(db_axes), tree_axis)
    forest_specs = jax.tree.map(lambda _: spec, Forest(
        proj_idx=0, proj_coef=0, thresh=0, child_base=0, perm=0,
        leaf_offset=0, leaf_count=0, n_nodes=0))

    if with_validity:
        fwd = compat.shard_map(
            _query, mesh=mesh,
            in_specs=(forest_specs, P(), _db_spec(db_axes),
                      _db_spec(db_axes)),
            out_specs=(P(), P()),
            check_vma=False,
        )

        @jax.jit
        def query_step(index: ShardedIndex, queries: jax.Array,
                       db: jax.Array, live: jax.Array):
            return fwd(index.forest, queries, db, live)

        return query_step

    fwd = compat.shard_map(
        lambda f, q, db_local: _query(f, q, db_local), mesh=mesh,
        in_specs=(forest_specs, P(), _db_spec(db_axes)),
        out_specs=(P(), P()),
        check_vma=False,
    )

    @jax.jit
    def query_step(index: ShardedIndex, queries: jax.Array, db: jax.Array):
        return fwd(index.forest, queries, db)

    return query_step

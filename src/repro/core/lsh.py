"""Locality-Sensitive Hashing baseline (the paper's comparison system, §2/§4).

E2LSH-style p-stable hashing for L2:  h(x) = floor((a.x + b) / w), with K
concatenated hashes per table and L tables.  The paper compares against a
*cascade* of LSH structures at increasing radii (0.4/0.53/0.63/0.88 on MNIST):
a query probes radii in order until enough candidates are found.  Buckets are
host-side hash maps (as in the original Andoni E2LSH software); the distance
rerank reuses the same JAX/Pallas rerank stage as the forest for a fair
accuracy-vs-cost comparison.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class LSHConfig:
    n_tables: int = 10          # L
    n_bits: int = 12            # K hashes concatenated per table
    width: float = 0.5          # w (bucket width, scales with target radius)
    seed: int = 0


class LSHIndex:
    """One radius level: L tables of K p-stable hashes each."""

    def __init__(self, x: np.ndarray, cfg: LSHConfig):
        self.cfg = cfg
        n, d = x.shape
        rng = np.random.default_rng(cfg.seed)
        # (L, K, d) gaussian projections; (L, K) uniform offsets
        self.a = rng.normal(size=(cfg.n_tables, cfg.n_bits, d)).astype(np.float32)
        self.b = rng.uniform(0.0, cfg.width,
                             size=(cfg.n_tables, cfg.n_bits)).astype(np.float32)
        keys = self._hash(x)                    # (L, N, K) int32
        self.tables: list[dict] = []
        for l in range(cfg.n_tables):
            table: dict = {}
            for i, key in enumerate(map(tuple, keys[l])):
                table.setdefault(key, []).append(i)
            self.tables.append(table)

    def _hash(self, x: np.ndarray) -> np.ndarray:
        # (L, n, K) = floor((x @ a^T + b) / w)
        proj = np.einsum("nd,lkd->lnk", x, self.a)
        return np.floor((proj + self.b[:, None, :]) / self.cfg.width).astype(
            np.int32)

    def candidates(self, q: np.ndarray) -> set:
        keys = self._hash(q[None, :])[:, 0, :]  # (L, K)
        out: set = set()
        for l in range(self.cfg.n_tables):
            out.update(self.tables[l].get(tuple(keys[l]), ()))
        return out


class CascadedLSH:
    """Multi-radius cascade (paper §2: 'a cascade of LSH tables ... searched in
    order of decreasing resolution, until either a match is found or all hash
    tables have been searched')."""

    def __init__(self, x: np.ndarray, radii: list[float], n_tables: int = 10,
                 n_bits: int = 12, width_scale: float = 1.0, seed: int = 0):
        self.x = np.asarray(x, np.float32)
        self.levels = [
            LSHIndex(self.x, LSHConfig(n_tables=n_tables, n_bits=n_bits,
                                       width=width_scale * r, seed=seed + 31 * i))
            for i, r in enumerate(radii)
        ]

    def retrieve(self, q: np.ndarray, min_candidates: int = 1) -> np.ndarray:
        cand: set = set()
        for level in self.levels:               # increasing radius
            cand.update(level.candidates(q))
            if len(cand) >= min_candidates:
                break
        return np.fromiter(cand, dtype=np.int64) if cand else np.empty(0, np.int64)

    def query(self, q: np.ndarray, k: int, min_candidates: int = 1
              ) -> tuple[np.ndarray, np.ndarray, int]:
        cand = self.retrieve(q, min_candidates)
        if cand.size == 0:
            return np.full(k, np.inf), np.full(k, -1), 0
        d = np.sum((self.x[cand] - q[None, :]) ** 2, axis=1)
        top = np.argsort(d)[:k]
        return d[top], cand[top], cand.size

"""Locality-Sensitive Hashing baseline (the paper's comparison system, §2/§4).

E2LSH-style p-stable hashing for L2:  h(x) = floor((a.x + b) / w), with K
concatenated hashes per table and L tables.  The paper compares against a
*cascade* of LSH structures at increasing radii (0.4/0.53/0.63/0.88 on MNIST):
a query probes radii in order until enough candidates are found.  Buckets are
host-side hash maps (as in the original Andoni E2LSH software); the distance
rerank reuses the same JAX/Pallas fused rerank stage as the forest for a fair
accuracy-vs-cost comparison.

Batch path: ``LSHIndex.candidates_batch`` / ``CascadedLSH.retrieve_batch``
hash a whole query batch with ONE projection einsum per level (instead of one
per point) and return padded (B, M) id/mask arrays shaped for
``core.pipeline.rerank_fused`` — the unified index API's "lsh-cascade"
backend feeds those straight into the shared fused rerank stage.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class LSHConfig:
    n_tables: int = 10          # L
    n_bits: int = 12            # K hashes concatenated per table
    width: float = 0.5          # w (bucket width, scales with target radius)
    seed: int = 0


def pad_candidate_lists(cands: list, pad_multiple: int = 64
                        ) -> tuple[np.ndarray, np.ndarray]:
    """Pad per-query candidate id lists to a common (B, M) matrix + mask.

    M is the max list length rounded up to ``pad_multiple`` (bounds the
    number of distinct shapes the downstream jitted rerank sees).  Invalid
    slots hold id 0 and mask False — the contract of
    ``forest.gather_candidates``.
    """
    m = max((len(c) for c in cands), default=0)
    m = max(pad_multiple, -(-m // pad_multiple) * pad_multiple)
    ids = np.zeros((len(cands), m), np.int32)
    mask = np.zeros((len(cands), m), bool)
    for j, c in enumerate(cands):
        ids[j, :len(c)] = c
        mask[j, :len(c)] = True
    return ids, mask


class LSHIndex:
    """One radius level: L tables of K p-stable hashes each."""

    def __init__(self, x: np.ndarray, cfg: LSHConfig):
        self.cfg = cfg
        n, d = x.shape
        rng = np.random.default_rng(cfg.seed)
        # (L, K, d) gaussian projections; (L, K) uniform offsets
        self.a = rng.normal(size=(cfg.n_tables, cfg.n_bits, d)).astype(np.float32)
        self.b = rng.uniform(0.0, cfg.width,
                             size=(cfg.n_tables, cfg.n_bits)).astype(np.float32)
        keys = self._hash(x)                    # (L, N, K) int32
        self.tables: list[dict] = []
        for l in range(cfg.n_tables):
            table: dict = {}
            for i, key in enumerate(map(tuple, keys[l])):
                table.setdefault(key, []).append(i)
            self.tables.append(table)

    def _hash(self, x: np.ndarray) -> np.ndarray:
        # (L, n, K) = floor((x @ a^T + b) / w)
        proj = np.einsum("nd,lkd->lnk", x, self.a)
        return np.floor((proj + self.b[:, None, :]) / self.cfg.width).astype(
            np.int32)

    def candidate_sets(self, q: np.ndarray) -> list:
        """(B, d) -> per-query candidate id sets; ONE _hash call per batch."""
        keys = self._hash(q)                    # (L, B, K)
        out = [set() for _ in range(q.shape[0])]
        for l in range(self.cfg.n_tables):
            table = self.tables[l]
            for j, key in enumerate(map(tuple, keys[l])):
                got = table.get(key)
                if got:
                    out[j].update(got)
        return out

    def candidates_batch(self, q: np.ndarray, pad_multiple: int = 64
                         ) -> tuple[np.ndarray, np.ndarray]:
        """(B, d) -> padded (B, M) int32 ids + (B, M) bool mask.

        Shaped for the shared fused rerank stage (ids/mask contract of
        ``gather_candidates``); one vectorized hash per batch.
        """
        sets = self.candidate_sets(np.atleast_2d(q))
        return pad_candidate_lists([sorted(s) for s in sets], pad_multiple)

    def candidates(self, q: np.ndarray) -> set:
        """Single-point shim over the batch path."""
        return self.candidate_sets(q[None, :])[0]


class CascadedLSH:
    """Multi-radius cascade (paper §2: 'a cascade of LSH tables ... searched in
    order of decreasing resolution, until either a match is found or all hash
    tables have been searched')."""

    def __init__(self, x: np.ndarray, radii: list[float], n_tables: int = 10,
                 n_bits: int = 12, width_scale: float = 1.0, seed: int = 0):
        self.x = np.asarray(x, np.float32)
        self.levels = [
            LSHIndex(self.x, LSHConfig(n_tables=n_tables, n_bits=n_bits,
                                       width=width_scale * r, seed=seed + 31 * i))
            for i, r in enumerate(radii)
        ]

    def retrieve_sets(self, q: np.ndarray, min_candidates: int = 1) -> list:
        """(B, d) -> per-query candidate sets; each query stops at the first
        radius level that accumulates >= min_candidates (cascade semantics,
        batched: one hash per level per batch)."""
        q = np.atleast_2d(q)
        out = [set() for _ in range(q.shape[0])]
        open_q = list(range(q.shape[0]))
        for level in self.levels:               # increasing radius
            if not open_q:
                break
            per_level = level.candidate_sets(q[open_q])
            still_open = []
            for j, cand in zip(open_q, per_level):
                out[j].update(cand)
                if len(out[j]) < min_candidates:
                    still_open.append(j)
            open_q = still_open
        return out

    def retrieve_batch(self, q: np.ndarray, min_candidates: int = 1,
                       pad_multiple: int = 64
                       ) -> tuple[np.ndarray, np.ndarray]:
        """(B, d) -> padded (B, M) ids + mask for the fused rerank stage."""
        sets = self.retrieve_sets(q, min_candidates)
        return pad_candidate_lists([sorted(s) for s in sets], pad_multiple)

    def retrieve(self, q: np.ndarray, min_candidates: int = 1) -> np.ndarray:
        cand = self.retrieve_sets(q[None, :], min_candidates)[0]
        return np.fromiter(cand, dtype=np.int64) if cand else np.empty(0, np.int64)

    def query(self, q: np.ndarray, k: int, min_candidates: int = 1
              ) -> tuple[np.ndarray, np.ndarray, int]:
        cand = self.retrieve(q, min_candidates)
        if cand.size == 0:
            return np.full(k, np.inf), np.full(k, -1), 0
        d = np.sum((self.x[cand] - q[None, :]) ** 2, axis=1)
        top = np.argsort(d)[:k]
        return d[top], cand[top], cand.size

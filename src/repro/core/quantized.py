"""int8-quantized database with fp32 rerank (beyond-paper memory optimization).

The candidate rerank is memory-bound (DESIGN.md §2): its roofline term is
candidate-bytes / HBM bandwidth.  Storing the DB in int8 with per-row scales
cuts that term 4x; the coarse int8 distances select a k' = expand*k shortlist
which is reranked against the fp32 rows (reading only k' fp32 rows/query).

Recall cost is negligible when expand >= 4 (tests assert parity on the
benchmark corpora).

Role note: the production dispatch lives in ``core.pipeline`` — pass a
``QuantizedDB`` to ``pipeline.fused_query`` (or use the unified
``repro.index`` API with backend="rpf+int8").  The staged implementations
here (``staged_rerank_quantized``/``staged_query_quantized``) materialize the
(B, M, d) int8 candidate tensor and survive only as the correctness oracle;
``query_forest_quantized`` is a deprecation shim over the fused path.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.forest import Forest, ForestConfig, gather_candidates, traverse
from repro.core.search import mask_duplicates, rerank_topk


class QuantizedDB(NamedTuple):
    q: jax.Array        # (N, d) int8
    scale: jax.Array    # (N,) f32 per-row scale
    fp: jax.Array       # (N, d) f32 full-precision rows (rerank source)


def quantize_db(db: jax.Array) -> QuantizedDB:
    scale = jnp.max(jnp.abs(db), axis=1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(db / scale[:, None]), -127, 127).astype(jnp.int8)
    return QuantizedDB(q=q, scale=scale, fp=db)


@functools.partial(jax.jit, static_argnames=("k", "expand"))
def staged_rerank_quantized(queries: jax.Array, cand_ids: jax.Array,
                            mask: jax.Array, qdb: QuantizedDB, k: int,
                            expand: int = 4) -> tuple[jax.Array, jax.Array]:
    """Coarse int8 L2 shortlist (k' = expand*k) -> exact fp32 rerank.

    ORACLE ONLY: gathers the full (B, M, d) int8 candidate tensor.  The
    production path is ``pipeline.rerank_fused_quantized`` (chunk-streamed,
    no full-width gather), validated against this function.
    """
    mask = mask_duplicates(cand_ids, mask)
    # coarse distances on dequantized int8 rows (4x fewer HBM bytes)
    rows = qdb.q[jnp.where(mask, cand_ids, 0)]
    deq = rows.astype(jnp.float32) * qdb.scale[
        jnp.where(mask, cand_ids, 0)][:, :, None]
    d = jnp.sum((queries[:, None, :] - deq) ** 2, axis=-1)
    d = jnp.where(mask, d, jnp.inf)
    kp = min(expand * k, cand_ids.shape[1])
    neg, pos = jax.lax.top_k(-d, kp)
    short_ids = jnp.take_along_axis(cand_ids, pos, axis=1)
    short_mask = jnp.take_along_axis(mask, pos, axis=1)
    # exact rerank on the shortlist only
    return rerank_topk(queries, short_ids, short_mask, qdb.fp, k=k,
                       dedup=False)


# kept under the historical name for external callers of the staged stage
rerank_quantized = staged_rerank_quantized


def staged_query_quantized(forest: Forest, queries: jax.Array,
                           qdb: QuantizedDB, k: int, cfg: ForestConfig,
                           expand: int = 4) -> tuple[jax.Array, jax.Array]:
    """Pre-fusion quantized query, kept verbatim as the correctness oracle."""
    cfg = cfg.resolved(qdb.fp.shape[0])
    leaves = traverse(forest, queries, cfg.max_depth)
    cand_ids, mask = gather_candidates(forest, leaves, cfg.leaf_pad)
    return staged_rerank_quantized(queries, cand_ids, mask, qdb, k=k,
                                   expand=expand)


def query_forest_quantized(forest: Forest, queries: jax.Array,
                           qdb: QuantizedDB, k: int, cfg: ForestConfig,
                           expand: int = 4, metric: str = "l2",
                           mode: str = "auto"):
    """DEPRECATED shim: use ``pipeline.fused_query(forest, q, qdb, ...)`` or
    ``repro.index`` with backend="rpf+int8".  Dispatches through the fused
    single-pass pipeline (int8 shortlist source, no (B, M, d) gather)."""
    from repro.core import pipeline  # local import to avoid cycle

    return pipeline.fused_query(forest, queries, qdb, k, cfg, metric=metric,
                                mode=mode, expand=expand)

"""Candidate rerank, dedup and top-k — the exact-distance stage of the paper.

The forest produces a padded candidate id matrix per query; this module
computes exact distances to those candidates and returns the k best.

Role note: ``rerank_topk`` here is the *staged* implementation — it gathers
the full (B, M, d) candidate tensor before scoring and serves as the oracle
the fused single-pass path (core/pipeline.py + kernels/fused_query.py) is
validated against.  Production query paths dispatch through core.pipeline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import distances as dist_mod

INF = jnp.inf


@functools.partial(jax.jit, static_argnames=())
def mask_duplicates(ids: jax.Array, mask: jax.Array) -> jax.Array:
    """Mask duplicate candidate ids per row (keeps the first occurrence).

    The paper unions the L leaf sets with a hash set; on TPU we instead sort the
    padded id row and invalidate repeats — O(M log M), fully vectorized.
    """
    big = jnp.iinfo(jnp.int32).max
    keyed = jnp.where(mask, ids, big)
    order = jnp.argsort(keyed, axis=1)
    sorted_ids = jnp.take_along_axis(keyed, order, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros_like(sorted_ids[:, :1], jnp.bool_),
         sorted_ids[:, 1:] == sorted_ids[:, :-1]], axis=1)
    # scatter dup flags back to original positions
    inv = jnp.argsort(order, axis=1)
    dup_orig = jnp.take_along_axis(dup, inv, axis=1)
    return mask & ~dup_orig


@functools.partial(jax.jit, static_argnames=("k", "metric", "dedup", "chunk"))
def rerank_topk(queries: jax.Array, cand_ids: jax.Array, mask: jax.Array,
                db: jax.Array, k: int, metric: str = "l2",
                dedup: bool = True, chunk: int = 0
                ) -> tuple[jax.Array, jax.Array]:
    """Exact distances to candidates + top-k.

    queries: (B, d); cand_ids/mask: (B, M); db: (N, d)
    Returns (dists (B, k), ids (B, k)); invalid slots: dist=+inf, id=-1.
    """
    if dedup:
        mask = mask_duplicates(cand_ids, mask)
    metric_fn = dist_mod.METRICS[metric]

    def score_block(ids_blk, mask_blk):
        cand = db[ids_blk]                       # (B, m, d) gather
        d = metric_fn(queries[:, None, :], cand)  # (B, m)
        return jnp.where(mask_blk, d, INF)

    b, m = cand_ids.shape
    if chunk and m > chunk and m % chunk == 0:
        # stream candidate blocks, keeping a running top-k (bounds peak memory;
        # mirrors the Pallas kernel's streaming structure)
        n_blk = m // chunk

        def body(carry, blk):
            best_d, best_i = carry
            ids_blk = jax.lax.dynamic_slice_in_dim(cand_ids, blk * chunk, chunk, 1)
            mask_blk = jax.lax.dynamic_slice_in_dim(mask, blk * chunk, chunk, 1)
            d = score_block(ids_blk, mask_blk)
            all_d = jnp.concatenate([best_d, d], axis=1)
            all_i = jnp.concatenate([best_i, ids_blk], axis=1)
            nd, pos = jax.lax.top_k(-all_d, k)
            return (-nd, jnp.take_along_axis(all_i, pos, axis=1)), None

        init = (jnp.full((b, k), INF, queries.dtype),
                jnp.full((b, k), -1, jnp.int32))
        (best_d, best_i), _ = jax.lax.scan(body, init, jnp.arange(n_blk))
        best_i = jnp.where(jnp.isinf(best_d), -1, best_i)
        return best_d, best_i

    d = score_block(cand_ids, mask)
    neg_d, pos = jax.lax.top_k(-d, k)
    ids = jnp.take_along_axis(cand_ids, pos, axis=1)
    dists = -neg_d
    ids = jnp.where(jnp.isinf(dists), -1, ids)
    return dists, ids


@functools.partial(jax.jit, static_argnames=("k",))
def merge_topk_pairs(dists: jax.Array, ids: jax.Array, k: int):
    """Associative (B, m*k)->(B, k) merge used by multi-level reductions.

    Invalid entries carry id -1; their distances are ignored.  (Historically
    lived in core.sharded_index, which still re-exports it.)
    """
    neg, pos = jax.lax.top_k(-jnp.where(ids >= 0, dists, jnp.inf), k)
    return -neg, jnp.take_along_axis(ids, pos, axis=1)


def recall_at_k(pred_ids: jax.Array, true_ids: jax.Array) -> jax.Array:
    """Fraction of the true k-NN ids recovered (order-insensitive).

    pred_ids, true_ids: (B, k). The paper's accuracy measure is recall@1
    ("percentage of correctly computed nearest neighbors").
    """
    hits = (pred_ids[:, :, None] == true_ids[:, None, :]).any(axis=1)
    return jnp.mean(hits.astype(jnp.float32))

"""Distance metrics used by the paper.

The paper evaluates with Euclidean distance (MNIST-784) and the Chi-Square
divergence (ISS-595, Eq. in §4):  chi2(x, y) = sum_k (x_k - y_k)^2 / (x_k + y_k).

All pairwise forms are written to be shard- and tile-friendly: the L2 pairwise
uses the |x|^2 - 2 x.y + |y|^2 expansion so the inner term is an MXU matmul.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

EPS = 1e-12

# ---------------------------------------------------------------------------
# point-to-point / point-to-set forms
# ---------------------------------------------------------------------------


def l2_sq(x: jax.Array, y: jax.Array) -> jax.Array:
    """Squared Euclidean distance along the last axis (broadcasting)."""
    d = x - y
    return jnp.sum(d * d, axis=-1)


def chi2(x: jax.Array, y: jax.Array) -> jax.Array:
    """Chi-square divergence along the last axis (broadcasting).

    Inputs are assumed non-negative (histogram features, per the paper).
    """
    num = (x - y) ** 2
    den = x + y
    return jnp.sum(num / (den + EPS), axis=-1)


def neg_dot(x: jax.Array, y: jax.Array) -> jax.Array:
    """Negative inner product (so that smaller == more similar, like a distance)."""
    return -jnp.sum(x * y, axis=-1)


def cosine_dist(x: jax.Array, y: jax.Array) -> jax.Array:
    xn = x / (jnp.linalg.norm(x, axis=-1, keepdims=True) + EPS)
    yn = y / (jnp.linalg.norm(y, axis=-1, keepdims=True) + EPS)
    return 1.0 - jnp.sum(xn * yn, axis=-1)


METRICS: dict[str, Callable[[jax.Array, jax.Array], jax.Array]] = {
    "l2": l2_sq,
    "chi2": chi2,
    "dot": neg_dot,
    "cosine": cosine_dist,
}

# user-facing aliases -> the canonical kernel spelling.  "ip" is the public
# inner-product name (SearchParams.metric accepts it); the kernels and refs
# keep scoring under "dot", so every dispatch site canonicalizes first.
METRIC_ALIASES: dict[str, str] = {
    "ip": "dot",
    "inner_product": "dot",
    "euclidean": "l2",
}


def canonical_metric(name: str) -> str:
    """Alias-resolve + validate a metric name (the one metric registry).

    Every surface that takes a metric string — ``SearchParams``,
    ``exact_knn``, the tuner — funnels through here, so "ip" and "dot"
    are the same operating point everywhere and an unknown metric fails
    loudly at the API boundary instead of as a kernel KeyError.
    """
    m = METRIC_ALIASES.get(name, name)
    if m not in METRICS:
        known = sorted(set(METRICS) | set(METRIC_ALIASES))
        raise ValueError(f"unknown metric {name!r} (known: {known})")
    return m

# ---------------------------------------------------------------------------
# pairwise (Q, d) x (N, d) -> (Q, N) forms
# ---------------------------------------------------------------------------


def pairwise_l2_sq(q: jax.Array, db: jax.Array) -> jax.Array:
    """(Q, d) x (N, d) -> (Q, N), via the matmul expansion (MXU-friendly)."""
    qn = jnp.sum(q * q, axis=-1)[:, None]
    dn = jnp.sum(db * db, axis=-1)[None, :]
    cross = q @ db.T
    out = qn - 2.0 * cross + dn
    return jnp.maximum(out, 0.0)


def pairwise_chi2(q: jax.Array, db: jax.Array) -> jax.Array:
    """(Q, d) x (N, d) -> (Q, N) chi-square. O(Q*N*d) elementwise (VPU-bound)."""
    x = q[:, None, :]
    y = db[None, :, :]
    return jnp.sum((x - y) ** 2 / (x + y + EPS), axis=-1)


def pairwise_dot(q: jax.Array, db: jax.Array) -> jax.Array:
    return -(q @ db.T)


def pairwise_cosine(q: jax.Array, db: jax.Array) -> jax.Array:
    qn = q / (jnp.linalg.norm(q, axis=-1, keepdims=True) + EPS)
    dn = db / (jnp.linalg.norm(db, axis=-1, keepdims=True) + EPS)
    return 1.0 - qn @ dn.T


PAIRWISE: dict[str, Callable[[jax.Array, jax.Array], jax.Array]] = {
    "l2": pairwise_l2_sq,
    "chi2": pairwise_chi2,
    "dot": pairwise_dot,
    "cosine": pairwise_cosine,
}


@functools.partial(jax.jit, static_argnames=("metric",))
def pairwise(q: jax.Array, db: jax.Array, metric: str = "l2") -> jax.Array:
    return PAIRWISE[metric](q, db)


def normalize_rows(x: jax.Array) -> jax.Array:
    """Unit-normalize rows (the paper normalizes MNIST vectors to norm 1)."""
    return x / (jnp.linalg.norm(x, axis=-1, keepdims=True) + EPS)

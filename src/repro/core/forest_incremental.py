"""Paper-faithful transcription of the incremental builder (Zhong 2015, Fig. 1/3).

This is the *semantics oracle*: a direct numpy port of the paper's pseudocode —
points are inserted one at a time in random order, a leaf splits when its count
exceeds C, and the split hyper-plane is Eq. 1 with the threshold a random
percentile in [r, 1-r] of the points at the node.  Used by tests to check that
the TPU-native level-synchronous builder (`core.forest`) yields partitions with
identical invariants, and by benchmarks as the paper-faithful baseline.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class _Node:
    # internal: test (idx, coef, thresh); leaf: point id list
    idx: Optional[np.ndarray] = None
    coef: Optional[np.ndarray] = None
    thresh: float = 0.0
    left: Optional["_Node"] = None
    right: Optional["_Node"] = None
    points: Optional[list] = None

    def is_leaf(self) -> bool:
        return self.left is None


class IncrementalTree:
    """One random binary partition tree, built incrementally (paper Fig. 1)."""

    def __init__(self, x: np.ndarray, capacity: int, split_ratio: float,
                 n_proj: int, rng: np.random.Generator):
        self.x = x
        self.capacity = capacity
        self.r = split_ratio
        self.k = n_proj
        self.rng = rng
        self.root = _Node(points=[])

    def _project(self, node: _Node, xi: np.ndarray) -> float:
        return float(np.dot(xi[node.idx], node.coef))

    def _descend(self, xi: np.ndarray) -> _Node:
        node = self.root
        while not node.is_leaf():
            # Eq. 1: t(x) = sum_k x[d_k] xi_k - psi >= 0  -> left child
            if self._project(node, xi) - node.thresh >= 0:
                node = node.left
            else:
                node = node.right
        return node

    def _make_test(self, node: _Node) -> None:
        """RandomTest(node.GetDataPoints(), r) from the paper's pseudocode."""
        d = self.x.shape[1]
        node.idx = self.rng.integers(0, d, size=self.k)
        node.coef = (np.ones(self.k) if self.k == 1
                     else self.rng.uniform(0.0, 1.0, size=self.k))
        y = self.x[np.asarray(node.points)][:, node.idx] @ node.coef
        y_sorted = np.sort(y)
        n = len(y_sorted)
        # paper Eq. 1: psi ~ U[y_{r n}, y_{(1-r) n}] (interval of VALUES)
        a = y_sorted[min(int(np.floor(self.r * n)), n - 1)]
        b = y_sorted[min(int(np.floor((1.0 - self.r) * n)), n - 1)]
        u = float(self.rng.uniform())
        psi = a + u * (b - a)
        lo, hi = y_sorted[0], y_sorted[-1]
        if psi <= lo:   # tie escape (see core/forest.py)
            psi = lo + max(u, 0.05) * (hi - lo)
        node.thresh = float(psi)

    def insert(self, i: int) -> None:
        node = self._descend(self.x[i])
        node.points.append(i)
        if len(node.points) > self.capacity:
            self._make_test(node)
            y = self.x[np.asarray(node.points)][:, node.idx] @ node.coef
            go_left = (y - node.thresh) >= 0
            left_pts = [p for p, g in zip(node.points, go_left) if g]
            right_pts = [p for p, g in zip(node.points, go_left) if not g]
            if not left_pts or not right_pts:
                # degenerate split (ties): keep as fat leaf, drop the test
                node.idx = None
                node.coef = None
                return
            node.left = _Node(points=left_pts)
            node.right = _Node(points=right_pts)
            node.points = None

    def retrieve(self, q: np.ndarray) -> list:
        """Paper Fig. 3: drop the query to a leaf, return its point ids."""
        return list(self._descend(q).points)

    # ---- structural helpers for tests -----------------------------------
    def leaves(self) -> list:
        out, stack = [], [self.root]
        while stack:
            n = stack.pop()
            if n.is_leaf():
                out.append(n)
            else:
                stack.extend([n.left, n.right])
        return out

    def depth_stats(self) -> tuple[float, int]:
        depths, stack = [], [(self.root, 0)]
        while stack:
            n, d = stack.pop()
            if n.is_leaf():
                depths.append(d)
            else:
                stack.extend([(n.left, d + 1), (n.right, d + 1)])
        return float(np.mean(depths)), int(np.max(depths))


class IncrementalForest:
    """Paper Fig. 1 TrainTrees + Fig. 3 Retrieve, for L trees."""

    def __init__(self, x: np.ndarray, n_trees: int, capacity: int = 12,
                 split_ratio: float = 0.3, n_proj: int = 1, seed: int = 0):
        self.x = np.asarray(x, np.float32)
        self.trees = []
        root_rng = np.random.default_rng(seed)
        for _ in range(n_trees):
            rng = np.random.default_rng(root_rng.integers(2**63))
            tree = IncrementalTree(self.x, capacity, split_ratio, n_proj, rng)
            order = rng.permutation(self.x.shape[0])  # random insert order
            for i in order:
                tree.insert(int(i))
            self.trees.append(tree)

    def retrieve(self, q: np.ndarray) -> np.ndarray:
        """Union of the L leaf point-sets (paper Fig. 3, outer loop)."""
        ids: set = set()
        for t in self.trees:
            ids.update(t.retrieve(q))
        return np.fromiter(ids, dtype=np.int64)

    def query(self, q: np.ndarray, k: int, metric: str = "l2"
              ) -> tuple[np.ndarray, np.ndarray]:
        cand = self.retrieve(q)
        x = self.x[cand]
        if metric == "l2":
            d = np.sum((x - q[None, :]) ** 2, axis=1)
        elif metric == "chi2":
            d = np.sum((x - q) ** 2 / (x + q + 1e-12), axis=1)
        else:
            raise ValueError(metric)
        top = np.argsort(d)[:k]
        return d[top], cand[top]

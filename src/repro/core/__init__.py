"""Core library: the paper's random-partition-forest ANN index + baselines."""
from repro.core.forest import (Forest, ForestConfig, build_forest,
                               gather_candidates, gather_candidates_multi,
                               query_forest, traverse, traverse_multiprobe)
from repro.core.knn import exact_knn
from repro.core.pipeline import fused_query, rerank_fused, staged_query
from repro.core.schedule import probe_widths, scheduled_query
from repro.core.search import (mask_duplicates, merge_topk_pairs, recall_at_k,
                               rerank_topk)

__all__ = [
    "Forest", "ForestConfig", "build_forest", "gather_candidates",
    "gather_candidates_multi", "query_forest", "traverse",
    "traverse_multiprobe", "exact_knn", "mask_duplicates",
    "merge_topk_pairs", "recall_at_k", "rerank_topk",
    "fused_query", "rerank_fused", "staged_query",
    "probe_widths", "scheduled_query",
]

"""Exact (brute-force) k-NN — the paper's ground-truth oracle ("ENN")."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import distances as dist_mod


@functools.partial(jax.jit, static_argnames=("k", "metric", "db_chunk"))
def exact_knn(queries: jax.Array, db: jax.Array, k: int, metric: str = "l2",
              db_chunk: int = 0) -> tuple[jax.Array, jax.Array]:
    """(B, d) x (N, d) -> exact top-k (dists, ids). Streams DB chunks."""
    b = queries.shape[0]
    n = db.shape[0]
    pairwise = dist_mod.PAIRWISE[dist_mod.canonical_metric(metric)]
    if not db_chunk or n <= db_chunk:
        d = pairwise(queries, db)
        neg, ids = jax.lax.top_k(-d, k)
        return -neg, ids

    assert n % db_chunk == 0, "pad the DB to a multiple of db_chunk"
    n_blk = n // db_chunk

    def body(carry, blk):
        best_d, best_i = carry
        db_blk = jax.lax.dynamic_slice_in_dim(db, blk * db_chunk, db_chunk, 0)
        d = pairwise(queries, db_blk)
        ids = blk * db_chunk + jnp.arange(db_chunk, dtype=jnp.int32)[None, :]
        all_d = jnp.concatenate([best_d, d], axis=1)
        all_i = jnp.concatenate([best_i, jnp.broadcast_to(ids, d.shape)], axis=1)
        neg, pos = jax.lax.top_k(-all_d, k)
        return (-neg, jnp.take_along_axis(all_i, pos, axis=1)), None

    init = (jnp.full((b, k), jnp.inf, queries.dtype),
            jnp.full((b, k), -1, jnp.int32))
    (best_d, best_i), _ = jax.lax.scan(body, init, jnp.arange(n_blk))
    return best_d, best_i

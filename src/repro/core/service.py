"""ANN index service: lifecycle + the paper's incremental-update path (§5).

The paper: "upon the query of a new data point, we can easily update the
indexer by saving the novel point in the arrived leaf node and split the node
when necessary."  Here: inserts append to a host-side overflow buffer mapped
by (tree, leaf); queries probe the static CSR AND the overflow; a background
rebuild folds the overflow into a fresh forest once it exceeds
``rebuild_frac`` of the DB (amortized O(log N) per insert).

Queries dispatch through the fused single-pass pipeline (core.pipeline):
traverse + dedup + streamed rerank in one jit, no (B, M, d) intermediate.
"""
from __future__ import annotations

import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.forest import ForestConfig, build_forest
from repro.core.pipeline import fused_query
from repro.core.search import merge_topk_pairs


class AnnService:
    def __init__(self, db: np.ndarray, cfg: ForestConfig, metric: str = "l2",
                 seed: int = 0, rebuild_frac: float = 0.1,
                 mode: str = "auto"):
        self.metric = metric
        self.cfg = cfg
        self.seed = seed
        self.rebuild_frac = rebuild_frac
        self.mode = mode
        self._lock = threading.Lock()
        self.db = np.asarray(db, np.float32)
        self._build(self.db)

    def _build(self, db: np.ndarray):
        self.rcfg = self.cfg.resolved(db.shape[0])
        self.forest = build_forest(jax.random.key(self.seed),
                                   jnp.asarray(db), self.cfg)
        self.db_dev = jnp.asarray(db)
        self.overflow_x: list[np.ndarray] = []   # appended points
        # overflow ids start after the static db
        self.n_static = db.shape[0]

    # ------------------------------------------------------------------ api
    def insert(self, x: np.ndarray) -> int:
        """Paper §5 incremental update. Returns the new point's id."""
        with self._lock:
            self.overflow_x.append(np.asarray(x, np.float32))
            new_id = self.n_static + len(self.overflow_x) - 1
            if len(self.overflow_x) >= self.rebuild_frac * self.n_static:
                self._rebuild_locked()
            return new_id

    def _rebuild_locked(self):
        db = np.concatenate([self.db] + [o[None] for o in self.overflow_x])
        self.db = db
        self._build(db)

    def query(self, q: np.ndarray, k: int = 10
              ) -> tuple[np.ndarray, np.ndarray]:
        """q (B, d) -> (dists (B,k), ids (B,k)); probes index + overflow."""
        q = jnp.asarray(np.atleast_2d(q).astype(np.float32))
        with self._lock:
            d, i = fused_query(self.forest, q, self.db_dev, k, self.cfg,
                               metric=self.metric, mode=self.mode)
            if self.overflow_x:
                # brute-force the (small) overflow and merge
                ox = jnp.asarray(np.stack(self.overflow_x))
                from repro.core.distances import PAIRWISE
                od = PAIRWISE[self.metric](q, ox)
                oi = self.n_static + jnp.arange(ox.shape[0])[None, :]
                cat_d = jnp.concatenate([d, od], axis=1)
                cat_i = jnp.concatenate(
                    [i, jnp.broadcast_to(oi, od.shape)], axis=1)
                d, i = merge_topk_pairs(cat_d, cat_i, k)
        return np.asarray(d), np.asarray(i)

    def stats(self) -> dict:
        return {"n_static": self.n_static,
                "n_overflow": len(self.overflow_x),
                "n_trees": self.cfg.n_trees}

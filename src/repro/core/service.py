"""ANN index service: DEPRECATED shim over the unified index API.

``AnnService`` predates ``repro.index``; it survives as a thin adapter so
external callers keep working.  New code should use::

    from repro.index import IndexSpec, SearchParams, build_index
    index = build_index(key, db, IndexSpec(backend="rpf", forest=cfg))
    dists, ids = index.search(q, SearchParams(k=10))

The behavior tracks the segmented index lifecycle (DESIGN.md §8): queries
dispatch through the fused single-pass pipeline (core/pipeline.py) against
the published immutable view (no reader/writer lock contention); inserts
land in the delta buffer (paper §5 incremental updates, immediately
queryable) and are sealed into an immutable segment once they exceed
``rebuild_frac`` of the static rows; deletes/upserts tombstone the old row.
``compact()`` exposes the explicit (optionally background) rebuild that
replaced the old synchronous overflow fold.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core.forest import ForestConfig
from repro.index import IndexSpec, SearchParams, build_index


class AnnService:
    def __init__(self, db: np.ndarray, cfg: ForestConfig, metric: str = "l2",
                 seed: int = 0, rebuild_frac: float = 0.1,
                 mode: str = "auto"):
        self.metric = metric
        self.cfg = cfg
        self.seed = seed
        self.rebuild_frac = rebuild_frac
        self.mode = mode
        self.index = build_index(
            jax.random.key(seed), db,
            IndexSpec(backend="rpf", forest=cfg, seed=seed,
                      rebuild_frac=rebuild_frac))

    # ------------------------------------------------------------------ api
    @property
    def db(self) -> np.ndarray:
        return self.index.db

    def insert(self, x: np.ndarray) -> int:
        """Paper §5 incremental update. Returns the new point's id."""
        return self.index.add(x)

    def delete(self, ids) -> int:
        """Tombstone one id or an iterable of ids. Returns the count."""
        return self.index.delete(ids)

    def upsert(self, gid: int, x: np.ndarray) -> int:
        """Insert-or-replace the vector for ``gid`` (id preserved)."""
        return self.index.upsert(gid, x)

    def compact(self, block: bool = True):
        """Rebuild the live point set into one segment (off the lock)."""
        return self.index.compact(block=block)

    def query(self, q: np.ndarray, k: int = 10
              ) -> tuple[np.ndarray, np.ndarray]:
        """q (B, d) -> (dists (B,k), ids (B,k)); probes index + delta."""
        d, i = self.index.search(q, SearchParams(k=k, metric=self.metric,
                                                 mode=self.mode))
        return np.asarray(d), np.asarray(i)

    def stats(self) -> dict:
        s = self.index.stats()
        return {"n_static": s["n_static"], "n_overflow": s["n_overflow"],
                "n_segments": s["n_segments"],
                "n_tombstones": s["n_tombstones"],
                "n_compactions": s["n_compactions"],
                "n_trees": self.cfg.n_trees}

"""Random Binary Partition Forest (the paper's core contribution), TPU-native.

Paper semantics (Zhong 2015, §3):
  * L independent random binary partition trees.
  * Internal node test (Eq. 1):  t(x) = sum_k x[d_k] * xi_k - psi >= 0, with the
    random index set {d_k} (size K, default K=1), random coefficients xi in [0,1],
    and psi a *data-adaptive* threshold: a random percentile in [r, 1-r] of the
    projected values of the points at that node.
  * A node is split when it holds more than C (capacity) points, so leaves hold
    between ~r*C and C points and the partition adapts to data density.
  * Query: descend each tree (one coordinate gather + one compare per level, no
    backtracking), union the L leaf point-sets, rerank exactly.  Beyond-paper:
    ``traverse_multiprobe`` widens the descent to the n_probes most marginal
    leaves per tree (DESIGN.md §9); the paper's query is its n_probes=1 case.

TPU-native re-expression (see DESIGN.md §2 and §10):
  * level-synchronous build — all overflowing nodes of a depth split together,
    per-node percentile thresholds computed with one segmented sort per level;
  * batched cross-tree construction — all L trees advance one level together
    as a single (L, N) problem: one flat segmented sort over composite
    (tree, node, projection) keys per level, thresholds read off the same
    sorted pass, and an early exit once no leaf anywhere is overfull;
  * flat SoA tree storage (compact node ids, child_base pointers);
  * CSR leaf storage (perm + offset/count) for O(1) candidate slicing;
  * batched query traversal: a fori_loop of gather+compare over a query batch.

Everything is jit-able with static shapes.  ``build_forest(impl="legacy")``
keeps the original per-tree (vmapped) builder as the parity oracle; under the
default ``seed_mode="compat"`` the batched builder reproduces its Forest
arrays bitwise (tests/test_forest_batched.py pins this).
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances as dist_mod


class ForestConfig(NamedTuple):
    """Hyper-parameters of the random partition forest (paper §3.4)."""

    n_trees: int = 80          # L
    capacity: int = 12         # C: max points per leaf
    split_ratio: float = 0.3   # r in (0, 0.5]
    n_proj: int = 1            # K: coordinates per random test (paper default 1)
    max_depth: int = 0         # 0 -> auto bound from N, C, r
    max_nodes: int = 0         # 0 -> auto bound
    leaf_pad: int = 0          # padded candidate slots per (query, tree); 0 -> C

    def resolved(self, n_points: int) -> "ForestConfig":
        r = float(self.split_ratio)
        rc = max(r * self.capacity, 1.0)
        depth = self.max_depth
        if depth <= 0:
            # depth budget: Eq. 1 guarantees each split keeps <= (1-r) of the
            # points on DISTINCT values, but tie-escape splits on heavily
            # tied data (sparse histograms, raw pixels) can be as uneven as
            # ~85/15 — budget for the worse of the two (traversal is one
            # compare per level, so a generous budget costs little)
            shrink = max(1.0 - r, 0.85)
            depth = int(math.ceil(math.log(max(n_points / rc, 2.0))
                                  / math.log(1.0 / shrink))) + 6
        nodes = self.max_nodes
        if nodes <= 0:
            nodes = int(4.0 * n_points / rc) + 64
        pad = self.leaf_pad if self.leaf_pad > 0 else self.capacity
        return self._replace(max_depth=depth, max_nodes=nodes, leaf_pad=pad)


class Forest(NamedTuple):
    """Flat SoA forest. All arrays carry a leading (L,) tree axis.

    A node is internal iff child_base >= 0; its children are child_base and
    child_base + 1.  Leaf points of node ``n`` of tree ``l`` are
    ``perm[l, leaf_offset[l, n] : leaf_offset[l, n] + leaf_count[l, n]]``.
    """

    proj_idx: jax.Array    # (L, max_nodes, K) int32  random coordinate indices
    proj_coef: jax.Array   # (L, max_nodes, K) f32    random coefficients xi
    thresh: jax.Array      # (L, max_nodes)    f32    psi
    child_base: jax.Array  # (L, max_nodes)    int32  left-child id, -1 for leaf
    perm: jax.Array        # (L, N)            int32  point ids sorted by leaf
    leaf_offset: jax.Array  # (L, max_nodes)   int32
    leaf_count: jax.Array   # (L, max_nodes)   int32
    n_nodes: jax.Array      # (L,)             int32  allocated node count

    @property
    def n_trees(self) -> int:
        return self.thresh.shape[0]

    @property
    def max_nodes(self) -> int:
        return self.thresh.shape[1]


# ---------------------------------------------------------------------------
# build (level-synchronous, single tree; vmapped for the forest)
# ---------------------------------------------------------------------------


def _project(x: jax.Array, idx: jax.Array, coef: jax.Array) -> jax.Array:
    """y_i = sum_k x[i, idx[i, k]] * coef[i, k]  with per-row index sets."""
    gathered = jnp.take_along_axis(x, idx, axis=1)  # (N, K)
    return jnp.sum(gathered * coef, axis=1)


def _build_one_tree(key: jax.Array, x: jax.Array, cfg: ForestConfig) -> Forest:
    """Build a single tree over points ``x`` (N, d). Returns Forest w/o L axis."""
    n, d = x.shape
    m = cfg.max_nodes
    k_proj = cfg.n_proj
    r = cfg.split_ratio

    def level(carry, level_key):
        assign, proj_idx, proj_coef, thresh, child_base, n_nodes = carry
        k_feat, k_coef, k_quant = jax.random.split(level_key, 3)

        counts = jax.ops.segment_sum(jnp.ones((n,), jnp.int32), assign,
                                     num_segments=m)
        is_leaf = child_base < 0
        node_ids = jnp.arange(m, dtype=jnp.int32)
        alive = node_ids < n_nodes
        overfull = is_leaf & alive & (counts > cfg.capacity)

        # --- candidate random tests for every slot (Eq. 1) ----------------
        cand_idx = jax.random.randint(k_feat, (m, k_proj), 0, d,
                                      dtype=jnp.int32)
        cand_coef = jax.random.uniform(k_coef, (m, k_proj), jnp.float32)
        if k_proj == 1:
            cand_coef = jnp.ones_like(cand_coef)  # scale-invariant for K=1
        test_idx = jnp.where(overfull[:, None], cand_idx, proj_idx)
        test_coef = jnp.where(overfull[:, None], cand_coef, proj_coef)

        # --- per-point projections under the candidate tests --------------
        y = _project(x, test_idx[assign], test_coef[assign])  # (N,)

        # --- per-node value range + random percentile threshold -----------
        order = jnp.lexsort((y, assign))
        assign_sorted = assign[order]
        y_sorted = y[order]
        start = jnp.searchsorted(assign_sorted, node_ids, side="left")
        last = jnp.clip(start + counts - 1, 0, n - 1)
        lo = y_sorted[jnp.clip(start, 0, n - 1)]
        hi = y_sorted[last]
        # ties guard: a constant projection can't split — the node stays open
        # and redraws a fresh random coordinate at the next level (the
        # paper's incremental builder has the same retry implicitly)
        degenerate = ~(hi > lo)
        splitting = overfull & ~degenerate

        # --- allocate children compactly -----------------------------------
        n_split = jnp.sum(splitting.astype(jnp.int32))
        rank = jnp.cumsum(splitting.astype(jnp.int32)) - 1
        new_child_base = jnp.where(splitting, n_nodes + 2 * rank, child_base)
        budget_overflow = (n_nodes + 2 * n_split) > m
        new_child_base = jnp.where(budget_overflow, child_base,
                                   new_child_base)
        splitting = jnp.where(budget_overflow, jnp.zeros_like(splitting),
                              splitting)
        new_n_nodes = jnp.where(budget_overflow, n_nodes,
                                n_nodes + 2 * n_split)

        # paper Eq. 1: psi is a uniform random VALUE in the interval between
        # the r and (1-r) percentile points of the sorted projections,
        # psi ~ U[y_{r n}, y_{(1-r) n}]
        u = jax.random.uniform(k_quant, (m,))
        last_idx = jnp.maximum(start, start + counts - 1)
        pos_a = jnp.clip(start + jnp.floor(
            r * counts.astype(jnp.float32)).astype(jnp.int32), start,
            last_idx)
        pos_b = jnp.clip(start + jnp.floor(
            (1.0 - r) * counts.astype(jnp.float32)).astype(jnp.int32), start,
            last_idx)
        a = y_sorted[jnp.clip(pos_a, 0, n - 1)]
        b_ = y_sorted[jnp.clip(pos_b, 0, n - 1)]
        cand_thresh = a + u * (b_ - a)
        # tie escape: on heavily-tied data (sparse histograms, raw MNIST
        # pixels) the percentile interval collapses onto the min value and
        # the left child (y < psi) would be empty; fall back to a uniform
        # value split over the node's full (lo, hi] range — progress is
        # guaranteed since lo < hi for splitting nodes
        cand_thresh = jnp.where(
            cand_thresh > lo, cand_thresh,
            lo + jnp.maximum(u, 0.05) * (hi - lo))

        proj_idx = jnp.where(splitting[:, None], cand_idx, proj_idx)
        proj_coef = jnp.where(splitting[:, None], cand_coef, proj_coef)
        thresh = jnp.where(splitting, cand_thresh, thresh)

        # --- reassign points of splitting nodes ---------------------------
        node_splits = splitting[assign]
        go_right = y >= thresh[assign]
        new_assign = jnp.where(
            node_splits,
            new_child_base[assign] + go_right.astype(jnp.int32),
            assign,
        )
        return (new_assign, proj_idx, proj_coef, thresh, new_child_base,
                new_n_nodes), n_split

    init = (
        jnp.zeros((n,), jnp.int32),                       # assign: all at root
        jnp.zeros((m, k_proj), jnp.int32),                # proj_idx
        jnp.ones((m, k_proj), jnp.float32),               # proj_coef
        jnp.zeros((m,), jnp.float32),                     # thresh
        jnp.full((m,), -1, jnp.int32),                    # child_base
        jnp.asarray(1, jnp.int32),                        # n_nodes (root)
    )
    level_keys = jax.random.split(key, cfg.max_depth)
    (assign, proj_idx, proj_coef, thresh, child_base, n_nodes), _ = jax.lax.scan(
        level, init, level_keys)

    # --- CSR leaf storage -------------------------------------------------
    order = jnp.argsort(assign)
    assign_sorted = assign[order]
    node_ids = jnp.arange(m, dtype=jnp.int32)
    leaf_offset = jnp.searchsorted(assign_sorted, node_ids, side="left")
    leaf_end = jnp.searchsorted(assign_sorted, node_ids, side="right")
    leaf_count = (leaf_end - leaf_offset).astype(jnp.int32)
    leaf_count = jnp.where(child_base < 0, leaf_count, 0)

    return Forest(
        proj_idx=proj_idx,
        proj_coef=proj_coef,
        thresh=thresh,
        child_base=child_base,
        perm=order.astype(jnp.int32),
        leaf_offset=leaf_offset.astype(jnp.int32),
        leaf_count=leaf_count,
        n_nodes=n_nodes,
    )


@functools.partial(jax.jit, static_argnames=("cfg", "tree_chunk"))
def _build_forest_legacy(key: jax.Array, x: jax.Array, cfg: ForestConfig,
                         tree_chunk: int = 0) -> Forest:
    """The original per-tree builder (vmap of ``_build_one_tree``).

    Kept as the parity oracle and benchmark baseline for the batched
    cross-tree builder (DESIGN.md §10); ``seed_mode="compat"`` of the
    batched path is pinned bitwise against this.
    """
    cfg = cfg.resolved(x.shape[0])
    keys = jax.random.split(key, cfg.n_trees)
    build = functools.partial(_build_one_tree, x=x, cfg=cfg)
    if tree_chunk and cfg.n_trees > tree_chunk:
        return jax.lax.map(lambda k: build(k), keys, batch_size=tree_chunk)
    return jax.vmap(lambda k: build(k))(keys)


# ---------------------------------------------------------------------------
# batched cross-tree build (DESIGN.md §10): all L trees advance together
# ---------------------------------------------------------------------------


def _batched_level_draws(keys: jax.Array, cfg: ForestConfig, d: int,
                         seed_mode: str):
    """Per-level RNG for the batched builder.

    compat: ``keys`` is the (L,) per-tree key array — the same
      ``split(key, L)`` the legacy builder starts from — and each level
      reproduces the legacy derivation exactly
      (split(tree_key, depth) -> split(level_key, 3)), so every draw lands
      bitwise where the per-tree builder put it.
    fused:  ``keys`` is one scalar key, split once per level for the whole
      forest; the three draws come out as single (L, m, ...) calls.
      Different (valid) stream, cheaper derivation; opt-in via
      ``build_forest(seed_mode="fused")``.
    """
    L, m, kp, depth = cfg.n_trees, cfg.max_nodes, cfg.n_proj, cfg.max_depth
    if seed_mode == "compat":
        level_keys = jax.vmap(lambda k: jax.random.split(k, depth),
                              out_axes=1)(keys)          # (depth, L)

        def draws(level):
            k3 = jax.vmap(lambda k: jax.random.split(k, 3))(level_keys[level])
            ci = jax.vmap(lambda k: jax.random.randint(
                k, (m, kp), 0, d, dtype=jnp.int32))(k3[:, 0])
            cc = jax.vmap(lambda k: jax.random.uniform(
                k, (m, kp), jnp.float32))(k3[:, 1])
            uu = jax.vmap(lambda k: jax.random.uniform(k, (m,)))(k3[:, 2])
            return ci, cc, uu
    elif seed_mode == "fused":
        level_keys = jax.random.split(keys, depth)       # (depth,)

        def draws(level):
            k_feat, k_coef, k_quant = jax.random.split(level_keys[level], 3)
            ci = jax.random.randint(k_feat, (L, m, kp), 0, d,
                                    dtype=jnp.int32)
            cc = jax.random.uniform(k_coef, (L, m, kp), jnp.float32)
            uu = jax.random.uniform(k_quant, (L, m))
            return ci, cc, uu
    else:
        raise ValueError(f"seed_mode must be compat|fused, got {seed_mode!r}")
    return draws


def _next_pow2(v: int) -> int:
    return 1 << max(0, (int(v) - 1).bit_length())


# below this many points the staged active-set shrink is pure overhead
# (extra compiles + host syncs); single full-width stage instead
_RESTAGE_MIN = 4096
# floor for the compacted sort width: shapes below this recompile for no
# measurable win (the sort is already sub-millisecond)
_STAGE_FLOOR = 256


@functools.partial(jax.jit,
                   static_argnames=("cfg", "seed_mode", "a_cap", "shrink"))
def _build_stage(keys: jax.Array, x: jax.Array, state: tuple,
                 cfg: ForestConfig, seed_mode: str, a_cap: int,
                 shrink: bool) -> tuple:
    """Run build levels from ``state`` until done / depth budget / restage.

    One jitted while_loop over levels at a fixed sort width ``a_cap``:
    the per-level segmented sort (and the occupancy update) covers only
    the ACTIVE points — points sitting in overfull leaves — compacted
    into an (L, a_cap) buffer.  Leaves that are not overfull never split
    again, so the active set only shrinks; when its per-tree maximum
    falls to half of ``a_cap`` (and ``shrink`` allows), the loop exits so
    the driver can relaunch at a smaller width.  ``a_cap == n`` skips the
    compaction scatter entirely (every point is in the sort anyway).

    Bitwise parity with the legacy builder holds because compaction is
    order-preserving: each overfull node's segment holds exactly its own
    points in original index order, so the stable (node, projection) sort
    yields the same per-segment value sequence — and thresholds only ever
    read values inside overfull segments.
    """
    n, d = x.shape
    L, m, kp = cfg.n_trees, cfg.max_nodes, cfg.n_proj
    r = cfg.split_ratio
    compacted = a_cap < n
    draws = _batched_level_draws(keys, cfg, d, seed_mode)
    node_ids = jnp.arange(m, dtype=jnp.int32)[None, :]           # (1, m)
    l_idx = jnp.arange(L, dtype=jnp.int32)[:, None]              # (L, 1)
    tree_off = l_idx * (m + 1)   # m is the pad bucket of each tree

    def cond(carry):
        level, go, active_max = carry[0], carry[1], carry[2]
        keep = go & (level < cfg.max_depth)
        if shrink:
            keep &= 2 * active_max > a_cap
        return keep

    def body(carry):
        (level, _, _, assign, counts, proj_idx, proj_coef, thresh,
         child_base, n_nodes) = carry

        is_leaf = child_base < 0
        alive = node_ids < n_nodes[:, None]
        overfull = is_leaf & alive & (counts > cfg.capacity)

        # --- candidate random tests for every (tree, slot) (Eq. 1) --------
        cand_idx, cand_coef, u = draws(level)
        if kp == 1:
            cand_coef = jnp.ones_like(cand_coef)  # scale-invariant for K=1
        test_idx = jnp.where(overfull[..., None], cand_idx, proj_idx)
        test_coef = jnp.where(overfull[..., None], cand_coef, proj_coef)

        # --- per-point projections under the candidate tests --------------
        y = jax.vmap(lambda ti, tc, a: _project(x, ti[a], tc[a])
                     )(test_idx, test_coef, assign)               # (L, N)

        # --- ONE segmented sort over composite (tree, node, y) keys -------
        # the (tree) key rides the batch axis, (node, projection) are the
        # two sort keys — the same (int, float) comparator as the legacy
        # per-tree lexsort, so the per-segment ordering (and thus every
        # threshold read) matches it bitwise.  Only the sorted projection
        # VALUES are kept: start offsets fall out of the occupancy cumsum
        # (no searchsorted), no argsort + gather.
        if compacted:
            # scatter the active points into the narrow sort buffer;
            # cumsum positions preserve index order, so stability carries
            flag = jnp.take_along_axis(overfull, assign, axis=1)  # (L, N)
            pos = jnp.cumsum(flag.astype(jnp.int32), axis=1) - 1
            row = jnp.where(flag, pos, a_cap)        # inactive -> dropped
            assign_c = jnp.full((L, a_cap), m, jnp.int32
                                ).at[l_idx, row].set(assign, mode="drop")
            y_c = jnp.zeros((L, a_cap), y.dtype
                            ).at[l_idx, row].set(y, mode="drop")
            seg_sizes = jnp.where(overfull, counts, 0)
        else:
            assign_c, y_c = assign, y
            seg_sizes = counts
        _, y_sorted = jax.lax.sort((assign_c, y_c), dimension=1, num_keys=2,
                                   is_stable=True)                # (L, A)

        start = jnp.cumsum(seg_sizes, axis=1) - seg_sizes         # (L, m)
        last = jnp.clip(start + counts - 1, 0, a_cap - 1)

        def at(pos):  # y_sorted value at per-node position (L, m)
            return jnp.take_along_axis(y_sorted,
                                       jnp.clip(pos, 0, a_cap - 1), axis=1)

        lo = at(start)
        hi = at(last)
        # ties guard: a constant projection can't split — the node stays
        # open and redraws a fresh random coordinate at the next level
        degenerate = ~(hi > lo)
        splitting = overfull & ~degenerate

        # --- allocate children compactly (per tree) -----------------------
        n_split = jnp.sum(splitting.astype(jnp.int32), axis=1)    # (L,)
        rank = jnp.cumsum(splitting.astype(jnp.int32), axis=1) - 1
        new_child_base = jnp.where(splitting,
                                   n_nodes[:, None] + 2 * rank, child_base)
        budget_overflow = (n_nodes + 2 * n_split) > m             # (L,)
        new_child_base = jnp.where(budget_overflow[:, None], child_base,
                                   new_child_base)
        splitting = jnp.where(budget_overflow[:, None],
                              jnp.zeros_like(splitting), splitting)
        new_n_nodes = jnp.where(budget_overflow, n_nodes,
                                n_nodes + 2 * n_split)

        # paper Eq. 1: psi ~ U[y_{r n}, y_{(1-r) n}], values read from the
        # SAME sorted pass (the fused percentile-threshold draw)
        last_idx = jnp.maximum(start, start + counts - 1)
        cnt_f = counts.astype(jnp.float32)
        pos_a = jnp.clip(start + jnp.floor(r * cnt_f).astype(jnp.int32),
                         start, last_idx)
        pos_b = jnp.clip(start + jnp.floor((1.0 - r) * cnt_f
                                           ).astype(jnp.int32),
                         start, last_idx)
        a = at(pos_a)
        b_ = at(pos_b)
        cand_thresh = a + u * (b_ - a)
        # tie escape (see _build_one_tree): collapsed percentile interval
        # falls back to a uniform value split over the full (lo, hi] range
        cand_thresh = jnp.where(
            cand_thresh > lo, cand_thresh,
            lo + jnp.maximum(u, 0.05) * (hi - lo))

        proj_idx = jnp.where(splitting[..., None], cand_idx, proj_idx)
        proj_coef = jnp.where(splitting[..., None], cand_coef, proj_coef)
        thresh = jnp.where(splitting, cand_thresh, thresh)

        # --- reassign points of splitting nodes ---------------------------
        node_splits = jnp.take_along_axis(splitting, assign, axis=1)
        go_right = y >= jnp.take_along_axis(thresh, assign, axis=1)
        new_assign = jnp.where(
            node_splits,
            jnp.take_along_axis(new_child_base, assign, axis=1)
            + go_right.astype(jnp.int32),
            assign,
        )

        # --- occupancy update over the active points only -----------------
        # every point of an overfull node is in the compacted set, so
        #   counts' = counts*(not overfull) + seg_count(new node of active)
        # (degenerate nodes re-add their own points; split points land in
        # their children); pads live in the per-tree bucket m, sliced off
        if compacted:
            # new_assign already holds every point's destination node —
            # reuse the active->buffer map from the sort compaction
            moved = jnp.full((L, a_cap), m, jnp.int32
                             ).at[l_idx, row].set(new_assign, mode="drop")
            seg = jax.ops.segment_sum(
                jnp.ones((L * a_cap,), jnp.int32),
                (moved + tree_off).reshape(-1),
                num_segments=L * (m + 1)).reshape(L, m + 1)
            new_counts = jnp.where(overfull, 0, counts) + seg[:, :m]
        else:
            new_counts = jax.ops.segment_sum(
                jnp.ones((L * n,), jnp.int32),
                (new_assign + tree_off).reshape(-1),
                num_segments=L * (m + 1)).reshape(L, m + 1)[:, :m]

        new_overfull = (new_child_base < 0) \
            & (node_ids < new_n_nodes[:, None]) \
            & (new_counts > cfg.capacity)
        go = jnp.any(new_overfull)
        active_max = jnp.max(jnp.sum(
            jnp.where(new_overfull, new_counts, 0), axis=1))
        return (level + 1, go, active_max, new_assign, new_counts, proj_idx,
                proj_coef, thresh, new_child_base, new_n_nodes)

    return jax.lax.while_loop(cond, body, state)


def _build_forest_batched(keys: jax.Array, x: jax.Array, cfg: ForestConfig,
                          seed_mode: str = "compat",
                          restage_min: int = _RESTAGE_MIN) -> Forest:
    """All-L-trees-at-once level-synchronous build (DESIGN.md §10).

    ``keys``: the (L,) per-tree key array in compat mode, one scalar key
    in fused mode (see ``_batched_level_draws``).

    Drives ``_build_stage`` in rounds: the first stage runs at full sort
    width; as the active point set decays, later stages relaunch with the
    sort width halved-or-better (power-of-two buckets, so the number of
    compiled shapes is logarithmic).  The level loop exits as soon as NO
    leaf anywhere is overfull — the depth budget in ``cfg.max_depth`` is
    a worst-case bound (heavily tied data) and typical builds finish in a
    fraction of it; skipped tail levels are bitwise no-ops in the legacy
    scan, so early exit preserves exact parity.
    """
    n, _ = x.shape
    L, m, kp = cfg.n_trees, cfg.max_nodes, cfg.n_proj

    counts0 = jnp.zeros((L, m), jnp.int32).at[:, 0].set(n)
    state = (
        jnp.asarray(0, jnp.int32),                        # level
        jnp.asarray(n > cfg.capacity),                    # go: root overfull
        jnp.asarray(n, jnp.int32),                        # active_max
        jnp.zeros((L, n), jnp.int32),                     # assign: all at root
        counts0,
        jnp.zeros((L, m, kp), jnp.int32),                 # proj_idx
        jnp.ones((L, m, kp), jnp.float32),                # proj_coef
        jnp.zeros((L, m), jnp.float32),                   # thresh
        jnp.full((L, m), -1, jnp.int32),                  # child_base
        jnp.ones((L,), jnp.int32),                        # n_nodes
    )

    if isinstance(x, jax.core.Tracer) or isinstance(keys, jax.core.Tracer):
        # traced caller (shard_map per-device builds, user jit/vmap over
        # the key with a closed-over concrete db, ...): the staged shrink
        # needs host control flow, so run one full-width in-graph stage —
        # the early-exit while_loop still applies
        state = _build_stage(keys, x, state, cfg, seed_mode, n,
                             shrink=False)
    else:
        a_cap = n
        shrink = n >= restage_min
        while True:
            state = _build_stage(keys, x, state, cfg, seed_mode, a_cap,
                                 shrink)
            level, go, active_max = (int(state[0]), bool(state[1]),
                                     int(state[2]))
            if not go or level >= cfg.max_depth:
                break
            nxt = max(_next_pow2(active_max), _STAGE_FLOOR)
            if nxt >= a_cap:      # no shrink possible: run to completion
                shrink = False
                continue
            a_cap = nxt
            shrink = a_cap > _STAGE_FLOOR

    (_, _, _, assign, counts, proj_idx, proj_coef, thresh, child_base,
     n_nodes) = state
    return _finalize_csr(assign, counts, proj_idx, proj_coef, thresh,
                         child_base, n_nodes)


@jax.jit
def _finalize_csr(assign, counts, proj_idx, proj_coef, thresh, child_base,
                  n_nodes) -> Forest:
    """CSR leaf storage: one batched stable int argsort over (L, N)."""
    perm = jnp.argsort(assign, axis=1, stable=True).astype(jnp.int32)
    leaf_offset = (jnp.cumsum(counts, axis=1) - counts).astype(jnp.int32)
    leaf_count = jnp.where(child_base < 0, counts, 0).astype(jnp.int32)
    return Forest(
        proj_idx=proj_idx,
        proj_coef=proj_coef,
        thresh=thresh,
        child_base=child_base,
        perm=perm,
        leaf_offset=leaf_offset,
        leaf_count=leaf_count,
        n_nodes=n_nodes,
    )


def build_forest(key: jax.Array, x: jax.Array, cfg: ForestConfig,
                 tree_chunk: int = 0, impl: str = "batched",
                 seed_mode: str = "compat") -> Forest:
    """Build the L-tree forest.

    ``impl="batched"`` (default) constructs all L trees at once — one
    segmented sort over composite (tree, node) keys per level plus an
    early exit when every leaf fits — and under the default
    ``seed_mode="compat"`` returns Forest arrays bitwise identical to
    ``impl="legacy"`` (the original per-tree builder, kept as the parity
    oracle).  ``seed_mode="fused"`` derives the per-level randomness from
    one key split per level instead of per tree — a different, equally
    valid stream (benchmarks/build_time.py measures both).

    ``tree_chunk`` > 0 builds trees in chunks of that size to bound peak
    memory for very large L (the paper sweeps L up to 640).  In compat
    mode chunking is exact (per-tree key derivation makes the chunks
    independent); in fused mode each chunk folds its index into the key.
    """
    cfg = cfg.resolved(x.shape[0])
    if impl == "legacy":
        return _build_forest_legacy(key, x, cfg, tree_chunk)
    if impl != "batched":
        raise ValueError(f"impl must be batched|legacy, got {impl!r}")
    keys = jax.random.split(key, cfg.n_trees) if seed_mode == "compat" \
        else key
    if tree_chunk and cfg.n_trees > tree_chunk:
        chunks = []
        for i, lo in enumerate(range(0, cfg.n_trees, tree_chunk)):
            width = min(tree_chunk, cfg.n_trees - lo)
            sub_cfg = cfg._replace(n_trees=width)
            sub_keys = keys[lo:lo + width] if seed_mode == "compat" \
                else jax.random.fold_in(key, i)
            chunks.append(_build_forest_batched(sub_keys, x, sub_cfg,
                                                seed_mode=seed_mode))
        return jax.tree.map(lambda *a: jnp.concatenate(a, axis=0), *chunks)
    return _build_forest_batched(keys, x, cfg, seed_mode=seed_mode)


# ---------------------------------------------------------------------------
# query: batched traversal + candidate retrieval
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("max_depth",))
def traverse(forest: Forest, queries: jax.Array, max_depth: int) -> jax.Array:
    """Map each query to its leaf node in every tree.

    queries: (B, d) -> leaf ids (L, B). One gather + compare per level, exactly
    the paper's "one random coordinate access ... one float comparison per node
    visited".  This is the ``n_probes = 1`` primitive; see
    :func:`traverse_multiprobe` for the widened descent (DESIGN.md §9).
    """

    def one_tree(tree: Forest):
        def step(_, node):
            idx = tree.proj_idx[node]          # (B, K)
            coef = tree.proj_coef[node]        # (B, K)
            y = jnp.sum(jnp.take_along_axis(queries, idx, axis=1) * coef, axis=1)
            go_right = y >= tree.thresh[node]
            child = tree.child_base[node] + go_right.astype(jnp.int32)
            return jnp.where(tree.child_base[node] < 0, node, child)

        node0 = jnp.zeros((queries.shape[0],), jnp.int32)
        return jax.lax.fori_loop(0, max_depth, step, node0)

    return jax.vmap(one_tree)(forest)


@functools.partial(jax.jit, static_argnames=("max_depth", "n_probes"))
def traverse_multiprobe(forest: Forest, queries: jax.Array, max_depth: int,
                        n_probes: int) -> jax.Array:
    """Priority-ordered multi-probe descent (DESIGN.md §9).

    Maps each query to its ``n_probes`` most marginal leaves per tree:
    probe 0 is the primary leaf (bitwise-identical to :func:`traverse`);
    probes 1..n_probes-1 are bounded best-first re-descents that flip the
    routing decision at the internal node with the smallest signed
    projection margin ``|t(x)| = |y - psi|`` along the primary path and
    then continue greedily to a leaf.  Two descents that diverge at an
    internal node end in disjoint subtrees, so the probes of one tree are
    pairwise-distinct leaves.

    queries: (B, d) -> leaf ids (L, B, n_probes) int32; slots for which no
    alternate exists (shallow paths with fewer than ``n_probes - 1``
    internal nodes) hold -1 and are masked by
    :func:`gather_candidates_multi`.  Static shapes throughout: the probe
    count bounds the expansion, every re-descent is a ``fori_loop`` of the
    same gather+compare step as the primary descent.
    """
    n_alt = max(0, min(n_probes - 1, max_depth))
    b = queries.shape[0]

    def one_tree(tree: Forest):
        def project(node):
            idx = tree.proj_idx[node]          # (B, K)
            coef = tree.proj_coef[node]        # (B, K)
            return jnp.sum(
                jnp.take_along_axis(queries, idx, axis=1) * coef, axis=1)

        def primary_step(node, _):
            y = project(node)
            internal = tree.child_base[node] >= 0
            margin = jnp.where(internal, jnp.abs(y - tree.thresh[node]),
                               jnp.inf)
            child = tree.child_base[node] \
                + (y >= tree.thresh[node]).astype(jnp.int32)
            return jnp.where(internal, child, node), margin

        node0 = jnp.zeros((b,), jnp.int32)
        leaf, margins = jax.lax.scan(primary_step, node0, None,
                                     length=max_depth)
        # margins: (max_depth, B); +inf rows mark depths past the leaf
        probes = [leaf[:, None]]
        if n_alt:
            # the n_alt smallest margins along the path, ascending (ties ->
            # shallower depth, matching the kernel's iterative argmin)
            neg, flip_depth = jax.lax.top_k(-margins.T, n_alt)  # (B, n_alt)
            valid = jnp.isfinite(neg)

            def alt_descend(depth_sel):
                def step(t, node):
                    y = project(node)
                    internal = tree.child_base[node] >= 0
                    go_right = y >= tree.thresh[node]
                    go_right = jnp.where(t == depth_sel, ~go_right, go_right)
                    child = tree.child_base[node] + go_right.astype(jnp.int32)
                    return jnp.where(internal, child, node)

                return jax.lax.fori_loop(0, max_depth, step, node0)

            alts = jax.vmap(alt_descend, in_axes=1, out_axes=1)(flip_depth)
            probes.append(jnp.where(valid, alts, -1))
        out = jnp.concatenate(probes, axis=1)               # (B, <=n_probes)
        if out.shape[1] < n_probes:                          # max_depth-bound
            out = jnp.pad(out, ((0, 0), (0, n_probes - out.shape[1])),
                          constant_values=-1)
        return out

    return jax.vmap(one_tree)(forest)


def traverse_forest(forest: Forest, queries: jax.Array, max_depth: int,
                    n_probes: int = 1, mode: str = "auto") -> jax.Array:
    """Mode-dispatched forest descent — the pipeline's traversal entry.

    Routes through the Pallas traversal kernels when the mode policy says
    so (kernels/ops.py: Pallas on TPU or forced) AND the forest uses K = 1
    projections (the paper default, where ``proj_coef`` is identically 1.0
    so the kernel's raw-coordinate compare is bitwise the jnp descent).
    Tree size no longer matters: the HBM-resident kernel (DESIGN.md §11)
    has no node cap, so ``mode="pallas"`` never leaves Pallas.  K > 1
    forests and ref mode use the XLA traversal (:func:`traverse` /
    :func:`traverse_multiprobe`) — on CPU ``"auto"`` resolves there, which
    keeps the historical bitwise pin of the pre-kernel graph.

    Returns (L, B) for ``n_probes == 1``, else (L, B, n_probes).
    """
    from repro.kernels import ops as _ops
    use_pallas, interp = _ops._resolve(mode)
    if use_pallas and forest.proj_idx.shape[-1] == 1:
        from repro.kernels import forest_traverse_hbm as _hbm
        return _hbm.forest_traverse_hbm(
            forest.proj_idx[..., 0], forest.thresh, forest.child_base,
            queries, max_depth, interpret=interp, n_probes=n_probes)
    if n_probes == 1:
        return traverse(forest, queries, max_depth)
    return traverse_multiprobe(forest, queries, max_depth, n_probes)


@functools.partial(jax.jit, static_argnames=("pad",))
def gather_candidates_multi(forest: Forest, leaves: jax.Array, pad: int
                            ) -> tuple[jax.Array, jax.Array]:
    """Candidate retrieval for the multi-probe leaf set.

    leaves: (L, B, P) leaf ids with -1 marking absent probes ->
    (B, L*P*pad) candidate ids, (B, L*P*pad) bool mask.  The probe axis
    folds into the candidate axis of the existing padded id/mask contract,
    so the fused rerank, int8 shortlist, tombstone validity and the sharded
    merge all compose without a kernel change (DESIGN.md §9).  For P=1 the
    output is identical to :func:`gather_candidates`.
    """
    L, B, P = leaves.shape
    flat = leaves.reshape(L, B * P)
    slot = jnp.arange(pad, dtype=jnp.int32)

    def one_tree(tree: Forest, leaf: jax.Array):
        ok = leaf >= 0
        safe = jnp.maximum(leaf, 0)
        off = tree.leaf_offset[safe]            # (B*P,)
        cnt = jnp.where(ok, tree.leaf_count[safe], 0)
        pos = off[:, None] + slot[None, :]      # (B*P, pad)
        mask = slot[None, :] < cnt[:, None]
        n = tree.perm.shape[0]
        ids = tree.perm[jnp.clip(pos, 0, n - 1)]
        return jnp.where(mask, ids, 0), mask

    ids, mask = jax.vmap(one_tree)(forest, flat)             # (L, B*P, pad)
    ids = ids.reshape(L, B, P * pad)
    mask = mask.reshape(L, B, P * pad)
    ids = jnp.transpose(ids, (1, 0, 2)).reshape(B, L * P * pad)
    mask = jnp.transpose(mask, (1, 0, 2)).reshape(B, L * P * pad)
    return ids, mask


@functools.partial(jax.jit, static_argnames=("pad",))
def gather_candidates(forest: Forest, leaves: jax.Array, pad: int
                      ) -> tuple[jax.Array, jax.Array]:
    """Retrieve the (padded) union of leaf point-sets.

    leaves: (L, B) leaf node ids -> (B, L*pad) candidate ids, (B, L*pad) bool mask.
    Invalid slots hold id 0 and mask False.
    """
    L, B = leaves.shape
    slot = jnp.arange(pad, dtype=jnp.int32)

    def one_tree(tree: Forest, leaf: jax.Array):
        off = tree.leaf_offset[leaf]            # (B,)
        cnt = tree.leaf_count[leaf]             # (B,)
        pos = off[:, None] + slot[None, :]      # (B, pad)
        mask = slot[None, :] < cnt[:, None]
        n = tree.perm.shape[0]
        ids = tree.perm[jnp.clip(pos, 0, n - 1)]
        return jnp.where(mask, ids, 0), mask

    ids, mask = jax.vmap(one_tree)(forest, leaves)       # (L, B, pad)
    ids = jnp.transpose(ids, (1, 0, 2)).reshape(B, L * pad)
    mask = jnp.transpose(mask, (1, 0, 2)).reshape(B, L * pad)
    return ids, mask


def query_forest(forest: Forest, queries: jax.Array, db: jax.Array, k: int,
                 cfg: ForestConfig, metric: str = "l2", dedup: bool = True,
                 mode: str = "auto", chunk: int = 0
                 ) -> tuple[jax.Array, jax.Array]:
    """End-to-end query: traverse -> dedup -> rerank -> top-k.

    Dispatches through the fused single-pass pipeline (core.pipeline) behind
    the mode policy; the pre-fusion staged composition survives as
    core.pipeline.staged_query (the oracle).

    Returns (dists (B, k), ids (B, k)); invalid slots have id -1 and dist +inf.
    """
    from repro.core import pipeline  # local import to avoid cycle

    return pipeline.fused_query(forest, queries, db, k, cfg, metric=metric,
                                dedup=dedup, mode=mode, chunk=chunk)


# ---------------------------------------------------------------------------
# structural statistics (paper §3.4 discussion; used in tests + benchmarks)
# ---------------------------------------------------------------------------


def forest_stats(forest: Forest, cfg: ForestConfig, n_points: int) -> dict:
    cfg = cfg.resolved(n_points)
    child = np.asarray(forest.child_base)
    count = np.asarray(forest.leaf_count)
    n_nodes = np.asarray(forest.n_nodes)
    stats = []
    for l in range(child.shape[0]):
        alive = np.arange(child.shape[1]) < n_nodes[l]
        leaf = (child[l] < 0) & alive
        occ = count[l][leaf & (count[l] > 0)]
        # depth per node via forward sweep
        depth = np.full(child.shape[1], -1, np.int32)
        depth[0] = 0
        for i in range(int(n_nodes[l])):
            if child[l, i] >= 0:
                depth[child[l, i]] = depth[i] + 1
                depth[child[l, i] + 1] = depth[i] + 1
        leaf_depths = depth[leaf & (count[l] > 0)]
        stats.append(dict(
            n_nodes=int(n_nodes[l]),
            n_leaves=int(leaf.sum()),
            occ_mean=float(occ.mean()) if occ.size else 0.0,
            occ_max=int(occ.max()) if occ.size else 0,
            overflow_points=int(occ[occ > cfg.capacity].sum()) if occ.size else 0,
            depth_mean=float(leaf_depths.mean()) if leaf_depths.size else 0.0,
            depth_max=int(leaf_depths.max()) if leaf_depths.size else 0,
        ))
    agg = {k: float(np.mean([s[k] for s in stats])) for k in stats[0]}
    agg["per_tree"] = stats
    return agg

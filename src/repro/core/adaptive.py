"""Early-exit wave scheduling over tree groups (beyond-paper, DESIGN.md §5).

The L trees are queried in waves of ``wave`` trees; after each wave the
current top-k distances are compared with the previous wave's — when the
relative improvement of the mean k-th distance drops below ``tol`` the search
stops.  Easy queries (dense neighborhoods) finish after 1-2 waves; hard ones
use the full forest — a per-query accuracy-compute tradeoff the static-L
paper configuration cannot express.  Trees are independent (paper §5), so any
prefix of the forest is itself a valid (smaller) forest.

Each wave dispatches through the fused single-pass pipeline
(``core.pipeline.fused_query``): traverse + dedup + chunk-streamed rerank in
one jit, no (B, M, d) intermediate.  Passing a ``QuantizedDB`` as ``db``
composes the early-exit schedule with the int8 shortlist rerank source.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.forest import Forest, ForestConfig
from repro.core.pipeline import fused_query
from repro.core.quantized import QuantizedDB
from repro.core.search import mask_duplicates, merge_topk_pairs


def _merge_dedup(d1, i1, d2, i2, k):
    """Top-k merge that drops repeated ids (the same neighbor is usually
    found by several waves)."""
    d = jnp.concatenate([d1, d2], axis=1)
    i = jnp.concatenate([i1, i2], axis=1)
    keep = mask_duplicates(i, i >= 0)
    d = jnp.where(keep, d, jnp.inf)
    return merge_topk_pairs(d, jnp.where(keep, i, -1), k)


def adaptive_query(forest: Forest, queries: jax.Array,
                   db: jax.Array | QuantizedDB, k: int, cfg: ForestConfig,
                   wave: int = 10, tol: float = 0.01, metric: str = "l2",
                   mode: str = "auto", chunk: int = 0, expand: int = 4,
                   dedup: bool = True, n_probes: int = 1,
                   valid: jax.Array | None = None):
    """Returns (dists, ids, trees_used). Host-side loop over tree waves.

    ``dedup`` masks duplicate ids within each wave's candidate set; the
    cross-wave merge always drops repeats regardless (a neighbor found by
    several waves must count once).  ``n_probes`` > 1 widens every wave to
    the multi-probe leaf set (DESIGN.md §9) — early exit then trades off
    against probes as well as trees.  ``valid`` optionally masks dead DB
    rows (segment tombstones) inside every wave's fused rerank.
    """
    n_points = db.fp.shape[0] if isinstance(db, QuantizedDB) else db.shape[0]
    cfg = cfg.resolved(n_points)
    n_trees = forest.n_trees
    best_d = jnp.full((queries.shape[0], k), jnp.inf)
    best_i = jnp.full((queries.shape[0], k), -1, jnp.int32)
    prev_kth = None
    used = 0
    for w0 in range(0, n_trees, wave):
        sub = jax.tree.map(lambda a: a[w0:w0 + wave], forest)
        d, i = fused_query(sub, queries, db, k, cfg, metric=metric, mode=mode,
                           chunk=chunk, expand=expand, dedup=dedup,
                           n_probes=n_probes, valid=valid)
        best_d, best_i = _merge_dedup(best_d, best_i, d, i, k)
        used = min(w0 + wave, n_trees)
        kth = float(jnp.mean(jnp.where(jnp.isfinite(best_d[:, -1]),
                                       best_d[:, -1], 0.0)))
        if prev_kth is not None and prev_kth > 0 \
                and (prev_kth - kth) / prev_kth < tol:
            break
        prev_kth = kth
    return best_d, best_i, used

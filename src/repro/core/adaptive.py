"""Early-exit wave scheduling over tree groups (beyond-paper, DESIGN.md §7).

The L trees are queried in waves of ``wave`` trees; after each wave the
current top-k distances are compared with the previous wave's — when the
relative improvement of the mean k-th distance drops below ``tol`` the search
stops.  Easy queries (dense neighborhoods) finish after 1-2 waves; hard ones
use the full forest — a per-query accuracy-compute tradeoff the static-L
paper configuration cannot express.  Trees are independent (paper §5), so any
prefix of the forest is itself a valid (smaller) forest.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.forest import Forest, ForestConfig, gather_candidates, traverse
from repro.core.search import mask_duplicates, rerank_topk
from repro.core.sharded_index import merge_topk_pairs


def _merge_dedup(d1, i1, d2, i2, k):
    """Top-k merge that drops repeated ids (the same neighbor is usually
    found by several waves)."""
    d = jnp.concatenate([d1, d2], axis=1)
    i = jnp.concatenate([i1, i2], axis=1)
    keep = mask_duplicates(i, i >= 0)
    d = jnp.where(keep, d, jnp.inf)
    return merge_topk_pairs(d, jnp.where(keep, i, -1), k)


def adaptive_query(forest: Forest, queries: jax.Array, db: jax.Array, k: int,
                   cfg: ForestConfig, wave: int = 10, tol: float = 0.01,
                   metric: str = "l2"):
    """Returns (dists, ids, trees_used). Host-side loop over tree waves."""
    cfg = cfg.resolved(db.shape[0])
    n_trees = forest.n_trees
    best_d = jnp.full((queries.shape[0], k), jnp.inf)
    best_i = jnp.full((queries.shape[0], k), -1, jnp.int32)
    prev_kth = None
    used = 0
    for w0 in range(0, n_trees, wave):
        sub = jax.tree.map(lambda a: a[w0:w0 + wave], forest)
        leaves = traverse(sub, queries, cfg.max_depth)
        ids, mask = gather_candidates(sub, leaves, cfg.leaf_pad)
        d, i = rerank_topk(queries, ids, mask, db, k=k, metric=metric)
        best_d, best_i = _merge_dedup(best_d, best_i, d, i, k)
        used = min(w0 + wave, n_trees)
        kth = float(jnp.mean(jnp.where(jnp.isfinite(best_d[:, -1]),
                                       best_d[:, -1], 0.0)))
        if prev_kth is not None and prev_kth > 0 \
                and (prev_kth - kth) / prev_kth < tol:
            break
        prev_kth = kth
    return best_d, best_i, used

"""Fused single-pass forest query pipeline: traverse -> dedup -> rerank.

The paper's query is "descend the L trees, union the leaf sets, rerank
exactly" (§3).  The staged implementation runs that as four dispatches
(traverse, gather_candidates, mask_duplicates, rerank_topk) with two fat HBM
intermediates: the padded (B, M) candidate matrix and — dominating at
M = L*C and paper-scale d — the gathered (B, M, d) candidate tensor.

This module is the production path: ONE jit that
  1. descends all L trees and assembles the (B, M) id matrix (cheap: int32),
  2. masks duplicate ids (the paper's leaf-set union) in-graph,
  3. streams candidate chunks through the fused gather+distance+top-k kernel
     (kernels/fused_query.py) which DMAs DB rows HBM->VMEM tile-by-tile and
     keeps the running (B, k) state on-chip.
The (B, M, d) tensor never exists; per-candidate HBM traffic drops ~3x
(gather-read + write + kernel-read  ->  one kernel-side read).  See
DESIGN.md §4 for the traffic model.

Chunk streaming serves two masters: it bounds the kernel's SMEM-resident id
operand (B * chunk * 4 bytes) and, in ref mode, bounds the per-chunk gather
to (B, chunk, d).  Chunks are merged with the associative top-k merge, so
the result is invariant to chunking (ties broken toward earlier chunks,
matching a single full-width top-k).  Both rerank sources — fp32 rows and
the int8 shortlist — derive their chunk width and batch-slab height from
the SAME helpers (``pick_rerank_chunk`` / ``pick_rows_budget``), so the
two paths cannot disagree on slab shape.

``core.schedule.scheduled_query`` layers per-query probe scheduling on top
of this module (DESIGN.md §14): it calls ``fused_query`` once per doubling
probe width on a shrinking active-query batch, so everything here — chunk
streaming, both rerank sources, the validity mask — composes with the
schedule unchanged.

The staged path stays available as ``staged_query`` — it is the oracle the
fused path is tested against, never a dispatch target.  Likewise the int8
coarse stage's jnp dequant-gather now lives only in
``kernels.ref.fused_gather_topk_int8_ref`` (the oracle); production
dispatches the fused int8 kernel (DESIGN.md §11).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.forest import (Forest, ForestConfig, gather_candidates,
                               gather_candidates_multi, traverse,
                               traverse_forest)
from repro.core.quantized import QuantizedDB
from repro.core.search import mask_duplicates, merge_topk_pairs, rerank_topk
from repro.kernels import ops

# The kernels keep the (B, chunk) id matrix in SMEM; stay well under the
# ~1 MB scalar-memory budget by default.
SMEM_ID_BUDGET_BYTES = 512 * 1024

# Ref-mode (oracle) reranks gather a (B, chunk, d) block per chunk; bound it
# so the full (B, M, d) tensor never exists on any path.
GATHER_BUDGET_BYTES = 1 << 20


def pick_rerank_chunk(b: int, m: int, d: int, chunk: int, bm: int, k: int,
                      mode: str) -> int:
    """THE candidate-axis chunk policy — shared by the fp32 and the int8
    rerank paths so they cannot disagree on slab shape (previously each
    derived its own budget: SMEM-only vs gather-only, and the int8 path
    ignored the SMEM bound entirely because it had no kernel).

    Width = explicit ``chunk`` if given, else the tighter of
      * the SMEM ids bound: B * chunk * 4 B (the kernels' scalar-prefetch
        operand) — always applies;
      * the gather bound: B * chunk * d * 4 B — applies when ``mode``
        resolves to the jnp oracle, which materializes that block per chunk.
    Never below k rounded up to a bm multiple: the per-chunk top-k needs k
    columns to select from, matching the staged oracle for any k <= M.
    """
    floor = -(-k // bm) * bm
    if chunk > 0:
        return min(max(chunk, floor), m)
    by_budget = SMEM_ID_BUDGET_BYTES // (4 * max(b, 1))
    use_pallas, _ = ops._resolve(mode)
    if not use_pallas:
        by_budget = min(by_budget,
                        GATHER_BUDGET_BYTES // (4 * max(b, 1) * max(d, 1)))
    by_budget = max(bm, (by_budget // bm) * bm)
    return min(m, max(by_budget, floor))


def pick_rows_budget(bq: int, bm: int) -> int:
    """Batch-axis slab height: keeps the SMEM ids operand (rows * chunk *
    4 B) within budget even at minimum chunk width, for any B.  Shared by
    both rerank sources (the other half of the slab-shape contract)."""
    return max(bq, SMEM_ID_BUDGET_BYTES // (4 * bm))


def _stream_rerank(queries, ids, k, fold_chunk, *, d: int, chunk: int,
                   bq: int, bm: int, rows_budget: int, mode: str):
    """Chunk- and slab-stream ``fold_chunk`` over the candidate matrix.

    ``fold_chunk(q_rows, id_rows) -> (dists, ids)`` scores one (rows, c)
    id block (the fused kernel or its oracle); chunks merge through the
    associative top-k, batch slabs ride ``lax.map``.  One streamer for both
    rerank sources = one slab shape.
    """
    b, m = ids.shape

    def stream(q_rows, id_rows):
        rows = q_rows.shape[0]
        c = pick_rerank_chunk(rows, m, d, chunk, bm, k, mode)
        if c >= m:
            return fold_chunk(q_rows, id_rows)
        m_pad = -m % c
        idp = jnp.pad(id_rows, ((0, 0), (0, m_pad)), constant_values=-1)
        n_chunks = (m + m_pad) // c

        def body(carry, blk):
            acc_d, acc_i = carry
            ids_blk = jax.lax.dynamic_slice_in_dim(idp, blk * c, c, axis=1)
            dd, ii = fold_chunk(q_rows, ids_blk)
            cat_d = jnp.concatenate([acc_d, dd], axis=1)
            cat_i = jnp.concatenate([acc_i, ii], axis=1)
            return merge_topk_pairs(cat_d, cat_i, k), None

        init = (jnp.full((rows, k), jnp.inf, jnp.float32),
                jnp.full((rows, k), -1, jnp.int32))
        (best_d, best_i), _ = jax.lax.scan(body, init, jnp.arange(n_chunks))
        return best_d, jnp.where(jnp.isinf(best_d), -1, best_i)

    if rows_budget <= 0:
        rows_budget = pick_rows_budget(bq, bm)
    if b <= rows_budget:
        return stream(queries, ids)
    b_pad = -b % rows_budget
    qp = jnp.pad(queries, ((0, b_pad), (0, 0)))
    idp = jnp.pad(ids, ((0, b_pad), (0, 0)), constant_values=-1)
    n_slab = (b + b_pad) // rows_budget
    dd, ii = jax.lax.map(
        lambda s: stream(s[0], s[1]),
        (qp.reshape(n_slab, rows_budget, -1),
         idp.reshape(n_slab, rows_budget, m)))
    return dd.reshape(-1, k)[:b], ii.reshape(-1, k)[:b]


@functools.partial(jax.jit, static_argnames=("k", "metric", "mode", "dedup",
                                             "chunk", "bq", "bm",
                                             "rows_budget"))
def rerank_fused(queries: jax.Array, cand_ids: jax.Array, mask: jax.Array,
                 db: jax.Array, k: int, metric: str = "l2",
                 mode: str = "auto", dedup: bool = True, chunk: int = 0,
                 bq: int = 8, bm: int = 32, rows_budget: int = 0,
                 valid: jax.Array | None = None
                 ) -> tuple[jax.Array, jax.Array]:
    """Chunk-streamed fused rerank: (B, M) candidate ids -> top-k.

    Drop-in for search.rerank_topk but never materializes (B, M, d); the
    per-chunk work dispatches through the mode policy (Pallas kernel on TPU
    or forced, jnp reference otherwise).

    ``valid`` is an optional (N,) bool row-validity mask (the segmented
    index's tombstone bitmap): candidates whose DB row is dead are folded
    into the existing id/mask path — their slots become id -1 before the
    kernel, so they issue no DMA and never occupy a top-k slot.
    """
    if valid is not None:
        mask = mask & valid[jnp.clip(cand_ids, 0, valid.shape[0] - 1)]
    if dedup:
        mask = mask_duplicates(cand_ids, mask)
    ids = jnp.where(mask, cand_ids, -1)

    return _stream_rerank(
        queries, ids, k,
        lambda q_rows, id_rows: ops.fused_rerank(
            q_rows, id_rows, db, k, metric=metric, mode=mode, bq=bq, bm=bm),
        d=queries.shape[1], chunk=chunk, bq=bq, bm=bm,
        rows_budget=rows_budget, mode=mode)


@functools.partial(jax.jit, static_argnames=("k", "expand", "metric", "mode",
                                             "dedup", "chunk", "bq", "bm"))
def rerank_fused_quantized(queries: jax.Array, cand_ids: jax.Array,
                           mask: jax.Array, qdb: QuantizedDB, k: int,
                           expand: int = 4, metric: str = "l2",
                           mode: str = "auto", dedup: bool = True,
                           chunk: int = 0, bq: int = 8, bm: int = 32,
                           valid: jax.Array | None = None
                           ) -> tuple[jax.Array, jax.Array]:
    """int8-shortlist-then-fp32 rerank source for the fused pipeline.

    Stage 1 streams candidate chunks through the fused int8 kernel
    (``ops.fused_rerank_int8``): d + 4 bytes DMA'd per candidate — ~4x
    fewer HBM bytes than fp32 rows — dequantized in VMEM registers, kept
    as a running coarse top-k' (k' = expand*k) scored under ``metric``,
    so the shortlist ranks like the fp32 rerank of record (the
    quantization scheme stays L2-calibrated — DESIGN.md §11/§13).  The
    jnp dequant-gather this
    stage used to run is now the ref-mode oracle only
    (``kernels.ref.fused_gather_topk_int8_ref``).  Stage 2 reranks only
    the (B, k') shortlist exactly against the fp32 rows through the fused
    gather+distance+top-k kernel.  Neither stage materializes (B, M, d),
    and both derive chunk/slab shape from the same shared helpers as the
    fp32 path.

    ``valid`` (optional (N,) bool tombstone mask) is applied at the coarse
    stage, so dead rows never occupy shortlist slots.

    Matches the staged quantized oracle (core.quantized.staged_rerank_quantized)
    exactly on tie-free data.
    """
    if valid is not None:
        mask = mask & valid[jnp.clip(cand_ids, 0, valid.shape[0] - 1)]
    if dedup:
        mask = mask_duplicates(cand_ids, mask)
    ids = jnp.where(mask, cand_ids, -1)
    kp = min(expand * k, ids.shape[1])

    short_d, short_i = _stream_rerank(
        queries, ids, kp,
        lambda q_rows, id_rows: ops.fused_rerank_int8(
            q_rows, id_rows, qdb.q, qdb.scale, kp, metric=metric, mode=mode,
            bq=bq, bm=bm),
        d=queries.shape[1], chunk=chunk, bq=bq, bm=bm, rows_budget=0,
        mode=mode)
    # exact fp32 rerank of the shortlist only (already deduped)
    return rerank_fused(queries, short_i, short_i >= 0, qdb.fp, k,
                        metric=metric, mode=mode, dedup=False, chunk=chunk,
                        bq=bq, bm=bm)


def _candidates(forest: Forest, queries: jax.Array, max_depth: int,
                leaf_pad: int, n_probes: int, mode: str = "auto"
                ) -> tuple[jax.Array, jax.Array]:
    """Traverse + candidate slice, single- or multi-probe.

    Traversal dispatches through :func:`repro.core.forest.traverse_forest`:
    the Pallas descent kernels when the mode policy says so (SMEM kernel
    below the node cap, HBM-resident kernel above — both bitwise-identical
    to the jnp descent for K = 1), the XLA traversal otherwise.  On CPU
    ``"auto"`` resolves to the jnp path, so ``n_probes == 1`` still traces
    the exact pre-multi-probe graph there (the historical bitwise pin);
    wider probes fold into the candidate axis of the same padded (B, M)
    id/mask contract, so nothing downstream changes.
    """
    if n_probes <= 1:
        leaves = traverse_forest(forest, queries, max_depth, 1, mode)
        return gather_candidates(forest, leaves, leaf_pad)
    leaves = traverse_forest(forest, queries, max_depth, n_probes, mode)
    return gather_candidates_multi(forest, leaves, leaf_pad)


@functools.partial(jax.jit, static_argnames=("k", "max_depth", "leaf_pad",
                                             "metric", "mode", "dedup",
                                             "chunk", "bq", "bm", "n_probes"))
def _fused_query_jit(forest: Forest, queries: jax.Array, db: jax.Array,
                     k: int, max_depth: int, leaf_pad: int, metric: str,
                     mode: str, dedup: bool, chunk: int, bq: int, bm: int,
                     n_probes: int, valid: jax.Array | None
                     ) -> tuple[jax.Array, jax.Array]:
    cand_ids, mask = _candidates(forest, queries, max_depth, leaf_pad,
                                 n_probes, mode)
    return rerank_fused(queries, cand_ids, mask, db, k, metric=metric,
                        mode=mode, dedup=dedup, chunk=chunk, bq=bq, bm=bm,
                        valid=valid)


@functools.partial(jax.jit, static_argnames=("k", "max_depth", "leaf_pad",
                                             "metric", "mode", "dedup",
                                             "chunk", "bq", "bm", "expand",
                                             "n_probes"))
def _fused_query_quantized_jit(forest: Forest, queries: jax.Array,
                               qdb: QuantizedDB, k: int, max_depth: int,
                               leaf_pad: int, metric: str, mode: str,
                               dedup: bool, chunk: int, bq: int, bm: int,
                               expand: int, n_probes: int,
                               valid: jax.Array | None
                               ) -> tuple[jax.Array, jax.Array]:
    cand_ids, mask = _candidates(forest, queries, max_depth, leaf_pad,
                                 n_probes, mode)
    return rerank_fused_quantized(queries, cand_ids, mask, qdb, k,
                                  expand=expand, metric=metric, mode=mode,
                                  dedup=dedup, chunk=chunk, bq=bq, bm=bm,
                                  valid=valid)


def fused_query(forest: Forest, queries: jax.Array,
                db: jax.Array | QuantizedDB, k: int, cfg: ForestConfig,
                metric: str = "l2", dedup: bool = True, mode: str = "auto",
                chunk: int = 0, bq: int = 8, bm: int = 32, expand: int = 4,
                n_probes: int = 1, valid: jax.Array | None = None
                ) -> tuple[jax.Array, jax.Array]:
    """End-to-end single-jit forest query (the production hot path).

    ``db`` selects the rerank source: a plain (N, d) f32 array reranks every
    candidate exactly through the fused kernel; a ``QuantizedDB`` runs the
    int8 coarse shortlist (k' = ``expand``*k) first and reranks only the
    shortlist in fp32 — same fused pipeline, pluggable rerank source.
    ``n_probes`` > 1 descends to that many most-marginal leaves per tree
    (DESIGN.md §9) — the wider candidate set rides the same (B, M) id/mask
    path, so it composes with every rerank source and with ``valid``.
    ``valid`` optionally masks dead DB rows (segment tombstones).

    Returns (dists (B, k), ids (B, k)); invalid slots: dist +inf, id -1.
    """
    if isinstance(db, QuantizedDB):
        cfg = cfg.resolved(db.fp.shape[0])
        return _fused_query_quantized_jit(forest, queries, db, k,
                                          cfg.max_depth, cfg.leaf_pad, metric,
                                          mode, dedup, chunk, bq, bm, expand,
                                          n_probes, valid)
    cfg = cfg.resolved(db.shape[0])
    return _fused_query_jit(forest, queries, db, k, cfg.max_depth,
                            cfg.leaf_pad, metric, mode, dedup, chunk, bq, bm,
                            n_probes, valid)


def staged_query(forest: Forest, queries: jax.Array, db: jax.Array, k: int,
                 cfg: ForestConfig, metric: str = "l2", dedup: bool = True
                 ) -> tuple[jax.Array, jax.Array]:
    """The pre-fusion pipeline, kept verbatim as the correctness oracle.

    Four dispatches; materializes (B, M) ids + the (B, M, d) gathered
    candidate tensor between stages.  Benchmarked against the fused path in
    benchmarks/fused_vs_staged.py.
    """
    cfg = cfg.resolved(db.shape[0])
    leaves = traverse(forest, queries, cfg.max_depth)
    cand_ids, mask = gather_candidates(forest, leaves, cfg.leaf_pad)
    return rerank_topk(queries, cand_ids, mask, db, k=k, metric=metric,
                       dedup=dedup)

"""Fused single-pass forest query pipeline: traverse -> dedup -> rerank.

The paper's query is "descend the L trees, union the leaf sets, rerank
exactly" (§3).  The staged implementation runs that as four dispatches
(traverse, gather_candidates, mask_duplicates, rerank_topk) with two fat HBM
intermediates: the padded (B, M) candidate matrix and — dominating at
M = L*C and paper-scale d — the gathered (B, M, d) candidate tensor.

This module is the production path: ONE jit that
  1. descends all L trees and assembles the (B, M) id matrix (cheap: int32),
  2. masks duplicate ids (the paper's leaf-set union) in-graph,
  3. streams candidate chunks through the fused gather+distance+top-k kernel
     (kernels/fused_query.py) which DMAs DB rows HBM->VMEM tile-by-tile and
     keeps the running (B, k) state on-chip.
The (B, M, d) tensor never exists; per-candidate HBM traffic drops ~3x
(gather-read + write + kernel-read  ->  one kernel-side read).  See
DESIGN.md §4 for the traffic model.

Chunk streaming serves two masters: it bounds the kernel's SMEM-resident id
operand (B * chunk * 4 bytes) and, in ref mode, bounds the per-chunk gather
to (B, chunk, d).  Chunks are merged with the associative top-k merge, so
the result is invariant to chunking (ties broken toward earlier chunks,
matching a single full-width top-k).

The staged path stays available as ``staged_query`` — it is the oracle the
fused path is tested against, never a dispatch target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.forest import (Forest, ForestConfig, gather_candidates,
                               gather_candidates_multi, traverse,
                               traverse_multiprobe)
from repro.core.quantized import QuantizedDB
from repro.core.search import mask_duplicates, merge_topk_pairs, rerank_topk
from repro.kernels import ops

# The kernel keeps the (B, chunk) id matrix in SMEM; stay well under the
# ~1 MB scalar-memory budget by default.
SMEM_ID_BUDGET_BYTES = 512 * 1024

# The int8 coarse stage gathers dequantized candidate blocks with plain jnp
# (no Pallas kernel reads int8 rows yet); bound that per-chunk gather so the
# (B, chunk, d) block stays HBM-cache-sized and the full (B, M, d) tensor
# never exists on this path either.
GATHER_BUDGET_BYTES = 1 << 20


def _pick_chunk(b: int, m: int, chunk: int, bm: int, k: int) -> int:
    """Candidate-axis chunk width: explicit > SMEM-budget-derived.

    Never below k (rounded up to a bm multiple): the per-chunk top-k needs
    k columns to select from, matching the staged oracle for any k <= M.
    """
    floor = -(-k // bm) * bm
    if chunk > 0:
        return min(max(chunk, floor), m)
    by_budget = SMEM_ID_BUDGET_BYTES // (4 * max(b, 1))
    by_budget = max(bm, (by_budget // bm) * bm)
    return min(m, max(by_budget, floor))


@functools.partial(jax.jit, static_argnames=("k", "metric", "mode", "dedup",
                                             "chunk", "bq", "bm",
                                             "rows_budget"))
def rerank_fused(queries: jax.Array, cand_ids: jax.Array, mask: jax.Array,
                 db: jax.Array, k: int, metric: str = "l2",
                 mode: str = "auto", dedup: bool = True, chunk: int = 0,
                 bq: int = 8, bm: int = 32, rows_budget: int = 0,
                 valid: jax.Array | None = None
                 ) -> tuple[jax.Array, jax.Array]:
    """Chunk-streamed fused rerank: (B, M) candidate ids -> top-k.

    Drop-in for search.rerank_topk but never materializes (B, M, d); the
    per-chunk work dispatches through the mode policy (Pallas kernel on TPU
    or forced, jnp reference otherwise).

    ``valid`` is an optional (N,) bool row-validity mask (the segmented
    index's tombstone bitmap): candidates whose DB row is dead are folded
    into the existing id/mask path — their slots become id -1 before the
    kernel, so they issue no DMA and never occupy a top-k slot.
    """
    if valid is not None:
        mask = mask & valid[jnp.clip(cand_ids, 0, valid.shape[0] - 1)]
    if dedup:
        mask = mask_duplicates(cand_ids, mask)
    ids = jnp.where(mask, cand_ids, -1)
    b, m = ids.shape

    def stream(q_rows, id_rows):
        """Chunk-streamed fused rerank over one slab of query rows."""
        rows = q_rows.shape[0]
        c = _pick_chunk(rows, m, chunk, bm, k)
        if c >= m:
            return ops.fused_rerank(q_rows, id_rows, db, k, metric=metric,
                                    mode=mode, bq=bq, bm=bm)
        m_pad = -m % c
        idp = jnp.pad(id_rows, ((0, 0), (0, m_pad)), constant_values=-1)
        n_chunks = (m + m_pad) // c

        def body(carry, blk):
            acc_d, acc_i = carry
            ids_blk = jax.lax.dynamic_slice_in_dim(idp, blk * c, c, axis=1)
            d, i = ops.fused_rerank(q_rows, ids_blk, db, k, metric=metric,
                                    mode=mode, bq=bq, bm=bm)
            cat_d = jnp.concatenate([acc_d, d], axis=1)
            cat_i = jnp.concatenate([acc_i, i], axis=1)
            return merge_topk_pairs(cat_d, cat_i, k), None

        init = (jnp.full((rows, k), jnp.inf, jnp.float32),
                jnp.full((rows, k), -1, jnp.int32))
        (best_d, best_i), _ = jax.lax.scan(body, init, jnp.arange(n_chunks))
        return best_d, jnp.where(jnp.isinf(best_d), -1, best_i)

    # slab the batch axis so the kernel's SMEM ids operand (rows*chunk*4 B)
    # respects the budget even at minimum chunk width for any B
    if rows_budget <= 0:
        rows_budget = max(bq, SMEM_ID_BUDGET_BYTES // (4 * bm))
    if b <= rows_budget:
        return stream(queries, ids)
    b_pad = -b % rows_budget
    qp = jnp.pad(queries, ((0, b_pad), (0, 0)))
    idp = jnp.pad(ids, ((0, b_pad), (0, 0)), constant_values=-1)
    n_slab = (b + b_pad) // rows_budget
    d, i = jax.lax.map(
        lambda s: stream(s[0], s[1]),
        (qp.reshape(n_slab, rows_budget, -1),
         idp.reshape(n_slab, rows_budget, m)))
    return d.reshape(-1, k)[:b], i.reshape(-1, k)[:b]


def _pick_gather_chunk(b: int, m: int, d: int, chunk: int, bm: int, k: int
                       ) -> int:
    """Coarse-stage chunk width: explicit > gather-budget-derived.

    Bounds the dequantized (B, chunk, d) f32 block at GATHER_BUDGET_BYTES;
    never below k rounded up to a bm multiple (the per-chunk top-k needs k
    columns to select from).
    """
    floor = -(-k // bm) * bm
    if chunk > 0:
        return min(max(chunk, floor), m)
    by_budget = GATHER_BUDGET_BYTES // (4 * max(b, 1) * max(d, 1))
    by_budget = max(bm, (by_budget // bm) * bm)
    return min(m, max(by_budget, floor))


@functools.partial(jax.jit, static_argnames=("k", "expand", "metric", "mode",
                                             "dedup", "chunk", "bq", "bm"))
def rerank_fused_quantized(queries: jax.Array, cand_ids: jax.Array,
                           mask: jax.Array, qdb: QuantizedDB, k: int,
                           expand: int = 4, metric: str = "l2",
                           mode: str = "auto", dedup: bool = True,
                           chunk: int = 0, bq: int = 8, bm: int = 32,
                           valid: jax.Array | None = None
                           ) -> tuple[jax.Array, jax.Array]:
    """int8-shortlist-then-fp32 rerank source for the fused pipeline.

    Stage 1 streams candidate chunks over the int8 rows (4x fewer HBM bytes
    than fp32) and keeps a running coarse top-k' (k' = expand*k, always L2 —
    the quantization scheme is L2-calibrated).  Stage 2 reranks only the
    (B, k') shortlist exactly against the fp32 rows through the fused
    gather+distance+top-k kernel.  Neither stage materializes (B, M, d).

    ``valid`` (optional (N,) bool tombstone mask) is applied at the coarse
    stage, so dead rows never occupy shortlist slots.

    Matches the staged quantized oracle (core.quantized.staged_rerank_quantized)
    exactly on tie-free data.
    """
    if valid is not None:
        mask = mask & valid[jnp.clip(cand_ids, 0, valid.shape[0] - 1)]
    if dedup:
        mask = mask_duplicates(cand_ids, mask)
    ids = jnp.where(mask, cand_ids, -1)
    b, m = ids.shape
    kp = min(expand * k, m)

    def coarse(ids_blk: jax.Array) -> jax.Array:
        """Coarse L2 on dequantized int8 rows for one (B, c) id block."""
        valid = ids_blk >= 0
        safe = jnp.where(valid, ids_blk, 0)
        deq = qdb.q[safe].astype(jnp.float32) * qdb.scale[safe][:, :, None]
        d = jnp.sum((queries[:, None, :] - deq) ** 2, axis=-1)
        return jnp.where(valid, d, jnp.inf)

    c = _pick_gather_chunk(b, m, queries.shape[1], chunk, bm, kp)
    if c >= m:
        d = coarse(ids)
        neg, pos = jax.lax.top_k(-d, kp)
        short_d = -neg
        short_i = jnp.take_along_axis(ids, pos, axis=1)
    else:
        m_pad = -m % c
        idp = jnp.pad(ids, ((0, 0), (0, m_pad)), constant_values=-1)
        n_chunks = (m + m_pad) // c

        def body(carry, blk):
            acc_d, acc_i = carry
            ids_blk = jax.lax.dynamic_slice_in_dim(idp, blk * c, c, axis=1)
            d = coarse(ids_blk)
            cat_d = jnp.concatenate([acc_d, d], axis=1)
            cat_i = jnp.concatenate([acc_i, ids_blk], axis=1)
            return merge_topk_pairs(cat_d, cat_i, kp), None

        init = (jnp.full((b, kp), jnp.inf, jnp.float32),
                jnp.full((b, kp), -1, jnp.int32))
        (short_d, short_i), _ = jax.lax.scan(body, init, jnp.arange(n_chunks))
    short_i = jnp.where(jnp.isinf(short_d), -1, short_i)
    # exact fp32 rerank of the shortlist only (already deduped)
    return rerank_fused(queries, short_i, short_i >= 0, qdb.fp, k,
                        metric=metric, mode=mode, dedup=False, chunk=chunk,
                        bq=bq, bm=bm)


def _candidates(forest: Forest, queries: jax.Array, max_depth: int,
                leaf_pad: int, n_probes: int
                ) -> tuple[jax.Array, jax.Array]:
    """Traverse + candidate slice, single- or multi-probe.

    ``n_probes == 1`` traces the exact pre-multi-probe graph
    (:func:`traverse` + :func:`gather_candidates`), keeping the bitwise
    guarantee trivially; wider probes fold into the candidate axis of the
    same padded (B, M) id/mask contract, so nothing downstream changes.
    """
    if n_probes <= 1:
        leaves = traverse(forest, queries, max_depth)
        return gather_candidates(forest, leaves, leaf_pad)
    leaves = traverse_multiprobe(forest, queries, max_depth, n_probes)
    return gather_candidates_multi(forest, leaves, leaf_pad)


@functools.partial(jax.jit, static_argnames=("k", "max_depth", "leaf_pad",
                                             "metric", "mode", "dedup",
                                             "chunk", "bq", "bm", "n_probes"))
def _fused_query_jit(forest: Forest, queries: jax.Array, db: jax.Array,
                     k: int, max_depth: int, leaf_pad: int, metric: str,
                     mode: str, dedup: bool, chunk: int, bq: int, bm: int,
                     n_probes: int, valid: jax.Array | None
                     ) -> tuple[jax.Array, jax.Array]:
    cand_ids, mask = _candidates(forest, queries, max_depth, leaf_pad,
                                 n_probes)
    return rerank_fused(queries, cand_ids, mask, db, k, metric=metric,
                        mode=mode, dedup=dedup, chunk=chunk, bq=bq, bm=bm,
                        valid=valid)


@functools.partial(jax.jit, static_argnames=("k", "max_depth", "leaf_pad",
                                             "metric", "mode", "dedup",
                                             "chunk", "bq", "bm", "expand",
                                             "n_probes"))
def _fused_query_quantized_jit(forest: Forest, queries: jax.Array,
                               qdb: QuantizedDB, k: int, max_depth: int,
                               leaf_pad: int, metric: str, mode: str,
                               dedup: bool, chunk: int, bq: int, bm: int,
                               expand: int, n_probes: int,
                               valid: jax.Array | None
                               ) -> tuple[jax.Array, jax.Array]:
    cand_ids, mask = _candidates(forest, queries, max_depth, leaf_pad,
                                 n_probes)
    return rerank_fused_quantized(queries, cand_ids, mask, qdb, k,
                                  expand=expand, metric=metric, mode=mode,
                                  dedup=dedup, chunk=chunk, bq=bq, bm=bm,
                                  valid=valid)


def fused_query(forest: Forest, queries: jax.Array,
                db: jax.Array | QuantizedDB, k: int, cfg: ForestConfig,
                metric: str = "l2", dedup: bool = True, mode: str = "auto",
                chunk: int = 0, bq: int = 8, bm: int = 32, expand: int = 4,
                n_probes: int = 1, valid: jax.Array | None = None
                ) -> tuple[jax.Array, jax.Array]:
    """End-to-end single-jit forest query (the production hot path).

    ``db`` selects the rerank source: a plain (N, d) f32 array reranks every
    candidate exactly through the fused kernel; a ``QuantizedDB`` runs the
    int8 coarse shortlist (k' = ``expand``*k) first and reranks only the
    shortlist in fp32 — same fused pipeline, pluggable rerank source.
    ``n_probes`` > 1 descends to that many most-marginal leaves per tree
    (DESIGN.md §9) — the wider candidate set rides the same (B, M) id/mask
    path, so it composes with every rerank source and with ``valid``.
    ``valid`` optionally masks dead DB rows (segment tombstones).

    Returns (dists (B, k), ids (B, k)); invalid slots: dist +inf, id -1.
    """
    if isinstance(db, QuantizedDB):
        cfg = cfg.resolved(db.fp.shape[0])
        return _fused_query_quantized_jit(forest, queries, db, k,
                                          cfg.max_depth, cfg.leaf_pad, metric,
                                          mode, dedup, chunk, bq, bm, expand,
                                          n_probes, valid)
    cfg = cfg.resolved(db.shape[0])
    return _fused_query_jit(forest, queries, db, k, cfg.max_depth,
                            cfg.leaf_pad, metric, mode, dedup, chunk, bq, bm,
                            n_probes, valid)


def staged_query(forest: Forest, queries: jax.Array, db: jax.Array, k: int,
                 cfg: ForestConfig, metric: str = "l2", dedup: bool = True
                 ) -> tuple[jax.Array, jax.Array]:
    """The pre-fusion pipeline, kept verbatim as the correctness oracle.

    Four dispatches; materializes (B, M) ids + the (B, M, d) gathered
    candidate tensor between stages.  Benchmarked against the fused path in
    benchmarks/fused_vs_staged.py.
    """
    cfg = cfg.resolved(db.shape[0])
    leaves = traverse(forest, queries, cfg.max_depth)
    cand_ids, mask = gather_candidates(forest, leaves, cfg.leaf_pad)
    return rerank_topk(queries, cand_ids, mask, db, k=k, metric=metric,
                       dedup=dedup)

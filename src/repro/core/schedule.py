"""Per-query adaptive probe scheduling (beyond-paper, DESIGN.md §14).

The paper fixes one probe budget for the whole batch, so every easy query
pays the p99 price of the hardest one.  Dynamic Continuous Indexing
(Li & Malik 2015, PAPERS.md) makes the budget per-query: retrieve more
candidates only while a query's top-k is still moving.  This module applies
that insight to the multi-probe forest descent (DESIGN.md §9): every query
starts at ``n_probes = 1`` and is re-descended at a doubling probe width —
1, 2, 4, … up to the cap — while its k-th distance keeps improving by more
than ``tol`` per round.  Converged queries drop out of later rounds.

Static shapes throughout (the ragged-to-padded trick): the still-active
queries are gathered into a padded batch whose height is rounded up to the
next power of two — the same staged active-set shrink
``_build_forest_batched`` uses — so each (bucket height, probe width) pair
compiles once and a shrinking batch never retraces.  Pad rows repeat a real
active query (batch-coupled kernels must not see synthetic points) and
their results are discarded.

Each round REPLACES a query's running result rather than merging into it:
probe sets are monotone prefixes (``traverse_multiprobe``'s top-k of
smallest margins at width p is the prefix of the set at width p+1), so the
round at width w sees a superset of every earlier round's candidates and
its exact-rerank result can only improve.  Replacement also makes the
never-converge case exact by construction: with ``tol = 0.0`` no query
ever converges (the improvement is clamped non-negative, and 0 < 0 is
false), so the final round runs the full batch in original order at the
cap — literally the same ``fused_query`` call as the fixed-``n_probes``
path, hence bitwise-identical on every rerank source, including the int8
shortlist whose coarse stage is not candidate-subset-decomposable.

Each round dispatches through the fused single-pass pipeline
(``core.pipeline.fused_query``): traverse + dedup + chunk-streamed rerank
in one jit, no (B, M, d) intermediate.  Passing a ``QuantizedDB`` as
``db`` composes the schedule with the int8 shortlist rerank source, and
``valid`` threads segment tombstones / filter bitmaps through unchanged.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.forest import Forest, ForestConfig
from repro.core.pipeline import fused_query
from repro.core.quantized import QuantizedDB

__all__ = ["probe_widths", "scheduled_query"]


def probe_widths(cap: int) -> list[int]:
    """The round schedule: doubling widths 1, 2, 4, … ending exactly at
    ``cap`` (e.g. cap=6 -> [1, 2, 4, 6]).  Doubling keeps the number of
    rounds — and with it the number of compiled (bucket, width) variants —
    logarithmic in the cap."""
    if cap < 1:
        raise ValueError(f"probe cap must be >= 1, got {cap}")
    widths, w = [], 1
    while w < cap:
        widths.append(w)
        w *= 2
    widths.append(cap)
    return widths


def _bucket(n: int, b: int) -> int:
    """Padded height for ``n`` active queries: next power of two, capped at
    the full batch.  Bounds distinct compiled batch heights to log2(B)."""
    p = 1
    while p < n:
        p *= 2
    return min(p, b)


def _improvement(prev_kth: np.ndarray, kth: np.ndarray) -> np.ndarray:
    """Relative k-th-distance improvement per query, the same signal
    ``core.adaptive`` uses across tree waves, made per-query:

      * an infinite previous k-th (top-k not yet filled) never converges;
      * the denominator is |prev| so signed metrics (ip/cosine) behave;
      * clamped at 0 so a round that cannot improve (or, on the int8
        shortlist, slightly regresses) reads as "no improvement" — which
        also makes ``tol = 0.0`` disable early stop exactly (0 < 0 is
        false), the bitwise-parity escape hatch the tests pin.
    """
    with np.errstate(invalid="ignore", divide="ignore"):
        rel = (prev_kth - kth) / np.abs(prev_kth)
    rel = np.where(np.isfinite(prev_kth),
                   np.where(prev_kth == 0.0, 0.0, rel), np.inf)
    return np.maximum(rel, 0.0)


def scheduled_query(forest: Forest, queries: jax.Array,
                    db: jax.Array | QuantizedDB, k: int, cfg: ForestConfig,
                    cap: int, tol: float = 0.01, metric: str = "l2",
                    mode: str = "auto", chunk: int = 0, expand: int = 4,
                    dedup: bool = True, valid: jax.Array | None = None
                    ) -> tuple[jax.Array, jax.Array, np.ndarray, np.ndarray]:
    """Convergence-gated per-query probe widening up to ``cap`` probes.

    Returns ``(dists (B, k), ids (B, k), probes_final (B,),
    probes_processed (B,))``: ``probes_final`` is the probe width each
    query's answer came from; ``probes_processed`` the total probes it was
    descended at across rounds (1 + 2 + … — the honest compute charge the
    tuner's cost model and the benchmark gate use).

    Host-side loop over rounds, like ``core.adaptive``'s wave loop; all
    array work stays on device.  ``tol = 0.0`` never converges any query,
    making the result bitwise-identical to ``fused_query`` at
    ``n_probes = cap``.
    """
    n_points = db.fp.shape[0] if isinstance(db, QuantizedDB) else db.shape[0]
    cfg = cfg.resolved(n_points)
    queries = jnp.asarray(queries)
    b = queries.shape[0]
    widths = probe_widths(cap)

    best_d, best_i = fused_query(forest, queries, db, k, cfg, metric=metric,
                                 dedup=dedup, mode=mode, chunk=chunk,
                                 expand=expand, n_probes=widths[0],
                                 valid=valid)
    probes_final = np.full(b, widths[0], np.int32)
    probes_processed = np.full(b, widths[0], np.int32)
    prev_kth = np.array(best_d[:, -1])      # writable host copy
    active = np.arange(b)

    for w in widths[1:]:
        if active.size == 0:
            break
        if active.size == b:
            q_act, n_act = queries, b        # full batch: original order
        else:
            n_act = active.size
            padded = np.concatenate(
                [active, np.full(_bucket(n_act, b) - n_act, active[0])])
            q_act = queries[jnp.asarray(padded)]
        d, i = fused_query(forest, q_act, db, k, cfg, metric=metric,
                           dedup=dedup, mode=mode, chunk=chunk,
                           expand=expand, n_probes=w, valid=valid)
        d_act, i_act = d[:n_act], i[:n_act]
        if active.size == b:
            best_d, best_i = d_act, i_act
        else:
            sel = jnp.asarray(active)
            best_d = best_d.at[sel].set(d_act)
            best_i = best_i.at[sel].set(i_act)
        probes_final[active] = w
        probes_processed[active] += w
        kth = np.asarray(d_act[:, -1])
        converged = _improvement(prev_kth[active], kth) < tol
        prev_kth[active] = kth
        active = active[~converged]

    return best_d, best_i, probes_final, probes_processed

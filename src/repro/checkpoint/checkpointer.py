"""Async, elastic checkpointing (no orbax in this environment).

Format: a checkpoint directory per step containing one .npy per pytree leaf
(leaf names are '/'-joined tree paths) + manifest.json (step, tree structure,
shapes/dtypes, mesh metadata).  Writes go to ``<dir>.tmp`` then atomically
rename — a crash mid-write never corrupts the latest checkpoint.

Elasticity: leaves are stored as *global logical arrays*; restore device_puts
them under ANY target mesh/sharding (tested 8->4 and 4->8 device reshapes).
At real multi-host scale the same layout maps to per-shard files keyed by the
shard index — single-process here, so device_get produces the global array
directly.

Async: `save(..., block=False)` snapshots to host then writes on a background
thread; `wait()` joins. A SIGTERM handler (install_preemption_handler) flips a
flag the train loop polls to checkpoint-and-exit cleanly (preemption safety).
"""
from __future__ import annotations

import json
import os
import shutil
import signal
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_names(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(_path_str(p) for p in path)
        out.append((name, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, block: bool = True,
             extra: Optional[dict] = None) -> str:
        self.wait()
        named = _flatten_with_names(tree)
        # snapshot to host memory first (cheap for the caller; the device
        # buffers are free to be donated to the next step immediately).
        # non-native float dtypes (bf16/fp8) are stored as f32 — LOSSLESS
        # upcasts — with the true dtype recorded in the manifest.
        host = []
        for n, x in named:
            a = np.asarray(jax.device_get(x))
            store = a
            if a.dtype.kind not in "fiub" or str(a.dtype) == "bfloat16":
                store = a.astype(np.float32)
            host.append((n, store, str(a.dtype)))
        treedef = jax.tree_util.tree_structure(tree)
        path = os.path.join(self.dir, f"step_{step:010d}")

        def _write():
            tmp = path + ".tmp"
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            manifest = {
                "step": step,
                "leaves": [{"name": n, "shape": list(a.shape), "dtype": dt}
                           for n, a, dt in host],
                "treedef": str(treedef),
                "extra": extra or {},
            }
            for n, a, _ in host:
                np.save(os.path.join(tmp, n.replace("/", "__") + ".npy"), a)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            shutil.rmtree(path, ignore_errors=True)
            os.rename(tmp, path)
            self._gc()

        if block:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        return path

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target_tree, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of ``target_tree``; optional sharding
        tree reshards onto a (possibly different) mesh — the elastic path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:010d}")
        import json as _json
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = _json.load(f)
        stored_dtypes = {l["name"]: l["dtype"] for l in manifest["leaves"]}
        named = _flatten_with_names(target_tree)
        arrays = []
        for n, leaf in named:
            a = np.load(os.path.join(path, n.replace("/", "__") + ".npy"))
            arrays.append(jnp_dtype_cast(a, stored_dtypes.get(n)))
        treedef = jax.tree_util.tree_structure(target_tree)
        restored = jax.tree_util.tree_unflatten(treedef, arrays)
        if shardings is not None:
            restored = jax.tree.map(
                lambda a, s: jax.device_put(a, s), restored, shardings)
        else:
            restored = jax.tree.map(
                lambda a, t: a if isinstance(a, np.ndarray)
                else jax.device_put(a).astype(t.dtype),
                restored, target_tree)
        return restored, step


def jnp_dtype_cast(a: np.ndarray, dtype_str: Optional[str]):
    """Cast a stored array back to its original (possibly non-numpy-native)
    dtype via jnp (bf16 was stored as lossless f32).  64-bit integer
    leaves (e.g. metadata timestamp columns) stay host-side numpy: without
    x64, jnp would silently truncate them to 32 bits."""
    import jax.numpy as jnp
    if (dtype_str is not None and np.dtype(dtype_str).kind in "iu"
            and np.dtype(dtype_str).itemsize == 8
            and not jax.config.jax_enable_x64):
        return np.asarray(a, np.dtype(dtype_str))
    if dtype_str is None or str(a.dtype) == dtype_str:
        return jnp.asarray(a)
    return jnp.asarray(a).astype(jnp.dtype(dtype_str))


_PREEMPTED = threading.Event()


def install_preemption_handler():
    """SIGTERM -> set flag; the train loop checkpoints and exits cleanly."""
    def _handler(signum, frame):
        _PREEMPTED.set()
    signal.signal(signal.SIGTERM, _handler)
    return _PREEMPTED


def preempted() -> bool:
    return _PREEMPTED.is_set()

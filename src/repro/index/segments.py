"""Segmented mutable-index state: sealed segments, the delta buffer, views.

The mutation half of the unified Index API (DESIGN.md §8) is LSM-shaped:

  * ``SealedSegment`` — an immutable block of rows with a backend-built
    search state ("engine"), a global-id column, and a tombstone bitmap.
    Sealed segments are never edited in place: a delete produces a new
    ``SealedSegment`` object sharing the engine/rows/ids and carrying a
    copy-on-write ``live`` bitmap, so published views stay frozen.
  * ``DeltaBuffer`` — the one mutable piece: a small growable host buffer
    of freshly added rows, brute-force searched through the same fused
    rerank kernel as every sealed backend.  The stacked device copy is
    cached and re-uploaded only when new rows landed since the last search
    (never re-stacked per query).  Sealing a delta builds a fresh engine
    over its rows — for forest backends that is one batched cross-tree
    build (DESIGN.md §10), which is what keeps the seal path cheap.
  * ``IndexView`` — an immutable snapshot of (sealed segments, delta
    prefix, tombstones).  ``Index.search`` grabs the current view with a
    single attribute read — readers never take the writer lock — and
    ``Index.snapshot()`` hands the view out directly for repeatable reads.

Engines are duck-typed (see ``index/backends.py``): anything exposing
``search(q, params, valid=None) -> (dists, local_ids)`` plus the host
``db`` rows works.  All distance math — sealed, delta, and brute-force —
funnels through ``core.pipeline.rerank_fused``'s fused gather+distance+
top-k path, so a row's distance is bitwise-identical no matter which
segment it currently lives in (the property the mutation tests pin).

Filtered search (DESIGN.md §13) rides the same machinery: a sealed
segment optionally carries an immutable ``MetaBlock`` of per-row metadata
columns; ``SearchParams.filter`` predicates compile per segment into a
match bitmap (cached on the block), AND with ``live``, and replace the
tombstone mask on the engine's ``valid=`` path — the kernels never learn
about predicates.  ``IndexView.search`` estimates the filter's
selectivity from those bitmaps and either widens the candidate budget
(``repro.filter.predicate.widen_params``) or, below the brute-force
threshold, exact-scans only the matching rows.
"""
from __future__ import annotations

import functools
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.search import merge_topk_pairs
from repro.index.params import SearchParams

# location tag for rows living in the (unsealed) delta buffer
DELTA_SID = -1

_DELTA_MIN_CAP = 64


@jax.jit
def _remap_gids(local_ids: jax.Array, gids_dev: jax.Array) -> jax.Array:
    """Segment-local result ids -> global ids (-1 slots pass through)."""
    safe = jnp.maximum(local_ids, 0)
    return jnp.where(local_ids >= 0, gids_dev[safe], -1)


def brute_force_topk(q: jax.Array, rows_dev: jax.Array, params: SearchParams,
                     valid: jax.Array | None = None
                     ) -> tuple[jax.Array, jax.Array]:
    """Exact scan via the fused rerank path: (B, k) dists + LOCAL row ids.

    Used by the bruteforce backend and the delta overlay.  Routing the scan
    through ``rerank_fused`` (ids = arange, mask = validity) keeps the
    distance arithmetic identical to every candidate-based backend, which
    is what makes mutated-index results bitwise-comparable to fresh builds.
    The id matrix is padded to >= k columns so the top-k is well-defined
    on segments smaller than k.
    """
    from repro.core.pipeline import rerank_fused
    b = q.shape[0]
    n = rows_dev.shape[0]
    m = max(n, params.k)
    ids = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None, :], (b, n))
    if valid is None:
        mask = jnp.ones((b, n), bool)
    else:
        mask = jnp.broadcast_to(valid[None, :], (b, n))
    if m > n:
        ids = jnp.pad(ids, ((0, 0), (0, m - n)), constant_values=-1)
        mask = jnp.pad(mask, ((0, 0), (0, m - n)))
    return rerank_fused(q, ids, mask, rows_dev, params.k,
                        metric=params.metric, mode=params.mode, dedup=False,
                        chunk=params.chunk)


class SealedSegment:
    """Immutable sealed segment: engine + global ids + tombstone bitmap.

    ``live`` is copy-on-write: ``with_tombstones`` returns a new segment
    sharing the engine/gids (and their cached device copies) with a fresh
    bitmap, so views published before a delete keep the old liveness.
    """

    __slots__ = ("sid", "engine", "gids", "live", "n_dead", "identity_gids",
                 "meta", "_gids_dev_cell", "_live_dev", "_filter_dev")

    def __init__(self, sid: int, engine, gids: np.ndarray,
                 live: np.ndarray | None = None,
                 identity_gids: bool | None = None,
                 meta=None,
                 _gids_dev_cell: list | None = None):
        self.sid = sid
        self.engine = engine
        self.gids = np.ascontiguousarray(np.asarray(gids, np.int32))
        if live is None:
            live = np.ones(self.gids.shape[0], bool)
        self.live = live
        self.n_dead = int(live.size - np.count_nonzero(live))
        if identity_gids is None:
            identity_gids = bool(np.array_equal(
                self.gids, np.arange(self.gids.shape[0], dtype=np.int32)))
        self.identity_gids = identity_gids
        # immutable per-row metadata columns (repro.filter.MetaBlock);
        # SHARED across with_tombstones copies — metadata never changes
        # after seal, so its predicate-bitmap cache warms once per segment
        self.meta = meta
        # one-element cell shared across with_tombstones copies
        self._gids_dev_cell = (_gids_dev_cell if _gids_dev_cell is not None
                               else [None])
        self._live_dev = None
        # per-OBJECT cache: predicate -> (n_match_live, device valid mask);
        # not shared across copies because it folds in THIS object's live
        self._filter_dev: dict = {}

    @property
    def n_rows(self) -> int:
        return self.gids.shape[0]

    @property
    def n_live(self) -> int:
        return self.n_rows - self.n_dead

    @property
    def rows(self) -> np.ndarray:
        return self.engine.db

    @property
    def gids_dev(self) -> jax.Array:
        if self._gids_dev_cell[0] is None:
            self._gids_dev_cell[0] = jnp.asarray(self.gids)
        return self._gids_dev_cell[0]

    @property
    def live_dev(self) -> jax.Array:
        if self._live_dev is None:
            self._live_dev = jnp.asarray(self.live)
        return self._live_dev

    def with_tombstones(self, rows: np.ndarray) -> "SealedSegment":
        """New segment object with ``rows`` (local indices) marked dead."""
        live = self.live.copy()
        live[rows] = False
        return SealedSegment(self.sid, self.engine, self.gids, live=live,
                             identity_gids=self.identity_gids,
                             meta=self.meta,
                             _gids_dev_cell=self._gids_dev_cell)

    def filter_valid(self, predicate, store) -> tuple[int, jax.Array | None]:
        """(live match count, device validity mask) for ``predicate``.

        The mask is ``match & live`` — the filter and the tombstones fused
        into ONE bitmap for the kernels' existing ``valid=`` path.  The
        host match bitmap caches on the (shared) MetaBlock; the combined
        device mask caches per segment object, so repeated filtered
        queries on an unmutated view upload nothing.
        """
        cached = self._filter_dev.get(predicate)
        if cached is None:
            combined = self.meta.match(predicate, store) & self.live
            n = int(np.count_nonzero(combined))
            cached = (n, jnp.asarray(combined) if n else None)
            self._filter_dev[predicate] = cached
        return cached

    def search(self, q: jax.Array, params: SearchParams,
               valid: jax.Array | None = None
               ) -> tuple[jax.Array, jax.Array]:
        """(dists, GLOBAL ids) over this segment's live rows.

        ``valid`` optionally overrides the validity mask (the filtered
        path passes its combined filter+tombstone bitmap); by default the
        tombstone bitmap applies when any row is dead.
        """
        if valid is None:
            valid = self.live_dev if self.n_dead else None
        d, li = self.engine.search(q, params, valid=valid)
        return d, _remap_gids(li, self.gids_dev)


class DeltaBuffer:
    """Growable host buffer of freshly added rows (the LSM memtable).

    Appends go to a capacity-doubling numpy buffer; rows are NEVER edited
    in place (an upsert appends a new row and tombstones the old), so any
    prefix of the buffer is immutable and can be shared with views.  The
    device copy is cached per (buffer, uploaded-count): a search after a
    burst of adds uploads once, later searches reuse it — the stacked
    buffer is invalidated by append/seal, not rebuilt per query.
    """

    def __init__(self, dim: int, meta_store=None):
        self.dim = dim
        cap = _DELTA_MIN_CAP
        self._rows = np.zeros((cap, dim), np.float32)
        self._gids = np.full(cap, -1, np.int32)
        self._live = np.zeros(cap, bool)
        # metadata columns grow in lockstep with the rows (codes, not raw
        # values — the Index encodes through its MetadataStore on add)
        self.meta_store = meta_store
        self._meta: dict[str, np.ndarray] | None = None
        if meta_store is not None:
            self._meta = {name: np.zeros(cap, meta_store.dtype(name))
                          for name in meta_store.columns}
        self.count = 0
        self.n_live = 0
        self._dev_lock = threading.Lock()
        self._dev_cache: tuple | None = None   # (buf_obj, count, rows, gids)

    def append(self, x: np.ndarray, gid: int,
               meta: dict[str, int] | None = None) -> int:
        if self.count == self._rows.shape[0]:
            self._rows = np.concatenate([self._rows,
                                         np.zeros_like(self._rows)])
            self._gids = np.concatenate([self._gids,
                                         np.full(self.count, -1, np.int32)])
            self._live = np.concatenate([self._live,
                                         np.zeros(self.count, bool)])
            if self._meta is not None:
                self._meta = {name: np.concatenate([col,
                                                    np.zeros_like(col)])
                              for name, col in self._meta.items()}
        row = self.count
        self._rows[row] = x
        self._gids[row] = gid
        if self._meta is not None:
            for name, col in self._meta.items():
                col[row] = meta[name]
        self._live[row] = True
        self.count = row + 1
        self.n_live += 1
        return row

    def kill(self, row: int) -> None:
        if self._live[row]:
            self._live[row] = False
            self.n_live -= 1

    def live_rows(self) -> tuple[np.ndarray, np.ndarray,
                                 dict[str, np.ndarray] | None]:
        """(rows (m, d), gids (m,), meta columns) of the live prefix —
        the seal payload (meta is None on metadata-less indexes)."""
        idx = np.flatnonzero(self._live[:self.count])
        meta = (None if self._meta is None
                else {name: col[idx].copy()
                      for name, col in self._meta.items()})
        return (np.ascontiguousarray(self._rows[idx]),
                self._gids[idx].copy(), meta)

    def view(self) -> "DeltaView | None":
        """Immutable snapshot of the current live prefix (None if empty)."""
        if self.n_live == 0:
            return None
        return DeltaView(self, self.count, self._live[:self.count].copy())

    def device_rows(self, min_count: int) -> tuple[jax.Array, jax.Array]:
        """Cached device copy of the buffer covering >= min_count rows."""
        with self._dev_lock:
            cache = self._dev_cache
            if (cache is not None and cache[0] is self._rows
                    and cache[1] >= min_count):
                return cache[2], cache[3]
            buf, count = self._rows, self.count
            rows_dev = jnp.asarray(buf)
            gids_dev = jnp.asarray(self._gids)
            self._dev_cache = (buf, count, rows_dev, gids_dev)
            return rows_dev, gids_dev


class DeltaView:
    """Frozen (buffer, count, liveness) triple — one snapshot of the delta."""

    __slots__ = ("_buffer", "count", "live", "_arrays", "_filter_cache")

    def __init__(self, buffer: DeltaBuffer, count: int, live: np.ndarray):
        self._buffer = buffer
        self.count = count
        self.live = live
        self._arrays = None
        self._filter_cache: dict = {}

    @property
    def n_live(self) -> int:
        return int(np.count_nonzero(self.live))

    def _device_arrays(self) -> tuple[jax.Array, jax.Array, jax.Array]:
        if self._arrays is None:
            rows_dev, gids_dev = self._buffer.device_rows(self.count)
            valid = np.zeros(rows_dev.shape[0], bool)
            valid[:self.count] = self.live
            self._arrays = (rows_dev, gids_dev, jnp.asarray(valid))
        return self._arrays

    def filter_valid(self, predicate, store) -> tuple[int, jax.Array | None]:
        """(live match count, device validity mask over the buffer rows).

        Delta rows are few and freshly written, so the predicate is
        evaluated directly over the buffer's column prefixes (per-view
        cached — a view is immutable; the next mutation publishes a new
        one).  The mask covers the buffer's full capacity like the default
        liveness mask, with the same brute-force scan consuming it.
        """
        cached = self._filter_cache.get(predicate)
        if cached is not None:
            return cached
        from repro.filter.metadata import MetaBlock
        buf = self._buffer
        block = MetaBlock({name: col[:self.count]
                           for name, col in buf._meta.items()})
        combined = block.match(predicate, store) & self.live
        n = int(np.count_nonzero(combined))
        dev = None
        if n:
            rows_dev, _ = buf.device_rows(self.count)
            valid = np.zeros(rows_dev.shape[0], bool)
            valid[:self.count] = combined
            dev = jnp.asarray(valid)
        self._filter_cache[predicate] = (n, dev)
        return n, dev

    def search(self, q: jax.Array, params: SearchParams,
               valid: jax.Array | None = None
               ) -> tuple[jax.Array, jax.Array]:
        """(dists, GLOBAL ids) over the live delta rows (brute force).

        ``valid`` optionally overrides the liveness mask (the filtered
        path passes its combined filter+liveness bitmap)."""
        rows_dev, gids_dev, live_valid = self._device_arrays()
        d, li = brute_force_topk(q, rows_dev, params,
                                 valid=live_valid if valid is None else valid)
        return d, _remap_gids(li, gids_dev)


class IndexView:
    """An immutable snapshot of the whole index: what ``search`` reads.

    ``Index`` republishes a fresh view after every mutation; readers pick
    it up with one attribute load and never touch the writer lock.  A view
    handed out via ``Index.snapshot()`` keeps answering from its frozen
    point-in-time state even while the live index mutates or compacts.
    """

    __slots__ = ("segments", "delta", "store")

    def __init__(self, segments: tuple[SealedSegment, ...],
                 delta: DeltaView | None, store=None):
        self.segments = segments
        self.delta = delta
        # the index's MetadataStore (schema + categorical vocab) — None on
        # metadata-less indexes; vocab growth is append-only, so a frozen
        # view may safely share the live store
        self.store = store

    @property
    def n_live(self) -> int:
        n = sum(s.n_live for s in self.segments)
        return n + (self.delta.n_live if self.delta is not None else 0)

    def live_points(self) -> tuple[np.ndarray, np.ndarray]:
        """Canonical (gids, rows) of the live point set, segment order.

        This is the ordering ``compact()`` rebuilds with, and the ordering
        the mutation tests use to build the "equivalent fresh index".
        """
        gids, rows = [], []
        for seg in self.segments:
            idx = np.flatnonzero(seg.live)
            gids.append(seg.gids[idx])
            rows.append(seg.rows[idx])
        if self.delta is not None:
            idx = np.flatnonzero(self.live_delta_mask())
            gids.append(self._buffer_gids()[idx])
            rows.append(self._buffer_rows()[idx])
        if not gids:
            return np.zeros(0, np.int32), np.zeros((0, 0), np.float32)
        return np.concatenate(gids), np.concatenate(rows)

    def filter_match_live(self, predicate) -> np.ndarray:
        """Host predicate-match bits over the live point set, in
        :meth:`live_points` row order.

        This is the sharded path's bitmap compiler (DESIGN.md §15): the
        sharded DB is laid out in exactly ``live_points()`` order, so
        these bits — padded to the even row split and ANDed with the
        pad-row mask — drop straight onto the row-sharded validity
        argument of the mesh query step.  Reuses the per-segment cached
        ``MetaBlock.match`` bitmaps the host-local filtered path warms.
        """
        if self.store is None:
            raise ValueError(
                "predicate given but this index carries no metadata — "
                "build with build_index(..., metadata={col: values}) to "
                "enable filtered search")
        parts = []
        for seg in self.segments:
            idx = np.flatnonzero(seg.live)
            if idx.size:
                parts.append(
                    np.asarray(seg.meta.match(predicate, self.store))[idx])
        if self.delta is not None:
            from repro.filter.metadata import MetaBlock
            buf = self.delta._buffer
            block = MetaBlock({name: col[:self.delta.count]
                               for name, col in buf._meta.items()})
            m = np.asarray(block.match(predicate, self.store))
            parts.append(m[np.flatnonzero(self.delta.live)])
        if not parts:
            return np.zeros(0, bool)
        return np.concatenate(parts)

    # small host-side accessors for live_points (delta internals)
    def live_delta_mask(self) -> np.ndarray:
        return self.delta.live

    def _buffer_gids(self) -> np.ndarray:
        return self.delta._buffer._gids[:self.delta.count]

    def _buffer_rows(self) -> np.ndarray:
        return self.delta._buffer._rows[:self.delta.count]

    def search(self, queries, params: SearchParams | None = None,
               **params_kw) -> tuple[jax.Array, jax.Array]:
        """queries (B, d) or (d,) -> (dists (B, k), ids (B, k)).

        Fans out over sealed segments + the delta overlay and merges with
        the associative top-k merge; tombstoned rows are masked inside the
        fused rerank (never surface, never occupy result slots).  Invalid
        slots: dist +inf, id -1.
        """
        params = params if params is not None else SearchParams(**params_kw)
        bad = params.capabilities("local")
        if bad:
            from repro.index.params import CapabilityError
            raise CapabilityError(bad, "local")
        q = jnp.asarray(np.atleast_2d(np.asarray(queries, np.float32)))
        if params.filter is not None:
            return self._search_filtered(q, params)
        segments = self.segments
        if (len(segments) == 1 and self.delta is None
                and segments[0].n_dead == 0 and segments[0].identity_gids):
            # pristine single-segment index: the exact pre-mutation path
            return segments[0].engine.search(q, params)
        parts = []
        for seg in segments:
            if seg.n_live == 0:
                continue
            parts.append(seg.search(q, params))
        if self.delta is not None:
            parts.append(self.delta.search(q, params))
        return self._merge(q, parts, params.k)

    def _merge(self, q, parts, k: int):
        if not parts:
            return (jnp.full((q.shape[0], k), jnp.inf, jnp.float32),
                    jnp.full((q.shape[0], k), -1, jnp.int32))
        if len(parts) == 1:
            return parts[0]
        cat_d = jnp.concatenate([p[0] for p in parts], axis=1)
        cat_i = jnp.concatenate([p[1] for p in parts], axis=1)
        return _merge_parts(cat_d, cat_i, k)

    def _search_filtered(self, q: jax.Array, params: SearchParams
                         ) -> tuple[jax.Array, jax.Array]:
        """Predicate-filtered fan-out (DESIGN.md §13).

        Per segment: compile the predicate into a match bitmap (cached),
        AND with the tombstones, and hand the combined mask to the exact
        ``valid=`` path the engines already serve.  The match counts give
        the filter's TRUE selectivity (the bitmap is exact, not an
        estimate); below the brute-force threshold the query exact-scans
        only the matching rows (the fused kernel issues no DMA for masked
        slots, so cost tracks the matches), otherwise the candidate budget
        is widened by ``repro.filter.predicate.widen_params`` so ~1/s
        fewer surviving candidates still fill k slots.
        """
        from repro.filter.predicate import use_brute_force, widen_params
        if self.store is None:
            from repro.index.params import CapabilityError, Violation
            raise CapabilityError([Violation(
                "filter", "local",
                "params.filter is set but this index carries no metadata",
                "build with build_index(..., metadata={col: values}) to "
                "enable filtered search")], "local")
        pred = params.filter
        seg_parts: list[tuple[SealedSegment, int, jax.Array]] = []
        n_match = 0
        for seg in self.segments:
            if seg.n_live == 0:
                continue
            cnt, vdev = seg.filter_valid(pred, self.store)
            if cnt:
                seg_parts.append((seg, cnt, vdev))
                n_match += cnt
        delta_cnt, delta_valid = 0, None
        if self.delta is not None:
            delta_cnt, delta_valid = self.delta.filter_valid(pred, self.store)
            n_match += delta_cnt
        if n_match == 0:
            return self._merge(q, [], params.k)
        selectivity = n_match / max(self.n_live, 1)
        brute = use_brute_force(selectivity, n_match)
        eff = params if brute else widen_params(params, selectivity)
        parts = []
        for seg, _, vdev in seg_parts:
            if brute:
                d, li = brute_force_topk(q, seg.engine.db_dev, params,
                                         valid=vdev)
                parts.append((d, _remap_gids(li, seg.gids_dev)))
            else:
                parts.append(seg.search(q, eff, valid=vdev))
        if delta_cnt:
            parts.append(self.delta.search(q, params, valid=delta_valid))
        return self._merge(q, parts, params.k)


@functools.partial(jax.jit, static_argnames=("k",))
def _merge_parts(cat_d: jax.Array, cat_i: jax.Array, k: int):
    return merge_topk_pairs(cat_d, cat_i, k)

"""Segmented mutable-index state: sealed segments, the delta buffer, views.

The mutation half of the unified Index API (DESIGN.md §8) is LSM-shaped:

  * ``SealedSegment`` — an immutable block of rows with a backend-built
    search state ("engine"), a global-id column, and a tombstone bitmap.
    Sealed segments are never edited in place: a delete produces a new
    ``SealedSegment`` object sharing the engine/rows/ids and carrying a
    copy-on-write ``live`` bitmap, so published views stay frozen.
  * ``DeltaBuffer`` — the one mutable piece: a small growable host buffer
    of freshly added rows, brute-force searched through the same fused
    rerank kernel as every sealed backend.  The stacked device copy is
    cached and re-uploaded only when new rows landed since the last search
    (never re-stacked per query).  Sealing a delta builds a fresh engine
    over its rows — for forest backends that is one batched cross-tree
    build (DESIGN.md §10), which is what keeps the seal path cheap.
  * ``IndexView`` — an immutable snapshot of (sealed segments, delta
    prefix, tombstones).  ``Index.search`` grabs the current view with a
    single attribute read — readers never take the writer lock — and
    ``Index.snapshot()`` hands the view out directly for repeatable reads.

Engines are duck-typed (see ``index/backends.py``): anything exposing
``search(q, params, valid=None) -> (dists, local_ids)`` plus the host
``db`` rows works.  All distance math — sealed, delta, and brute-force —
funnels through ``core.pipeline.rerank_fused``'s fused gather+distance+
top-k path, so a row's distance is bitwise-identical no matter which
segment it currently lives in (the property the mutation tests pin).
"""
from __future__ import annotations

import functools
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.search import merge_topk_pairs
from repro.index.params import SearchParams

# location tag for rows living in the (unsealed) delta buffer
DELTA_SID = -1

_DELTA_MIN_CAP = 64


@jax.jit
def _remap_gids(local_ids: jax.Array, gids_dev: jax.Array) -> jax.Array:
    """Segment-local result ids -> global ids (-1 slots pass through)."""
    safe = jnp.maximum(local_ids, 0)
    return jnp.where(local_ids >= 0, gids_dev[safe], -1)


def brute_force_topk(q: jax.Array, rows_dev: jax.Array, params: SearchParams,
                     valid: jax.Array | None = None
                     ) -> tuple[jax.Array, jax.Array]:
    """Exact scan via the fused rerank path: (B, k) dists + LOCAL row ids.

    Used by the bruteforce backend and the delta overlay.  Routing the scan
    through ``rerank_fused`` (ids = arange, mask = validity) keeps the
    distance arithmetic identical to every candidate-based backend, which
    is what makes mutated-index results bitwise-comparable to fresh builds.
    The id matrix is padded to >= k columns so the top-k is well-defined
    on segments smaller than k.
    """
    from repro.core.pipeline import rerank_fused
    b = q.shape[0]
    n = rows_dev.shape[0]
    m = max(n, params.k)
    ids = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None, :], (b, n))
    if valid is None:
        mask = jnp.ones((b, n), bool)
    else:
        mask = jnp.broadcast_to(valid[None, :], (b, n))
    if m > n:
        ids = jnp.pad(ids, ((0, 0), (0, m - n)), constant_values=-1)
        mask = jnp.pad(mask, ((0, 0), (0, m - n)))
    return rerank_fused(q, ids, mask, rows_dev, params.k,
                        metric=params.metric, mode=params.mode, dedup=False,
                        chunk=params.chunk)


class SealedSegment:
    """Immutable sealed segment: engine + global ids + tombstone bitmap.

    ``live`` is copy-on-write: ``with_tombstones`` returns a new segment
    sharing the engine/gids (and their cached device copies) with a fresh
    bitmap, so views published before a delete keep the old liveness.
    """

    __slots__ = ("sid", "engine", "gids", "live", "n_dead", "identity_gids",
                 "_gids_dev_cell", "_live_dev")

    def __init__(self, sid: int, engine, gids: np.ndarray,
                 live: np.ndarray | None = None,
                 identity_gids: bool | None = None,
                 _gids_dev_cell: list | None = None):
        self.sid = sid
        self.engine = engine
        self.gids = np.ascontiguousarray(np.asarray(gids, np.int32))
        if live is None:
            live = np.ones(self.gids.shape[0], bool)
        self.live = live
        self.n_dead = int(live.size - np.count_nonzero(live))
        if identity_gids is None:
            identity_gids = bool(np.array_equal(
                self.gids, np.arange(self.gids.shape[0], dtype=np.int32)))
        self.identity_gids = identity_gids
        # one-element cell shared across with_tombstones copies
        self._gids_dev_cell = (_gids_dev_cell if _gids_dev_cell is not None
                               else [None])
        self._live_dev = None

    @property
    def n_rows(self) -> int:
        return self.gids.shape[0]

    @property
    def n_live(self) -> int:
        return self.n_rows - self.n_dead

    @property
    def rows(self) -> np.ndarray:
        return self.engine.db

    @property
    def gids_dev(self) -> jax.Array:
        if self._gids_dev_cell[0] is None:
            self._gids_dev_cell[0] = jnp.asarray(self.gids)
        return self._gids_dev_cell[0]

    @property
    def live_dev(self) -> jax.Array:
        if self._live_dev is None:
            self._live_dev = jnp.asarray(self.live)
        return self._live_dev

    def with_tombstones(self, rows: np.ndarray) -> "SealedSegment":
        """New segment object with ``rows`` (local indices) marked dead."""
        live = self.live.copy()
        live[rows] = False
        return SealedSegment(self.sid, self.engine, self.gids, live=live,
                             identity_gids=self.identity_gids,
                             _gids_dev_cell=self._gids_dev_cell)

    def search(self, q: jax.Array, params: SearchParams
               ) -> tuple[jax.Array, jax.Array]:
        """(dists, GLOBAL ids) over this segment's live rows."""
        valid = self.live_dev if self.n_dead else None
        d, li = self.engine.search(q, params, valid=valid)
        return d, _remap_gids(li, self.gids_dev)


class DeltaBuffer:
    """Growable host buffer of freshly added rows (the LSM memtable).

    Appends go to a capacity-doubling numpy buffer; rows are NEVER edited
    in place (an upsert appends a new row and tombstones the old), so any
    prefix of the buffer is immutable and can be shared with views.  The
    device copy is cached per (buffer, uploaded-count): a search after a
    burst of adds uploads once, later searches reuse it — the stacked
    buffer is invalidated by append/seal, not rebuilt per query.
    """

    def __init__(self, dim: int):
        self.dim = dim
        cap = _DELTA_MIN_CAP
        self._rows = np.zeros((cap, dim), np.float32)
        self._gids = np.full(cap, -1, np.int32)
        self._live = np.zeros(cap, bool)
        self.count = 0
        self.n_live = 0
        self._dev_lock = threading.Lock()
        self._dev_cache: tuple | None = None   # (buf_obj, count, rows, gids)

    def append(self, x: np.ndarray, gid: int) -> int:
        if self.count == self._rows.shape[0]:
            self._rows = np.concatenate([self._rows,
                                         np.zeros_like(self._rows)])
            self._gids = np.concatenate([self._gids,
                                         np.full(self.count, -1, np.int32)])
            self._live = np.concatenate([self._live,
                                         np.zeros(self.count, bool)])
        row = self.count
        self._rows[row] = x
        self._gids[row] = gid
        self._live[row] = True
        self.count = row + 1
        self.n_live += 1
        return row

    def kill(self, row: int) -> None:
        if self._live[row]:
            self._live[row] = False
            self.n_live -= 1

    def live_rows(self) -> tuple[np.ndarray, np.ndarray]:
        """(rows (m, d), gids (m,)) of the live prefix — the seal payload."""
        idx = np.flatnonzero(self._live[:self.count])
        return (np.ascontiguousarray(self._rows[idx]),
                self._gids[idx].copy())

    def view(self) -> "DeltaView | None":
        """Immutable snapshot of the current live prefix (None if empty)."""
        if self.n_live == 0:
            return None
        return DeltaView(self, self.count, self._live[:self.count].copy())

    def device_rows(self, min_count: int) -> tuple[jax.Array, jax.Array]:
        """Cached device copy of the buffer covering >= min_count rows."""
        with self._dev_lock:
            cache = self._dev_cache
            if (cache is not None and cache[0] is self._rows
                    and cache[1] >= min_count):
                return cache[2], cache[3]
            buf, count = self._rows, self.count
            rows_dev = jnp.asarray(buf)
            gids_dev = jnp.asarray(self._gids)
            self._dev_cache = (buf, count, rows_dev, gids_dev)
            return rows_dev, gids_dev


class DeltaView:
    """Frozen (buffer, count, liveness) triple — one snapshot of the delta."""

    __slots__ = ("_buffer", "count", "live", "_arrays")

    def __init__(self, buffer: DeltaBuffer, count: int, live: np.ndarray):
        self._buffer = buffer
        self.count = count
        self.live = live
        self._arrays = None

    @property
    def n_live(self) -> int:
        return int(np.count_nonzero(self.live))

    def _device_arrays(self) -> tuple[jax.Array, jax.Array, jax.Array]:
        if self._arrays is None:
            rows_dev, gids_dev = self._buffer.device_rows(self.count)
            valid = np.zeros(rows_dev.shape[0], bool)
            valid[:self.count] = self.live
            self._arrays = (rows_dev, gids_dev, jnp.asarray(valid))
        return self._arrays

    def search(self, q: jax.Array, params: SearchParams
               ) -> tuple[jax.Array, jax.Array]:
        """(dists, GLOBAL ids) over the live delta rows (brute force)."""
        rows_dev, gids_dev, valid = self._device_arrays()
        d, li = brute_force_topk(q, rows_dev, params, valid=valid)
        return d, _remap_gids(li, gids_dev)


class IndexView:
    """An immutable snapshot of the whole index: what ``search`` reads.

    ``Index`` republishes a fresh view after every mutation; readers pick
    it up with one attribute load and never touch the writer lock.  A view
    handed out via ``Index.snapshot()`` keeps answering from its frozen
    point-in-time state even while the live index mutates or compacts.
    """

    __slots__ = ("segments", "delta")

    def __init__(self, segments: tuple[SealedSegment, ...],
                 delta: DeltaView | None):
        self.segments = segments
        self.delta = delta

    @property
    def n_live(self) -> int:
        n = sum(s.n_live for s in self.segments)
        return n + (self.delta.n_live if self.delta is not None else 0)

    def live_points(self) -> tuple[np.ndarray, np.ndarray]:
        """Canonical (gids, rows) of the live point set, segment order.

        This is the ordering ``compact()`` rebuilds with, and the ordering
        the mutation tests use to build the "equivalent fresh index".
        """
        gids, rows = [], []
        for seg in self.segments:
            idx = np.flatnonzero(seg.live)
            gids.append(seg.gids[idx])
            rows.append(seg.rows[idx])
        if self.delta is not None:
            idx = np.flatnonzero(self.live_delta_mask())
            gids.append(self._buffer_gids()[idx])
            rows.append(self._buffer_rows()[idx])
        if not gids:
            return np.zeros(0, np.int32), np.zeros((0, 0), np.float32)
        return np.concatenate(gids), np.concatenate(rows)

    # small host-side accessors for live_points (delta internals)
    def live_delta_mask(self) -> np.ndarray:
        return self.delta.live

    def _buffer_gids(self) -> np.ndarray:
        return self.delta._buffer._gids[:self.delta.count]

    def _buffer_rows(self) -> np.ndarray:
        return self.delta._buffer._rows[:self.delta.count]

    def search(self, queries, params: SearchParams | None = None,
               **params_kw) -> tuple[jax.Array, jax.Array]:
        """queries (B, d) or (d,) -> (dists (B, k), ids (B, k)).

        Fans out over sealed segments + the delta overlay and merges with
        the associative top-k merge; tombstoned rows are masked inside the
        fused rerank (never surface, never occupy result slots).  Invalid
        slots: dist +inf, id -1.
        """
        params = params if params is not None else SearchParams(**params_kw)
        q = jnp.asarray(np.atleast_2d(np.asarray(queries, np.float32)))
        segments = self.segments
        if (len(segments) == 1 and self.delta is None
                and segments[0].n_dead == 0 and segments[0].identity_gids):
            # pristine single-segment index: the exact pre-mutation path
            return segments[0].engine.search(q, params)
        parts = []
        for seg in segments:
            if seg.n_live == 0:
                continue
            parts.append(seg.search(q, params))
        if self.delta is not None:
            parts.append(self.delta.search(q, params))
        if not parts:
            b = q.shape[0]
            return (jnp.full((b, params.k), jnp.inf, jnp.float32),
                    jnp.full((b, params.k), -1, jnp.int32))
        if len(parts) == 1:
            return parts[0]
        cat_d = jnp.concatenate([p[0] for p in parts], axis=1)
        cat_i = jnp.concatenate([p[1] for p in parts], axis=1)
        return _merge_parts(cat_d, cat_i, params.k)


@functools.partial(jax.jit, static_argnames=("k",))
def _merge_parts(cat_d: jax.Array, cat_i: jax.Array, k: int):
    return merge_topk_pairs(cat_d, cat_i, k)

"""Unified Index API: one composable search surface over every backend.

The paper's pitch is ONE indexer with tunable accuracy/cost knobs; this
module is that surface (DESIGN.md §5).  An ``IndexSpec`` describes how an
index is built, ``SearchParams`` describes one query's knobs, and every
registered backend (rpf, rpf+int8, lsh-cascade, bruteforce) answers the same
``search(queries, params)`` call — all candidate-based backends rerank
through the fused single-pass pipeline (``core.pipeline``).

Lifecycle (DESIGN.md §8 — segmented, LSM-style):
  * ``build_index(key, db, spec)``   — registry-dispatched constructor,
  * ``index.search(queries, params)``— (dists (B, k), ids (B, k)); reads a
    published immutable ``IndexView`` — NO writer lock on the read path,
  * ``index.add(x)`` / ``index.upsert(id, x)`` / ``index.delete(ids)`` —
    paper §5 incremental updates: adds land in a small delta buffer
    (immediately queryable), the delta is sealed into an immutable segment
    once it outgrows ``spec.delta_cap``, and deletes/upserts tombstone the
    old row via a per-segment validity bitmap that the fused rerank masks,
  * ``index.snapshot()``            — the current ``IndexView``: a frozen,
    independently searchable point-in-time state (copy-on-write; later
    mutations never leak into it),
  * ``index.compact(block=...)``    — rebuild the live point set into one
    fresh segment.  The rebuild runs OFF the writer lock (readers and
    writers proceed concurrently) and the segment list is swapped in
    atomically, folding in any deletes that raced the rebuild,
  * ``index.tuned_params``          — the recall-targeted operating point
    found by ``repro.index.tune`` (DESIGN.md §9); when set it becomes the
    default for ``search()`` calls that pass no params, and it rides the
    manifest so a loaded index remembers how it was tuned,
  * ``index.shard_params`` / ``index.serving_plan`` — the serving-runtime
    metadata (DESIGN.md §12): per-shard tuned operating points from
    ``tune_sharded`` and the capacity planner's traffic model + fleet plan
    (plain dict here — the index layer never imports the serve layer),
  * ``index.save(path)`` / ``load_index(path)`` — versioned multi-segment
    manifest (format 5: format 4's segment state + tuned/per-shard
    operating points + serving plan, plus the per-row metadata columns
    and their schema/vocab) via the elastic checkpointer; format-4/3/2/1
    checkpoints written by older code load through read shims,
  * ``build_index(..., metadata={col: values})`` — columnar per-row
    attributes (int/categorical/timestamp) enabling
    ``SearchParams.filter`` predicates (DESIGN.md §13): evaluated into
    per-segment bitmaps that ride the same fused-kernel validity path as
    tombstones, with selectivity-aware candidate widening.

Thread safety: mutations serialize on a per-index lock and publish a fresh
immutable view; searches read the latest view with a single attribute load
(the serving layer calls them from batcher threads while writers mutate).
"""
from __future__ import annotations

import json
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer, _flatten_with_names
from repro.filter.metadata import MetaBlock, MetadataStore
from repro.index.params import IndexSpec, SearchParams
from repro.index.segments import DELTA_SID, DeltaBuffer, IndexView, SealedSegment

_BACKENDS: dict[str, type["Index"]] = {}
_BUILTINS_LOADED = False


def register_backend(name: str):
    """Class decorator: register an Index subclass under ``name``."""

    def deco(cls: type["Index"]) -> type["Index"]:
        cls.backend = name
        _BACKENDS[name] = cls
        return cls

    return deco


def _ensure_backends_loaded() -> None:
    # flag, not `if not _BACKENDS`: a user-registered backend must not
    # suppress the built-in registrations
    global _BUILTINS_LOADED
    if not _BUILTINS_LOADED:
        _BUILTINS_LOADED = True
        import repro.index.backends  # noqa: F401  (registers on import)


def get_backend(name: str) -> type["Index"]:
    _ensure_backends_loaded()
    if name not in _BACKENDS:
        raise KeyError(f"unknown index backend {name!r}; "
                       f"registered: {sorted(_BACKENDS)}")
    return _BACKENDS[name]


def available_backends() -> list[str]:
    _ensure_backends_loaded()
    return sorted(_BACKENDS)


def build_index(key: jax.Array | None, db: np.ndarray,
                spec: IndexSpec | None = None, metadata: dict | None = None,
                meta_schema: dict | None = None, **spec_kw) -> "Index":
    """Build an index per ``spec`` (or ``IndexSpec(**spec_kw)``).

    ``key`` seeds the randomized builds (rpf forests); None falls back to
    ``jax.random.key(spec.seed)``.

    ``metadata`` (optional) attaches columnar per-row attributes — a dict
    of column name -> length-N values — enabling ``SearchParams.filter``
    predicates.  Column kinds (int/categorical/timestamp) are inferred
    from dtypes or pinned by ``meta_schema`` ({name: kind}); see
    ``repro.filter``.
    """
    spec = spec if spec is not None else IndexSpec(**spec_kw)
    return get_backend(spec.backend).build(key, db, spec, metadata=metadata,
                                           meta_schema=meta_schema)


def load_index(path: str) -> "Index":
    """Restore an index saved with ``Index.save`` (backend from manifest)."""
    manifest = _read_manifest(path)
    spec = IndexSpec.from_dict(manifest["extra"]["spec"])
    return get_backend(spec.backend)._load(path, spec, manifest)


def _ckpt_dir(path: str, step: int) -> str:
    return os.path.join(path, f"step_{step:010d}")


def _read_manifest(path: str) -> dict:
    step = Checkpointer(path).latest_step()
    if step is None:
        raise FileNotFoundError(f"no index checkpoint under {path}")
    with open(os.path.join(_ckpt_dir(path, step), "manifest.json")) as f:
        manifest = json.load(f)
    return manifest


class Index:
    """Base class: the segmented mutable lifecycle; backends plug in engines.

    Subclass contract (see index/backends.py):
      * ``engine_cls``            — the per-segment search engine: built as
        ``engine_cls(spec, key, rows)``, exposing
        ``search(q, params, valid=None) -> (dists, local_ids)``, host
        ``db`` rows, ``state_tree()`` / ``state_skeleton(spec)`` /
        ``from_state(spec, state)`` for checkpointing,
      * ``_v1_skeleton(spec)``    — pytree shape of the legacy single-
        segment checkpoint format (the format-1 read shim),
      * ``_extra_stats()``        — backend-specific ``stats()`` keys.
    """

    backend: str = ""
    engine_cls: type | None = None

    def __init__(self, key: jax.Array | None, db: np.ndarray,
                 spec: IndexSpec, metadata: dict | None = None,
                 meta_schema: dict | None = None):
        self.spec = spec
        self._lock = threading.Lock()
        if key is None:
            key = jax.random.key(spec.seed)
        self.key = key
        db = np.ascontiguousarray(np.asarray(db, np.float32))
        self._d = int(db.shape[1])
        meta_block = None
        meta_store = None
        if metadata is not None:
            meta_store, meta_block = MetadataStore.from_arrays(
                metadata, db.shape[0], schema=meta_schema)
        engine = self.engine_cls(spec, key, db)
        seg = SealedSegment(sid=0, engine=engine,
                            gids=np.arange(db.shape[0], dtype=np.int32),
                            meta=meta_block)
        self._init_runtime([seg], next_gid=db.shape[0], next_sid=1,
                           meta_store=meta_store)

    def _init_runtime(self, segments: list[SealedSegment], next_gid: int,
                      next_sid: int, meta_store: MetadataStore | None = None
                      ) -> None:
        """Shared tail of __init__ and the checkpoint loaders."""
        self._tuned_params: SearchParams | None = None
        self._shard_params: tuple[SearchParams, ...] | None = None
        self._serving_plan: dict | None = None
        # what the last tune() saw (sample queries + kwargs + live-row
        # count), session-local: compact() retunes from it when the live
        # set has drifted past the staleness threshold (DESIGN.md §14)
        self._tune_ctx: dict | None = None
        self._tuned_n_live = 0
        self._n_retunes = 0
        self._meta_store = meta_store
        self._segments = list(segments)
        self._delta = DeltaBuffer(self._d, meta_store=meta_store)
        self._next_gid = int(next_gid)
        self._next_sid = int(next_sid)
        self._compacting = False
        self._n_seals = 0
        self._n_compactions = 0
        self._n_deleted_total = 0
        # live-row directory: global id -> (segment sid | DELTA_SID, row)
        self._loc: dict[int, tuple[int, int]] = {}
        for seg in self._segments:
            rows = np.flatnonzero(seg.live)
            self._loc.update(zip(seg.gids[rows].tolist(),
                                 ((seg.sid, int(r)) for r in rows)))
        self._publish_locked()

    # ------------------------------------------------------------ lifecycle
    @classmethod
    def build(cls, key: jax.Array | None, db: np.ndarray, spec: IndexSpec,
              metadata: dict | None = None,
              meta_schema: dict | None = None) -> "Index":
        return cls(key, db, spec, metadata=metadata, meta_schema=meta_schema)

    def _publish_locked(self) -> None:
        """Swap in a fresh immutable view (caller holds the writer lock)."""
        self._view = IndexView(tuple(self._segments), self._delta.view(),
                               store=self._meta_store)

    def snapshot(self) -> IndexView:
        """The current immutable view: searchable, frozen, lock-free."""
        return self._view

    @property
    def n_rows(self) -> int:
        """Number of LIVE points (tombstoned rows excluded)."""
        return self._view.n_live

    @property
    def db(self) -> np.ndarray:
        """All sealed rows, segment order (compat; includes tombstoned rows
        still physically present until the next ``compact()``)."""
        segments = self._view.segments
        if len(segments) == 1:
            return segments[0].rows
        if not segments:
            return np.zeros((0, self._d), np.float32)
        return np.concatenate([s.rows for s in segments])

    def live_points(self) -> tuple[np.ndarray, np.ndarray]:
        """Canonical (gids, rows) of the live point set (segment order) —
        the ordering ``compact()`` rebuilds with."""
        return self._view.live_points()

    @property
    def _primary_engine(self):
        return self._view.segments[0].engine

    @property
    def meta_store(self) -> MetadataStore | None:
        """The metadata schema + categorical vocab (None = no metadata)."""
        return self._meta_store

    def stats(self) -> dict:
        """Consistent counter snapshot (taken under the writer lock)."""
        with self._lock:
            segments = list(self._segments)
            n_static = sum(s.n_rows for s in segments)
            n_dead = sum(s.n_dead for s in segments)
            n_delta = self._delta.n_live
            return {
                "backend": self.backend,
                "n_static": n_static,
                "n_overflow": n_delta,
                "n_delta": n_delta,
                "n_live": n_static - n_dead + n_delta,
                "n_tombstones": n_dead + (self._delta.count
                                          - self._delta.n_live),
                "n_deleted_total": self._n_deleted_total,
                "n_segments": len(segments),
                "n_seals": self._n_seals,
                "n_compactions": self._n_compactions,
                "n_retunes": self._n_retunes,
                "compaction_in_progress": self._compacting,
                "metadata_columns": (sorted(self._meta_store.columns)
                                     if self._meta_store is not None else []),
                **self._extra_stats(),
            }

    def _extra_stats(self) -> dict:
        return {}

    # --------------------------------------------------------------- search
    @property
    def tuned_params(self) -> SearchParams | None:
        """The tuned operating point (``repro.index.tune``), or None.

        When set, a bare ``search(queries)`` — no params, no kwargs — uses
        it instead of ``SearchParams()``; explicit params always win.
        Persisted in the manifest (format 3), so it survives save/load.
        """
        return self._tuned_params

    @tuned_params.setter
    def tuned_params(self, params: SearchParams | None) -> None:
        if params is not None and not isinstance(params, SearchParams):
            raise TypeError(f"tuned_params must be SearchParams or None, "
                            f"got {type(params).__name__}")
        self._tuned_params = params

    @property
    def shard_params(self) -> tuple[SearchParams, ...] | None:
        """Per-shard tuned operating points (``tune_sharded``), or None.

        One ``SearchParams`` per DB shard of the mesh partitioning the
        tuning measured on; the serving runtime projects them onto the
        sharded query path (``serve.runtime.uniform_shard_params`` for the
        SPMD hot loop).  Persisted in the manifest (format 4).
        """
        return self._shard_params

    @shard_params.setter
    def shard_params(self, params) -> None:
        if params is not None:
            params = tuple(params)
            if not params or not all(isinstance(p, SearchParams)
                                     for p in params):
                raise TypeError("shard_params must be a non-empty sequence "
                                "of SearchParams, or None")
        self._shard_params = params

    @property
    def serving_plan(self) -> dict | None:
        """Capacity-planner output riding the manifest (format 4): a plain
        ``{"plan": ..., "traffic_model": ...}`` dict (see
        ``repro.serve.planner`` for the typed views — the index layer
        stays below the serve layer and never imports it)."""
        return self._serving_plan

    @serving_plan.setter
    def serving_plan(self, plan: dict | None) -> None:
        if plan is not None and not isinstance(plan, dict):
            raise TypeError(f"serving_plan must be a JSON-ready dict or "
                            f"None, got {type(plan).__name__}")
        self._serving_plan = plan

    def search(self, queries: np.ndarray, params: SearchParams | None = None,
               **params_kw) -> tuple[jax.Array, jax.Array]:
        """queries (B, d) or (d,) -> (dists (B, k), ids (B, k)).

        ``params`` (or loose ``**params_kw``, e.g. ``search(q, k=5)``)
        selects the operating point; with neither, the index's persisted
        ``tuned_params`` apply when present, else ``SearchParams()``.

        Invalid slots: dist +inf, id -1.  Fans out over the sealed segments
        and the incremental-add delta, with tombstones masked inside the
        fused rerank; reads the published view — never the writer lock.
        """
        if params is None and not params_kw and self._tuned_params is not None:
            params = self._tuned_params
        return self._view.search(queries, params, **params_kw)

    # ------------------------------------------------------------ mutations
    def _encode_meta_locked(self, metadata: dict | None) -> dict | None:
        """Point metadata -> column codes (the add/upsert front door).

        Metadata-carrying indexes require every column on every add (the
        predicates are total); metadata on a metadata-less index is an
        error rather than a silent drop."""
        if self._meta_store is None:
            if metadata:
                raise ValueError("this index carries no metadata — build "
                                 "with build_index(..., metadata=...) first")
            return None
        return self._meta_store.encode_point(metadata)

    def add(self, x: np.ndarray, metadata: dict | None = None) -> int:
        """Paper §5 incremental update. Returns the new point's id.

        The point lands in the delta buffer (immediately queryable); once
        the delta outgrows the seal threshold it is sealed into an
        immutable segment with its own engine — no full rebuild (that is
        ``compact()``'s job, explicitly or in the background).
        ``metadata`` must cover the index's metadata schema exactly when
        one exists ({column: value}).
        """
        x = np.asarray(x, np.float32).reshape(-1)
        with self._lock:
            codes = self._encode_meta_locked(metadata)
            gid = self._next_gid
            self._next_gid += 1
            row = self._delta.append(x, gid, meta=codes)
            self._loc[gid] = (DELTA_SID, row)
            self._maybe_seal_locked()
            self._publish_locked()
            return gid

    def delete(self, ids) -> int:
        """Tombstone one id or an iterable of ids. Returns the count.

        Raises KeyError (before any mutation) if any id is unknown or
        already deleted; deleted rows stop appearing in search results
        immediately and are physically dropped at the next seal/compact.
        """
        id_list = [int(ids)] if np.isscalar(ids) else [int(g) for g in ids]
        with self._lock:
            locs, seen = [], set()
            for gid in id_list:
                loc = self._loc.get(gid)
                if loc is None or gid in seen:
                    raise KeyError(f"id {gid} is not a live point")
                seen.add(gid)
                locs.append(loc)
            # apply: one bitmap copy per touched segment, not per id
            by_sid: dict[int, list[int]] = {}
            for gid, (sid, row) in zip(id_list, locs):
                del self._loc[gid]
                by_sid.setdefault(sid, []).append(row)
            for sid, rows in by_sid.items():
                if sid == DELTA_SID:
                    for row in rows:
                        self._delta.kill(row)
                else:
                    i = self._segment_pos_locked(sid)
                    self._segments[i] = self._segments[i].with_tombstones(
                        np.asarray(rows))
            self._n_deleted_total += len(id_list)
            self._publish_locked()
        return len(id_list)

    def upsert(self, gid: int, x: np.ndarray,
               metadata: dict | None = None) -> int:
        """Insert-or-replace the vector for ``gid`` (id is preserved).

        The old row (if any) is tombstoned and the new vector appended to
        the delta under the same global id — searches see exactly one live
        row per id at all times.  On a metadata-carrying index the new
        row's ``metadata`` replaces the old row's (all columns required,
        like :meth:`add` — rows are immutable, attributes ride the row).
        """
        gid = int(gid)
        x = np.asarray(x, np.float32).reshape(-1)
        with self._lock:
            codes = self._encode_meta_locked(metadata)
            old = self._loc.get(gid)
            if old is not None:
                self._kill_locked(old)
            row = self._delta.append(x, gid, meta=codes)
            self._loc[gid] = (DELTA_SID, row)
            if gid >= self._next_gid:
                self._next_gid = gid + 1
            self._maybe_seal_locked()
            self._publish_locked()
        return gid

    def _segment_pos_locked(self, sid: int) -> int:
        for i, seg in enumerate(self._segments):
            if seg.sid == sid:
                return i
        raise AssertionError(f"directory references unknown segment {sid}")

    def _kill_locked(self, loc: tuple[int, int]) -> None:
        sid, row = loc
        if sid == DELTA_SID:
            self._delta.kill(row)
            return
        i = self._segment_pos_locked(sid)
        self._segments[i] = self._segments[i].with_tombstones(
            np.asarray([row]))

    # ----------------------------------------------------------- seal/flush
    def _seal_threshold(self) -> float:
        if self.spec.delta_cap > 0:
            return float(self.spec.delta_cap)
        n_static = sum(s.n_rows for s in self._segments)
        return max(1.0, self.spec.rebuild_frac * n_static)

    def _maybe_seal_locked(self) -> None:
        if self._delta.count >= self._seal_threshold():
            self._seal_delta_locked()

    def _seal_delta_locked(self) -> None:
        """Freeze the delta's live rows into a new immutable segment."""
        rows, gids, meta_cols = self._delta.live_rows()
        if rows.shape[0] == 0:
            self._delta = DeltaBuffer(self._d, meta_store=self._meta_store)
            return
        sid = self._next_sid
        # build the engine BEFORE retiring the delta: a failed build (OOM,
        # interrupt) must not lose the pending adds or corrupt the directory
        engine = self.engine_cls(self.spec, jax.random.fold_in(self.key, sid),
                                 rows)
        self._next_sid += 1
        self._delta = DeltaBuffer(self._d, meta_store=self._meta_store)
        meta = MetaBlock(meta_cols) if meta_cols is not None else None
        self._segments.append(SealedSegment(sid=sid, engine=engine,
                                            gids=gids, meta=meta))
        self._loc.update(zip(gids.tolist(),
                             ((sid, j) for j in range(gids.shape[0]))))
        self._n_seals += 1

    def flush(self) -> None:
        """Seal any pending delta rows into an immutable segment."""
        with self._lock:
            self._seal_delta_locked()
            self._publish_locked()

    # ------------------------------------------------------------ compaction
    def compact(self, block: bool = True):
        """Rebuild the live point set into one fresh segment.

        The expensive rebuild runs OFF the writer lock: concurrent
        searches keep reading the old view and concurrent mutations keep
        landing (deletes that race the rebuild are re-applied to the new
        segment at swap time; adds sealed during the rebuild survive as
        their own segments).  ``block=False`` runs the rebuild on a
        daemon thread and returns it; ``block=True`` returns a stats dict.

        The rebuild uses the index's original key over the live rows in
        canonical (segment) order, so a compacted index answers bitwise
        identically to a fresh ``build_index(key, live_rows, spec)``.
        The rebuild itself rides the batched cross-tree forest builder
        (DESIGN.md §10), so compaction cost scales like one fast build,
        not L tree builds.

        Tuner-aware: when the index was tuned and the live-row count has
        since drifted past the staleness threshold, the swap is followed
        by a retune from the recorded tuning context, so the compacted
        index never keeps serving a pre-churn operating point
        (:meth:`_maybe_retune`, counted in ``stats()['n_retunes']``).
        """
        with self._lock:
            if self._compacting:
                raise RuntimeError("compaction already in progress")
            self._compacting = True
            try:
                self._seal_delta_locked()
                snap = list(self._segments)
                parts = []
                for seg in snap:
                    live_idx = np.flatnonzero(seg.live)
                    parts.append((seg.sid, live_idx, seg.rows[live_idx],
                                  seg.gids[live_idx],
                                  seg.meta.take(live_idx)
                                  if seg.meta is not None else None))
                self._publish_locked()
            except BaseException:
                self._compacting = False
                raise

        def _rebuild() -> dict:
            try:
                sources = [(sid, int(r)) for sid, live_idx, _, _, _ in parts
                           for r in live_idx]
                gids = (np.concatenate([p[3] for p in parts])
                        if parts else np.zeros(0, np.int32))
                rows = (np.concatenate([p[2] for p in parts])
                        if parts else np.zeros((0, self._d), np.float32))
                meta = (MetaBlock.concat([p[4] for p in parts])
                        if self._meta_store is not None else None)
                engine = (self.engine_cls(self.spec, self.key, rows)
                          if rows.shape[0] else None)
                with self._lock:
                    snap_sids = {seg.sid for seg in snap}
                    newer = [s for s in self._segments
                             if s.sid not in snap_sids]
                    if engine is not None:
                        # fold in deletes/upserts that raced the rebuild:
                        # a source row is still live iff the directory
                        # still points at its pre-compaction location
                        live = np.fromiter(
                            (self._loc.get(int(g)) == src
                             for g, src in zip(gids, sources)),
                            bool, count=gids.shape[0])
                        sid = self._next_sid
                        self._next_sid += 1
                        seg = SealedSegment(sid=sid, engine=engine,
                                            gids=gids, live=live, meta=meta)
                        for j, (g, alive) in enumerate(zip(gids.tolist(),
                                                           live)):
                            if alive:
                                self._loc[g] = (sid, j)
                        self._segments = [seg] + newer
                    else:
                        self._segments = newer
                    self._n_compactions += 1
                    self._publish_locked()
                    stats = {"n_rows": int(rows.shape[0]),
                             "n_segments_in": len(snap),
                             "n_segments_out": len(self._segments)}
            finally:
                self._compacting = False
            # retune (if stale) only after the swap is published and the
            # compaction flag dropped: the tuner searches the index, and a
            # concurrent compact() must not be blocked by it
            self._maybe_retune()
            return stats

        if block:
            return _rebuild()
        t = threading.Thread(target=_rebuild, daemon=True)
        t.start()
        return t

    # staleness threshold: retune when the live-row count has drifted by
    # more than this fraction since the operating point was tuned
    _RETUNE_STALENESS = 0.25

    def _maybe_retune(self) -> None:
        """Close the stale-tune gap: after compaction, refresh the tuned
        operating point when the live set no longer resembles the one the
        last ``tune()`` measured.

        A tuned probe budget is a statement about a specific corpus; heavy
        churn (deletes halving the index, bulk adds doubling it) silently
        invalidates it, and before this hook ``compact()`` kept serving the
        pre-churn ``tuned_params``.  Requires a recorded tuning context
        (``tune()`` ran in this session — the context is session-local, it
        does not ride the manifest); retunes with the same sample queries
        and kwargs, so the refreshed point answers the same recall target.
        """
        ctx, tuned_n = self._tune_ctx, self._tuned_n_live
        if ctx is None or tuned_n <= 0:
            return
        if abs(self.n_rows - tuned_n) / tuned_n < self._RETUNE_STALENESS:
            return
        from repro.index.tune import tune_report   # deferred: avoids a cycle
        tune_report(self, ctx["queries"], **ctx["kwargs"])
        self._n_retunes += 1

    # -------------------------------------------------------------- save/load
    def save(self, path: str) -> str:
        """Checkpoint the index under ``path`` (multi-segment manifest v5).

        Pending delta rows are sealed first (cheap — a per-delta engine
        build, NOT a full rebuild), then every segment's engine state,
        global-id column, tombstone bitmap and metadata columns land
        through the elastic checkpointer, along with the tuned operating
        point (``tuned_params``), the per-shard operating points
        (``shard_params``), the capacity plan (``serving_plan``) and the
        metadata schema + categorical vocab (``meta_schema``) when set.
        A save→load roundtrip is bitwise: the restored index answers
        every query — filtered or not — identically to the saved one,
        with the same default params — and a serving runtime stood up on
        it resolves the same fleet plan.
        """
        with self._lock:
            self._seal_delta_locked()
            self._publish_locked()
            tree: dict = {"key_data": jax.random.key_data(self.key),
                          "segments": {}}
            seg_meta = []
            for i, seg in enumerate(self._segments):
                seg_tree = {
                    "engine": seg.engine.state_tree(),
                    "gids": seg.gids,
                    "live": seg.live,
                }
                if self._meta_store is not None:
                    seg_tree["meta"] = dict(seg.meta.cols)
                tree["segments"][f"{i:03d}"] = seg_tree
                seg_meta.append({"sid": seg.sid, "n_rows": seg.n_rows})
            ckpt = Checkpointer(path, keep=1)
            return ckpt.save(0, tree,
                             extra={"spec": self.spec.to_dict(),
                                    "backend": self.backend,
                                    "format": 5,
                                    "dim": self._d,
                                    "segments": seg_meta,
                                    "next_gid": self._next_gid,
                                    "next_sid": self._next_sid,
                                    "tuned_params": (
                                        self._tuned_params.to_dict()
                                        if self._tuned_params is not None
                                        else None),
                                    "shard_params": (
                                        [p.to_dict()
                                         for p in self._shard_params]
                                        if self._shard_params is not None
                                        else None),
                                    "serving_plan": self._serving_plan,
                                    "meta_schema": (
                                        self._meta_store.to_json()
                                        if self._meta_store is not None
                                        else None)})

    @classmethod
    def load(cls, path: str) -> "Index":
        manifest = _read_manifest(path)
        return cls._load(path, IndexSpec.from_dict(manifest["extra"]["spec"]),
                         manifest)

    @classmethod
    def _restore_tree(cls, path: str, manifest: dict, skeleton) -> dict:
        """Restore a checkpoint into the SHAPE of ``skeleton`` (leaf values
        ignored; shapes/dtypes come from the manifest)."""
        shapes = {leaf["name"]: (leaf["shape"], leaf["dtype"])
                  for leaf in manifest["leaves"]}
        named = _flatten_with_names(skeleton)
        leaves = []
        for name, _ in named:
            shape, dtype = shapes[name]
            leaves.append(np.zeros(shape, dtype))
        template = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(skeleton), leaves)
        state, _ = Checkpointer(path).restore(template,
                                              step=manifest["step"])
        return state

    @classmethod
    def _load(cls, path: str, spec: IndexSpec, manifest: dict) -> "Index":
        if manifest["extra"].get("format", 1) >= 2:
            return cls._load_v2(path, spec, manifest)
        return cls._load_v1(path, spec, manifest)

    @classmethod
    def _load_v2(cls, path: str, spec: IndexSpec, manifest: dict) -> "Index":
        """Loader for segmented manifests (formats 2 through 5).

        Each format only ADDS optional extras on top of format 2's segment
        state — format 3 the tuned operating point, format 4 the per-shard
        params and serving plan, format 5 the metadata schema + per-segment
        metadata columns — so the older-format read shims are this same
        path with the newer extras absent (None).
        """
        extra = manifest["extra"]
        n_seg = len(extra["segments"])
        meta_schema = extra.get("meta_schema")
        store = (MetadataStore.from_json(meta_schema)
                 if meta_schema is not None else None)
        # meta leaves exist on disk only when the writer carried a store;
        # keying the skeleton off meta_schema (not the leaf list) means a
        # v4-and-earlier manifest — or a v5 one with the schema stripped —
        # skips them, and surplus on-disk leaves are simply ignored.
        meta_cols = sorted(store.columns) if store is not None else []
        skeleton = {"key_data": 0,
                    "segments": {f"{i:03d}": {
                        "engine": cls.engine_cls.state_skeleton(spec),
                        "gids": 0, "live": 0,
                        **({"meta": {c: 0 for c in meta_cols}}
                           if store is not None else {})}
                        for i in range(n_seg)}}
        state = cls._restore_tree(path, manifest, skeleton)
        obj = cls.__new__(cls)
        obj.spec = spec
        obj._lock = threading.Lock()
        obj.key = jax.random.wrap_key_data(
            jnp.asarray(state["key_data"], jnp.uint32))
        obj._d = int(extra["dim"])
        segments = []
        for i, meta in enumerate(extra["segments"]):
            st = state["segments"][f"{i:03d}"]
            seg_meta = None
            if store is not None:
                seg_meta = MetaBlock({c: np.asarray(st["meta"][c],
                                                    store.dtype(c))
                                      for c in meta_cols})
            segments.append(SealedSegment(
                sid=int(meta["sid"]),
                engine=cls.engine_cls.from_state(spec, st["engine"]),
                gids=np.asarray(st["gids"], np.int32),
                live=np.asarray(st["live"], bool),
                meta=seg_meta))
        obj._init_runtime(segments, next_gid=extra["next_gid"],
                          next_sid=extra["next_sid"], meta_store=store)
        tuned = extra.get("tuned_params")
        if tuned is not None:
            obj._tuned_params = SearchParams.from_dict(tuned)
        shard = extra.get("shard_params")
        if shard:
            obj._shard_params = tuple(SearchParams.from_dict(p)
                                      for p in shard)
        obj._serving_plan = extra.get("serving_plan") or None
        return obj

    @classmethod
    def _load_v1(cls, path: str, spec: IndexSpec, manifest: dict) -> "Index":
        """Read shim for the legacy single-segment checkpoint format."""
        state = cls._restore_tree(path, manifest, cls._v1_skeleton(spec))
        obj = cls.__new__(cls)
        obj.spec = spec
        obj._lock = threading.Lock()
        obj.key = jax.random.wrap_key_data(
            jnp.asarray(state["key_data"], jnp.uint32))
        engine = cls.engine_cls.from_state(spec, state)
        obj._d = int(engine.db.shape[1])
        n = engine.db.shape[0]
        seg = SealedSegment(sid=0, engine=engine,
                            gids=np.arange(n, dtype=np.int32))
        obj._init_runtime([seg], next_gid=n, next_sid=1)
        return obj

    # ------------------------------------------------------ subclass hooks
    @classmethod
    def _v1_skeleton(cls, spec: IndexSpec) -> dict:
        raise NotImplementedError

"""Unified Index API: one composable search surface over every backend.

The paper's pitch is ONE indexer with tunable accuracy/cost knobs; this
module is that surface (DESIGN.md §5).  An ``IndexSpec`` describes how an
index is built, ``SearchParams`` describes one query's knobs, and every
registered backend (rpf, rpf+int8, lsh-cascade, bruteforce) answers the same
``search(queries, params)`` call — all candidate-based backends rerank
through the fused single-pass pipeline (``core.pipeline``).

Lifecycle (the ``Index`` protocol):
  * ``build_index(key, db, spec)``   — registry-dispatched constructor,
  * ``index.search(queries, params)``— (dists (B, k), ids (B, k)),
  * ``index.add(x)``                 — paper §5 incremental update: the point
    is queryable immediately (brute-force overflow merge) and folded into a
    rebuilt index once the overflow exceeds ``spec.rebuild_frac`` of the DB,
  * ``index.save(path)`` / ``load_index(path)`` — via the elastic
    checkpointer (checkpoint/checkpointer.py): the device state tree lands
    as one .npy per leaf + a manifest carrying the spec.

Thread safety: search/add/save serialize on a per-index lock (the serving
layer calls them from batcher threads).
"""
from __future__ import annotations

import json
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer, _flatten_with_names
from repro.core.search import merge_topk_pairs
from repro.index.params import IndexSpec, SearchParams

_BACKENDS: dict[str, type["Index"]] = {}
_BUILTINS_LOADED = False


def register_backend(name: str):
    """Class decorator: register an Index subclass under ``name``."""

    def deco(cls: type["Index"]) -> type["Index"]:
        cls.backend = name
        _BACKENDS[name] = cls
        return cls

    return deco


def _ensure_backends_loaded() -> None:
    # flag, not `if not _BACKENDS`: a user-registered backend must not
    # suppress the built-in registrations
    global _BUILTINS_LOADED
    if not _BUILTINS_LOADED:
        _BUILTINS_LOADED = True
        import repro.index.backends  # noqa: F401  (registers on import)


def get_backend(name: str) -> type["Index"]:
    _ensure_backends_loaded()
    if name not in _BACKENDS:
        raise KeyError(f"unknown index backend {name!r}; "
                       f"registered: {sorted(_BACKENDS)}")
    return _BACKENDS[name]


def available_backends() -> list[str]:
    _ensure_backends_loaded()
    return sorted(_BACKENDS)


def build_index(key: jax.Array | None, db: np.ndarray,
                spec: IndexSpec | None = None, **spec_kw) -> "Index":
    """Build an index per ``spec`` (or ``IndexSpec(**spec_kw)``).

    ``key`` seeds the randomized builds (rpf forests); None falls back to
    ``jax.random.key(spec.seed)``.
    """
    spec = spec if spec is not None else IndexSpec(**spec_kw)
    return get_backend(spec.backend).build(key, db, spec)


def load_index(path: str) -> "Index":
    """Restore an index saved with ``Index.save`` (backend from manifest)."""
    manifest = _read_manifest(path)
    spec = IndexSpec.from_dict(manifest["extra"]["spec"])
    return get_backend(spec.backend)._load(path, spec, manifest)


def _ckpt_dir(path: str, step: int) -> str:
    return os.path.join(path, f"step_{step:010d}")


def _read_manifest(path: str) -> dict:
    step = Checkpointer(path).latest_step()
    if step is None:
        raise FileNotFoundError(f"no index checkpoint under {path}")
    with open(os.path.join(_ckpt_dir(path, step), "manifest.json")) as f:
        manifest = json.load(f)
    return manifest


class Index:
    """Base class: shared lifecycle; subclasses implement the static search.

    Subclass contract:
      * ``_build_state(db_dev)``       — build device/host search state,
      * ``_search_static(q, params)``  — top-k over the static DB only,
      * ``_state_skeleton()``          — pytree SHAPE of the saved state
        (leaf values ignored; structure + names must match ``_state_tree``),
      * ``_state_tree()``              — the pytree of arrays to checkpoint,
      * ``_restore_state(state)``      — inverse of ``_state_tree``.
    """

    backend: str = ""

    def __init__(self, key: jax.Array | None, db: np.ndarray,
                 spec: IndexSpec):
        self.spec = spec
        self._lock = threading.Lock()
        if key is None:
            key = jax.random.key(spec.seed)
        self.key = key
        self.db = np.ascontiguousarray(np.asarray(db, np.float32))
        self._overflow: list[np.ndarray] = []
        self._build_state(jnp.asarray(self.db))

    # ------------------------------------------------------------ lifecycle
    @classmethod
    def build(cls, key: jax.Array | None, db: np.ndarray,
              spec: IndexSpec) -> "Index":
        return cls(key, db, spec)

    @property
    def n_rows(self) -> int:
        return self.db.shape[0] + len(self._overflow)

    def stats(self) -> dict:
        return {"backend": self.backend, "n_static": int(self.db.shape[0]),
                "n_overflow": len(self._overflow)}

    # --------------------------------------------------------------- search
    def search(self, queries: np.ndarray, params: SearchParams | None = None,
               **params_kw) -> tuple[jax.Array, jax.Array]:
        """queries (B, d) or (d,) -> (dists (B, k), ids (B, k)).

        Invalid slots: dist +inf, id -1.  Probes the static index AND the
        incremental-add overflow; pass ``params`` or SearchParams kwargs.
        """
        params = params if params is not None else SearchParams(**params_kw)
        q = jnp.asarray(np.atleast_2d(np.asarray(queries, np.float32)))
        with self._lock:
            d, i = self._search_static(q, params)
            if self._overflow:
                d, i = self._merge_overflow(q, d, i, params)
        return d, i

    def _merge_overflow(self, q: jax.Array, d: jax.Array, i: jax.Array,
                        params: SearchParams
                        ) -> tuple[jax.Array, jax.Array]:
        """Brute-force the (small) overflow buffer and top-k merge."""
        from repro.core.distances import PAIRWISE
        ox = jnp.asarray(np.stack(self._overflow))
        od = PAIRWISE[params.metric](q, ox)
        oi = self.db.shape[0] + jnp.arange(ox.shape[0])[None, :]
        cat_d = jnp.concatenate([d, od], axis=1)
        cat_i = jnp.concatenate([i, jnp.broadcast_to(oi, od.shape)], axis=1)
        return merge_topk_pairs(cat_d, cat_i, params.k)

    # ------------------------------------------------------------------ add
    def add(self, x: np.ndarray) -> int:
        """Paper §5 incremental update. Returns the new point's id."""
        with self._lock:
            self._overflow.append(np.asarray(x, np.float32).reshape(-1))
            new_id = self.db.shape[0] + len(self._overflow) - 1
            if len(self._overflow) >= max(
                    1, self.spec.rebuild_frac * self.db.shape[0]):
                self._fold_overflow()
            return new_id

    def _fold_overflow(self) -> None:
        """Rebuild the static state over db + overflow (caller holds lock)."""
        if not self._overflow:
            return
        self.db = np.concatenate([self.db] + [o[None] for o in self._overflow])
        self._overflow = []
        self._build_state(jnp.asarray(self.db))

    # -------------------------------------------------------------- save/load
    def save(self, path: str) -> str:
        """Checkpoint the index under ``path`` (folds pending adds first, so
        the saved state is the compacted static index)."""
        with self._lock:
            self._fold_overflow()
            ckpt = Checkpointer(path, keep=1)
            return ckpt.save(0, self._state_tree(),
                             extra={"spec": self.spec.to_dict(),
                                    "backend": self.backend})

    @classmethod
    def load(cls, path: str) -> "Index":
        manifest = _read_manifest(path)
        return cls._load(path, IndexSpec.from_dict(manifest["extra"]["spec"]),
                         manifest)

    @classmethod
    def _load(cls, path: str, spec: IndexSpec, manifest: dict) -> "Index":
        shapes = {leaf["name"]: (leaf["shape"], leaf["dtype"])
                  for leaf in manifest["leaves"]}
        skeleton = cls._state_skeleton(spec)
        named = _flatten_with_names(skeleton)
        leaves = []
        for name, _ in named:
            shape, dtype = shapes[name]
            leaves.append(np.zeros(shape, dtype))
        template = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(skeleton), leaves)
        state, _ = Checkpointer(path).restore(template,
                                             step=manifest["step"])
        obj = cls.__new__(cls)
        obj.spec = spec
        obj._lock = threading.Lock()
        obj._overflow = []
        obj._restore_state(state)
        return obj

    # ------------------------------------------------------ subclass hooks
    def _build_state(self, db_dev: jax.Array) -> None:
        raise NotImplementedError

    def _search_static(self, q: jax.Array, params: SearchParams
                       ) -> tuple[jax.Array, jax.Array]:
        raise NotImplementedError

    def _state_tree(self) -> dict:
        raise NotImplementedError

    @classmethod
    def _state_skeleton(cls, spec: IndexSpec) -> dict:
        raise NotImplementedError

    def _restore_state(self, state: dict) -> None:
        raise NotImplementedError

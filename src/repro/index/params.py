"""IndexSpec / SearchParams — the two value types of the unified index API.

Kept dependency-light (only ForestConfig) so any layer — core, serving,
benchmarks, the sharded runtime — can import them without cycles.  Both are
frozen (hashable), so SearchParams can ride through jit static arguments.

See DESIGN.md §5 for the full spec/params tables and the backend registry.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.core.distances import METRIC_ALIASES, METRICS
from repro.core.forest import ForestConfig


@dataclasses.dataclass(frozen=True)
class SearchParams:
    """Every query-time knob, composable with every backend.

    k              neighbors returned
    metric         l2 | chi2 | cosine | ip (alias of dot) — the scoring
                   metric, threaded through every backend's coarse and
                   exact stage (DESIGN.md §13); aliases canonicalize at
                   construction, unknown names are reported by
                   :meth:`violations` (checked on every search path)
    mode           kernel dispatch: auto (Pallas on TPU) | pallas | ref
    dedup          mask duplicate candidate ids before rerank
    expand         int8 shortlist width multiplier (quantized backends):
                   coarse stage keeps expand*k candidates for fp32 rerank
    adaptive_wave  >0 queries the forest in waves of this many trees with
                   early exit (rpf backends); 0 = single full-forest pass
    tol            early-exit threshold: stop when the mean k-th distance
                   improves by less than this relative fraction per wave
    chunk          candidate-axis streaming width (0 = budget-derived)
    min_candidates lsh-cascade: probe radii until this many candidates
    n_probes       rpf backends: leaves visited per tree (DESIGN.md §9) —
                   1 is the paper's single descent (bitwise-identical to
                   the pre-multi-probe path); >1 adds the smallest-margin
                   alternate branches, trading one tree's memory for many
                   trees' recall
    probe_schedule rpf backends: >0 schedules probes PER QUERY up to this
                   cap (DESIGN.md §14) — every query starts at one probe
                   and is re-descended at doubling widths while its k-th
                   distance still improves by more than ``tol`` per round;
                   ``n_probes`` is ignored on that path (the schedule owns
                   the probe axis).  0 = the fixed budget above.  Does not
                   compose with ``adaptive_wave`` (both consume the same
                   convergence signal — :meth:`violations` rejects the
                   pair) and is host-scheduled, so the sharded path
                   rejects it (``sharded_violations``)
    n_trees        rpf backends: query only the first ``n_trees`` trees of
                   the built forest (0 = all).  Any prefix of the forest
                   is itself a valid smaller forest (the trees are
                   independent), so this is the search-time half of the
                   probes-vs-trees tradeoff the tuner walks
    filter         optional ``repro.filter`` predicate AST: only rows
                   matching it can surface, enforced through the same
                   validity-bitmap path as tombstones (DESIGN.md §13).
                   Requires a metadata-carrying index; rejected on the
                   sharded path (``sharded_violations``)

    Typically hand-written for exploration and produced by
    ``repro.index.tune`` for operation: the tuner returns the cheapest
    SearchParams meeting a recall target and persists it in the index
    manifest, so a loaded index remembers its tuned operating point.
    """

    k: int = 10
    metric: str = "l2"
    mode: str = "auto"
    dedup: bool = True
    expand: int = 4
    adaptive_wave: int = 0
    tol: float = 0.01
    chunk: int = 0
    min_candidates: int = 1
    n_probes: int = 1
    n_trees: int = 0
    probe_schedule: int = 0
    filter: Any = None

    def __post_init__(self):
        if self.mode not in ("auto", "pallas", "ref"):
            raise ValueError(f"mode must be auto|pallas|ref, got {self.mode!r}")
        if self.k < 1:
            raise ValueError(f"k must be >= 1, got {self.k}")
        if self.n_probes < 1:
            raise ValueError(f"n_probes must be >= 1, got {self.n_probes}")
        if self.n_trees < 0:
            raise ValueError(f"n_trees must be >= 0, got {self.n_trees}")
        if self.probe_schedule < 0:
            raise ValueError(f"probe_schedule must be >= 0, got "
                             f"{self.probe_schedule}")
        # alias-resolve the metric ("ip" -> "dot"); unknown names survive
        # construction and are reported by violations() — every search
        # path checks it, so they fail with a capability message, not a
        # kernel KeyError
        object.__setattr__(self, "metric",
                           METRIC_ALIASES.get(self.metric, self.metric))

    def violations(self) -> list[str]:
        """Capability violations of this operating point (empty = servable).

        THE one definition of "can this params be served": ``Index.search``
        / ``IndexView.search``, the sharded path (via
        :meth:`sharded_violations`) and ``ServingRuntime`` all consult it,
        so accept and reject can never drift between surfaces
        (previously each path had its own ad-hoc checks or none).
        """
        bad = []
        if self.metric not in METRICS:
            known = sorted(set(METRICS) | set(METRIC_ALIASES))
            bad.append(f"metric={self.metric!r} (known: {known})")
        if self.probe_schedule and self.adaptive_wave:
            # both knobs consume the same k-th-distance convergence signal
            # (per query across probe rounds vs batch-mean across tree
            # waves); composing them would double-count it
            bad.append(f"probe_schedule={self.probe_schedule} with "
                       f"adaptive_wave={self.adaptive_wave} (pick one "
                       f"convergence-gated axis)")
        if self.filter is not None:
            from repro.filter.predicate import Predicate
            if not isinstance(self.filter, Predicate):
                bad.append(f"filter must be a repro.filter Predicate, got "
                           f"{type(self.filter).__name__}")
        return bad

    def sharded_violations(self) -> list[str]:
        """Knobs of this params that the sharded query path cannot honor
        (a superset of :meth:`violations` — sharded serving adds limits).

        ``core.sharded_index.make_query_fn`` serves only the per-cell knobs
        (k/metric/dedup/mode/chunk/n_probes): adaptive waves, the per-query
        probe schedule and the lsh cascade don't compose with the cell-local
        rerank + tiny top-k merge (the first two are host-side convergence
        loops with data-dependent round counts), trees are a build-time
        shard property (a search-time ``n_trees`` restriction is
        meaningless there), and metadata filters need the host-side bitmap
        compiler, which the SPMD hot loop has no seam for.
        ``make_query_fn`` REJECTS such params; this lists what it would
        reject (empty = the params are sharded-legal), and :meth:`sharded`
        strips exactly the same set — one definition, so accept and reject
        can never drift.
        """
        bad = self.violations()
        if self.adaptive_wave:
            bad.append(f"adaptive_wave={self.adaptive_wave}")
        if self.min_candidates != 1:
            bad.append(f"min_candidates={self.min_candidates}")
        if self.n_trees:
            bad.append(f"n_trees={self.n_trees}")
        if self.probe_schedule:
            # the active-set shrink is host-scheduled (data-dependent round
            # count); the SPMD hot loop traces one fixed program
            bad.append(f"probe_schedule={self.probe_schedule}")
        if self.filter is not None:
            bad.append("filter=<predicate> (filtered search is host-local)")
        return bad

    def sharded(self) -> "SearchParams":
        """This operating point restricted to the sharded-legal knobs.

        Neutralizes exactly the knobs :meth:`sharded_violations` names
        (``adaptive_wave=0``, ``min_candidates=1``, ``n_trees=0``,
        ``probe_schedule=0``, ``filter=None``); the result always passes
        ``make_query_fn``'s params check.  The serving runtime uses this to project a
        host-tuned operating point onto the mesh instead of crashing on
        it — and counts the downgrade.
        """
        return dataclasses.replace(self, adaptive_wave=0, min_candidates=1,
                                   n_trees=0, probe_schedule=0, filter=None)

    def to_dict(self) -> dict[str, Any]:
        """JSON-ready dict (the manifest-v3 ``tuned_params`` payload);
        a predicate filter serializes through its tagged AST form."""
        d = dataclasses.asdict(self)
        if self.filter is not None:
            d["filter"] = self.filter.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "SearchParams":
        """Inverse of :meth:`to_dict`; unknown keys are ignored so params
        saved by a newer writer still load (forward compatibility)."""
        known = {f.name for f in dataclasses.fields(cls)}
        d = {k: v for k, v in d.items() if k in known}
        if d.get("filter") is not None:
            from repro.filter.predicate import from_dict as pred_from_dict
            d["filter"] = pred_from_dict(d["filter"])
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class IndexSpec:
    """Build-time description of an index: backend + build config.

    backend        registry key: rpf | rpf+int8 | lsh-cascade | bruteforce
    forest         ForestConfig for the rpf backends (trees/capacity/ratio)
    lsh_radii      cascade radii (increasing) for lsh-cascade
    lsh_tables     tables per cascade level (L)
    lsh_bits       concatenated hashes per table (K)
    lsh_width_scale  bucket width = width_scale * radius
    tree_chunk     >0 builds forest trees in lax.map chunks of this size
                   (bounds peak build memory for very large L)
    seed           fallback build seed when no PRNG key is supplied
    delta_cap      seal the mutable delta buffer into an immutable sealed
                   segment once it holds this many rows (0 = derive from
                   rebuild_frac * static rows, the legacy trigger)
    rebuild_frac   DEPRECATED spelling of the seal trigger: when delta_cap
                   is 0, the delta seals at rebuild_frac * static rows.
                   Adds no longer trigger a synchronous full rebuild —
                   that is ``Index.compact()``'s job (DESIGN.md §8).
    """

    backend: str = "rpf"
    forest: ForestConfig = ForestConfig()
    lsh_radii: tuple[float, ...] = (0.4, 0.53, 0.63, 0.88)
    lsh_tables: int = 10
    lsh_bits: int = 12
    lsh_width_scale: float = 1.0
    tree_chunk: int = 0
    seed: int = 0
    delta_cap: int = 0
    rebuild_frac: float = 0.1

    def to_dict(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d["forest"] = dict(self.forest._asdict())
        d["lsh_radii"] = list(self.lsh_radii)
        return d

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "IndexSpec":
        d = dict(d)
        d["forest"] = ForestConfig(**d.get("forest", {}))
        d["lsh_radii"] = tuple(d.get("lsh_radii", ()))
        return cls(**d)

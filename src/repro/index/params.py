"""IndexSpec / SearchParams — the two value types of the unified index API.

Kept dependency-light (only ForestConfig) so any layer — core, serving,
benchmarks, the sharded runtime — can import them without cycles.  Both are
frozen (hashable), so SearchParams can ride through jit static arguments.

See DESIGN.md §5 for the full spec/params tables and the backend registry.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.core.forest import ForestConfig


@dataclasses.dataclass(frozen=True)
class SearchParams:
    """Every query-time knob, composable with every backend.

    k              neighbors returned
    metric         l2 | dot | chi2 | cosine (exact-rerank metric)
    mode           kernel dispatch: auto (Pallas on TPU) | pallas | ref
    dedup          mask duplicate candidate ids before rerank
    expand         int8 shortlist width multiplier (quantized backends):
                   coarse stage keeps expand*k candidates for fp32 rerank
    adaptive_wave  >0 queries the forest in waves of this many trees with
                   early exit (rpf backends); 0 = single full-forest pass
    tol            early-exit threshold: stop when the mean k-th distance
                   improves by less than this relative fraction per wave
    chunk          candidate-axis streaming width (0 = budget-derived)
    min_candidates lsh-cascade: probe radii until this many candidates
    """

    k: int = 10
    metric: str = "l2"
    mode: str = "auto"
    dedup: bool = True
    expand: int = 4
    adaptive_wave: int = 0
    tol: float = 0.01
    chunk: int = 0
    min_candidates: int = 1

    def __post_init__(self):
        if self.mode not in ("auto", "pallas", "ref"):
            raise ValueError(f"mode must be auto|pallas|ref, got {self.mode!r}")
        if self.k < 1:
            raise ValueError(f"k must be >= 1, got {self.k}")


@dataclasses.dataclass(frozen=True)
class IndexSpec:
    """Build-time description of an index: backend + build config.

    backend        registry key: rpf | rpf+int8 | lsh-cascade | bruteforce
    forest         ForestConfig for the rpf backends (trees/capacity/ratio)
    lsh_radii      cascade radii (increasing) for lsh-cascade
    lsh_tables     tables per cascade level (L)
    lsh_bits       concatenated hashes per table (K)
    lsh_width_scale  bucket width = width_scale * radius
    tree_chunk     >0 builds forest trees in lax.map chunks of this size
                   (bounds peak build memory for very large L)
    seed           fallback build seed when no PRNG key is supplied
    delta_cap      seal the mutable delta buffer into an immutable sealed
                   segment once it holds this many rows (0 = derive from
                   rebuild_frac * static rows, the legacy trigger)
    rebuild_frac   DEPRECATED spelling of the seal trigger: when delta_cap
                   is 0, the delta seals at rebuild_frac * static rows.
                   Adds no longer trigger a synchronous full rebuild —
                   that is ``Index.compact()``'s job (DESIGN.md §8).
    """

    backend: str = "rpf"
    forest: ForestConfig = ForestConfig()
    lsh_radii: tuple[float, ...] = (0.4, 0.53, 0.63, 0.88)
    lsh_tables: int = 10
    lsh_bits: int = 12
    lsh_width_scale: float = 1.0
    tree_chunk: int = 0
    seed: int = 0
    delta_cap: int = 0
    rebuild_frac: float = 0.1

    def to_dict(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d["forest"] = dict(self.forest._asdict())
        d["lsh_radii"] = list(self.lsh_radii)
        return d

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "IndexSpec":
        d = dict(d)
        d["forest"] = ForestConfig(**d.get("forest", {}))
        d["lsh_radii"] = tuple(d.get("lsh_radii", ()))
        return cls(**d)

"""IndexSpec / SearchParams — the two value types of the unified index API.

Kept dependency-light (only ForestConfig) so any layer — core, serving,
benchmarks, the sharded runtime — can import them without cycles.  Both are
frozen (hashable), so SearchParams can ride through jit static arguments.

See DESIGN.md §5 for the full spec/params tables and the backend registry.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.core.distances import METRIC_ALIASES, METRICS
from repro.core.forest import ForestConfig

#: The capability contexts a SearchParams can be checked against.
#: ``local``   — ``Index.search`` / ``IndexView.search`` on one host.
#: ``sharded`` — ``repro.core.sharded_index.ShardedIndex.search`` over a
#:               device mesh (host-driven: filters and probe schedules ARE
#:               served there; only the raw SPMD step builder
#:               ``make_query_fn`` still rejects them).
#: ``serving`` — ``ServingRuntime``'s batched path (host-local runtime;
#:               a mesh runtime composes ``serving`` + ``sharded``).
CONTEXTS = ("local", "sharded", "serving")


@dataclasses.dataclass(frozen=True)
class Violation:
    """One capability the given context cannot honor for a params.

    ``str(v)`` renders the legacy message format, so code (and tests)
    that matched substrings of ``violations()`` strings keeps working;
    structured callers read ``knob``/``context``/``hint`` instead.
    """

    knob: str       # the SearchParams field (or index property) at fault
    context: str    # which CONTEXTS entry rejected it
    message: str    # human text, normally starting "knob=value (...)"
    hint: str = ""  # what to do instead, if anything

    def __str__(self) -> str:
        return self.message + (f" — {self.hint}" if self.hint else "")


class CapabilityError(ValueError):
    """A params asked for capabilities its context cannot honor.

    Subclasses ValueError so every pre-existing ``except ValueError`` /
    ``pytest.raises(ValueError)`` around the search paths still catches
    it; carries the structured entries in ``.violations``.
    """

    def __init__(self, violations, context: str = "local",
                 prefix: str = "params cannot be served"):
        self.violations = tuple(violations)
        self.context = context
        super().__init__(
            f"{prefix} [{context}]: "
            + "; ".join(str(v) for v in self.violations))


@dataclasses.dataclass(frozen=True)
class SearchParams:
    """Every query-time knob, composable with every backend.

    k              neighbors returned
    metric         l2 | chi2 | cosine | ip (alias of dot) — the scoring
                   metric, threaded through every backend's coarse and
                   exact stage (DESIGN.md §13); aliases canonicalize at
                   construction, unknown names are reported by
                   :meth:`violations` (checked on every search path)
    mode           kernel dispatch: auto (Pallas on TPU) | pallas | ref
    dedup          mask duplicate candidate ids before rerank
    expand         int8 shortlist width multiplier (quantized backends):
                   coarse stage keeps expand*k candidates for fp32 rerank
    adaptive_wave  >0 queries the forest in waves of this many trees with
                   early exit (rpf backends); 0 = single full-forest pass
    tol            early-exit threshold: stop when the mean k-th distance
                   improves by less than this relative fraction per wave
    chunk          candidate-axis streaming width (0 = budget-derived)
    min_candidates lsh-cascade: probe radii until this many candidates
    n_probes       rpf backends: leaves visited per tree (DESIGN.md §9) —
                   1 is the paper's single descent (bitwise-identical to
                   the pre-multi-probe path); >1 adds the smallest-margin
                   alternate branches, trading one tree's memory for many
                   trees' recall
    probe_schedule rpf backends: >0 schedules probes PER QUERY up to this
                   cap (DESIGN.md §14) — every query starts at one probe
                   and is re-descended at doubling widths while its k-th
                   distance still improves by more than ``tol`` per round;
                   ``n_probes`` is ignored on that path (the schedule owns
                   the probe axis).  0 = the fixed budget above.  Does not
                   compose with ``adaptive_wave`` (both consume the same
                   convergence signal — :meth:`capabilities` rejects the
                   pair).  Host-scheduled, so the one-fixed-program
                   ``make_query_fn`` rejects it, but ``ShardedIndex``
                   serves it on a mesh (host rounds over per-width steps)
    n_trees        rpf backends: query only the first ``n_trees`` trees of
                   the built forest (0 = all).  Any prefix of the forest
                   is itself a valid smaller forest (the trees are
                   independent), so this is the search-time half of the
                   probes-vs-trees tradeoff the tuner walks
    filter         optional ``repro.filter`` predicate AST: only rows
                   matching it can surface, enforced through the same
                   validity-bitmap path as tombstones (DESIGN.md §13).
                   Requires a metadata-carrying index.  Served on the
                   sharded path too (DESIGN.md §15): ``ShardedIndex``
                   compiles the bitmap host-side in ``live_points`` order
                   and ANDs it onto the row-sharded validity argument

    Typically hand-written for exploration and produced by
    ``repro.index.tune`` for operation: the tuner returns the cheapest
    SearchParams meeting a recall target and persists it in the index
    manifest, so a loaded index remembers its tuned operating point.
    """

    k: int = 10
    metric: str = "l2"
    mode: str = "auto"
    dedup: bool = True
    expand: int = 4
    adaptive_wave: int = 0
    tol: float = 0.01
    chunk: int = 0
    min_candidates: int = 1
    n_probes: int = 1
    n_trees: int = 0
    probe_schedule: int = 0
    filter: Any = None

    def __post_init__(self):
        if self.mode not in ("auto", "pallas", "ref"):
            raise ValueError(f"mode must be auto|pallas|ref, got {self.mode!r}")
        if self.k < 1:
            raise ValueError(f"k must be >= 1, got {self.k}")
        if self.n_probes < 1:
            raise ValueError(f"n_probes must be >= 1, got {self.n_probes}")
        if self.n_trees < 0:
            raise ValueError(f"n_trees must be >= 0, got {self.n_trees}")
        if self.probe_schedule < 0:
            raise ValueError(f"probe_schedule must be >= 0, got "
                             f"{self.probe_schedule}")
        # alias-resolve the metric ("ip" -> "dot"); unknown names survive
        # construction and are reported by violations() — every search
        # path checks it, so they fail with a capability message, not a
        # kernel KeyError
        object.__setattr__(self, "metric",
                           METRIC_ALIASES.get(self.metric, self.metric))

    def capabilities(self, context: str = "local") -> list[Violation]:
        """Capability violations of this operating point in ``context``
        (empty = servable there).

        THE one definition of "can this params be served where": every
        search surface — ``Index.search`` / ``IndexView.search``
        (``local``), ``ShardedIndex.search`` and the raw ``make_query_fn``
        step builder (``sharded``), and ``ServingRuntime`` (``serving``,
        composed with ``sharded`` on a mesh) — consults this matrix, so
        accept and reject can never drift between surfaces.  The legacy
        :meth:`violations` / :meth:`sharded_violations` are shims over it.

        ``local`` / ``serving``: unknown metrics, malformed filters, and
        the ``probe_schedule``×``adaptive_wave`` combination (both consume
        the same k-th-distance convergence signal) are rejected.

        ``sharded`` adds the knobs the per-cell rerank + tiny top-k merge
        cannot honor: ``adaptive_wave`` (host wave loop with a
        data-dependent round count), ``min_candidates != 1`` (the lsh
        cascade is not built sharded) and ``n_trees`` (trees are a
        build-time shard property).  ``probe_schedule`` and ``filter`` are
        sharded-LEGAL since the host-driven ``ShardedIndex`` schedules
        rounds and compiles predicate bitmaps onto the row-sharded
        validity argument; only the single fixed SPMD program that
        ``make_query_fn`` compiles still rejects them (it points at
        ``ShardedIndex.search``).
        """
        if context not in CONTEXTS:
            raise ValueError(f"context must be one of {CONTEXTS}, "
                             f"got {context!r}")
        bad: list[Violation] = []
        if self.metric not in METRICS:
            known = sorted(set(METRICS) | set(METRIC_ALIASES))
            bad.append(Violation(
                "metric", context,
                f"metric={self.metric!r} (known: {known})"))
        if self.probe_schedule and self.adaptive_wave:
            # both knobs consume the same k-th-distance convergence signal
            # (per query across probe rounds vs batch-mean across tree
            # waves); composing them would double-count it
            bad.append(Violation(
                "probe_schedule", context,
                f"probe_schedule={self.probe_schedule} with "
                f"adaptive_wave={self.adaptive_wave} (pick one "
                f"convergence-gated axis)"))
        if self.filter is not None:
            from repro.filter.predicate import Predicate
            if not isinstance(self.filter, Predicate):
                bad.append(Violation(
                    "filter", context,
                    f"filter must be a repro.filter Predicate, got "
                    f"{type(self.filter).__name__}"))
        if context == "sharded":
            if self.adaptive_wave:
                bad.append(Violation(
                    "adaptive_wave", context,
                    f"adaptive_wave={self.adaptive_wave} (host-side wave "
                    f"loop with a data-dependent round count)"))
            if self.min_candidates != 1:
                bad.append(Violation(
                    "min_candidates", context,
                    f"min_candidates={self.min_candidates} (the lsh "
                    f"cascade is not built sharded)"))
            if self.n_trees:
                bad.append(Violation(
                    "n_trees", context,
                    f"n_trees={self.n_trees} (trees are a build-time "
                    f"shard property)"))
        return bad

    def require(self, context: str = "local") -> "SearchParams":
        """Raise :class:`CapabilityError` unless servable in ``context``;
        returns self so it chains (``params.require("sharded")``)."""
        bad = self.capabilities(context)
        if bad:
            raise CapabilityError(bad, context)
        return self

    def violations(self) -> list[str]:
        """Deprecated shim: ``capabilities("local")`` rendered as the
        legacy message strings.  Prefer :meth:`capabilities`."""
        return [str(v) for v in self.capabilities("local")]

    def sharded_violations(self) -> list[str]:
        """Deprecated shim: ``capabilities("sharded")`` rendered as the
        legacy message strings.  Prefer :meth:`capabilities`.

        Note the matrix is narrower than the pre-matrix behavior:
        ``probe_schedule`` and ``filter`` are now sharded-legal (served by
        ``ShardedIndex``'s host driver), so they no longer appear here.
        """
        return [str(v) for v in self.capabilities("sharded")]

    def sharded(self) -> "SearchParams":
        """This operating point projected onto the sharded-legal knobs.

        Neutralizes exactly what ``capabilities("sharded")`` rejects
        (``adaptive_wave=0``, ``min_candidates=1``, ``n_trees=0``) and
        KEEPS ``probe_schedule`` and ``filter`` — ``ShardedIndex`` serves
        both, so projecting an operating point onto a mesh no longer
        silently drops a predicate (that used to be a correctness trap:
        unfiltered results for a filtered request).  The serving runtime
        uses this to project a host-tuned point onto the mesh — and counts
        any perf-knob downgrade.
        """
        return dataclasses.replace(self, adaptive_wave=0, min_candidates=1,
                                   n_trees=0)

    def to_dict(self) -> dict[str, Any]:
        """JSON-ready dict (the manifest-v3 ``tuned_params`` payload);
        a predicate filter serializes through its tagged AST form."""
        d = dataclasses.asdict(self)
        if self.filter is not None:
            d["filter"] = self.filter.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "SearchParams":
        """Inverse of :meth:`to_dict`; unknown keys are ignored so params
        saved by a newer writer still load (forward compatibility)."""
        known = {f.name for f in dataclasses.fields(cls)}
        d = {k: v for k, v in d.items() if k in known}
        if d.get("filter") is not None:
            from repro.filter.predicate import from_dict as pred_from_dict
            d["filter"] = pred_from_dict(d["filter"])
        return cls(**d)


# The README "Capability matrix" table is GENERATED from these rows
# (``python tools/capability_table.py --write``; CI runs ``--check``), so
# the docs can never drift from what :meth:`SearchParams.capabilities`
# actually accepts.  Columns: knob, per-context verdicts, notes.
CAPABILITY_MATRIX: tuple[dict[str, str], ...] = (
    {"knob": "`metric` (l2 / chi2 / cosine / ip)",
     "local": "yes", "sharded": "yes", "serving": "yes",
     "notes": "aliases canonicalize at construction; unknown names are a "
              "violation in every context"},
    {"knob": "`k` / `expand` / `chunk` / `mode` / `dedup`",
     "local": "yes", "sharded": "yes", "serving": "yes",
     "notes": "per-cell knobs: compiled straight into every query step"},
    {"knob": "`n_probes` (fixed multiprobe)",
     "local": "yes", "sharded": "yes", "serving": "yes",
     "notes": "descends each tree once per probe; sharded cells probe "
              "their local trees"},
    {"knob": "`probe_schedule` (per-query probes)",
     "local": "yes", "sharded": "yes — host-scheduled rounds over "
              "per-width mesh steps", "serving": "yes",
     "notes": "does not compose with `adaptive_wave` (same convergence "
              "signal); raw `make_query_fn` compiles one fixed program "
              "and points at `ShardedIndex.search`"},
    {"knob": "`filter` (metadata predicate)",
     "local": "yes", "sharded": "yes — host bitmap ANDed onto the "
              "row-sharded validity argument", "serving": "yes",
     "notes": "needs a metadata-carrying index (a structured "
              "`CapabilityError` names the entry otherwise); never "
              "silently stripped"},
    {"knob": "`adaptive_wave` (tree waves)",
     "local": "yes", "sharded": "no", "serving": "yes",
     "notes": "host wave loop with a data-dependent round count; "
              "`sharded()` neutralizes it"},
    {"knob": "`min_candidates` ≠ 1 (lsh cascade)",
     "local": "yes", "sharded": "no", "serving": "yes",
     "notes": "the lsh cascade is not built sharded; `sharded()` "
              "neutralizes it"},
    {"knob": "`n_trees` (forest prefix)",
     "local": "yes", "sharded": "no", "serving": "yes",
     "notes": "trees are a build-time shard property; `sharded()` "
              "neutralizes it"},
)


def capability_table_md() -> str:
    """Render :data:`CAPABILITY_MATRIX` as the README markdown table.

    ``serving`` describes the host-local ``ServingRuntime``; a mesh
    runtime composes the ``serving`` and ``sharded`` columns.
    """
    lines = [
        "| knob | local `Index.search` | sharded `ShardedIndex.search` | "
        "`ServingRuntime` | notes |",
        "|---|---|---|---|---|",
    ]
    for row in CAPABILITY_MATRIX:
        lines.append(f"| {row['knob']} | {row['local']} | {row['sharded']} "
                     f"| {row['serving']} | {row['notes']} |")
    return "\n".join(lines)


@dataclasses.dataclass(frozen=True)
class IndexSpec:
    """Build-time description of an index: backend + build config.

    backend        registry key: rpf | rpf+int8 | lsh-cascade | bruteforce
    forest         ForestConfig for the rpf backends (trees/capacity/ratio)
    lsh_radii      cascade radii (increasing) for lsh-cascade
    lsh_tables     tables per cascade level (L)
    lsh_bits       concatenated hashes per table (K)
    lsh_width_scale  bucket width = width_scale * radius
    tree_chunk     >0 builds forest trees in lax.map chunks of this size
                   (bounds peak build memory for very large L)
    seed           fallback build seed when no PRNG key is supplied
    delta_cap      seal the mutable delta buffer into an immutable sealed
                   segment once it holds this many rows (0 = derive from
                   rebuild_frac * static rows, the legacy trigger)
    rebuild_frac   DEPRECATED spelling of the seal trigger: when delta_cap
                   is 0, the delta seals at rebuild_frac * static rows.
                   Adds no longer trigger a synchronous full rebuild —
                   that is ``Index.compact()``'s job (DESIGN.md §8).
    """

    backend: str = "rpf"
    forest: ForestConfig = ForestConfig()
    lsh_radii: tuple[float, ...] = (0.4, 0.53, 0.63, 0.88)
    lsh_tables: int = 10
    lsh_bits: int = 12
    lsh_width_scale: float = 1.0
    tree_chunk: int = 0
    seed: int = 0
    delta_cap: int = 0
    rebuild_frac: float = 0.1

    def to_dict(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d["forest"] = dict(self.forest._asdict())
        d["lsh_radii"] = list(self.lsh_radii)
        return d

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "IndexSpec":
        d = dict(d)
        d["forest"] = ForestConfig(**d.get("forest", {}))
        d["lsh_radii"] = tuple(d.get("lsh_radii", ()))
        return cls(**d)

"""Unified Index / SearchParams API — the single public search surface.

    from repro.index import IndexSpec, SearchParams, build_index, tune

    index = build_index(jax.random.key(0), db,
                        IndexSpec(backend="rpf+int8",
                                  forest=ForestConfig(n_trees=80)))
    dists, ids = index.search(queries, SearchParams(k=10, n_probes=4))
    params = tune(index, sample_queries, target_recall=0.95)
    dists, ids = index.search(queries)    # tuned params now the default
    index.save("/tmp/idx");  index2 = load_index("/tmp/idx")

Backends (``available_backends()``): rpf, rpf+int8, lsh-cascade, bruteforce.
Every knob in ``SearchParams`` composes with every backend (knobs that do
not apply to a backend are inert); all candidate-based backends rerank
through the fused single-pass pipeline (DESIGN.md §4/§5).  Backend modules
import lazily on first ``build_index``/``get_backend`` call.

Recall/cost operating point (DESIGN.md §9): ``SearchParams.n_probes``
(leaves per tree) and ``SearchParams.n_trees`` (forest prefix queried) span
the probes-vs-trees frontier; :func:`tune` walks it against a brute-force
oracle and persists the cheapest params meeting a recall target on the
index (manifest format 3), so a loaded index remembers its tuned operating
point.  See docs/TUNING.md for the cookbook.

Mutation lifecycle (DESIGN.md §8): ``index.add(x)`` / ``index.delete(ids)``
/ ``index.upsert(id, x)`` mutate through an LSM-style segment model —
adds land in a delta buffer sealed into immutable segments, deletes are
tombstones masked inside the fused rerank.  ``index.snapshot()`` returns a
frozen, independently searchable ``IndexView`` (readers never take the
writer lock) and ``index.compact(block=False)`` rebuilds the live point
set in the background without stalling searches.
"""
from repro.index.api import (Index, available_backends, build_index,
                             get_backend, load_index, register_backend)
from repro.index.params import IndexSpec, SearchParams
from repro.index.segments import IndexView, SealedSegment
from repro.index.tune import tune, tune_report, tune_sharded

__all__ = [
    "Index", "IndexSpec", "IndexView", "SealedSegment", "SearchParams",
    "available_backends", "build_index", "get_backend", "load_index",
    "register_backend", "tune", "tune_report", "tune_sharded",
]

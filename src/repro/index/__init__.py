"""Unified Index / SearchParams API — the single public search surface.

    from repro.index import IndexSpec, SearchParams, build_index

    index = build_index(jax.random.key(0), db,
                        IndexSpec(backend="rpf+int8",
                                  forest=ForestConfig(n_trees=80)))
    dists, ids = index.search(queries, SearchParams(k=10, adaptive_wave=20))
    index.save("/tmp/idx");  index2 = load_index("/tmp/idx")

Backends (``available_backends()``): rpf, rpf+int8, lsh-cascade, bruteforce.
Every knob in SearchParams composes with every backend; all candidate-based
backends rerank through the fused single-pass pipeline (DESIGN.md §4/§5).
Backend modules import lazily on first ``build_index``/``get_backend`` call.

Mutation lifecycle (DESIGN.md §8): ``index.add(x)`` / ``index.delete(ids)``
/ ``index.upsert(id, x)`` mutate through an LSM-style segment model —
adds land in a delta buffer sealed into immutable segments, deletes are
tombstones masked inside the fused rerank.  ``index.snapshot()`` returns a
frozen, independently searchable ``IndexView`` (readers never take the
writer lock) and ``index.compact(block=False)`` rebuilds the live point
set in the background without stalling searches.
"""
from repro.index.api import (Index, available_backends, build_index,
                             get_backend, load_index, register_backend)
from repro.index.params import IndexSpec, SearchParams
from repro.index.segments import IndexView, SealedSegment

__all__ = [
    "Index", "IndexSpec", "IndexView", "SealedSegment", "SearchParams",
    "available_backends", "build_index", "get_backend", "load_index",
    "register_backend",
]

"""Recall-targeted auto-tuning: find the cheapest SearchParams for a target.

The paper trades recall for speed only by adding trees (L), so its sole
recall knob multiplies both build memory and query cost.  With multi-probe
traversal (DESIGN.md §9) the same recall is reachable along several axes —
probes per tree, trees queried, early-exit waves, int8 shortlist width —
and the cheapest combination is workload-dependent.  This module walks that
surface for the operator:

    from repro.index import build_index, tune

    index = build_index(key, db, spec)
    params = tune(index, sample_queries, target_recall=0.95)
    dists, ids = index.search(queries)      # tuned params now the default

``tune`` measures recall@k against a brute-force oracle over the index's
live rows, evaluates a small backend-specific grid in ascending-cost order,
and returns the cheapest ``SearchParams`` meeting the target.  The result
is persisted on the index (``index.tuned_params``) and rides the manifest
(format 3), so a saved-then-loaded index remembers its tuned operating
point without retuning.

Determinism: the grid, the oracle and every measured search are pure
functions of (index state, queries), so the same index key + queries always
yield the same SearchParams — pinned by ``tests/test_multiprobe.py``.

Cost model: expected fp32 candidate rows touched per query — the quantity
the fused rerank's HBM traffic is linear in (DESIGN.md §4).  For the rpf
backends that is ``trees_used * n_probes * leaf_pad`` (int8 backends pay a
quarter of it at the coarse stage plus ``expand * k`` exact rows); for
lsh-cascade it is the measured mean candidate count.  Adaptive entries are
charged for the trees they actually used on the sample; scheduled entries
(``probe_schedule`` — DESIGN.md §14) for the mean probes they actually
processed.
"""
from __future__ import annotations

from typing import Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.knn import exact_knn
from repro.index.params import SearchParams

__all__ = ["tune", "tune_report", "tune_sharded"]


def _recall(pred_ids: np.ndarray, true_ids: np.ndarray) -> float:
    """Order-insensitive recall@k of predicted vs oracle global ids."""
    hits = (pred_ids[:, :, None] == true_ids[:, None, :]).any(axis=1)
    return float(hits.mean())


def _tree_grid(n_trees: int, tree_fracs: Sequence[float]) -> list[int]:
    grid = sorted({max(1, int(round(n_trees * f))) for f in tree_fracs
                   if 0.0 < f <= 1.0} | {n_trees})
    return [t for t in grid if t <= n_trees]


def _candidate_grid(index, k: int, metric: str, mode: str,
                    probe_grid: Sequence[int], tree_fracs: Sequence[float],
                    adaptive_waves: Sequence[int],
                    expand_grid: Sequence[int],
                    schedule_grid: Sequence[int] = (0,)
                    ) -> list[SearchParams]:
    """Backend-specific search grid, deterministic order."""
    backend = getattr(index, "backend", "")
    base = dict(k=k, metric=metric, mode=mode)
    if backend == "bruteforce":
        return [SearchParams(**base)]
    if backend == "lsh-cascade":
        return [SearchParams(**base, min_candidates=mc)
                for mc in sorted({1, k, 4 * k, 16 * k})]
    # rpf / rpf+int8 (and any forest-shaped custom backend)
    total = index.spec.forest.n_trees
    trees = _tree_grid(total, tree_fracs)
    expands = sorted(set(expand_grid)) if backend == "rpf+int8" else [4]
    grid = []
    for t in trees:
        for p in sorted(set(probe_grid)):
            for w in sorted(set(adaptive_waves)):
                if w >= t:          # a wave covering the forest is a no-op
                    continue
                for e in expands:
                    # the full-forest point is spelled n_trees=0 ("all"),
                    # so a tuned SearchParams that restricts nothing stays
                    # valid on surfaces without a search-time tree knob
                    # (the sharded runtime rejects explicit n_trees)
                    grid.append(SearchParams(
                        **base, n_trees=0 if t == total else t,
                        n_probes=p, adaptive_wave=w, expand=e))
        # scheduled entries (DESIGN.md §14) ride the tree axis but own the
        # probe axis themselves (n_probes is inert under a schedule); the
        # default schedule_grid=(0,) adds nothing, keeping the historical
        # grid — and the determinism pin — unchanged
        for s in sorted(set(schedule_grid)):
            if s < 1:
                continue
            for e in expands:
                grid.append(SearchParams(
                    **base, n_trees=0 if t == total else t,
                    probe_schedule=s, expand=e))
    return grid


def _static_cost(index, params: SearchParams, k: int) -> float:
    """Upper-bound cost (fp32-row-equivalents/query) used for scan order."""
    backend = getattr(index, "backend", "")
    if backend == "bruteforce":
        return float(index.n_rows)
    if backend == "lsh-cascade":
        return float(params.min_candidates)
    cfg = index.spec.forest.resolved(max(index.n_rows, 2))
    trees = params.n_trees or index.spec.forest.n_trees
    if params.probe_schedule:
        # a never-converging query is re-descended at every width of the
        # doubling schedule, so the honest upper bound is their sum
        # (~2x the cap), not the cap itself
        from repro.core.schedule import probe_widths
        probes = sum(probe_widths(params.probe_schedule))
    else:
        probes = params.n_probes
    rows = trees * probes * cfg.leaf_pad
    if backend == "rpf+int8":
        return 0.25 * rows + params.expand * k
    return float(rows)


def _single_segment(index) -> bool:
    view = index.snapshot()
    return len(view.segments) == 1 and view.delta is None


def _measured_cost(index, params: SearchParams, k: int) -> float:
    """Like _static_cost but charging adaptive entries for the trees they
    actually used (``engine.last_trees_used``) and scheduled entries for
    the probes they actually processed (``engine.last_mean_probes``) on
    the sample queries.

    Both discounts apply only to single-segment indexes: the counters
    reflect the primary segment's engine, and on a mutated (multi-segment)
    index every segment converges independently, so the static upper bound
    is the honest charge there.
    """
    backend = getattr(index, "backend", "")
    if backend == "lsh-cascade":
        return float(getattr(index, "last_mean_candidates", 0.0)
                     or params.min_candidates)
    if backend in ("rpf", "rpf+int8") and params.adaptive_wave \
            and _single_segment(index):
        cfg = index.spec.forest.resolved(max(index.n_rows, 2))
        used = int(getattr(index, "last_trees_used",
                           params.n_trees or index.spec.forest.n_trees))
        rows = used * params.n_probes * cfg.leaf_pad
        if backend == "rpf+int8":
            return 0.25 * rows + params.expand * k
        return float(rows)
    if backend in ("rpf", "rpf+int8") and params.probe_schedule \
            and _single_segment(index):
        cfg = index.spec.forest.resolved(max(index.n_rows, 2))
        trees = params.n_trees or index.spec.forest.n_trees
        probes = float(getattr(index, "last_mean_probes", 0.0)) or \
            float(params.probe_schedule)
        rows = trees * probes * cfg.leaf_pad
        if backend == "rpf+int8":
            return 0.25 * rows + params.expand * k
        return float(rows)
    return _static_cost(index, params, k)


def tune_report(index, queries, target_recall: float = 0.95, k: int = 10,
                metric: str = "l2", mode: str = "auto",
                probe_grid: Iterable[int] = (1, 2, 4, 8),
                tree_fracs: Iterable[float] = (0.25, 0.5, 1.0),
                adaptive_waves: Iterable[int] = (0,),
                expand_grid: Iterable[int] = (2, 4),
                schedule_grid: Iterable[int] = (0,),
                persist: bool = True
                ) -> tuple[SearchParams, list[dict]]:
    """``tune`` returning ``(params, report)`` — one report row per grid
    point: ``{"params", "recall", "cost", "meets_target"}``, in the
    evaluated (ascending static-cost) order.  See :func:`tune`.
    """
    queries = jnp.asarray(np.atleast_2d(np.asarray(queries, np.float32)))
    gids, rows = index.live_points()
    if rows.shape[0] == 0:
        raise ValueError("cannot tune an empty index")
    k_oracle = min(k, rows.shape[0])
    # held-out brute-force oracle over the live rows, in GLOBAL ids
    _, pos = exact_knn(queries, jnp.asarray(rows), k=k_oracle, metric=metric)
    true_ids = np.asarray(gids)[np.asarray(pos)]

    grid = _candidate_grid(index, k, metric, mode, tuple(probe_grid),
                           tuple(tree_fracs), tuple(adaptive_waves),
                           tuple(expand_grid), tuple(schedule_grid))
    if not grid:
        raise ValueError(
            "tuner grid is empty — probe_grid/tree_fracs/adaptive_waves "
            f"prune every combination for backend "
            f"{getattr(index, 'backend', '?')!r} "
            f"(L={getattr(index.spec.forest, 'n_trees', '?')})")
    grid.sort(key=lambda p: (_static_cost(index, p, k), p.n_probes,
                             p.n_trees, p.expand, p.adaptive_wave,
                             p.probe_schedule, p.min_candidates))

    report: list[dict] = []
    best: tuple[float, SearchParams] | None = None       # (cost, params)
    fallback: tuple[float, float, SearchParams] | None = None
    for params in grid:
        if best is not None and _static_cost(index, params, k) >= best[0] \
                and not params.adaptive_wave and not params.probe_schedule:
            # static cost is an upper bound on measured cost only for
            # non-adaptive, non-scheduled entries; those can never beat
            # the incumbent
            continue
        _, ids = index.search(queries, params)
        rec = _recall(np.asarray(ids), true_ids)
        cost = _measured_cost(index, params, k)
        meets = rec >= target_recall
        report.append({"params": params, "recall": rec, "cost": cost,
                       "meets_target": meets})
        if meets and (best is None or cost < best[0]):
            best = (cost, params)
        if fallback is None or (-rec, cost) < (-fallback[0], fallback[1]):
            fallback = (rec, cost, params)
    chosen = best[1] if best is not None else fallback[2]
    if persist:
        index.tuned_params = chosen
        # remember what this tune saw, so compact() can detect a stale
        # operating point after heavy churn and retune with the same
        # arguments (DESIGN.md §14; session-local, not in the manifest)
        index._tune_ctx = {
            "queries": np.asarray(queries),
            "kwargs": dict(target_recall=target_recall, k=k, metric=metric,
                           mode=mode, probe_grid=tuple(probe_grid),
                           tree_fracs=tuple(tree_fracs),
                           adaptive_waves=tuple(adaptive_waves),
                           expand_grid=tuple(expand_grid),
                           schedule_grid=tuple(schedule_grid)),
        }
        index._tuned_n_live = index.n_rows
    return chosen, report


def tune(index, queries, target_recall: float = 0.95, k: int = 10,
         metric: str = "l2", mode: str = "auto",
         probe_grid: Iterable[int] = (1, 2, 4, 8),
         tree_fracs: Iterable[float] = (0.25, 0.5, 1.0),
         adaptive_waves: Iterable[int] = (0,),
         expand_grid: Iterable[int] = (2, 4),
         schedule_grid: Iterable[int] = (0,),
         persist: bool = True) -> SearchParams:
    """Find the cheapest ``SearchParams`` meeting ``target_recall``.

    Measures recall@``k`` of the index against a brute-force oracle over
    its live rows on ``queries`` (a representative sample, (B, d)), walking
    a small backend-specific grid in ascending cost order:

    * ``rpf`` / ``rpf+int8`` — ``n_trees`` x ``n_probes`` (the
      probes-vs-trees frontier of DESIGN.md §9), optionally early-exit
      waves (``adaptive_waves``, 0 = off), per-query probe schedules
      (``schedule_grid`` of caps, 0 = off — DESIGN.md §14, charged their
      measured mean probes processed) and, for the int8 backend, the
      shortlist width ``expand_grid``;
    * ``lsh-cascade`` — the cascade stop threshold ``min_candidates``;
    * ``bruteforce`` — nothing to tune (always exact).

    Returns the cheapest grid point whose measured recall clears the
    target; if none does, the highest-recall point (cheapest among ties).
    With ``persist=True`` (default) the result is stored as
    ``index.tuned_params`` — the default operating point for bare
    ``index.search(q)`` calls, persisted through ``save()``/``load_index``
    (manifest format 3).

    Deterministic: same index key + queries -> same SearchParams.
    """
    params, _ = tune_report(index, queries, target_recall=target_recall,
                            k=k, metric=metric, mode=mode,
                            probe_grid=probe_grid, tree_fracs=tree_fracs,
                            adaptive_waves=adaptive_waves,
                            expand_grid=expand_grid,
                            schedule_grid=schedule_grid, persist=persist)
    return params


# ---------------------------------------------------------------------------
# distributed tuning: measure on the mesh partitioning, not one host
# ---------------------------------------------------------------------------


def _shard_bounds(n: int, n_shards: int) -> list[tuple[int, int]]:
    """Row ranges of each DB shard — the same contiguous even split
    ``shard_map`` applies to a row-sharded array (last shard absorbs the
    pad remainder when ``n`` doesn't divide; build paths pad instead)."""
    n_local = n // n_shards
    return [(s * n_local, (s + 1) * n_local if s < n_shards - 1 else n)
            for s in range(n_shards)]


def tune_sharded(index, queries, n_shards: int, target_recall: float = 0.95,
                 k: int = 10, metric: str = "l2", mode: str = "auto",
                 probe_grid: Iterable[int] = (1, 2, 4, 8),
                 mesh=None, db_axes=("data",), tree_axis: str = "model",
                 persist: bool = True
                 ) -> tuple[list[SearchParams], list[dict]]:
    """Per-shard tuned operating points, measured on the mesh partitioning.

    Host ``tune()`` answers "what does THIS index need"; a sharded fleet
    asks a different question — each DB shard owns a slice of the corpus
    and contributes its local top-k to the global merge
    (``core.sharded_index``), so the budget each shard needs depends on
    *its* rows, not the global ones.  Global recall decomposes exactly over
    the partition: a true neighbor is found iff the shard that OWNS it
    surfaces it locally, so

        recall = sum_s |found_s ∩ owned_s| / |true neighbors|

    and per-shard tuning is well-posed: for shard ``s``, measure the
    owned-neighbor recall of its local search over the sharded-legal grid
    (``n_probes`` — see ``SearchParams.sharded_violations``) and keep the
    cheapest point clearing ``target_recall``.  A shard holding easy,
    well-clustered rows gets a small probe budget; a shard straddling
    cluster boundaries pays more — exactly the heterogeneity a one-host
    tune() cannot see.

    ``mesh`` (optional) additionally validates the merged result end to
    end: the per-shard points collapse to the uniform SPMD operating point
    (max over shards — ``serve.runtime.uniform_shard_params``) and the
    actual ``make_query_fn`` program must clear the target on the mesh;
    the measured merged recall lands in the report's final row.

    Returns ``(shard_params, report)``; ``persist=True`` stores the list
    as ``index.shard_params`` (manifest format 4) and, when the index has
    no host-tuned point yet, the uniform projection as ``tuned_params``.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    queries = jnp.asarray(np.atleast_2d(np.asarray(queries, np.float32)))
    gids, rows = index.live_points()
    if rows.shape[0] < n_shards:
        raise ValueError(f"cannot split {rows.shape[0]} live rows into "
                         f"{n_shards} shards")
    k_oracle = min(k, rows.shape[0])
    _, pos = exact_knn(queries, jnp.asarray(rows), k=k_oracle, metric=metric)
    pos = np.asarray(pos)                       # oracle in ROW positions
    n_true = pos.size

    from repro.index.api import build_index      # deferred: avoids a cycle
    bounds = _shard_bounds(rows.shape[0], n_shards)
    grid = sorted({int(p) for p in probe_grid if p >= 1})
    if not grid:
        raise ValueError("tuner grid is empty — probe_grid prunes "
                         "every sharded-legal combination")
    shard_params: list[SearchParams] = []
    report: list[dict] = []
    for s, (lo, hi) in enumerate(bounds):
        # the shard's own engine over ITS rows — same spec, shard-folded
        # key (matching build_sharded_index's per-shard stream derivation)
        sub = build_index(jax.random.fold_in(index.key, s), rows[lo:hi],
                          index.spec)
        owned = (pos >= lo) & (pos < hi)
        n_owned = int(owned.sum())
        chosen = None
        for p in grid:
            params = SearchParams(k=k, metric=metric, mode=mode,
                                  n_probes=p)
            _, ids = sub.search(queries, params)
            ids = np.asarray(ids)
            # local ids -> global row positions; owned-neighbor hit rate
            found = (pos[..., None] - lo ==
                     ids[:, None, :]).any(-1) & owned
            rec_owned = (float(found.sum()) / n_owned if n_owned
                         else 1.0)
            row = {"shard": s, "params": params, "recall_owned": rec_owned,
                   "n_owned": n_owned, "meets_target": rec_owned
                   >= target_recall}
            report.append(row)
            if row["meets_target"]:
                chosen = params
                break
            chosen = params                     # fallback: best-effort max
        shard_params.append(chosen)

    # contribution-weighted global recall implied by the per-shard picks
    implied = sum(r["recall_owned"] * r["n_owned"] / max(1, n_true)
                  for r in report
                  if r["params"] is shard_params[r["shard"]])
    report.append({"shard": -1, "params": None,
                   "implied_global_recall": round(implied, 4)})

    if mesh is not None:
        from repro.core.sharded_index import (build_sharded_index,
                                              make_query_fn)
        from repro.serve.runtime import uniform_shard_params
        uni = uniform_shard_params(shard_params)
        sharded = build_sharded_index(index.key, jnp.asarray(rows),
                                      index.spec.forest, mesh,
                                      db_axes=db_axes, tree_axis=tree_axis)
        qfn = make_query_fn(sharded.cfg, sharded.n_local, mesh, params=uni)
        with mesh:
            _, ids = qfn(sharded, queries, jnp.asarray(rows))
        mesh_rec = _recall(np.asarray(ids), pos)
        report.append({"shard": -1, "params": uni,
                       "mesh_recall": round(mesh_rec, 4),
                       "meets_target": mesh_rec >= target_recall})

    if persist:
        index.shard_params = tuple(shard_params)
        if index.tuned_params is None:
            from repro.serve.runtime import uniform_shard_params
            index.tuned_params = uniform_shard_params(shard_params)
    return shard_params, report

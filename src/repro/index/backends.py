"""The registered index backends of the unified API (DESIGN.md §5/§8).

Every candidate-based backend funnels into ``core.pipeline``'s fused
single-pass rerank — the (B, M, d) gathered candidate tensor never
materializes on any of them:

  rpf          random-partition forest, fp32 fused rerank (the paper)
  rpf+int8     same forest, int8 coarse shortlist -> fp32 fused rerank
  lsh-cascade  multi-radius LSH candidates -> shared fused rerank stage
  bruteforce   exact scan through the same fused rerank stage (oracle
               backend: what the others are measured against)

``SearchParams.adaptive_wave`` composes with both rpf backends (early-exit
wave scheduling, core/adaptive.py), as does ``probe_schedule`` (per-query
convergence-gated probe widening, core/schedule.py — DESIGN.md §14);
``expand`` tunes the int8 shortlist; ``n_probes``/``n_trees`` walk the
probes-vs-trees frontier (DESIGN.md §9).
Knobs that do not apply to a backend are inert (lsh-cascade and bruteforce
ignore the forest-only knobs), so one tuned ``SearchParams`` can be carried
across backends safely.

Since the segmented-lifecycle redesign each backend is split in two:

  * an **engine** — the immutable per-segment search core.  Engines are
    built once over a frozen row block (``engine_cls(spec, key, rows)``),
    answer ``search(q, params, valid=None)`` with SEGMENT-LOCAL ids, and
    accept an optional ``valid`` (n,) bool tombstone mask that is threaded
    down the fused pipeline's id/mask path (dead rows never reach the
    top-k).  One engine instance exists per sealed segment.
  * a thin ``Index`` subclass — picks the engine, exposes the legacy
    attribute surface (``index.forest`` / ``.qdb`` / ``.cascade`` resolve
    to the primary segment's engine) and the v1-checkpoint read shim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adaptive import adaptive_query
from repro.core.forest import Forest, ForestConfig, build_forest
from repro.core.lsh import CascadedLSH
from repro.core.pipeline import fused_query, rerank_fused
from repro.core.quantized import QuantizedDB, quantize_db
from repro.core.schedule import scheduled_query
from repro.index.api import Index, register_backend
from repro.index.params import IndexSpec, SearchParams
from repro.index.segments import brute_force_topk

_FOREST_SKELETON = Forest(proj_idx=0, proj_coef=0, thresh=0, child_base=0,
                          perm=0, leaf_offset=0, leaf_count=0, n_nodes=0)


# ---------------------------------------------------------------------------
# engines: the immutable per-segment search cores
# ---------------------------------------------------------------------------


class RPFEngine:
    """The paper's random-partition-forest core, fused fp32 rerank.

    Engine construction IS forest construction: every build — fresh
    index, delta-buffer seal, ``compact()`` rebuild — funnels through
    ``build_forest``'s batched cross-tree builder (DESIGN.md §10), whose
    compat seed mode keeps rebuilds bitwise-reproducible for the
    compaction-vs-fresh and save/load pins.

    Honors the full probes-vs-trees search surface (DESIGN.md §9):
    ``params.n_probes`` widens the per-tree descent to the most-marginal
    leaves, ``params.n_trees`` restricts the query to a prefix of the
    built forest (trees are independent, so any prefix is a valid smaller
    forest — the prefix sub-pytree is cached per width), and
    ``params.adaptive_wave`` composes with both.  ``params.probe_schedule``
    replaces the fixed probe budget with the per-query convergence-gated
    widening of ``core.schedule`` (DESIGN.md §14); the probes each query
    actually consumed land in ``last_mean_probes`` for the tuner's
    measured-cost discount.
    """

    def __init__(self, spec: IndexSpec, key: jax.Array, rows: np.ndarray):
        self.spec = spec
        self.db = np.ascontiguousarray(np.asarray(rows, np.float32))
        self.db_dev = jnp.asarray(self.db)
        self.forest = build_forest(key, self.db_dev, spec.forest,
                                   tree_chunk=spec.tree_chunk)
        self.last_trees_used = spec.forest.n_trees
        self.last_mean_probes = 0.0
        self._prefix_cache: dict[int, Forest] = {}

    def _rerank_source(self) -> jax.Array | QuantizedDB:
        return self.db_dev

    def _forest_prefix(self, n_trees: int) -> tuple[Forest, ForestConfig]:
        """(forest, cfg) restricted to the first ``n_trees`` trees (0=all)."""
        cfg = self.spec.forest
        total = cfg.n_trees
        if n_trees <= 0 or n_trees >= total:
            return self.forest, cfg
        if n_trees not in self._prefix_cache:
            self._prefix_cache[n_trees] = jax.tree.map(
                lambda a: a[:n_trees], self.forest)
        return self._prefix_cache[n_trees], cfg._replace(n_trees=n_trees)

    def search(self, q: jax.Array, params: SearchParams,
               valid: jax.Array | None = None
               ) -> tuple[jax.Array, jax.Array]:
        src = self._rerank_source()
        forest, cfg = self._forest_prefix(params.n_trees)
        if params.probe_schedule > 0:
            # per-query convergence-gated probe widening (DESIGN.md §14);
            # violations() rejects the adaptive_wave combination upstream
            d, i, _, processed = scheduled_query(
                forest, q, src, params.k, cfg, cap=params.probe_schedule,
                tol=params.tol, metric=params.metric, mode=params.mode,
                chunk=params.chunk, expand=params.expand,
                dedup=params.dedup, valid=valid)
            self.last_trees_used = cfg.n_trees
            self.last_mean_probes = float(processed.mean())
            return d, i
        if params.adaptive_wave > 0:
            d, i, used = adaptive_query(
                forest, q, src, params.k, cfg,
                wave=params.adaptive_wave, tol=params.tol,
                metric=params.metric, mode=params.mode, chunk=params.chunk,
                expand=params.expand, dedup=params.dedup,
                n_probes=params.n_probes, valid=valid)
            self.last_trees_used = used
            self.last_mean_probes = float(params.n_probes)
            return d, i
        self.last_trees_used = cfg.n_trees
        self.last_mean_probes = float(params.n_probes)
        return fused_query(forest, q, src, params.k, cfg,
                           metric=params.metric, dedup=params.dedup,
                           mode=params.mode, chunk=params.chunk,
                           expand=params.expand, n_probes=params.n_probes,
                           valid=valid)

    # ------------------------------------------------------------- save/load
    def state_tree(self) -> dict:
        # self.db stays host-side: Checkpointer snapshots leaves via
        # device_get, which passes numpy arrays through copy-free
        return {"db": self.db, "forest": self.forest}

    @classmethod
    def state_skeleton(cls, spec: IndexSpec) -> dict:
        return {"db": 0, "forest": _FOREST_SKELETON}

    @classmethod
    def from_state(cls, spec: IndexSpec, state: dict) -> "RPFEngine":
        obj = cls.__new__(cls)
        obj.spec = spec
        obj.db = np.asarray(state["db"], np.float32)
        obj.db_dev = jnp.asarray(obj.db)
        obj.forest = state["forest"]
        obj.last_trees_used = spec.forest.n_trees
        obj.last_mean_probes = 0.0
        obj._prefix_cache = {}
        return obj


class RPFInt8Engine(RPFEngine):
    """Same forest; int8 coarse shortlist -> exact fp32 fused rerank.

    ``SearchParams.expand`` sets the shortlist width k' = expand*k; both
    stages honor ``params.metric`` — the coarse stage scores the
    DEQUANTIZED rows under it (DESIGN.md §13), so the shortlist ranks
    like the exact fp32 stage (the per-row int8 calibration stays
    L2-shaped, §11).  The tombstone mask is applied at the coarse stage,
    so dead rows never occupy shortlist slots.
    """

    def __init__(self, spec: IndexSpec, key: jax.Array, rows: np.ndarray):
        super().__init__(spec, key, rows)
        self.qdb = quantize_db(self.db_dev)

    def _rerank_source(self) -> QuantizedDB:
        return self.qdb

    @classmethod
    def from_state(cls, spec: IndexSpec, state: dict) -> "RPFInt8Engine":
        obj = super().from_state(spec, state)
        obj.qdb = quantize_db(obj.db_dev)
        return obj


class LSHEngine:
    """The paper's LSH-cascade baseline behind the same search surface.

    Host-side bucket probe (vectorized: one hash per batch per level), then
    the SAME fused rerank stage as the forest backends — fair accuracy/cost
    comparisons come free.  Hash projections depend only on (seed, d), so
    every segment of the same index hashes identically to a fresh build.
    """

    def __init__(self, spec: IndexSpec, key: jax.Array, rows: np.ndarray):
        self.spec = spec
        self.db = np.ascontiguousarray(np.asarray(rows, np.float32))
        self.db_dev = jnp.asarray(self.db)
        self.cascade = CascadedLSH(
            self.db, list(spec.lsh_radii),
            n_tables=spec.lsh_tables, n_bits=spec.lsh_bits,
            width_scale=spec.lsh_width_scale, seed=spec.seed)
        self.last_mean_candidates = 0.0

    def search(self, q: jax.Array, params: SearchParams,
               valid: jax.Array | None = None
               ) -> tuple[jax.Array, jax.Array]:
        ids, mask = self.cascade.retrieve_batch(
            np.asarray(q), min_candidates=params.min_candidates)
        self.last_mean_candidates = float(mask.sum(1).mean())
        # candidate sets are already unique per query -> dedup not needed
        return rerank_fused(q, jnp.asarray(ids), jnp.asarray(mask),
                            self.db_dev, params.k, metric=params.metric,
                            mode=params.mode, dedup=False, chunk=params.chunk,
                            valid=valid)

    def state_tree(self) -> dict:
        return {"db": self.db}

    @classmethod
    def state_skeleton(cls, spec: IndexSpec) -> dict:
        return {"db": 0}

    @classmethod
    def from_state(cls, spec: IndexSpec, state: dict) -> "LSHEngine":
        # tables are a pure function of (db, spec): rebuild deterministically
        return cls(spec, None, np.asarray(state["db"], np.float32))


class BruteForceEngine:
    """Exact scan routed through the shared fused rerank stage.

    One code path with and without tombstones (the mask only flips score
    slots to +inf), so a mutated bruteforce index answers bitwise
    identically to a fresh build over the live rows — the oracle property
    the mutation tests lean on.
    """

    def __init__(self, spec: IndexSpec, key: jax.Array, rows: np.ndarray):
        self.spec = spec
        self.db = np.ascontiguousarray(np.asarray(rows, np.float32))
        self.db_dev = jnp.asarray(self.db)

    def search(self, q: jax.Array, params: SearchParams,
               valid: jax.Array | None = None
               ) -> tuple[jax.Array, jax.Array]:
        return brute_force_topk(q, self.db_dev, params, valid=valid)

    def state_tree(self) -> dict:
        return {"db": self.db}

    @classmethod
    def state_skeleton(cls, spec: IndexSpec) -> dict:
        return {"db": 0}

    @classmethod
    def from_state(cls, spec: IndexSpec, state: dict) -> "BruteForceEngine":
        return cls(spec, None, np.asarray(state["db"], np.float32))


# ---------------------------------------------------------------------------
# registered Index subclasses: engine choice + legacy attribute surface
# ---------------------------------------------------------------------------


@register_backend("rpf")
class RPFIndex(Index):
    """The paper's random-partition-forest index, fused fp32 rerank."""

    engine_cls = RPFEngine

    @property
    def forest(self) -> Forest:
        """Primary segment's forest (compat with the pre-segment API)."""
        return self._primary_engine.forest

    @property
    def db_dev(self) -> jax.Array:
        return self._primary_engine.db_dev

    @property
    def last_trees_used(self) -> int:
        return self._primary_engine.last_trees_used

    @property
    def last_mean_probes(self) -> float:
        """Mean probes per query the primary engine processed on its last
        search (the scheduled path's honest cumulative charge; equals
        ``params.n_probes`` on the fixed-budget paths)."""
        return self._primary_engine.last_mean_probes

    def _extra_stats(self) -> dict:
        return {"n_trees": self.spec.forest.n_trees}

    @classmethod
    def _v1_skeleton(cls, spec: IndexSpec) -> dict:
        return {"db": 0, "key_data": 0, "forest": _FOREST_SKELETON}


@register_backend("rpf+int8")
class RPFInt8Index(RPFIndex):
    """Same forest; int8 coarse shortlist -> exact fp32 fused rerank."""

    engine_cls = RPFInt8Engine

    @property
    def qdb(self) -> QuantizedDB:
        return self._primary_engine.qdb


@register_backend("lsh-cascade")
class LSHCascadeIndex(Index):
    """The paper's LSH-cascade baseline behind the same search surface."""

    engine_cls = LSHEngine

    @property
    def cascade(self) -> CascadedLSH:
        return self._primary_engine.cascade

    @property
    def last_mean_candidates(self) -> float:
        return self._primary_engine.last_mean_candidates

    def _extra_stats(self) -> dict:
        return {"n_levels": len(self.spec.lsh_radii),
                "n_tables": self.spec.lsh_tables}

    @classmethod
    def _v1_skeleton(cls, spec: IndexSpec) -> dict:
        return {"db": 0, "key_data": 0}


@register_backend("bruteforce")
class BruteForceIndex(Index):
    """Exact scan via the shared fused rerank stage (the recall oracle)."""

    engine_cls = BruteForceEngine

    @property
    def db_dev(self) -> jax.Array:
        return self._primary_engine.db_dev

    @classmethod
    def _v1_skeleton(cls, spec: IndexSpec) -> dict:
        return {"db": 0, "key_data": 0}

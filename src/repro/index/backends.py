"""The registered index backends of the unified API (DESIGN.md §5).

Every candidate-based backend funnels into ``core.pipeline``'s fused
single-pass rerank — the (B, M, d) gathered candidate tensor never
materializes on any of them:

  rpf          random-partition forest, fp32 fused rerank (the paper)
  rpf+int8     same forest, int8 coarse shortlist -> fp32 fused rerank
  lsh-cascade  multi-radius LSH candidates -> shared fused rerank stage
  bruteforce   exact scan via the fused matmul/chi2 top-k kernels (oracle
               backend: what the others are measured against)

``SearchParams.adaptive_wave`` composes with both rpf backends (early-exit
wave scheduling, core/adaptive.py); ``expand`` tunes the int8 shortlist.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adaptive import adaptive_query
from repro.core.forest import Forest, build_forest
from repro.core.knn import exact_knn
from repro.core.lsh import CascadedLSH
from repro.core.pipeline import fused_query, rerank_fused
from repro.core.quantized import QuantizedDB, quantize_db
from repro.index.api import Index, register_backend
from repro.index.params import IndexSpec, SearchParams
from repro.kernels import ops

_FOREST_SKELETON = Forest(proj_idx=0, proj_coef=0, thresh=0, child_base=0,
                          perm=0, leaf_offset=0, leaf_count=0, n_nodes=0)


@register_backend("rpf")
class RPFIndex(Index):
    """The paper's random-partition-forest index, fused fp32 rerank."""

    def _build_state(self, db_dev: jax.Array) -> None:
        self.db_dev = db_dev
        self.forest = build_forest(self.key, db_dev, self.spec.forest,
                                   tree_chunk=self.spec.tree_chunk)
        self.last_trees_used = self.spec.forest.n_trees

    def _rerank_source(self) -> jax.Array | QuantizedDB:
        return self.db_dev

    def _search_static(self, q: jax.Array, params: SearchParams
                       ) -> tuple[jax.Array, jax.Array]:
        src = self._rerank_source()
        cfg = self.spec.forest
        if params.adaptive_wave > 0:
            d, i, used = adaptive_query(
                self.forest, q, src, params.k, cfg,
                wave=params.adaptive_wave, tol=params.tol,
                metric=params.metric, mode=params.mode, chunk=params.chunk,
                expand=params.expand, dedup=params.dedup)
            self.last_trees_used = used
            return d, i
        self.last_trees_used = cfg.n_trees
        return fused_query(self.forest, q, src, params.k, cfg,
                           metric=params.metric, dedup=params.dedup,
                           mode=params.mode, chunk=params.chunk,
                           expand=params.expand)

    def stats(self) -> dict:
        return {**super().stats(), "n_trees": self.spec.forest.n_trees}

    # ------------------------------------------------------------- save/load
    def _state_tree(self) -> dict:
        # self.db stays host-side: Checkpointer snapshots leaves via
        # device_get, which passes numpy arrays through copy-free
        return {"db": self.db,
                "key_data": jax.random.key_data(self.key),
                "forest": self.forest}

    @classmethod
    def _state_skeleton(cls, spec: IndexSpec) -> dict:
        return {"db": 0, "key_data": 0, "forest": _FOREST_SKELETON}

    def _restore_state(self, state: dict) -> None:
        self.key = jax.random.wrap_key_data(
            jnp.asarray(state["key_data"], jnp.uint32))
        self.db = np.asarray(state["db"], np.float32)
        self.db_dev = jnp.asarray(self.db)
        self.forest = state["forest"]
        self.last_trees_used = self.spec.forest.n_trees


@register_backend("rpf+int8")
class RPFInt8Index(RPFIndex):
    """Same forest; int8 coarse shortlist -> exact fp32 fused rerank.

    ``SearchParams.expand`` sets the shortlist width k' = expand*k; the
    coarse stage is always L2 (the per-row int8 calibration is L2-shaped),
    the exact stage honors ``params.metric``.
    """

    def _build_state(self, db_dev: jax.Array) -> None:
        super()._build_state(db_dev)
        self.qdb = quantize_db(db_dev)

    def _rerank_source(self) -> QuantizedDB:
        return self.qdb

    def _restore_state(self, state: dict) -> None:
        super()._restore_state(state)
        self.qdb = quantize_db(self.db_dev)


@register_backend("lsh-cascade")
class LSHCascadeIndex(Index):
    """The paper's LSH-cascade baseline behind the same search surface.

    Host-side bucket probe (vectorized: one hash per batch per level), then
    the SAME fused rerank stage as the forest backends — fair accuracy/cost
    comparisons come free.
    """

    def _build_state(self, db_dev: jax.Array) -> None:
        self.db_dev = db_dev
        self.cascade = CascadedLSH(
            self.db, list(self.spec.lsh_radii),
            n_tables=self.spec.lsh_tables, n_bits=self.spec.lsh_bits,
            width_scale=self.spec.lsh_width_scale, seed=self.spec.seed)
        self.last_mean_candidates = 0.0

    def _search_static(self, q: jax.Array, params: SearchParams
                       ) -> tuple[jax.Array, jax.Array]:
        ids, mask = self.cascade.retrieve_batch(
            np.asarray(q), min_candidates=params.min_candidates)
        self.last_mean_candidates = float(mask.sum(1).mean())
        # candidate sets are already unique per query -> dedup not needed
        return rerank_fused(q, jnp.asarray(ids), jnp.asarray(mask),
                            self.db_dev, params.k, metric=params.metric,
                            mode=params.mode, dedup=False, chunk=params.chunk)

    def stats(self) -> dict:
        return {**super().stats(), "n_levels": len(self.spec.lsh_radii),
                "n_tables": self.spec.lsh_tables}

    def _state_tree(self) -> dict:
        return {"db": self.db,
                "key_data": jax.random.key_data(self.key)}

    @classmethod
    def _state_skeleton(cls, spec: IndexSpec) -> dict:
        return {"db": 0, "key_data": 0}

    def _restore_state(self, state: dict) -> None:
        self.key = jax.random.wrap_key_data(
            jnp.asarray(state["key_data"], jnp.uint32))
        self.db = np.asarray(state["db"], np.float32)
        # tables are a pure function of (db, spec): rebuild deterministically
        self._build_state(jnp.asarray(self.db))


@register_backend("bruteforce")
class BruteForceIndex(Index):
    """Exact scan through the fused score+top-k kernels (the recall oracle)."""

    def _build_state(self, db_dev: jax.Array) -> None:
        self.db_dev = db_dev

    def _search_static(self, q: jax.Array, params: SearchParams
                       ) -> tuple[jax.Array, jax.Array]:
        if params.metric == "cosine":   # not a kernel metric; jnp pairwise
            return exact_knn(q, self.db_dev, k=params.k, metric="cosine")
        return ops.topk(q, self.db_dev, params.k, metric=params.metric,
                        mode=params.mode)

    def _state_tree(self) -> dict:
        return {"db": self.db,
                "key_data": jax.random.key_data(self.key)}

    @classmethod
    def _state_skeleton(cls, spec: IndexSpec) -> dict:
        return {"db": 0, "key_data": 0}

    def _restore_state(self, state: dict) -> None:
        self.key = jax.random.wrap_key_data(
            jnp.asarray(state["key_data"], jnp.uint32))
        self.db = np.asarray(state["db"], np.float32)
        self.db_dev = jnp.asarray(self.db)

"""Filtered search: metadata columns + predicate ASTs on the validity path.

    from repro.filter import Eq, In, Range, And, Or, Not

    index = build_index(key, db, spec, metadata={"tenant": tenants,
                                                 "ts": timestamps})
    d, i = index.search(q, SearchParams(k=10, filter=And(
        Eq("tenant", "acme"), Range("ts", lo=t0))))

See DESIGN.md §13: predicates compile to per-segment bitmaps that ride the
same fused-kernel mask path as tombstones — no kernel changes, every
backend, with selectivity-aware candidate widening.
"""
from repro.filter.metadata import KINDS, MetaBlock, MetadataStore
from repro.filter.predicate import (And, Eq, In, Not, Or, Predicate, Range,
                                    from_dict, widen_params)

__all__ = ["KINDS", "MetaBlock", "MetadataStore", "Predicate", "Eq", "In",
           "Range", "And", "Or", "Not", "from_dict", "widen_params"]

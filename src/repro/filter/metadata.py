"""Columnar per-row metadata: the store (schema + vocab) and per-segment blocks.

Attributes ride the index with the same lifecycle as the tombstone bitmap
(DESIGN.md §13): every sealed segment carries one immutable ``MetaBlock``
— a dict of column arrays aligned with the segment's rows — and the delta
buffer grows the same columns row-by-row.  Blocks are shared, not copied,
by ``SealedSegment.with_tombstones`` (metadata never changes after seal;
only liveness does), survive ``flush()``/``compact()`` by plain gather/
concat of the column arrays, and land in the manifest (format 5) as
ordinary checkpoint leaves.

Column kinds and their storage:

  int          int64 as given
  timestamp    int64 nanoseconds (``datetime64`` input converted)
  categorical  int32 codes into an APPEND-ONLY per-column vocabulary kept
               by the ``MetadataStore`` (interned on ingest, persisted in
               the manifest JSON)

The append-only vocab is what makes the per-block predicate-bitmap cache
sound: a sealed block's codes never change, and a query value the vocab
has not seen encodes to -1 (matches nothing) — if that value is added
later it is interned for the NEW rows only, so a cached all-False bitmap
for an old block stays correct forever.
"""
from __future__ import annotations

import threading
from typing import Any, Mapping

import numpy as np

__all__ = ["KINDS", "MetadataStore", "MetaBlock"]

KINDS = ("int", "categorical", "timestamp")

_DTYPES = {"int": np.int64, "timestamp": np.int64, "categorical": np.int32}


def _infer_kind(values: np.ndarray) -> str:
    if np.issubdtype(values.dtype, np.datetime64):
        return "timestamp"
    if np.issubdtype(values.dtype, np.integer):
        return "int"
    return "categorical"


class MetadataStore:
    """Schema + categorical vocabulary of one index's metadata columns.

    The store is the only mutable piece of the metadata subsystem, and its
    only mutation is append-only vocab growth (under a lock: ``add``
    interns from mutator threads).  Everything row-shaped lives in
    immutable ``MetaBlock``s / the delta buffer's columns.
    """

    def __init__(self, columns: Mapping[str, str],
                 vocab: Mapping[str, list] | None = None):
        for name, kind in columns.items():
            if kind not in KINDS:
                raise ValueError(f"column {name!r}: unknown kind {kind!r} "
                                 f"(known: {KINDS})")
        self.columns: dict[str, str] = dict(columns)
        self._lock = threading.Lock()
        self._vocab: dict[str, list] = {
            name: list((vocab or {}).get(name, ()))
            for name, kind in self.columns.items() if kind == "categorical"}
        self._code: dict[str, dict] = {
            name: {v: i for i, v in enumerate(vals)}
            for name, vals in self._vocab.items()}

    # -------------------------------------------------------------- schema
    def kind(self, name: str) -> str:
        if name not in self.columns:
            raise KeyError(f"unknown metadata column {name!r} "
                           f"(schema: {sorted(self.columns)})")
        return self.columns[name]

    def dtype(self, name: str):
        return _DTYPES[self.kind(name)]

    # ------------------------------------------------------------ encoding
    def encode_rows(self, name: str, values) -> np.ndarray:
        """Column values -> stored codes, interning new categoricals."""
        kind = self.kind(name)
        if kind == "categorical":
            vals = np.asarray(values, object).reshape(-1)
            with self._lock:
                code = self._code[name]
                out = np.empty(vals.shape[0], np.int32)
                for i, v in enumerate(vals):
                    if isinstance(v, np.generic):
                        v = v.item()
                    c = code.get(v)
                    if c is None:
                        c = len(self._vocab[name])
                        self._vocab[name].append(v)
                        code[v] = c
                    out[i] = c
            return out
        arr = np.asarray(values)
        if np.issubdtype(arr.dtype, np.datetime64):
            arr = arr.astype("datetime64[ns]").astype(np.int64)
        return np.asarray(arr, np.int64).reshape(-1)

    def encode_row(self, name: str, value) -> int:
        """One row's value -> its stored code (interning; the add path)."""
        return int(self.encode_rows(name, [value])[0])

    def encode_value(self, name: str, value) -> int:
        """A QUERY value -> code; never interns.  Unseen categorical -> -1
        (matches no stored code, which is the correct empty match)."""
        kind = self.kind(name)
        if kind == "categorical":
            if isinstance(value, np.generic):
                value = value.item()
            return self._code[name].get(value, -1)
        if isinstance(value, np.datetime64):
            return int(value.astype("datetime64[ns]").astype(np.int64))
        return int(value)

    # -------------------------------------------------------------- ingest
    @classmethod
    def from_arrays(cls, metadata: Mapping[str, Any], n_rows: int,
                    schema: Mapping[str, str] | None = None
                    ) -> tuple["MetadataStore", "MetaBlock"]:
        """Build a store + the first block from build-time column arrays.

        ``schema`` (optional) pins column kinds; otherwise they are
        inferred (datetime64 -> timestamp, integer -> int, anything else
        -> categorical).  Every column must cover all ``n_rows``.
        """
        columns = {}
        arrays = {name: np.asarray(vals) if not isinstance(vals, np.ndarray)
                  else vals for name, vals in metadata.items()}
        for name, vals in arrays.items():
            kind = (schema or {}).get(name) or _infer_kind(
                vals if vals.dtype != object else np.asarray([0]))
            if vals.dtype == object and (schema or {}).get(name) is None:
                kind = "categorical"
            columns[name] = kind
        store = cls(columns)
        return store, store.make_block(arrays, n_rows)

    def make_block(self, metadata: Mapping[str, Any], n_rows: int
                   ) -> "MetaBlock":
        """Encode full-length column arrays into a block (build/seal path)."""
        missing = set(self.columns) - set(metadata)
        extra = set(metadata) - set(self.columns)
        if missing or extra:
            raise ValueError(
                f"metadata columns must match the schema exactly: "
                f"missing {sorted(missing)}, unknown {sorted(extra)}")
        cols = {}
        for name in self.columns:
            codes = self.encode_rows(name, metadata[name])
            if codes.shape[0] != n_rows:
                raise ValueError(f"column {name!r} has {codes.shape[0]} "
                                 f"values for {n_rows} rows")
            cols[name] = codes
        return MetaBlock(cols)

    def encode_point(self, metadata: Mapping[str, Any] | None
                     ) -> dict[str, int]:
        """One point's metadata dict -> {column: code} (the add path).

        Metadata-carrying indexes require every column on every add —
        predicates are total (no null semantics to reason about)."""
        metadata = metadata or {}
        missing = set(self.columns) - set(metadata)
        extra = set(metadata) - set(self.columns)
        if missing or extra:
            raise ValueError(
                f"point metadata must cover the schema exactly: "
                f"missing {sorted(missing)}, unknown {sorted(extra)}")
        return {name: self.encode_row(name, metadata[name])
                for name in self.columns}

    # ----------------------------------------------------------- manifest
    def to_json(self) -> dict:
        with self._lock:
            return {"columns": dict(self.columns),
                    "vocab": {k: list(v) for k, v in self._vocab.items()}}

    @classmethod
    def from_json(cls, d: dict) -> "MetadataStore":
        return cls(d["columns"], d.get("vocab") or {})


class MetaBlock:
    """Immutable columnar metadata of one sealed segment + bitmap cache.

    The cache maps a predicate (hashable AST node) to its (n_rows,) match
    bitmap over THIS block's rows.  Blocks are shared across
    ``with_tombstones`` copies of a segment — metadata is liveness-
    independent — so the cache warms once per (segment, predicate)
    regardless of how often the segment's tombstone bitmap is reissued.
    """

    __slots__ = ("cols", "n_rows", "_cache", "_cache_lock")

    def __init__(self, cols: dict[str, np.ndarray]):
        self.cols = {name: np.ascontiguousarray(arr)
                     for name, arr in cols.items()}
        sizes = {arr.shape[0] for arr in self.cols.values()}
        if len(sizes) > 1:
            raise ValueError(f"ragged metadata columns: {sizes}")
        self.n_rows = sizes.pop() if sizes else 0
        self._cache: dict = {}
        self._cache_lock = threading.Lock()

    def column(self, name: str) -> np.ndarray:
        if name not in self.cols:
            raise KeyError(f"unknown metadata column {name!r} "
                           f"(have: {sorted(self.cols)})")
        return self.cols[name]

    def match(self, predicate, store: MetadataStore) -> np.ndarray:
        """Cached (n_rows,) bool match bitmap for ``predicate``."""
        with self._cache_lock:
            hit = self._cache.get(predicate)
        if hit is not None:
            return hit
        out = predicate.evaluate(self, store)
        out = np.ascontiguousarray(np.asarray(out, bool))
        with self._cache_lock:
            self._cache[predicate] = out
        return out

    def take(self, idx: np.ndarray) -> "MetaBlock":
        """Gather rows into a fresh block (the compaction path)."""
        return MetaBlock({name: arr[idx] for name, arr in self.cols.items()})

    @staticmethod
    def concat(parts: list["MetaBlock"]) -> "MetaBlock":
        """Stitch gathered parts back into one block (compaction/seal)."""
        if not parts:
            return MetaBlock({})
        names = parts[0].cols.keys()
        return MetaBlock({name: np.concatenate([p.cols[name] for p in parts])
                          for name in names})

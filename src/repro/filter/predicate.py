"""Structured predicate AST compiled to per-segment validity bitmaps.

Filtered ANN here is the tombstone trick generalized (DESIGN.md §13): the
fused rerank already masks dead rows through the candidate id path — a
masked row's slot becomes id -1 before the kernel, issues no DMA and scores
+inf.  A metadata predicate is just *more rows masked for one query*: the
AST below is evaluated host-side against a segment's columnar metadata
(``repro.filter.metadata``) into an (n_rows,) bool bitmap, AND-merged with
the segment's ``live`` bitmap, and handed to the exact same ``valid=``
path every backend already serves.  No kernel learns about predicates.

The AST is deliberately tiny and closed: ``Eq``/``In``/``Range`` leaves
over one column, ``And``/``Or``/``Not`` combinators.  Nodes are frozen
(hashable) so a predicate can ride ``SearchParams`` — itself frozen — and
key the per-segment bitmap caches; ``to_dict``/``from_dict`` give a tagged
JSON roundtrip for tooling.

Selectivity-aware widening lives here too (:func:`widen_params`): a
filter that keeps only a fraction ``s`` of the live rows starves the
candidate stage — the traversal surfaces the same leaves but ~(1-s) of
them are masked, so the effective shortlist shrinks by s.  Per-query
candidate scaling is the Dynamic Continuous Indexing insight (Li & Malik
2015, PAPERS.md) applied to filters: widen ``n_probes`` /
``min_candidates`` like 1/s, and below :data:`BRUTE_FORCE_SELECTIVITY`
(or :data:`BRUTE_FORCE_MAX_ROWS` matching rows) skip the index entirely —
an exact scan over the matching rows is both cheaper and recall-1.0, which
is how production vector stores serve very selective filters.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import numpy as np

__all__ = ["Predicate", "Eq", "In", "Range", "And", "Or", "Not",
           "from_dict", "widen_params",
           "BRUTE_FORCE_SELECTIVITY", "BRUTE_FORCE_MAX_ROWS", "MAX_PROBES"]

# below this match fraction (or below this many matching rows) the filtered
# query exact-scans the matching rows instead of widening the index probe —
# guaranteed recall, and cost proportional to the matches, not the corpus
BRUTE_FORCE_SELECTIVITY = 0.05
BRUTE_FORCE_MAX_ROWS = 4096

# widening never pushes the per-tree probe count past this (leaf sets start
# overlapping heavily long before; past it, brute force over matches wins)
MAX_PROBES = 16


class Predicate:
    """Base class: evaluation + JSON tagging shared by every node."""

    def evaluate(self, block, store) -> np.ndarray:
        """(n_rows,) bool match bitmap over ``block``'s rows.

        ``block`` is a ``repro.filter.metadata.MetaBlock`` (columnar codes),
        ``store`` the index's ``MetadataStore`` (schema + categorical
        vocab).  Unknown columns raise; a categorical value the vocab has
        never seen matches nothing (correct under the store's append-only
        interning: codes of existing rows never change).
        """
        raise NotImplementedError

    def columns(self) -> frozenset[str]:
        raise NotImplementedError

    def to_dict(self) -> dict[str, Any]:
        raise NotImplementedError


def _scalar(value) -> Any:
    """Normalize a leaf comparison value to a hashable python scalar."""
    if isinstance(value, (np.generic,)):
        return value.item()
    if isinstance(value, np.datetime64):
        return int(value.astype("datetime64[ns]").astype(np.int64))
    return value


@dataclasses.dataclass(frozen=True)
class Eq(Predicate):
    """column == value (any column kind)."""

    column: str
    value: Any

    def __post_init__(self):
        object.__setattr__(self, "value", _scalar(self.value))

    def evaluate(self, block, store) -> np.ndarray:
        codes = block.column(self.column)
        return codes == store.encode_value(self.column, self.value)

    def columns(self) -> frozenset[str]:
        return frozenset((self.column,))

    def to_dict(self) -> dict[str, Any]:
        return {"op": "eq", "column": self.column, "value": self.value}


@dataclasses.dataclass(frozen=True)
class In(Predicate):
    """column ∈ values (any column kind)."""

    column: str
    values: tuple

    def __post_init__(self):
        object.__setattr__(self, "values",
                           tuple(_scalar(v) for v in self.values))

    def evaluate(self, block, store) -> np.ndarray:
        codes = block.column(self.column)
        wanted = np.asarray(sorted({store.encode_value(self.column, v)
                                    for v in self.values}), codes.dtype)
        return np.isin(codes, wanted)

    def columns(self) -> frozenset[str]:
        return frozenset((self.column,))

    def to_dict(self) -> dict[str, Any]:
        return {"op": "in", "column": self.column,
                "values": list(self.values)}


@dataclasses.dataclass(frozen=True)
class Range(Predicate):
    """lo <= column <= hi over an ordered (int/timestamp) column.

    ``None`` bounds are open; categorical columns reject (codes are
    interning order, not value order).
    """

    column: str
    lo: Any = None
    hi: Any = None

    def __post_init__(self):
        if self.lo is None and self.hi is None:
            raise ValueError("Range needs at least one bound "
                             "(lo=None, hi=None matches everything)")
        object.__setattr__(self, "lo", _scalar(self.lo))
        object.__setattr__(self, "hi", _scalar(self.hi))

    def evaluate(self, block, store) -> np.ndarray:
        if store.kind(self.column) == "categorical":
            raise ValueError(f"Range over categorical column "
                             f"{self.column!r} is not ordered")
        vals = block.column(self.column)
        out = np.ones(vals.shape[0], bool)
        if self.lo is not None:
            out &= vals >= store.encode_value(self.column, self.lo)
        if self.hi is not None:
            out &= vals <= store.encode_value(self.column, self.hi)
        return out

    def columns(self) -> frozenset[str]:
        return frozenset((self.column,))

    def to_dict(self) -> dict[str, Any]:
        return {"op": "range", "column": self.column, "lo": self.lo,
                "hi": self.hi}


def _children(ps) -> tuple:
    ps = tuple(ps)
    if not ps or not all(isinstance(p, Predicate) for p in ps):
        raise TypeError("combinator children must be a non-empty sequence "
                        "of Predicate nodes")
    return ps


@dataclasses.dataclass(frozen=True)
class And(Predicate):
    children: tuple

    def __init__(self, *children):
        object.__setattr__(self, "children", _children(children))

    def evaluate(self, block, store) -> np.ndarray:
        out = self.children[0].evaluate(block, store)
        for child in self.children[1:]:
            out = out & child.evaluate(block, store)
        return out

    def columns(self) -> frozenset[str]:
        return frozenset().union(*(c.columns() for c in self.children))

    def to_dict(self) -> dict[str, Any]:
        return {"op": "and", "children": [c.to_dict()
                                          for c in self.children]}


@dataclasses.dataclass(frozen=True)
class Or(Predicate):
    children: tuple

    def __init__(self, *children):
        object.__setattr__(self, "children", _children(children))

    def evaluate(self, block, store) -> np.ndarray:
        out = self.children[0].evaluate(block, store)
        for child in self.children[1:]:
            out = out | child.evaluate(block, store)
        return out

    def columns(self) -> frozenset[str]:
        return frozenset().union(*(c.columns() for c in self.children))

    def to_dict(self) -> dict[str, Any]:
        return {"op": "or", "children": [c.to_dict() for c in self.children]}


@dataclasses.dataclass(frozen=True)
class Not(Predicate):
    child: Predicate

    def __post_init__(self):
        if not isinstance(self.child, Predicate):
            raise TypeError("Not() wraps a Predicate node")

    def evaluate(self, block, store) -> np.ndarray:
        return ~self.child.evaluate(block, store)

    def columns(self) -> frozenset[str]:
        return self.child.columns()

    def to_dict(self) -> dict[str, Any]:
        return {"op": "not", "child": self.child.to_dict()}


_OPS = {"eq": Eq, "in": In, "range": Range, "and": And, "or": Or, "not": Not}


def from_dict(d: dict[str, Any]) -> Predicate:
    """Inverse of ``Predicate.to_dict`` (tagged JSON -> AST)."""
    op = d.get("op")
    if op == "eq":
        return Eq(d["column"], d["value"])
    if op == "in":
        return In(d["column"], tuple(d["values"]))
    if op == "range":
        return Range(d["column"], d.get("lo"), d.get("hi"))
    if op == "and":
        return And(*(from_dict(c) for c in d["children"]))
    if op == "or":
        return Or(*(from_dict(c) for c in d["children"]))
    if op == "not":
        return Not(from_dict(d["child"]))
    raise ValueError(f"unknown predicate op {op!r} "
                     f"(known: {sorted(_OPS)})")


# ---------------------------------------------------------------------------
# selectivity-aware widening
# ---------------------------------------------------------------------------


def use_brute_force(selectivity: float, n_match: int) -> bool:
    """Should a filter this selective skip the index and exact-scan the
    matching rows?  (The scan rides the same fused kernel with every
    non-match masked to id -1 — no DMA — so its cost is ~n_match rows.)"""
    return (selectivity <= BRUTE_FORCE_SELECTIVITY
            or n_match <= BRUTE_FORCE_MAX_ROWS)


def widen_params(params, selectivity: float):
    """Scale the candidate budget so recall-under-filter holds.

    With a match fraction ``s``, a candidate set of size C holds ~s*C
    matching rows — the index must surface ~1/s more candidates to keep
    the effective shortlist at its unfiltered size.  Forest backends widen
    ``n_probes`` by 1/sqrt(s) (probes overlap, so full 1/s overshoots) and
    drop any search-time tree restriction; the lsh cascade raises its stop
    threshold to the caller's budget scaled by 1/s (floored at ~2k/s, so a
    tiny caller budget still surfaces enough matches).  Returns a new
    ``SearchParams`` (the original is frozen); no-op at s >= 1.
    """
    if selectivity >= 1.0:
        return params
    s = max(float(selectivity), 1e-6)
    n_probes = min(MAX_PROBES,
                   int(math.ceil(params.n_probes / math.sqrt(s))))
    min_candidates = max(int(math.ceil(params.min_candidates / s)),
                         int(math.ceil(2.0 * params.k / s)))
    return dataclasses.replace(params, n_probes=n_probes,
                               min_candidates=min_candidates, n_trees=0)

"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128e top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Modeling notes: interleaved MoE (every other layer routed, as in Llama-4
"interleaved MoE" / early-fusion family) + one shared expert — this is what
lands total params at ~400B with ~17B active; an all-MoE stack at these dims
would be ~780B.  40 heads / 8 KV heads don't divide tp=16 -> sequence-sharded
attention (DESIGN.md §3.2).  bf16 params + bf16 Adam moments + FSDP over dp:
400B * (2+2+2) / 512 chips ~= 4.7 GB/chip of state.
"""
from repro.configs.base import ArchSpec, LMConfig, ShapeCell

CONFIG = LMConfig(
    name="llama4-maverick-400b-a17b",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    moe=True,
    n_experts=128,
    top_k=1,
    moe_every=2,
    shared_expert=True,
    capacity_factor=1.25,
    attn_shard="sequence",
    rope_base=500000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    fsdp=True,
    remat=True,
)

CELLS = (
    ShapeCell("train_4k", "train", seq_len=4096, global_batch=256),
    ShapeCell("prefill_32k", "prefill", seq_len=32768, global_batch=32),
    ShapeCell("decode_32k", "decode", seq_len=32768, global_batch=128),
    ShapeCell("long_500k", "decode", seq_len=524288, global_batch=1,
              skip=True,
              skip_reason="pure full attention; no sub-quadratic structure "
                          "(DESIGN.md §5)"),
)

ARCH = ArchSpec(arch_id="llama4-maverick-400b-a17b", family="lm",
                config=CONFIG, cells=CELLS,
                notes="~400B total / ~17B active (param_count() check in tests)")

"""The paper's own MNIST-784 experiment config (Zhong 2015, §4 / Fig. 4).

N=60000 database vectors, 784-D, unit-normalized; C=12, r=0.3, K=1;
L swept over {1,2,5,10,20,40,80,160,320,640}; Euclidean distance; recall@1
against exact NN. Data: deterministic MNIST-statistics generator (offline
container — DESIGN.md §7.5).
"""
from repro.configs.base import ArchSpec, ShapeCell
from repro.core.forest import ForestConfig

CONFIG = ForestConfig(n_trees=80, capacity=12, split_ratio=0.3, n_proj=1)

L_SWEEP = (1, 2, 5, 10, 20, 40, 80, 160, 320, 640)
N_DB = 60_000
N_TEST = 10_000
DIM = 784
METRIC = "l2"

CELLS = (
    ShapeCell("index_build", "train", batch=N_DB),
    ShapeCell("query_batch", "serve", batch=1024),
)

ARCH = ArchSpec(arch_id="rpf-mnist784", family="ann", config=CONFIG,
                cells=CELLS, notes="paper Fig. 4 reproduction")

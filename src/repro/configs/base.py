"""Config schema for every architecture family + input-shape cells."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) cell of the dry-run matrix."""

    name: str                    # e.g. "train_4k"
    kind: str                    # train | prefill | decode | serve | retrieval
    # LM shapes
    seq_len: int = 0
    global_batch: int = 0
    # GNN shapes
    n_nodes: int = 0
    n_edges: int = 0
    d_feat: int = 0
    batch_nodes: int = 0
    fanout: tuple[int, ...] = ()
    n_graphs: int = 0
    # RecSys shapes
    batch: int = 0
    n_candidates: int = 0
    skip: bool = False           # inapplicable cell (documented in DESIGN.md)
    skip_reason: str = ""


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1           # 1 = every layer is MoE; 2 = alternate dense/MoE
    shared_expert: bool = False
    capacity_factor: float = 1.25
    # attention pattern
    sliding_window: int = 0      # 0 = all-global
    global_every: int = 0        # gemma3: every 6th layer is global
    attn_shard: str = "heads"    # "heads" | "sequence" (DESIGN.md §3.2)
    attn_impl: str = "dense"     # "dense" | "blockwise" (flash-style)
    kv_block: int = 1024         # blockwise KV tile
    rope_base: float = 10000.0
    norm_eps: float = 1e-6
    logit_softcap: float = 0.0
    tie_embeddings: bool = False
    # numerics / memory
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    fsdp: bool = False           # shard params+opt over dp too (ZeRO-3 analogue)
    expert_fsdp: int = -1        # -1: follow fsdp; 0/1 override for MoE experts
    # (hillclimb: expert weights NOT dp-sharded kill the per-layer weight
    # all-gathers; feasible when paired with factored optimizer states)
    opt: str = "adamw"           # "adamw" | "adafactor"
    moe_gather_quant: bool = False  # int8-compress FSDP expert-weight gathers
    moe_a2a: bool = False        # top-1 all_to_all dispatch (vs gather+psum)
    vocab_pad_to: int = 128
    split_cache: bool = False    # per-window KV cache sizes (hillclimb variant)
    unroll: bool = False         # python-loop layers instead of lax.scan —
    # identical math; used by the roofline dry-run because XLA cost_analysis
    # counts a while-loop body ONCE regardless of trip count

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_to
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def layer_windows(self) -> tuple[int, ...]:
        """Per-layer sliding window (0 = global)."""
        if self.sliding_window and self.global_every:
            return tuple(0 if (l + 1) % self.global_every == 0
                         else self.sliding_window
                         for l in range(self.n_layers))
        if self.sliding_window:
            return (self.sliding_window,) * self.n_layers
        return (0,) * self.n_layers

    def param_count(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        attn = d * self.n_heads * self.head_dim * 2 \
            + d * self.n_kv_heads * self.head_dim * 2
        dense_ffn = 3 * d * f
        moe_ffn = self.n_experts * 3 * d * f + d * self.n_experts
        if self.shared_expert:
            moe_ffn += 3 * d * f
        n_moe = self.n_layers // self.moe_every if self.moe else 0
        n_dense = self.n_layers - n_moe
        total = self.n_layers * (attn + 2 * d) \
            + n_dense * dense_ffn + n_moe * moe_ffn + d
        total += v * d * (1 if self.tie_embeddings else 2)
        return total


@dataclasses.dataclass(frozen=True)
class MACEConfig:
    name: str = "mace"
    n_layers: int = 2
    d_hidden: int = 128
    l_max: int = 2
    correlation_order: int = 3
    n_rbf: int = 8
    r_cut: float = 5.0
    n_species: int = 16
    d_feat_in: int = 0           # raw node-attribute dim (projected to species emb)
    param_dtype: str = "float32"
    compute_dtype: str = "float32"  # equivariance is precision-sensitive
    exchange_dtype: str = "float32"  # node-feature all-gather wire dtype
    # ("bfloat16" halves the dominant collective + h_full transient — §Perf)


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str = ""
    model: str = ""              # dlrm | autoint | widedeep | mind
    n_dense: int = 0
    n_sparse: int = 0
    embed_dim: int = 0
    table_sizes: tuple[int, ...] = ()
    multi_hot: int = 1           # ids per sparse field (bag size)
    bot_mlp: tuple[int, ...] = ()
    top_mlp: tuple[int, ...] = ()
    mlp: tuple[int, ...] = ()
    # autoint
    n_attn_layers: int = 0
    n_attn_heads: int = 0
    d_attn: int = 0
    # mind
    n_interests: int = 0
    capsule_iters: int = 0
    hist_len: int = 50
    item_vocab: int = 1_000_000
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    row_pad_to: int = 256        # pad table rows for even tp sharding


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    """A registered architecture: config + its shape cells + metadata."""

    arch_id: str
    family: str                  # lm | gnn | recsys
    config: object
    cells: tuple[ShapeCell, ...]
    notes: str = ""

"""mace [gnn] — n_layers=2 d_hidden=128 l_max=2 correlation_order=3 n_rbf=8,
E(3)-equivariant ACE message passing. [arXiv:2206.07697; paper]

Shape cells are generic-GNN datasets (the assignment pairs MACE with them):
  full_graph_sm  = Cora-like   (2708 nodes / 10556 edges / 1433 feats, 7 cls)
  minibatch_lg   = Reddit-like (232965 nodes / 114.6M edges, fanout 15-10,
                   602 feats, 41 cls) — REAL CSR neighbor sampler in data/
  ogb_products   = 2.45M nodes / 61.86M edges / 100 feats, 47 cls
  molecule       = 128 graphs x 30 nodes x 64 edges, energy (+forces) target
Positions are synthesized for the citation/product graphs (MACE is geometric);
node attributes enter through cfg.d_feat_in -> species-embedding projection.
"""
from repro.configs.base import ArchSpec, MACEConfig, ShapeCell

CONFIG = MACEConfig(
    name="mace",
    n_layers=2,
    d_hidden=128,
    l_max=2,
    correlation_order=3,
    n_rbf=8,
    r_cut=5.0,
    n_species=16,
)

CELLS = (
    ShapeCell("full_graph_sm", "train", n_nodes=2708, n_edges=10556,
              d_feat=1433),
    ShapeCell("minibatch_lg", "train", n_nodes=232965, n_edges=114615892,
              batch_nodes=1024, fanout=(15, 10), d_feat=602),
    ShapeCell("ogb_products", "train", n_nodes=2449029, n_edges=61859140,
              d_feat=100),
    ShapeCell("molecule", "train", n_nodes=30, n_edges=64, n_graphs=128),
)

N_CLASSES = {"full_graph_sm": 7, "minibatch_lg": 41, "ogb_products": 47,
             "molecule": 0}

ARCH = ArchSpec(arch_id="mace", family="gnn", config=CONFIG, cells=CELLS)

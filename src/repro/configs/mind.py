"""mind [recsys] — embed_dim=64 n_interests=4 capsule_iters=3
interaction=multi-interest (B2I dynamic routing). [arXiv:1904.08030; unverified]

1M-item catalog; retrieval_cand scores all 1M items against the 4 user
interest capsules — THE cell where the paper's RPF index plugs in
(brute-force fused matmul_topk vs forest-pruned rerank; EXPERIMENTS.md §Perf).
"""
from repro.configs.base import ArchSpec, RecsysConfig, ShapeCell

CONFIG = RecsysConfig(
    name="mind",
    model="mind",
    embed_dim=64,
    n_interests=4,
    capsule_iters=3,
    hist_len=50,
    item_vocab=1_000_000,
    table_sizes=(1_000_000,),
)

CELLS = (
    ShapeCell("train_batch", "train", batch=65536),
    ShapeCell("serve_p99", "serve", batch=512),
    ShapeCell("serve_bulk", "serve", batch=262144),
    ShapeCell("retrieval_cand", "retrieval", batch=1, n_candidates=1_000_000),
)

ARCH = ArchSpec(arch_id="mind", family="recsys", config=CONFIG, cells=CELLS)

"""wide-deep [recsys] — n_sparse=40 embed_dim=32 mlp=1024-512-256
interaction=concat. [arXiv:1606.07792; paper]

40 fields: 8 high-cardinality hashed (1M), 16 medium (10k), 16 small (100) —
the Google-Play-style app/impression feature mix from the paper.
"""
from repro.configs.base import ArchSpec, RecsysConfig, ShapeCell

TABLE_SIZES = tuple([1_000_000] * 8 + [10_000] * 16 + [100] * 16)

CONFIG = RecsysConfig(
    name="wide-deep",
    model="widedeep",
    n_sparse=40,
    embed_dim=32,
    table_sizes=TABLE_SIZES,
    mlp=(1024, 512, 256),
    row_pad_to=2048,     # divisible by 512 chips for all-axis row sharding
)

CELLS = (
    ShapeCell("train_batch", "train", batch=65536),
    ShapeCell("serve_p99", "serve", batch=512),
    ShapeCell("serve_bulk", "serve", batch=262144),
    ShapeCell("retrieval_cand", "retrieval", batch=1, n_candidates=1_000_000),
)

ARCH = ArchSpec(arch_id="wide-deep", family="recsys", config=CONFIG,
                cells=CELLS)

"""gemma3-4b [dense] — 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144,
5:1 local:global attention, 128k+ context. [hf:google/gemma-3-1b-pt; unverified]

head_dim=256 (gemma convention; the q/k/v projections are rectangular).
Every 6th layer is global, the rest use a 1024-token sliding window — which
makes long_500k decode tractable (5/6 of layers touch a bounded window):
this is the ONE assigned LM arch that runs the long_500k cell.
"""
from repro.configs.base import ArchSpec, LMConfig, ShapeCell

CONFIG = LMConfig(
    name="gemma3-4b",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    sliding_window=1024,
    global_every=6,
    attn_shard="sequence",
    rope_base=1000000.0,
    logit_softcap=0.0,
    tie_embeddings=True,
)

CELLS = (
    ShapeCell("train_4k", "train", seq_len=4096, global_batch=256),
    ShapeCell("prefill_32k", "prefill", seq_len=32768, global_batch=32),
    ShapeCell("decode_32k", "decode", seq_len=32768, global_batch=128),
    ShapeCell("long_500k", "decode", seq_len=524288, global_batch=1),
)

ARCH = ArchSpec(arch_id="gemma3-4b", family="lm", config=CONFIG, cells=CELLS)

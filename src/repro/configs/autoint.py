"""autoint [recsys] — n_sparse=39 embed_dim=16 n_attn_layers=3 n_heads=2
d_attn=32 interaction=self-attn. [arXiv:1810.11921; paper]

39 fields = 13 bucketized-numeric (64 buckets each) + 26 categorical hashed
to <=100k (the paper hashes rare values; sizes below mirror Criteo post-hash).
"""
from repro.configs.base import ArchSpec, RecsysConfig, ShapeCell

TABLE_SIZES = tuple([64] * 13 + [
    100000, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 100000,
    100000, 100000, 10, 2208, 11938, 155, 4, 976, 14, 100000,
    100000, 100000, 100000, 12972, 108, 36,
])

CONFIG = RecsysConfig(
    name="autoint",
    model="autoint",
    n_sparse=39,
    embed_dim=16,
    table_sizes=TABLE_SIZES,
    n_attn_layers=3,
    n_attn_heads=2,
    d_attn=32,
)

CELLS = (
    ShapeCell("train_batch", "train", batch=65536),
    ShapeCell("serve_p99", "serve", batch=512),
    ShapeCell("serve_bulk", "serve", batch=262144),
    ShapeCell("retrieval_cand", "retrieval", batch=1, n_candidates=1_000_000),
)

ARCH = ArchSpec(arch_id="autoint", family="recsys", config=CONFIG, cells=CELLS)

"""dlrm-mlperf [recsys] — n_dense=13 n_sparse=26 embed_dim=128
bot_mlp=13-512-256-128 top_mlp=1024-1024-512-256-1 interaction=dot.
MLPerf DLRM benchmark config (Criteo 1TB). [arXiv:1906.00091; paper]

Table sizes are the 26 Criteo-Terabyte categorical cardinalities from the
MLPerf reference implementation (~187.8M rows total -> 24B embedding params
at dim 128). Big tables (>=1M rows) are row-sharded over ALL mesh axes.
"""
from repro.configs.base import ArchSpec, RecsysConfig, ShapeCell

# MLPerf/Criteo-1TB categorical cardinalities (facebookresearch/dlrm reference)
TABLE_SIZES = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36,
)

CONFIG = RecsysConfig(
    name="dlrm-mlperf",
    model="dlrm",
    n_dense=13,
    n_sparse=26,
    embed_dim=128,
    table_sizes=TABLE_SIZES,
    bot_mlp=(512, 256, 128),
    top_mlp=(1024, 1024, 512, 256, 1),
    row_pad_to=2048,     # divisible by 512 chips for all-axis row sharding
)

CELLS = (
    ShapeCell("train_batch", "train", batch=65536),
    ShapeCell("serve_p99", "serve", batch=512),
    ShapeCell("serve_bulk", "serve", batch=262144),
    ShapeCell("retrieval_cand", "retrieval", batch=1, n_candidates=1_000_000),
)

ARCH = ArchSpec(arch_id="dlrm-mlperf", family="recsys", config=CONFIG,
                cells=CELLS)

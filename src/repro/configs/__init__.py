"""Architecture registry: --arch <id> -> ArchSpec (config + shape cells)."""
from __future__ import annotations

from repro.configs import (autoint, dlrm_mlperf, gemma3_4b, granite_moe_1b,
                           llama4_maverick_400b, mace_arch, mind,
                           rpf_iss595, rpf_mnist784, smollm_135m,
                           stablelm_12b, wide_deep)
from repro.configs.base import ArchSpec

_MODULES = [
    llama4_maverick_400b, granite_moe_1b, smollm_135m, stablelm_12b,
    gemma3_4b, mace_arch, mind, dlrm_mlperf, autoint, wide_deep,
    rpf_mnist784, rpf_iss595,
]

REGISTRY: dict[str, ArchSpec] = {m.ARCH.arch_id: m.ARCH for m in _MODULES}

# the 10 assigned architectures (the 2 rpf-* entries are the paper's own)
ASSIGNED = [
    "llama4-maverick-400b-a17b", "granite-moe-1b-a400m", "smollm-135m",
    "stablelm-12b", "gemma3-4b", "mace", "mind", "dlrm-mlperf", "autoint",
    "wide-deep",
]


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(REGISTRY)}")
    return REGISTRY[arch_id]


def list_archs() -> list[str]:
    return sorted(REGISTRY)

"""smollm-135m [dense] — 30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152,
llama-arch small, tied embeddings. [hf:HuggingFaceTB/SmolLM-135M; hf]

9 heads / 3 KV heads don't divide tp=16 -> sequence-sharded attention.
"""
from repro.configs.base import ArchSpec, LMConfig, ShapeCell

CONFIG = LMConfig(
    name="smollm-135m",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    head_dim=64,
    d_ff=1536,
    vocab_size=49152,
    attn_shard="sequence",
    tie_embeddings=True,
)

CELLS = (
    ShapeCell("train_4k", "train", seq_len=4096, global_batch=256),
    ShapeCell("prefill_32k", "prefill", seq_len=32768, global_batch=32),
    ShapeCell("decode_32k", "decode", seq_len=32768, global_batch=128),
    ShapeCell("long_500k", "decode", seq_len=524288, global_batch=1,
              skip=True,
              skip_reason="pure full attention; no sub-quadratic structure "
                          "(DESIGN.md §5)"),
)

ARCH = ArchSpec(arch_id="smollm-135m", family="lm", config=CONFIG, cells=CELLS)

"""granite-moe-1b-a400m [moe] — 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32e top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

vocab 49155 is not divisible by tp=16 -> padded to 49280 (Megatron-style
vocab padding; logits masked in the loss).
"""
from repro.configs.base import ArchSpec, LMConfig, ShapeCell

CONFIG = LMConfig(
    name="granite-moe-1b-a400m",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    moe=True,
    n_experts=32,
    top_k=8,
    moe_every=1,
    capacity_factor=1.25,
    attn_shard="heads",
    tie_embeddings=True,
)

CELLS = (
    ShapeCell("train_4k", "train", seq_len=4096, global_batch=256),
    ShapeCell("prefill_32k", "prefill", seq_len=32768, global_batch=32),
    ShapeCell("decode_32k", "decode", seq_len=32768, global_batch=128),
    ShapeCell("long_500k", "decode", seq_len=524288, global_batch=1,
              skip=True,
              skip_reason="pure full attention; no sub-quadratic structure "
                          "(DESIGN.md §5)"),
)

ARCH = ArchSpec(arch_id="granite-moe-1b-a400m", family="lm", config=CONFIG,
                cells=CELLS)

"""The paper's ISS-595 3-D shape descriptor experiment (Zhong 2015, §4/Fig. 5).

N=250736 descriptors from 72 vehicle models, 595-D non-negative histograms,
chi-square divergence; C=12, r=0.3, K=1; L swept; recall@1 vs exact NN;
plus the 81x-speedup-at-96%-recall wall-clock claim (speedup_table bench).
"""
from repro.configs.base import ArchSpec, ShapeCell
from repro.core.forest import ForestConfig

CONFIG = ForestConfig(n_trees=160, capacity=12, split_ratio=0.3, n_proj=1)

L_SWEEP = (10, 20, 40, 80, 160, 320)
N_DB = 250_736
N_TEST = 30_000
DIM = 595
METRIC = "chi2"
N_MODELS = 72

CELLS = (
    ShapeCell("index_build", "train", batch=N_DB),
    ShapeCell("query_batch", "serve", batch=1024),
)

ARCH = ArchSpec(arch_id="rpf-iss595", family="ann", config=CONFIG,
                cells=CELLS, notes="paper Fig. 5 + 81x speedup reproduction")

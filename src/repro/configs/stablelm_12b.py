"""stablelm-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352. [hf:stabilityai/stablelm-2-1_6b; hf]
"""
from repro.configs.base import ArchSpec, LMConfig, ShapeCell

CONFIG = LMConfig(
    name="stablelm-12b",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab_size=100352,
    attn_shard="heads",
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    fsdp=True,
)

CELLS = (
    ShapeCell("train_4k", "train", seq_len=4096, global_batch=256),
    ShapeCell("prefill_32k", "prefill", seq_len=32768, global_batch=32),
    ShapeCell("decode_32k", "decode", seq_len=32768, global_batch=128),
    ShapeCell("long_500k", "decode", seq_len=524288, global_batch=1,
              skip=True,
              skip_reason="pure full attention; no sub-quadratic structure "
                          "(DESIGN.md §5)"),
)

ARCH = ArchSpec(arch_id="stablelm-12b", family="lm", config=CONFIG, cells=CELLS)

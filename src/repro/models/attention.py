"""Grouped-query attention with causal / sliding-window masks and KV cache.

Sharding modes (chosen per-arch by the config, see DESIGN.md §3.2):
  * "heads":    q-heads sharded over tp (requires n_heads % tp == 0); KV heads
                replicated (GQA KV is small).
  * "sequence": query positions sharded over tp (context parallelism) — used
                when head counts don't divide the tp degree (llama4 40H,
                gemma3 8H, smollm 9H on tp=16). K/V are all-gathered, scores
                are (B, H, S/tp, S).
The mode only changes sharding constraints — the math is identical.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import Axes, apply_rope, dense_init

NEG_INF = -2.0**30  # large-but-finite: keeps softmax well-defined on all-masked rows


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, dtype) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, n_kv_heads * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, n_kv_heads * head_dim, dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model, dtype),
    }


def attention_specs(axes: Axes, shard_mode: str, fsdp: bool = False) -> dict:
    """PartitionSpec tree matching init_attention's output.

    "heads": Megatron-style — wq column-sharded over tp, wo row-sharded; GQA
    KV projections replicated (they are small and tp rarely divides n_kv).
    "sequence": weights sharded the same way (the q-head dim still divides tp
    times head groups at the matmul level); the *activation* constraints in
    transformer.py move the sharding to the sequence axis for the attention
    math itself.  FSDP additionally shards the first weight axis over dp.
    """
    tp = axes.tp
    fs = tuple(axes.dp) if fsdp else None
    return {"wq": P(fs, tp), "wk": P(fs, None), "wv": P(fs, None),
            "wo": P(tp, fs)}


class KVCache(NamedTuple):
    k: jax.Array  # (B, S_max, KV, Dh)
    v: jax.Array  # (B, S_max, KV, Dh)


def _mask(q_pos: jax.Array, k_pos: jax.Array, window: jax.Array | int
          ) -> jax.Array:
    """causal + optional sliding window; window<=0 means global (causal only).

    q_pos: (Sq,), k_pos: (Sk,) absolute positions. Returns (Sq, Sk) bool.
    """
    causal = q_pos[:, None] >= k_pos[None, :]
    dist = q_pos[:, None] - k_pos[None, :]
    win = jnp.asarray(window, jnp.int32)
    windowed = jnp.where(win > 0, dist < win, True)
    return causal & windowed


def _sdpa(q, k, v, mask, softcap: float = 0.0):
    """q (B,Sq,H,Dh), k/v (B,Sk,KV,Dh) GQA scaled-dot-product, f32 softmax."""
    b, sq, h, dh = q.shape
    kv = k.shape[2]
    groups = h // kv
    qg = q.reshape(b, sq, kv, groups, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(dh).astype(jnp.float32)
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    scores = jnp.where(mask[None, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, sq, h, dh)


def _sdpa_blockwise(q, k, v, q_pos, k_pos, window, softcap: float = 0.0,
                    kv_block: int = 1024, extra_kmask=None,
                    unroll: bool = False):
    """FlashAttention-style streaming softmax over KV blocks (pure jnp).

    Never materializes the (Sq, Skv) score matrix: a scan over KV blocks
    carries the running (max, normalizer, weighted-accumulator).  This is the
    beyond-paper memory optimization for the 32k prefill / train cells
    (EXPERIMENTS.md §Perf): live attention memory drops from O(Sq*Skv) to
    O(Sq*kv_block).

    q (B,Sq,H,Dh); k/v (B,Skv,KV,Dh); q_pos (Sq,); k_pos (Skv,).
    ``extra_kmask`` (Skv,) optionally invalidates cache slots.
    """
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    kvh = k.shape[2]
    groups = h // kvh
    kv_block = min(kv_block, skv)
    assert skv % kv_block == 0, "pad the KV length to the block size"
    nb = skv // kv_block

    qg = q.reshape(b, sq, kvh, groups, dh)
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    kb = k.reshape(b, nb, kv_block, kvh, dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nb, kv_block, kvh, dh).transpose(1, 0, 2, 3, 4)
    kpb = k_pos.reshape(nb, kv_block)
    emb = (extra_kmask.reshape(nb, kv_block) if extra_kmask is not None
           else jnp.ones((nb, kv_block), bool))

    def block(carry, xs):
        m, l, acc = carry
        k_blk, v_blk, kp_blk, em_blk = xs
        s = jnp.einsum("bskgd,btkd->bkgst", qg, k_blk,
                       preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        msk = _mask(q_pos, kp_blk, window) & em_blk[None, :]
        s = jnp.where(msk[None, None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)                       # (b,kv,g,sq)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgst,btkd->bkgsd", p.astype(v_blk.dtype), v_blk)
        acc_new = acc * alpha[..., None] + pv.astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, groups, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, groups, sq), jnp.float32)
    a0 = jnp.zeros((b, kvh, groups, sq, dh), jnp.float32)
    if unroll:
        carry = (m0, l0, a0)
        for i in range(nb):
            carry, _ = block(carry, (kb[i], vb[i], kpb[i], emb[i]))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(block, (m0, l0, a0),
                                      (kb, vb, kpb, emb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]         # (b,kv,g,sq,dh)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dh)
    return out.astype(v.dtype)


def attention_fwd(params: dict, x: jax.Array, positions: jax.Array,
                  window: jax.Array | int, *, n_heads: int, n_kv_heads: int,
                  head_dim: int, rope_base: float, softcap: float = 0.0,
                  cache: KVCache | None = None,
                  cache_pos: jax.Array | None = None,
                  attn_impl: str = "dense", kv_block: int = 1024,
                  unroll: bool = False):
    """Full-sequence (training/prefill) or single-token (decode) attention.

    x: (B, S, D). If ``cache`` is given, x is the new chunk (S=1 for decode);
    K/V are written at ``cache_pos`` and attention runs against the cache.
    attn_impl "blockwise" streams KV blocks with a running softmax (flash-
    attention memory profile); "dense" materializes the score matrix.
    Returns (out (B, S, D), new_cache).
    """
    b, s, _ = x.shape
    q = (x @ params["wq"]).reshape(b, s, n_heads, head_dim)
    k = (x @ params["wk"]).reshape(b, s, n_kv_heads, head_dim)
    v = (x @ params["wv"]).reshape(b, s, n_kv_heads, head_dim)
    q = apply_rope(q, positions, rope_base)
    k = apply_rope(k, positions, rope_base)

    if cache is None:
        if attn_impl == "blockwise":
            out = _sdpa_blockwise(q, k, v, positions,
                                  positions.astype(jnp.int32), window,
                                  softcap, kv_block, unroll=unroll)
        else:
            mask = _mask(positions, positions, window)
            out = _sdpa(q, k, v, mask, softcap)
        new_cache = None
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k.astype(cache.k.dtype), cache_pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v.astype(cache.v.dtype), cache_pos, axis=1)
        new_cache = KVCache(ck, cv)
        k_pos = jnp.arange(ck.shape[1], dtype=jnp.int32)
        written = k_pos <= cache_pos + s - 1   # not-yet-written cache slots
        if attn_impl == "blockwise":
            out = _sdpa_blockwise(q, ck, cv, positions, k_pos, window,
                                  softcap, kv_block, extra_kmask=written,
                                  unroll=unroll)
        else:
            mask = _mask(positions, k_pos, window) & written[None, :]
            out = _sdpa(q, ck, cv, mask, softcap)

    out = out.reshape(b, s, n_heads * head_dim) @ params["wo"]
    return out, new_cache

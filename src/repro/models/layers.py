"""Core NN layers as pure functions over explicit param pytrees (no flax).

Every init_* returns a dict of arrays; every *_specs returns the matching
PartitionSpec tree given the mesh Axes. Compute dtype is configurable; params
are kept in ``param_dtype`` and cast at use.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True, eq=False)
class Axes:
    """Mesh axis naming: dp = batch/data axes (includes 'pod' when multi-pod),
    tp = tensor-model axis.  ``mesh`` (optional) enables shard_map-based
    subroutines (the expert-parallel MoE dispatch needs the Mesh object)."""

    dp: tuple[str, ...] = ("data",)
    tp: str = "model"
    mesh: object = None


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
            ).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
            ).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(dt)


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * gamma.astype(jnp.float32) + beta.astype(jnp.float32)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, base: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (base ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, base: float) -> jax.Array:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], base)                       # (half,)
    angle = positions[..., None].astype(jnp.float32) * freqs    # (..., S, half)
    cos = jnp.cos(angle)[..., None, :]                          # (..., S, 1, half)
    sin = jnp.sin(angle)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin,
                           x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: jax.Array | None = None,
                          z_loss: float = 0.0) -> jax.Array:
    """logits (..., V) f32-upcast CE with optional z-loss; labels int (...,)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * lse**2
    if mask is not None:
        return jnp.sum(loss * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(loss)


def pad_vocab(v: int, multiple: int) -> int:
    return ((v + multiple - 1) // multiple) * multiple

"""Decoder-only transformer LM: dense / MoE / interleaved, scan-over-layers.

Structure modes (static, derived from the config):
  * "dense"     — scan over n_layers of (attn + SwiGLU FFN); per-layer sliding
                  window sizes are scanned-over data (gemma3's 5:1 local:global
                  pattern is an array, not a structural change).
  * "moe"       — scan over n_layers of (attn + MoE FFN)          (granite)
  * "dense_moe" — scan over n_layers/2 groups of [dense, moe]     (llama4)

Params are stacked along the scan axis; remat wraps each block. All sharding
is expressed as PartitionSpecs on params + with_sharding_constraint on the
residual stream; pass axes=None (smoke tests / CPU) to skip constraints.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import LMConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models.attention import KVCache
from repro.models.layers import (Axes, dense_init, dtype_of, embed_init,
                                 pad_vocab, rms_norm, softmax_cross_entropy)


def structure(cfg: LMConfig) -> str:
    if cfg.moe and cfg.moe_every == 2:
        return "dense_moe"
    if cfg.moe:
        return "moe"
    return "dense"


def _constrain(x, axes: Optional[Axes], spec: P):
    if axes is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_dense_block(key, cfg: LMConfig, dtype):
    ks = jax.random.split(key, 4)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": attn_mod.init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                        cfg.n_kv_heads, cfg.head_dim, dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "ffn": {
            "w_gate": dense_init(ks[1], cfg.d_model, cfg.d_ff, dtype),
            "w_up": dense_init(ks[2], cfg.d_model, cfg.d_ff, dtype),
            "w_down": dense_init(ks[3], cfg.d_ff, cfg.d_model, dtype),
        },
    }


def _init_moe_block(key, cfg: LMConfig, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": attn_mod.init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                        cfg.n_kv_heads, cfg.head_dim, dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "moe": moe_mod.init_moe(ks[1], cfg.d_model, cfg.d_ff, cfg.n_experts,
                                dtype, cfg.shared_expert),
    }


def init_lm(key, cfg: LMConfig) -> dict:
    dtype = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    vpad = cfg.padded_vocab
    struct = structure(cfg)
    if struct == "dense":
        layer_keys = jax.random.split(ks[0], cfg.n_layers)
        layers = jax.vmap(lambda k: _init_dense_block(k, cfg, dtype))(layer_keys)
    elif struct == "moe":
        layer_keys = jax.random.split(ks[0], cfg.n_layers)
        layers = jax.vmap(lambda k: _init_moe_block(k, cfg, dtype))(layer_keys)
    else:  # dense_moe: groups of [dense, moe]
        n_groups = cfg.n_layers // 2
        gk = jax.random.split(ks[0], n_groups)
        layers = jax.vmap(lambda k: {
            "dense": _init_dense_block(jax.random.fold_in(k, 0), cfg, dtype),
            "moe": _init_moe_block(jax.random.fold_in(k, 1), cfg, dtype),
        })(gk)
    params = {
        "embed": embed_init(ks[1], vpad, cfg.d_model, dtype),
        "layers": layers,
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(ks[2], cfg.d_model, vpad, dtype,
                                       scale=0.02)
    return params


def lm_param_specs(cfg: LMConfig, axes: Axes) -> dict:
    """PartitionSpec tree matching init_lm's output."""
    tp = axes.tp
    fs = tuple(axes.dp) if cfg.fsdp else None
    a_specs = attn_mod.attention_specs(axes, cfg.attn_shard, cfg.fsdp)
    dense_block = {
        "ln1": P(None), "attn": a_specs, "ln2": P(None),
        "ffn": {"w_gate": P(fs, tp), "w_up": P(fs, tp), "w_down": P(tp, fs)},
    }
    moe_block = {
        "ln1": P(None), "attn": a_specs, "ln2": P(None),
        "moe": moe_mod.moe_specs(axes, cfg.shared_expert, cfg.fsdp,
                                 cfg.expert_fsdp),
    }

    def stack(spec_tree):
        return jax.tree.map(lambda s: P(None, *s), spec_tree,
                            is_leaf=lambda x: isinstance(x, P))

    struct = structure(cfg)
    if struct == "dense":
        layers = stack(dense_block)
    elif struct == "moe":
        layers = stack(moe_block)
    else:
        layers = {"dense": stack(dense_block), "moe": stack(moe_block)}
    specs = {
        "embed": P(tp, None),           # vocab-sharded (Megatron-style)
        "layers": layers,
        "final_norm": P(None),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = P(None, tp)
    return specs


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def _cast(p, dtype):
    """Cast a param subtree to the compute dtype (norm/router math re-upcasts
    internally where precision matters)."""
    return jax.tree.map(lambda a: a.astype(dtype), p)


def _ffn(p, x):
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


def _attn_kwargs(cfg: LMConfig):
    return dict(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.head_dim, rope_base=cfg.rope_base,
                attn_impl=cfg.attn_impl, kv_block=cfg.kv_block,
                unroll=cfg.unroll)


def _act_spec(cfg: LMConfig, axes: Optional[Axes],
              x: Optional[jax.Array] = None) -> P:
    """Residual-stream sharding, degrading gracefully for non-divisible dims
    (decode has S=1; long-context decode has B=1)."""
    if axes is None:
        return P()
    dp = tuple(axes.dp)
    bspec, sspec = dp, None
    if x is not None and axes.mesh is not None:
        dpn = 1
        for a in dp:
            dpn *= axes.mesh.shape[a]
        if x.shape[0] % dpn:
            bspec = None
        if cfg.attn_shard == "sequence" \
                and x.shape[1] % axes.mesh.shape[axes.tp] == 0:
            sspec = axes.tp
    elif cfg.attn_shard == "sequence":
        sspec = axes.tp
    return P(bspec, sspec, None)


def _dense_block_fwd(p, x, positions, window, cfg: LMConfig,
                     axes: Optional[Axes], cache=None, cache_pos=None):
    p = _cast(p, x.dtype)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    a, new_cache = attn_mod.attention_fwd(
        p["attn"], h, positions, window, softcap=cfg.logit_softcap,
        cache=cache, cache_pos=cache_pos, **_attn_kwargs(cfg))
    x = _constrain(x + a, axes, _act_spec(cfg, axes, x))
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = _constrain(x + _ffn(p["ffn"], h), axes, _act_spec(cfg, axes, x))
    return x, new_cache


def _moe_block_fwd(p, x, positions, window, cfg: LMConfig,
                   axes: Optional[Axes], cache=None, cache_pos=None):
    p = _cast(p, x.dtype)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    a, new_cache = attn_mod.attention_fwd(
        p["attn"], h, positions, window, softcap=cfg.logit_softcap,
        cache=cache, cache_pos=cache_pos, **_attn_kwargs(cfg))
    x = _constrain(x + a, axes, _act_spec(cfg, axes, x))
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    b, s, d = h.shape
    t_tokens = h.shape[0] * h.shape[1]
    dpn = 1
    if axes is not None and axes.mesh is not None:
        for a_ in axes.dp:
            dpn *= axes.mesh.shape[a_]
    tpn = 1 if axes is None or axes.mesh is None else \
        axes.mesh.shape[axes.tp]
    if axes is not None and axes.mesh is not None and cfg.moe_a2a \
            and cfg.top_k == 1 and t_tokens % (dpn * tpn) == 0:
        # §Perf iteration: top-1 all_to_all dispatch — tokens stay dp x tp
        # sharded end-to-end (no (B,S,D) gather / psum per layer)
        out, aux = moe_mod.moe_fwd_a2a(
            p["moe"], h.reshape(b * s, d), n_experts=cfg.n_experts,
            capacity_factor=cfg.capacity_factor, axes=axes, fsdp=cfg.fsdp,
            gather_quant=cfg.moe_gather_quant)
    elif axes is not None and axes.mesh is not None \
            and t_tokens % dpn == 0:
        # production expert-parallel dispatch (explicit shard_map collectives)
        out, aux = moe_mod.moe_fwd_sharded(
            p["moe"], h.reshape(b * s, d), n_experts=cfg.n_experts,
            top_k=cfg.top_k, capacity_factor=cfg.capacity_factor, axes=axes,
            fsdp=cfg.fsdp, expert_fsdp=cfg.expert_fsdp,
            gather_quant=cfg.moe_gather_quant)
    else:
        out, aux = moe_mod.moe_fwd(
            p["moe"], h.reshape(b * s, d), n_experts=cfg.n_experts,
            top_k=cfg.top_k, capacity_factor=cfg.capacity_factor, axes=axes)
    x = _constrain(x + out.reshape(b, s, d), axes, _act_spec(cfg, axes, x))
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# forward (training / prefill, full sequence)
# ---------------------------------------------------------------------------


def forward(params: dict, tokens: jax.Array, cfg: LMConfig,
            axes: Optional[Axes] = None) -> tuple[jax.Array, jax.Array]:
    """tokens (B, S) -> (logits (B, S, Vpad) f32, aux_loss scalar)."""
    compute_dtype = dtype_of(cfg.compute_dtype)
    x, aux = forward_hidden(params, tokens, cfg, axes)
    unembed = (params["embed"].T if cfg.tie_embeddings
               else params["unembed"]).astype(compute_dtype)
    logits = (x @ unembed).astype(jnp.float32)
    if axes is not None:
        logits = jax.lax.with_sharding_constraint(
            logits, P(tuple(axes.dp), None, axes.tp))
    return logits, aux


def forward_hidden(params: dict, tokens: jax.Array, cfg: LMConfig,
                   axes: Optional[Axes] = None) -> tuple[jax.Array, jax.Array]:
    """Like forward() but stops before the unembedding: (hidden, aux)."""
    compute_dtype = dtype_of(cfg.compute_dtype)
    x = params["embed"][tokens].astype(compute_dtype)
    x = _constrain(x, axes, _act_spec(cfg, axes, x))
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    windows = jnp.asarray(cfg.layer_windows, jnp.int32)
    struct = structure(cfg)
    if struct == "dense":
        def block(x, xs):
            p, w = xs
            x, _ = _dense_block_fwd(p, x, positions, w, cfg, axes)
            return x, jnp.zeros((), jnp.float32)
    elif struct == "moe":
        def block(x, xs):
            p, w = xs
            x, _, aux = _moe_block_fwd(p, x, positions, w, cfg, axes)
            return x, aux
    else:
        windows = windows.reshape(cfg.n_layers // 2, 2)

        def block(x, xs):
            p, w = xs
            x, _ = _dense_block_fwd(p["dense"], x, positions, w[0], cfg, axes)
            x, _, aux = _moe_block_fwd(p["moe"], x, positions, w[1], cfg, axes)
            return x, aux
    if cfg.remat:
        block = jax.checkpoint(block)
    if cfg.unroll:
        aux_sum = jnp.zeros((), jnp.float32)
        n = windows.shape[0]
        for i in range(n):
            p_i = jax.tree.map(lambda a: a[i], params["layers"])
            x, aux_i = block(x, (p_i, windows[i]))
            aux_sum = aux_sum + aux_i
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, aux_sum
    x, auxes = jax.lax.scan(block, x, (params["layers"], windows))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, jnp.sum(auxes)


def chunked_cross_entropy(x: jax.Array, unembed: jax.Array, labels: jax.Array,
                          vocab_size: int, chunk: int,
                          axes: Optional[Axes] = None,
                          unroll: bool = False) -> jax.Array:
    """CE without materializing (B, S, V) logits: scan over sequence chunks,
    rematerializing each chunk's logits in the backward pass.  Essential for
    200k-vocab x 1M-token training steps (DESIGN.md §7)."""
    b, s, d = x.shape
    n = s // chunk
    vpad = unembed.shape[1]
    neg = jnp.where(jnp.arange(vpad) < vocab_size, 0.0, -1e9)

    xs = x.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(tot, xc_lc):
        xc, lc = xc_lc
        logits = (xc @ unembed).astype(jnp.float32) + neg
        if axes is not None:
            logits = jax.lax.with_sharding_constraint(
                logits, P(tuple(axes.dp), None, axes.tp))
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(lse - ll), None

    if unroll:
        total = jnp.zeros((), jnp.float32)
        for i in range(n):
            total, _ = body(total, (xs[i], ls[i]))
        return total / (b * s)
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ls))
    return total / (b * s)


def loss_fn(params: dict, batch: dict, cfg: LMConfig,
            axes: Optional[Axes] = None, aux_weight: float = 0.01,
            logit_chunk: int = 0):
    """logit_chunk > 0 uses the chunked CE path (no (B,S,V) materialization)."""
    if logit_chunk:
        compute_dtype = dtype_of(cfg.compute_dtype)
        x, aux = forward_hidden(params, batch["tokens"], cfg, axes)
        unembed = (params["embed"].T if cfg.tie_embeddings
                   else params["unembed"]).astype(compute_dtype)
        ce = chunked_cross_entropy(x, unembed, batch["labels"],
                                   cfg.vocab_size, logit_chunk, axes,
                                   unroll=cfg.unroll)
        loss = ce + aux_weight * aux
        return loss, {"ce": ce, "aux": aux}
    logits, aux = forward(params, batch["tokens"], cfg, axes)
    # mask out padded vocab entries
    vpad = cfg.padded_vocab
    if vpad != cfg.vocab_size:
        neg = jnp.where(jnp.arange(vpad) < cfg.vocab_size, 0.0, -1e9)
        logits = logits + neg
    mask = batch.get("mask")
    ce = softmax_cross_entropy(logits, batch["labels"], mask)
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# decode (single-token step against a KV cache)
# ---------------------------------------------------------------------------


def init_cache(cfg: LMConfig, batch: int, s_max: int, dtype=jnp.bfloat16
               ) -> KVCache:
    shape = (cfg.n_layers, batch, s_max, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def cache_specs(cfg: LMConfig, axes: Axes) -> KVCache:
    """KV cache sharded over sequence (tp) — decode reads dominate; splitting
    S over tp gives each chip 1/tp of the cache-read bytes."""
    spec = P(None, tuple(axes.dp), axes.tp, None, None)
    return KVCache(spec, spec)


def decode_step(params: dict, cache: KVCache, tokens: jax.Array,
                pos: jax.Array, cfg: LMConfig, axes: Optional[Axes] = None,
                last_only: bool = False) -> tuple[jax.Array, KVCache]:
    """tokens (B, S) at absolute positions pos..pos+S-1 -> (logits, cache).

    S=1 is the decode hot loop; S=seq_len with pos=0 is prefill (pass
    last_only=True to only unembed the final position — unembedding a 32k
    prefill against a 200k vocab would materialize TB-scale logits).

    Activation constraints degrade gracefully for non-shardable dims
    (see _act_spec); the shard_map MoE path is used whenever the token count
    divides the dp size.
    """
    compute_dtype = dtype_of(cfg.compute_dtype)
    x = params["embed"][tokens].astype(compute_dtype)
    positions = pos + jnp.arange(tokens.shape[1], dtype=jnp.int32)
    windows = jnp.asarray(cfg.layer_windows, jnp.int32)
    struct = structure(cfg)

    if struct == "dense":
        def block(x, xs):
            p, w, c = xs
            x, nc = _dense_block_fwd(p, x, positions, w, cfg, axes,
                                     cache=c, cache_pos=pos)
            return x, nc
    elif struct == "moe":
        def block(x, xs):
            p, w, c = xs
            x, nc, _ = _moe_block_fwd(p, x, positions, w, cfg, axes,
                                      cache=c, cache_pos=pos)
            return x, nc
    else:
        windows = windows.reshape(cfg.n_layers // 2, 2)

        def block(x, xs):
            p, w, c = xs
            cd = jax.tree.map(lambda a: a[0], c)
            cm = jax.tree.map(lambda a: a[1], c)
            x, ncd = _dense_block_fwd(p["dense"], x, positions, w[0], cfg,
                                      axes, cache=cd, cache_pos=pos)
            x, ncm, _ = _moe_block_fwd(p["moe"], x, positions, w[1], cfg,
                                       axes, cache=cm, cache_pos=pos)
            nc = jax.tree.map(lambda a, b: jnp.stack([a, b]), ncd, ncm)
            return x, nc

    scan_cache = cache
    if struct == "dense_moe":
        scan_cache = jax.tree.map(
            lambda a: a.reshape(cfg.n_layers // 2, 2, *a.shape[1:]), cache)

    if cfg.unroll:
        caches = []
        for i in range(windows.shape[0]):
            p_i = jax.tree.map(lambda a: a[i], params["layers"])
            c_i = jax.tree.map(lambda a: a[i], scan_cache)
            x, nc_i = block(x, (p_i, windows[i], c_i))
            caches.append(nc_i)
        new_cache = jax.tree.map(lambda *cs: jnp.stack(cs), *caches)
    else:
        x, new_cache = jax.lax.scan(block, x, (params["layers"], windows,
                                               scan_cache))
    if struct == "dense_moe":
        new_cache = jax.tree.map(
            lambda a: a.reshape(cfg.n_layers, *a.shape[2:]), new_cache)

    if last_only:
        x = x[:, -1:, :]
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    unembed = (params["embed"].T if cfg.tie_embeddings
               else params["unembed"]).astype(compute_dtype)
    logits = (x @ unembed).astype(jnp.float32)
    return logits, new_cache

"""MACE: higher-order E(3)-equivariant message passing (arXiv:2206.07697).

TPU-native implementation notes (DESIGN.md §3.2 / kernel_taxonomy §GNN):
  * features are dense (n_nodes, C, M) arrays with M = sum_l (2l+1) = 9 for
    l_max = 2; per-l blocks are static slices — everything is einsum +
    segment_sum (no BCOO, no pointer graph structures);
  * message passing = gather by edge sender + `jax.ops.segment_sum` scatter to
    receivers (THE canonical JAX GNN primitive);
  * the order-nu=3 ACE contraction is two iterated channel-wise CG tensor
    products with learned per-(path, channel) weights — the O(L^6) general
    contraction reduced to a fixed 15-path list for l<=2 (eSCN-style path
    pruning is unnecessary at l_max=2).

Equivariance (energy invariance under global rotations) is asserted by tests.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro import compat
import numpy as np

from repro.configs.base import MACEConfig
from repro.models.equivariant import (L_SLICES, coupling_paths,
                                      real_clebsch_gordan, real_sph_harm_l2)

M_TOT = 9  # sum (2l+1), l <= 2


@functools.lru_cache(maxsize=None)
def _paths_and_cg(l_max: int):
    paths = coupling_paths(l_max)
    cgs = [jnp.asarray(real_clebsch_gordan(*p), jnp.float32) for p in paths]
    return paths, cgs


def bessel_basis(r: jax.Array, n_rbf: int, r_cut: float) -> jax.Array:
    """Radial Bessel basis with smooth cosine cutoff. r: (E,) -> (E, n_rbf)."""
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    rr = jnp.maximum(r, 1e-6)[:, None]
    basis = jnp.sqrt(2.0 / r_cut) * jnp.sin(n * jnp.pi * rr / r_cut) / rr
    env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(r / r_cut, 0, 1)) + 1.0)
    return basis * env[:, None]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_mace(key, cfg: MACEConfig, n_classes: int = 0) -> dict:
    c = cfg.d_hidden
    paths, _ = _paths_and_cg(cfg.l_max)
    n_paths = len(paths)
    ks = jax.random.split(key, 8 + 4 * cfg.n_layers)
    params = {
        "species_embed": jax.random.normal(ks[0], (cfg.n_species, c)) * 0.5,
        "readout_w1": jax.random.normal(ks[1], (c, c)) / np.sqrt(c),
        "readout_w2": jax.random.normal(ks[2], (c, 1)) / np.sqrt(c),
        "layers": [],
    }
    if cfg.d_feat_in:
        params["feat_proj"] = (jax.random.normal(ks[3], (cfg.d_feat_in, c))
                               / np.sqrt(cfg.d_feat_in))
    if n_classes:
        params["cls_head"] = (jax.random.normal(ks[4], (c, n_classes))
                              / np.sqrt(c))
    for i in range(cfg.n_layers):
        k1, k2, k3, k4 = jax.random.split(ks[8 + i], 4)
        layer = {
            # radial MLP: bessel -> hidden -> per-(edge-path, channel) weights
            "radial_w1": jax.random.normal(k1, (cfg.n_rbf, 64)) / np.sqrt(cfg.n_rbf),
            "radial_w2": jax.random.normal(k2, (64, n_paths * c)) / np.sqrt(64.0),
            # channel mixers per l for messages and self-connection
            "mix_msg": jax.random.normal(k3, (cfg.l_max + 1, c, c)) / np.sqrt(c),
            "mix_self": jax.random.normal(k4, (cfg.l_max + 1, c, c)) / np.sqrt(c),
            # learned per-(path, channel) weights for the nu=2 / nu=3 products
            "prod2_w": jnp.ones((n_paths, c)) * 0.3,
            "prod3_w": jnp.ones((n_paths, c)) * 0.1,
        }
        params["layers"].append(layer)
    return params


# ---------------------------------------------------------------------------
# tensor-product helpers
# ---------------------------------------------------------------------------


def _cg_product(a: jax.Array, b: jax.Array, weights: jax.Array, l_max: int
                ) -> jax.Array:
    """Channel-wise weighted CG product of two (..., C, M) feature arrays."""
    paths, cgs = _paths_and_cg(l_max)
    out = jnp.zeros_like(a)
    for p, (l1, l2, l3) in enumerate(paths):
        s1, s2, s3 = L_SLICES[l1], L_SLICES[l2], L_SLICES[l3]
        term = jnp.einsum("abc,...na,...nb->...nc", cgs[p],
                          a[..., s1], b[..., s2])
        out = out.at[..., s3].add(weights[p][:, None] * term)
    return out


def _mix_per_l(x: jax.Array, w: jax.Array, l_max: int) -> jax.Array:
    """Per-l channel mixing: x (..., C, M), w (l_max+1, C, C)."""
    outs = []
    for l in range(l_max + 1):
        s = L_SLICES[l]
        outs.append(jnp.einsum("...cm,cd->...dm", x[..., s], w[l]))
    return jnp.concatenate(outs, axis=-1)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def mace_fwd(params: dict, cfg: MACEConfig, species: jax.Array,
             positions: jax.Array, senders: jax.Array, receivers: jax.Array,
             node_feat: Optional[jax.Array] = None,
             edge_mask: Optional[jax.Array] = None,
             graph_ids: Optional[jax.Array] = None, n_graphs: int = 1,
             axes=None, n_edge_chunks: int = 1, unroll: bool = False) -> dict:
    """species (n,), positions (n,3), senders/receivers (E,).

    Returns {node_inv (n,C), energy (n_graphs,), node_logits?}.

    ``axes`` (models.layers.Axes) adds sharding constraints keeping the big
    per-edge tensors (E, P, C) / (E, C, M) sharded over dp — at ogb_products
    scale those are hundreds of GB if left replicated.

    ``n_edge_chunks`` > 1 streams the per-edge message computation in chunks,
    each wrapped in jax.checkpoint: live memory = one chunk's (E/c, P, C)
    tensors instead of the whole edge set's, in both fwd and bwd (the
    61.9M-edge ogb_products cell is ~30x over HBM without this).  segment_sum
    is additive, so chunked partial scatters are exact.
    """
    from jax.sharding import PartitionSpec as P

    def _c(a):
        if axes is None:
            return a
        spec = P(tuple(axes.dp), *([None] * (a.ndim - 1)))
        return jax.lax.with_sharding_constraint(a, spec)

    n = species.shape[0]
    c = cfg.d_hidden
    paths, _ = _paths_and_cg(cfg.l_max)
    n_paths = len(paths)

    # --- edge geometry ----------------------------------------------------
    rvec = _c(positions[senders] - positions[receivers])      # (E, 3)
    r = jnp.linalg.norm(rvec + 1e-12, axis=-1)
    u = rvec / (r[:, None] + 1e-12)
    sph = _c(real_sph_harm_l2(u))                             # (E, 9)
    rbf = _c(bessel_basis(r, cfg.n_rbf, cfg.r_cut))           # (E, n_rbf)
    if edge_mask is not None:
        rbf = rbf * edge_mask[:, None]

    # --- initial node features (l=0 only) ----------------------------------
    h = jnp.zeros((n, c, M_TOT), jnp.float32)
    h0 = params["species_embed"][species]
    if node_feat is not None and "feat_proj" in params:
        h0 = h0 + node_feat @ params["feat_proj"]
    h = h.at[..., 0].set(h0)

    e_total = senders.shape[0]
    n_chunks = max(1, n_edge_chunks)
    assert e_total % n_chunks == 0, "pad edges to a chunk multiple"
    paths_l, cgs = _paths_and_cg(cfg.l_max)

    def _msg_chunk(layer, h_src, rbf_c, sph_c, send_c):
        """Per-edge messages for one chunk; gather from ``h_src``.

        Accumulation is grouped by OUTPUT degree l3 (3 narrow accumulators)
        instead of 15 sequential updates of the full (Ec, C, M) tensor —
        XLA's buffer assignment kept many of those full-width copies live
        simultaneously (measured 3x temp-memory difference at ogb scale).
        """
        rw = jax.nn.silu(rbf_c @ layer["radial_w1"]) @ layer["radial_w2"]
        rw = rw.reshape(-1, n_paths, c)                       # (Ec, P, C)
        hj = h_src[send_c].astype(jnp.float32)                # (Ec, C, M)
        outs = []
        for l3 in range(cfg.l_max + 1):
            s3 = L_SLICES[l3]
            acc = jnp.zeros((send_c.shape[0], c, s3.stop - s3.start),
                            jnp.float32)
            for p, (l1, l2, l3p) in enumerate(paths_l):
                if l3p != l3:
                    continue
                s1, s2 = L_SLICES[l1], L_SLICES[l2]
                # cg (a,b,k) x hj (e,C,a) x sph (e,b) -> (e,C,k)
                term = jnp.einsum("abk,eca,eb->eck",
                                  cgs[p], hj[..., s1], sph_c[:, s2])
                acc = acc + rw[:, p, :, None] * term
            outs.append(acc)
        return jnp.concatenate(outs, axis=-1)

    def _a_features_local(layer, h_):
        """Single-device path (smoke tests / small graphs)."""
        def contrib(h__, rbf_c, sph_c, send_c, recv_c):
            m = _msg_chunk(layer, h__, rbf_c, sph_c, send_c)
            return jax.ops.segment_sum(m, recv_c, num_segments=n)

        if n_chunks == 1:
            return contrib(h_, rbf, sph, senders, receivers)
        contrib = jax.checkpoint(contrib)
        ec = e_total // n_chunks
        resh = lambda a: a.reshape(n_chunks, ec, *a.shape[1:])
        if unroll:
            acc = jnp.zeros((n, c, M_TOT), jnp.float32)
            for ci in range(n_chunks):
                sl = slice(ci * ec, (ci + 1) * ec)
                acc = acc + contrib(h_, rbf[sl], sph[sl], senders[sl],
                                    receivers[sl])
            return acc
        acc, _ = jax.lax.scan(
            lambda a_, xs: (a_ + contrib(h_, *xs), None),
            jnp.zeros((n, c, M_TOT), jnp.float32),
            (resh(rbf), resh(sph), resh(senders), resh(receivers)))
        return acc

    def _a_features_sharded(layer, h_):
        """Production path (DESIGN.md §3.2): explicit shard_map.

        Preprocessing contract: edges are SORTED BY RECEIVER SHARD (the data
        pipeline guarantee — graph_data.sort_edges_for_mesh), so every cell
        scatters only into its local node range.  Per layer: ONE tiled
        all-gather of h (senders are arbitrary) + local chunked messages +
        local segment_sum.  No GSPMD-invented collectives.
        """
        from jax.sharding import PartitionSpec as P
        mesh = axes.mesh
        dp = tuple(axes.dp)
        dp_n = 1
        for a_ in dp:
            dp_n *= mesh.shape[a_]
        n_loc = n // dp_n

        ex_dtype = {"float32": jnp.float32,
                    "bfloat16": jnp.bfloat16}[cfg.exchange_dtype]

        def cell(h_loc, rbf_l, sph_l, send_l, recv_l):
            di = jax.lax.axis_index(dp)
            h_full = jax.lax.all_gather(h_loc.astype(ex_dtype), dp, axis=0,
                                        tiled=True).astype(h_loc.dtype)
            recv_loc = recv_l - di * n_loc     # receiver-sorted => in-range
            e_loc = send_l.shape[0]
            ec = max(e_loc // n_chunks, 1)
            nc = e_loc // ec

            def contrib(hf, rbf_c, sph_c, send_c, recv_c):
                m = _msg_chunk(layer, hf, rbf_c, sph_c, send_c)
                return jax.ops.segment_sum(m, recv_c, num_segments=n_loc)

            if nc <= 1:
                return contrib(h_full, rbf_l, sph_l, send_l, recv_loc)
            contrib = jax.checkpoint(contrib)
            if unroll:
                acc = jnp.zeros((n_loc, c, M_TOT), jnp.float32)
                for ci in range(nc):
                    sl = slice(ci * ec, (ci + 1) * ec)
                    acc = acc + contrib(h_full, rbf_l[sl], sph_l[sl],
                                        send_l[sl], recv_loc[sl])
                return acc
            resh = lambda a_: a_.reshape(nc, ec, *a_.shape[1:])
            acc, _ = jax.lax.scan(
                lambda a_, xs: (a_ + contrib(h_full, *xs), None),
                jnp.zeros((n_loc, c, M_TOT), jnp.float32),
                (resh(rbf_l), resh(sph_l), resh(send_l), resh(recv_loc)))
            return acc

        return compat.shard_map(
            cell, mesh=mesh,
            in_specs=(P(dp, None, None), P(dp, None), P(dp, None), P(dp),
                      P(dp)),
            out_specs=P(dp, None, None),
            check_vma=False,
        )(h_, rbf, sph, senders, receivers)

    for layer in params["layers"]:
        if axes is not None and getattr(axes, "mesh", None) is not None:
            a_feat = _a_features_sharded(layer, h)
        else:
            a_feat = _a_features_local(layer, h)
        a_feat = _c(a_feat)

        # higher-order ACE products (correlation order 3): B = A + w2*AxA + w3*(AxA)xA
        b_feat = a_feat
        if cfg.correlation_order >= 2:
            a2 = _cg_product(a_feat, a_feat, layer["prod2_w"], cfg.l_max)
            b_feat = b_feat + a2
            if cfg.correlation_order >= 3:
                a3 = _cg_product(a2, a_feat, layer["prod3_w"], cfg.l_max)
                b_feat = b_feat + a3

        # message mixing + gated nonlinearity on invariants + residual
        m = _mix_per_l(b_feat, layer["mix_msg"], cfg.l_max)
        gate = jax.nn.sigmoid(m[..., 0])[..., None]
        h = _mix_per_l(h.astype(jnp.float32), layer["mix_self"],
                       cfg.l_max) + m * gate
        if cfg.exchange_dtype == "bfloat16":
            # store/exchange node features in bf16 (halves the dominant
            # all-gather + the h_full transient); per-edge math stays f32.
            # NOTE: un-measurable on the CPU dry-run backend (bf16 is
            # legalized to f32) — accounted analytically in §Perf.
            h = h.astype(jnp.bfloat16)

    node_inv = h[..., 0].astype(jnp.float32)                  # (n, C) invariant
    site_e = (jax.nn.silu(node_inv @ params["readout_w1"])
              @ params["readout_w2"])[:, 0]                   # (n,)
    if graph_ids is None:
        energy = jnp.sum(site_e, keepdims=True)
    else:
        energy = jax.ops.segment_sum(site_e, graph_ids, num_segments=n_graphs)
    out = {"node_inv": node_inv, "energy": energy}
    if "cls_head" in params:
        out["node_logits"] = node_inv @ params["cls_head"]
    return out

"""E(3)-equivariant building blocks: real spherical harmonics + CG couplings.

Numpy (trace-time) machinery:
  * complex Clebsch-Gordan coefficients via the Racah closed form,
  * complex->real spherical-harmonic change of basis,
  * real-basis coupling tensors C[(2l1+1),(2l2+1),(2l3+1)] (made real by the
    standard i-phase fix when l1+l2+l3 is odd).

Equivariance of everything here is asserted numerically by the test suite
(rotation invariance of contracted scalars to ~1e-5).
"""
from __future__ import annotations

import functools
import math

import numpy as np


@functools.lru_cache(maxsize=None)
def _fact(n: int) -> float:
    return float(math.factorial(n))


def clebsch_gordan(j1: int, j2: int, j3: int) -> np.ndarray:
    """Numerically robust CG via projection (small j only, which is our case).

    Builds the coupling by projecting product states onto total-angular-
    momentum eigenstates constructed by explicit diagonalization of J^2, Jz in
    the product basis — avoids alternating-sum cancellation entirely and gives
    the standard Condon-Shortley phases up to per-j3 sign, which is irrelevant
    for equivariance (absorbed into learned weights).
    """
    def jz(j):
        return np.diag(np.arange(-j, j + 1, dtype=np.float64))

    # raising operator in the |j m> basis ordered m = -j..j
    def jp(j):
        m = np.arange(-j, j, dtype=np.float64)
        v = np.sqrt(j * (j + 1) - m * (m + 1))
        out = np.zeros((2 * j + 1, 2 * j + 1))
        for i, val in enumerate(v):
            out[i + 1, i] = val  # J+ |j,m> = v |j,m+1>
        return out

    n1, n2, n3 = 2 * j1 + 1, 2 * j2 + 1, 2 * j3 + 1
    i1, i2 = np.eye(n1), np.eye(n2)
    Jz = np.kron(jz(j1), i2) + np.kron(i1, jz(j2))
    Jp = np.kron(jp(j1), i2) + np.kron(i1, jp(j2))
    Jm = Jp.T
    J2 = Jm @ Jp + Jz @ Jz + Jz   # J^2 = J-J+ + Jz^2 + Jz  (hbar = 1)

    evals, evecs = np.linalg.eigh(J2)
    target = j3 * (j3 + 1)
    sel = np.abs(evals - target) < 1e-6
    sub = evecs[:, sel]                       # (n1*n2, n3) total-j3 subspace
    # within the subspace, diagonalize Jz to label m3
    zsub = sub.T @ Jz @ sub
    zvals, zvecs = np.linalg.eigh(zsub)
    states = sub @ zvecs                      # columns ordered m3 = -j3..j3
    # fix phases: make the highest-m1 component of each column positive
    cg = np.zeros((n1, n2, n3))
    for c in range(n3):
        col = states[:, c]
        nz = np.argmax(np.abs(col) > 1e-9)
        if col[nz] < 0:
            col = -col
        cg[:, :, c] = col.reshape(n1, n2)
    return cg


def real_sh_transform(l: int) -> np.ndarray:
    """U with  Y^real_a = sum_m U[a, m] Y^complex_m  (m ordered -l..l).

    Real convention: a=-l..-1 -> sin (odd), a=0 -> m=0, a=1..l -> cos (even).
    """
    n = 2 * l + 1
    u = np.zeros((n, n), complex)
    s2 = 1.0 / math.sqrt(2.0)
    u[l, l] = 1.0
    for m in range(1, l + 1):
        u[l + m, l + m] = (-1.0) ** m * s2       # cos row: ((-1)^m Y_m + Y_-m)/√2
        u[l + m, l - m] = s2
        u[l - m, l + m] = (-1.0) ** m * (-1j * s2)  # sin row
        u[l - m, l - m] = 1j * s2
    return u


def real_clebsch_gordan(l1: int, l2: int, l3: int) -> np.ndarray:
    """Coupling tensor in the REAL spherical-harmonic basis (real-valued).

    Built numerically: C_real = U1 U2 conj(U3) . C_complex; when l1+l2+l3 is
    odd the tensor is purely imaginary and we use its imaginary part (the
    -i phase is a valid equivariant redefinition).
    """
    cg = clebsch_gordan(l1, l2, l3)
    u1, u2, u3 = (real_sh_transform(l) for l in (l1, l2, l3))
    c = np.einsum("am,bn,co,mno->abc", u1, u2, u3.conj(), cg.astype(complex))
    re, im = np.real(c), np.imag(c)
    return re if np.abs(re).sum() >= np.abs(im).sum() else im


def real_sph_harm_l2(unit_vecs: "np.ndarray | object"):
    """Real spherical harmonics l=0,1,2 for unit vectors (..., 3).

    Works for numpy *and* jax arrays (pure arithmetic). Returns (..., 9) in
    the order [l0; l1(-1,0,1); l2(-2..2)], e3nn-style component ordering
    (y, z, x) for l=1.
    """
    x = unit_vecs[..., 0]
    y = unit_vecs[..., 1]
    z = unit_vecs[..., 2]
    import jax.numpy as jnp
    c0 = 0.28209479177387814          # 1/2 sqrt(1/pi)
    c1 = 0.4886025119029199           # sqrt(3/(4pi))
    c2a = 1.0925484305920792          # sqrt(15/(4pi))
    c2b = 0.31539156525252005         # 1/4 sqrt(5/pi)
    c2c = 0.5462742152960396          # 1/4 sqrt(15/pi)
    comps = [
        x * 0 + c0,
        c1 * y, c1 * z, c1 * x,
        c2a * x * y, c2a * y * z, c2b * (3 * z * z - 1.0),
        c2a * x * z, c2c * (x * x - y * y),
    ]
    return jnp.stack(comps, axis=-1)


L_SLICES = {0: slice(0, 1), 1: slice(1, 4), 2: slice(4, 9)}


def coupling_paths(l_max: int) -> list[tuple[int, int, int]]:
    """All (l1, l2, l3) with l1,l2,l3 <= l_max, |l1-l2| <= l3 <= l1+l2."""
    out = []
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(abs(l1 - l2), min(l_max, l1 + l2) + 1):
                out.append((l1, l2, l3))
    return out

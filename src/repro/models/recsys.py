"""RecSys models: DLRM, AutoInt, Wide&Deep, MIND (+ two-tower retrieval).

Common substrate: `embedding_bag` — JAX has no native EmbeddingBag, so lookup
is take + weighted sum (and the Pallas scalar-prefetch kernel on TPU, see
kernels/embedding_bag.py).  Tables are row-sharded over the tp axis (rows
padded to cfg.row_pad_to); the lookup of globally-indexed ids from row-sharded
tables lowers to the standard gather + AllToAll under GSPMD.

The paper's technique plugs in at `retrieval_cand`: the 1M-candidate scoring
is served either brute-force (fused matmul_topk kernel) or through the
random-partition-forest index (core/) — benchmarked against each other in
EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import RecsysConfig
from repro.models.layers import Axes, dense_init


def _pad_rows(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


def _mlp_init(key, dims: tuple[int, ...], dtype=jnp.float32) -> list:
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": dense_init(ks[i], dims[i], dims[i + 1], dtype),
             "b": jnp.zeros((dims[i + 1],), dtype)}
            for i in range(len(dims) - 1)]


def _mlp_specs(dims: tuple[int, ...], shard_wide: Optional[str]) -> list:
    out = []
    for i in range(len(dims) - 1):
        # shard the widest layers' columns over tp; keep small ones replicated
        big = shard_wide is not None and dims[i + 1] >= 512
        out.append({"w": P(None, shard_wide if big else None), "b": P(None)})
    return out


def _mlp_fwd(layers: list, x: jax.Array, final_act: bool = False) -> jax.Array:
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if i < len(layers) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def embedding_bag(table: jax.Array, ids: jax.Array,
                  weights: Optional[jax.Array] = None) -> jax.Array:
    """take + weighted segment-sum bag. ids (B, H) -> (B, D)."""
    rows = table[ids]                                   # (B, H, D)
    if weights is None:
        return jnp.sum(rows, axis=1)
    return jnp.sum(rows * weights[..., None], axis=1)


def init_tables(key, cfg: RecsysConfig, dtype=jnp.float32) -> list:
    ks = jax.random.split(key, len(cfg.table_sizes))
    return [
        (jax.random.normal(ks[i], (_pad_rows(v, cfg.row_pad_to),
                                   cfg.embed_dim), jnp.float32)
         / np.sqrt(cfg.embed_dim)).astype(dtype)
        for i, v in enumerate(cfg.table_sizes)
    ]


def table_specs(cfg: RecsysConfig, axes: Axes) -> list:
    """Row-shard big tables over tp; replicate small ones (< 16k rows)."""
    return [P(axes.tp, None) if v >= 16384 else P(None, None)
            for v in cfg.table_sizes]


# ---------------------------------------------------------------------------
# DLRM (arXiv:1906.00091, MLPerf config)
# ---------------------------------------------------------------------------


def init_dlrm(key, cfg: RecsysConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "tables": init_tables(k1, cfg),
        "bot_mlp": _mlp_init(k2, (cfg.n_dense,) + cfg.bot_mlp),
        "top_mlp": _mlp_init(k3, (_dlrm_top_in(cfg),) + cfg.top_mlp),
    }


def _dlrm_top_in(cfg: RecsysConfig) -> int:
    f = cfg.n_sparse + 1
    return f * (f - 1) // 2 + cfg.embed_dim


def dlrm_specs(cfg: RecsysConfig, axes: Axes) -> dict:
    return {
        "tables": table_specs(cfg, axes),
        "bot_mlp": _mlp_specs((cfg.n_dense,) + cfg.bot_mlp, axes.tp),
        "top_mlp": _mlp_specs((_dlrm_top_in(cfg),) + cfg.top_mlp, axes.tp),
    }


def dlrm_fwd(params: dict, dense: jax.Array, sparse_ids: jax.Array) -> jax.Array:
    """dense (B, n_dense), sparse_ids (B, n_sparse) -> logits (B,)."""
    b = dense.shape[0]
    x0 = _mlp_fwd(params["bot_mlp"], dense, final_act=True)   # (B, D)
    embs = [t[sparse_ids[:, i]] for i, t in enumerate(params["tables"])]
    z = jnp.stack([x0] + embs, axis=1)                        # (B, F, D)
    g = jnp.einsum("bfd,bgd->bfg", z, z)                      # pairwise dots
    f = z.shape[1]
    iu, ju = jnp.triu_indices(f, k=1)
    inter = g[:, iu, ju]                                      # (B, F(F-1)/2)
    top_in = jnp.concatenate([x0, inter], axis=1)
    return _mlp_fwd(params["top_mlp"], top_in)[:, 0]


# ---------------------------------------------------------------------------
# AutoInt (arXiv:1810.11921)
# ---------------------------------------------------------------------------


def init_autoint(key, cfg: RecsysConfig) -> dict:
    ks = jax.random.split(key, 3 + cfg.n_attn_layers)
    d_attn = cfg.d_attn
    layers = []
    for i in range(cfg.n_attn_layers):
        k = jax.random.split(ks[3 + i], 4)
        d_in = cfg.embed_dim if i == 0 else d_attn
        layers.append({
            "wq": dense_init(k[0], d_in, cfg.n_attn_heads * d_attn, jnp.float32),
            "wk": dense_init(k[1], d_in, cfg.n_attn_heads * d_attn, jnp.float32),
            "wv": dense_init(k[2], d_in, cfg.n_attn_heads * d_attn, jnp.float32),
            "wo": dense_init(k[3], cfg.n_attn_heads * d_attn, d_attn, jnp.float32),
            "res": dense_init(jax.random.fold_in(k[3], 1), d_in, d_attn,
                              jnp.float32),
        })
    return {
        "tables": init_tables(ks[0], cfg),
        "attn": layers,
        "out_w": dense_init(ks[1], cfg.n_sparse * d_attn, 1, jnp.float32),
    }


def autoint_specs(cfg: RecsysConfig, axes: Axes) -> dict:
    layer = {"wq": P(None, None), "wk": P(None, None), "wv": P(None, None),
             "wo": P(None, None), "res": P(None, None)}
    return {"tables": table_specs(cfg, axes),
            "attn": [dict(layer) for _ in range(cfg.n_attn_layers)],
            "out_w": P(None, None)}


def autoint_fwd(params: dict, sparse_ids: jax.Array) -> jax.Array:
    """sparse_ids (B, F) -> logits (B,)."""
    x = jnp.stack([t[sparse_ids[:, i]]
                   for i, t in enumerate(params["tables"])], axis=1)  # (B,F,D)
    for l in params["attn"]:
        h = l  # alias
        b, f, d_in = x.shape
        d_attn = h["wo"].shape[1]
        heads = h["wq"].shape[1] // d_attn
        q = (x @ h["wq"]).reshape(b, f, heads, d_attn)
        k = (x @ h["wk"]).reshape(b, f, heads, d_attn)
        v = (x @ h["wv"]).reshape(b, f, heads, d_attn)
        scores = jnp.einsum("bfhd,bghd->bhfg", q, k) / np.sqrt(d_attn)
        probs = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bhfg,bghd->bfhd", probs, v).reshape(b, f, -1)
        x = jax.nn.relu(o @ h["wo"] + x @ h["res"])
    b = x.shape[0]
    return (x.reshape(b, -1) @ params["out_w"])[:, 0]


# ---------------------------------------------------------------------------
# Wide & Deep (arXiv:1606.07792)
# ---------------------------------------------------------------------------


def init_widedeep(key, cfg: RecsysConfig) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    wide_cfg = RecsysConfig(**{**cfg.__dict__, "embed_dim": 1})
    return {
        "tables": init_tables(k1, cfg),
        "wide_tables": init_tables(k2, wide_cfg),
        "deep_mlp": _mlp_init(k3, (cfg.n_sparse * cfg.embed_dim,) + cfg.mlp
                              + (1,)),
    }


def widedeep_specs(cfg: RecsysConfig, axes: Axes) -> dict:
    return {
        "tables": table_specs(cfg, axes),
        "wide_tables": table_specs(cfg, axes),
        "deep_mlp": _mlp_specs((cfg.n_sparse * cfg.embed_dim,) + cfg.mlp + (1,),
                               axes.tp),
    }


def widedeep_fwd(params: dict, sparse_ids: jax.Array) -> jax.Array:
    embs = jnp.concatenate([t[sparse_ids[:, i]]
                            for i, t in enumerate(params["tables"])], axis=1)
    deep = _mlp_fwd(params["deep_mlp"], embs)[:, 0]
    wide = sum(t[sparse_ids[:, i]][:, 0]
               for i, t in enumerate(params["wide_tables"]))
    return deep + wide


# ---------------------------------------------------------------------------
# MIND: multi-interest capsule routing (arXiv:1904.08030)
# ---------------------------------------------------------------------------


def init_mind(key, cfg: RecsysConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.embed_dim
    return {
        "item_embed": (jax.random.normal(
            k1, (_pad_rows(cfg.item_vocab, cfg.row_pad_to), d)) / np.sqrt(d)),
        "bilinear": dense_init(k2, d, d, jnp.float32),   # B2I shared S matrix
        "out_mlp": _mlp_init(k3, (d, 4 * d, d)),
    }


def mind_specs(cfg: RecsysConfig, axes: Axes) -> dict:
    return {"item_embed": P(axes.tp, None), "bilinear": P(None, None),
            "out_mlp": _mlp_specs((cfg.embed_dim, 4 * cfg.embed_dim,
                                   cfg.embed_dim), None)}


def _squash(s: jax.Array) -> jax.Array:
    n2 = jnp.sum(s * s, axis=-1, keepdims=True)
    return (n2 / (1.0 + n2)) * s / jnp.sqrt(n2 + 1e-9)


def mind_user_fwd(params: dict, cfg: RecsysConfig, hist_ids: jax.Array,
                  hist_mask: Optional[jax.Array] = None) -> jax.Array:
    """Behavior-to-Interest dynamic routing. hist_ids (B, H) -> (B, K, D)."""
    u = params["item_embed"][hist_ids] @ params["bilinear"]   # (B, H, D)
    if hist_mask is None:
        hist_mask = jnp.ones(hist_ids.shape, u.dtype)
    b, h, d = u.shape
    k = cfg.n_interests
    # fixed (shared) routing-logit init, as in the paper's shared-B variant.
    # the few routing iterations are unrolled (static python loop) so the
    # dry-run cost analysis counts them all (see LMConfig.unroll note).
    blog = jnp.zeros((b, k, h), u.dtype)
    v = None
    for _ in range(cfg.capsule_iters):
        c = jax.nn.softmax(blog, axis=1) * hist_mask[:, None, :]
        s = jnp.einsum("bkh,bhd->bkd", c, u)
        v = _squash(s)
        blog = blog + jnp.einsum("bkd,bhd->bkh", v, u)
    interests = v                                             # (B, K, D)
    # H-layer MLP with residual (paper: one ReLU layer per interest)
    return interests + _mlp_fwd(params["out_mlp"], interests)


def mind_train_logits(params: dict, cfg: RecsysConfig, hist_ids: jax.Array,
                      target_ids: jax.Array,
                      hist_mask: Optional[jax.Array] = None) -> jax.Array:
    """Label-aware attention (pow=2) over interests -> logit vs target item."""
    interests = mind_user_fwd(params, cfg, hist_ids, hist_mask)  # (B, K, D)
    tgt = params["item_embed"][target_ids]                        # (B, D)
    att = jax.nn.softmax(
        jnp.einsum("bkd,bd->bk", interests, tgt) ** 2, axis=-1)
    user = jnp.einsum("bk,bkd->bd", att, interests)
    return jnp.sum(user * tgt, axis=-1)


def mind_score_candidates(params: dict, cfg: RecsysConfig, hist_ids: jax.Array,
                          cand: jax.Array,
                          hist_mask: Optional[jax.Array] = None) -> jax.Array:
    """Retrieval scoring: max over interests of interest . candidate.

    cand (N, D) -> scores (B, N). The brute-force path; the RPF index version
    lives in serve/ann_serve.py.
    """
    interests = mind_user_fwd(params, cfg, hist_ids, hist_mask)  # (B, K, D)
    scores = jnp.einsum("bkd,nd->bkn", interests, cand)
    return jnp.max(scores, axis=1)


# ---------------------------------------------------------------------------
# two-tower retrieval (substrate for the paper-integration example)
# ---------------------------------------------------------------------------


def init_two_tower(key, n_users: int, n_items: int, d: int = 64,
                   hidden: int = 256) -> dict:
    ks = jax.random.split(key, 6)
    return {
        "user_embed": jax.random.normal(ks[0], (n_users, d)) / np.sqrt(d),
        "item_embed": jax.random.normal(ks[1], (n_items, d)) / np.sqrt(d),
        "user_mlp": _mlp_init(ks[2], (d, hidden, d)),
        "item_mlp": _mlp_init(ks[3], (d, hidden, d)),
    }


def two_tower_user(params, user_ids):
    return _mlp_fwd(params["user_mlp"], params["user_embed"][user_ids])


def two_tower_item(params, item_ids):
    return _mlp_fwd(params["item_mlp"], params["item_embed"][item_ids])


def two_tower_loss(params, user_ids, item_ids):
    """In-batch sampled softmax (the standard two-tower objective)."""
    u = two_tower_user(params, user_ids)
    v = two_tower_item(params, item_ids)
    logits = u @ v.T
    labels = jnp.arange(u.shape[0])
    return jnp.mean(
        -jax.nn.log_softmax(logits, axis=-1)[jnp.arange(u.shape[0]), labels])

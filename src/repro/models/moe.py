"""Mixture-of-Experts FFN with sort-free scatter dispatch (top-k, capacity).

Dispatch strategy (DESIGN.md §3.2): tokens are routed with a scatter to a
(E * capacity, D) buffer laid out expert-major — under pjit with the buffer
sharded over tp on the expert axis this lowers to the expert-parallel
all-to-all; no (T, E, capacity) one-hot einsum is ever materialized (GShard's
dense dispatch is O(T*E*cap) memory — infeasible at 1M tokens x 128 experts).

Position-in-expert is computed with a segmented cumsum over a stable argsort
of expert assignments (O(T log T), fully vectorized). Tokens beyond capacity
are dropped (standard switch behaviour); the aux load-balance loss keeps the
drop rate low.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat
from jax.sharding import PartitionSpec as P

from repro.models.layers import Axes, dense_init


def init_moe(key, d_model: int, d_ff: int, n_experts: int, dtype,
             shared_expert: bool) -> dict:
    ks = jax.random.split(key, 7)
    p = {
        "router": dense_init(ks[0], d_model, n_experts, jnp.float32),
        "w_gate": _expert_init(ks[1], n_experts, d_model, d_ff, dtype),
        "w_up": _expert_init(ks[2], n_experts, d_model, d_ff, dtype),
        "w_down": _expert_init(ks[3], n_experts, d_ff, d_model, dtype),
    }
    if shared_expert:
        p["shared"] = {
            "w_gate": dense_init(ks[4], d_model, d_ff, dtype),
            "w_up": dense_init(ks[5], d_model, d_ff, dtype),
            "w_down": dense_init(ks[6], d_ff, d_model, dtype),
        }
    return p


def _expert_init(key, e, d_in, d_out, dtype):
    return (jax.random.normal(key, (e, d_in, d_out), jnp.float32)
            / jnp.sqrt(d_in)).astype(dtype)


def moe_specs(axes: Axes, shared_expert: bool, fsdp: bool = False,
              expert_fsdp: int = -1) -> dict:
    """Experts sharded over tp on the expert axis (expert parallelism).

    ``expert_fsdp``: -1 follows ``fsdp``; 0 keeps expert weights tp-sharded
    only (no per-layer dp all-gathers — the collective-term hillclimb)."""
    tp = axes.tp
    fs = tuple(axes.dp) if fsdp else None
    efs = fs if expert_fsdp == -1 else (
        tuple(axes.dp) if expert_fsdp else None)
    p = {
        "router": P(None, None),
        "w_gate": P(tp, efs, None),
        "w_up": P(tp, efs, None),
        "w_down": P(tp, efs, None),
    }
    if shared_expert:
        p["shared"] = {"w_gate": P(fs, tp), "w_up": P(fs, tp),
                       "w_down": P(tp, fs)}
    return p


def _position_in_expert(expert_ids: jax.Array, n_experts: int) -> jax.Array:
    """Rank of each routed slot among slots sent to the same expert.

    expert_ids: (M,) int32. Stable argsort groups same-expert slots; position
    = index within group, scattered back to the original slot order.
    """
    m = expert_ids.shape[0]
    order = jnp.argsort(expert_ids, stable=True)
    sorted_e = expert_ids[order]
    start = jnp.searchsorted(sorted_e, jnp.arange(n_experts, dtype=expert_ids.dtype))
    pos_sorted = jnp.arange(m, dtype=jnp.int32) - start[sorted_e]
    inv = jnp.argsort(order)
    return pos_sorted[inv]


def moe_fwd(params: dict, x: jax.Array, *, n_experts: int, top_k: int,
            capacity_factor: float, axes: Axes | None = None):
    """x: (T, D) token-major. Returns (out (T, D), aux_loss scalar)."""
    t, d = x.shape
    cap = int(max(top_k * capacity_factor * t / n_experts, 4))

    logits = x.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, sel = jax.lax.top_k(probs, top_k)                    # (T, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)        # renormalize

    # switch-style aux load-balance loss
    density = jnp.mean(jax.nn.one_hot(sel[:, 0], n_experts), axis=0)
    router_mean = jnp.mean(probs, axis=0)
    aux = n_experts * jnp.sum(density * router_mean)

    # ---- scatter dispatch ------------------------------------------------
    # destination buffer is (E, cap+1, D), expert-major and expert-sharded
    # over tp from birth: the dp-sharded-token -> tp-sharded-expert scatter IS
    # the expert-parallel all-to-all.  Slot ``cap`` is the drop slot.
    flat_e = sel.reshape(-1).astype(jnp.int32)                 # (T*k,)
    pos = _position_in_expert(flat_e, n_experts)               # (T*k,)
    keep = pos < cap
    slot = jnp.where(keep, pos, cap)
    x_rep = jnp.repeat(x, top_k, axis=0)                       # (T*k, D)

    def _c(a):
        if axes is None:
            return a
        return jax.lax.with_sharding_constraint(a, P(axes.tp, None, None))

    buf = _c(jnp.zeros((n_experts, cap + 1, d), x.dtype))
    buf = _c(buf.at[flat_e, slot].set(x_rep))                  # (E, cap+1, D)

    # ---- expert compute (grouped GEMMs on the MXU) -----------------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    y = _c(jnp.einsum("ecf,efd->ecd", h, params["w_down"]))    # (E, cap+1, D)

    # ---- combine (gather back: tp-sharded experts -> dp-sharded tokens) ---
    out_rep = y[flat_e, slot] * gate.reshape(-1, 1).astype(y.dtype)
    out_rep = jnp.where(keep[:, None], out_rep, 0.0)
    out = jnp.sum(out_rep.reshape(t, top_k, d), axis=1)

    out = out.astype(x.dtype)   # gate is f32; don't promote the residual
    if "shared" in params:
        s = params["shared"]
        out = out + (jax.nn.silu(x @ s["w_gate"]) * (x @ s["w_up"])) @ s["w_down"]
    return out, aux


# ---------------------------------------------------------------------------
# expert-parallel shard_map dispatch (the production path)
# ---------------------------------------------------------------------------
#
# The pure-pjit scatter above is correct but GSPMD lowers the
# dp-tokens -> tp-experts scatter catastrophically (it replicates the scatter
# indices broadcast to (T*k, D) u32 and all-gathers it — 64 GiB/device at the
# granite train_4k cell).  The production path makes the communication
# explicit instead:
#   * tokens stay dp-sharded and are REPLICATED across tp (they already are:
#     activations are P(dp, None)),
#   * each tp cell routes all its local tokens, keeps the (token, slot) pairs
#     owned by ITS E/tp experts, and builds its (E_local, cap, D) buffer with
#     a purely LOCAL scatter,
#   * after the expert GEMMs each cell holds partial outputs for its experts'
#     tokens; a psum over tp combines them (bytes = T_local * D * 4 — the
#     same order as a bidirectional all-to-all at top_k ~ tp/2, and far
#     simpler to reason about; see EXPERIMENTS.md §Perf for the measurement).
# Capacity note: capacity becomes per-(dp-shard, expert) — exactly how
# per-rank capacity works in deployed EP systems.


def make_quantized_all_gather(axis_names, axis: int):
    """int8-compressed weight all-gather (fwd) with exact transpose (bwd).

    The FSDP expert-weight gathers dominate the MoE train collective term
    (EXPERIMENTS.md §Perf); gathering int8 + per-(expert, column) scales
    halves the wire bytes vs bf16 at <0.4% relative weight error.  Backward
    is the exact transpose of a tiled all_gather (psum_scatter of the
    cotangent) — gradients are unbiased (quantization treated as identity,
    standard weight-quantized-forward practice).
    """

    @jax.custom_vjp
    def qag(w_loc):
        return _fwd_impl(w_loc)

    def _fwd_impl(w_loc):
        scale = jnp.max(jnp.abs(w_loc), axis=axis, keepdims=True) / 127.0
        scale = scale + 1e-12
        q = jnp.clip(jnp.round(w_loc / scale), -127, 127).astype(jnp.int8)
        qg = jax.lax.all_gather(q, axis_names, axis=0, tiled=False)
        sg = jax.lax.all_gather(scale, axis_names, axis=0, tiled=False)
        deq = qg.astype(w_loc.dtype) * sg.astype(w_loc.dtype)
        out = jnp.moveaxis(deq, 0, axis)       # (..., dp, D_loc, ...)
        return out.reshape(w_loc.shape[:axis] + (-1,)
                           + w_loc.shape[axis + 1:])

    def fwd(w_loc):
        return _fwd_impl(w_loc), None

    def bwd(_, g):
        return (jax.lax.psum_scatter(g, axis_names,
                                     scatter_dimension=axis, tiled=True),)

    qag.defvjp(fwd, bwd)
    return qag


def moe_fwd_a2a(params: dict, x: jax.Array, *, n_experts: int,
                capacity_factor: float, axes: Axes, fsdp: bool = False,
                gather_quant: bool = False):
    """Top-1 expert-parallel dispatch via all_to_all (the §Perf iteration
    that removes the per-layer (B, S, D) activation all-gather + psum of the
    psum-combine path).

    Tokens stay sharded over dp AND tp (sequence-parallel residual feeds in
    with zero resharding); each cell routes its T/(dp·tp) tokens, buckets
    them by destination tp cell (per-destination capacity), exchanges
    buckets with ONE all_to_all, runs its experts, and a second all_to_all
    returns outputs to the token owners.  Wire bytes per cell per direction:
    tp·cap_d·D  ~=  cf·T_cell·D  — ~12x less than gather+psum at tp=16.
    """
    t, d = x.shape
    mesh = axes.mesh
    tp_n = mesh.shape[axes.tp]
    dp_n = 1
    for a in axes.dp:
        dp_n *= mesh.shape[a]
    t_cell = t // (dp_n * tp_n)
    e_local = n_experts // tp_n
    cap_d = int(max(capacity_factor * t_cell / tp_n, 4))     # per-dest slots
    cap_e = int(max(capacity_factor * t_cell / e_local, 4))  # per-expert rows

    def cell(x_loc, router, wg, wu, wd):
        # x_loc (t_cell, D); weights (E_loc, D[/dp], F)
        if fsdp:
            if gather_quant:
                qag = make_quantized_all_gather(axes.dp, axis=1)
                wg_, wu_, wd_ = qag(wg), qag(wu), qag(wd)
            else:
                wg_ = jax.lax.all_gather(wg, axes.dp, axis=1, tiled=True)
                wu_ = jax.lax.all_gather(wu, axes.dp, axis=1, tiled=True)
                wd_ = jax.lax.all_gather(wd, axes.dp, axis=1, tiled=True)
        else:
            wg_, wu_, wd_ = wg, wu, wd

        logits = x_loc.astype(jnp.float32) @ router.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, sel = jax.lax.top_k(probs, 1)
        sel = sel[:, 0].astype(jnp.int32)                     # (Tc,)
        gate = jnp.ones_like(gate[:, 0])   # top-1 renormalized (== moe_fwd)
        density = jnp.mean(jax.nn.one_hot(sel, n_experts), axis=0)
        aux = n_experts * jnp.sum(density * jnp.mean(probs, axis=0))

        # ---- bucket by destination tp cell ----------------------------
        dest = sel // e_local                                 # (Tc,)
        pos = _position_in_expert(dest, tp_n)
        keep = pos < cap_d
        slot = jnp.where(keep, pos, cap_d)
        row = jnp.where(keep, dest, tp_n)
        send = jnp.zeros((tp_n + 1, cap_d + 1, d), x_loc.dtype)
        send = send.at[row, slot].set(x_loc)[:tp_n, :cap_d]
        send_e = jnp.full((tp_n + 1, cap_d + 1), e_local, jnp.int32)
        send_e = send_e.at[row, slot].set(sel % e_local)[:tp_n, :cap_d]

        # ---- exchange: one all_to_all each way -------------------------
        recv = jax.lax.all_to_all(send, axes.tp, 0, 0, tiled=False)
        recv_e = jax.lax.all_to_all(send_e, axes.tp, 0, 0, tiled=False)
        rflat = recv.reshape(tp_n * cap_d, d)
        eflat = recv_e.reshape(tp_n * cap_d)                  # e_local = pad

        # ---- local expert buffers --------------------------------------
        pos_e = _position_in_expert(eflat, e_local + 1)
        keep_e = (eflat < e_local) & (pos_e < cap_e)
        erow = jnp.where(keep_e, eflat, e_local)
        eslot = jnp.where(keep_e, pos_e, cap_e)
        buf = jnp.zeros((e_local + 1, cap_e + 1, d), x_loc.dtype)
        buf = buf.at[erow, eslot].set(rflat)[:e_local, :cap_e]

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg_)) * \
            jnp.einsum("ecd,edf->ecf", buf, wu_)
        y = jnp.einsum("ecf,efd->ecd", h, wd_)                # (E_loc,cap_e,D)

        # ---- route back -------------------------------------------------
        y_pad = jnp.pad(y, ((0, 1), (0, 1), (0, 0)))
        y_slots = jnp.where(keep_e[:, None], y_pad[erow, eslot], 0.0)
        back = jax.lax.all_to_all(
            y_slots.reshape(tp_n, cap_d, d), axes.tp, 0, 0, tiled=False)
        back_pad = jnp.pad(back, ((0, 1), (0, 1), (0, 0)))
        out = back_pad[row, slot] * gate[:, None].astype(y.dtype)
        out = jnp.where(keep[:, None], out, 0.0)
        return out.astype(x_loc.dtype), aux[None]

    from jax.sharding import PartitionSpec as P
    dp = tuple(axes.dp)
    tok = dp + (axes.tp,)
    fs = dp if fsdp else None
    w_spec = P(axes.tp, fs, None)
    out, aux = compat.shard_map(
        cell, mesh=mesh,
        in_specs=(P(tok, None), P(None, None), w_spec, w_spec, w_spec),
        out_specs=(P(tok, None), P(tok)),
        check_vma=False,
    )(x, params["router"], params["w_gate"], params["w_up"],
      params["w_down"])
    out = out.astype(x.dtype)
    if "shared" in params:
        s = params["shared"]
        out = out + (jax.nn.silu(x @ s["w_gate"]) * (x @ s["w_up"])) @ s["w_down"]
    return out, jnp.mean(aux)


def moe_fwd_sharded(params: dict, x: jax.Array, *, n_experts: int,
                    top_k: int, capacity_factor: float, axes: Axes,
                    fsdp: bool = False, expert_fsdp: int = -1,
                    gather_quant: bool = False):
    """x: (T, D) token-major, sharded P(dp, None). Requires axes.mesh."""
    e_fsdp = fsdp if expert_fsdp == -1 else bool(expert_fsdp)
    t, d = x.shape
    mesh = axes.mesh
    tp_n = mesh.shape[axes.tp]
    dp_n = 1
    for a in axes.dp:
        dp_n *= mesh.shape[a]
    t_local = t // dp_n
    e_local = n_experts // tp_n
    cap = int(max(capacity_factor * top_k * t_local / n_experts, 4))

    def cell(x_loc, router, wg, wu, wd):
        # x_loc (T_local, D); wg/wu/wd (E_local, D[/dp], F)
        if e_fsdp:
            if gather_quant:
                qag = make_quantized_all_gather(axes.dp, axis=1)
                wg, wu, wd = qag(wg), qag(wu), qag(wd)
            else:
                wg = jax.lax.all_gather(wg, axes.dp, axis=1, tiled=True)
                wu = jax.lax.all_gather(wu, axes.dp, axis=1, tiled=True)
                wd = jax.lax.all_gather(wd, axes.dp, axis=1, tiled=True)
        ti = jax.lax.axis_index(axes.tp)
        e0 = ti * e_local

        logits = x_loc.astype(jnp.float32) @ router.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, sel = jax.lax.top_k(probs, top_k)               # (T_loc, k)
        gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

        density = jnp.mean(jax.nn.one_hot(sel[:, 0], n_experts), axis=0)
        aux = n_experts * jnp.sum(density * jnp.mean(probs, axis=0))

        flat_e = sel.reshape(-1).astype(jnp.int32)            # (T_loc*k,)
        mine = (flat_e >= e0) & (flat_e < e0 + e_local)
        eloc = jnp.where(mine, flat_e - e0, e_local)          # sentinel bucket
        pos = _position_in_expert(eloc, e_local + 1)
        keep = mine & (pos < cap)
        slot = jnp.where(keep, pos, cap)
        erow = jnp.where(keep, eloc, e_local)
        x_rep = jnp.repeat(x_loc, top_k, axis=0)

        buf = jnp.zeros((e_local + 1, cap + 1, d), x_loc.dtype)
        buf = buf.at[erow, slot].set(x_rep)                   # LOCAL scatter
        buf = buf[:e_local, :cap]                             # (E_loc, cap, D)

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * \
            jnp.einsum("ecd,edf->ecf", buf, wu)
        y = jnp.einsum("ecf,efd->ecd", h, wd)                 # (E_loc, cap, D)

        y_pad = jnp.pad(y, ((0, 1), (0, 1), (0, 0)))
        out_rep = y_pad[erow, slot] * gate.reshape(-1, 1).astype(y.dtype)
        out_rep = jnp.where(keep[:, None], out_rep, 0.0)
        partial = jnp.sum(out_rep.reshape(t_local, top_k, d), axis=1)
        out = jax.lax.psum(partial, axes.tp)                  # combine
        return out.astype(x_loc.dtype), aux[None]

    from jax.sharding import PartitionSpec as P
    dp = tuple(axes.dp)
    fs = dp if e_fsdp else None
    w_spec = P(axes.tp, fs, None)
    out, aux = compat.shard_map(
        cell, mesh=mesh,
        in_specs=(P(dp, None), P(None, None), w_spec, w_spec, w_spec),
        out_specs=(P(dp, None), P((dp + (axes.tp,)))),
        check_vma=False,
    )(x, params["router"], params["w_gate"], params["w_up"],
      params["w_down"])
    out = out.astype(x.dtype)
    if "shared" in params:
        s = params["shared"]
        out = out + (jax.nn.silu(x @ s["w_gate"]) * (x @ s["w_up"])) @ s["w_down"]
    return out, jnp.mean(aux)

"""Graph generators + a real CSR fanout neighbor sampler (minibatch_lg cell)."""
from __future__ import annotations

import numpy as np


def random_graph(n_nodes: int, n_edges: int, d_feat: int = 0, seed: int = 0,
                 power_law: bool = True):
    """Random directed graph with power-law-ish degree. Returns dict of arrays."""
    rng = np.random.default_rng(seed)
    if power_law:
        w = rng.pareto(1.5, size=n_nodes) + 1.0
        p = w / w.sum()
        src = rng.choice(n_nodes, size=n_edges, p=p)
    else:
        src = rng.integers(0, n_nodes, size=n_edges)
    dst = rng.integers(0, n_nodes, size=n_edges)
    out = {
        "senders": src.astype(np.int32),
        "receivers": dst.astype(np.int32),
        "positions": rng.normal(size=(n_nodes, 3)).astype(np.float32),
        "species": rng.integers(0, 16, size=n_nodes).astype(np.int32),
    }
    if d_feat:
        out["node_feat"] = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    return out


def to_csr(senders: np.ndarray, receivers: np.ndarray, n_nodes: int
           ) -> tuple[np.ndarray, np.ndarray]:
    """(indptr, indices): out-neighbors of each node (CSR over senders)."""
    order = np.argsort(senders, kind="stable")
    indices = receivers[order].astype(np.int32)
    counts = np.bincount(senders, minlength=n_nodes)
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr, indices


class NeighborSampler:
    """GraphSAGE-style uniform fanout sampler over a CSR adjacency.

    Produces fixed-shape padded samples (TPU-friendly): per hop h with fanout
    f_h, every frontier node draws f_h neighbors with replacement; isolated
    nodes self-loop.  Returns a subgraph as (senders, receivers, node_map).
    """

    def __init__(self, indptr: np.ndarray, indices: np.ndarray, seed: int = 0):
        self.indptr = indptr
        self.indices = indices
        self.rng = np.random.default_rng(seed)

    def sample(self, seeds: np.ndarray, fanouts: tuple[int, ...]):
        nodes = [seeds.astype(np.int64)]
        edges_s, edges_r = [], []
        frontier = seeds.astype(np.int64)
        for f in fanouts:
            deg = (self.indptr[frontier + 1] - self.indptr[frontier])
            offs = self.rng.integers(0, np.maximum(deg, 1),
                                     size=(len(frontier), f))
            neigh = self.indices[
                np.minimum(self.indptr[frontier, None] + offs,
                           len(self.indices) - 1)]
            # isolated nodes -> self loops
            neigh = np.where(deg[:, None] > 0, neigh, frontier[:, None])
            src = neigh.reshape(-1)
            dst = np.repeat(frontier, f)
            edges_s.append(src)
            edges_r.append(dst)
            frontier = np.unique(src)
            nodes.append(frontier)
        all_nodes, inv = np.unique(np.concatenate(nodes), return_inverse=True)
        # relabel endpoints into the compact node set
        relabel = {g: i for i, g in enumerate(all_nodes)}
        s = np.concatenate(edges_s)
        r = np.concatenate(edges_r)
        s_local = np.searchsorted(all_nodes, s)
        r_local = np.searchsorted(all_nodes, r)
        return {
            "node_ids": all_nodes.astype(np.int64),       # global ids
            "senders": s_local.astype(np.int32),
            "receivers": r_local.astype(np.int32),
            "seed_local": np.searchsorted(all_nodes, seeds).astype(np.int32),
        }


def sort_edges_for_mesh(senders: np.ndarray, receivers: np.ndarray,
                        n_nodes: int, n_shards: int
                        ) -> tuple[np.ndarray, np.ndarray]:
    """Sort edges by receiver shard AND pad per-shard edge counts equal.

    This is the preprocessing contract of the sharded MACE message-passing
    path (models/mace._a_features_sharded): with edges grouped by receiver
    shard, every device scatters only into its local node range.  Padding
    edges are self-loops on the shard's first node with zero weight (callers
    must mask them via edge_mask).
    Returns (senders, receivers, edge_mask) all of length
    n_shards * max_per_shard.
    """
    n_loc = n_nodes // n_shards
    shard = np.minimum(receivers // n_loc, n_shards - 1)
    order = np.argsort(shard, kind="stable")
    s, r = senders[order], receivers[order]
    shard = shard[order]
    counts = np.bincount(shard, minlength=n_shards)
    m = int(counts.max())
    out_s = np.zeros((n_shards, m), np.int32)
    out_r = np.zeros((n_shards, m), np.int32)
    mask = np.zeros((n_shards, m), np.float32)
    start = 0
    for sh in range(n_shards):
        c = counts[sh]
        out_s[sh, :c] = s[start:start + c]
        out_r[sh, :c] = r[start:start + c]
        out_s[sh, c:] = sh * n_loc
        out_r[sh, c:] = sh * n_loc
        mask[sh, :c] = 1.0
        start += c
    return out_s.reshape(-1), out_r.reshape(-1), mask.reshape(-1)


def batched_molecules(batch: int, n_nodes: int, n_edges: int, seed: int = 0):
    """Batch of small molecule-like graphs, flattened with graph_ids."""
    rng = np.random.default_rng(seed)
    pos = rng.uniform(-2.5, 2.5, size=(batch, n_nodes, 3)).astype(np.float32)
    species = rng.integers(0, 8, size=(batch, n_nodes)).astype(np.int32)
    senders, receivers, gids = [], [], []
    for g in range(batch):
        d = np.linalg.norm(pos[g][:, None] - pos[g][None], axis=-1)
        np.fill_diagonal(d, np.inf)
        # keep the n_edges shortest directed edges
        s, r = np.unravel_index(np.argsort(d, axis=None)[:n_edges], d.shape)
        senders.append(s + g * n_nodes)
        receivers.append(r + g * n_nodes)
        gids.append(np.full(n_nodes, g))
    return {
        "positions": pos.reshape(-1, 3),
        "species": species.reshape(-1),
        "senders": np.concatenate(senders).astype(np.int32),
        "receivers": np.concatenate(receivers).astype(np.int32),
        "graph_ids": np.concatenate(gids).astype(np.int32),
        "n_graphs": batch,
    }

"""Criteo-like synthetic recsys stream: correlated sparse ids + CTR labels."""
from __future__ import annotations

import numpy as np


class CTRStream:
    """Synthetic click stream with a planted (learnable) logit structure."""

    def __init__(self, table_sizes, n_dense: int = 0, seed: int = 0,
                 multi_hot: int = 1):
        self.sizes = [int(s) for s in table_sizes]
        self.n_dense = n_dense
        self.rng = np.random.default_rng(seed)
        # planted per-field weights that define ground-truth CTR
        self.field_w = [self.rng.normal(scale=0.5, size=min(s, 1024))
                        for s in self.sizes]
        self.dense_w = self.rng.normal(scale=0.3, size=n_dense)

    def batch(self, b: int) -> dict:
        out = {}
        sparse = np.stack(
            [self.rng.zipf(1.3, size=b).clip(max=s) - 1 for s in self.sizes],
            axis=1).astype(np.int32)
        out["sparse"] = sparse
        logit = sum(self.field_w[i][sparse[:, i] % len(self.field_w[i])]
                    for i in range(len(self.sizes)))
        if self.n_dense:
            dense = self.rng.normal(size=(b, self.n_dense)).astype(np.float32)
            out["dense"] = dense
            logit = logit + dense @ self.dense_w
        p = 1.0 / (1.0 + np.exp(-logit + 1.5))
        out["labels"] = (self.rng.uniform(size=b) < p).astype(np.float32)
        return out

    def batches(self, b: int):
        while True:
            yield self.batch(b)


class BehaviorStream:
    """MIND-style user behavior sequences over a clustered item catalog."""

    def __init__(self, n_items: int, hist_len: int = 50, n_tastes: int = 64,
                 seed: int = 0):
        self.n_items = n_items
        self.hist_len = hist_len
        self.rng = np.random.default_rng(seed)
        self.item_taste = self.rng.integers(0, n_tastes, size=n_items)
        self.taste_items = [np.where(self.item_taste == t)[0]
                            for t in range(n_tastes)]
        self.n_tastes = n_tastes

    def batch(self, b: int) -> dict:
        # each user mixes 1-3 tastes; target comes from one of them
        hist = np.empty((b, self.hist_len), np.int32)
        target = np.empty((b,), np.int32)
        for u in range(b):
            k = self.rng.integers(1, 4)
            tastes = self.rng.choice(self.n_tastes, size=k, replace=False)
            pools = [self.taste_items[t] for t in tastes
                     if len(self.taste_items[t])]
            if not pools:
                pools = [np.arange(self.n_items)]
            picks = [self.rng.choice(p, size=self.hist_len) for p in pools]
            mix = self.rng.integers(0, len(pools), size=self.hist_len)
            hist[u] = np.choose(mix, picks)
            target[u] = self.rng.choice(pools[self.rng.integers(len(pools))])
        return {"hist": hist, "target": target,
                "labels": np.ones((b,), np.float32)}

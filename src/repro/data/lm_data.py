"""Synthetic LM token streams (offline env) + sharded batch iterator.

A Zipf-distributed Markov token generator gives a learnable (non-uniform
bigram) distribution so train-loss curves are meaningful in examples/tests.
"""
from __future__ import annotations

import numpy as np


class MarkovTokens:
    """Order-1 Markov chain over the vocab with Zipfian stationary dist."""

    def __init__(self, vocab_size: int, branch: int = 20, seed: int = 0):
        self.vocab = vocab_size
        self.branch = branch
        self.rng = np.random.default_rng(seed)
        # per-token successor table (sparse transition structure)
        self.successors = self.rng.integers(
            0, vocab_size, size=(vocab_size, branch)).astype(np.int32)
        w = 1.0 / np.arange(1, branch + 1)
        self.w = w / w.sum()

    def sample(self, batch: int, seq_len: int) -> np.ndarray:
        out = np.empty((batch, seq_len + 1), np.int32)
        cur = self.rng.integers(0, self.vocab, size=batch)
        out[:, 0] = cur
        for t in range(1, seq_len + 1):
            pick = self.rng.choice(self.branch, size=batch, p=self.w)
            cur = self.successors[cur, pick]
            out[:, t] = cur
        return out

    def batches(self, batch: int, seq_len: int):
        while True:
            tok = self.sample(batch, seq_len)
            yield {"tokens": tok[:, :-1], "labels": tok[:, 1:]}
